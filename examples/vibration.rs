//! Piezoelectric vibration learning (paper §6.3) with heuristic sweep.
//!
//!     cargo run --release --example vibration
//!
//! Runs the §6.3 gesture protocol (alternating gentle/abrupt hours, 100
//! gestures each) under all four example-selection policies and shows the
//! §7.3 effect: the heuristics reach the same accuracy while learning far
//! fewer examples than learn-everything.

use ilearn::apps::{AppConfig, AppKind};
use ilearn::selection::Heuristic;

const H: u64 = 3_600_000_000;

fn main() -> anyhow::Result<()> {
    println!("4 h vibration runs, one per selection heuristic:");
    println!(
        "{:<14} {:>8} {:>9} {:>10} {:>10} {:>9}",
        "heuristic", "learned", "discarded", "energy_mJ", "final_acc", "mean_acc"
    );
    for h in Heuristic::ALL {
        let mut cfg = AppConfig::new(AppKind::Vibration, 42, 4 * H);
        cfg.heuristic = h;
        let r = cfg.build_engine()?.run()?;
        println!(
            "{:<14} {:>8} {:>9} {:>10.1} {:>10.2} {:>9.2}",
            h.name(),
            r.learned,
            r.discarded_select,
            r.energy_uj / 1000.0,
            r.final_accuracy(),
            r.mean_accuracy(3)
        );
    }
    println!();
    println!(
        "(the paper's §7.3 finding: selection reaches comparable accuracy\n\
         with ~half the learned examples; k-last is the most expensive\n\
         heuristic, randomized the cheapest — see `ilearn figure fig17`)"
    );
    Ok(())
}
