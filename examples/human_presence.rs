//! RF-powered human-presence learning with area moves (paper §6.2).
//!
//!     cargo run --release --example human_presence
//!
//! The device harvests from an RF source and detects humans from
//! short-term RSSI variation. Every 8 simulated hours it is moved to a
//! different area whose RF baseline is different — accuracy drops, then
//! recovers as the learner re-adapts (Fig. 7(c)'s headline behaviour).
//! A running-mean threshold baseline is run on the same world for
//! comparison; it never recovers properly.

use ilearn::apps::{AppConfig, AppKind, SchedulerKind};
use ilearn::baselines::RunningMeanThreshold;

const H: u64 = 3_600_000_000;

fn main() -> anyhow::Result<()> {
    let horizon = 24 * H;
    let il_cfg = AppConfig::new(AppKind::Presence, 42, horizon);
    println!("running the intermittent presence learner (24 h, moves at 8 h / 16 h)...");
    let il = il_cfg.build_engine()?.run()?;

    let mut base_cfg = AppConfig::new(AppKind::Presence, 42, horizon);
    base_cfg.scheduler = SchedulerKind::Alpaca { learn_pct: 0.5 };
    let mut engine = base_cfg.build_engine()?;
    engine.learner = Box::new(RunningMeanThreshold::new(0, 2.5));
    println!("running the RSSI running-mean threshold baseline on the same world...");
    let base = engine.run()?;

    println!();
    println!("hour | intermittent-learning | threshold baseline");
    for (c_il, c_b) in il.checkpoints.iter().zip(&base.checkpoints) {
        let h = c_il.t_us / H;
        let marker = if h == 8 || h == 16 { "  <- moved" } else { "" };
        println!(
            "{:>4} |         {:.2}          |       {:.2}{}",
            h, c_il.accuracy, c_b.accuracy, marker
        );
    }
    println!();
    println!(
        "means: IL {:.2} vs baseline {:.2} (paper: baseline stays < 0.50)",
        il.mean_accuracy(3),
        base.mean_accuracy(3)
    );
    Ok(())
}
