//! Quickstart: run the vibration intermittent learner for two simulated
//! hours on the native backend and print what happened.
//!
//!     cargo run --release --example quickstart
//!
//! This exercises the whole L3 coordinator — piezo harvester, capacitor,
//! NVM-atomic actions, dynamic action planner, round-robin example
//! selection, NN-k-means learner — on the paper's §6.3 gesture protocol.

use ilearn::apps::{AppConfig, AppKind};

const H: u64 = 3_600_000_000;

fn main() -> anyhow::Result<()> {
    let cfg = AppConfig::new(AppKind::Vibration, 42, 2 * H);
    println!("building the vibration app (piezo harvester, NN-k-means)...");
    let r = cfg.build_engine()?.run()?;

    println!("simulated 2 h of the paper's gesture protocol:");
    println!("  wake cycles     {}", r.cycles);
    println!("  sensed          {}", r.sensed);
    println!("  learned         {} (selection discarded {})", r.learned, r.discarded_select);
    println!("  inferences      {}", r.inferred);
    println!("  power failures  {}", r.power_failures);
    println!("  energy          {:.1} mJ", r.energy_uj / 1000.0);
    println!("  final accuracy  {:.2}", r.final_accuracy());
    println!();
    println!("accuracy trajectory (learning the two shaking classes):");
    for c in r.checkpoints.iter().step_by(2) {
        let bars = (c.accuracy * 40.0) as usize;
        println!(
            "  t={:>4.1}h {:>5.2} {}",
            c.t_us as f64 / H as f64,
            c.accuracy,
            "#".repeat(bars)
        );
    }
    Ok(())
}
