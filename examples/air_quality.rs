//! Air-quality learning on solar harvesting (paper §6.1).
//!
//!     cargo run --release --example air_quality -- [days]
//!
//! Reproduces the deployment scenario: a solar-charged supercap wakes the
//! learner during daylight; the k-NN anomaly learner tracks UV/eCO2/TVOC
//! and its 90th-percentile anomaly threshold evolves as it learns. At
//! night the system is off; buffered examples are learned when the sun
//! returns (the behaviour Fig. 15(a) shows).

use ilearn::apps::{AppConfig, AppKind};

const H: u64 = 3_600_000_000;

fn main() -> anyhow::Result<()> {
    let days: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let cfg = AppConfig::new(AppKind::AirQuality, 42, days * 24 * H);
    println!("running the solar air-quality learner for {days} simulated day(s)...");
    let r = cfg.build_engine()?.run()?;

    println!(
        "learned {} examples ({} sensed, {} discarded by selection), {} inferences",
        r.learned, r.sensed, r.discarded_select, r.inferred
    );
    println!(
        "energy {:.1} mJ over {} wake cycles; mean accuracy {:.2}",
        r.energy_uj / 1000.0,
        r.cycles,
        r.mean_accuracy(4)
    );
    println!();
    println!("diurnal pattern (accuracy | capacitor voltage):");
    for c in &r.checkpoints {
        let hod = (c.t_us / H) % 24;
        let night = !(6..19).contains(&hod);
        println!(
            "  day {} {:02}:00 {} acc={:.2} V={:.2} learned={}",
            c.t_us / (24 * H),
            hod,
            if night { "(night)" } else { "       " },
            c.accuracy,
            c.voltage,
            c.learned
        );
    }
    Ok(())
}
