//! END-TO-END DRIVER — the full three-layer stack on a real workload.
//!
//!     make artifacts && cargo run --release --example end_to_end
//!
//! Proves all layers compose: the L1 Pallas kernels (pairwise distances,
//! competitive update, feature extraction) were lowered through the L2 JAX
//! model to HLO-text artifacts at build time; this binary loads them on
//! the PJRT CPU client (L3 `runtime`) and the rust coordinator drives the
//! paper's vibration and presence workloads through them — Python never
//! runs. Results (accuracy, energy, learned counts) are reported alongside
//! a native-backend control run, and backend agreement is checked.
//! The headline metric recorded in EXPERIMENTS.md comes from this run.

use ilearn::apps::{AppConfig, AppKind, BackendKind};
use std::time::Instant;

const H: u64 = 3_600_000_000;

fn run(kind: AppKind, hours: u64, backend: BackendKind) -> anyhow::Result<ilearn::sim::RunResult> {
    let mut cfg = AppConfig::new(kind, 42, hours * H);
    cfg.backend = backend;
    Ok(cfg.build_engine()?.run()?)
}

fn main() -> anyhow::Result<()> {
    println!("== end-to-end: rust coordinator driving AOT PJRT artifacts ==\n");

    for (kind, hours) in [(AppKind::Vibration, 4u64), (AppKind::Presence, 6u64)] {
        println!("--- {} ({} simulated hours) ---", kind.name(), hours);
        let t0 = Instant::now();
        let pjrt = run(kind, hours, BackendKind::Pjrt)?;
        let pjrt_wall = t0.elapsed();
        let t1 = Instant::now();
        let native = run(kind, hours, BackendKind::Native)?;
        let native_wall = t1.elapsed();

        println!(
            "  pjrt  : learned {:>4}  inferred {:>6}  energy {:>9.1} mJ  final acc {:.2}  wall {:>6.2}s",
            pjrt.learned,
            pjrt.inferred,
            pjrt.energy_uj / 1000.0,
            pjrt.final_accuracy(),
            pjrt_wall.as_secs_f64()
        );
        println!(
            "  native: learned {:>4}  inferred {:>6}  energy {:>9.1} mJ  final acc {:.2}  wall {:>6.2}s",
            native.learned,
            native.inferred,
            native.energy_uj / 1000.0,
            native.final_accuracy(),
            native_wall.as_secs_f64()
        );
        anyhow::ensure!(
            pjrt.learned == native.learned && pjrt.inferred == native.inferred,
            "backend divergence: pjrt ({}, {}) vs native ({}, {})",
            pjrt.learned,
            pjrt.inferred,
            native.learned,
            native.inferred
        );
        let da = (pjrt.final_accuracy() - native.final_accuracy()).abs();
        anyhow::ensure!(da < 0.11, "accuracy divergence {da}");
        println!("  backends agree (identical decisions; |Δacc| = {da:.3})\n");
    }

    println!("all layers compose: Pallas (L1) -> JAX/HLO (L2) -> rust+PJRT (L3). OK");
    Ok(())
}
