# L2: paper's jax model — the numeric payload of each learning action,
# composed from the L1 Pallas kernels. These are the functions that
# python/compile/aot.py lowers ONCE to HLO text; the rust coordinator
# (L3) executes the resulting artifacts on its PJRT CPU client and never
# imports python at runtime.
#
# Payloads (shapes are the canonical artifact shapes from kernels.ref):
#   extract        : (W=64, C=4) window            -> (C, 8) features
#   knn_learn      : (N=64, F=32) buffer + mask    -> (scores (N,), AS_TH ())
#   knn_infer      : buffer + mask + example       -> anomaly score ()
#   knn_infer_batch: buffer + mask + (B=16, F)     -> scores (B,)   [perf]
#   kmeans_learn   : (K=2, F) weights, example, eta-> (new_w, acts)
#   kmeans_infer   : weights, example              -> acts (K,)
#   diversity_repr : k-last-lists selection scores (Eq. 2/3) in one call
#
# The k-NN top-k / percentile-threshold logic lives here (XLA top_k + sort)
# rather than inside the Pallas kernels: it is O(N log N) sorting work that
# XLA already fuses well, while the O(N^2 F) distance work is the kernel's
# job.

import jax
import jax.numpy as jnp

from .kernels import competitive, features, pairwise
from .kernels.ref import BATCH, K_NEIGHBORS, PCTL

_BIG = jnp.float32(3.4e38)


def _sum_k_smallest(d, k):
    """Sum of the k smallest entries along the last axis.

    Implemented as k rounds of argmin + mask rather than `lax.top_k`: the
    crate's xla_extension 0.5.1 HLO-text parser predates the `largest=`
    attribute jax >= 0.4.30 emits on the TopK custom-call, so exported
    payloads must stick to primitive HLO ops. k is 3; the extra passes are
    noise next to the O(N^2 F) distance work.
    """
    total = jnp.zeros(d.shape[:-1], jnp.float32)
    n = d.shape[-1]
    for _ in range(k):
        idx = jnp.argmin(d, axis=-1)
        m = jnp.min(d, axis=-1)
        total = total + m
        onehot = jax.nn.one_hot(idx, n, dtype=jnp.float32)
        d = d + onehot * _BIG  # knock out exactly one occurrence
    return total


def extract(window):
    """`extract` action payload: window -> per-channel feature matrix."""
    return (features.extract_features(window),)


def _mask_invalid(d, mask):
    """Push distances to padded buffer rows out of top-k range."""
    return jnp.where(mask[None, :] > 0.5, d, _BIG)


def knn_learn(examples, mask):
    """`learn` payload for the k-NN anomaly learner (§6.1).

    Recomputes every buffered example's anomaly score
    AS_i = sum_{j in kNN(i)} d(e_i, e_j) and the detection threshold
    AS_TH = 90th percentile of the valid scores.
    """
    n = examples.shape[0]
    d2 = pairwise.pairwise_sq_dists(examples, examples)
    d = jnp.sqrt(d2)
    d = _mask_invalid(d, mask)
    d = jnp.where(jnp.eye(n, dtype=bool), _BIG, d)  # exclude self
    knn_sum = _sum_k_smallest(d, K_NEIGHBORS)
    cnt = jnp.sum(mask)
    enough = cnt > K_NEIGHBORS
    scores = jnp.where((mask > 0.5) & enough, knn_sum, 0.0)
    sortkey = jnp.where(mask > 0.5, scores, -_BIG)
    ss = jnp.sort(sortkey)
    idx = n - cnt + jnp.ceil(PCTL * cnt) - 1.0
    idx = jnp.clip(idx, 0, n - 1).astype(jnp.int32)
    thr = jnp.where(enough, ss[idx], jnp.float32(0.0))
    return scores, thr


def knn_infer(examples, mask, x):
    """`infer` payload: anomaly score of one new example."""
    d2 = pairwise.pairwise_sq_dists(x[None, :], examples, block_n=1)
    d = _mask_invalid(jnp.sqrt(d2), mask)
    score = _sum_k_smallest(d, K_NEIGHBORS)[0]
    return (jnp.where(jnp.sum(mask) >= K_NEIGHBORS, score, 0.0),)


def knn_infer_batch(examples, mask, xs):
    """Batched `infer` payload (B queries per dispatch) — amortizes the
    PJRT call overhead on the rust hot path; see EXPERIMENTS.md §Perf."""
    d2 = pairwise.pairwise_sq_dists(xs, examples, block_n=BATCH)
    d = _mask_invalid(jnp.sqrt(d2), mask)
    scores = _sum_k_smallest(d, K_NEIGHBORS)
    ok = jnp.sum(mask) >= K_NEIGHBORS
    return (jnp.where(ok, scores, jnp.zeros_like(scores)),)


def kmeans_learn(w, x, eta):
    """`learn` payload for the NN-k-means learner (§6.3): one competitive
    step. Returns (new_w, acts); the host keeps new_w in NVM."""
    return competitive.competitive_step(w, x, eta)


def kmeans_infer(w, x):
    """`infer` payload: cluster activations (host argmaxes the winner)."""
    acts = competitive.competitive_step(w, x, jnp.float32(0.0))[1]
    return (acts,)


def diversity_repr(b, bp, x):
    """k-last-lists heuristic payload (§5.2, Eq. 2/3): returns
    [div(B), div(B+x), rep(B, B'), rep(B+x, B')] in one dispatch so the
    `select` action costs a single artifact call."""
    k, _ = b.shape
    bx = jnp.concatenate([b, x[None, :]], axis=0)  # (k+1, f)
    d_bb = jnp.sqrt(pairwise.pairwise_sq_dists(b, b, block_n=k, block_m=k))
    d_xx = jnp.sqrt(
        pairwise.pairwise_sq_dists(bx, bx, block_n=k + 1, block_m=k + 1)
    )
    d_bp = jnp.sqrt(pairwise.pairwise_sq_dists(b, bp, block_n=k, block_m=k))
    d_xp = jnp.sqrt(
        pairwise.pairwise_sq_dists(bx, bp, block_n=k + 1, block_m=k)
    )
    div_b = jnp.sum(d_bb) / jnp.float32(k * k)
    div_bx = jnp.sum(d_xx) / jnp.float32((k + 1) * (k + 1))
    rep_b = jnp.mean(d_bp)
    rep_bx = jnp.mean(d_xp)
    return (jnp.stack([div_b, div_bx, rep_b, rep_bx]),)


# ----------------------------------------------------------------------
# Export table used by aot.py: name -> (fn, example-arg ShapeDtypeStructs).
def export_specs():
    from .kernels.ref import (
        CHANNELS,
        FEAT_DIM,
        KLAST,
        N_BUF,
        N_CLUSTERS,
        WINDOW,
    )

    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return {
        "extract": (extract, (s((WINDOW, CHANNELS), f32),)),
        "knn_learn": (
            knn_learn,
            (s((N_BUF, FEAT_DIM), f32), s((N_BUF,), f32)),
        ),
        "knn_infer": (
            knn_infer,
            (s((N_BUF, FEAT_DIM), f32), s((N_BUF,), f32), s((FEAT_DIM,), f32)),
        ),
        "knn_infer_batch": (
            knn_infer_batch,
            (
                s((N_BUF, FEAT_DIM), f32),
                s((N_BUF,), f32),
                s((BATCH, FEAT_DIM), f32),
            ),
        ),
        "kmeans_learn": (
            kmeans_learn,
            (s((N_CLUSTERS, FEAT_DIM), f32), s((FEAT_DIM,), f32), s((), f32)),
        ),
        "kmeans_infer": (
            kmeans_infer,
            (s((N_CLUSTERS, FEAT_DIM), f32), s((FEAT_DIM,), f32)),
        ),
        "diversity_repr": (
            diversity_repr,
            (
                s((KLAST, FEAT_DIM), f32),
                s((KLAST, FEAT_DIM), f32),
                s((FEAT_DIM,), f32),
            ),
        ),
    }
