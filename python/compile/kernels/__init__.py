"""L1 Pallas kernels (compute hot-spots) + pure-jnp oracle (ref)."""

from . import competitive, features, pairwise, ref  # noqa: F401
