"""L1 Pallas kernel: winner-take-all competitive-learning step.

Paper §6.3 (neural-network k-means): activation a_j = sum_i w_ij x_i; only
the winner neuron (largest activation) is updated, dw = eta * (x - w_win).
One fused kernel keeps the weight matrix resident in VMEM across the
activation matvec and the masked update — the paper's MCU implementation
does two passes over FRAM; fusing halves the (simulated) memory traffic and
on a real TPU avoids a second HBM round-trip for W.

Shapes are tiny ((K=2, F=32)); the value of the kernel is structural: it is
the `learn` action's entire numeric payload, so the AOT'd HLO module for
`kmeans_learn` is a single fused unit the rust coordinator invokes once per
learned example.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _competitive_kernel(w_ref, x_ref, eta_ref, neww_ref, acts_ref):
    w = w_ref[...]  # (K, F)
    x = x_ref[...]  # (1, F)  (kept 2-D for TPU layout friendliness)
    eta = eta_ref[0, 0]
    # Activation a_j = -||x - w_j||^2 (the normalized-input equivalent of
    # the paper's dot-product activation; see ref.py). K*F is tiny (2x32),
    # so the direct VPU form beats a Gram-form matmul and matches the
    # oracle bit-for-bit in summation order.
    diff = w - x  # (K, F) broadcast over the 1-row x
    acts = -jnp.sum(diff * diff, axis=-1)  # (K,)
    winner = jnp.argmax(acts)
    k = w.shape[0]
    onehot = (jax.lax.iota(jnp.int32, k) == winner).astype(jnp.float32)
    neww_ref[...] = w + eta * onehot[:, None] * (x - w)
    acts_ref[...] = acts[None, :]


@jax.jit
def competitive_step(w, x, eta):
    """(K, F) weights, (F,) input, scalar eta -> (new_w (K, F), acts (K,))."""
    k, f = w.shape
    new_w, acts = pl.pallas_call(
        _competitive_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((k, f), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
        ),
        interpret=True,
    )(
        w.astype(jnp.float32),
        x.astype(jnp.float32)[None, :],
        jnp.asarray(eta, jnp.float32)[None, None],
    )
    return new_w, acts[0]
