"""L1 Pallas kernel: windowed feature extraction (the `extract` action).

Computes the paper's feature set — mean, std, median, RMS, peak-to-peak,
zero-crossing rate, average absolute acceleration variation (§6.1, §6.3) —
for every channel of a (W, C) sensor window in one VMEM-resident pass.
The window is tiny (64 x 4 x 4 B = 1 KiB) so a single program instance
holds everything; the win over the MCU implementation is the same as for
the other kernels: one fused module per action, invoked once per `extract`.

The median uses a full sort along the window axis; W is static so the sort
lowers to a fixed sorting network in XLA.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _features_kernel(w_ref, o_ref):
    w = w_ref[...]  # (W, C) f32
    n = w.shape[0]
    mean = jnp.mean(w, axis=0)
    var = jnp.mean(w * w, axis=0) - mean * mean
    std = jnp.sqrt(jnp.maximum(var, 0.0))
    srt = jnp.sort(w, axis=0)
    # W is even for all artifact shapes; average the two middle samples.
    med = 0.5 * (srt[n // 2 - 1] + srt[n // 2])
    rms = jnp.sqrt(jnp.mean(w * w, axis=0))
    p2p = jnp.max(w, axis=0) - jnp.min(w, axis=0)
    centered = w - mean[None, :]
    sign = jnp.where(centered >= 0.0, 1.0, -1.0)
    zcr = jnp.sum(jnp.abs(sign[1:] - sign[:-1]), axis=0) / (2.0 * (n - 1))
    diff = w[1:] - w[:-1]
    aav = jnp.mean(jnp.abs(diff), axis=0)
    mav = jnp.mean(jnp.abs(w), axis=0)
    o_ref[...] = jnp.stack([mean, std, med, rms, p2p, zcr, aav, mav], axis=-1)


@jax.jit
def extract_features(window):
    """(W, C) window -> (C, 8) features; see ref.extract_features."""
    w, c = window.shape
    return pl.pallas_call(
        _features_kernel,
        out_shape=jax.ShapeDtypeStruct((c, 8), jnp.float32),
        interpret=True,
    )(window.astype(jnp.float32))
