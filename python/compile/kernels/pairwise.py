"""L1 Pallas kernel: tiled pairwise squared-Euclidean-distance matrix.

The paper's k-NN anomaly learner (§6.1) computes
d(e_i, e_j) = sqrt(sum_m (f_m^i - f_m^j)^2) for all pairs in the example
buffer — on the MSP430 this is a scalar double loop. TPU adaptation
(DESIGN.md §Hardware-Adaptation): reformulate as the Gram identity

    D2[i, j] = ||x_i||^2 + ||y_j||^2 - 2 * (X @ Y^T)[i, j]

so the O(N^2 F) work becomes one MXU-shaped matmul plus rank-1 row/column
norm broadcasts. The kernel is tiled with BlockSpec over an (N/bn, M/bm)
grid: each program instance holds an (bn, F) X-tile and an (bm, F) Y-tile
in VMEM and emits one (bn, bm) output tile. For the canonical artifact
shapes (N = M = 64, F = 32) the whole problem is a single block
(64*32*4 B * 2 inputs + 64*64*4 B out ≈ 32 KiB VMEM), but the grid code
path is exercised by tests with larger N.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; real-TPU performance is estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_kernel(x_ref, y_ref, o_ref):
    """One (bn, bm) tile: D2 = xn + yn^T - 2 X Y^T, clamped at 0."""
    x = x_ref[...]  # (bn, F)
    y = y_ref[...]  # (bm, F)
    xn = jnp.sum(x * x, axis=-1, keepdims=True)  # (bn, 1)
    yn = jnp.sum(y * y, axis=-1, keepdims=True)  # (bm, 1)
    # fp32 accumulation on the MXU path
    g = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bn, bm)
    o_ref[...] = jnp.maximum(xn + yn.T - 2.0 * g, 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m"))
def pairwise_sq_dists(x, y, *, block_n=64, block_m=64):
    """Pairwise squared distances between rows of x (n, f) and y (m, f).

    n and m must be multiples of the block sizes (callers pad; the
    canonical buffers are already 64-row).
    """
    n, f = x.shape
    m, _ = y.shape
    bn = min(block_n, n)
    bm = min(block_m, m)
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    grid = (n // bn, m // bm)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, f), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
