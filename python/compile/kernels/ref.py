"""Pure-jnp reference oracle for every L1 Pallas kernel and L2 payload.

This file is the *correctness ground truth* of the compile path: each
function is a straightforward (unoptimized, loop-free jnp) transcription of
the math in the paper:

  - pairwise Euclidean distance  d(e_i, e_j) = sqrt(sum_m (f_m^i - f_m^j)^2)
    (paper §6.1, feature distance for the k-NN anomaly learner),
  - k-NN anomaly score  AS_i = sum over the k nearest neighbours of d(e_i, .)
    with the anomaly threshold AS_TH = 90th percentile of scores (§6.1),
  - competitive-learning (neural-network k-means) activation and update
    a_j = sum_i w_ij x_i ; winner = argmax_j a_j ; dw = eta * (x - w_winner)
    (§6.3),
  - windowed feature extraction: mean, std, median, RMS, P2P, ZCR, AAV
    (§6.1 and §6.3 feature sets; superset of both).

pytest pins the Pallas kernels (kernels/*.py) and the AOT'd HLO modules to
these functions via assert_allclose.
"""

import jax
import jax.numpy as jnp

# Canonical artifact shapes (shared with aot.py and the rust runtime).
WINDOW = 64  # samples per sensing window
CHANNELS = 4  # sensor channels (apps use a prefix, rest zero)
N_FEATURES = 8  # features per channel
FEAT_DIM = CHANNELS * N_FEATURES  # flattened example dimension (32)
N_BUF = 64  # example-buffer capacity for the k-NN learner
K_NEIGHBORS = 3  # paper's k for the anomaly score
N_CLUSTERS = 2  # normal / abnormal (paper's NN k-means)
PCTL = 0.9  # anomaly-threshold percentile (90th, §6.1)
BATCH = 16  # batched-inference artifact width
KLAST = 4  # k-last-lists heuristic list length (artifact shape)


def pairwise_sq_dists(x, y):
    """Squared Euclidean distance matrix via the Gram-matrix identity.

    ||a - b||^2 = ||a||^2 + ||b||^2 - 2 a.b  — one matmul instead of an
    O(N^2 F) subtraction loop; this is the formulation the Pallas kernel
    tiles for the MXU.
    """
    xn = jnp.sum(x * x, axis=-1, keepdims=True)  # (n, 1)
    yn = jnp.sum(y * y, axis=-1, keepdims=True).T  # (1, m)
    d = xn + yn - 2.0 * (x @ y.T)
    return jnp.maximum(d, 0.0)  # clamp numeric negatives


def knn_scores(examples, mask, k=K_NEIGHBORS):
    """Anomaly score for every (valid) example in the buffer.

    examples : (N, F) float32, rows >= count are padding
    mask     : (N,) float32 1.0 valid / 0.0 padding
    Returns (scores (N,), threshold ()): score_i = sum of distances to the
    k nearest *other* valid examples; threshold = 90th percentile of the
    valid scores. Padded rows get score 0.
    """
    n = examples.shape[0]
    d2 = pairwise_sq_dists(examples, examples)
    d = jnp.sqrt(d2)
    big = jnp.float32(3.4e38)
    # exclude self-distance and padded columns
    invalid = (1.0 - mask)[None, :] > 0.5
    d = jnp.where(invalid | jnp.eye(n, dtype=bool), big, d)
    # k smallest per row == -(k largest of -d)
    neg_topk, _ = jax.lax.top_k(-d, k)
    knn_sum = -jnp.sum(neg_topk, axis=-1)
    # A score is only defined when at least k other valid neighbours exist;
    # the rust native backend applies the same rule.
    valid_cnt = jnp.sum(mask)
    enough = valid_cnt > k
    scores = jnp.where((mask > 0.5) & enough, knn_sum, 0.0)
    # 90th percentile over valid scores: sort with invalid pushed to -inf,
    # then index ceil(0.9 * cnt) - 1 within the valid tail block.
    sortkey = jnp.where(mask > 0.5, scores, -big)
    ss = jnp.sort(sortkey)  # invalid first, valid ascending at the end
    idx = n - valid_cnt + jnp.ceil(PCTL * valid_cnt) - 1.0
    idx = jnp.clip(idx, 0, n - 1).astype(jnp.int32)
    thr = jnp.where(enough, ss[idx], jnp.float32(0.0))
    return scores, thr


def knn_infer(examples, mask, x, k=K_NEIGHBORS):
    """Anomaly score of a new example against the valid buffer rows."""
    d2 = pairwise_sq_dists(x[None, :], examples)[0]
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    big = jnp.float32(3.4e38)
    d = jnp.where(mask > 0.5, d, big)
    neg_topk, _ = jax.lax.top_k(-d, k)
    score = -jnp.sum(neg_topk)
    return jnp.where(jnp.sum(mask) >= k, score, jnp.float32(0.0))


def knn_infer_batch(examples, mask, xs, k=K_NEIGHBORS):
    """Batched variant of knn_infer: xs (B, F) -> scores (B,)."""
    d2 = pairwise_sq_dists(xs, examples)
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    big = jnp.float32(3.4e38)
    d = jnp.where(mask[None, :] > 0.5, d, big)
    neg_topk, _ = jax.lax.top_k(-d, k)
    scores = -jnp.sum(neg_topk, axis=-1)
    return jnp.where(jnp.sum(mask) >= k, scores, jnp.zeros_like(scores))


def competitive_step(w, x, eta):
    """One competitive-learning step (paper §6.3).

    w : (K, F) cluster weights, x : (F,) input, eta: () learning rate.
    Returns (new_w (K, F), acts (K,)).
    Only the winner row (largest activation) moves: w_win += eta*(x - w_win).

    Activation: the paper's text uses a_j = w_j . x; Marsland's NN-k-means
    (the paper's cited formulation) assumes normalized inputs, where the
    dot product is ordering-equivalent to the negative distance. Our
    vibration features are magnitude-separated (gentle vs abrupt differ in
    scale, not direction), for which the raw dot product degenerates (the
    larger-norm neuron wins everything), so we use the normalized-input
    equivalent directly: a_j = -||x - w_j||^2 = 2 w.x - ||w||^2 - ||x||^2.
    Documented in DESIGN.md §Hardware-Adaptation.
    """
    acts = -jnp.sum((w - x[None, :]) ** 2, axis=-1)  # (K,)
    winner = jnp.argmax(acts)
    onehot = jax.nn.one_hot(winner, w.shape[0], dtype=w.dtype)  # (K,)
    new_w = w + eta * onehot[:, None] * (x[None, :] - w)
    return new_w, acts


def kmeans_infer(w, x):
    """Activations for classification; winner = argmax (done host-side)."""
    return -jnp.sum((w - x[None, :]) ** 2, axis=-1)


def extract_features(window):
    """(W, C) sensor window -> (C, 8) feature matrix.

    Features per channel (paper §6.1 + §6.3 union):
      0 mean, 1 std, 2 median, 3 RMS, 4 P2P, 5 ZCR, 6 AAV, 7 mean-abs.
    """
    w = window.astype(jnp.float32)
    n = w.shape[0]
    mean = jnp.mean(w, axis=0)
    std = jnp.std(w, axis=0)
    med = jnp.median(w, axis=0)
    rms = jnp.sqrt(jnp.mean(w * w, axis=0))
    p2p = jnp.max(w, axis=0) - jnp.min(w, axis=0)
    centered = w - mean[None, :]
    sign = jnp.where(centered >= 0.0, 1.0, -1.0)
    zcr = jnp.sum(jnp.abs(jnp.diff(sign, axis=0)), axis=0) / (2.0 * (n - 1))
    aav = jnp.mean(jnp.abs(jnp.diff(w, axis=0)), axis=0)
    mav = jnp.mean(jnp.abs(w), axis=0)
    return jnp.stack([mean, std, med, rms, p2p, zcr, aav, mav], axis=-1)


def diversity(b):
    """Mean pairwise distance within a set (paper Eq. 2), b: (k, F)."""
    k = b.shape[0]
    d = jnp.sqrt(pairwise_sq_dists(b, b))
    return jnp.sum(d) / jnp.float32(k * k)


def representation(b, b_prime):
    """Mean selected<->non-selected distance (paper Eq. 3)."""
    d = jnp.sqrt(pairwise_sq_dists(b, b_prime))
    return jnp.mean(d)
