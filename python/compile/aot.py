# AOT pipeline: lower every L2 payload (model.export_specs) to HLO TEXT
# artifacts the rust runtime loads via HloModuleProto::from_text_file.
#
# HLO *text*, NOT lowered.compile()/.serialize(): jax >= 0.5 emits
# HloModuleProto with 64-bit instruction ids which the xla crate's
# xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The HLO text
# parser reassigns ids, so text round-trips cleanly. See
# /opt/xla-example/README.md and gen_hlo.py.
#
# Usage:  cd python && python -m compile.aot --out ../artifacts
#
# Also writes artifacts/manifest.txt — one line per artifact:
#   name <tab> in=<shape;shape;...> <tab> out=<shape;...>
# which the rust runtime parses to validate buffer sizes at load time.

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True so
    the rust side always unwraps a tuple, regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _fmt_shapes(avals) -> str:
    return ";".join(
        "x".join(str(d) for d in getattr(a, "shape", ())) or "scalar"
        for a in avals
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact dir")
    ap.add_argument(
        "--only", default=None, help="comma-separated subset of payloads"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    specs = model.export_specs()
    if args.only:
        keep = set(args.only.split(","))
        specs = {k: v for k, v in specs.items() if k in keep}

    manifest = []
    for name, (fn, arg_specs) in sorted(specs.items()):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *arg_specs)
        manifest.append(
            f"{name}\tin={_fmt_shapes(arg_specs)}\tout={_fmt_shapes(outs)}"
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(args.out, 'manifest.txt')}")


if __name__ == "__main__":
    main()
