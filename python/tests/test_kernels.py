"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes and dtypes; assert_allclose is the contract.
These tests are the CORE correctness signal of the compile path — if they
pass, the HLO artifacts the rust runtime executes compute the paper's math.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import competitive, features, pairwise, ref

SET = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def rng_array(seed, shape, dtype=np.float32, scale=4.0):
    r = np.random.default_rng(seed)
    return (r.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------- pairwise
@SET
@given(
    n=st.sampled_from([1, 2, 4, 8, 16, 64]),
    m=st.sampled_from([1, 4, 16, 64]),
    f=st.sampled_from([1, 3, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_matches_ref(n, m, f, seed):
    x = rng_array(seed, (n, f))
    y = rng_array(seed + 1, (m, f))
    got = pairwise.pairwise_sq_dists(x, y, block_n=n, block_m=m)
    want = ref.pairwise_sq_dists(jnp.asarray(x), jnp.asarray(y))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


@SET
@given(
    grid_n=st.sampled_from([2, 4]),
    grid_m=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_tiled_grid(grid_n, grid_m, seed):
    """Multi-block grids must agree with the single-block result."""
    bn, bm, f = 16, 8, 8
    x = rng_array(seed, (bn * grid_n, f))
    y = rng_array(seed + 7, (bm * grid_m, f))
    got = pairwise.pairwise_sq_dists(x, y, block_n=bn, block_m=bm)
    want = ref.pairwise_sq_dists(jnp.asarray(x), jnp.asarray(y))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


def test_pairwise_zero_distance_diagonal():
    x = rng_array(0, (8, 8))
    d = np.asarray(pairwise.pairwise_sq_dists(x, x, block_n=8, block_m=8))
    assert_allclose(np.diag(d), np.zeros(8), atol=1e-3)
    assert (d >= 0).all()


def test_pairwise_dtype_promotion():
    """f64 / int inputs are accepted and computed in f32."""
    x64 = rng_array(3, (4, 4)).astype(np.float64)
    got = pairwise.pairwise_sq_dists(x64, x64, block_n=4, block_m=4)
    assert got.dtype == jnp.float32


# ------------------------------------------------------------- competitive
@SET
@given(
    k=st.sampled_from([2, 3, 5]),
    f=st.sampled_from([4, 8, 32]),
    eta=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_competitive_matches_ref(k, f, eta, seed):
    w = rng_array(seed, (k, f), scale=1.0)
    x = rng_array(seed + 1, (f,), scale=1.0)
    got_w, got_a = competitive.competitive_step(w, x, eta)
    want_w, want_a = ref.competitive_step(
        jnp.asarray(w), jnp.asarray(x), jnp.float32(eta)
    )
    assert_allclose(np.asarray(got_a), np.asarray(want_a), rtol=1e-5)
    assert_allclose(np.asarray(got_w), np.asarray(want_w), rtol=2e-5, atol=1e-6)


def test_competitive_only_winner_moves():
    w = np.array([[1.0, 0.0], [0.0, 1.0]], np.float32)
    x = np.array([1.0, 0.1], np.float32)
    new_w, acts = competitive.competitive_step(w, x, 0.5)
    new_w = np.asarray(new_w)
    assert int(np.argmax(np.asarray(acts))) == 0
    assert_allclose(new_w[1], w[1])  # loser untouched
    assert_allclose(new_w[0], w[0] + 0.5 * (x - w[0]))


def test_competitive_eta_zero_identity():
    w = rng_array(5, (2, 32), scale=1.0)
    x = rng_array(6, (32,), scale=1.0)
    new_w, _ = competitive.competitive_step(w, x, 0.0)
    assert_allclose(np.asarray(new_w), w)


def test_competitive_converges_to_input():
    """Repeated updates with the same x pull the winner weight to x."""
    w = rng_array(7, (2, 8), scale=0.1)
    x = np.full((8,), 2.0, np.float32)
    for _ in range(60):
        w, _ = competitive.competitive_step(np.asarray(w), x, 0.3)
    winner = np.asarray(ref.kmeans_infer(jnp.asarray(w), jnp.asarray(x)))
    assert_allclose(np.asarray(w)[int(np.argmax(winner))], x, atol=1e-2)


# ---------------------------------------------------------------- features
@SET
@given(
    w=st.sampled_from([4, 16, 64]),
    c=st.sampled_from([1, 3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_features_match_ref(w, c, seed):
    win = rng_array(seed, (w, c))
    got = features.extract_features(win)
    want = ref.extract_features(jnp.asarray(win))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_features_constant_window():
    win = np.full((64, 4), 3.0, np.float32)
    f = np.asarray(features.extract_features(win))
    mean, std, med, rms, p2p, zcr, aav, mav = f[0]
    assert_allclose(mean, 3.0)
    assert_allclose(std, 0.0, atol=1e-6)
    assert_allclose(med, 3.0)
    assert_allclose(rms, 3.0, rtol=1e-6)
    assert_allclose(p2p, 0.0)
    assert_allclose(aav, 0.0)
    assert_allclose(mav, 3.0)


def test_features_alternating_signal_zcr():
    """+1/-1 alternating signal: ZCR = 1, mean = 0, rms = 1."""
    sig = np.tile(np.array([1.0, -1.0], np.float32), 32)
    win = np.stack([sig] * 4, axis=1)
    f = np.asarray(features.extract_features(win))
    assert_allclose(f[:, 0], 0.0, atol=1e-6)  # mean
    assert_allclose(f[:, 5], 1.0, atol=1e-6)  # zcr
    assert_allclose(f[:, 3], 1.0, rtol=1e-6)  # rms
    assert_allclose(f[:, 4], 2.0)  # p2p
    assert_allclose(f[:, 6], 2.0)  # aav


def test_features_median_even_window():
    win = np.arange(64, dtype=np.float32)[:, None] * np.ones((1, 4), np.float32)
    f = np.asarray(features.extract_features(win))
    assert_allclose(f[:, 2], 31.5)  # median of 0..63


# --------------------------------------------------- selection-score maths
@SET
@given(k=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_diversity_representation_ref_props(k, seed):
    b = rng_array(seed, (k, 8))
    bp = rng_array(seed + 2, (k, 8))
    div = float(ref.diversity(jnp.asarray(b)))
    rep = float(ref.representation(jnp.asarray(b), jnp.asarray(bp)))
    assert div >= 0.0 and rep >= 0.0
    # diversity of identical points is 0
    same = np.tile(b[:1], (k, 1))
    assert float(ref.diversity(jnp.asarray(same))) == pytest.approx(
        0.0, abs=2e-2  # Gram-identity cancellation then sqrt: ~sqrt(eps*scale^2)
    )
