"""L2 correctness: model payloads vs oracle compositions, plus the AOT
manifest/shape contract the rust runtime relies on."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

SET = settings(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def buf(seed, count, scale=3.0):
    """Canonical (N_BUF, FEAT_DIM) buffer with `count` valid rows + mask."""
    r = np.random.default_rng(seed)
    ex = np.zeros((ref.N_BUF, ref.FEAT_DIM), np.float32)
    ex[:count] = r.standard_normal((count, ref.FEAT_DIM)) * scale
    mask = np.zeros((ref.N_BUF,), np.float32)
    mask[:count] = 1.0
    return ex, mask


# ------------------------------------------------------------------- knn
@SET
@given(count=st.integers(4, 64), seed=st.integers(0, 2**31 - 1))
def test_knn_learn_matches_ref(count, seed):
    ex, mask = buf(seed, count)
    scores, thr = model.knn_learn(ex, mask)
    want_s, want_t = ref.knn_scores(jnp.asarray(ex), jnp.asarray(mask))
    assert_allclose(np.asarray(scores), np.asarray(want_s), rtol=1e-4, atol=1e-3)
    assert_allclose(float(thr), float(want_t), rtol=1e-4, atol=1e-3)


def test_knn_learn_padding_rows_zero():
    ex, mask = buf(0, 10)
    scores, _ = model.knn_learn(ex, mask)
    assert_allclose(np.asarray(scores)[10:], 0.0)


def test_knn_learn_too_few_examples():
    """With <= k valid rows the score/threshold are undefined -> 0."""
    ex, mask = buf(1, ref.K_NEIGHBORS)
    scores, thr = model.knn_learn(ex, mask)
    assert_allclose(np.asarray(scores), 0.0)
    assert float(thr) == 0.0


def test_knn_threshold_is_90th_percentile():
    ex, mask = buf(2, 40)
    scores, thr = model.knn_learn(ex, mask)
    s = np.sort(np.asarray(scores)[:40])
    idx = int(np.ceil(0.9 * 40)) - 1
    assert_allclose(float(thr), s[idx], rtol=1e-5)


@SET
@given(count=st.integers(4, 64), seed=st.integers(0, 2**31 - 1))
def test_knn_infer_matches_ref(count, seed):
    ex, mask = buf(seed, count)
    x = np.random.default_rng(seed + 9).standard_normal(ref.FEAT_DIM)
    x = (x * 3).astype(np.float32)
    (score,) = model.knn_infer(ex, mask, x)
    want = ref.knn_infer(jnp.asarray(ex), jnp.asarray(mask), jnp.asarray(x))
    assert_allclose(float(score), float(want), rtol=1e-4, atol=1e-3)


def test_knn_infer_outlier_scores_higher():
    ex, mask = buf(3, 30, scale=1.0)
    near = ex[0] + 0.05
    far = np.full((ref.FEAT_DIM,), 50.0, np.float32)
    (s_near,) = model.knn_infer(ex, mask, near)
    (s_far,) = model.knn_infer(ex, mask, far)
    assert float(s_far) > float(s_near)


@SET
@given(count=st.integers(4, 64), seed=st.integers(0, 2**31 - 1))
def test_knn_infer_batch_matches_scalar(count, seed):
    ex, mask = buf(seed, count)
    r = np.random.default_rng(seed + 13)
    xs = (r.standard_normal((ref.BATCH, ref.FEAT_DIM)) * 3).astype(np.float32)
    (scores,) = model.knn_infer_batch(ex, mask, xs)
    for i in range(0, ref.BATCH, 5):
        (si,) = model.knn_infer(ex, mask, xs[i])
        assert_allclose(
            float(np.asarray(scores)[i]), float(si), rtol=1e-4, atol=1e-3
        )


# ---------------------------------------------------------------- kmeans
@SET
@given(eta=st.floats(0.01, 0.9), seed=st.integers(0, 2**31 - 1))
def test_kmeans_learn_matches_ref(eta, seed):
    r = np.random.default_rng(seed)
    w = r.standard_normal((ref.N_CLUSTERS, ref.FEAT_DIM)).astype(np.float32)
    x = r.standard_normal(ref.FEAT_DIM).astype(np.float32)
    new_w, acts = model.kmeans_learn(w, x, eta)
    want_w, want_a = ref.competitive_step(
        jnp.asarray(w), jnp.asarray(x), jnp.float32(eta)
    )
    assert_allclose(np.asarray(new_w), np.asarray(want_w), rtol=2e-5, atol=1e-6)
    assert_allclose(np.asarray(acts), np.asarray(want_a), rtol=1e-5)


def test_kmeans_infer_is_pure():
    r = np.random.default_rng(11)
    w = r.standard_normal((ref.N_CLUSTERS, ref.FEAT_DIM)).astype(np.float32)
    x = r.standard_normal(ref.FEAT_DIM).astype(np.float32)
    (acts,) = model.kmeans_infer(w, x)
    want = -np.sum((w - x[None, :]) ** 2, axis=-1)
    assert_allclose(np.asarray(acts), want, rtol=1e-4)


# -------------------------------------------------------- diversity_repr
@SET
@given(seed=st.integers(0, 2**31 - 1))
def test_diversity_repr_matches_ref(seed):
    r = np.random.default_rng(seed)
    b = r.standard_normal((ref.KLAST, ref.FEAT_DIM)).astype(np.float32)
    bp = r.standard_normal((ref.KLAST, ref.FEAT_DIM)).astype(np.float32)
    x = r.standard_normal(ref.FEAT_DIM).astype(np.float32)
    (out,) = model.diversity_repr(b, bp, x)
    out = np.asarray(out)
    bx = jnp.concatenate([jnp.asarray(b), jnp.asarray(x)[None, :]])
    assert_allclose(out[0], float(ref.diversity(jnp.asarray(b))), rtol=1e-4)
    assert_allclose(out[1], float(ref.diversity(bx)), rtol=1e-4)
    assert_allclose(
        out[2],
        float(ref.representation(jnp.asarray(b), jnp.asarray(bp))),
        rtol=1e-4,
    )
    assert_allclose(
        out[3], float(ref.representation(bx, jnp.asarray(bp))), rtol=1e-4
    )


# ----------------------------------------------------- AOT export contract
def test_export_specs_cover_all_payloads():
    specs = model.export_specs()
    assert set(specs) == {
        "extract",
        "knn_learn",
        "knn_infer",
        "knn_infer_batch",
        "kmeans_learn",
        "kmeans_infer",
        "diversity_repr",
    }


def test_export_specs_lowerable_and_shapes():
    """Every payload must lower with its example args and produce the
    output shapes the rust runtime expects."""
    specs = model.export_specs()
    out_shapes = {
        "extract": [(ref.CHANNELS, 8)],
        "knn_learn": [(ref.N_BUF,), ()],
        "knn_infer": [()],
        "knn_infer_batch": [(ref.BATCH,)],
        "kmeans_learn": [(ref.N_CLUSTERS, ref.FEAT_DIM), (ref.N_CLUSTERS,)],
        "kmeans_infer": [(ref.N_CLUSTERS,)],
        "diversity_repr": [(4,)],
    }
    for name, (fn, args) in specs.items():
        outs = jax.eval_shape(fn, *args)
        got = [tuple(o.shape) for o in outs]
        assert got == out_shapes[name], name
