"""Executable Python mirror of the Rust charge kernels (toolchain-free check).

Line-for-line port of ``rust/src/sim/world.rs``'s two charge kernels
(``ChargeKernel::Event`` / ``ChargeKernel::Stepped``), the capacitor
energy model, and the Solar piecewise view, driven by the same
charge-phase/eval-clipping loop the engine uses. It exists so the event
kernel's equivalence and speedup claims can be inspected and re-run in
environments without a Rust toolchain (the PR-session sandbox), and it is
the source of the projected speedup recorded in CHANGES.md for PR 2.

Run:

    python3 python/tools/kernel_mirror.py

Expected output (one line per regime): event vs stepped wake counts must
match within a fraction of a percent on smooth sources (identical in the
starved regimes), and the stepped kernel's iteration count shows the cost
the event kernel removes (>10x on the starved 24 h solar cell, ~60x on a
fully dark day).

Keep this file in sync with ``world.rs`` when the kernel changes — it is
a mirror, not a spec.
"""

import math

RESOLVE_US = 60_000_000
SLEEP_HOP_MAX_US = 3_600_000_000
MINUTE_US = 60_000_000
DAY_US = 86_400_000_000
MASK = (1 << 64) - 1


def bucket_noise(seed, bucket):
    """splitmix64 of (seed, bucket), mirroring harvester.rs."""
    z = (seed ^ (bucket * 0x9E3779B97F4A7C15 & MASK)) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    z ^= z >> 31
    return (z >> 11) * (1.0 / (1 << 53))


class Capacitor:
    """Mirror of energy/capacitor.rs (charge/deduct/time_to_wake)."""

    def __init__(self, c_f, v_max, v_on, v_off):
        self.c_f, self.v_max, self.v_on, self.v_off = c_f, v_max, v_on, v_off
        self.leak_w, self.eff, self.v = 2e-6, 0.8, v_off

    def charge(self, p_w, dt_us):
        de = (p_w * self.eff - self.leak_w) * (dt_us / 1e6)
        e = max(0.5 * self.c_f * self.v * self.v + de, 0.0)
        self.v = min(math.sqrt(2 * e / self.c_f), self.v_max)

    def awake_ready(self):
        return self.v >= self.v_on

    def drain(self):
        self.v = self.v_off

    def time_to_wake_s(self, p_w):
        if self.v >= self.v_on:
            return 0.0
        net = p_w * self.eff - self.leak_w
        if net <= 0:
            return None
        return 0.5 * self.c_f * (self.v_on**2 - self.v**2) / net


class Solar:
    """Mirror of harvester.rs Solar incl. the piecewise view."""

    def __init__(self, peak_w=0.045, seed=42 ^ 0xA0):
        self.peak_w, self.seed = peak_w, seed
        self.sunrise_s, self.sunset_s, self.cloud_prob = 6 * 3600.0, 19 * 3600.0, 0.08

    def tex_at(self, minute):
        n1 = bucket_noise(self.seed, minute)
        n2 = bucket_noise(self.seed ^ 0xABCD, minute)
        return (0.85 + 0.15 * n1) * (0.06 if n2 < self.cloud_prob else 1.0)

    def power_w(self, t_us):
        t_s = t_us / 1e6
        tod = t_s % 86400.0
        if tod < self.sunrise_s or tod > self.sunset_s:
            return 0.0
        phase = (tod - self.sunrise_s) / (self.sunset_s - self.sunrise_s)
        irr = max(math.sin(math.pi * phase), 0.0)
        return self.peak_w * irr * self.tex_at(int(t_s / 60.0))

    def _sun_us(self):
        return (
            min(int(self.sunrise_s * 1e6), DAY_US),
            min(int(self.sunset_s * 1e6), DAY_US),
        )

    def segment_end_us(self, t_us):
        sunrise_us, sunset_us = self._sun_us()
        tod = t_us % DAY_US
        day0 = t_us - tod
        if tod < sunrise_us:
            return day0 + sunrise_us
        if tod >= sunset_us:
            return day0 + DAY_US + sunrise_us
        return day0 + sunset_us

    def _tex_mean_weighted(self, lo_us, hi_us):
        m0, m1 = lo_us // MINUTE_US, (hi_us - 1) // MINUTE_US
        if m0 == m1:
            return self.tex_at(m0)
        first_w = (m0 + 1) * MINUTE_US - lo_us
        last_w = hi_us - m1 * MINUTE_US
        acc = self.tex_at(m0) * first_w + self.tex_at(m1) * last_w
        for m in range(m0 + 1, m1):
            acc += self.tex_at(m) * MINUTE_US
        return acc / (hi_us - lo_us)

    def mean_power_w(self, from_us, to_us):
        if to_us <= from_us:
            return self.power_w(from_us)
        sunrise_us, sunset_us = self._sun_us()
        if sunset_us <= sunrise_us:
            return 0.0
        day0 = from_us - from_us % DAY_US
        lo = max(from_us, day0 + sunrise_us)
        hi = min(to_us, day0 + sunset_us)
        if hi <= lo:
            return 0.0
        span_sun = float(sunset_us - sunrise_us)
        ua = (lo - day0 - sunrise_us) / span_sun
        ub = (hi - day0 - sunrise_us) / span_sun
        if ub - ua < 1e-9:
            mean_irr = max(math.sin(math.pi * 0.5 * (ua + ub)), 0.0)
        else:
            mean_irr = max(
                (math.cos(math.pi * ua) - math.cos(math.pi * ub))
                / (math.pi * (ub - ua)),
                0.0,
            )
        tex = self._tex_mean_weighted(lo, hi)
        sunlit = (hi - lo) / (to_us - from_us)
        return self.peak_w * mean_irr * tex * sunlit


class Constant:
    def __init__(self, p):
        self.p = p

    def power_w(self, _t):
        return self.p

    def segment_end_us(self, _t):
        return MASK

    def mean_power_w(self, _a, _b):
        return self.p


class World:
    """Mirror of sim/world.rs World::{charge_event, charge_stepped}."""

    def __init__(self, harvester, cap):
        self.h, self.cap, self.t_us, self.iters = harvester, cap, 0, 0

    def charge_stepped(self, until_us, charge_step_us):
        while self.t_us < until_us:
            if self.cap.awake_ready():
                return True
            p = self.h.power_w(self.t_us)
            tw = self.cap.time_to_wake_s(p)
            step = min(int(tw * 1e6) + 1, charge_step_us) if tw is not None else charge_step_us
            step = min(max(step, 1000), until_us - self.t_us)
            self.cap.charge(p, step)
            self.t_us += step
            self.iters += 1
        return self.cap.awake_ready()

    def charge_event(self, until_us):
        while self.t_us < until_us:
            if self.cap.awake_ready():
                return True
            seg_end = min(max(self.h.segment_end_us(self.t_us), self.t_us + 1), until_us)
            seg_span = seg_end - self.t_us
            p0 = self.h.power_w(self.t_us)
            tw0 = self.cap.time_to_wake_s(p0)
            guess = min(int(tw0 * 1e6) + 1, MASK) if tw0 is not None else seg_span
            end = self.t_us + max(min(RESOLVE_US, seg_span), min(guess, seg_span))
            while True:
                self.iters += 1
                span = end - self.t_us
                p = self.h.mean_power_w(self.t_us, end)
                tw = self.cap.time_to_wake_s(p)
                dt = min(int(tw * 1e6) + 1, MASK) if tw is not None else None
                if dt is not None and dt < span:
                    if span <= RESOLVE_US:
                        self.cap.charge(p, dt)
                        self.t_us += dt
                        break
                    lo = max(min(RESOLVE_US, span - 1), 1)
                    hi = max(span // 2, lo)
                    end = self.t_us + max(lo, min(dt, hi))
                else:
                    hop_end = self.t_us + min(span, SLEEP_HOP_MAX_US)
                    p_hop = p if hop_end == end else self.h.mean_power_w(self.t_us, hop_end)
                    self.cap.charge(p_hop, hop_end - self.t_us)
                    self.t_us = hop_end
                    break
        return self.cap.awake_ready()


def drive(harvester, cap, kernel, hours=24, charge_step_us=60_000_000,
          eval_period_us=3_600_000_000):
    """Engine charge-phase mirror: wake bursts emulated as a full drain."""
    world = World(harvester, cap)
    horizon = hours * 3_600_000_000
    next_eval = 0
    wakes = 0
    while world.t_us < horizon:
        awake = False
        while True:
            if world.cap.awake_ready():
                awake = world.t_us < horizon
                break
            if world.t_us >= horizon:
                break
            if world.t_us >= next_eval:
                next_eval = world.t_us + eval_period_us
            until = min(horizon, max(next_eval, world.t_us + 1))
            ok = (world.charge_event(until) if kernel == "event"
                  else world.charge_stepped(until, charge_step_us))
            if ok:
                awake = world.t_us < horizon
                break
        if not awake:
            break
        wakes += 1
        world.cap.drain()
        world.t_us += 1_000_000
    return wakes, world.iters


def main():
    aq_cap = (0.2, 3.3, 2.8, 2.0)  # air-quality 0.2 F supercap
    regimes = [
        ("solar 45mW (preset)", lambda: Solar(), aq_cap, 3_600_000_000),
        ("solar 0.5mW (starved, 6h eval)", lambda: Solar(peak_w=0.0005), aq_cap,
         6 * 3_600_000_000),
        ("constant 0 (dark day)", lambda: Constant(0.0), (0.006, 3.3, 2.8, 2.0),
         3_600_000_000),
    ]
    ok = True
    for name, mk, cap_args, evalp in regimes:
        we, ie = drive(mk(), Capacitor(*cap_args), "event", eval_period_us=evalp)
        ws, is_ = drive(mk(), Capacitor(*cap_args), "stepped", eval_period_us=evalp)
        ratio = is_ / max(ie, 1)
        dw = abs(we - ws)
        print(f"{name:<34} event {we:>5}w/{ie:>6}i | stepped {ws:>5}w/{is_:>6}i "
              f"| iter ratio {ratio:>5.1f}x | dwakes {dw}")
        if dw > max(0.01 * max(ws, 1), 8):
            ok = False
            print(f"  !! wake-count divergence beyond tolerance: {we} vs {ws}")
    if not ok:
        raise SystemExit(1)
    print("kernel mirror OK")


if __name__ == "__main__":
    main()
