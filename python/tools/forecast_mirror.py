"""Executable Python mirror of the EWMA harvest forecaster's accuracy
against the exact piecewise view, over the recorded preset traces.

Mirror of ``rust/src/energy/harvester.rs::{Ewma, piecewise_mean_w}`` and
the EWMA unit tests there: replay each ``examples/traces/*.csv`` at the
test's 30 s sampling cadence, run the identical rational-decay recurrence
(``w = dt / (dt + tau)`` — no ``exp``, so Python's f64 arithmetic
reproduces Rust's bit for bit), and score the estimate against the exact
piecewise-constant mean of the *next* 10 simulated minutes. The error
rows are exact and deterministic — unlike wall time they do not depend on
the box the bench runs on — so this mirror is the source of the committed
``BENCH_forecast.json`` accuracy rows in environments without a Rust
toolchain (the PR-session sandbox).

Run:

    python3 python/tools/forecast_mirror.py [--emit-json]

``--emit-json`` writes BENCH_forecast.json at the repo root with the
exact accuracy rows and ``null`` simulation/wall-time fields;
``cargo bench --bench forecast`` (on a toolchain-equipped box) overwrites
it with the same accuracy rows plus the starved-solar elision counts and
measured timings, and CI's ``--smoke`` mode re-asserts the invariants
every push.

Keep this file in sync with harvester.rs / benches/forecast.rs — it is a
mirror, not a spec.
"""

import json
import sys
import pathlib

# rust/src/energy/harvester.rs::Forecast::EWMA_TAU_US
TAU_US = 120_000_000
# the EWMA unit tests' replay cadence and scoring lookahead
STEP_US = 30_000_000
LOOKAHEAD_US = 600_000_000

ROOT = pathlib.Path(__file__).resolve().parents[2]
# per-trace mean-relative-error ceilings, asserted identically by the
# harvester.rs EWMA unit tests (measured: 0.6562 / 0.1415 / 0.0720)
TRACES = {"kinetic_walk": 0.75, "rf_office": 0.20, "solar_day": 0.12}


def load_trace(name):
    """Trace::parse_csv: `t_us,power_w` rows, comments and blanks skipped."""
    points = []
    for raw in (ROOT / "examples" / "traces" / f"{name}.csv").read_text().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        t, p = line.split(",")
        points.append((int(t.strip()), float(p.strip())))
    assert points, name
    return points


def power_w(points, t_us):
    """Trace::power_w: last point at or before t (0 before the first)."""
    p = 0.0
    for start, pw in points:
        if t_us >= start:
            p = pw
        else:
            break
    return p


def piecewise_mean_w(points, from_us, to_us):
    """piecewise_mean_w over a Trace: exact piecewise-constant mean."""
    if to_us <= from_us:
        return power_w(points, from_us)
    bounds = [t for t, _ in points if from_us < t < to_us]
    acc = 0.0
    t = from_us
    for b in bounds + [to_us]:
        acc += power_w(points, t) * (b - t)
        t = b
    return acc / (to_us - from_us)


class Ewma:
    """harvester.rs::Ewma — rational decay, first sample primes."""

    def __init__(self, tau_us=TAU_US):
        self.tau_us = tau_us
        self.est_w = 0.0
        self.last_us = 0
        self.primed = False

    def observe(self, t_us, p_w):
        if not self.primed:
            self.est_w, self.last_us, self.primed = p_w, t_us, True
            return
        dt = t_us - self.last_us
        if dt <= 0:
            return
        w = dt / (dt + self.tau_us)
        self.est_w += (p_w - self.est_w) * w
        self.last_us = t_us

    def mean_power_w(self):
        return self.est_w


def score(points):
    """Replay at STEP_US; score each estimate against the exact mean of
    the next LOOKAHEAD_US. Returns (windows, mean_rel_err) where the
    error is normalized by the mean future power (the trace's scale)."""
    span = points[-1][0]
    ewma = Ewma()
    abs_err = 0.0
    base = 0.0
    windows = 0
    t = points[0][0]
    while t + LOOKAHEAD_US <= span:
        ewma.observe(t, power_w(points, t))
        future = piecewise_mean_w(points, t, t + LOOKAHEAD_US)
        abs_err += abs(ewma.mean_power_w() - future)
        base += future
        windows += 1
        t += STEP_US
    assert windows > 0 and base > 0.0
    return windows, abs_err / base


def main():
    rows = {}
    for name, bound in TRACES.items():
        points = load_trace(name)
        windows, rel = score(points)
        rows[name] = (windows, rel)
        print(f"{name}: {windows} windows, mean relative error {rel:.4f} (bound {bound})")
        # same ceilings as the harvester.rs EWMA unit tests; rel >= 1.0
        # would mean the estimator is no better than predicting zero
        assert rel < bound, f"{name}: EWMA relative error {rel} >= {bound}"

    if "--emit-json" in sys.argv:
        doc = {
            "bench": "forecast",
            "source": "python/tools/forecast_mirror.py (exact EWMA accuracy rows; "
            "elision/wall-time fields pending `cargo bench --bench forecast` "
            "on a toolchain-equipped box)",
            "ewma_tau_us": TAU_US,
            "ewma_sample_step_us": STEP_US,
            "ewma_lookahead_us": LOOKAHEAD_US,
        }
        for name, (windows, rel) in rows.items():
            doc[f"{name}_windows"] = windows
            doc[f"{name}_mean_rel_err"] = round(rel, 4)
            doc[f"{name}_rel_err_bound"] = TRACES[name]
        doc.update(
            {
                "starved_solar_default_ckpt_bytes": None,
                "starved_solar_forecast_ckpt_bytes": None,
                "starved_solar_ckpt_bytes_saved_pct": None,
                "starved_solar_checkpoints_taken": None,
                "starved_solar_checkpoints_elided": None,
                "starved_solar_accuracy_delta": None,
                "fleet_learns_deferred_per_shard_day": None,
                "default_ms": None,
                "forecast_ms": None,
            }
        )
        out = ROOT / "BENCH_forecast.json"
        out.write_text(json.dumps(doc, indent=1) + "\n")
        print(f"wrote {out}")


if __name__ == "__main__":
    main()
