"""Executable Python mirror of the NVM checkpoint byte accounting.

Mirror of the save paths in ``rust/src/learning/{knn.rs,kmeans_nn.rs}``
over the store accounting of ``rust/src/nvm/mod.rs``: a full
``Learner::save`` re-serializes the whole model every learn, a
``Learner::save_delta`` writes the dirty ring slot / winner row plus the
scalar tail (and reads the 8-byte generation guard). The byte counts are
exact and deterministic — unlike wall time they do not depend on the box
the bench runs on — so this mirror is the source of the committed
``bytes_written_per_learn`` rows in ``BENCH_nvm.json`` in environments
without a Rust toolchain (the PR-session sandbox).

Run:

    python3 python/tools/nvm_mirror.py [--emit-json]

``--emit-json`` writes BENCH_nvm.json at the repo root with the exact
byte rows and ``null`` wall-time fields; ``cargo bench --bench
nvm_checkpoint`` (on a toolchain-equipped box) overwrites it with the
same byte numbers plus measured timings, and CI's ``--smoke`` mode
re-asserts the >=5x byte ratio every push.

Keep this file in sync with the learner save paths — it is a mirror, not
a spec.
"""

import json
import sys

# rust/src/backend/mod.rs shapes
CHANNELS = 4
N_FEATURES = 8
FEAT_DIM = CHANNELS * N_FEATURES  # 32
N_BUF = 64
N_CLUSTERS = 2

F32 = 4
U64 = 8


def knn_full():
    """knn.rs save(): buf + mask + times + scalars(3 f32) + learned + gen.

    PR 5 added the per-slot acquisition times (N_BUF u64) for the fleet
    ring merge's recency ordering + Mayfly expiry of adopted peer data.
    """
    return {
        "written": N_BUF * FEAT_DIM * F32  # knn/buf      8192
        + N_BUF * F32  # knn/mask      256
        + N_BUF * U64  # knn/times     512
        + 3 * F32  # knn/scalars    12
        + U64  # knn/learned     8
        + U64,  # knn/gen         8
        "read": 0,
    }


def knn_delta(dirty_slots=1):
    """knn.rs save_delta(): dirty rows + mask slots + time slots + tail.

    Steady state dirties exactly one ring slot per learn. The generation
    guard costs one 8-byte read.
    """
    return {
        "written": dirty_slots * (FEAT_DIM * F32 + F32 + U64) + 3 * F32 + U64 + U64,
        "read": U64,
    }


def kmeans_full():
    """kmeans_nn.rs save(): w + misc(4 + 6K f32) + learned + gen.

    PR 5 widened the misc block from 4 + 3K to 4 + 6K: per-cluster
    since-merge update counts and since-merge vote deltas (the FedAvg
    weights / vote payload of the fleet merge).
    """
    misc = 4 + 6 * N_CLUSTERS
    return {
        "written": N_CLUSTERS * FEAT_DIM * F32 + misc * F32 + U64 + U64,
        "read": 0,
    }


def kmeans_delta(dirty_rows=1):
    """kmeans_nn.rs save_delta(): winner row(s) + misc tail."""
    misc = 4 + 6 * N_CLUSTERS
    return {
        "written": dirty_rows * FEAT_DIM * F32 + misc * F32 + U64 + U64,
        "read": U64,
    }


def cells():
    rows = []
    for name, full, delta in [
        ("knn-learn-cycle", knn_full(), knn_delta()),
        ("kmeans-learn-cycle", kmeans_full(), kmeans_delta()),
    ]:
        for mode, acc in [("full", full), ("delta", delta)]:
            rows.append(
                {
                    "name": name,
                    "mode": mode,
                    "capacity": 0,
                    "learns": None,
                    "ns_per_learn": None,
                    "learns_per_sec": None,
                    "bytes_written_per_learn": acc["written"],
                    "bytes_read_per_learn": acc["read"],
                }
            )
    return rows


def main():
    rows = cells()
    by = {(r["name"], r["mode"]): r for r in rows}
    knn_ratio = (
        by[("knn-learn-cycle", "full")]["bytes_written_per_learn"]
        / by[("knn-learn-cycle", "delta")]["bytes_written_per_learn"]
    )
    kmeans_ratio = (
        by[("kmeans-learn-cycle", "full")]["bytes_written_per_learn"]
        / by[("kmeans-learn-cycle", "delta")]["bytes_written_per_learn"]
    )
    for r in rows:
        print(
            f"{r['name']:<20} {r['mode']:<6} "
            f"{r['bytes_written_per_learn']:>6} B written/learn "
            f"{r['bytes_read_per_learn']:>3} B read/learn"
        )
    print(f"knn    full/delta bytes ratio: {knn_ratio:.1f}x (target >= 5x)")
    print(f"kmeans full/delta bytes ratio: {kmeans_ratio:.1f}x")
    assert knn_ratio >= 5.0

    if "--emit-json" in sys.argv:
        doc = {
            "bench": "nvm_checkpoint",
            "source": "python/tools/nvm_mirror.py (exact byte accounting; "
            "wall-time fields pending `cargo bench --bench nvm_checkpoint` "
            "on a toolchain-equipped box)",
            "learns": None,
            "headline_bytes_ratio": round(knn_ratio, 2),
            "headline_speedup": None,
            "kmeans_bytes_ratio": round(kmeans_ratio, 2),
            "cells": rows,
        }
        import pathlib

        root = pathlib.Path(__file__).resolve().parents[2]
        out = root / "BENCH_nvm.json"
        out.write_text(json.dumps(doc, indent=1) + "\n")
        print(f"wrote {out}")


if __name__ == "__main__":
    main()
