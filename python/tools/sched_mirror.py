"""Executable Python mirror of the event-scheduler wake accounting and
the delta-snapshot wire sizes.

Mirror of ``rust/src/sim/sched.rs::planned_wakes`` (one heap event per
shard-local strict-interior sync boundary) against the round barrier's
equivalent (every shard dragged to every fastest-cadence boundary), over
the 64-shard 30/60/90-minute fleet of ``rust/benches/event_sched.rs``,
plus the ``ModelSnapshot::KnnDelta`` wire formulas behind the
``knn_delta_*_bytes`` rows of ``BENCH_sync.json``. The counts and bytes
are exact and deterministic — unlike wall time they do not depend on the
box the bench runs on — so this mirror is the source of the committed
``BENCH_sched.json`` count rows and the ``BENCH_sync.json`` wire-size
rows in environments without a Rust toolchain (the PR-session sandbox).

Run:

    python3 python/tools/sched_mirror.py [--emit-json]

``--emit-json`` writes BENCH_sched.json at the repo root with the exact
count rows and ``null`` wall-time fields, and refreshes the wire-size
rows of BENCH_sync.json in place; ``cargo bench --bench event_sched``
/ ``--bench sync`` (on a toolchain-equipped box) overwrite them with the
same counts plus measured timings, and CI's ``--smoke`` modes re-assert
the invariants every push.

Keep this file in sync with sched.rs / knn.rs — it is a mirror, not a
spec.
"""

import json
import sys

# rust/src/backend/mod.rs shapes
CHANNELS = 4
N_FEATURES = 8
FEAT_DIM = CHANNELS * N_FEATURES  # 32
N_BUF = 64

F32 = 4
U64 = 8

MIN30_US = 1_800_000_000
HOUR_US = 3_600_000_000


def planned_wakes(periods, horizon_us):
    """sched.rs planned_wakes: strict-interior boundaries per shard."""
    return sum((horizon_us - 1) // p for p in periods if p and horizon_us)


def het_periods(shards):
    """benches/event_sched.rs cadence mix: shard i syncs every
    (1 + i % 3) x 30 min."""
    return [(1 + i % 3) * MIN30_US for i in range(shards)]


def knn_full_snapshot():
    """ModelSnapshot::Knn bytes(): buf + mask + times + learned +
    threshold-et-al (8 + 8 + 4), as billed on first contact."""
    return N_BUF * FEAT_DIM * F32 + N_BUF * F32 + N_BUF * U64 + U64 + U64 + F32


def knn_delta_snapshot(slots):
    """ModelSnapshot::KnnDelta bytes(): changed rows + their times +
    learned + threshold."""
    return slots * (FEAT_DIM * F32 + U64) + U64 + F32


def main():
    shards = 64
    horizon_us = 4 * HOUR_US
    periods = het_periods(shards)
    event = planned_wakes(periods, horizon_us)
    barrier = shards * ((horizon_us - 1) // min(periods))
    ratio = barrier / event
    print("64-shard 30/60/90 min fleet over 4 h:")
    print(f"  event heap wakes:       {event}")
    print(f"  barrier-equivalent:     {barrier}")
    print(f"  ratio:                  {ratio:.2f}x fewer wakes")
    full = knn_full_snapshot()
    empty = knn_delta_snapshot(0)
    one = knn_delta_snapshot(1)
    print(f"knn snapshot wire sizes: full {full} B, delta {one} B/slot, {empty} B empty")
    assert event == 259 and barrier == 448
    assert (full, one, empty) == (8980, 148, 12)

    if "--emit-json" in sys.argv:
        import pathlib

        root = pathlib.Path(__file__).resolve().parents[2]
        doc = {
            "bench": "event_sched",
            "source": "python/tools/sched_mirror.py (exact wake counts; "
            "wall-time fields pending `cargo bench --bench event_sched` "
            "on a toolchain-equipped box)",
            "fleet_shards": shards,
            "uniform_sim_hours_per_shard": 2,
            "uniform_rounds_ms": None,
            "uniform_event_ms": None,
            "het_sim_hours_per_shard": 4,
            "het_periods_min_pattern": "30/60/90",
            "het_event_ms": None,
            "het_event_wakes": event,
            "het_barrier_wakes": barrier,
            "het_wake_ratio": round(ratio, 2),
            "het_syncs_done": None,
            "het_syncs_solo": None,
            "het_syncs_skipped": None,
        }
        out = root / "BENCH_sched.json"
        out.write_text(json.dumps(doc, indent=1) + "\n")
        print(f"wrote {out}")

        sync_path = root / "BENCH_sync.json"
        old = json.loads(sync_path.read_text())
        old["knn_snapshot_bytes"] = full
        # keep the delta rows next to the snapshot rows, where
        # `cargo bench --bench sync` writes them
        sync_doc = {}
        for key, value in old.items():
            if key.startswith("knn_delta_"):
                continue
            sync_doc[key] = value
            if key == "kmeans_snapshot_bytes":
                sync_doc["knn_delta_empty_bytes"] = empty
                sync_doc["knn_delta_one_slot_bytes"] = one
        sync_path.write_text(json.dumps(sync_doc, indent=1) + "\n")
        print(f"refreshed wire-size rows in {sync_path}")


if __name__ == "__main__":
    main()
