//! Failure-injection and persistence tests: the §3.5 guarantees under
//! adversarial energy conditions, and model survival through NVM.

use ilearn::backend::native::NativeBackend;
use ilearn::backend::shapes::FEAT_DIM;
use ilearn::energy::harvester::Trace;
use ilearn::fault::decide;
use ilearn::energy::{Capacitor, CostModel};
use ilearn::learning::{Example, KnnAnomalyLearner, Learner};
use ilearn::nvm::Nvm;
use ilearn::planner::DynamicActionPlanner;
use ilearn::selection::Heuristic;
use ilearn::sim::engine::Engine;
use ilearn::sim::{PlannerScheduler, SimConfig};
use ilearn::util::Rng;

/// Drain `nvm`'s access trace and assert the intermittent-safety analyzer
/// finds nothing in it (debug builds; a release-profile run has no trace).
fn assert_audit_clean(nvm: &mut Nvm, which: &str) {
    if let Some(trace) = nvm.audit_take() {
        let findings = ilearn::analysis::lint_trace(&trace);
        assert!(findings.is_empty(), "analyzer findings ({which}): {findings:?}");
    }
}

fn engine_with_trace(points: Vec<(u64, f64)>, horizon_s: u64) -> Engine {
    let profile = ilearn::sensors::accel::MotionProfile::alternating_hours(1.0, 3.0, 8);
    let sensor = ilearn::sensors::accel::Accel::new(profile, 3);
    Engine::builder()
        .sim(SimConfig {
            seed: 3,
            horizon_us: horizon_s * 1_000_000,
            eval_period_us: 600_000_000,
            probe_count: 10,
            charge_step_us: 2_000_000,
            probe_lookback_us: 3_600_000_000,
            ..Default::default()
        })
        .harvester(Box::new(Trace { points }))
        .capacitor(Capacitor::vibration())
        .sensor(Box::new(sensor))
        .learner(Box::new(KnnAnomalyLearner::new()))
        .selector(Heuristic::None.build(1))
        .scheduler(Box::new(PlannerScheduler(DynamicActionPlanner::default())))
        .backend(Box::new(NativeBackend::new()))
        .costs(CostModel::kmeans())
        .build()
        .unwrap()
}

#[test]
fn blackout_mid_run_loses_no_committed_learning() {
    // power for 10 min, dead for 20 min, power again: the learned counter
    // must be monotone through the blackout (no rollback of committed
    // learns) and learning must resume afterwards.
    let on = 0.010;
    let r = engine_with_trace(
        vec![(0, on), (600_000_000, 0.0), (1_800_000_000, on)],
        3_000,
    )
    .run()
    .unwrap();
    assert!(r.learned > 0);
    let mut last = 0;
    for c in &r.checkpoints {
        assert!(c.learned >= last, "learned went backwards");
        last = c.learned;
    }
    // progress after the blackout
    let before: u64 = r
        .checkpoints
        .iter()
        .filter(|c| c.t_us <= 600_000_000)
        .map(|c| c.learned)
        .max()
        .unwrap_or(0);
    assert!(
        r.learned > before,
        "no learning after power returned ({before} -> {})",
        r.learned
    );
}

#[test]
fn flickering_power_never_corrupts_bookkeeping() {
    // 2 s on / 8 s off flicker: lots of mid-action deaths
    let mut points = Vec::new();
    for i in 0..300u64 {
        points.push((i * 10_000_000, 0.012));
        points.push((i * 10_000_000 + 2_000_000, 0.0));
    }
    let r = engine_with_trace(points, 3_000).run().unwrap();
    assert!(r.power_failures > 0, "flicker produced no failures");
    // accounting stays coherent
    assert!(r.learned + r.inferred + r.discarded_select + r.expired + 2 >= r.sensed);
}

#[test]
fn learner_state_survives_via_nvm_restore() {
    // train a learner, persist to NVM, restore into a fresh instance (the
    // cold-boot path on a real platform), verify identical behaviour
    let mut be = NativeBackend::new();
    let mut nvm = Nvm::new();
    let mut rng = Rng::new(5);
    let mut learner = KnnAnomalyLearner::new();
    for t in 0..30u64 {
        let f: Vec<f32> = (0..FEAT_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        learner.learn(&Example::new(f, t, false), &mut be).unwrap();
    }
    learner.save(&mut nvm).unwrap();

    let mut rebooted = KnnAnomalyLearner::new();
    rebooted.restore(&mut nvm).unwrap();
    assert_eq!(rebooted.learned_count(), 30);
    assert_eq!(rebooted.threshold(), learner.threshold());
    for t in 0..10u64 {
        let scale = if t % 3 == 0 { 8.0 } else { 1.0 };
        let f: Vec<f32> = (0..FEAT_DIM)
            .map(|_| rng.normal(0.0, scale) as f32)
            .collect();
        let ex = Example::new(f, 100 + t, false);
        assert_eq!(
            learner.infer(&ex, &mut be).unwrap(),
            rebooted.infer(&ex, &mut be).unwrap()
        );
    }
}

/// Property: interleaving delta saves, injected mid-action power failures
/// (aborted save transactions) and reboots (restore from NVM) leaves the
/// k-NN learner bit-identical to a twin that always full-saves under the
/// same schedule — the delta checkpoint's §3.5 equivalence contract.
#[test]
fn prop_delta_saves_with_aborts_match_full_save_baseline() {
    use ilearn::util::prop;
    prop::check_cases("delta-vs-full-knn", 0xD17A, 16, |rng| {
        let mut be_d = NativeBackend::new();
        let mut be_f = NativeBackend::new();
        let mut nvm_d = Nvm::new();
        let mut nvm_f = Nvm::new();
        nvm_d.audit_start();
        nvm_f.audit_start();
        let mut ld = KnnAnomalyLearner::new();
        let mut lf = KnnAnomalyLearner::new();
        for t in 0..80u64 {
            let f: Vec<f32> = (0..FEAT_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let ex = Example::new(f, t, false);
            ld.learn(&ex, &mut be_d).unwrap();
            lf.learn(&ex, &mut be_f).unwrap();
            // the checkpoint runs inside an action transaction; a power
            // failure mid-action aborts it on both stores (schedule drawn
            // through the one fault-injection source of truth)
            let d = decide(rng, 0.3, 0.1);
            nvm_d.begin_action().unwrap();
            ld.save_delta(&mut nvm_d).unwrap();
            if d.abort {
                nvm_d.abort_action();
            } else {
                nvm_d.commit_action().unwrap();
            }
            nvm_f.begin_action().unwrap();
            lf.save(&mut nvm_f).unwrap();
            if d.abort {
                nvm_f.abort_action();
            } else {
                nvm_f.commit_action().unwrap();
            }
            // a power failure reboots the device: volatile learner state
            // is lost and restored from NVM (an occasional clean reboot
            // exercises the same path without a failure)
            if d.reboot {
                ld = KnnAnomalyLearner::new();
                ld.restore(&mut nvm_d).unwrap();
                lf = KnnAnomalyLearner::new();
                lf.restore(&mut nvm_f).unwrap();
            }
            assert_eq!(ld.buffer().0, lf.buffer().0, "ring buffers diverged at t={t}");
            assert_eq!(ld.buffer().1, lf.buffer().1, "masks diverged at t={t}");
            assert_eq!(ld.threshold(), lf.threshold(), "thresholds diverged at t={t}");
            assert_eq!(ld.learned_count(), lf.learned_count());
        }
        // subsequent verdicts agree bit-for-bit
        for t in 0..10u64 {
            let scale = if t % 3 == 0 { 8.0 } else { 1.0 };
            let f: Vec<f32> = (0..FEAT_DIM)
                .map(|_| rng.normal(0.0, scale) as f32)
                .collect();
            let ex = Example::new(f, 1000 + t, false);
            assert_eq!(
                ld.infer(&ex, &mut be_d).unwrap(),
                lf.infer(&ex, &mut be_f).unwrap()
            );
        }
        // and the delta path pays far less NVM traffic for it
        assert!(
            nvm_d.bytes_written * 5 <= nvm_f.bytes_written,
            "delta wrote {} B vs full {} B",
            nvm_d.bytes_written,
            nvm_f.bytes_written
        );
        assert_audit_clean(&mut nvm_d, "delta store");
        assert_audit_clean(&mut nvm_f, "full store");
    });
}

/// Property (federated sync × §3.5): interleaving fleet *merges* into the
/// delta-checkpoint schedule — merge → `save_delta` → power-fail →
/// `restore` — leaves the learner bit-identical to a twin that full-saves
/// under the same schedule. A merge rewrites model state outside the
/// dirty tracking, so its `save_delta` MUST degrade to a full save; an
/// aborted post-merge save must roll back to the pre-merge snapshot and
/// self-heal on the next one.
#[test]
fn prop_merge_then_delta_save_with_aborts_matches_full_save_baseline() {
    use ilearn::learning::ModelSnapshot;
    use ilearn::util::prop;
    // donor snapshots from independently trained learners (plain data —
    // exactly what a fleet peer would radio over)
    let mut be = NativeBackend::new();
    let mut donors: Vec<ModelSnapshot> = Vec::new();
    let mut rng = Rng::new(0xFEED);
    for d in 0..4u64 {
        let mut l = KnnAnomalyLearner::new();
        for t in 0..(10 + d * 17) {
            let f: Vec<f32> = (0..FEAT_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            l.learn(&Example::new(f, 1_000 * d + t, false), &mut be).unwrap();
        }
        donors.push(l.snapshot().expect("knn snapshots"));
    }
    prop::check_cases("merge-delta-vs-full-knn", 0x3E6C, 16, |rng| {
        let mut be_d = NativeBackend::new();
        let mut be_f = NativeBackend::new();
        let mut nvm_d = Nvm::new();
        let mut nvm_f = Nvm::new();
        nvm_d.audit_start();
        nvm_f.audit_start();
        let mut ld = KnnAnomalyLearner::new();
        let mut lf = KnnAnomalyLearner::new();
        for t in 0..60u64 {
            let f: Vec<f32> = (0..FEAT_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let ex = Example::new(f, 10_000 + t, false);
            ld.learn(&ex, &mut be_d).unwrap();
            lf.learn(&ex, &mut be_f).unwrap();
            // a sync boundary fires on ~1/4 of the steps: both twins merge
            // the same peer snapshot(s) at the same instant
            if rng.f32() < 0.25 {
                let donor = &donors[(rng.f32() * 3.99) as usize];
                let now = 20_000 + t;
                let expiry = if rng.f32() < 0.5 { Some(15_000) } else { None };
                assert_eq!(
                    ld.merge(&[donor], &mut be_d, now, expiry).unwrap(),
                    lf.merge(&[donor], &mut be_f, now, expiry).unwrap()
                );
            }
            let d = decide(rng, 0.3, 0.1);
            nvm_d.begin_action().unwrap();
            ld.save_delta(&mut nvm_d).unwrap();
            if d.abort {
                nvm_d.abort_action();
            } else {
                nvm_d.commit_action().unwrap();
            }
            nvm_f.begin_action().unwrap();
            lf.save(&mut nvm_f).unwrap();
            if d.abort {
                nvm_f.abort_action();
            } else {
                nvm_f.commit_action().unwrap();
            }
            if d.reboot {
                ld = KnnAnomalyLearner::new();
                ld.restore(&mut nvm_d).unwrap();
                lf = KnnAnomalyLearner::new();
                lf.restore(&mut nvm_f).unwrap();
            }
            assert_eq!(ld.buffer().0, lf.buffer().0, "ring buffers diverged at t={t}");
            assert_eq!(ld.buffer().1, lf.buffer().1, "masks diverged at t={t}");
            assert_eq!(ld.threshold(), lf.threshold(), "thresholds diverged at t={t}");
            assert_eq!(ld.learned_count(), lf.learned_count());
        }
        // verdict parity after the full schedule
        for t in 0..8u64 {
            let scale = if t % 3 == 0 { 8.0 } else { 1.0 };
            let f: Vec<f32> = (0..FEAT_DIM)
                .map(|_| rng.normal(0.0, scale) as f32)
                .collect();
            let ex = Example::new(f, 99_000 + t, false);
            assert_eq!(
                ld.infer(&ex, &mut be_d).unwrap(),
                lf.infer(&ex, &mut be_f).unwrap()
            );
        }
        assert_audit_clean(&mut nvm_d, "delta store");
        assert_audit_clean(&mut nvm_f, "full store");
    });
}

/// The same merge-in-schedule property for the k-means learner
/// (count-weighted centroid merges forcing full post-merge saves).
#[test]
fn prop_kmeans_merge_then_delta_save_matches_full_save_baseline() {
    use ilearn::learning::{ClusterLabelLearner, ModelSnapshot};
    use ilearn::util::prop;
    let mut be = NativeBackend::new();
    let mut donors: Vec<ModelSnapshot> = Vec::new();
    let mut rng = Rng::new(0xD0);
    for d in 0..3u64 {
        let mut l = ClusterLabelLearner::new(100 + d, 12);
        for i in 0..30u64 {
            let abnormal = i % 2 == 0;
            let mut f = vec![0.0f32; FEAT_DIM];
            let base = if abnormal { 8 } else { 0 };
            for v in f[base..base + 8].iter_mut() {
                *v = 2.0 + rng.normal(0.0, 0.2) as f32;
            }
            l.learn(&Example::new(f, i, abnormal), &mut be).unwrap();
        }
        donors.push(l.snapshot().expect("kmeans snapshots"));
    }
    prop::check_cases("merge-delta-vs-full-kmeans", 0x6E6C, 16, |rng| {
        let mut be_d = NativeBackend::new();
        let mut be_f = NativeBackend::new();
        let mut nvm_d = Nvm::new();
        let mut nvm_f = Nvm::new();
        nvm_d.audit_start();
        nvm_f.audit_start();
        let mut ld = ClusterLabelLearner::new(9, 20);
        let mut lf = ClusterLabelLearner::new(9, 20);
        for t in 0..50u64 {
            let abnormal = rng.f32() < 0.5;
            let mut f = vec![0.0f32; FEAT_DIM];
            let base = if abnormal { 8 } else { 0 };
            for v in f[base..base + 8].iter_mut() {
                *v = 2.0 + rng.normal(0.0, 0.2) as f32;
            }
            let ex = Example::new(f, t, abnormal);
            ld.learn(&ex, &mut be_d).unwrap();
            lf.learn(&ex, &mut be_f).unwrap();
            if rng.f32() < 0.25 {
                let donor = &donors[(rng.f32() * 2.99) as usize];
                ld.merge(&[donor], &mut be_d, t, None).unwrap();
                lf.merge(&[donor], &mut be_f, t, None).unwrap();
            }
            let d = decide(rng, 0.3, 0.1);
            nvm_d.begin_action().unwrap();
            ld.save_delta(&mut nvm_d).unwrap();
            if d.abort {
                nvm_d.abort_action();
            } else {
                nvm_d.commit_action().unwrap();
            }
            nvm_f.begin_action().unwrap();
            lf.save(&mut nvm_f).unwrap();
            if d.abort {
                nvm_f.abort_action();
            } else {
                nvm_f.commit_action().unwrap();
            }
            if d.reboot {
                ld = ClusterLabelLearner::new(9, 20);
                ld.restore(&mut nvm_d).unwrap();
                lf = ClusterLabelLearner::new(9, 20);
                lf.restore(&mut nvm_f).unwrap();
            }
            assert_eq!(ld.weights(), lf.weights(), "weights diverged at t={t}");
            assert_eq!(ld.learned_count(), lf.learned_count());
            assert_eq!(ld.labels_remaining(), lf.labels_remaining());
        }
        assert_audit_clean(&mut nvm_d, "delta store");
        assert_audit_clean(&mut nvm_f, "full store");
    });
}

/// Same property for the k-means learner (winner-row deltas).
#[test]
fn prop_kmeans_delta_saves_match_full_save_baseline() {
    use ilearn::learning::ClusterLabelLearner;
    use ilearn::util::prop;
    prop::check_cases("delta-vs-full-kmeans", 0x5EED5, 16, |rng| {
        let mut be_d = NativeBackend::new();
        let mut be_f = NativeBackend::new();
        let mut nvm_d = Nvm::new();
        let mut nvm_f = Nvm::new();
        nvm_d.audit_start();
        nvm_f.audit_start();
        let mut ld = ClusterLabelLearner::new(9, 20);
        let mut lf = ClusterLabelLearner::new(9, 20);
        for t in 0..60u64 {
            let abnormal = rng.f32() < 0.5;
            let mut f = vec![0.0f32; FEAT_DIM];
            let base = if abnormal { 8 } else { 0 };
            for v in f[base..base + 8].iter_mut() {
                *v = 2.0 + rng.normal(0.0, 0.2) as f32;
            }
            let ex = Example::new(f, t, abnormal);
            ld.learn(&ex, &mut be_d).unwrap();
            lf.learn(&ex, &mut be_f).unwrap();
            let d = decide(rng, 0.3, 0.1);
            nvm_d.begin_action().unwrap();
            ld.save_delta(&mut nvm_d).unwrap();
            if d.abort {
                nvm_d.abort_action();
            } else {
                nvm_d.commit_action().unwrap();
            }
            nvm_f.begin_action().unwrap();
            lf.save(&mut nvm_f).unwrap();
            if d.abort {
                nvm_f.abort_action();
            } else {
                nvm_f.commit_action().unwrap();
            }
            if d.reboot {
                // reboot constructs the same firmware-determined initial
                // learner (seed 9) before restoring, as a device would
                ld = ClusterLabelLearner::new(9, 20);
                ld.restore(&mut nvm_d).unwrap();
                lf = ClusterLabelLearner::new(9, 20);
                lf.restore(&mut nvm_f).unwrap();
            }
            assert_eq!(ld.weights(), lf.weights(), "weights diverged at t={t}");
            assert_eq!(ld.learned_count(), lf.learned_count());
            assert_eq!(ld.labels_remaining(), lf.labels_remaining());
        }
        assert!(nvm_d.bytes_written < nvm_f.bytes_written);
        assert_audit_clean(&mut nvm_d, "delta store");
        assert_audit_clean(&mut nvm_f, "full store");
    });
}

#[test]
fn run_state_survives_a_simulated_host_restart_bit_identically() {
    // ROADMAP item: RunResult/meter aggregates persist through the KeyId +
    // delta-checkpoint path. Run an engine, carry its NVM across a
    // "host restart" (fresh engine, adopted store), and the restored
    // aggregates must match the finished run bit for bit.
    let points = vec![(0, 0.010), (600_000_000, 0.0), (1_200_000_000, 0.010)];
    let mut e = engine_with_trace(points.clone(), 2_400);
    let r = e.run_to_end().unwrap();
    assert!(r.learned > 0 && !r.checkpoints.is_empty(), "empty run proves nothing");
    let nvm = std::mem::take(&mut e.exec.nvm);

    // host restart: a fresh engine of the same firmware adopts the NVM
    let mut rebooted = engine_with_trace(points, 2_400);
    assert!(!rebooted.restore_run_state().unwrap(), "fresh NVM restored state");
    rebooted.exec.nvm = nvm;
    assert!(rebooted.restore_run_state().unwrap(), "carried NVM had no state");
    let back = rebooted.aggregates();
    assert_eq!(
        back.to_json().to_string(),
        r.to_json().to_string(),
        "restored aggregates diverged"
    );
    // parts the JSON summary does not cover
    assert_eq!(back.energy_series, r.energy_series);
    assert_eq!(back.infer_log, r.infer_log);
    assert_eq!(back.checkpoints.len(), r.checkpoints.len());
}

#[test]
fn run_state_restores_the_interruption_point_not_the_future() {
    // an "interrupted" run is one that stopped at an earlier horizon: its
    // NVM must restore the aggregates as of its own last checkpoint, and
    // those match a prefix of the longer run's checkpoint trajectory
    let points = vec![(0, 0.010)];
    let full = engine_with_trace(points.clone(), 2_400).run().unwrap();
    let mut interrupted = engine_with_trace(points.clone(), 1_200);
    let partial = interrupted.run_to_end().unwrap();
    let mut nvm = std::mem::take(&mut interrupted.exec.nvm);
    let (restored, meter) = ilearn::sim::RunState::new()
        .restore(&mut nvm)
        .unwrap()
        .expect("interrupted run persisted no state");
    assert_eq!(restored.to_json().to_string(), partial.to_json().to_string());
    assert_eq!(meter.total_uj(), partial.energy_uj);
    assert!(restored.checkpoints.len() < full.checkpoints.len());
    // all but the interrupted run's final (horizon) checkpoint line up
    // with the longer run's trajectory
    let prefix = restored.checkpoints.len() - 1;
    for (a, b) in restored.checkpoints[..prefix].iter().zip(&full.checkpoints) {
        assert_eq!(a.t_us, b.t_us, "checkpoint cadence diverged");
        assert_eq!(a.learned, b.learned, "prefix diverged at t={}", a.t_us);
    }
}

#[test]
fn aborted_action_rolls_back_nvm_writes() {
    let mut nvm = Nvm::new();
    nvm.write_u64("model_version", 1).unwrap();
    nvm.begin_action().unwrap();
    nvm.write_u64("model_version", 2).unwrap();
    nvm.write_f32s("weights", &[9.9; 8]).unwrap();
    // power failure
    nvm.abort_action();
    assert_eq!(nvm.read_u64("model_version"), 1);
    assert!(nvm.read_f32s("weights").is_none());
}

#[test]
fn energy_budget_error_when_action_cannot_ever_fit() {
    // a capacitor so small that a sense sub-action exceeds one full charge
    // must surface the pre-inspection error, not loop forever
    let profile = ilearn::sensors::accel::MotionProfile::alternating_hours(1.0, 3.0, 1);
    let sensor = ilearn::sensors::accel::Accel::new(profile, 3);
    // 50 uF: the planner's 57 uJ decision fits one charge, but a sense
    // sub-action (1.81 mJ) exceeds even a full 3.3 V -> 2.0 V discharge
    let tiny_cap = Capacitor::new(0.00005, 3.3, 2.8, 2.0);
    let engine = Engine::builder()
        .sim(SimConfig {
            seed: 1,
            horizon_us: 600_000_000,
            eval_period_us: 600_000_000,
            probe_count: 4,
            charge_step_us: 2_000_000,
            probe_lookback_us: 600_000_000,
            ..Default::default()
        })
        .harvester(Box::new(Trace {
            points: vec![(0, 0.010)],
        }))
        .capacitor(tiny_cap)
        .sensor(Box::new(sensor))
        .learner(Box::new(KnnAnomalyLearner::new()))
        .selector(Heuristic::None.build(1))
        .scheduler(Box::new(PlannerScheduler(DynamicActionPlanner::default())))
        .backend(Box::new(NativeBackend::new()))
        .costs(CostModel::kmeans())
        .build()
        .unwrap();
    let err = engine.run().unwrap_err();
    assert!(
        matches!(err, ilearn::Error::EnergyBudget { .. }),
        "expected EnergyBudget, got {err:?}"
    );
}
