//! Property-based tests on coordinator invariants (routing, batching,
//! state) using the in-repo property driver (`util::prop`): randomized
//! scenario parameters, deterministic per-seed, failure seeds reported.

use ilearn::actions::Action;
use ilearn::apps::{AppConfig, AppKind, SchedulerKind};
use ilearn::backend::native::NativeBackend;
use ilearn::energy::harvester::Constant;
use ilearn::energy::{Capacitor, CostModel};
use ilearn::learning::KnnAnomalyLearner;
use ilearn::planner::{DynamicActionPlanner, PlanContext, Planned};
use ilearn::selection::Heuristic;
use ilearn::sim::engine::Engine;
use ilearn::sim::{PlannerScheduler, RunResult, SimConfig};
use ilearn::util::prop;
use ilearn::util::Rng;

const H: u64 = 3_600_000_000;

fn run_constant_power(seed: u64, power_mw: f64, minutes: u64) -> RunResult {
    let profile =
        ilearn::sensors::accel::MotionProfile::alternating_hours(1.0, 3.0, minutes / 60 + 1);
    let sensor = ilearn::sensors::accel::Accel::new(profile, seed);
    Engine::builder()
        .sim(SimConfig {
            seed,
            horizon_us: minutes * 60_000_000,
            eval_period_us: 10 * 60_000_000,
            probe_count: 10,
            charge_step_us: 5_000_000,
            probe_lookback_us: H,
            ..Default::default()
        })
        .harvester(Box::new(Constant(power_mw / 1000.0)))
        .capacitor(Capacitor::vibration())
        .sensor(Box::new(sensor))
        .learner(Box::new(KnnAnomalyLearner::new()))
        .selector(Heuristic::RoundRobin.build(seed))
        .scheduler(Box::new(PlannerScheduler(DynamicActionPlanner::default())))
        .backend(Box::new(NativeBackend::new()))
        .costs(CostModel::kmeans())
        .build()
        .unwrap()
        .run()
        .unwrap()
}

#[test]
fn prop_energy_books_balance() {
    // total metered energy == sum of per-action tallies (incl. waste)
    prop::check_cases("energy-books", 11, 12, |rng| {
        let power = 1.0 + rng.f64() * 15.0;
        let r = run_constant_power(rng.next_u64() % 1000, power, 30);
        let talled: f64 = r
            .action_tallies
            .iter()
            .map(|(_, _, e, _)| *e)
            .sum::<f64>();
        // action_tallies excludes per-abort waste rows? they are folded in
        // the meter; compare against the run total within rounding
        assert!(
            talled <= r.energy_uj + 1.0,
            "tallies {talled} > total {}",
            r.energy_uj
        );
        assert!(r.energy_uj > 0.0 || r.cycles == 0);
    });
}

#[test]
fn prop_learn_counts_consistent() {
    // learned count matches the learn-action completions (atomicity: no
    // double-counted or phantom learns across power failures)
    prop::check_cases("learn-counts", 13, 10, |rng| {
        let power = 0.8 + rng.f64() * 10.0; // include brown-out regimes
        let r = run_constant_power(rng.next_u64() % 1000, power, 45);
        let learn_subs = r
            .action_tallies
            .iter()
            .find(|(n, ..)| n == "learn")
            .map(|(_, c, ..)| *c)
            .unwrap_or(0);
        let splits = CostModel::kmeans().cost(Action::Learn).splits as u64;
        // every completed learn contributed exactly `splits` committed
        // sub-actions; at most 2 learns (the admission cap) can be left
        // mid-flight at the horizon with some sub-actions committed
        assert!(
            learn_subs >= r.learned * splits,
            "fewer learn sub-actions ({learn_subs}) than completed learns x splits ({})",
            r.learned * splits
        );
        assert!(
            learn_subs <= r.learned * splits + 2 * (splits - 1),
            "orphan learn sub-actions: {learn_subs} vs learned {} x {splits}",
            r.learned
        );
        // every sensed example is accounted for: still pending (<= 2),
        // discarded, expired, inferred or learned
        assert!(
            r.learned + r.inferred + r.discarded_select + r.expired + 2 >= r.sensed,
            "example bookkeeping: {r:?}"
        );
    });
}

#[test]
fn prop_runs_are_deterministic() {
    prop::check_cases("determinism", 17, 6, |rng| {
        let seed = rng.next_u64() % 512;
        let power = 2.0 + rng.f64() * 8.0;
        let a = run_constant_power(seed, power, 30);
        let b = run_constant_power(seed, power, 30);
        assert_eq!(a.learned, b.learned);
        assert_eq!(a.inferred, b.inferred);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_uj, b.energy_uj);
        assert_eq!(
            a.checkpoints.last().map(|c| c.accuracy),
            b.checkpoints.last().map(|c| c.accuracy)
        );
    });
}

#[test]
fn prop_more_power_never_less_work() {
    // monotonicity: strictly more harvest power should never produce less
    // total completed work (learn+infer) on the same world
    prop::check_cases("power-monotone", 19, 6, |rng| {
        let seed = rng.next_u64() % 512;
        let p_lo = 1.0 + rng.f64() * 4.0;
        let p_hi = p_lo * (2.0 + rng.f64());
        let lo = run_constant_power(seed, p_lo, 30);
        let hi = run_constant_power(seed, p_hi, 30);
        let work = |r: &RunResult| r.learned + r.inferred;
        assert!(
            work(&hi) + 3 >= work(&lo),
            "power {p_hi:.1} mW did {} vs {} at {p_lo:.1} mW",
            work(&hi),
            work(&lo)
        );
    });
}

#[test]
fn prop_planner_transitions_always_legal() {
    // under arbitrary contexts the planner only proposes diagram-legal
    // transitions and respects the admission cap
    prop::check("planner-legal", |rng| {
        let mut planner = DynamicActionPlanner::default();
        planner.cfg.max_admitted = 1 + rng.below_usize(3);
        let costs = CostModel::knn();
        let mut pending: Vec<Action> = Vec::new();
        let steps = 20 + rng.below_usize(30);
        for _ in 0..steps {
            let ctx = PlanContext {
                learned_total: rng.next_u64() % 300,
                quality: rng.f32(),
                window_learns: rng.below(5),
                window_infers: rng.below(5),
                window_cycle: 1 + rng.below(10),
                forecast_uj: None,
            };
            match planner.next_action(&pending, &ctx, &costs) {
                Planned::SenseNew => {
                    assert!(pending.len() < planner.cfg.max_admitted);
                    pending.push(Action::Sense);
                }
                Planned::Advance { slot, action } => {
                    assert!(slot < pending.len(), "slot {slot} of {}", pending.len());
                    assert!(
                        pending[slot].can_precede(action),
                        "{:?} -> {action:?}",
                        pending[slot]
                    );
                    if action.next().is_empty() {
                        pending.remove(slot);
                    } else {
                        pending[slot] = action;
                    }
                }
                Planned::Idle => {
                    assert!(pending.len() >= planner.cfg.max_admitted || pending.is_empty());
                    break;
                }
            }
        }
    });
}

#[test]
fn prop_mayfly_expires_only_stale_data() {
    prop::check_cases("mayfly-expiry", 23, 6, |rng: &mut Rng| {
        let expiry_s = 1 + rng.below(5) as u64;
        let mut cfg = AppConfig::new(AppKind::Vibration, rng.next_u64() % 128, 2 * H);
        cfg.scheduler = SchedulerKind::Mayfly {
            learn_pct: 0.5,
            expiry_us: expiry_s * 1_000_000,
        };
        let r = cfg.build_engine().unwrap().run().unwrap();
        // with alpaca-style immediate processing, expiry should be rare but
        // the accounting must never exceed sensed examples
        assert!(r.expired <= r.sensed);
    });
}
