//! Fleet-layer integration: determinism across thread counts, the
//! 1-shard == plain-engine equivalence on all three paper presets, the
//! 16-shard solar fleet acceptance run through the sweep runner, and the
//! federated-sync acceptance cells (sync-off PR-4 equivalence, synced
//! thread-count determinism, sync-vs-isolated accuracy, energy gating).

use ilearn::energy::harvester::Trace;
use ilearn::scenario::{
    preset, FleetSpec, HarvesterSpec, ScenarioSpec, ShardOverride, SweepRunner, SweepSpec,
    SyncSpec,
};
use ilearn::sim::{FleetResult, FleetSched, RunResult, SyncStrategy};

const H: u64 = 3_600_000_000;

fn fp(r: &RunResult) -> String {
    r.to_json().to_string()
}

fn fleet_fp(f: &FleetResult) -> String {
    f.to_json().to_string()
}

fn with_fleet(mut spec: ScenarioSpec, shards: u32, jitter_us: u64) -> ScenarioSpec {
    spec.fleet = Some(FleetSpec {
        shards,
        phase_jitter_us: jitter_us,
        seed_stride: 1,
        overrides: vec![],
        sync: None,
        sched: None,
        stream: None,
    });
    spec
}

#[test]
fn fleet_is_bit_identical_for_threads_1_2_and_all() {
    // the acceptance determinism contract: an N-shard fleet cell returns
    // bit-identical FleetResults for threads in {1, 2, 0}
    let spec = with_fleet(preset("vibration", 3, 2 * H).unwrap(), 4, 60_000_000);
    let one = spec.run_fleet(1).unwrap();
    let two = spec.run_fleet(2).unwrap();
    let all = spec.run_fleet(0).unwrap();
    assert_eq!(fleet_fp(&one), fleet_fp(&two), "threads 1 vs 2 diverged");
    assert_eq!(fleet_fp(&one), fleet_fp(&all), "threads 1 vs all diverged");
    assert!(one.shards.iter().all(|r| r.sensed > 0), "dead shard");
    // phase jitter + seed stride actually de-correlated the shards
    let fps: Vec<String> = one.shards.iter().map(fp).collect();
    assert!(fps.iter().any(|f| f != &fps[0]), "shards identical");
}

#[test]
fn one_shard_fleet_equals_the_plain_engine_on_all_presets() {
    for name in ["air_quality", "presence", "vibration"] {
        let plain = preset(name, 7, 2 * H).unwrap();
        let solo = plain.build_engine().unwrap().run().unwrap();
        let fleet = with_fleet(plain, 1, 123_456_789) // jitter moot at 1 shard
            .run_fleet(0)
            .unwrap();
        assert_eq!(fleet.shards.len(), 1);
        assert_eq!(
            fp(fleet.primary()),
            fp(&solo),
            "{name}: 1-shard fleet diverged from the plain engine run"
        );
    }
}

#[test]
fn sixteen_shard_solar_fleet_through_the_sweep_runner() {
    // the acceptance cell: a 16-shard solar-preset fleet through
    // SweepRunner with per-shard parallelism, deterministic rollups
    // across thread counts
    // 8 h from midnight with 30 min of solar phase per shard: shard 0 gets
    // 2 h of post-sunrise daylight, shard 15 starts at 07:30 and sees 8 h
    let grid = r#"{
        "name": "fleet-acceptance",
        "hours": 8,
        "scenarios": ["air_quality"],
        "fleet": {"shards": 16, "phase_jitter_us": 1800000000, "seed_stride": 1}
    }"#;
    let sweep = SweepSpec::parse(grid).unwrap();
    let cells = sweep.expand().unwrap();
    assert_eq!(cells.len(), 1);
    assert_eq!(cells[0].spec.shard_count(), 16);

    let serial = SweepRunner::new(1).run(&sweep).unwrap();
    let pooled = SweepRunner::new(4).run(&sweep).unwrap();
    let (a, b) = (
        serial[0].result.as_ref().unwrap(),
        pooled[0].result.as_ref().unwrap(),
    );
    assert_eq!(fleet_fp(a), fleet_fp(b), "rollups diverged across thread counts");
    assert_eq!(a.shards.len(), 16);
    assert_eq!(a.rollup.shards, 16);
    // fan-in totals equal the per-shard sums
    let learned: u64 = a.shards.iter().map(|r| r.learned).sum();
    assert_eq!(a.rollup.learned.total, learned as f64);
    assert!(a.rollup.energy_uj.min <= a.rollup.energy_uj.max);
    // staggered solar phases: later shards sit deeper into daylight, so
    // the fleet is genuinely diverse
    let cycles: Vec<u64> = a.shards.iter().map(|r| r.cycles).collect();
    assert!(cycles.iter().any(|&c| c != cycles[0]), "{cycles:?}");
    // the cell document carries the fleet aggregate
    let doc = serial[0].to_json().to_string();
    assert!(doc.contains("\"fleet\"") && doc.contains("\"rollup\""));
}

#[test]
fn streaming_fleet_reproduces_the_retained_rollups_on_all_presets() {
    // population-scale acceptance: the fold-and-drop fan-in equals the
    // retained per-shard path's rollup bit for bit on every paper preset
    for name in ["air_quality", "presence", "vibration"] {
        let spec = with_fleet(preset(name, 7, 2 * H).unwrap(), 4, 1_800_000_000);
        let retained = spec.run_fleet(0).unwrap();
        let streamed = spec.run_fleet_streaming(0).unwrap();
        assert_eq!(
            streamed.rollup.to_json().to_string(),
            retained.rollup.to_json().to_string(),
            "{name}: streamed rollup diverged from the retained fan-in"
        );
        // every shard's stats reached the sketches before being dropped
        assert_eq!(streamed.sketches.final_accuracy.count(), 4, "{name}");
        assert_eq!(streamed.sketches.energy_uj.count(), 4, "{name}");
    }
}

#[test]
fn streaming_sixteen_shard_solar_fleet_is_thread_count_invariant() {
    // the 16-shard solar acceptance cell through the streaming path:
    // bit-identical to the retained fan-in for threads in {1, 2, 0}
    let spec = with_fleet(preset("air_quality", 42, 8 * H).unwrap(), 16, 1_800_000_000);
    let retained = spec.run_fleet(0).unwrap();
    for threads in [1, 2, 0] {
        let streamed = spec.run_fleet_streaming(threads).unwrap();
        assert_eq!(
            streamed.rollup.to_json().to_string(),
            retained.rollup.to_json().to_string(),
            "threads {threads}: streamed rollup diverged"
        );
    }
}

#[test]
fn one_shard_streaming_fleet_matches_the_bare_engine() {
    // golden pin: streaming a 1-shard fleet is the plain engine run
    // folded once, and the document keeps sketches in, per-shard out
    for name in ["air_quality", "presence", "vibration"] {
        let plain = preset(name, 7, 2 * H).unwrap();
        let solo = plain.build_engine().unwrap().run().unwrap();
        let streamed = with_fleet(plain, 1, 0).run_fleet_streaming(1).unwrap();
        let expect = FleetResult::aggregate(vec![solo]);
        assert_eq!(
            streamed.rollup.to_json().to_string(),
            expect.rollup.to_json().to_string(),
            "{name}: 1-shard streamed rollup diverged from the bare engine"
        );
        let doc = streamed.to_json().to_string();
        assert!(doc.starts_with("{\"shards\":1,\"rollup\":{"), "{doc}");
        assert!(doc.contains("\"sketches\":{\"final_accuracy\":{\"n\":1,"), "{doc}");
        assert!(!doc.contains("per_shard"), "{doc}");
    }
}

fn hourly_sync(strategy: SyncStrategy) -> SyncSpec {
    SyncSpec {
        period_us: 3_600_000_000,
        strategy,
        radio: None,
    }
}

#[test]
fn sync_disabled_fleets_reproduce_the_isolated_shard_runs_on_all_presets() {
    // acceptance (a), half 1: a sync-less fleet through the round-aware
    // Fleet must equal the per-shard plain-engine runs (the PR-4 path)
    // bit for bit on all three paper presets
    for name in ["air_quality", "presence", "vibration"] {
        let spec = with_fleet(preset(name, 7, 2 * H).unwrap(), 2, 1_800_000_000);
        let fleet = spec.run_fleet(0).unwrap();
        let manual: Vec<RunResult> = (0..2)
            .map(|i| spec.build_shard_engine(i).unwrap().run().unwrap())
            .collect();
        let manual = FleetResult::aggregate(manual);
        assert_eq!(
            fleet_fp(&fleet),
            fleet_fp(&manual),
            "{name}: sync-less fleet diverged from isolated shard runs"
        );
        assert!(!fleet_fp(&fleet).contains("syncs_"), "{name}: sync keys leaked");
    }
}

#[test]
fn one_shard_fleet_with_sync_still_equals_the_plain_engine() {
    // acceptance (a), half 2: shards = 1 reproduces the plain engine even
    // with a sync block present (there is nobody to talk to — the round
    // scheduler must not engage, charge radio, or touch the counters)
    for name in ["air_quality", "presence", "vibration"] {
        let mut spec = with_fleet(preset(name, 7, 2 * H).unwrap(), 1, 0);
        spec.fleet.as_mut().unwrap().sync = Some(hourly_sync(SyncStrategy::Gossip));
        let fleet = spec.run_fleet(0).unwrap();
        let mut plain = spec.clone();
        plain.fleet = None;
        let solo = plain.build_engine().unwrap().run().unwrap();
        assert_eq!(
            fp(fleet.primary()),
            fp(&solo),
            "{name}: 1-shard synced fleet diverged from the plain engine"
        );
    }
}

#[test]
fn synced_fleet_is_bit_identical_for_threads_1_2_and_all() {
    // acceptance (b): a synced fleet's FleetResult is bit-identical
    // across --threads {1, 2, 0}
    let mut spec = with_fleet(preset("vibration", 3, 2 * H).unwrap(), 4, 60_000_000);
    spec.fleet.as_mut().unwrap().sync = Some(hourly_sync(SyncStrategy::AllReduce));
    let one = spec.run_fleet(1).unwrap();
    let two = spec.run_fleet(2).unwrap();
    let all = spec.run_fleet(0).unwrap();
    assert_eq!(fleet_fp(&one), fleet_fp(&two), "threads 1 vs 2 diverged");
    assert_eq!(fleet_fp(&one), fleet_fp(&all), "threads 1 vs all diverged");
    let exchanges: u64 = one.shards.iter().map(|r| r.syncs_done).sum();
    assert!(exchanges > 0, "no shard ever completed a sync exchange");
    assert_eq!(one.rollup.syncs_done.total, exchanges as f64);
}

#[test]
fn heterogeneous_fleet_mixes_harvesters_per_shard() {
    // per-shard energy diversity: one shard of a piezo fleet runs on a
    // recorded trace slice instead
    let trace = Trace::parse_csv("0,0.0\n300000000,0.012\n").unwrap();
    let mut spec = with_fleet(preset("vibration", 5, 2 * H).unwrap(), 3, 0);
    spec.fleet.as_mut().unwrap().overrides = vec![ShardOverride::harvester(
        1,
        HarvesterSpec::Trace {
            points: trace,
            path: None,
        },
    )];
    let fr = spec.run_fleet(0).unwrap();
    assert_eq!(fr.shards.len(), 3);
    // shard 1 charges through the trace's dark 5 min, then constant 12 mW:
    // its energy profile must differ from the piezo shards'
    assert_ne!(fp(&fr.shards[1]), fp(&fr.shards[0]));
    assert!(fr.shards[1].cycles > 0, "trace shard never woke");
}

#[test]
fn sixteen_shard_solar_sync_beats_the_isolated_fleet() {
    // acceptance (c): the 16-shard solar cell with periodic sync achieves
    // a strictly higher mean-accuracy rollup than the isolated fleet —
    // phase-jittered shards that spend the first hours in darkness adopt
    // the lit shards' mature models at their first affordable boundary
    // instead of answering Unknown until they can learn for themselves
    let isolated = with_fleet(preset("air_quality", 42, 8 * H).unwrap(), 16, 1_800_000_000);
    let mut synced = isolated.clone();
    synced.fleet.as_mut().unwrap().sync = Some(hourly_sync(SyncStrategy::AllReduce));
    let iso = isolated.run_fleet(0).unwrap();
    let syn = synced.run_fleet(0).unwrap();
    assert!(
        syn.rollup.mean_accuracy.mean > iso.rollup.mean_accuracy.mean,
        "sync did not lift the fleet: synced {:.4} vs isolated {:.4}",
        syn.rollup.mean_accuracy.mean,
        iso.rollup.mean_accuracy.mean
    );
    // the lift was paid for: radio exchanges happened and were metered
    assert!(syn.rollup.syncs_done.total > 0.0);
    let radioed = syn
        .shards
        .iter()
        .flat_map(|r| &r.action_tallies)
        .any(|(n, c, e, _)| n == "tx" && *c > 0 && *e > 0.0);
    assert!(radioed, "no tx tally metered");
    // isolated documents carry no sync keys (PR-4 shape)
    assert!(!fleet_fp(&iso).contains("syncs_"));
    assert!(fleet_fp(&syn).contains("\"syncs_done\""));
}

#[test]
fn starved_shard_skips_sync_rounds_energy_gating_observable() {
    // a 0 W override shard can never cover the radio price: every round
    // it reports a skip, while its healthy siblings keep exchanging
    let mut spec = with_fleet(preset("vibration", 5, 3 * H).unwrap(), 3, 0);
    {
        let fleet = spec.fleet.as_mut().unwrap();
        fleet.overrides =
            vec![ShardOverride::harvester(1, HarvesterSpec::Constant { power_w: 0.0 })];
        fleet.sync = Some(hourly_sync(SyncStrategy::Gossip));
    }
    let fr = spec.run_fleet(0).unwrap();
    let starved = &fr.shards[1];
    assert_eq!(starved.syncs_done, 0, "a dead shard paid for radio");
    assert!(
        starved.syncs_skipped > 0,
        "energy gating invisible: {starved:?}"
    );
    assert!(fr.rollup.syncs_skipped.total >= starved.syncs_skipped as f64);
    // healthy shards completed exchanges in the same rounds
    assert!(fr.shards[0].syncs_done + fr.shards[2].syncs_done > 0);
}

#[test]
fn event_scheduler_matches_the_round_barrier_on_all_presets() {
    // acceptance: under one uniform sync period the event heap replays
    // the round barrier bit for bit — same rendezvous instants, same
    // rotation partners, same radio prices — on every paper preset, and
    // the event side is itself deterministic for threads {1, 2, 0}
    for name in ["air_quality", "presence", "vibration"] {
        let mut spec = with_fleet(preset(name, 7, 2 * H).unwrap(), 3, 1_800_000_000);
        spec.fleet.as_mut().unwrap().sync = Some(hourly_sync(SyncStrategy::Gossip));
        spec.fleet.as_mut().unwrap().sched = Some(FleetSched::Rounds);
        let rounds = spec.run_fleet(0).unwrap();
        spec.fleet.as_mut().unwrap().sched = Some(FleetSched::Event);
        for threads in [1, 2, 0] {
            let event = spec.run_fleet(threads).unwrap();
            assert_eq!(
                fleet_fp(&rounds),
                fleet_fp(&event),
                "{name}: event scheduler diverged from the round barrier (threads {threads})"
            );
        }
        // an unset `sched` knob defaults to the event scheduler
        spec.fleet.as_mut().unwrap().sched = None;
        assert_eq!(
            fleet_fp(&rounds),
            fleet_fp(&spec.run_fleet(0).unwrap()),
            "{name}: default sched is not the event scheduler"
        );
    }
}

#[test]
fn heterogeneous_period_fleet_attends_per_shard_boundaries_only() {
    // periods 30/60/90 min over a 2 h horizon: shard 0 wakes at its own
    // three boundaries, shards 1 and 2 only at theirs — there is no
    // fleet-wide barrier to drag them to the others'. Every attended
    // boundary is accounted exactly once (done, skipped or solo), and
    // the whole fleet is bit-identical across thread counts.
    let mut spec = with_fleet(preset("vibration", 7, 2 * H).unwrap(), 3, 0);
    {
        let fleet = spec.fleet.as_mut().unwrap();
        fleet.sync = Some(SyncSpec {
            period_us: 1_800_000_000,
            strategy: SyncStrategy::Gossip,
            radio: None,
        });
        fleet.overrides = vec![
            ShardOverride::sync_period(1, 3_600_000_000),
            ShardOverride::sync_period(2, 5_400_000_000),
        ];
    }
    let fr = spec.run_fleet(1).unwrap();
    // strict-interior boundary counts: 30 min → {30, 60, 90}, 60 min →
    // {60}, 90 min → {90} (the 120 min horizon itself is never a wake)
    let attempts: Vec<u64> = fr
        .shards
        .iter()
        .map(|r| r.syncs_done + r.syncs_skipped + r.syncs_solo)
        .collect();
    assert_eq!(attempts, vec![3, 1, 1], "per-shard rendezvous attendance");
    // shard 0's 30 min boundary has no partner: whenever it can afford
    // the radio it rides solo, never a phantom exchange
    assert!(fr.shards[1].syncs_done <= 1 && fr.shards[2].syncs_done <= 1);
    for threads in [2, 0] {
        assert_eq!(
            fleet_fp(&fr),
            fleet_fp(&spec.run_fleet(threads).unwrap()),
            "threads {threads}: heterogeneous-period fleet diverged"
        );
    }
    // the rounds barrier cannot express per-shard cadences: named
    // together they are rejected up front
    spec.fleet.as_mut().unwrap().sched = Some(FleetSched::Rounds);
    let err = spec.run_fleet(1).unwrap_err().to_string();
    assert!(err.contains("event scheduler"), "{err}");
}

#[test]
fn trace_corpus_files_load_and_power_a_fleet() {
    // the preset corpus is real spec surface: load a corpus file by path
    // and slice it across shards via phase jitter
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/traces/solar_day.csv");
    let trace = Trace::from_csv(path).unwrap();
    assert!(trace.points.len() > 90, "corpus file unexpectedly short");
    assert!(trace.points.iter().any(|&(_, p)| p > 0.01));

    let mut spec = preset("air_quality", 1, 6 * H).unwrap();
    spec.harvester = HarvesterSpec::Trace {
        points: trace.points,
        path: Some(path.to_string()),
    };
    // 4 shards staggered by 2 h: each replays a different slice of the day
    let spec = with_fleet(spec, 4, 2 * 3_600_000_000);
    let fr = spec.run_fleet(0).unwrap();
    assert_eq!(fr.shards.len(), 4);
    let cycles: Vec<u64> = fr.shards.iter().map(|r| r.cycles).collect();
    assert!(cycles.iter().any(|&c| c != cycles[0]), "slices identical: {cycles:?}");
    // the spec (with its corpus path) round-trips through JSON
    let back = ScenarioSpec::parse(&spec.to_json().to_string()).unwrap();
    assert_eq!(back, spec);
}
