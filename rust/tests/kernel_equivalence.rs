//! Event-kernel equivalence: on all three paper presets, the analytic
//! event-driven charge kernel must reproduce the stepped reference
//! oracle's `RunResult` within tolerance.
//!
//! The kernels are *not* bit-identical by design — the oracle holds the
//! instantaneous power sampled at each step start for up to
//! `charge_step_us`, while the event kernel uses exact segment means — so
//! wake instants drift by seconds over multi-hour runs and individual
//! examples differ. What must match is everything aggregate: wake-cycle
//! counts, sensed/learned/inferred tallies, and total energy.

use ilearn::apps::AppKind;
use ilearn::sim::{ChargeKernel, RunResult};

const H: u64 = 3_600_000_000;

fn run_with(kind: AppKind, hours: u64, kernel: ChargeKernel) -> RunResult {
    let mut spec = kind.spec(42, hours * H);
    spec.charge_kernel = kernel;
    spec.build_engine().unwrap().run().unwrap()
}

/// |a - b| within `rel` of the larger, or within `abs` absolutely.
fn close(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    (a - b).abs() <= (rel * a.abs().max(b.abs())).max(abs)
}

fn assert_equivalent(kind: AppKind, hours: u64, ev: &RunResult, st: &RunResult) {
    let ctx = format!(
        "{:?} {hours}h\n event : cycles {} sensed {} learned {} inferred {} energy {:.0}\n \
         stepped: cycles {} sensed {} learned {} inferred {} energy {:.0}",
        kind,
        ev.cycles,
        ev.sensed,
        ev.learned,
        ev.inferred,
        ev.energy_uj,
        st.cycles,
        st.sensed,
        st.learned,
        st.inferred,
        st.energy_uj
    );
    // The oracle itself under-harvests bursty sources (it holds the power
    // sampled at each step start, losing the front of a gesture that
    // begins mid-step), so the event kernel legitimately wakes a few
    // percent *more* often on piezo worlds — the tolerances below bound
    // that modelling gap, not numerical error.
    assert!(st.cycles > 0 && st.sensed > 0, "dead oracle run: {ctx}");
    assert!(
        close(ev.cycles as f64, st.cycles as f64, 0.15, 5.0),
        "wake count diverged: {ctx}"
    );
    assert!(
        close(ev.sensed as f64, st.sensed as f64, 0.25, 15.0),
        "sensed diverged: {ctx}"
    );
    assert!(
        close(ev.learned as f64, st.learned as f64, 0.25, 15.0),
        "learned diverged: {ctx}"
    );
    assert!(
        close(ev.inferred as f64, st.inferred as f64, 0.25, 15.0),
        "inferred diverged: {ctx}"
    );
    assert!(
        close(ev.energy_uj, st.energy_uj, 0.15, 2_000.0),
        "energy diverged: {ctx}"
    );
    // same checkpoint cadence (driven by the clock, not the kernel)
    assert!(
        close(ev.checkpoints.len() as f64, st.checkpoints.len() as f64, 0.1, 2.0),
        "checkpoint count diverged: {ctx}"
    );
}

#[test]
fn vibration_event_kernel_matches_stepped_oracle() {
    // piezo energy arrives in second-bucketed gesture bursts: the kernels
    // integrate the same piecewise-constant texture, so this preset pins
    // the tightest equivalence
    let ev = run_with(AppKind::Vibration, 4, ChargeKernel::Event);
    let st = run_with(AppKind::Vibration, 4, ChargeKernel::Stepped);
    assert_equivalent(AppKind::Vibration, 4, &ev, &st);
}

#[test]
fn presence_event_kernel_matches_stepped_oracle() {
    let ev = run_with(AppKind::Presence, 8, ChargeKernel::Event);
    let st = run_with(AppKind::Presence, 8, ChargeKernel::Stepped);
    assert_equivalent(AppKind::Presence, 8, &ev, &st);
}

#[test]
fn air_quality_event_kernel_matches_stepped_oracle_across_a_night() {
    // 24 h of solar: covers a full night (the event kernel crosses it in
    // one segment; the oracle crawls it in 60 s steps) plus a sunrise ramp
    let ev = run_with(AppKind::AirQuality, 24, ChargeKernel::Event);
    let st = run_with(AppKind::AirQuality, 24, ChargeKernel::Stepped);
    assert_equivalent(AppKind::AirQuality, 24, &ev, &st);
}
