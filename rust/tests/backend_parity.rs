//! Backend parity: the PJRT backend (AOT HLO artifacts — L1 Pallas kernels
//! lowered through the L2 JAX model) must agree with the native rust
//! backend on every payload, over randomized inputs.
//!
//! pytest pins kernels ↔ jnp oracle; this test pins pjrt ↔ native; together
//! they pin all three layers to one semantics.
//!
//! Requires `make artifacts` and the `pjrt` cargo feature; the suite
//! fails with a clear message if the artifacts are missing.
#![cfg(feature = "pjrt")]

use ilearn::backend::native::NativeBackend;
use ilearn::backend::pjrt::PjrtBackend;
use ilearn::backend::shapes::*;
use ilearn::backend::ComputeBackend;
use ilearn::util::Rng;

fn pjrt() -> PjrtBackend {
    PjrtBackend::discover().expect(
        "PJRT artifacts not found — run `make artifacts` before `cargo test`",
    )
}

fn buf(rng: &mut Rng, count: usize) -> (Vec<f32>, Vec<f32>) {
    let mut ex = vec![0.0f32; N_BUF * FEAT_DIM];
    let mut mask = vec![0.0f32; N_BUF];
    for i in 0..count {
        mask[i] = 1.0;
        for j in 0..FEAT_DIM {
            ex[i * FEAT_DIM + j] = rng.normal(0.0, 3.0) as f32;
        }
    }
    (ex, mask)
}

fn vecn(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| rng.normal(0.0, scale) as f32).collect()
}

fn close(a: f32, b: f32, tol: f32) -> bool {
    let denom = a.abs().max(b.abs()).max(1.0);
    (a - b).abs() / denom < tol
}

#[test]
fn extract_parity() {
    let mut p = pjrt();
    let mut n = NativeBackend::new();
    let mut rng = Rng::new(1);
    for _ in 0..5 {
        let win = vecn(&mut rng, WINDOW * CHANNELS, 2.0);
        let a = p.extract(&win).unwrap();
        let b = n.extract(&win).unwrap();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(close(*x, *y, 1e-4), "feature {i}: pjrt {x} native {y}");
        }
    }
}

#[test]
fn knn_learn_parity() {
    let mut p = pjrt();
    let mut n = NativeBackend::new();
    let mut rng = Rng::new(2);
    for count in [4, 17, 40, 64] {
        let (ex, mask) = buf(&mut rng, count);
        let mut sp = vec![0.0f32; N_BUF];
        let mut sn = vec![0.0f32; N_BUF];
        let tp = p.knn_learn(&ex, &mask, &mut sp).unwrap();
        let tn = n.knn_learn(&ex, &mask, &mut sn).unwrap();
        assert!(close(tp, tn, 1e-4), "threshold: pjrt {tp} native {tn} (count {count})");
        for i in 0..N_BUF {
            assert!(close(sp[i], sn[i], 1e-3), "score {i}: {} vs {}", sp[i], sn[i]);
        }
    }
}

#[test]
fn knn_infer_parity_scalar_and_batch() {
    let mut p = pjrt();
    let mut n = NativeBackend::new();
    let mut rng = Rng::new(3);
    let (ex, mask) = buf(&mut rng, 30);
    for _ in 0..5 {
        let x = vecn(&mut rng, FEAT_DIM, 3.0);
        let a = p.knn_infer(&ex, &mask, &x).unwrap();
        let b = n.knn_infer(&ex, &mask, &x).unwrap();
        assert!(close(a, b, 1e-4), "pjrt {a} native {b}");
    }
    let xs = vecn(&mut rng, BATCH * FEAT_DIM, 3.0);
    let mut a = vec![0.0f32; BATCH];
    let mut b = vec![0.0f32; BATCH];
    p.knn_infer_batch(&ex, &mask, &xs, &mut a).unwrap();
    n.knn_infer_batch(&ex, &mask, &xs, &mut b).unwrap();
    for i in 0..BATCH {
        assert!(close(a[i], b[i], 1e-4), "batch {i}: {} vs {}", a[i], b[i]);
    }
}

#[test]
fn kmeans_parity() {
    let mut p = pjrt();
    let mut n = NativeBackend::new();
    let mut rng = Rng::new(4);
    for _ in 0..10 {
        let w = vecn(&mut rng, N_CLUSTERS * FEAT_DIM, 1.0);
        let x = vecn(&mut rng, FEAT_DIM, 1.0);
        let eta = rng.f32() * 0.8;
        let mut wp = w.clone();
        let mut wn = w.clone();
        let mut ap = [0.0f32; N_CLUSTERS];
        let mut an = [0.0f32; N_CLUSTERS];
        let winp = p.kmeans_learn(&mut wp, &x, eta, &mut ap).unwrap();
        let winn = n.kmeans_learn(&mut wn, &x, eta, &mut an).unwrap();
        assert_eq!(winp, winn, "winner diverged");
        for i in 0..N_CLUSTERS {
            assert!(close(ap[i], an[i], 1e-4), "act {i}: {} vs {}", ap[i], an[i]);
        }
        for i in 0..w.len() {
            assert!(close(wp[i], wn[i], 1e-4), "w {i}: {} vs {}", wp[i], wn[i]);
        }
        let ip = p.kmeans_infer(&w, &x).unwrap();
        let inn = n.kmeans_infer(&w, &x).unwrap();
        for i in 0..N_CLUSTERS {
            assert!(close(ip[i], inn[i], 1e-4));
        }
    }
}

#[test]
fn diversity_repr_parity() {
    let mut p = pjrt();
    let mut n = NativeBackend::new();
    let mut rng = Rng::new(5);
    for _ in 0..5 {
        let b = vecn(&mut rng, KLAST * FEAT_DIM, 2.0);
        let bp = vecn(&mut rng, KLAST * FEAT_DIM, 2.0);
        let x = vecn(&mut rng, FEAT_DIM, 2.0);
        let a = p.diversity_repr(&b, &bp, &x).unwrap();
        let c = n.diversity_repr(&b, &bp, &x).unwrap();
        for i in 0..4 {
            assert!(close(a[i], c[i], 1e-3), "score {i}: {} vs {}", a[i], c[i]);
        }
    }
}

#[test]
fn learners_agree_across_backends() {
    // identical learner fed identical examples on both backends must make
    // identical decisions (within tolerance of the threshold comparison)
    use ilearn::learning::{Example, KnnAnomalyLearner, Learner};
    let mut p = pjrt();
    let mut n = NativeBackend::new();
    let mut lp = KnnAnomalyLearner::new();
    let mut ln = KnnAnomalyLearner::new();
    let mut rng = Rng::new(6);
    for t in 0..25u64 {
        let ex = Example::new(vecn(&mut rng, FEAT_DIM, 1.0), t, false);
        lp.learn(&ex, &mut p).unwrap();
        ln.learn(&ex, &mut n).unwrap();
    }
    assert!(close(lp.threshold(), ln.threshold(), 1e-4));
    let mut agree = 0;
    for t in 0..20u64 {
        let scale = if t % 4 == 0 { 10.0 } else { 1.0 };
        let ex = Example::new(vecn(&mut rng, FEAT_DIM, scale), 100 + t, false);
        let vp = lp.infer(&ex, &mut p).unwrap();
        let vn = ln.infer(&ex, &mut n).unwrap();
        agree += (vp == vn) as u32;
    }
    assert!(agree >= 19, "verdict agreement {agree}/20");
}
