//! Scenario-spec API integration tests: JSON round-trips that rebuild
//! identical worlds, preset equivalence with the legacy `AppConfig` path,
//! and sweep determinism across thread counts.

use ilearn::apps::AppKind;
use ilearn::backend::native::NativeBackend;
use ilearn::energy::harvester::{Piezo, Rf};
use ilearn::energy::{Capacitor, CostModel};
use ilearn::learning::{ClusterLabelLearner, KnnAnomalyLearner};
use ilearn::planner::{DynamicActionPlanner, Goal, PlannerConfig};
use ilearn::scenario::{preset, ScenarioSpec, SweepRunner, SweepSpec, PRESETS};
use ilearn::selection::Heuristic;
use ilearn::sensors::accel::{Accel, MotionProfile};
use ilearn::sensors::Rssi;
use ilearn::sim::engine::Engine;
use ilearn::sim::{PlannerScheduler, SimConfig};

const H: u64 = 3_600_000_000;

/// Strong run comparison: the full JSON rendering (counters, accuracy
/// summaries, checkpoints, per-action tallies).
fn fingerprint(r: &ilearn::sim::RunResult) -> String {
    r.to_json().to_string()
}

/// The pre-refactor `AppConfig::build_engine` wiring for the vibration
/// app, transcribed by hand. This is the independent fixture the preset
/// is measured against — it must NOT go through `scenario::preset` (the
/// old `apps::AppConfig` now delegates there, so comparing against it
/// would be circular).
fn legacy_vibration_engine(seed: u64, horizon_us: u64) -> Engine {
    let hours = (horizon_us / H).max(1);
    let profile = MotionProfile::alternating_hours(1.2, 3.4, hours);
    Engine::builder()
        .sim(SimConfig {
            seed,
            horizon_us,
            eval_period_us: (horizon_us / 24).max(60_000_000),
            probe_count: 30,
            probe_lookback_us: 2 * H,
            charge_step_us: 1_000_000,
            ..Default::default()
        })
        .harvester(Box::new(Piezo::new(profile.clone())))
        .capacitor(Capacitor::vibration())
        .sensor(Box::new(Accel::new(profile, seed)))
        .learner(Box::new(ClusterLabelLearner::new(seed, 30)))
        .selector(Heuristic::RoundRobin.build(seed ^ 0x5E1))
        .scheduler(Box::new(PlannerScheduler(DynamicActionPlanner::new(
            Goal {
                rho_learn: 0.6,
                n_learn: 100,
                rho_infer: 1.0,
                window: 10,
            },
            PlannerConfig::default(),
        ))))
        .backend(Box::new(NativeBackend::new()))
        .costs(CostModel::kmeans())
        .build()
        .unwrap()
}

/// The pre-refactor wiring for the presence app (see above).
fn legacy_presence_engine(seed: u64, horizon_us: u64) -> Engine {
    Engine::builder()
        .sim(SimConfig {
            seed,
            horizon_us,
            eval_period_us: (horizon_us / 24).max(60_000_000),
            probe_count: 30,
            probe_lookback_us: 2 * H,
            charge_step_us: 60_000_000,
            ..Default::default()
        })
        .harvester(Box::new(Rf {
            seed: seed ^ 0xB0,
            ..Rf::default()
        }))
        .capacitor(Capacitor::presence())
        .sensor(Box::new(Rssi::three_areas(seed, horizon_us, horizon_us / 3)))
        .learner(Box::new(KnnAnomalyLearner::new()))
        .selector(Heuristic::RoundRobin.build(seed ^ 0x5E1))
        .scheduler(Box::new(PlannerScheduler(DynamicActionPlanner::new(
            Goal {
                rho_learn: 0.7,
                n_learn: u64::MAX,
                rho_infer: 1.2,
                window: 10,
            },
            PlannerConfig::default(),
        ))))
        .backend(Box::new(NativeBackend::new()))
        .costs(CostModel::knn_rssi())
        .build()
        .unwrap()
}

#[test]
fn preset_reproduces_the_legacy_construction_bit_for_bit() {
    let spec_r = AppKind::Vibration
        .spec(11, 2 * H)
        .build_engine()
        .unwrap()
        .run()
        .unwrap();
    let legacy_r = legacy_vibration_engine(11, 2 * H).run().unwrap();
    assert_eq!(
        fingerprint(&spec_r),
        fingerprint(&legacy_r),
        "vibration preset diverged from the pre-refactor construction"
    );
    assert!(spec_r.sensed > 0, "empty run proves nothing");

    let spec_r = AppKind::Presence
        .spec(11, 4 * H)
        .build_engine()
        .unwrap()
        .run()
        .unwrap();
    let legacy_r = legacy_presence_engine(11, 4 * H).run().unwrap();
    assert_eq!(
        fingerprint(&spec_r),
        fingerprint(&legacy_r),
        "presence preset diverged from the pre-refactor construction"
    );
    assert!(spec_r.cycles > 0, "empty run proves nothing");
}

#[test]
fn json_round_trip_rebuilds_an_identical_world() {
    for name in PRESETS {
        let spec = preset(name, 7, 2 * H).unwrap();
        let text = spec.to_json().to_string();
        let back = ScenarioSpec::parse(&text).unwrap();
        assert_eq!(back, spec, "{name}: parse(to_json) changed the spec");
    }
    // and the rebuilt world runs identically (vibration: cheap + eventful)
    let spec = preset("vibration", 9, 2 * H).unwrap();
    let back = ScenarioSpec::parse(&spec.to_json().to_string()).unwrap();
    let a = spec.build_engine().unwrap().run().unwrap();
    let b = back.build_engine().unwrap().run().unwrap();
    assert_eq!(fingerprint(&a), fingerprint(&b));
    assert!(a.sensed > 0);
}

#[test]
fn sweep_grid_is_deterministic_across_thread_counts() {
    // 2 scenarios x 2 schedulers x 2 seeds (the acceptance grid)
    let grid = r#"{
        "name": "acceptance",
        "hours": 2,
        "scenarios": ["vibration", "presence"],
        "seeds": [1, 2],
        "schedulers": ["planner", "alpaca:50"]
    }"#;
    let sweep = SweepSpec::parse(grid).unwrap();
    assert_eq!(sweep.expand().unwrap().len(), 8);

    let serial = SweepRunner::new(1).run(&sweep).unwrap();
    let threaded = SweepRunner::new(4).run(&sweep).unwrap();
    assert_eq!(serial.len(), threaded.len());
    for (a, b) in serial.iter().zip(&threaded) {
        assert_eq!(a.id, b.id, "cell order changed with thread count");
        let (ra, rb) = (
            a.result.as_ref().unwrap().primary(),
            b.result.as_ref().unwrap().primary(),
        );
        assert_eq!(
            fingerprint(ra),
            fingerprint(rb),
            "cell `{}` diverged across thread counts",
            a.id
        );
    }
    // the grid actually exercised both axes
    let sched = |o: &ilearn::scenario::SweepOutcome| {
        o.result.as_ref().unwrap().primary().scheduler.clone()
    };
    assert!(serial.iter().any(|o| sched(o) == "intermittent_learning"));
    assert!(serial.iter().any(|o| sched(o).starts_with("alpaca")));
    // per-cell JSON documents carry spec + result
    let doc = serial[0].to_json().to_string();
    assert!(doc.contains("\"spec\"") && doc.contains("\"result\""));
}

#[test]
fn run_and_rollup_json_keep_the_pre_sync_golden_shape() {
    use ilearn::sim::{FleetRollup, RunResult};
    // golden strings pinned to the PR-4 document shapes: a run (or fleet)
    // that never hit a sync boundary must serialize WITHOUT the sync keys
    // so archived sweep outputs diff clean against new ones
    let r = RunResult {
        scheduler: "s".into(),
        ..Default::default()
    };
    assert_eq!(
        r.to_json().to_string(),
        "{\"scheduler\":\"s\",\"cycles\":0,\"sensed\":0,\"learned\":0,\"inferred\":0,\
         \"discarded_select\":0,\"expired\":0,\"power_failures\":0,\"stale_plans\":0,\
         \"energy_uj\":0,\"mean_accuracy\":0,\"final_accuracy\":0,\"online_accuracy\":0,\
         \"checkpoints\":[],\"action_tallies\":[]}"
    );
    let zero = "{\"mean\":0,\"min\":0,\"max\":0,\"total\":0}";
    assert_eq!(
        FleetRollup::of(&[r.clone()]).to_json().to_string(),
        format!(
            "{{\"shards\":1,\"final_accuracy\":{zero},\"mean_accuracy\":{zero},\
             \"energy_uj\":{zero},\"learned\":{zero},\"inferred\":{zero},\
             \"power_failures\":{zero},\"stale_plans\":{zero}}}"
        )
    );
    // ... and a run that DID sync gains exactly the two counters, between
    // stale_plans and energy_uj
    let mut synced = r;
    synced.syncs_done = 3;
    synced.syncs_skipped = 1;
    assert!(synced.to_json().to_string().contains(
        "\"stale_plans\":0,\"syncs_done\":3,\"syncs_skipped\":1,\"energy_uj\":0"
    ));
    let roll = FleetRollup::of(&[synced]).to_json().to_string();
    assert!(roll.contains("\"syncs_done\""));
}

#[test]
fn sweep_outcome_documents_keep_pre_sync_shapes_end_to_end() {
    use ilearn::scenario::FleetSpec;
    // one fleet-less cell and one sync-less 2-shard fleet cell through the
    // real runner: the PR-4 payload shapes survive
    let sweep = SweepSpec::parse(r#"{"hours": 1, "scenarios": ["vibration"], "seeds": [1, 2]}"#)
        .unwrap();
    let mut cells = sweep.expand().unwrap();
    cells[1].spec.fleet = Some(FleetSpec {
        shards: 2,
        ..FleetSpec::default()
    });
    let outcomes = SweepRunner::new(2).run_cells(cells);
    let plain = outcomes[0].to_json().to_string();
    assert!(plain.contains("\"result\":{\"scheduler\":"), "{plain}");
    assert!(!plain.contains("\"fleet\":{"), "{plain}");
    assert!(!plain.contains("syncs_"), "{plain}");
    let fleet = outcomes[1].to_json().to_string();
    assert!(fleet.contains("\"fleet\":{\"shards\":2,\"rollup\":{"), "{fleet}");
    assert!(!fleet.contains("syncs_"), "sync keys leaked into a sync-less fleet doc");
    assert!(!fleet.contains("\"sync\""), "spec sync block leaked");
}

#[test]
fn failing_cell_does_not_discard_the_sweep() {
    // backend=pjrt in the default (pure-rust) build fails that cell at
    // engine construction; the sibling native cell must still complete
    let grid = r#"{
        "hours": 2,
        "scenarios": ["vibration"],
        "backends": ["native", "pjrt"]
    }"#;
    let sweep = SweepSpec::parse(grid).unwrap();
    let outcomes = SweepRunner::new(2).run(&sweep).unwrap();
    assert_eq!(outcomes.len(), 2);
    let native = outcomes.iter().find(|o| o.id.contains("-native-")).unwrap();
    let pjrt = outcomes.iter().find(|o| o.id.contains("-pjrt-")).unwrap();
    assert!(native.result.is_ok(), "{:?}", native.result);
    // under the pjrt feature (artifacts present) that cell may even pass;
    // what matters is the native cell above survived either way
    if !cfg!(feature = "pjrt") {
        let err = pjrt.result.as_ref().unwrap_err();
        assert!(err.contains("pjrt"), "{err}");
        let doc = pjrt.to_json().to_string();
        assert!(doc.contains("\"error\""));
    }
}
