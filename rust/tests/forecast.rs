//! Forecast-policy integration pins: the `policy` spec block present but
//! disabled must be byte-for-byte invisible — identical run and fleet
//! result JSON to a spec with no block at all, on every paper preset and
//! across worker thread counts — and a default spec document must not
//! carry a `policy` key, so pre-knob archived specs and sweep outputs
//! diff clean against new ones.

use ilearn::scenario::{preset, FleetSpec, PolicySpec, ScenarioSpec};
use ilearn::sim::{FleetResult, RunResult};

const H: u64 = 3_600_000_000;

fn fp(r: &RunResult) -> String {
    r.to_json().to_string()
}

fn fleet_fp(f: &FleetResult) -> String {
    f.to_json().to_string()
}

fn with_knob(mut spec: ScenarioSpec, forecast: bool) -> ScenarioSpec {
    spec.policy = Some(PolicySpec { forecast });
    spec
}

fn with_fleet(mut spec: ScenarioSpec, shards: u32) -> ScenarioSpec {
    spec.fleet = Some(FleetSpec {
        shards,
        phase_jitter_us: 60_000_000,
        seed_stride: 1,
        overrides: vec![],
        sync: None,
        sched: None,
        stream: None,
    });
    spec
}

#[test]
fn disabled_knob_runs_are_byte_identical_to_the_default_policy() {
    for name in ["air_quality", "presence", "vibration"] {
        let plain = preset(name, 7, 2 * H).unwrap();
        let base = plain.build_engine().unwrap().run().unwrap();
        let knob = with_knob(preset(name, 7, 2 * H).unwrap(), false)
            .build_engine()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(
            fp(&base),
            fp(&knob),
            "{name}: a present-but-disabled policy block changed the run"
        );
        // the dormant knob leaks no forecast counters into the document
        assert!(!fp(&knob).contains("checkpoints_elided"), "{name}");
        assert!(!fp(&knob).contains("ckpt_nvm_bytes"), "{name}");
    }
}

#[test]
fn disabled_knob_fleets_are_byte_identical_across_thread_counts() {
    for name in ["air_quality", "presence", "vibration"] {
        let base = with_fleet(preset(name, 7, 2 * H).unwrap(), 2)
            .run_fleet(1)
            .unwrap();
        let knob = with_fleet(with_knob(preset(name, 7, 2 * H).unwrap(), false), 2);
        for threads in [1, 2, 0] {
            let got = knob.run_fleet(threads).unwrap();
            assert_eq!(
                fleet_fp(&base),
                fleet_fp(&got),
                "{name}: disabled policy block diverged (threads {threads})"
            );
        }
    }
}

#[test]
fn default_spec_documents_carry_no_policy_key() {
    for name in ["air_quality", "presence", "vibration"] {
        let doc = preset(name, 7, 2 * H).unwrap().to_json().to_string();
        assert!(!doc.contains("\"policy\""), "{name}: {doc}");
        // the dormant knob round-trips without becoming the default
        let knob = with_knob(preset(name, 7, 2 * H).unwrap(), false);
        let back = ScenarioSpec::parse(&knob.to_json().to_string()).unwrap();
        assert_eq!(back.policy, Some(PolicySpec { forecast: false }));
    }
}

#[test]
fn forecast_fleets_are_bit_identical_across_thread_counts() {
    // the new code path itself must stay thread-count deterministic
    let spec = with_fleet(with_knob(preset("vibration", 3, 2 * H).unwrap(), true), 4);
    let one = spec.run_fleet(1).unwrap();
    for threads in [2, 0] {
        let got = spec.run_fleet(threads).unwrap();
        assert_eq!(
            fleet_fp(&one),
            fleet_fp(&got),
            "forecast fleet diverged (threads {threads})"
        );
    }
    // and the counters actually surface in the fleet document
    assert!(
        one.shards
            .iter()
            .any(|r| r.checkpoints_taken + r.checkpoints_elided > 0),
        "forecast fleet never exercised the checkpoint path"
    );
}
