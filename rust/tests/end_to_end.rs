//! End-to-end integration: full engine runs per app/scheduler, failure
//! injection, and the complete three-layer stack (PJRT backend) driving a
//! real simulated workload.

use ilearn::apps::{AppConfig, AppKind, SchedulerKind};
use ilearn::selection::Heuristic;

const H: u64 = 3_600_000_000;

#[test]
fn vibration_end_to_end_learns_and_detects() {
    let cfg = AppConfig::new(AppKind::Vibration, 42, 4 * H);
    let r = cfg.build_engine().unwrap().run().unwrap();
    assert!(r.learned >= 20, "learned {}", r.learned);
    assert!(r.inferred > 50, "inferred {}", r.inferred);
    assert!(r.final_accuracy() >= 0.7, "final acc {}", r.final_accuracy());
    // energy-data correlation: no energy at idle -> cycles bounded by
    // gesture count (400 gestures, few wakes each)
    assert!(r.cycles < 4_000, "cycles {}", r.cycles);
}

#[test]
fn presence_recovers_after_area_moves() {
    let cfg = AppConfig::new(AppKind::Presence, 42, 24 * H);
    let r = cfg.build_engine().unwrap().run().unwrap();
    // area moves at 8 h and 16 h: accuracy during the last quarter of each
    // area's dwell should exceed the accuracy right after the move
    let acc_at = |h_lo: f64, h_hi: f64| -> f64 {
        let cps: Vec<f64> = r
            .checkpoints
            .iter()
            .filter(|c| {
                let h = c.t_us as f64 / H as f64;
                h > h_lo && h <= h_hi
            })
            .map(|c| c.accuracy)
            .collect();
        cps.iter().sum::<f64>() / cps.len().max(1) as f64
    };
    let settled_area3 = acc_at(21.0, 24.0);
    let after_move3 = acc_at(16.0, 18.0);
    assert!(
        settled_area3 >= after_move3 - 0.05,
        "no recovery: settled {settled_area3:.2} vs after-move {after_move3:.2}"
    );
    assert!(r.mean_accuracy(6) > 0.6, "mean {}", r.mean_accuracy(6));
}

#[test]
fn air_quality_learns_on_solar_cycle() {
    let cfg = AppConfig::new(AppKind::AirQuality, 42, 36 * H);
    let r = cfg.build_engine().unwrap().run().unwrap();
    assert!(r.learned > 10);
    // night hours contribute no harvest: there must be long sleep gaps —
    // wake cycles far fewer than a continuously powered system would have
    assert!(r.mean_accuracy(6) > 0.6, "mean {}", r.mean_accuracy(6));
}

#[test]
fn intermittent_learner_beats_alpaca_on_vibration() {
    // headline claim (§7.1 shape): at the same world/horizon, IL reaches
    // at least the best Alpaca accuracy while learning far fewer examples
    let mut il = AppConfig::new(AppKind::Vibration, 7, 6 * H);
    il.scheduler = SchedulerKind::Planner;
    let il_r = il.build_engine().unwrap().run().unwrap();

    let mut best_alpaca = 0.0f64;
    let mut alpaca_learned = 0u64;
    for pct in [0.1, 0.5, 0.9] {
        let mut a = AppConfig::new(AppKind::Vibration, 7, 6 * H);
        a.scheduler = SchedulerKind::Alpaca { learn_pct: pct };
        let r = a.build_engine().unwrap().run().unwrap();
        if r.mean_accuracy(4) > best_alpaca {
            best_alpaca = r.mean_accuracy(4);
            alpaca_learned = r.learned;
        }
    }
    assert!(
        il_r.mean_accuracy(4) >= best_alpaca - 0.05,
        "IL {:.2} vs best alpaca {:.2}",
        il_r.mean_accuracy(4),
        best_alpaca
    );
    assert!(
        il_r.learned < alpaca_learned,
        "IL learned {} vs alpaca {}",
        il_r.learned,
        alpaca_learned
    );
}

#[test]
fn selection_heuristics_cut_learned_examples() {
    // §7.3 shape: with selection on, fewer examples learned at comparable
    // accuracy vs no-selection
    let mut none = AppConfig::new(AppKind::Vibration, 9, 4 * H);
    none.heuristic = Heuristic::None;
    let r_none = none.build_engine().unwrap().run().unwrap();
    let mut rr = AppConfig::new(AppKind::Vibration, 9, 4 * H);
    rr.heuristic = Heuristic::RoundRobin;
    let r_rr = rr.build_engine().unwrap().run().unwrap();
    assert!(
        r_rr.discarded_select > 0,
        "round robin never discarded anything"
    );
    assert!(
        r_rr.final_accuracy() >= r_none.final_accuracy() - 0.1,
        "rr {:.2} vs none {:.2}",
        r_rr.final_accuracy(),
        r_none.final_accuracy()
    );
}

#[test]
#[cfg(feature = "pjrt")]
fn full_stack_pjrt_backend_runs_the_paper_workload() {
    use ilearn::apps::BackendKind;
    // The three-layer proof: Pallas kernels (L1) lowered through the JAX
    // model (L2), executed by the rust coordinator (L3) on PJRT, drive a
    // real intermittent-learning workload end to end.
    let mut cfg = AppConfig::new(AppKind::Vibration, 42, H);
    cfg.backend = BackendKind::Pjrt;
    let r = cfg
        .build_engine()
        .expect("PJRT artifacts not found — run `make artifacts` first")
        .run()
        .unwrap();
    assert!(r.learned > 0 && r.inferred > 0);

    // and it must agree with the native backend on the same world
    let mut native = AppConfig::new(AppKind::Vibration, 42, H);
    native.backend = BackendKind::Native;
    let n = native.build_engine().unwrap().run().unwrap();
    assert_eq!(r.learned, n.learned, "learned diverged across backends");
    assert_eq!(r.inferred, n.inferred);
    assert_eq!(r.cycles, n.cycles);
    let (ra, na) = (r.final_accuracy(), n.final_accuracy());
    assert!((ra - na).abs() < 0.11, "final acc pjrt {ra} vs native {na}");
}
