//! The declarative scenario specification: every knob of a device world —
//! harvester, capacitor, sensor, cost model, learner, goal, scheduler,
//! selection heuristic, backend, horizon, seed — as plain serializable
//! data. A [`ScenarioSpec`] can be validated, round-tripped through JSON
//! (`util::json`), and compiled into a ready-to-run engine via the
//! [`crate::sim::engine::EngineBuilder`].

use crate::actions::Action;
use crate::backend::native::NativeBackend;
#[cfg(feature = "pjrt")]
use crate::backend::pjrt::PjrtBackend;
use crate::backend::ComputeBackend;
use crate::baselines::{DutyCycleScheduler, MayflyScheduler};
use crate::energy::cost::ActionCost;
use crate::energy::harvester::{Constant, Harvester, PhaseShift, Piezo, Rf, Solar, Trace, DAY_S};
use crate::energy::{Capacitor, CostModel};
use crate::error::{Error, Result};
use crate::learning::{ClusterLabelLearner, KnnAnomalyLearner, Learner};
use crate::planner::{DynamicActionPlanner, Goal, PlannerConfig};
use crate::selection::Heuristic;
use crate::sensors::accel::{Accel, MotionProfile};
use crate::sensors::rssi::Area;
use crate::sensors::{AirQuality, Rssi, Sensor};
use crate::sim::engine::Engine;
use crate::sim::fleet::{
    Fleet, FleetResult, FleetSched, Shard, ShardFactory, SyncPlan, SyncStrategy,
};
use crate::sim::{ChargeKernel, PlannerScheduler, Scheduler, SimConfig, StreamResult};
use crate::util::json::Json;

// ------------------------------------------------------------ json helpers

fn req<'a>(j: &'a Json, key: &str, what: &str) -> Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| Error::Config(format!("{what}: missing field `{key}`")))
}

fn req_f64(j: &Json, key: &str, what: &str) -> Result<f64> {
    req(j, key, what)?
        .as_f64()
        .ok_or_else(|| Error::Config(format!("{what}: field `{key}` must be a number")))
}

fn req_u64(j: &Json, key: &str, what: &str) -> Result<u64> {
    req(j, key, what)?
        .as_u64()
        .ok_or_else(|| {
            Error::Config(format!("{what}: field `{key}` must be a non-negative integer"))
        })
}

fn req_u32(j: &Json, key: &str, what: &str) -> Result<u32> {
    let v = req_u64(j, key, what)?;
    u32::try_from(v).map_err(|_| {
        Error::Config(format!("{what}: field `{key}` value {v} exceeds u32 range"))
    })
}

fn req_str<'a>(j: &'a Json, key: &str, what: &str) -> Result<&'a str> {
    req(j, key, what)?
        .as_str()
        .ok_or_else(|| Error::Config(format!("{what}: field `{key}` must be a string")))
}

fn opt_u64(j: &Json, key: &str, what: &str) -> Result<Option<u64>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) if v.is_null() => Ok(None),
        Some(v) => v.as_u64().map(Some).ok_or_else(|| {
            Error::Config(format!("{what}: field `{key}` must be an integer or null"))
        }),
    }
}

/// `[[t_us, value], ...]` pair lists (harvester schedules / traces).
fn pairs_to_json(pairs: &[(u64, f64)]) -> Json {
    Json::Arr(
        pairs
            .iter()
            .map(|&(t, v)| Json::nums([t as f64, v]))
            .collect(),
    )
}

fn pairs_from_json(j: &Json, what: &str) -> Result<Vec<(u64, f64)>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| Error::Config(format!("{what}: expected an array of [t_us, value]")))?;
    arr.iter()
        .map(|p| {
            let pair = p.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                Error::Config(format!("{what}: each entry must be a [t_us, value] pair"))
            })?;
            let t = pair[0].as_u64().ok_or_else(|| {
                Error::Config(format!("{what}: pair time must be a non-negative integer"))
            })?;
            let v = pair[1]
                .as_f64()
                .ok_or_else(|| Error::Config(format!("{what}: pair value must be a number")))?;
            Ok((t, v))
        })
        .collect()
}

// ------------------------------------------------------------ motion spec

/// The §6.3 gesture protocol: alternating gentle/abrupt shaking hours.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MotionSpec {
    /// Gentle-hour shake amplitude.
    pub gentle: f64,
    /// Abrupt-hour shake amplitude.
    pub abrupt: f64,
    /// Hours of alternating protocol to generate.
    pub hours: u64,
}

impl MotionSpec {
    pub fn build(&self) -> MotionProfile {
        MotionProfile::alternating_hours(self.gentle, self.abrupt, self.hours)
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("gentle", Json::Num(self.gentle)),
            ("abrupt", Json::Num(self.abrupt)),
            ("hours", Json::Num(self.hours as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<MotionSpec> {
        Ok(MotionSpec {
            gentle: req_f64(j, "gentle", "motion")?,
            abrupt: req_f64(j, "abrupt", "motion")?,
            hours: req_u64(j, "hours", "motion")?,
        })
    }
}

// ---------------------------------------------------------- harvester spec

/// Which energy source powers the scenario. Per-source seeds are optional:
/// `None` reproduces the paper apps' wiring exactly — solar and RF derive
/// from the scenario seed (`^ 0xA0` / `^ 0xB0`, so seed sweeps re-seed
/// their noise streams), while piezo keeps its fixed default jitter seed
/// (the legacy apps never varied it; its randomness rides mostly on the
/// motion profile). Pin `Some(seed)` to control any of them explicitly.
#[derive(Debug, Clone, PartialEq)]
pub enum HarvesterSpec {
    Solar {
        peak_w: f64,
        sunrise_s: f64,
        sunset_s: f64,
        cloud_prob: f64,
        seed: Option<u64>,
    },
    Rf {
        p_ref_w: f64,
        d_ref_m: f64,
        /// (start_us, distance_m) schedule, sorted by time.
        schedule: Vec<(u64, f64)>,
        seed: Option<u64>,
    },
    Piezo {
        motion: MotionSpec,
        w_per_amp2: f64,
        seed: Option<u64>,
    },
    Constant {
        power_w: f64,
    },
    Trace {
        points: Vec<(u64, f64)>,
        /// CSV file the points were loaded from ([`Trace::from_csv`]);
        /// `Some` serializes as the path (re-loaded on parse), `None` as
        /// the inline point list.
        path: Option<String>,
    },
}

impl HarvesterSpec {
    pub fn kind(&self) -> &'static str {
        match self {
            HarvesterSpec::Solar { .. } => "solar",
            HarvesterSpec::Rf { .. } => "rf",
            HarvesterSpec::Piezo { .. } => "piezo",
            HarvesterSpec::Constant { .. } => "constant",
            HarvesterSpec::Trace { .. } => "trace",
        }
    }

    /// Instantiate; `scenario_seed` feeds the per-source seed derivations
    /// (`^ 0xA0` solar, `^ 0xB0` RF — the paper apps' wiring).
    pub fn build(&self, scenario_seed: u64) -> Box<dyn Harvester> {
        match self {
            HarvesterSpec::Solar {
                peak_w,
                sunrise_s,
                sunset_s,
                cloud_prob,
                seed,
            } => Box::new(Solar::new(
                *peak_w,
                *sunrise_s,
                *sunset_s,
                *cloud_prob,
                seed.unwrap_or(scenario_seed ^ 0xA0),
            )),
            HarvesterSpec::Rf {
                p_ref_w,
                d_ref_m,
                schedule,
                seed,
            } => Box::new(Rf {
                p_ref_w: *p_ref_w,
                d_ref_m: *d_ref_m,
                schedule: schedule.clone(),
                seed: seed.unwrap_or(scenario_seed ^ 0xB0),
            }),
            HarvesterSpec::Piezo {
                motion,
                w_per_amp2,
                seed,
            } => {
                let mut p = Piezo::new(motion.build());
                p.w_per_amp2 = *w_per_amp2;
                if let Some(s) = seed {
                    p.seed = *s;
                }
                Box::new(p)
            }
            HarvesterSpec::Constant { power_w } => Box::new(Constant(*power_w)),
            HarvesterSpec::Trace { points, .. } => Box::new(Trace {
                points: points.clone(),
            }),
        }
    }

    fn validate(&self, what: &str) -> Result<()> {
        let bad = |msg: String| Err(Error::Config(format!("{what}: {msg}")));
        match self {
            HarvesterSpec::Solar {
                peak_w,
                sunrise_s,
                sunset_s,
                cloud_prob,
                ..
            } => {
                if *peak_w < 0.0 {
                    return bad(format!("solar peak_w {peak_w} must be >= 0"));
                }
                if sunrise_s >= sunset_s {
                    return bad(format!("solar sunrise {sunrise_s} must precede sunset {sunset_s}"));
                }
                // both kernels assume seconds-of-day; out-of-range values
                // would make the stepped and event integrators disagree
                if !(0.0..DAY_S).contains(sunrise_s) || !(0.0..=DAY_S).contains(sunset_s) {
                    return bad(format!(
                        "solar sunrise {sunrise_s} / sunset {sunset_s} must be seconds-of-day \
                         within [0, {DAY_S}]"
                    ));
                }
                if !(0.0..=1.0).contains(cloud_prob) {
                    return bad(format!("solar cloud_prob {cloud_prob} must be in [0, 1]"));
                }
            }
            HarvesterSpec::Rf {
                p_ref_w,
                d_ref_m,
                schedule,
                ..
            } => {
                if *p_ref_w < 0.0 || *d_ref_m <= 0.0 {
                    return bad("rf p_ref_w must be >= 0 and d_ref_m > 0".into());
                }
                if schedule.is_empty() {
                    return bad("rf schedule must not be empty".into());
                }
                if schedule.windows(2).any(|w| w[0].0 >= w[1].0) {
                    return bad("rf schedule times must be strictly increasing".into());
                }
                if schedule.iter().any(|&(_, d)| d <= 0.0) {
                    return bad("rf schedule distances must be > 0".into());
                }
            }
            HarvesterSpec::Piezo {
                motion, w_per_amp2, ..
            } => {
                if *w_per_amp2 <= 0.0 {
                    return bad("piezo w_per_amp2 must be > 0".into());
                }
                if motion.hours == 0 {
                    return bad("piezo motion hours must be > 0".into());
                }
            }
            HarvesterSpec::Constant { power_w } => {
                if *power_w < 0.0 {
                    return bad(format!("constant power_w {power_w} must be >= 0"));
                }
            }
            HarvesterSpec::Trace { points, .. } => {
                if points.is_empty() {
                    return bad("trace must not be empty (a permanently 0 W world)".into());
                }
                if points.windows(2).any(|w| w[0].0 >= w[1].0) {
                    return bad("trace times must be strictly increasing".into());
                }
                if points.iter().any(|&(_, p)| p < 0.0) {
                    return bad("trace powers must be >= 0".into());
                }
            }
        }
        Ok(())
    }

    fn seed_json(seed: &Option<u64>) -> Json {
        match seed {
            Some(s) => Json::Num(*s as f64),
            None => Json::Null,
        }
    }

    fn to_json(&self) -> Json {
        match self {
            HarvesterSpec::Solar {
                peak_w,
                sunrise_s,
                sunset_s,
                cloud_prob,
                seed,
            } => Json::obj(vec![
                ("kind", "solar".into()),
                ("peak_w", Json::Num(*peak_w)),
                ("sunrise_s", Json::Num(*sunrise_s)),
                ("sunset_s", Json::Num(*sunset_s)),
                ("cloud_prob", Json::Num(*cloud_prob)),
                ("seed", Self::seed_json(seed)),
            ]),
            HarvesterSpec::Rf {
                p_ref_w,
                d_ref_m,
                schedule,
                seed,
            } => Json::obj(vec![
                ("kind", "rf".into()),
                ("p_ref_w", Json::Num(*p_ref_w)),
                ("d_ref_m", Json::Num(*d_ref_m)),
                ("schedule", pairs_to_json(schedule)),
                ("seed", Self::seed_json(seed)),
            ]),
            HarvesterSpec::Piezo {
                motion,
                w_per_amp2,
                seed,
            } => Json::obj(vec![
                ("kind", "piezo".into()),
                ("motion", motion.to_json()),
                ("w_per_amp2", Json::Num(*w_per_amp2)),
                ("seed", Self::seed_json(seed)),
            ]),
            HarvesterSpec::Constant { power_w } => Json::obj(vec![
                ("kind", "constant".into()),
                ("power_w", Json::Num(*power_w)),
            ]),
            HarvesterSpec::Trace { points, path } => match path {
                Some(p) => Json::obj(vec![
                    ("kind", "trace".into()),
                    ("path", Json::Str(p.clone())),
                ]),
                None => Json::obj(vec![
                    ("kind", "trace".into()),
                    ("points", pairs_to_json(points)),
                ]),
            },
        }
    }

    fn from_json(j: &Json) -> Result<HarvesterSpec> {
        let what = "harvester";
        // `type` is accepted as a synonym for `kind` (trace-corpus specs)
        let kind = match j.get("kind").or_else(|| j.get("type")) {
            Some(v) => v.as_str().ok_or_else(|| {
                Error::Config(format!("{what}: field `kind` must be a string"))
            })?,
            None => return Err(Error::Config(format!("{what}: missing field `kind`"))),
        };
        match kind {
            "solar" => Ok(HarvesterSpec::Solar {
                peak_w: req_f64(j, "peak_w", what)?,
                sunrise_s: req_f64(j, "sunrise_s", what)?,
                sunset_s: req_f64(j, "sunset_s", what)?,
                cloud_prob: req_f64(j, "cloud_prob", what)?,
                seed: opt_u64(j, "seed", what)?,
            }),
            "rf" => Ok(HarvesterSpec::Rf {
                p_ref_w: req_f64(j, "p_ref_w", what)?,
                d_ref_m: req_f64(j, "d_ref_m", what)?,
                schedule: pairs_from_json(req(j, "schedule", what)?, "harvester schedule")?,
                seed: opt_u64(j, "seed", what)?,
            }),
            "piezo" => Ok(HarvesterSpec::Piezo {
                motion: MotionSpec::from_json(req(j, "motion", what)?)?,
                w_per_amp2: req_f64(j, "w_per_amp2", what)?,
                seed: opt_u64(j, "seed", what)?,
            }),
            "constant" => Ok(HarvesterSpec::Constant {
                power_w: req_f64(j, "power_w", what)?,
            }),
            "trace" => match j.get("path").filter(|v| !v.is_null()) {
                Some(v) => {
                    let path = v.as_str().ok_or_else(|| {
                        Error::Config(format!("{what}: trace `path` must be a string"))
                    })?;
                    Ok(HarvesterSpec::Trace {
                        points: Trace::from_csv(path)?.points,
                        path: Some(path.to_string()),
                    })
                }
                None => Ok(HarvesterSpec::Trace {
                    points: pairs_from_json(req(j, "points", what)?, "harvester trace")?,
                    path: None,
                }),
            },
            other => Err(Error::Config(format!(
                "unknown harvester kind `{other}` (solar|rf|piezo|constant|trace)"
            ))),
        }
    }
}

// ---------------------------------------------------------- capacitor spec

/// Capacitor parameters (§6 platform columns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CapacitorSpec {
    pub c_f: f64,
    pub v_max: f64,
    pub v_on: f64,
    pub v_off: f64,
    pub leak_w: f64,
    pub eff: f64,
}

impl CapacitorSpec {
    pub fn from_capacitor(c: &Capacitor) -> CapacitorSpec {
        CapacitorSpec {
            c_f: c.c_f,
            v_max: c.v_max,
            v_on: c.v_on,
            v_off: c.v_off,
            leak_w: c.leak_w,
            eff: c.eff,
        }
    }

    pub fn build(&self) -> Capacitor {
        let mut c = Capacitor::new(self.c_f, self.v_max, self.v_on, self.v_off);
        c.leak_w = self.leak_w;
        c.eff = self.eff;
        c
    }

    fn validate(&self, what: &str) -> Result<()> {
        if self.c_f <= 0.0 {
            return Err(Error::Config(format!(
                "{what}: capacitance {} F must be > 0",
                self.c_f
            )));
        }
        if !(self.v_max >= self.v_on && self.v_on > self.v_off && self.v_off >= 0.0) {
            return Err(Error::Config(format!(
                "{what}: need v_max >= v_on > v_off >= 0, got {} / {} / {}",
                self.v_max, self.v_on, self.v_off
            )));
        }
        if !(0.0 < self.eff && self.eff <= 1.0) {
            return Err(Error::Config(format!(
                "{what}: efficiency {} must be in (0, 1]",
                self.eff
            )));
        }
        if self.leak_w < 0.0 {
            return Err(Error::Config(format!(
                "{what}: leakage {} W must be >= 0",
                self.leak_w
            )));
        }
        Ok(())
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("c_f", Json::Num(self.c_f)),
            ("v_max", Json::Num(self.v_max)),
            ("v_on", Json::Num(self.v_on)),
            ("v_off", Json::Num(self.v_off)),
            ("leak_w", Json::Num(self.leak_w)),
            ("eff", Json::Num(self.eff)),
        ])
    }

    fn from_json(j: &Json) -> Result<CapacitorSpec> {
        let what = "capacitor";
        Ok(CapacitorSpec {
            c_f: req_f64(j, "c_f", what)?,
            v_max: req_f64(j, "v_max", what)?,
            v_on: req_f64(j, "v_on", what)?,
            v_off: req_f64(j, "v_off", what)?,
            leak_w: req_f64(j, "leak_w", what)?,
            eff: req_f64(j, "eff", what)?,
        })
    }
}

// ------------------------------------------------------------- sensor spec

/// Which sensor world the scenario observes. Seeded from the scenario seed
/// and spanning the scenario horizon at build time.
#[derive(Debug, Clone, PartialEq)]
pub enum SensorSpec {
    /// §6.1 UV/eCO2/TVOC world with diurnal structure.
    AirQuality,
    /// §6.2 RSSI presence world (three areas). `distances` reproduces the
    /// Fig. 15(b) protocol: one area whose observable human perturbation
    /// scales with the RF link budget at each (start_us, distance_m) step.
    Rssi { distances: Option<Vec<(u64, f64)>> },
    /// §6.3 accelerometer gesture world.
    Accel { motion: MotionSpec },
}

impl SensorSpec {
    pub fn kind(&self) -> &'static str {
        match self {
            SensorSpec::AirQuality => "air_quality",
            SensorSpec::Rssi { .. } => "rssi",
            SensorSpec::Accel { .. } => "accel",
        }
    }

    pub fn build(&self, seed: u64, horizon_us: u64) -> Box<dyn Sensor> {
        match self {
            SensorSpec::AirQuality => Box::new(AirQuality::new(seed, horizon_us)),
            SensorSpec::Rssi { distances } => {
                let mut r = Rssi::three_areas(seed, horizon_us, horizon_us / 3);
                if let Some(sched) = distances {
                    // The device stays in one RF environment but its
                    // distance to the powered antenna changes; the human
                    // perturbation rides on the carrier, so its observable
                    // magnitude scales with the link budget (§7.4). The
                    // scale is referenced to the paper's 3 m deployment
                    // distance — it intentionally does NOT track a custom
                    // harvester `d_ref_m`, which calibrates received
                    // *power*, not the observable perturbation baseline.
                    const REF_DISTANCE_M: f64 = 3.0;
                    let base = r.areas[0];
                    r.areas = sched
                        .iter()
                        .map(|&(start_us, d_m)| {
                            let scale =
                                (REF_DISTANCE_M / d_m.max(0.1)).powi(2).min(1.5);
                            Area {
                                start_us,
                                base_dbm: base.base_dbm,
                                noise_db: base.noise_db,
                                human_db: base.human_db * scale,
                                human_shift_db: base.human_shift_db * scale,
                            }
                        })
                        .collect();
                }
                Box::new(r)
            }
            SensorSpec::Accel { motion } => Box::new(Accel::new(motion.build(), seed)),
        }
    }

    fn validate(&self, what: &str) -> Result<()> {
        match self {
            SensorSpec::Rssi {
                distances: Some(d),
            } => {
                if d.is_empty() {
                    return Err(Error::Config(format!(
                        "{what}: rssi distances must not be empty when given"
                    )));
                }
                if d.windows(2).any(|w| w[0].0 >= w[1].0) {
                    return Err(Error::Config(format!(
                        "{what}: rssi distance times must be strictly increasing"
                    )));
                }
                if d.iter().any(|&(_, m)| m <= 0.0) {
                    return Err(Error::Config(format!(
                        "{what}: rssi distances must be > 0"
                    )));
                }
            }
            SensorSpec::Accel { motion } if motion.hours == 0 => {
                return Err(Error::Config(format!(
                    "{what}: accel motion hours must be > 0"
                )));
            }
            _ => {}
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        match self {
            SensorSpec::AirQuality => Json::obj(vec![("kind", "air_quality".into())]),
            SensorSpec::Rssi { distances } => Json::obj(vec![
                ("kind", "rssi".into()),
                (
                    "distances",
                    match distances {
                        Some(d) => pairs_to_json(d),
                        None => Json::Null,
                    },
                ),
            ]),
            SensorSpec::Accel { motion } => Json::obj(vec![
                ("kind", "accel".into()),
                ("motion", motion.to_json()),
            ]),
        }
    }

    fn from_json(j: &Json) -> Result<SensorSpec> {
        let what = "sensor";
        match req_str(j, "kind", what)? {
            "air_quality" => Ok(SensorSpec::AirQuality),
            "rssi" => {
                let distances = match j.get("distances") {
                    None => None,
                    Some(v) if v.is_null() => None,
                    Some(v) => Some(pairs_from_json(v, "sensor distances")?),
                };
                Ok(SensorSpec::Rssi { distances })
            }
            "accel" => Ok(SensorSpec::Accel {
                motion: MotionSpec::from_json(req(j, "motion", what)?)?,
            }),
            other => Err(Error::Config(format!(
                "unknown sensor kind `{other}` (air_quality|rssi|accel)"
            ))),
        }
    }
}

// --------------------------------------------------------------- cost kind

/// Which of the paper's calibrated cost tables (Fig. 16) the scenario pays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostKind {
    Knn,
    Kmeans,
    KnnRssi,
}

impl CostKind {
    pub const ALL: [CostKind; 3] = [CostKind::Knn, CostKind::Kmeans, CostKind::KnnRssi];

    pub fn name(self) -> &'static str {
        match self {
            CostKind::Knn => "knn",
            CostKind::Kmeans => "kmeans",
            CostKind::KnnRssi => "knn_rssi",
        }
    }

    pub fn parse(s: &str) -> Option<CostKind> {
        CostKind::ALL.into_iter().find(|c| c.name() == s)
    }

    pub fn build(self) -> CostModel {
        match self {
            CostKind::Knn => CostModel::knn(),
            CostKind::Kmeans => CostModel::kmeans(),
            CostKind::KnnRssi => CostModel::knn_rssi(),
        }
    }
}

// ------------------------------------------------------------ learner spec

/// Which on-device learner processes the examples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LearnerSpec {
    /// k-NN anomaly learner (air-quality / presence apps).
    Knn,
    /// NN-k-means cluster-then-label learner with a semi-supervised label
    /// budget (vibration app).
    ClusterLabel { label_budget: u32 },
}

impl LearnerSpec {
    pub fn kind(self) -> &'static str {
        match self {
            LearnerSpec::Knn => "knn",
            LearnerSpec::ClusterLabel { .. } => "cluster_label",
        }
    }

    pub fn build(self, seed: u64) -> Box<dyn Learner> {
        match self {
            LearnerSpec::Knn => Box::new(KnnAnomalyLearner::new()),
            LearnerSpec::ClusterLabel { label_budget } => {
                Box::new(ClusterLabelLearner::new(seed, label_budget))
            }
        }
    }

    fn to_json(self) -> Json {
        match self {
            LearnerSpec::Knn => Json::obj(vec![("kind", "knn".into())]),
            LearnerSpec::ClusterLabel { label_budget } => Json::obj(vec![
                ("kind", "cluster_label".into()),
                ("label_budget", Json::Num(label_budget as f64)),
            ]),
        }
    }

    fn from_json(j: &Json) -> Result<LearnerSpec> {
        match req_str(j, "kind", "learner")? {
            "knn" => Ok(LearnerSpec::Knn),
            "cluster_label" => Ok(LearnerSpec::ClusterLabel {
                label_budget: req_u32(j, "label_budget", "learner")?,
            }),
            other => Err(Error::Config(format!(
                "unknown learner kind `{other}` (knn|cluster_label)"
            ))),
        }
    }
}

// ---------------------------------------------------------- scheduler kind

/// Scheduler selection for the experiment matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedulerKind {
    /// The paper's dynamic action planner.
    Planner,
    /// Alpaca-style fixed duty cycle, `learn_pct` of examples learned.
    Alpaca { learn_pct: f64 },
    /// Mayfly-style duty cycle + data expiration.
    Mayfly { learn_pct: f64, expiry_us: u64 },
}

impl SchedulerKind {
    pub fn build(self, goal: Goal) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Planner => Box::new(PlannerScheduler(DynamicActionPlanner::new(
                goal,
                PlannerConfig::default(),
            ))),
            SchedulerKind::Alpaca { learn_pct } => Box::new(DutyCycleScheduler::new(learn_pct)),
            SchedulerKind::Mayfly {
                learn_pct,
                expiry_us,
            } => Box::new(MayflyScheduler::new(learn_pct, expiry_us)),
        }
    }

    /// Duty cycle as a clean percent string: rounded to 1/10000th of a
    /// percent and stripped of float noise ("50", "12.5" — never
    /// "28.999999999999996").
    fn pct(learn_pct: f64) -> String {
        let s = format!("{:.4}", learn_pct * 100.0);
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    }

    /// Display label matching the paper's series naming (rounds the duty
    /// cycle to a whole percent, drops the expiry). For identity use
    /// [`SchedulerKind::id`].
    pub fn label(self) -> String {
        match self {
            SchedulerKind::Planner => "intermittent_learning".into(),
            SchedulerKind::Alpaca { learn_pct } => {
                format!("alpaca_{}l", (learn_pct * 100.0).round() as u32)
            }
            SchedulerKind::Mayfly { learn_pct, .. } => {
                format!("mayfly_{}l", (learn_pct * 100.0).round() as u32)
            }
        }
    }

    /// Filename-safe identity: distinguishes every parameter (duty cycle
    /// to 1/10000th of a percent, mayfly expiry exactly) so sweep cells
    /// over e.g. two mayfly expiries or fractional duty cycles never
    /// collide.
    pub fn id(self) -> String {
        match self {
            SchedulerKind::Planner => "intermittent_learning".into(),
            SchedulerKind::Alpaca { learn_pct } => {
                format!("alpaca_{}l", Self::pct(learn_pct))
            }
            SchedulerKind::Mayfly {
                learn_pct,
                expiry_us,
            } => format!("mayfly_{}l_{}us", Self::pct(learn_pct), expiry_us),
        }
    }

    /// Parse the CLI/sweep shorthand:
    /// `planner` | `alpaca:<pct>` | `mayfly:<pct>:<expiry_s>`.
    pub fn parse(s: &str) -> Result<SchedulerKind> {
        if s == "planner" {
            return Ok(SchedulerKind::Planner);
        }
        let parts: Vec<&str> = s.split(':').collect();
        let bad = || {
            Error::Config(format!(
                "bad scheduler `{s}` (planner | alpaca:<pct> | mayfly:<pct>:<expiry_s>)"
            ))
        };
        match parts.as_slice() {
            ["alpaca", pct] => Ok(SchedulerKind::Alpaca {
                learn_pct: pct.parse::<f64>().map_err(|_| bad())? / 100.0,
            }),
            ["mayfly", pct, expiry_s] => Ok(SchedulerKind::Mayfly {
                learn_pct: pct.parse::<f64>().map_err(|_| bad())? / 100.0,
                expiry_us: expiry_s
                    .parse::<u64>()
                    .ok()
                    .and_then(|s| s.checked_mul(1_000_000))
                    .ok_or_else(bad)?,
            }),
            _ => Err(bad()),
        }
    }

    fn validate(&self, what: &str) -> Result<()> {
        let pct = match self {
            SchedulerKind::Planner => return Ok(()),
            SchedulerKind::Alpaca { learn_pct } => *learn_pct,
            SchedulerKind::Mayfly {
                learn_pct,
                expiry_us,
            } => {
                if *expiry_us == 0 {
                    return Err(Error::Config(format!(
                        "{what}: mayfly expiry_us must be > 0"
                    )));
                }
                *learn_pct
            }
        };
        if !(0.0..=1.0).contains(&pct) {
            return Err(Error::Config(format!(
                "{what}: learn_pct {pct} must be in [0, 1]"
            )));
        }
        Ok(())
    }

    fn to_json(self) -> Json {
        match self {
            SchedulerKind::Planner => Json::obj(vec![("kind", "planner".into())]),
            SchedulerKind::Alpaca { learn_pct } => Json::obj(vec![
                ("kind", "alpaca".into()),
                ("learn_pct", Json::Num(learn_pct)),
            ]),
            SchedulerKind::Mayfly {
                learn_pct,
                expiry_us,
            } => Json::obj(vec![
                ("kind", "mayfly".into()),
                ("learn_pct", Json::Num(learn_pct)),
                ("expiry_us", Json::Num(expiry_us as f64)),
            ]),
        }
    }

    /// Accepts both the object form (`{"kind": "alpaca", "learn_pct": 0.5}`)
    /// and the CLI shorthand string (`"alpaca:50"`).
    pub fn from_json(j: &Json) -> Result<SchedulerKind> {
        if let Some(s) = j.as_str() {
            return SchedulerKind::parse(s);
        }
        match req_str(j, "kind", "scheduler")? {
            "planner" => Ok(SchedulerKind::Planner),
            "alpaca" => Ok(SchedulerKind::Alpaca {
                learn_pct: req_f64(j, "learn_pct", "scheduler")?,
            }),
            "mayfly" => Ok(SchedulerKind::Mayfly {
                learn_pct: req_f64(j, "learn_pct", "scheduler")?,
                expiry_us: req_u64(j, "expiry_us", "scheduler")?,
            }),
            other => Err(Error::Config(format!(
                "unknown scheduler kind `{other}` (planner|alpaca|mayfly)"
            ))),
        }
    }
}

// ------------------------------------------------------------ backend kind

/// Compute-backend selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust math (fast; used for the big sweeps).
    Native,
    /// AOT HLO artifacts on the PJRT CPU client (full 3-layer stack;
    /// requires the `pjrt` cargo feature and `make artifacts`).
    Pjrt,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }

    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "native" => Some(BackendKind::Native),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    pub fn build(self) -> Result<Box<dyn ComputeBackend>> {
        match self {
            BackendKind::Native => Ok(Box::new(NativeBackend::new())),
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => Ok(Box::new(PjrtBackend::discover()?)),
            #[cfg(not(feature = "pjrt"))]
            BackendKind::Pjrt => Err(Error::Config(
                "this binary was built without PJRT support; rebuild with \
                 `--features pjrt` (and run `make artifacts`)"
                    .into(),
            )),
        }
    }
}

// -------------------------------------------------------------- sync spec

/// Radio cost overrides for the sync exchange, replacing the cost table's
/// calibrated `tx`/`rx` entries (deployments radio different payloads
/// over different links than the defaults assume).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RadioSpec {
    pub tx_uj: f64,
    pub tx_us: u64,
    pub rx_uj: f64,
    pub rx_us: u64,
}

impl RadioSpec {
    fn validate(&self, what: &str) -> Result<()> {
        if self.tx_uj < 0.0 || self.rx_uj < 0.0 {
            return Err(Error::Config(format!(
                "{what}: radio energies must be >= 0 (tx {} / rx {})",
                self.tx_uj, self.rx_uj
            )));
        }
        Ok(())
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("tx_uj", Json::Num(self.tx_uj)),
            ("tx_us", Json::Num(self.tx_us as f64)),
            ("rx_uj", Json::Num(self.rx_uj)),
            ("rx_us", Json::Num(self.rx_us as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<RadioSpec> {
        let what = "sync radio";
        Ok(RadioSpec {
            tx_uj: req_f64(j, "tx_uj", what)?,
            tx_us: req_u64(j, "tx_us", what)?,
            rx_uj: req_f64(j, "rx_uj", what)?,
            rx_us: req_u64(j, "rx_us", what)?,
        })
    }
}

/// The fleet `"sync"` block: round-based federated aggregation. Every
/// `period_us` of simulated time the fleet pauses at a sync boundary,
/// shards that can cover the radio price exchange learner snapshots under
/// `strategy`, merge, and continue. Absent (`None` on [`FleetSpec`]):
/// shards learn in total isolation — the pre-sync behavior bit for bit.
#[derive(Debug, Clone, PartialEq)]
pub struct SyncSpec {
    /// Sync boundary period, µs (> 0).
    pub period_us: u64,
    /// `gossip` (1 rotating partner/round) or `all_reduce` (everyone).
    pub strategy: SyncStrategy,
    /// Optional radio cost overrides (default: the cost table's entries).
    pub radio: Option<RadioSpec>,
}

impl SyncSpec {
    fn validate(&self, what: &str) -> Result<()> {
        if self.period_us == 0 {
            return Err(Error::Config(format!(
                "{what}: sync period_us must be > 0"
            )));
        }
        if let Some(r) = &self.radio {
            r.validate(what)?;
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        let mut kvs = vec![
            ("period_us", Json::Num(self.period_us as f64)),
            ("strategy", Json::Str(self.strategy.name().into())),
        ];
        if let Some(r) = self.radio {
            kvs.push(("radio", r.to_json()));
        }
        Json::obj(kvs)
    }

    pub fn from_json(j: &Json) -> Result<SyncSpec> {
        let what = "fleet sync";
        let strategy = match j.get("strategy") {
            None => SyncStrategy::Gossip,
            Some(v) if v.is_null() => SyncStrategy::Gossip,
            Some(v) => {
                let name = v.as_str().ok_or_else(|| {
                    Error::Config(format!("{what}: `strategy` must be a string"))
                })?;
                SyncStrategy::parse(name).ok_or_else(|| {
                    Error::Config(format!(
                        "unknown sync strategy `{name}` (gossip|all_reduce)"
                    ))
                })?
            }
        };
        Ok(SyncSpec {
            period_us: req_u64(j, "period_us", what)?,
            strategy,
            radio: match j.get("radio") {
                None => None,
                Some(v) if v.is_null() => None,
                Some(v) => Some(RadioSpec::from_json(v)?),
            },
        })
    }
}

// --------------------------------------------------------- shard override

/// One shard's declared deviations from the fleet-wide scenario: replace
/// its harvester (heterogeneous power — a few RF nodes in a solar
/// deployment) and/or its sync cadence (heterogeneous rendezvous — a
/// starved node attends every other boundary). At least one field must
/// be set; `sync_period_us` requires a `"sync"` block and the event
/// scheduler (the round barrier pauses every shard at every fleet-wide
/// boundary and cannot honor per-shard cadences).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardOverride {
    /// Shard index this override applies to.
    pub shard: u32,
    /// Replacement harvester (`None`: the scenario's own).
    pub harvester: Option<HarvesterSpec>,
    /// Shard-local sync period, µs (`None`: the fleet-wide period).
    pub sync_period_us: Option<u64>,
}

impl ShardOverride {
    /// Harvester-only override (the pre-event-scheduler shape).
    pub fn harvester(shard: u32, harvester: HarvesterSpec) -> Self {
        ShardOverride {
            shard,
            harvester: Some(harvester),
            sync_period_us: None,
        }
    }

    /// Sync-cadence-only override.
    pub fn sync_period(shard: u32, period_us: u64) -> Self {
        ShardOverride {
            shard,
            harvester: None,
            sync_period_us: Some(period_us),
        }
    }

    fn validate(&self, what: &str, shards: u32, synced: bool) -> Result<()> {
        if self.shard >= shards {
            return Err(Error::Config(format!(
                "{what}: fleet override names shard {} but the fleet has {shards} shard(s)",
                self.shard
            )));
        }
        if self.harvester.is_none() && self.sync_period_us.is_none() {
            return Err(Error::Config(format!(
                "{what}: fleet override for shard {} sets neither a harvester \
                 nor a sync_period_us",
                self.shard
            )));
        }
        if let Some(h) = &self.harvester {
            h.validate(&format!("{what} (shard {} override)", self.shard))?;
        }
        if let Some(p) = self.sync_period_us {
            if p == 0 {
                return Err(Error::Config(format!(
                    "{what}: shard {} sync_period_us override must be > 0",
                    self.shard
                )));
            }
            if !synced {
                return Err(Error::Config(format!(
                    "{what}: shard {} overrides sync_period_us but the fleet \
                     has no sync block",
                    self.shard
                )));
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        // emitted only when present: pre-event-scheduler harvester-only
        // overrides keep their JSON shape byte for byte
        let mut kvs = vec![("shard", Json::Num(self.shard as f64))];
        if let Some(h) = &self.harvester {
            kvs.push(("harvester", h.to_json()));
        }
        if let Some(p) = self.sync_period_us {
            kvs.push(("sync_period_us", Json::Num(p as f64)));
        }
        Json::obj(kvs)
    }

    fn from_json(j: &Json) -> Result<ShardOverride> {
        let what = "fleet override";
        Ok(ShardOverride {
            shard: req_u32(j, "shard", what)?,
            harvester: match j.get("harvester") {
                None => None,
                Some(v) if v.is_null() => None,
                Some(v) => Some(HarvesterSpec::from_json(v)?),
            },
            sync_period_us: opt_u64(j, "sync_period_us", what)?,
        })
    }
}

// ------------------------------------------------------------- fleet spec

/// A fleet block: one scenario deployed across `shards` devices. Shard
/// `i` derives its world from the per-shard seed/offset rule —
/// `seed + i × seed_stride` re-seeds the sensor, learner, selection
/// heuristic and (by derivation) the harvester's stochastic texture, and
/// `i × phase_jitter_us` phase-shifts the harvester (so 16 solar nodes
/// see the same diurnal curve each a little deeper into the day, and
/// trace shards replay distinct slices of one recording). `overrides`
/// optionally replaces the harvester and/or sync cadence of named shards
/// (heterogeneous fleets: a few RF nodes in a solar deployment, a weak
/// node syncing at half rate).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub shards: u32,
    /// Per-shard harvester phase offset (shard i starts i × this deeper
    /// into the energy world).
    pub phase_jitter_us: u64,
    /// Per-shard seed stride (shard i runs at seed + i × this).
    pub seed_stride: u64,
    /// Per-shard overrides, sorted by shard index.
    pub overrides: Vec<ShardOverride>,
    /// Round-based federated sync (`None`: isolated shards, the pre-sync
    /// fleet behavior bit for bit).
    pub sync: Option<SyncSpec>,
    /// Which coordinator drives the synced fleet (`None`: the default,
    /// [`FleetSched::Event`]). `rounds` pins the reference barrier and is
    /// incompatible with per-shard sync cadences.
    pub sched: Option<FleetSched>,
    /// Streaming fan-in (`Some(true)`: fold-and-drop shard execution via
    /// [`crate::sim::run_streaming`] — bounded memory, no per-shard
    /// results; `Some(false)`: always retain per-shard results; `None`:
    /// auto — stream when the fleet is isolated and at least
    /// [`FleetSpec::STREAM_AUTO_SHARDS`] shards).
    pub stream: Option<bool>,
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec {
            shards: 1,
            phase_jitter_us: 0,
            seed_stride: 1,
            overrides: Vec::new(),
            sync: None,
            sched: None,
            stream: None,
        }
    }
}

impl FleetSpec {
    /// Auto-stream threshold: an unset `stream` knob streams isolated
    /// fleets of at least this many shards (a million 1-KB `RunResult`s
    /// is a gigabyte; below this, retained per-shard results are cheap
    /// and strictly more informative).
    pub const STREAM_AUTO_SHARDS: u32 = 4096;

    /// Whether this fleet runs through the streaming (fold-and-drop)
    /// path. Explicit `stream` wins; auto streams isolated fleets of
    /// [`FleetSpec::STREAM_AUTO_SHARDS`]+ shards.
    pub fn streaming(&self) -> bool {
        self.stream
            .unwrap_or(self.sync.is_none() && self.shards >= FleetSpec::STREAM_AUTO_SHARDS)
    }

    /// Harvester override for `shard`, if one is declared.
    pub fn override_for(&self, shard: u32) -> Option<&HarvesterSpec> {
        self.overrides
            .iter()
            .find(|o| o.shard == shard)
            .and_then(|o| o.harvester.as_ref())
    }

    /// Sync-cadence override for `shard`, if one is declared.
    pub fn sync_period_for(&self, shard: u32) -> Option<u64> {
        self.overrides
            .iter()
            .find(|o| o.shard == shard)
            .and_then(|o| o.sync_period_us)
    }

    fn validate(&self, what: &str) -> Result<()> {
        if self.shards == 0 {
            return Err(Error::Config(format!("{what}: fleet shards must be >= 1")));
        }
        for w in self.overrides.windows(2) {
            if w[0].shard >= w[1].shard {
                return Err(Error::Config(format!(
                    "{what}: fleet override shard indices must be strictly increasing"
                )));
            }
        }
        for o in &self.overrides {
            o.validate(what, self.shards, self.sync.is_some())?;
        }
        if let Some(sync) = &self.sync {
            sync.validate(what)?;
        }
        if let Some(sched) = self.sched {
            if self.sync.is_none() {
                return Err(Error::Config(format!(
                    "{what}: `sched` ({}) named but the fleet has no sync \
                     block to schedule",
                    sched.name()
                )));
            }
            if sched == FleetSched::Rounds
                && self.overrides.iter().any(|o| o.sync_period_us.is_some())
            {
                return Err(Error::Config(format!(
                    "{what}: the round barrier needs one uniform sync period — \
                     per-shard sync_period_us overrides require the event scheduler"
                )));
            }
        }
        if self.stream == Some(true) && self.sync.is_some() && self.shards > 1 {
            return Err(Error::Config(format!(
                "{what}: stream=true is incompatible with federated sync \
                 (sync rounds need resident engines)"
            )));
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        let mut kvs = vec![
            ("shards", Json::Num(self.shards as f64)),
            ("phase_jitter_us", Json::Num(self.phase_jitter_us as f64)),
            ("seed_stride", Json::Num(self.seed_stride as f64)),
            (
                "overrides",
                Json::Arr(self.overrides.iter().map(|o| o.to_json()).collect()),
            ),
        ];
        // emitted only when present: pre-knob fleet documents keep
        // their JSON shape byte for byte
        if let Some(stream) = self.stream {
            kvs.push(("stream", Json::Bool(stream)));
        }
        if let Some(sync) = &self.sync {
            kvs.push(("sync", sync.to_json()));
        }
        if let Some(sched) = self.sched {
            kvs.push(("sched", Json::Str(sched.name().into())));
        }
        Json::obj(kvs)
    }

    pub fn from_json(j: &Json) -> Result<FleetSpec> {
        let what = "fleet";
        let mut overrides = Vec::new();
        if let Some(v) = j.get("overrides").filter(|v| !v.is_null()) {
            let arr = v.as_arr().ok_or_else(|| {
                Error::Config(format!("{what}: `overrides` must be an array"))
            })?;
            for o in arr {
                overrides.push(ShardOverride::from_json(o)?);
            }
        }
        Ok(FleetSpec {
            shards: req_u32(j, "shards", what)?,
            phase_jitter_us: opt_u64(j, "phase_jitter_us", what)?.unwrap_or(0),
            seed_stride: opt_u64(j, "seed_stride", what)?.unwrap_or(1),
            overrides,
            sync: match j.get("sync") {
                None => None,
                Some(v) if v.is_null() => None,
                Some(v) => Some(SyncSpec::from_json(v)?),
            },
            sched: match j.get("sched") {
                None => None,
                Some(v) if v.is_null() => None,
                Some(v) => {
                    let name = v.as_str().ok_or_else(|| {
                        Error::Config(format!("{what}: `sched` must be a string"))
                    })?;
                    Some(FleetSched::parse(name).ok_or_else(|| {
                        Error::Config(format!(
                            "unknown fleet sched `{name}` (event|rounds)"
                        ))
                    })?)
                }
            },
            stream: match j.get("stream") {
                None => None,
                Some(v) if v.is_null() => None,
                Some(v) => Some(v.as_bool().ok_or_else(|| {
                    Error::Config(format!("{what}: `stream` must be a boolean"))
                })?),
            },
        })
    }
}

// ------------------------------------------------------------ policy spec

/// Policy-layer knobs beyond the scheduler choice. The whole block is
/// optional in spec JSON and omitted when unset, so pre-forecast specs
/// round-trip byte-identically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PolicySpec {
    /// Forecast-aware planning: checkpoint elision, harvest-sized bursts
    /// and sync energy reserves. Off by default; present-but-false runs
    /// bit-identically to an absent block.
    pub forecast: bool,
}

impl PolicySpec {
    fn to_json(&self) -> Json {
        Json::obj(vec![("forecast", Json::Bool(self.forecast))])
    }

    fn from_json(j: &Json) -> Result<PolicySpec> {
        let forecast = match j.get("forecast") {
            None => false,
            Some(v) if v.is_null() => false,
            Some(Json::Bool(b)) => *b,
            Some(_) => {
                return Err(Error::Config(
                    "scenario: `policy.forecast` must be a boolean".into(),
                ))
            }
        };
        Ok(PolicySpec { forecast })
    }
}

// ---------------------------------------------------------- scenario spec

/// A complete, declarative experiment scenario. Everything an engine needs
/// is plain data here; `build_engine` compiles it through the
/// [`crate::sim::engine::EngineBuilder`].
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Scenario label (used in sweep-cell ids and output filenames).
    pub name: String,
    /// Master seed: sensors, selection heuristics and (by derivation)
    /// harvesters are all re-seeded from this.
    pub seed: u64,
    /// Simulated horizon, µs.
    pub horizon_us: u64,
    pub harvester: HarvesterSpec,
    pub capacitor: CapacitorSpec,
    pub sensor: SensorSpec,
    pub cost: CostKind,
    pub learner: LearnerSpec,
    pub goal: Goal,
    pub scheduler: SchedulerKind,
    pub heuristic: Heuristic,
    pub backend: BackendKind,
    /// Accuracy-probe checkpoint period, µs.
    pub eval_period_us: u64,
    /// Probe-set size per checkpoint.
    pub probe_count: usize,
    /// Probe lookback window, µs.
    pub probe_lookback_us: u64,
    /// Max charging step while asleep, µs (stepped-kernel resolution).
    pub charge_step_us: u64,
    /// Charging integrator: the event-driven analytic kernel (default) or
    /// the stepped reference oracle.
    pub charge_kernel: ChargeKernel,
    /// Policy-layer knobs (`None` = all defaults; serialized only when
    /// present, so pre-policy spec JSON is untouched).
    pub policy: Option<PolicySpec>,
    /// Fleet block: deploy this scenario across N shards (`None` = the
    /// plain single device, which equals a 1-shard fleet bit-for-bit).
    pub fleet: Option<FleetSpec>,
}

impl ScenarioSpec {
    /// Sweep-cell identity: scenario, seed, scheduler, heuristic, backend.
    /// Uses the lossless [`SchedulerKind::id`] so distinct cells never
    /// collide (and stays filename-safe; see `validate` on `name`).
    pub fn label(&self) -> String {
        format!(
            "{}-{}-{}-{}-s{}",
            self.name,
            self.scheduler.id(),
            self.heuristic.name(),
            self.backend.name(),
            self.seed
        )
    }

    /// Largest integer (seed, horizon) that survives the JSON round trip
    /// exactly — specs serialize numbers as f64. 2^53 µs is ~285 years of
    /// simulated time, so this bounds nothing real.
    pub const MAX_SEED: u64 = 1 << 53;

    /// Check every part before building; the error names the scenario.
    pub fn validate(&self) -> Result<()> {
        let what = format!("scenario `{}`", self.name);
        if self.name.is_empty() {
            return Err(Error::Config("scenario name must not be empty".into()));
        }
        // names feed sweep-cell ids and output *filenames*: keep them to a
        // safe charset so `sweep --out` can never fail late or escape the
        // output directory
        if !self
            .name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
        {
            return Err(Error::Config(format!(
                "{what}: name may only contain [A-Za-z0-9._-] (it becomes a filename)"
            )));
        }
        if self.seed > Self::MAX_SEED {
            return Err(Error::Config(format!(
                "{what}: seed {} exceeds 2^53 and would not survive the JSON round trip",
                self.seed
            )));
        }
        if let HarvesterSpec::Solar { seed: Some(s), .. }
        | HarvesterSpec::Rf { seed: Some(s), .. }
        | HarvesterSpec::Piezo { seed: Some(s), .. } = &self.harvester
        {
            if *s > Self::MAX_SEED {
                return Err(Error::Config(format!(
                    "{what}: harvester seed {s} exceeds 2^53 and would not survive the JSON round trip"
                )));
            }
        }
        if self.horizon_us == 0 {
            return Err(Error::Config(format!("{what}: horizon_us must be > 0")));
        }
        if self.horizon_us > Self::MAX_SEED {
            return Err(Error::Config(format!(
                "{what}: horizon_us {} exceeds 2^53 (µs) and would not survive the JSON round trip",
                self.horizon_us
            )));
        }
        if self.eval_period_us == 0 || self.charge_step_us == 0 {
            return Err(Error::Config(format!(
                "{what}: eval_period_us and charge_step_us must be > 0"
            )));
        }
        if self.probe_count == 0 {
            return Err(Error::Config(format!("{what}: probe_count must be > 0")));
        }
        if self.goal.window == 0 {
            return Err(Error::Config(format!("{what}: goal window must be > 0")));
        }
        if self.goal.rho_learn < 0.0 || self.goal.rho_infer < 0.0 {
            return Err(Error::Config(format!(
                "{what}: goal rates must be >= 0"
            )));
        }
        // u64::MAX is the lifelong sentinel (serialized as null); every
        // other n_learn travels as an f64 number
        if self.goal.n_learn != u64::MAX && self.goal.n_learn > Self::MAX_SEED {
            return Err(Error::Config(format!(
                "{what}: goal n_learn {} exceeds 2^53 and would not survive the JSON round trip \
                 (use null / u64::MAX for lifelong learning)",
                self.goal.n_learn
            )));
        }
        if let SchedulerKind::Mayfly { expiry_us, .. } = self.scheduler {
            if expiry_us > Self::MAX_SEED {
                return Err(Error::Config(format!(
                    "{what}: mayfly expiry_us {expiry_us} exceeds 2^53 and would not survive \
                     the JSON round trip"
                )));
            }
        }
        self.harvester.validate(&what)?;
        self.capacitor.validate(&what)?;
        self.sensor.validate(&what)?;
        self.scheduler.validate(&what)?;
        if let Some(fleet) = &self.fleet {
            fleet.validate(&what)?;
            // the last shard's derived seed must itself survive the JSON
            // round trip (and not overflow)
            let last = u64::from(fleet.shards - 1);
            let max_seed = last
                .checked_mul(fleet.seed_stride)
                .and_then(|d| self.seed.checked_add(d));
            match max_seed {
                Some(s) if s <= Self::MAX_SEED => {}
                _ => {
                    return Err(Error::Config(format!(
                        "{what}: shard {last}'s derived seed (seed {} + {last} x stride {}) \
                         exceeds 2^53",
                        self.seed, fleet.seed_stride
                    )))
                }
            }
            if last.checked_mul(fleet.phase_jitter_us).is_none() {
                return Err(Error::Config(format!(
                    "{what}: shard {last}'s phase offset overflows ({last} x jitter {})",
                    fleet.phase_jitter_us
                )));
            }
            if let Some(sync) = &fleet.sync {
                if sync.period_us > Self::MAX_SEED {
                    return Err(Error::Config(format!(
                        "{what}: sync period_us {} exceeds 2^53 and would not survive the \
                         JSON round trip",
                        sync.period_us
                    )));
                }
            }
        }
        // A motion profile shorter than the horizon means zero gestures and
        // (for piezo) zero harvest past its last episode — a mostly-dead
        // world that would "succeed" with empty results. A fractional
        // trailing hour is tolerated (the legacy apps rounded down).
        let whole_hours = self.horizon_us / 3_600_000_000;
        let check_motion = |m: &MotionSpec, part: &str| -> Result<()> {
            if m.hours < whole_hours {
                return Err(Error::Config(format!(
                    "{what}: {part} motion covers {} h but the horizon is {} h — \
                     the world is dead past the motion protocol",
                    m.hours, whole_hours
                )));
            }
            Ok(())
        };
        if let HarvesterSpec::Piezo { motion, .. } = &self.harvester {
            check_motion(motion, "piezo")?;
        }
        if let SensorSpec::Accel { motion } = &self.sensor {
            check_motion(motion, "accel")?;
        }
        Ok(())
    }

    /// Simulation parameters for the engine.
    pub fn sim_config(&self) -> SimConfig {
        SimConfig {
            seed: self.seed,
            horizon_us: self.horizon_us,
            eval_period_us: self.eval_period_us,
            probe_count: self.probe_count,
            charge_step_us: self.charge_step_us,
            probe_lookback_us: self.probe_lookback_us,
            charge_kernel: self.charge_kernel,
            forecast: self.policy.is_some_and(|p| p.forecast),
        }
    }

    pub fn build_harvester(&self) -> Box<dyn Harvester> {
        self.harvester.build(self.seed)
    }

    pub fn build_capacitor(&self) -> Capacitor {
        self.capacitor.build()
    }

    pub fn build_sensor(&self) -> Box<dyn Sensor> {
        self.sensor.build(self.seed, self.horizon_us)
    }

    pub fn build_learner(&self) -> Box<dyn Learner> {
        self.learner.build(self.seed)
    }

    /// Number of fleet shards (1 for a fleet-less scenario).
    pub fn shard_count(&self) -> u32 {
        self.fleet.as_ref().map(|f| f.shards).unwrap_or(1)
    }

    /// The fleet's runtime sync plan (`None` when the fleet block has no
    /// `"sync"` — isolated shards).
    pub fn sync_plan(&self) -> Option<SyncPlan> {
        let sync = self.fleet.as_ref()?.sync.as_ref()?;
        Some(SyncPlan {
            period_us: sync.period_us,
            strategy: sync.strategy,
            horizon_us: self.horizon_us,
        })
    }

    /// The per-action cost model this scenario pays, with the sync
    /// block's radio overrides (if any) applied to the `tx`/`rx` entries.
    pub fn build_costs(&self) -> CostModel {
        let mut costs = self.cost.build();
        if let Some(r) = self
            .fleet
            .as_ref()
            .and_then(|f| f.sync.as_ref())
            .and_then(|s| s.radio)
        {
            costs.set_cost(Action::Tx, ActionCost::new(r.tx_uj, r.tx_us, 1));
            costs.set_cost(Action::Rx, ActionCost::new(r.rx_uj, r.rx_us, 1));
        }
        costs
    }

    /// Shard `index`'s identity under the seed/offset derivation rule.
    pub fn shard(&self, index: u32) -> Result<Shard> {
        if index >= self.shard_count() {
            return Err(Error::Config(format!(
                "scenario `{}`: shard {index} out of range (fleet has {} shard(s))",
                self.name,
                self.shard_count()
            )));
        }
        let (stride, jitter) = self
            .fleet
            .as_ref()
            .map(|f| (f.seed_stride, f.phase_jitter_us))
            .unwrap_or((1, 0));
        Ok(Shard {
            index,
            seed: self.seed + u64::from(index) * stride,
            phase_us: u64::from(index) * jitter,
        })
    }

    /// Point both the RF harvester and the RSSI sensor at a
    /// (start_us, distance_m) schedule — the Fig. 15(b) protocol. Errors
    /// if the scenario has neither an RF harvester nor an RSSI sensor.
    pub fn set_rf_distances(&mut self, sched: Vec<(u64, f64)>) -> Result<()> {
        let mut applied = false;
        if let HarvesterSpec::Rf { schedule, .. } = &mut self.harvester {
            *schedule = sched.clone();
            applied = true;
        }
        if let SensorSpec::Rssi { distances } = &mut self.sensor {
            *distances = Some(sched);
            applied = true;
        }
        if applied {
            Ok(())
        } else {
            Err(Error::Config(format!(
                "scenario `{}` has no RF harvester or RSSI sensor to apply distances to",
                self.name
            )))
        }
    }

    /// Validate and compile into a ready-to-run engine (the 1-shard
    /// special case: exactly shard 0 of this scenario's fleet).
    pub fn build_engine(&self) -> Result<Engine> {
        self.build_shard_engine(0)
    }

    /// Validate and compile shard `index`'s engine. Shard 0 of a
    /// fleet-less scenario is the plain [`ScenarioSpec::build_engine`]
    /// construction bit-for-bit: the base seed, no phase offset.
    pub fn build_shard_engine(&self, index: u32) -> Result<Engine> {
        self.validate()?;
        let sh = self.shard(index)?;
        let hs = self
            .fleet
            .as_ref()
            .and_then(|f| f.override_for(index))
            .unwrap_or(&self.harvester);
        let mut harvester = hs.build(sh.seed);
        if sh.phase_us > 0 {
            harvester = Box::new(PhaseShift::new(harvester, sh.phase_us));
        }
        let mut cfg = self.sim_config();
        cfg.seed = sh.seed;
        Engine::builder()
            .sim(cfg)
            .harvester(harvester)
            .capacitor(self.build_capacitor())
            .sensor(self.sensor.build(sh.seed, self.horizon_us))
            .learner(self.learner.build(sh.seed))
            .selector(self.heuristic.build(sh.seed ^ 0x5E1))
            .scheduler(self.scheduler.build(self.goal))
            .backend(self.backend.build()?)
            .costs(self.build_costs())
            .build()
    }

    /// Run the whole fleet (`threads` = 0 uses the available parallelism)
    /// and fan the per-shard results into a [`FleetResult`].
    pub fn run_fleet(&self, threads: usize) -> Result<FleetResult> {
        self.validate()?;
        Fleet::new(self)?.run(threads)
    }

    /// Run the whole fleet through the streaming (fold-and-drop) path:
    /// per-shard results are folded into rollups + sketches and dropped,
    /// so memory stays bounded at any shard count. The rollup is
    /// bit-identical to [`ScenarioSpec::run_fleet`]'s over the same
    /// shards. Errors on fleets with an active federated sync plan.
    pub fn run_fleet_streaming(&self, threads: usize) -> Result<StreamResult> {
        self.validate()?;
        crate::sim::run_streaming(self, threads)
    }

    pub fn to_json(&self) -> Json {
        let n_learn = if self.goal.n_learn == u64::MAX {
            Json::Null // lifelong learning phase
        } else {
            Json::Num(self.goal.n_learn as f64)
        };
        let mut kvs = vec![
            ("name", Json::Str(self.name.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("horizon_us", Json::Num(self.horizon_us as f64)),
            ("harvester", self.harvester.to_json()),
            ("capacitor", self.capacitor.to_json()),
            ("sensor", self.sensor.to_json()),
            ("cost_model", Json::Str(self.cost.name().into())),
            ("learner", self.learner.to_json()),
            (
                "goal",
                Json::obj(vec![
                    ("rho_learn", Json::Num(self.goal.rho_learn)),
                    ("n_learn", n_learn),
                    ("rho_infer", Json::Num(self.goal.rho_infer)),
                    ("window", Json::Num(self.goal.window as f64)),
                ]),
            ),
            ("scheduler", self.scheduler.to_json()),
            ("heuristic", Json::Str(self.heuristic.name().into())),
        ];
        // optional policy block: omitted when unset so pre-policy spec
        // documents stay byte-identical
        if let Some(p) = &self.policy {
            kvs.push(("policy", p.to_json()));
        }
        kvs.extend([
            ("backend", Json::Str(self.backend.name().into())),
            ("eval_period_us", Json::Num(self.eval_period_us as f64)),
            ("probe_count", Json::Num(self.probe_count as f64)),
            ("probe_lookback_us", Json::Num(self.probe_lookback_us as f64)),
            ("charge_step_us", Json::Num(self.charge_step_us as f64)),
            ("charge_kernel", Json::Str(self.charge_kernel.name().into())),
            (
                "fleet",
                match &self.fleet {
                    Some(f) => f.to_json(),
                    None => Json::Null,
                },
            ),
        ]);
        Json::obj(kvs)
    }

    pub fn from_json(j: &Json) -> Result<ScenarioSpec> {
        let what = "scenario";
        let goal_j = req(j, "goal", what)?;
        let goal = Goal {
            rho_learn: req_f64(goal_j, "rho_learn", "goal")?,
            n_learn: opt_u64(goal_j, "n_learn", "goal")?.unwrap_or(u64::MAX),
            rho_infer: req_f64(goal_j, "rho_infer", "goal")?,
            window: req_u32(goal_j, "window", "goal")?,
        };
        let cost_name = req_str(j, "cost_model", what)?;
        let cost = CostKind::parse(cost_name).ok_or_else(|| {
            Error::Config(format!(
                "unknown cost model `{cost_name}` (knn|kmeans|knn_rssi)"
            ))
        })?;
        let heuristic_name = req_str(j, "heuristic", what)?;
        let heuristic = Heuristic::parse(heuristic_name).ok_or_else(|| {
            Error::Config(format!(
                "unknown heuristic `{heuristic_name}` (round_robin|k_last_lists|randomized|none)"
            ))
        })?;
        let backend_name = req_str(j, "backend", what)?;
        let backend = BackendKind::parse(backend_name).ok_or_else(|| {
            Error::Config(format!("unknown backend `{backend_name}` (native|pjrt)"))
        })?;
        // optional (older specs predate the event kernel): default kernel
        let charge_kernel = match j.get("charge_kernel") {
            None => ChargeKernel::default(),
            Some(v) if v.is_null() => ChargeKernel::default(),
            Some(v) => {
                let name = v.as_str().ok_or_else(|| {
                    Error::Config(format!("{what}: `charge_kernel` must be a string"))
                })?;
                ChargeKernel::parse(name).ok_or_else(|| {
                    Error::Config(format!(
                        "unknown charge kernel `{name}` (event|stepped)"
                    ))
                })?
            }
        };
        let spec = ScenarioSpec {
            name: req_str(j, "name", what)?.to_string(),
            seed: req_u64(j, "seed", what)?,
            horizon_us: req_u64(j, "horizon_us", what)?,
            harvester: HarvesterSpec::from_json(req(j, "harvester", what)?)?,
            capacitor: CapacitorSpec::from_json(req(j, "capacitor", what)?)?,
            sensor: SensorSpec::from_json(req(j, "sensor", what)?)?,
            cost,
            learner: LearnerSpec::from_json(req(j, "learner", what)?)?,
            goal,
            scheduler: SchedulerKind::from_json(req(j, "scheduler", what)?)?,
            heuristic,
            backend,
            eval_period_us: req_u64(j, "eval_period_us", what)?,
            probe_count: req_u32(j, "probe_count", what)? as usize,
            probe_lookback_us: req_u64(j, "probe_lookback_us", what)?,
            charge_step_us: req_u64(j, "charge_step_us", what)?,
            charge_kernel,
            // optional (older specs predate the policy block): defaults
            policy: match j.get("policy") {
                None => None,
                Some(v) if v.is_null() => None,
                Some(v) => Some(PolicySpec::from_json(v)?),
            },
            fleet: match j.get("fleet") {
                None => None,
                Some(v) if v.is_null() => None,
                Some(v) => Some(FleetSpec::from_json(v)?),
            },
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from JSON text.
    pub fn parse(text: &str) -> Result<ScenarioSpec> {
        Self::from_json(&Json::parse(text)?)
    }
}

/// A scenario is a shard factory: it owns the seed/phase derivation rule
/// and the per-shard overrides, so [`Fleet`] (and the sweep runner's
/// shard-level work items) can build any shard's engine on any worker
/// thread.
impl ShardFactory for ScenarioSpec {
    fn shard_count(&self) -> u32 {
        ScenarioSpec::shard_count(self)
    }

    fn shard(&self, index: u32) -> Result<Shard> {
        ScenarioSpec::shard(self, index)
    }

    fn build_shard_engine(&self, index: u32) -> Result<Engine> {
        ScenarioSpec::build_shard_engine(self, index)
    }

    fn sync_plan(&self) -> Option<SyncPlan> {
        ScenarioSpec::sync_plan(self)
    }

    fn shard_sync_period_us(&self, index: u32) -> u64 {
        self.fleet
            .as_ref()
            .and_then(|f| f.sync_period_for(index))
            .or_else(|| ScenarioSpec::sync_plan(self).map(|p| p.period_us))
            .unwrap_or(0)
    }

    fn fleet_sched(&self) -> FleetSched {
        self.fleet
            .as_ref()
            .and_then(|f| f.sched)
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::preset;

    const H: u64 = 3_600_000_000;

    #[test]
    fn scheduler_parse_matches_cli_shorthand() {
        assert_eq!(SchedulerKind::parse("planner").unwrap(), SchedulerKind::Planner);
        assert_eq!(
            SchedulerKind::parse("alpaca:90").unwrap(),
            SchedulerKind::Alpaca { learn_pct: 0.9 }
        );
        assert_eq!(
            SchedulerKind::parse("mayfly:50:120").unwrap(),
            SchedulerKind::Mayfly {
                learn_pct: 0.5,
                expiry_us: 120_000_000
            }
        );
        assert!(SchedulerKind::parse("alpaca").is_err());
        assert!(SchedulerKind::parse("nope:1").is_err());
    }

    #[test]
    fn labels_distinguish_duty_cycles() {
        assert_eq!(SchedulerKind::Alpaca { learn_pct: 0.9 }.label(), "alpaca_90l");
        assert_eq!(
            SchedulerKind::Mayfly {
                learn_pct: 0.1,
                expiry_us: 1
            }
            .label(),
            "mayfly_10l"
        );
    }

    #[test]
    fn ids_are_lossless_where_labels_round() {
        // label() collapses these; id() must not (sweep-cell identity)
        let a = SchedulerKind::Mayfly { learn_pct: 0.5, expiry_us: 60_000_000 };
        let b = SchedulerKind::Mayfly { learn_pct: 0.5, expiry_us: 120_000_000 };
        assert_eq!(a.label(), b.label());
        assert_ne!(a.id(), b.id());
        let c = SchedulerKind::Alpaca { learn_pct: 0.12 };
        let d = SchedulerKind::Alpaca { learn_pct: 0.1204 };
        assert_eq!(c.label(), d.label()); // both round to "alpaca_12l"
        assert_eq!(c.id(), "alpaca_12l");
        assert_eq!(d.id(), "alpaca_12.04l");
        // label rounds (not truncates): 29% is not "alpaca_28l"
        assert_eq!(SchedulerKind::Alpaca { learn_pct: 0.29 }.label(), "alpaca_29l");
        assert_ne!(c.id(), d.id());
        // ids stay filename-safe
        for id in [a.id(), b.id(), c.id(), d.id()] {
            assert!(
                id.chars().all(|ch| ch.is_ascii_alphanumeric() || "._-".contains(ch)),
                "{id}"
            );
        }
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut s = preset("vibration", 1, 2 * H).unwrap();
        s.capacitor.v_off = s.capacitor.v_on + 1.0;
        assert!(s.validate().is_err());

        let mut s = preset("presence", 1, 2 * H).unwrap();
        s.scheduler = SchedulerKind::Alpaca { learn_pct: 1.7 };
        assert!(s.validate().is_err());

        let mut s = preset("air_quality", 1, 2 * H).unwrap();
        if let HarvesterSpec::Solar {
            sunrise_s, sunset_s, ..
        } = &mut s.harvester
        {
            std::mem::swap(sunrise_s, sunset_s);
        }
        assert!(s.validate().is_err());

        // out-of-day solar times would make the charge kernels disagree
        let mut s = preset("air_quality", 1, 2 * H).unwrap();
        if let HarvesterSpec::Solar { sunset_s, .. } = &mut s.harvester {
            *sunset_s = 90_000.0; // past 24 h
        }
        assert!(s.validate().is_err());

        let mut s = preset("vibration", 1, 2 * H).unwrap();
        s.horizon_us = 0;
        assert!(s.validate().is_err());

        // names become sweep output filenames: path characters rejected
        let mut s = preset("vibration", 1, 2 * H).unwrap();
        s.name = "foo/../bar".into();
        assert!(s.validate().is_err());

        // seeds beyond f64-exact range would corrupt on JSON round trip
        let mut s = preset("vibration", 1, 2 * H).unwrap();
        s.seed = ScenarioSpec::MAX_SEED + 1;
        assert!(s.validate().is_err());
        let mut s = preset("presence", 1, 2 * H).unwrap();
        if let HarvesterSpec::Rf { seed, .. } = &mut s.harvester {
            *seed = Some(u64::MAX - 1);
        }
        assert!(s.validate().is_err());

        // a motion protocol shorter than the horizon is a dead world
        let mut s = preset("vibration", 1, 10 * H).unwrap();
        if let SensorSpec::Accel { motion } = &mut s.sensor {
            motion.hours = 1;
        }
        assert!(s.validate().is_err());

        // an empty trace is a permanently dark world
        let mut s = preset("vibration", 1, 2 * H).unwrap();
        s.harvester = HarvesterSpec::Trace {
            points: vec![],
            path: None,
        };
        assert!(s.validate().is_err());
    }

    #[test]
    fn charge_kernel_round_trips_and_defaults() {
        let mut s = preset("vibration", 1, 2 * H).unwrap();
        assert_eq!(s.charge_kernel, ChargeKernel::default());
        s.charge_kernel = ChargeKernel::Stepped;
        let back = ScenarioSpec::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.charge_kernel, ChargeKernel::Stepped);
        // spec files predating the event kernel (no field): default kernel
        let mut j = preset("vibration", 1, 2 * H).unwrap().to_json();
        if let Json::Obj(kvs) = &mut j {
            kvs.retain(|(k, _)| k != "charge_kernel");
        }
        let back = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(back.charge_kernel, ChargeKernel::default());
        // unknown kernel names are rejected
        if let Json::Obj(kvs) = &mut j {
            kvs.push(("charge_kernel".into(), Json::Str("warp".into())));
        }
        assert!(ScenarioSpec::from_json(&j).is_err());
    }

    #[test]
    fn policy_block_round_trips_and_defaults() {
        // absent by default: the document carries no "policy" key at all,
        // so pre-forecast spec JSON (and its golden pins) are untouched
        let s = preset("vibration", 1, 2 * H).unwrap();
        assert_eq!(s.policy, None);
        let doc = s.to_json().to_string();
        assert!(!doc.contains("\"policy\""), "{doc}");
        assert!(
            !s.sim_config().forecast,
            "absent policy block must not enable the forecast"
        );
        // present-but-false round-trips and still compiles to forecast off
        let mut s = preset("vibration", 1, 2 * H).unwrap();
        s.policy = Some(PolicySpec { forecast: false });
        let back = ScenarioSpec::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.policy, Some(PolicySpec { forecast: false }));
        assert!(!back.sim_config().forecast);
        // enabled: survives the round trip and reaches the engine config
        s.policy = Some(PolicySpec { forecast: true });
        let doc = s.to_json().to_string();
        assert!(doc.contains("\"policy\":{\"forecast\":true}"), "{doc}");
        let back = ScenarioSpec::parse(&doc).unwrap();
        assert!(back.sim_config().forecast);
        assert!(back.build_engine().unwrap().world.forecast_enabled());
        // an empty or null block means defaults; a non-bool is rejected
        let mut j = preset("vibration", 1, 2 * H).unwrap().to_json();
        if let Json::Obj(kvs) = &mut j {
            kvs.push(("policy".into(), Json::obj(vec![])));
        }
        assert!(!ScenarioSpec::from_json(&j).unwrap().policy.unwrap().forecast);
        if let Json::Obj(kvs) = &mut j {
            kvs.retain(|(k, _)| k != "policy");
            kvs.push((
                "policy".into(),
                Json::obj(vec![("forecast", Json::Num(1.0))]),
            ));
        }
        assert!(ScenarioSpec::from_json(&j).is_err());
    }

    #[test]
    fn lifelong_goal_survives_json() {
        let s = preset("air_quality", 3, 2 * H).unwrap();
        assert_eq!(s.goal.n_learn, u64::MAX);
        let back = ScenarioSpec::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.goal.n_learn, u64::MAX);
    }

    #[test]
    fn rf_distances_patch_both_sides() {
        let mut s = preset("presence", 3, 9 * H).unwrap();
        s.set_rf_distances(vec![(0, 3.0), (3 * H, 5.0), (6 * H, 7.0)])
            .unwrap();
        let h = s.build_harvester();
        let avg = |t0: u64| -> f64 {
            (0..60).map(|i| h.power_w(t0 + i * 1_000_000)).sum::<f64>() / 60.0
        };
        // power at 7 m (hour 7) far below power at 3 m (hour 1)
        assert!(avg(H) > 3.0 * avg(7 * H));
        // sensor side took the schedule too
        match &s.sensor {
            SensorSpec::Rssi { distances: Some(d) } => assert_eq!(d.len(), 3),
            other => panic!("unexpected sensor {other:?}"),
        }
        // and a vibration scenario refuses the patch
        let mut v = preset("vibration", 3, 2 * H).unwrap();
        assert!(v.set_rf_distances(vec![(0, 3.0)]).is_err());
    }

    #[test]
    fn fleet_block_round_trips_and_validates() {
        let mut s = preset("air_quality", 1, 2 * H).unwrap();
        assert_eq!(s.shard_count(), 1);
        s.fleet = Some(FleetSpec {
            shards: 4,
            phase_jitter_us: 250_000,
            seed_stride: 7,
            overrides: vec![ShardOverride::harvester(
                2,
                HarvesterSpec::Constant { power_w: 0.02 },
            )],
            sync: None,
            sched: None,
            stream: None,
        });
        s.validate().unwrap();
        let back = ScenarioSpec::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back, s, "fleet block changed across JSON round trip");
        // derivation rule
        assert_eq!(back.shard_count(), 4);
        let sh = back.shard(3).unwrap();
        assert_eq!(sh.seed, 1 + 3 * 7);
        assert_eq!(sh.phase_us, 750_000);
        assert!(back.shard(4).is_err());
        // bad blocks rejected: zero shards, out-of-range override,
        // non-increasing override indices, overflowing derived seed
        let mut bad = s.clone();
        bad.fleet.as_mut().unwrap().shards = 0;
        assert!(bad.validate().is_err());
        let mut bad = s.clone();
        bad.fleet.as_mut().unwrap().overrides =
            vec![ShardOverride::harvester(9, HarvesterSpec::Constant { power_w: 0.1 })];
        assert!(bad.validate().is_err());
        let mut bad = s.clone();
        bad.fleet.as_mut().unwrap().overrides = vec![
            ShardOverride::harvester(2, HarvesterSpec::Constant { power_w: 0.1 }),
            ShardOverride::harvester(2, HarvesterSpec::Constant { power_w: 0.2 }),
        ];
        assert!(bad.validate().is_err());
        let mut bad = s.clone();
        bad.fleet.as_mut().unwrap().seed_stride = ScenarioSpec::MAX_SEED;
        assert!(bad.validate().is_err());
        // an invalid override harvester is caught too
        let mut bad = s;
        bad.fleet.as_mut().unwrap().overrides =
            vec![ShardOverride::harvester(1, HarvesterSpec::Constant { power_w: -1.0 })];
        assert!(bad.validate().is_err());
    }

    #[test]
    fn sync_block_round_trips_validates_and_overrides_radio_costs() {
        let mut s = preset("air_quality", 1, 2 * H).unwrap();
        s.fleet = Some(FleetSpec {
            shards: 4,
            sync: Some(SyncSpec {
                period_us: 1_800_000_000,
                strategy: SyncStrategy::AllReduce,
                radio: Some(RadioSpec {
                    tx_uj: 500.0,
                    tx_us: 20_000,
                    rx_uj: 300.0,
                    rx_us: 20_000,
                }),
            }),
            ..FleetSpec::default()
        });
        s.validate().unwrap();
        let back = ScenarioSpec::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back, s, "sync block changed across JSON round trip");
        // the runtime plan derives from the block + horizon
        let plan = back.sync_plan().unwrap();
        assert_eq!(plan.period_us, 1_800_000_000);
        assert_eq!(plan.strategy, SyncStrategy::AllReduce);
        assert_eq!(plan.horizon_us, 2 * H);
        assert_eq!(plan.boundaries(), vec![1_800_000_000, 3_600_000_000, 5_400_000_000]);
        // radio overrides reach the cost model
        let costs = back.build_costs();
        assert_eq!(costs.cost(Action::Tx).energy_uj, 500.0);
        assert_eq!(costs.cost(Action::Rx).energy_uj, 300.0);
        assert_eq!(costs.sync_price(3), (500.0 + 3.0 * 300.0, 80_000));
        // a sync-less spec keeps the calibrated table
        let plain = preset("air_quality", 1, 2 * H).unwrap();
        assert!(plain.sync_plan().is_none());
        assert_eq!(plain.build_costs().cost(Action::Tx).energy_uj, 2_200.0);
        // strategy defaults to gossip; bad blocks rejected
        let j = Json::parse(r#"{"period_us": 1000}"#).unwrap();
        assert_eq!(SyncSpec::from_json(&j).unwrap().strategy, SyncStrategy::Gossip);
        let mut bad = s.clone();
        bad.fleet.as_mut().unwrap().sync.as_mut().unwrap().period_us = 0;
        assert!(bad.validate().is_err());
        let mut bad = s.clone();
        bad.fleet.as_mut().unwrap().sync.as_mut().unwrap().radio.as_mut().unwrap().tx_uj =
            -1.0;
        assert!(bad.validate().is_err());
        let mut bad = s;
        bad.fleet.as_mut().unwrap().sync.as_mut().unwrap().period_us =
            ScenarioSpec::MAX_SEED + 1;
        assert!(bad.validate().is_err());
        assert!(SyncSpec::from_json(
            &Json::parse(r#"{"period_us": 1, "strategy": "warp"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn sync_less_fleet_json_keeps_the_pre_sync_shape() {
        // back-compat: a fleet block without sync must serialize without
        // any `"sync"` key at all (golden documents from PR 4 still match)
        let mut s = preset("vibration", 1, 2 * H).unwrap();
        s.fleet = Some(FleetSpec {
            shards: 3,
            ..FleetSpec::default()
        });
        let text = s.to_json().to_string();
        assert!(!text.contains("\"sync\""), "{text}");
        assert_eq!(
            ScenarioSpec::parse(&text).unwrap().fleet.unwrap().sync,
            None
        );
    }

    #[test]
    fn per_shard_sync_and_sched_knobs_round_trip_and_validate() {
        let mut s = preset("air_quality", 1, 2 * H).unwrap();
        s.fleet = Some(FleetSpec {
            shards: 4,
            overrides: vec![
                ShardOverride::sync_period(1, 3_600_000_000),
                ShardOverride {
                    shard: 2,
                    harvester: Some(HarvesterSpec::Constant { power_w: 0.02 }),
                    sync_period_us: Some(900_000_000),
                },
            ],
            sync: Some(SyncSpec {
                period_us: 1_800_000_000,
                strategy: SyncStrategy::Gossip,
                radio: None,
            }),
            sched: Some(FleetSched::Event),
            ..FleetSpec::default()
        });
        s.validate().unwrap();
        let text = s.to_json().to_string();
        assert!(text.contains("\"sched\":\"event\""), "{text}");
        let back = ScenarioSpec::parse(&text).unwrap();
        assert_eq!(back, s, "override/sched knobs changed across JSON round trip");
        // the shard-factory view: overridden cadences, plan-period default
        assert_eq!(back.shard_sync_period_us(0), 1_800_000_000);
        assert_eq!(back.shard_sync_period_us(1), 3_600_000_000);
        assert_eq!(back.shard_sync_period_us(2), 900_000_000);
        assert_eq!(back.fleet_sched(), FleetSched::Event);
        // harvester-only overrides without a sched keep the pre-event
        // wire shape: no new keys at all
        let mut old = s.clone();
        old.fleet.as_mut().unwrap().overrides =
            vec![ShardOverride::harvester(2, HarvesterSpec::Constant { power_w: 0.02 })];
        old.fleet.as_mut().unwrap().sched = None;
        let text = old.to_json().to_string();
        assert!(!text.contains("sync_period_us"), "{text}");
        assert!(!text.contains("\"sched\""), "{text}");
        // bad blocks rejected: an override with no fields, a zero-period
        // cadence, cadences without a sync block, a sched without a sync
        // block, and the round barrier over per-shard cadences
        let mut bad = s.clone();
        bad.fleet.as_mut().unwrap().overrides[0] = ShardOverride {
            shard: 1,
            harvester: None,
            sync_period_us: None,
        };
        assert!(bad.validate().is_err());
        let mut bad = s.clone();
        bad.fleet.as_mut().unwrap().overrides[0].sync_period_us = Some(0);
        assert!(bad.validate().is_err());
        let mut bad = s.clone();
        bad.fleet.as_mut().unwrap().sync = None;
        bad.fleet.as_mut().unwrap().sched = None;
        assert!(bad.validate().is_err());
        let mut bad = s.clone();
        bad.fleet.as_mut().unwrap().overrides.clear();
        bad.fleet.as_mut().unwrap().sync = None;
        assert!(bad.validate().is_err());
        let mut bad = s;
        bad.fleet.as_mut().unwrap().sched = Some(FleetSched::Rounds);
        assert!(bad.validate().is_err());
        // unknown sched names are parse errors
        assert!(FleetSpec::from_json(
            &Json::parse(r#"{"shards": 2, "sched": "warp"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn stream_knob_round_trips_validates_and_auto_resolves() {
        let mut s = preset("vibration", 1, 2 * H).unwrap();
        s.fleet = Some(FleetSpec {
            shards: 3,
            stream: Some(true),
            ..FleetSpec::default()
        });
        s.validate().unwrap();
        let text = s.to_json().to_string();
        assert!(text.contains("\"stream\":true"), "{text}");
        let back = ScenarioSpec::parse(&text).unwrap();
        assert_eq!(back, s, "stream knob changed across JSON round trip");
        // unset knob: absent from JSON (pre-knob documents unchanged)...
        s.fleet.as_mut().unwrap().stream = None;
        assert!(!s.to_json().to_string().contains("\"stream\""));
        // ...and auto-resolves on fleet size and sync
        let small = s.fleet.as_ref().unwrap().clone();
        assert!(!small.streaming(), "small isolated fleet retains");
        let mut big = small.clone();
        big.shards = FleetSpec::STREAM_AUTO_SHARDS;
        assert!(big.streaming(), "big isolated fleet streams");
        big.sync = Some(SyncSpec {
            period_us: 1_800_000_000,
            strategy: SyncStrategy::Gossip,
            radio: None,
        });
        assert!(!big.streaming(), "synced fleet never auto-streams");
        // explicit stream=true wins over the auto rule
        let forced = FleetSpec {
            shards: 2,
            stream: Some(true),
            ..FleetSpec::default()
        };
        assert!(forced.streaming());
        // stream=true + active sync is a config error
        let mut bad = preset("vibration", 1, 2 * H).unwrap();
        bad.fleet = Some(FleetSpec {
            shards: 4,
            sync: big.sync.clone(),
            stream: Some(true),
            ..FleetSpec::default()
        });
        assert!(bad.validate().is_err());
        // non-boolean stream rejected
        assert!(
            FleetSpec::from_json(&Json::parse(r#"{"shards": 2, "stream": 1}"#).unwrap())
                .is_err()
        );
    }

    #[test]
    fn shard_zero_is_the_plain_engine_construction() {
        // fleet-less build_engine == build_shard_engine(0), and adding a
        // fleet block does not perturb shard 0 (base seed, zero phase)
        let mut s = preset("vibration", 5, 2 * H).unwrap();
        let a = s.build_engine().unwrap().run().unwrap();
        s.fleet = Some(FleetSpec {
            shards: 3,
            phase_jitter_us: 1_000_000,
            seed_stride: 11,
            overrides: vec![],
            sync: None,
            sched: None,
            stream: None,
        });
        let b = s.build_shard_engine(0).unwrap().run().unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn shard_overrides_and_phase_change_the_world() {
        let mut s = preset("vibration", 5, 2 * H).unwrap();
        s.fleet = Some(FleetSpec {
            shards: 3,
            phase_jitter_us: 0,
            seed_stride: 0, // identical seeds: only the override differs
            overrides: vec![ShardOverride::harvester(
                1,
                HarvesterSpec::Constant { power_w: 0.0 },
            )],
            sync: None,
            sched: None,
            stream: None,
        });
        let base = s.build_shard_engine(0).unwrap().run().unwrap();
        let dark = s.build_shard_engine(1).unwrap().run().unwrap();
        let twin = s.build_shard_engine(2).unwrap().run().unwrap();
        assert_eq!(dark.sensed, 0, "0 W override still sensed");
        assert!(base.sensed > 0);
        // stride 0 + no override: shard 2 is shard 0's exact twin
        assert_eq!(base.to_json().to_string(), twin.to_json().to_string());
    }

    #[test]
    fn trace_path_specs_load_the_csv() {
        let dir = std::env::temp_dir().join("ilearn_trace_spec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        std::fs::write(&path, "# test\n0,0.0\n1000000,0.01\n").unwrap();
        let mut s = preset("vibration", 1, 2 * H).unwrap();
        s.harvester = HarvesterSpec::Trace {
            points: Trace::from_csv(path.to_str().unwrap()).unwrap().points,
            path: Some(path.to_str().unwrap().to_string()),
        };
        s.validate().unwrap();
        // serializes as the path, re-loads to the same points
        let text = s.to_json().to_string();
        assert!(text.contains("t.csv") && !text.contains("\"points\""));
        let back = ScenarioSpec::parse(&text).unwrap();
        assert_eq!(back, s);
        // `type` is accepted as a synonym for `kind`
        let alt = text.replace("\"kind\":\"trace\"", "\"type\":\"trace\"");
        assert_eq!(ScenarioSpec::parse(&alt).unwrap(), s);
        // a missing file fails at parse time, naming the path
        let gone = text.replace("t.csv", "gone.csv");
        assert!(ScenarioSpec::parse(&gone).unwrap_err().to_string().contains("gone.csv"));
    }

    #[test]
    fn scheduler_from_json_accepts_both_forms() {
        let a = SchedulerKind::from_json(&Json::parse("\"alpaca:50\"").unwrap()).unwrap();
        let b = SchedulerKind::from_json(
            &Json::parse(r#"{"kind":"alpaca","learn_pct":0.5}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(a, b);
    }
}
