//! Grid sweeps over scenario specs, executed across worker threads.
//!
//! A [`SweepSpec`] is a declarative grid: base scenarios (named presets or
//! inline [`ScenarioSpec`] objects) crossed with optional scheduler /
//! heuristic / backend / seed axes, optionally fleet-deployed via a
//! sweep-level `"fleet"` block. [`SweepSpec::expand`] materializes one
//! [`SweepCell`] per grid point; [`SweepRunner`] schedules **shard-level**
//! work items — every cell contributes one item per fleet shard — on the
//! shared claim-counter pool ([`crate::util::pool`]), one engine per
//! worker thread (the compute backends are deliberately not `Send`), and
//! fans shard results back into per-cell [`FleetResult`]s in cell order,
//! so the output is identical for any thread count.

use crate::error::{Error, Result};
use crate::scenario::spec::{BackendKind, FleetSpec, ScenarioSpec, SchedulerKind};
use crate::scenario::{preset, PRESETS};
use crate::selection::Heuristic;
use crate::sim::fleet::{FleetResult, ShardFactory};
use crate::sim::RunResult;
use crate::util::json::Json;
use crate::util::pool;

pub use crate::util::pool::resolve_workers;

/// Run many scenarios concurrently (one engine per worker thread),
/// keeping one `Result` per scenario: a failing cell never discards its
/// siblings' finished work. `threads == 0` uses the available
/// parallelism. Results come back in input order regardless of
/// scheduling (the shared claim-counter pool, [`crate::util::pool`]).
pub fn run_parallel_each(specs: &[ScenarioSpec], threads: usize) -> Vec<Result<RunResult>> {
    pool::run_indexed(specs.len(), threads, |i| {
        specs[i].build_engine().and_then(|e| e.run())
    })
}

/// All-or-nothing variant of [`run_parallel_each`] (the figure harness's
/// contract: any failed run fails the figure).
pub fn run_parallel(specs: &[ScenarioSpec], threads: usize) -> Result<Vec<RunResult>> {
    run_parallel_each(specs, threads).into_iter().collect()
}

/// One grid point of a sweep: a fully resolved scenario plus its id.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// `<scenario>-<scheduler>-<heuristic>-<backend>-s<seed>`.
    pub id: String,
    pub spec: ScenarioSpec,
}

/// A finished cell: the fan-in over its fleet shards (a fleet-less cell
/// is a 1-shard fleet whose [`FleetResult::primary`] is the plain run).
/// Failed cells carry the error text instead of a result, so one bad cell
/// never discards a sweep's completed work.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub id: String,
    pub spec: ScenarioSpec,
    pub result: std::result::Result<FleetResult, String>,
}

impl SweepOutcome {
    /// The per-cell JSON document the CLI writes: spec + result (or the
    /// cell's error). Fleet-less cells keep the pre-fleet document shape
    /// (`"result"`: the single run); fleet cells emit `"fleet"` with the
    /// rollups and every shard's run.
    pub fn to_json(&self) -> Json {
        let payload = match &self.result {
            Ok(f) if self.spec.fleet.is_none() => ("result", f.primary().to_json()),
            Ok(f) => ("fleet", f.to_json()),
            Err(e) => ("error", Json::Str(e.clone())),
        };
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("spec", self.spec.to_json()),
            payload,
        ])
    }
}

/// A declarative experiment grid.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub name: String,
    /// Base scenarios; every axis below crosses each of them.
    pub scenarios: Vec<ScenarioSpec>,
    /// Seed axis (empty: keep each scenario's own seed).
    pub seeds: Vec<u64>,
    /// Scheduler axis (empty: keep each scenario's own scheduler).
    pub schedulers: Vec<SchedulerKind>,
    /// Heuristic axis (empty: keep each scenario's own heuristic).
    pub heuristics: Vec<Heuristic>,
    /// Backend axis (empty: keep each scenario's own backend).
    pub backends: Vec<BackendKind>,
    /// Sweep-level fleet block, applied to every scenario that does not
    /// declare its own (`None`: keep each scenario's own fleet, if any).
    pub fleet: Option<FleetSpec>,
}

impl SweepSpec {
    /// Parse a sweep grid from JSON text. Format:
    ///
    /// ```json
    /// {
    ///   "name": "paper-matrix",
    ///   "hours": 4,
    ///   "scenarios": ["vibration", "presence"],
    ///   "seeds": [1, 2],
    ///   "schedulers": ["planner", "alpaca:50"],
    ///   "heuristics": ["round_robin"],
    ///   "backends": ["native"]
    /// }
    /// ```
    ///
    /// `scenarios` entries are preset names (instantiated at `hours`
    /// simulated hours, default 4) or inline scenario objects; the other
    /// axes are optional and default to each scenario's own setting.
    pub fn parse(text: &str) -> Result<SweepSpec> {
        let j = Json::parse(text)?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<SweepSpec> {
        let what = "sweep";
        // axes are optional, so a typo'd key ("scheduler" for
        // "schedulers") would silently drop a whole axis — reject unknown
        // keys instead of running a different experiment
        const KNOWN: [&str; 8] = [
            "name",
            "hours",
            "scenarios",
            "seeds",
            "schedulers",
            "heuristics",
            "backends",
            "fleet",
        ];
        let Json::Obj(kvs) = j else {
            return Err(Error::Config(format!("{what}: expected a JSON object")));
        };
        for (k, _) in kvs {
            if !KNOWN.contains(&k.as_str()) {
                return Err(Error::Config(format!(
                    "{what}: unknown field `{k}` (known: {})",
                    KNOWN.join(", ")
                )));
            }
        }
        let name = match j.get("name") {
            Some(v) => v
                .as_str()
                .ok_or_else(|| Error::Config("sweep: `name` must be a string".into()))?
                .to_string(),
            None => "sweep".to_string(),
        };
        let hours = match j.get("hours") {
            Some(v) => v
                .as_u64()
                .ok_or_else(|| Error::Config("sweep: `hours` must be an integer".into()))?,
            None => 4,
        };
        if hours == 0 {
            return Err(Error::Config("sweep: `hours` must be > 0".into()));
        }
        let horizon_us = hours
            .checked_mul(3_600_000_000)
            .ok_or_else(|| Error::Config(format!("sweep: `hours` {hours} overflows the horizon")))?;

        let scen_j = j
            .get("scenarios")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Config(format!("{what}: `scenarios` array is required")))?;
        if scen_j.is_empty() {
            return Err(Error::Config(format!(
                "{what}: `scenarios` must not be empty (presets: {})",
                PRESETS.join(", ")
            )));
        }
        let mut scenarios = Vec::with_capacity(scen_j.len());
        for s in scen_j {
            match s {
                // seed 42 matches `ilearn run <preset>`'s default, so a
                // grid without a seeds axis reproduces the run command
                Json::Str(name) => scenarios.push(preset(name, 42, horizon_us)?),
                Json::Obj(_) => scenarios.push(ScenarioSpec::from_json(s)?),
                other => {
                    return Err(Error::Config(format!(
                        "{what}: scenario entries must be preset names or objects, got {other:?}"
                    )))
                }
            }
        }

        let mut seeds = Vec::new();
        if let Some(v) = j.get("seeds") {
            let arr = v
                .as_arr()
                .ok_or_else(|| Error::Config(format!("{what}: `seeds` must be an array")))?;
            for s in arr {
                seeds.push(s.as_u64().ok_or_else(|| {
                    Error::Config(format!("{what}: seeds must be non-negative integers"))
                })?);
            }
        }

        let mut schedulers = Vec::new();
        if let Some(v) = j.get("schedulers") {
            let arr = v
                .as_arr()
                .ok_or_else(|| Error::Config(format!("{what}: `schedulers` must be an array")))?;
            for s in arr {
                schedulers.push(SchedulerKind::from_json(s)?);
            }
        }

        let mut heuristics = Vec::new();
        if let Some(v) = j.get("heuristics") {
            let arr = v
                .as_arr()
                .ok_or_else(|| Error::Config(format!("{what}: `heuristics` must be an array")))?;
            for s in arr {
                let name = s.as_str().ok_or_else(|| {
                    Error::Config(format!("{what}: heuristic entries must be strings"))
                })?;
                heuristics.push(Heuristic::parse(name).ok_or_else(|| {
                    Error::Config(format!("{what}: unknown heuristic `{name}`"))
                })?);
            }
        }

        let mut backends = Vec::new();
        if let Some(v) = j.get("backends") {
            let arr = v
                .as_arr()
                .ok_or_else(|| Error::Config(format!("{what}: `backends` must be an array")))?;
            for s in arr {
                let name = s.as_str().ok_or_else(|| {
                    Error::Config(format!("{what}: backend entries must be strings"))
                })?;
                backends.push(BackendKind::parse(name).ok_or_else(|| {
                    Error::Config(format!("{what}: unknown backend `{name}` (native|pjrt)"))
                })?);
            }
        }

        let fleet = match j.get("fleet") {
            None => None,
            Some(v) if v.is_null() => None,
            Some(v) => Some(FleetSpec::from_json(v)?),
        };

        Ok(SweepSpec {
            name,
            scenarios,
            seeds,
            schedulers,
            heuristics,
            backends,
            fleet,
        })
    }

    /// Materialize the grid in deterministic order:
    /// scenario → scheduler → heuristic → backend → seed (outer to inner).
    /// Every cell is validated; duplicate cell ids are an error.
    pub fn expand(&self) -> Result<Vec<SweepCell>> {
        let mut cells = Vec::new();
        for base in &self.scenarios {
            let schedulers = if self.schedulers.is_empty() {
                vec![base.scheduler]
            } else {
                self.schedulers.clone()
            };
            let heuristics = if self.heuristics.is_empty() {
                vec![base.heuristic]
            } else {
                self.heuristics.clone()
            };
            let backends = if self.backends.is_empty() {
                vec![base.backend]
            } else {
                self.backends.clone()
            };
            let seeds = if self.seeds.is_empty() {
                vec![base.seed]
            } else {
                self.seeds.clone()
            };
            for &scheduler in &schedulers {
                for &heuristic in &heuristics {
                    for &backend in &backends {
                        for &seed in &seeds {
                            let mut spec = base.clone();
                            spec.scheduler = scheduler;
                            spec.heuristic = heuristic;
                            spec.backend = backend;
                            spec.seed = seed;
                            if spec.fleet.is_none() {
                                spec.fleet = self.fleet.clone();
                            }
                            spec.validate()?;
                            cells.push(SweepCell {
                                id: spec.label(),
                                spec,
                            });
                        }
                    }
                }
            }
        }
        let mut seen = std::collections::HashSet::with_capacity(cells.len());
        for cell in &cells {
            if !seen.insert(cell.id.as_str()) {
                return Err(Error::Config(format!(
                    "sweep `{}`: duplicate cell id `{}` (same scenario name and axes twice?)",
                    self.name, cell.id
                )));
            }
        }
        Ok(cells)
    }
}

/// Executes expanded sweep cells across worker threads.
#[derive(Debug, Clone, Copy)]
pub struct SweepRunner {
    /// Worker threads; 0 = available parallelism.
    pub threads: usize,
}

impl SweepRunner {
    pub fn new(threads: usize) -> Self {
        SweepRunner { threads }
    }

    /// Expand and run the whole grid; outcomes come back in cell order,
    /// identical for any thread count. Per-cell failures are embedded in
    /// the outcomes, not propagated (only grid expansion can error).
    pub fn run(&self, sweep: &SweepSpec) -> Result<Vec<SweepOutcome>> {
        Ok(self.run_cells(sweep.expand()?))
    }

    /// Run pre-expanded cells. Isolated (sync-less) cells expand into
    /// **shard-level** work items on the shared claim-counter pool, so
    /// one 16-shard cell saturates 16 workers instead of one. A cell
    /// with a fleet `"sync"` block is **round-segmented**: its shards
    /// rendezvous at every sync boundary, so they cannot be split into
    /// independent claim-pool jobs (queued siblings would deadlock the
    /// barrier) — and nesting its round scheduler inside a pool worker
    /// would *multiply* the thread budget, so synced cells run one at a
    /// time on the calling thread after the pooled jobs, each getting the
    /// runner's full budget for its internal shard workers. Results fan
    /// back into per-cell [`FleetResult`]s in cell order (deterministic
    /// for any thread count); a cell fails with its first failing shard's
    /// error.
    pub fn run_cells(&self, cells: Vec<SweepCell>) -> Vec<SweepOutcome> {
        let synced =
            |c: &SweepCell| c.spec.sync_plan().is_some() && c.spec.shard_count() > 1;
        // shard-level jobs for the isolated cells, cell-major
        let jobs: Vec<(usize, u32)> = cells
            .iter()
            .enumerate()
            .filter(|(_, c)| !synced(c))
            .flat_map(|(ci, c)| (0..c.spec.shard_count()).map(move |s| (ci, s)))
            .collect();
        let mut shard_results = pool::run_indexed(jobs.len(), self.threads, |k| {
            let (ci, shard) = jobs[k];
            cells[ci].spec.run_shard(shard)
        })
        .into_iter();
        // synced cells: sequential at this level, parallel inside
        let mut fleet_results = cells
            .iter()
            .filter(|c| synced(c))
            .map(|c| c.spec.run_fleet(self.threads))
            .collect::<Vec<_>>()
            .into_iter();
        // both streams are in cell order, so each cell consumes the next
        // contiguous run of its own stream
        cells
            .into_iter()
            .map(|cell| {
                let result = if synced(&cell) {
                    fleet_results
                        .next()
                        .expect("one result per synced cell")
                        .map_err(|e| e.to_string())
                } else {
                    let n = cell.spec.shard_count();
                    let mut shards = Vec::with_capacity(n as usize);
                    let mut err = None;
                    for s in 0..n {
                        match shard_results.next().expect("one result per shard job") {
                            Ok(r) => shards.push(r),
                            Err(e) if err.is_none() => err = Some(format!("shard {s}: {e}")),
                            Err(_) => {}
                        }
                    }
                    match err {
                        None => Ok(FleetResult::aggregate(shards)),
                        Some(e) => Err(e),
                    }
                };
                SweepOutcome {
                    id: cell.id,
                    spec: cell.spec,
                    result,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GRID: &str = r#"{
        "name": "t",
        "hours": 2,
        "scenarios": ["vibration", "presence"],
        "seeds": [1, 2],
        "schedulers": ["planner", "alpaca:50"],
        "heuristics": ["round_robin"]
    }"#;

    #[test]
    fn grid_expansion_covers_the_matrix_in_order() {
        let sweep = SweepSpec::parse(GRID).unwrap();
        let cells = sweep.expand().unwrap();
        // 2 scenarios x 2 schedulers x 1 heuristic x 1 backend x 2 seeds
        assert_eq!(cells.len(), 8);
        assert_eq!(
            cells[0].id,
            "vibration-intermittent_learning-round_robin-native-s1"
        );
        assert_eq!(
            cells[1].id,
            "vibration-intermittent_learning-round_robin-native-s2"
        );
        assert_eq!(cells[2].id, "vibration-alpaca_50l-round_robin-native-s1");
        assert!(cells[4].id.starts_with("presence-"));
        // ids unique
        for (i, a) in cells.iter().enumerate() {
            assert!(!cells[i + 1..].iter().any(|b| b.id == a.id), "{}", a.id);
        }
    }

    #[test]
    fn empty_axes_keep_scenario_defaults() {
        let sweep =
            SweepSpec::parse(r#"{"hours": 2, "scenarios": ["vibration"]}"#).unwrap();
        let cells = sweep.expand().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].spec.scheduler, SchedulerKind::Planner);
        assert_eq!(cells[0].spec.heuristic, Heuristic::RoundRobin);
    }

    #[test]
    fn seed_axis_reseeds_the_whole_world() {
        let sweep = SweepSpec::parse(
            r#"{"hours": 2, "scenarios": ["presence"], "seeds": [5]}"#,
        )
        .unwrap();
        let cells = sweep.expand().unwrap();
        assert_eq!(cells[0].spec.seed, 5);
        // RF harvester seed stays derived (None in spec), so the cell's
        // scenario seed re-seeds its fading stream at build time
        match &cells[0].spec.harvester {
            crate::scenario::HarvesterSpec::Rf { seed, .. } => assert!(seed.is_none()),
            other => panic!("unexpected harvester {other:?}"),
        }
    }

    #[test]
    fn bad_grids_are_rejected() {
        assert!(SweepSpec::parse(r#"{"scenarios": []}"#).is_err());
        // a typo'd axis key must not silently drop the axis
        assert!(
            SweepSpec::parse(r#"{"scenarios": ["vibration"], "scheduler": ["planner"]}"#)
                .is_err()
        );
        assert!(SweepSpec::parse(r#"{"scenarios": ["nope"]}"#).is_err());
        assert!(SweepSpec::parse(r#"{"scenarios": ["vibration"], "hours": 0}"#).is_err());
        assert!(
            SweepSpec::parse(r#"{"scenarios": ["vibration"], "heuristics": ["zzz"]}"#)
                .is_err()
        );
        // duplicate scenario entry -> duplicate cell ids
        let dup = SweepSpec::parse(r#"{"scenarios": ["vibration", "vibration"]}"#).unwrap();
        assert!(dup.expand().is_err());
    }

    #[test]
    fn run_parallel_handles_empty_input() {
        assert!(run_parallel(&[], 4).unwrap().is_empty());
    }

    #[test]
    fn sweep_level_fleet_deploys_every_cell() {
        let sweep = SweepSpec::parse(
            r#"{"hours": 2, "scenarios": ["vibration", "presence"], "seeds": [1, 2],
                "fleet": {"shards": 3, "phase_jitter_us": 60000000}}"#,
        )
        .unwrap();
        let cells = sweep.expand().unwrap();
        assert_eq!(cells.len(), 4);
        for c in &cells {
            assert_eq!(c.spec.shard_count(), 3);
            let sh = c.spec.shard(2).unwrap();
            assert_eq!(sh.seed, c.spec.seed + 2); // default stride 1
            assert_eq!(sh.phase_us, 120_000_000);
        }
        // a scenario's own fleet block wins over the sweep-level one
        let mut own = crate::scenario::preset("vibration", 9, 7_200_000_000).unwrap();
        own.fleet = Some(FleetSpec {
            shards: 5,
            ..FleetSpec::default()
        });
        let sweep = SweepSpec {
            name: "t".into(),
            scenarios: vec![own],
            seeds: vec![],
            schedulers: vec![],
            heuristics: vec![],
            backends: vec![],
            fleet: Some(FleetSpec {
                shards: 2,
                ..FleetSpec::default()
            }),
        };
        assert_eq!(sweep.expand().unwrap()[0].spec.shard_count(), 5);
    }

    #[test]
    fn fleet_cells_fan_in_on_the_shard_pool() {
        // one 2-shard cell next to a plain cell: the runner schedules 3
        // shard jobs and fans them back into 2 outcomes in cell order
        let sweep = SweepSpec::parse(
            r#"{"hours": 1, "scenarios": ["vibration"], "seeds": [1, 2]}"#,
        )
        .unwrap();
        let mut cells = sweep.expand().unwrap();
        cells[0].spec.fleet = Some(FleetSpec {
            shards: 2,
            seed_stride: 100,
            ..FleetSpec::default()
        });
        let outcomes = SweepRunner::new(2).run_cells(cells.clone());
        assert_eq!(outcomes.len(), 2);
        let fleet = outcomes[0].result.as_ref().unwrap();
        assert_eq!(fleet.shards.len(), 2);
        assert_eq!(fleet.rollup.shards, 2);
        assert_eq!(outcomes[1].result.as_ref().unwrap().shards.len(), 1);
        // the fleet cell's document carries rollups; the plain cell keeps
        // the pre-fleet shape
        assert!(outcomes[0].to_json().to_string().contains("\"fleet\""));
        assert!(outcomes[1].to_json().to_string().contains("\"result\""));
        // shard 0 of the fleet cell equals the same spec run solo
        let solo = cells[0].spec.build_shard_engine(0).unwrap().run().unwrap();
        assert_eq!(
            fleet.primary().to_json().to_string(),
            solo.to_json().to_string()
        );
    }
}
