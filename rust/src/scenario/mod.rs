//! Scenario-spec API: declarative experiment construction and batched
//! sweeps.
//!
//! The paper's contribution is an experiment *matrix* — (app × scheduler ×
//! heuristic × backend) swept across seeds in §7 — and this module makes
//! that matrix a first-class, data-driven object:
//!
//! * [`ScenarioSpec`] ([`spec`]) — one device world as plain serializable
//!   data: harvester, capacitor, sensor world, cost model, learner, goal,
//!   scheduler, selection heuristic, backend, horizon and seed. Specs
//!   validate before they build, round-trip through JSON (`util::json`),
//!   and compile into an engine via [`crate::sim::engine::EngineBuilder`].
//! * [`preset`] — the three paper applications (§6.1–§6.3) as named spec
//!   factories; [`crate::apps`] is a thin veneer over these.
//! * [`SweepSpec`] / [`SweepRunner`] ([`sweep`]) — grid expansion of
//!   (scenarios × schedulers × heuristics × backends × seeds) and threaded
//!   execution, one engine per worker thread (the compute backends are
//!   deliberately not `Send`), emitting one JSON document per cell in
//!   deterministic cell order.
//! * [`FleetSpec`] — the `"fleet"` block: deploy one scenario across N
//!   shards (phase-jittered harvesters, strided seeds, optional per-shard
//!   harvester and sync-cadence overrides). The sweep runner schedules
//!   shard-level work items and fans each cell's shards into a
//!   [`crate::sim::fleet::FleetResult`].

pub mod spec;
pub mod sweep;

pub use spec::{
    BackendKind, CapacitorSpec, CostKind, FleetSpec, HarvesterSpec, LearnerSpec, MotionSpec,
    PolicySpec, RadioSpec, ScenarioSpec, SchedulerKind, SensorSpec, ShardOverride, SyncSpec,
};
pub use sweep::{SweepCell, SweepOutcome, SweepRunner, SweepSpec};

use crate::energy::Capacitor;
use crate::error::{Error, Result};
use crate::planner::Goal;
use crate::selection::Heuristic;
use crate::sim::ChargeKernel;

/// Names accepted by [`preset`].
pub const PRESETS: [&str; 3] = ["air_quality", "presence", "vibration"];

/// Build a named paper-app preset. The returned spec reproduces the
/// corresponding `apps::AppConfig` world bit-for-bit at the same seed.
pub fn preset(name: &str, seed: u64, horizon_us: u64) -> Result<ScenarioSpec> {
    match name {
        "air_quality" => Ok(air_quality(seed, horizon_us)),
        "presence" => Ok(presence(seed, horizon_us)),
        "vibration" => Ok(vibration(seed, horizon_us)),
        other => Err(Error::Config(format!(
            "unknown scenario preset `{other}` (known: {})",
            PRESETS.join(", ")
        ))),
    }
}

/// Default checkpoint cadence for a horizon (~24 probes per run, at least
/// one per simulated minute-hour).
fn eval_period_us(horizon_us: u64) -> u64 {
    (horizon_us / 24).max(60_000_000)
}

/// §6.1: solar-powered UV/eCO2/TVOC anomaly learner (k-NN).
pub fn air_quality(seed: u64, horizon_us: u64) -> ScenarioSpec {
    ScenarioSpec {
        name: "air_quality".into(),
        seed,
        horizon_us,
        harvester: HarvesterSpec::Solar {
            peak_w: 0.045,
            sunrise_s: 6.0 * 3600.0,
            sunset_s: 19.0 * 3600.0,
            cloud_prob: 0.08,
            seed: None, // derived: scenario seed ^ 0xA0
        },
        capacitor: CapacitorSpec::from_capacitor(&Capacitor::air_quality()),
        sensor: SensorSpec::AirQuality,
        cost: CostKind::Knn,
        learner: LearnerSpec::Knn,
        // slow world: modest learning rate; the environment drifts
        // (diurnal + seasonal), so learning never ends (lifelong phase)
        goal: Goal {
            rho_learn: 0.4,
            n_learn: u64::MAX,
            rho_infer: 0.8,
            window: 12,
        },
        scheduler: SchedulerKind::Planner,
        heuristic: Heuristic::RoundRobin,
        backend: BackendKind::Native,
        eval_period_us: eval_period_us(horizon_us),
        probe_count: 30,
        // slow diurnal world: anomalies are hours apart
        probe_lookback_us: 6 * 3_600_000_000,
        charge_step_us: 60_000_000,
        charge_kernel: ChargeKernel::default(),
        policy: None,
        fleet: None,
    }
}

/// §6.2: RF-powered RSSI human-presence learner (k-NN over RSSI).
pub fn presence(seed: u64, horizon_us: u64) -> ScenarioSpec {
    ScenarioSpec {
        name: "presence".into(),
        seed,
        horizon_us,
        harvester: HarvesterSpec::Rf {
            p_ref_w: 0.010,
            d_ref_m: 3.0,
            schedule: vec![(0, 3.0)],
            seed: None, // derived: scenario seed ^ 0xB0
        },
        capacitor: CapacitorSpec::from_capacitor(&Capacitor::presence()),
        sensor: SensorSpec::Rssi { distances: None },
        cost: CostKind::KnnRssi,
        learner: LearnerSpec::Knn,
        // fast RF world: the device is mobile (area moves), so it keeps
        // learning forever to re-adapt
        goal: Goal {
            rho_learn: 0.7,
            n_learn: u64::MAX,
            rho_infer: 1.2,
            window: 10,
        },
        scheduler: SchedulerKind::Planner,
        heuristic: Heuristic::RoundRobin,
        backend: BackendKind::Native,
        eval_period_us: eval_period_us(horizon_us),
        probe_count: 30,
        probe_lookback_us: 2 * 3_600_000_000,
        charge_step_us: 60_000_000,
        charge_kernel: ChargeKernel::default(),
        policy: None,
        fleet: None,
    }
}

/// §6.3: piezo-powered vibration learner (NN-k-means cluster-then-label).
pub fn vibration(seed: u64, horizon_us: u64) -> ScenarioSpec {
    let motion = MotionSpec {
        gentle: 1.2,
        abrupt: 3.4,
        hours: (horizon_us / 3_600_000_000).max(1),
    };
    ScenarioSpec {
        name: "vibration".into(),
        seed,
        horizon_us,
        // the harvester is driven by the *same* motion profile the sensor
        // observes — the paper's §2.3 energy↔data correlation
        harvester: HarvesterSpec::Piezo {
            motion,
            w_per_amp2: 0.009,
            seed: None,
        },
        capacitor: CapacitorSpec::from_capacitor(&Capacitor::vibration()),
        sensor: SensorSpec::Accel { motion },
        cost: CostKind::Kmeans,
        learner: LearnerSpec::ClusterLabel { label_budget: 30 },
        goal: Goal {
            rho_learn: 0.6,
            n_learn: 100,
            rho_infer: 1.0,
            window: 10,
        },
        scheduler: SchedulerKind::Planner,
        heuristic: Heuristic::RoundRobin,
        backend: BackendKind::Native,
        eval_period_us: eval_period_us(horizon_us),
        probe_count: 30,
        probe_lookback_us: 2 * 3_600_000_000,
        // energy arrives in 5 s gesture bursts; a 60 s charging step would
        // sample right past them
        charge_step_us: 1_000_000,
        charge_kernel: ChargeKernel::default(),
        policy: None,
        fleet: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H: u64 = 3_600_000_000;

    #[test]
    fn presets_build_and_validate() {
        for name in PRESETS {
            let s = preset(name, 7, 4 * H).unwrap();
            assert_eq!(s.name, name);
            s.validate().unwrap();
        }
        assert!(preset("nope", 1, H).is_err());
    }

    #[test]
    fn preset_json_round_trip_is_identity() {
        for name in PRESETS {
            let s = preset(name, 11, 6 * H).unwrap();
            let text = s.to_json().to_string();
            let back = ScenarioSpec::parse(&text).unwrap();
            assert_eq!(back, s, "{name} spec changed across JSON round trip");
            // and the serialized form is stable
            assert_eq!(back.to_json().to_string(), text);
        }
    }

    #[test]
    fn preset_labels_are_unique_per_axis() {
        let a = preset("vibration", 1, H).unwrap();
        let mut b = a.clone();
        b.scheduler = SchedulerKind::Alpaca { learn_pct: 0.5 };
        let mut c = a.clone();
        c.seed = 2;
        assert_ne!(a.label(), b.label());
        assert_ne!(a.label(), c.label());
    }
}
