//! Dynamic action planner (paper §4).
//!
//! The system state is the set of in-flight examples tagged with the last
//! action performed on each (§4.1). Whenever enough energy is harvested
//! for at least one action, the planner unfolds the state space over a
//! finite decision horizon L (§4.3), scores each reachable state by its
//! distance to the goal state (§4.2), and returns the first transition of
//! the best sequence.
//!
//! Search refinements implemented exactly as listed in §4.3:
//! * finite horizon L (default = longest path of the action diagram),
//! * a cap on admitted examples (default 2, as in the §7.5 overhead setup),
//! * boolean gates (`select`) folded into an *expected* pass probability
//!   learned from the heuristic's recent acceptance rate (the paper's
//!   "bypass ... and use their default return value"),
//! * lightweight gate actions are combined with their successor by the
//!   engine when energy allows (the "combining lightweight actions"
//!   refinement),
//! * memoization of repeated (pending-set, depth) subproblems.

use crate::actions::Action;
use crate::energy::cost::CostModel;
use std::collections::HashMap;

/// Goal-state parameters (§4.2). Rates are per planning window of
/// `window` harvesting cycles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Goal {
    /// Desired learned examples per window while in the learning phase.
    pub rho_learn: f64,
    /// Examples to learn before the goal switches to the inference phase.
    pub n_learn: u64,
    /// Desired inferences per window in the inference phase.
    pub rho_infer: f64,
    /// Window length in harvesting cycles (the paper's L cycles).
    pub window: u32,
}

impl Default for Goal {
    fn default() -> Self {
        Goal {
            rho_learn: 0.6,
            n_learn: 120,
            rho_infer: 0.8,
            window: 10,
        }
    }
}

/// Planner tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct PlannerConfig {
    /// Decision horizon L (transitions).
    pub horizon: usize,
    /// Maximum number of concurrently admitted examples.
    pub max_admitted: usize,
    /// Initial expected pass rate of the `select` gate (adapted online).
    pub p_select: f64,
    /// Energy tiebreak weight (reward units per mJ) — prefers cheaper
    /// sequences among equal-reward ones.
    pub lambda_energy: f64,
    /// Per-transition discount factor. Strictly < 1 or the receding
    /// horizon procrastinates: with undiscounted rewards, "infer now and
    /// learn one step later" always ties "learn now", and the deferred
    /// learn slides forever as the horizon recedes.
    pub gamma: f64,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            horizon: Action::longest_path_len(),
            max_admitted: 2,
            p_select: 0.6,
            lambda_energy: 0.01,
            gamma: 0.85,
        }
    }
}

/// Run-time context the engine passes at each decision point. The
/// windowed completion counts are the engine's ([`crate::sim::Policy`]'s)
/// bookkeeping — the planner holds no private mirror of them.
#[derive(Debug, Clone, Copy)]
pub struct PlanContext {
    /// Total examples learned so far.
    pub learned_total: u64,
    /// Learner quality indicator from the last `evaluate` (0..1).
    pub quality: f32,
    /// Learns completed in the current window.
    pub window_learns: u32,
    /// Infers completed in the current window.
    pub window_infers: u32,
    /// Harvesting cycles elapsed in the current window (1-based during a
    /// wake burst; the §4.2 rate targets scale with it).
    pub window_cycle: u32,
    /// Forecast energy budget, µJ: stored usable energy plus the net
    /// harvest predicted over the current burst window, minus any sync
    /// reserve the engine is holding for an upcoming rendezvous. `None`
    /// when forecast-aware planning is off — the planner then behaves
    /// bit-identically to the pre-forecast policy.
    pub forecast_uj: Option<f64>,
}

/// What the planner tells the engine to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Planned {
    /// Execute `action` on pending example `slot`.
    Advance { slot: usize, action: Action },
    /// Sense a new example.
    SenseNew,
    /// Nothing useful to do (no pending work and admission full — engine
    /// should sleep through this cycle).
    Idle,
}

/// Per-example planner state: the last action completed on it.
pub type Pending = Vec<Action>;

/// The dynamic action planner.
#[derive(Debug, Clone)]
pub struct DynamicActionPlanner {
    pub goal: Goal,
    pub cfg: PlannerConfig,
    /// EMA of the select gate's acceptance rate.
    p_select_ema: f64,
    memo: HashMap<u64, f64>,
}

/// Reward weights derived from goal + context.
#[derive(Debug, Clone, Copy)]
struct Weights {
    learn: f64,
    infer: f64,
}

impl DynamicActionPlanner {
    pub fn new(goal: Goal, cfg: PlannerConfig) -> Self {
        DynamicActionPlanner {
            goal,
            cfg,
            p_select_ema: cfg.p_select,
            memo: HashMap::new(),
        }
    }

    /// Observe the outcome of a `select` gate (adapts the expected pass
    /// rate used during lookahead).
    pub fn observe_select(&mut self, accepted: bool) {
        let x = if accepted { 1.0 } else { 0.0 };
        self.p_select_ema = 0.9 * self.p_select_ema + 0.1 * x;
    }

    /// Goal phase: still learning, or maintaining inference?
    pub fn in_learning_phase(&self, learned_total: u64) -> bool {
        learned_total < self.goal.n_learn
    }

    fn weights(&self, ctx: &PlanContext) -> Weights {
        let learning_phase = self.in_learning_phase(ctx.learned_total);
        // Rate maintenance reads the windowed completion counts straight
        // from the context ([`crate::sim::Policy`]'s bookkeeping) — the
        // planner used to keep a duplicate mirror of them.
        let per_cycle_l = self.goal.rho_learn / self.goal.window as f64;
        let per_cycle_c = self.goal.rho_infer / self.goal.window as f64;
        let expected_l = per_cycle_l * ctx.window_cycle.max(1) as f64;
        let expected_c = per_cycle_c * ctx.window_cycle.max(1) as f64;
        let behind_l = (ctx.window_learns as f64) < expected_l;
        let behind_c = (ctx.window_infers as f64) < expected_c;
        if learning_phase {
            // Learning phase (§4.2): the goal is the learn rate ρ_l.
            // Inference is opportunistic only — once the window's learn
            // rate is met, spare cycles may infer.
            let mut w = Weights {
                learn: 1.0,
                infer: 0.1,
            };
            if behind_l {
                w.learn *= 2.0;
            } else {
                w.infer = 0.5;
            }
            w
        } else {
            // Inference phase: learn pays off proportionally to how badly
            // the model fits (paper: "if the learner is under-performing,
            // retraining is a more sensible action").
            let mut w = Weights {
                learn: (1.0 - ctx.quality as f64).clamp(0.0, 1.0) * 0.6,
                infer: 1.0,
            };
            if behind_c {
                w.infer *= 2.0;
            }
            w
        }
    }

    /// The planner's decision procedure: finite-horizon search for the
    /// next transition (§4.3). `pending` holds the last completed action
    /// of each in-flight example.
    pub fn next_action(
        &mut self,
        pending: &Pending,
        ctx: &PlanContext,
        costs: &CostModel,
    ) -> Planned {
        let w = self.weights(ctx);
        self.memo.clear();

        // Forecast gate: a transition whose energy cost exceeds the
        // predicted budget cannot complete before the capacitor dies —
        // starting it only buys a rollback. Filtering here sizes the
        // burst to the forecast harvest window (Islam et al. 2025); when
        // every candidate is filtered the planner idles and the engine
        // sleeps the device into the next harvest segment. `None` (the
        // knob off) filters nothing.
        let fits = |a: Action| match ctx.forecast_uj {
            Some(budget_uj) => costs.cost(a).energy_uj <= budget_uj,
            None => true,
        };

        let mut best = f64::NEG_INFINITY;
        let mut best_move = Planned::Idle;

        // Candidate 1: advance each pending example along the diagram.
        for (slot, &last) in pending.iter().enumerate() {
            for &nxt in last.next() {
                if !fits(nxt) {
                    continue;
                }
                // The Decide branch is resolved here: advancing to Select
                // commits to the learn path, advancing to Infer to the
                // inference path.
                let mut state: Vec<Action> = pending.clone();
                state[slot] = nxt;
                let gain = self.transition_reward(nxt, &w)
                    - self.cfg.lambda_energy * costs.cost(nxt).energy_uj / 1_000.0;
                let v = gain
                    + self.cfg.gamma
                        * self.search(&state, self.cfg.horizon.saturating_sub(1), &w, costs);
                if v > best {
                    best = v;
                    best_move = Planned::Advance { slot, action: nxt };
                }
            }
            // terminal examples leave the system implicitly (engine pops them)
        }

        // Candidate 2: sense a new example (if admission allows).
        if pending.len() < self.cfg.max_admitted && fits(Action::Sense) {
            let mut state = pending.clone();
            state.push(Action::Sense);
            let gain = -self.cfg.lambda_energy * costs.cost(Action::Sense).energy_uj / 1_000.0;
            let v = gain
                + self.cfg.gamma
                    * self.search(&state, self.cfg.horizon.saturating_sub(1), &w, costs);
            if v > best {
                best_move = Planned::SenseNew;
            }
        }

        best_move
    }

    /// Expected immediate reward of completing `a`.
    fn transition_reward(&self, a: Action, w: &Weights) -> f64 {
        match a {
            // Learn only happens if the select gate passed; the expected
            // reward folds the gate's pass rate in (§4.3 refinement). The
            // floor keeps a low-acceptance heuristic from freezing the
            // learn path entirely (a rejected select is cheap — the slot
            // simply frees for the next candidate).
            Action::Learn => w.learn * self.p_select_ema.max(0.25),
            Action::Infer => w.infer,
            // Completing evaluate frees the example's admission slot and
            // refreshes the quality signal the goal logic depends on.
            Action::Evaluate => 0.1 * w.learn.max(w.infer),
            _ => 0.0,
        }
    }

    /// DFS over the unfolded state space, memoized. `state` is the caller's
    /// snapshot; completed (terminal) examples are filtered out here — they
    /// have left the system (§4.1).
    fn search(&mut self, state: &[Action], depth: usize, w: &Weights, costs: &CostModel) -> f64 {
        let live: Vec<Action> = state
            .iter()
            .copied()
            .filter(|a| !a.next().is_empty())
            .collect();
        if depth == 0 {
            return 0.0;
        }
        let key = Self::encode(&live, depth);
        if let Some(&v) = self.memo.get(&key) {
            return v;
        }

        let mut best: f64 = 0.0; // doing nothing scores 0
        let mut next_state = live.clone();
        for slot in 0..live.len() {
            for &nxt in live[slot].next() {
                next_state[slot] = nxt;
                let gain = self.transition_reward(nxt, w)
                    - self.cfg.lambda_energy * costs.cost(nxt).energy_uj / 1_000.0;
                let v = gain + self.cfg.gamma * self.search(&next_state, depth - 1, w, costs);
                next_state[slot] = live[slot];
                if v > best {
                    best = v;
                }
            }
        }
        if live.len() < self.cfg.max_admitted {
            next_state.push(Action::Sense);
            let gain = -self.cfg.lambda_energy * costs.cost(Action::Sense).energy_uj / 1_000.0;
            let v = gain + self.cfg.gamma * self.search(&next_state, depth - 1, w, costs);
            next_state.pop();
            if v > best {
                best = v;
            }
        }

        self.memo.insert(key, best);
        best
    }

    /// Order-independent state hash: pending multiset + depth.
    fn encode(state: &[Action], depth: usize) -> u64 {
        let mut counts = [0u64; Action::ALL.len()];
        for &a in state {
            counts[Action::ALL.iter().position(|&x| x == a).unwrap()] += 1;
        }
        let mut h = depth as u64;
        for c in counts {
            h = h.wrapping_mul(31).wrapping_add(c);
        }
        h
    }
}

impl Default for DynamicActionPlanner {
    fn default() -> Self {
        Self::new(Goal::default(), PlannerConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(learned: u64, quality: f32) -> PlanContext {
        PlanContext {
            learned_total: learned,
            quality,
            window_learns: 0,
            window_infers: 0,
            window_cycle: 1,
            forecast_uj: None,
        }
    }

    fn run_to_completion(p: &mut DynamicActionPlanner, ctx: &PlanContext) -> Vec<Action> {
        // simulate the engine: execute whatever the planner asks until an
        // example completes a terminal action; record the action sequence.
        let costs = CostModel::kmeans();
        let mut pending: Pending = vec![];
        let mut seq = vec![];
        for _ in 0..32 {
            match p.next_action(&pending, ctx, &costs) {
                Planned::SenseNew => {
                    pending.push(Action::Sense);
                    seq.push(Action::Sense);
                }
                Planned::Advance { slot, action } => {
                    seq.push(action);
                    if action.next().is_empty() {
                        pending.remove(slot);
                        return seq;
                    }
                    pending[slot] = action;
                }
                Planned::Idle => break,
            }
        }
        seq
    }

    #[test]
    fn learning_phase_prefers_learn_path() {
        let mut p = DynamicActionPlanner::default();
        let seq = run_to_completion(&mut p, &ctx(0, 0.0));
        // the learn path must be taken, and before any opportunistic infer
        // on a second admitted example
        let li = seq
            .iter()
            .position(|&a| a == Action::Learn)
            .unwrap_or_else(|| panic!("no Learn in {seq:?}"));
        if let Some(ii) = seq.iter().position(|&a| a == Action::Infer) {
            assert!(li < ii, "{seq:?}");
        }
        // order respects the diagram
        assert_eq!(seq[0], Action::Sense);
        let si = seq.iter().position(|&a| a == Action::Select).unwrap();
        assert!(si < li);
    }

    #[test]
    fn inference_phase_with_good_model_prefers_infer() {
        let mut p = DynamicActionPlanner::default();
        let c = ctx(p.goal.n_learn + 10, 0.95);
        let seq = run_to_completion(&mut p, &c);
        assert!(seq.contains(&Action::Infer), "{seq:?}");
        assert!(!seq.contains(&Action::Learn), "{seq:?}");
    }

    #[test]
    fn poor_quality_in_inference_phase_can_trigger_relearn() {
        let mut p = DynamicActionPlanner::default();
        // quality 0 -> learn weight 0.6(*2 if behind) vs infer 1.0(*2):
        // infer still wins per-step, but learn shouldn't be starved when
        // the select gate is known to accept everything.
        p.observe_select(true);
        let c = ctx(p.goal.n_learn + 10, 0.0);
        let w = p.weights(&c);
        assert!(w.learn > 0.0);
    }

    #[test]
    fn planner_respects_admission_cap() {
        let mut p = DynamicActionPlanner::default();
        p.cfg.max_admitted = 1;
        let costs = CostModel::knn();
        let pending = vec![Action::Sense];
        // with one admitted example, SenseNew must never be chosen
        let mv = p.next_action(&pending, &ctx(0, 0.0), &costs);
        assert_ne!(mv, Planned::SenseNew);
    }

    #[test]
    fn planner_only_proposes_legal_transitions() {
        let mut p = DynamicActionPlanner::default();
        let costs = CostModel::knn();
        let mut pending = vec![Action::Extract];
        for _ in 0..8 {
            match p.next_action(&pending, &ctx(0, 0.5), &costs) {
                Planned::Advance { slot, action } => {
                    assert!(
                        pending[slot].can_precede(action),
                        "{:?} -> {action:?}",
                        pending[slot]
                    );
                    if action.next().is_empty() {
                        pending.remove(slot);
                    } else {
                        pending[slot] = action;
                    }
                }
                Planned::SenseNew => pending.push(Action::Sense),
                Planned::Idle => break,
            }
            if pending.is_empty() {
                break;
            }
        }
    }

    #[test]
    fn select_gate_ema_adapts() {
        let mut p = DynamicActionPlanner::default();
        let before = p.p_select_ema;
        for _ in 0..20 {
            p.observe_select(false);
        }
        assert!(p.p_select_ema < before * 0.3);
        for _ in 0..40 {
            p.observe_select(true);
        }
        assert!(p.p_select_ema > 0.9);
    }

    #[test]
    fn windowed_rates_come_from_the_context() {
        // a planner behind on its learn rate boosts the learn weight; the
        // same counts delivered through the context must flip the boost
        // off (no private mirror left to disagree with)
        let p = DynamicActionPlanner::default();
        let behind = PlanContext {
            learned_total: 0,
            quality: 0.0,
            window_learns: 0,
            window_infers: 0,
            window_cycle: p.goal.window,
            forecast_uj: None,
        };
        let caught_up = PlanContext {
            window_learns: p.goal.rho_learn.ceil() as u32 + 1,
            ..behind
        };
        assert!(p.weights(&behind).learn > p.weights(&caught_up).learn);
    }

    #[test]
    fn idle_when_no_work_possible() {
        let mut p = DynamicActionPlanner::default();
        p.cfg.max_admitted = 0;
        let costs = CostModel::knn();
        let mv = p.next_action(&vec![], &ctx(0, 0.5), &costs);
        assert_eq!(mv, Planned::Idle);
    }

    #[test]
    fn forecast_budget_filters_unaffordable_transitions() {
        let costs = CostModel::knn();
        let budget = |b: f64| PlanContext {
            forecast_uj: Some(b),
            ..ctx(0, 0.0)
        };
        // a budget below the cheapest transition forces Idle — the engine
        // then sleeps the device into the next harvest segment instead of
        // starting work that can only roll back
        let mut p = DynamicActionPlanner::default();
        let mv = p.next_action(&vec![Action::Sense], &budget(0.0), &costs);
        assert_eq!(mv, Planned::Idle);
        // a budget that cannot cover Learn never starts one
        let learn_uj = costs.cost(Action::Learn).energy_uj;
        let mv = p.next_action(&vec![Action::Select], &budget(learn_uj - 1.0), &costs);
        assert_ne!(
            mv,
            Planned::Advance { slot: 0, action: Action::Learn }
        );
        // an unlimited budget decides exactly like no forecast at all
        let mut a = DynamicActionPlanner::default();
        let mut b = DynamicActionPlanner::default();
        for pending in [vec![], vec![Action::Sense], vec![Action::Select, Action::Extract]] {
            let open = a.next_action(&pending, &budget(f64::INFINITY), &costs);
            let off = b.next_action(&pending, &ctx(0, 0.0), &costs);
            assert_eq!(open, off, "{pending:?}");
        }
    }
}
