//! Access-trace recorder for the intermittent-safety analyzer.
//!
//! The auditor shadows the [`Nvm`](super::Nvm) store: when armed (debug
//! builds only — see `Nvm::audit_start`), every transaction bracket and
//! every byte-level read/write is appended to an [`AccessTrace`]. The
//! `analysis` module lints that trace for write-after-read hazards,
//! writes outside transactions, and save/restore key parity.
//!
//! Events are plain data so the lint rules stay pure functions over the
//! trace; the recorder itself makes no judgements. Recording is gated by
//! `cfg(debug_assertions)` at the `Nvm` hook sites, so the release hot
//! path compiles the hooks down to nothing.

/// One recorded store operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AccessEvent {
    /// `begin_action` succeeded.
    Begin,
    /// `commit_action` succeeded.
    Commit,
    /// `abort_action` rolled back an open transaction.
    Abort,
    /// A read of `range` bytes of `key`. `committed` holds the sub-ranges
    /// of the read that observed *committed pre-action* state — i.e. the
    /// read range minus the spans staged earlier in the same transaction
    /// (read-your-writes never observes committed bytes) and clipped to
    /// the committed value's length. Only committed observations can
    /// participate in a write-after-read hazard.
    Read {
        key: String,
        range: (usize, usize),
        committed: Vec<(usize, usize)>,
        in_txn: bool,
    },
    /// A write of `range` bytes of `key`. `full` marks whole-value
    /// overwrites (`write_id` / `write_f32s_id`), which replace the slot
    /// irrespective of its prior contents and therefore replay cleanly.
    Write {
        key: String,
        range: (usize, usize),
        full: bool,
        in_txn: bool,
    },
    /// A commit persist step durably flushed the staged image of `key`
    /// (`bytes` long) into the redo area. Flushes happen in sorted key
    /// order before the commit record, so a trace shows exactly how far
    /// a torn commit progressed.
    Flush { key: String, bytes: usize },
    /// The checksummed commit record was written — the single persist
    /// step that makes the transaction durable (the nonce-last idiom).
    Record { bytes: usize },
    /// `Nvm::recover` healed an interrupted commit: `rolled_back` means
    /// the pre-transaction image was restored; `false` means a complete
    /// commit record was found and the staged image was rolled forward.
    Heal { rolled_back: bool },
}

/// An ordered recording of store operations.
#[derive(Debug, Clone, Default)]
pub struct AccessTrace {
    pub events: Vec<AccessEvent>,
}

impl AccessTrace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Sort half-open byte ranges and merge overlapping/adjacent ones.
/// Empty ranges are dropped.
pub fn normalize(mut ranges: Vec<(usize, usize)>) -> Vec<(usize, usize)> {
    ranges.retain(|&(s, e)| e > s);
    ranges.sort_unstable();
    let mut out: Vec<(usize, usize)> = Vec::with_capacity(ranges.len());
    for (s, e) in ranges {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Subtract every range in `cuts` from `whole`, returning the surviving
/// sub-ranges in order. `cuts` need not be normalized.
pub fn subtract(whole: (usize, usize), cuts: &[(usize, usize)]) -> Vec<(usize, usize)> {
    let cuts = normalize(cuts.to_vec());
    let mut out = Vec::new();
    let (mut cursor, end) = whole;
    for (cs, ce) in cuts {
        if ce <= cursor {
            continue;
        }
        if cs >= end {
            break;
        }
        if cs > cursor {
            out.push((cursor, cs.min(end)));
        }
        cursor = cursor.max(ce);
        if cursor >= end {
            break;
        }
    }
    if cursor < end {
        out.push((cursor, end));
    }
    out
}

/// First intersection of `range` with any range in `list`, if one exists.
pub fn overlap(range: (usize, usize), list: &[(usize, usize)]) -> Option<(usize, usize)> {
    let (s, e) = range;
    list.iter()
        .filter_map(|&(ls, le)| {
            let lo = s.max(ls);
            let hi = e.min(le);
            (hi > lo).then_some((lo, hi))
        })
        .next()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_merges_and_drops_empty() {
        let got = normalize(vec![(8, 12), (0, 4), (4, 6), (10, 10), (11, 14)]);
        assert_eq!(got, vec![(0, 6), (8, 14)]);
        assert!(normalize(vec![]).is_empty());
    }

    #[test]
    fn subtract_carves_cuts_out_of_the_whole() {
        assert_eq!(subtract((0, 10), &[]), vec![(0, 10)]);
        assert_eq!(subtract((0, 10), &[(2, 4), (6, 8)]), vec![(0, 2), (4, 6), (8, 10)]);
        assert_eq!(subtract((0, 10), &[(0, 10)]), Vec::<(usize, usize)>::new());
        assert_eq!(subtract((4, 8), &[(0, 5), (7, 12)]), vec![(5, 7)]);
        // cuts outside the whole are ignored
        assert_eq!(subtract((4, 8), &[(0, 2), (9, 12)]), vec![(4, 8)]);
    }

    #[test]
    fn overlap_finds_the_first_intersection() {
        assert_eq!(overlap((4, 8), &[(0, 2), (6, 10)]), Some((6, 8)));
        assert_eq!(overlap((4, 8), &[(0, 4), (8, 12)]), None);
        assert_eq!(overlap((0, 0), &[(0, 4)]), None);
    }
}
