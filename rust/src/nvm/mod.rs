//! Non-volatile memory model with action atomicity.
//!
//! Paper §3.5 memory model: *action-shared* variables live in non-volatile
//! memory (EEPROM/FRAM) and survive power failures; *action-local* state
//! is volatile and lost. An action's writes become visible to other
//! actions only when the action completes ("once an action completes
//! writing a value ... the value can be read by any action"); if power
//! fails mid-action, the framework discards the intermediate results and
//! the action restarts from scratch (§3.5 action-based programming).
//!
//! This module implements exactly that: a committed store plus a staging
//! buffer with read-your-writes semantics, `commit` on action completion,
//! `abort` on power failure, and read/write accounting so the energy model
//! can charge NVM traffic.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Byte-granular non-volatile store with transactional action semantics.
#[derive(Debug, Clone, Default)]
pub struct Nvm {
    committed: BTreeMap<String, Vec<u8>>,
    /// Writes staged by the in-flight action (None = no action open).
    staged: Option<BTreeMap<String, Vec<u8>>>,
    /// Capacity limit in bytes (0 = unlimited). The paper's platforms
    /// range from 512 B (PIC) to 256 KB (MSP430 FRAM).
    pub capacity: usize,
    // accounting
    pub bytes_written: u64,
    pub bytes_read: u64,
    pub commits: u64,
    pub aborts: u64,
}

impl Nvm {
    /// Unlimited-capacity store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Store with a byte capacity (over-capacity writes fail).
    pub fn with_capacity(capacity: usize) -> Self {
        Nvm {
            capacity,
            ..Self::default()
        }
    }

    /// Open an action transaction. Nested transactions are an error (an
    /// intermittent MCU runs one action at a time).
    pub fn begin_action(&mut self) -> Result<()> {
        if self.staged.is_some() {
            return Err(Error::Nvm("action already in flight".into()));
        }
        self.staged = Some(BTreeMap::new());
        Ok(())
    }

    /// Commit the in-flight action's writes.
    pub fn commit_action(&mut self) -> Result<()> {
        let staged = self
            .staged
            .take()
            .ok_or_else(|| Error::Nvm("commit without begin".into()))?;
        for (k, v) in staged {
            self.committed.insert(k, v);
        }
        self.commits += 1;
        Ok(())
    }

    /// Discard the in-flight action's writes (power failure mid-action).
    pub fn abort_action(&mut self) {
        if self.staged.take().is_some() {
            self.aborts += 1;
        }
    }

    /// Is an action transaction open?
    pub fn in_action(&self) -> bool {
        self.staged.is_some()
    }

    fn used_bytes(&self) -> usize {
        self.committed.values().map(|v| v.len()).sum()
    }

    /// Raw write. Inside an action the write is staged; outside (framework
    /// bookkeeping, e.g. at boot) it commits immediately.
    pub fn write(&mut self, key: &str, bytes: &[u8]) -> Result<()> {
        if self.capacity > 0 {
            let old = self
                .staged
                .as_ref()
                .and_then(|s| s.get(key))
                .or_else(|| self.committed.get(key))
                .map(|v| v.len())
                .unwrap_or(0);
            if self.used_bytes() + bytes.len().saturating_sub(old) > self.capacity {
                return Err(Error::Nvm(format!(
                    "capacity exceeded writing `{key}` ({} B used of {} B)",
                    self.used_bytes(),
                    self.capacity
                )));
            }
        }
        self.bytes_written += bytes.len() as u64;
        match &mut self.staged {
            Some(s) => {
                s.insert(key.to_string(), bytes.to_vec());
            }
            None => {
                self.committed.insert(key.to_string(), bytes.to_vec());
            }
        }
        Ok(())
    }

    /// Raw read with read-your-writes semantics.
    pub fn read(&mut self, key: &str) -> Option<Vec<u8>> {
        let v = self
            .staged
            .as_ref()
            .and_then(|s| s.get(key))
            .or_else(|| self.committed.get(key))
            .cloned();
        if let Some(ref v) = v {
            self.bytes_read += v.len() as u64;
        }
        v
    }

    /// Does a committed or staged value exist?
    pub fn contains(&self, key: &str) -> bool {
        self.staged
            .as_ref()
            .map(|s| s.contains_key(key))
            .unwrap_or(false)
            || self.committed.contains_key(key)
    }

    // ---- typed helpers -------------------------------------------------

    /// Write an f32 slice.
    pub fn write_f32s(&mut self, key: &str, xs: &[f32]) -> Result<()> {
        let mut bytes = Vec::with_capacity(xs.len() * 4);
        for x in xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        self.write(key, &bytes)
    }

    /// Read an f32 slice.
    pub fn read_f32s(&mut self, key: &str) -> Option<Vec<f32>> {
        let bytes = self.read(key)?;
        Some(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        )
    }

    /// Write a u64 counter.
    pub fn write_u64(&mut self, key: &str, v: u64) -> Result<()> {
        self.write(key, &v.to_le_bytes())
    }

    /// Read a u64 counter (0 if absent).
    pub fn read_u64(&mut self, key: &str) -> u64 {
        self.read(key)
            .filter(|b| b.len() == 8)
            .map(|b| u64::from_le_bytes(b.try_into().unwrap()))
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn committed_writes_survive() {
        let mut nvm = Nvm::new();
        nvm.write_f32s("w", &[1.0, 2.0]).unwrap();
        assert_eq!(nvm.read_f32s("w").unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn abort_discards_staged_writes() {
        let mut nvm = Nvm::new();
        nvm.write_f32s("model", &[1.0]).unwrap();
        nvm.begin_action().unwrap();
        nvm.write_f32s("model", &[9.0]).unwrap();
        // read-your-writes inside the action
        assert_eq!(nvm.read_f32s("model").unwrap(), vec![9.0]);
        nvm.abort_action(); // power failure
        assert_eq!(nvm.read_f32s("model").unwrap(), vec![1.0]);
        assert_eq!(nvm.aborts, 1);
    }

    #[test]
    fn commit_publishes_staged_writes() {
        let mut nvm = Nvm::new();
        nvm.begin_action().unwrap();
        nvm.write_u64("count", 7).unwrap();
        nvm.commit_action().unwrap();
        assert_eq!(nvm.read_u64("count"), 7);
        assert_eq!(nvm.commits, 1);
    }

    #[test]
    fn nested_begin_rejected() {
        let mut nvm = Nvm::new();
        nvm.begin_action().unwrap();
        assert!(nvm.begin_action().is_err());
    }

    #[test]
    fn commit_without_begin_rejected() {
        let mut nvm = Nvm::new();
        assert!(nvm.commit_action().is_err());
    }

    #[test]
    fn capacity_enforced() {
        let mut nvm = Nvm::with_capacity(8);
        nvm.write_f32s("a", &[1.0, 2.0]).unwrap(); // 8 bytes
        assert!(nvm.write_f32s("b", &[3.0]).is_err());
        // overwriting the same key with the same size is fine
        nvm.write_f32s("a", &[4.0, 5.0]).unwrap();
    }

    #[test]
    fn accounting_counts_bytes() {
        let mut nvm = Nvm::new();
        nvm.write_f32s("x", &[0.0; 4]).unwrap();
        nvm.read_f32s("x");
        assert_eq!(nvm.bytes_written, 16);
        assert_eq!(nvm.bytes_read, 16);
    }

    #[test]
    fn missing_counter_defaults_zero() {
        let mut nvm = Nvm::new();
        assert_eq!(nvm.read_u64("nope"), 0);
    }
}
