//! Non-volatile memory model with action atomicity.
//!
//! Paper §3.5 memory model: *action-shared* variables live in non-volatile
//! memory (EEPROM/FRAM) and survive power failures; *action-local* state
//! is volatile and lost. An action's writes become visible to other
//! actions only when the action completes ("once an action completes
//! writing a value ... the value can be read by any action"); if power
//! fails mid-action, the framework discards the intermediate results and
//! the action restarts from scratch (§3.5 action-based programming).
//!
//! This module implements exactly that: a committed store plus a staging
//! buffer with read-your-writes semantics, `commit` on action completion,
//! `abort` on power failure, and read/write accounting so the energy model
//! can charge NVM traffic.
//!
//! §Perf — the store is built for the steady-state learn hot path:
//!
//! * Keys are interned once into [`KeyId`] handles ([`Nvm::intern`]); the
//!   handle paths (`write_id`, `read_id`, `write_f32s_at`, ...) never
//!   touch a string or allocate a key.
//! * Values live in a slab indexed by handle; a running byte counter makes
//!   the capacity check O(1) instead of an O(#keys) rescan per write.
//! * Range writes ([`Nvm::write_at`] / [`Nvm::write_f32s_at`]) stage only
//!   the dirty span — the staging buffer records per-slot dirty ranges —
//!   so a delta checkpoint of one ring-buffer row costs that row's bytes,
//!   not the model's.
//! * Reads can borrow ([`Nvm::read_id`]) or decode into a caller buffer
//!   ([`Nvm::read_f32s_into`]) instead of cloning.
//!
//! Every buffer (staging, dirty lists) keeps its capacity across
//! transactions, so after warm-up the write/commit cycle performs no heap
//! allocation.
//!
//! §Crash consistency — commit is not atomic on real FRAM/EEPROM, so it
//! is not modeled as atomic here either. A non-empty commit executes as
//! **persist steps**: each staged slot flushes to a durable redo area in
//! deterministic (key-id) order, then a checksummed **commit record** is
//! written last — the same written-last idiom `sim/state.rs::RunState`
//! uses for its head blob. A power failure between or inside steps (the
//! [`crate::fault::FaultInjector`] every store carries can cut or tear
//! any step) leaves a representable torn state: after
//! [`Nvm::power_failure_reset`] (volatile loss), [`Nvm::recover`] rolls
//! the interrupted commit forward (valid record: adopt every flushed
//! image, exactly what commit would have done) or back (missing/torn
//! record: the pre-transaction committed image stands untouched). The
//! record's checksum covers only the record itself — which is what lets
//! the crash sweep's negative control catch a wrong-order commit. The
//! record is framework overhead and is deliberately *not* charged to
//! `bytes_written` (the committed byte goldens predate it).

pub mod arena;
pub mod audit;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::fault::{self, FaultInjector, StepKind, StepOutcome};

/// Interned key handle: resolve a string key once ([`Nvm::intern`]), then
/// address the slot directly. Handles are only meaningful for the store
/// that issued them; [`Nvm::store_id`] lets callers detect a foreign
/// store and re-intern. Clones get a fresh identity — their slot layout
/// is copied, so re-interning the same names yields the same slots, but
/// handles interned on either side *after* the clone would silently
/// alias otherwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KeyId(u32);

/// Distinct identity per store (including clones).
static NEXT_STORE_ID: AtomicU64 = AtomicU64::new(1);

/// One slab slot: a committed value plus its reusable staging buffer.
#[derive(Debug, Clone, Default)]
struct Slot {
    name: String,
    committed: Vec<u8>,
    /// Does a committed value exist? (`committed` keeps its capacity after
    /// the value conceptually disappears, so emptiness is not absence.)
    present: bool,
    /// Staging buffer for the open transaction (capacity reused).
    staged: Vec<u8>,
    /// Is this slot staged in the open transaction?
    staged_present: bool,
    /// Byte ranges of `staged` dirtied by the open transaction
    /// (start, end). A full overwrite records one whole-value range.
    dirty: Vec<(usize, usize)>,
}

impl Slot {
    /// Length the slot would have if the open transaction committed now.
    fn pending_len(&self) -> usize {
        if self.staged_present {
            self.staged.len()
        } else if self.present {
            self.committed.len()
        } else {
            0
        }
    }
}

/// One durable flush-log entry of an in-flight commit: slot `id`'s
/// staged image, `done` of `len` bytes flushed (a tear leaves a proper
/// prefix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct JournalEntry {
    id: u32,
    len: usize,
    done: usize,
}

/// The durable commit journal: the flush log and commit record of the
/// in-flight (or interrupted) commit. Buffers keep their capacity across
/// commits so the steady-state commit cycle stays allocation-free.
#[derive(Debug, Clone, Default)]
struct Journal {
    /// Commits durably recorded over this store's lifetime (encoded into
    /// each record so no two records are bit-identical).
    seq: u64,
    /// Flush log of the in-flight commit (durable with each flush step).
    entries: Vec<JournalEntry>,
    /// `staged_used` snapshot encoded in the record; adopted as the
    /// committed byte counter on roll-forward.
    staged_used: usize,
    /// Encoded commit record bytes (layout: seq, staged_used, n,
    /// n×(id, len), FNV-1a checksum).
    record_buf: Vec<u8>,
    /// Durable prefix of `record_buf` (`None` = record never started;
    /// `Some(n) < len` = torn record).
    record_done: Option<usize>,
}

impl Journal {
    /// Is a complete, checksum-valid, structurally sound commit record
    /// durable?
    fn record_valid(&self) -> bool {
        let Some(done) = self.record_done else {
            return false;
        };
        let buf = &self.record_buf;
        if done != buf.len() || buf.len() < 28 {
            return false;
        }
        let n = u32::from_le_bytes(buf[16..20].try_into().unwrap()) as usize;
        if buf.len() != 28 + 12 * n {
            return false;
        }
        let tail = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
        fault::fnv1a(&buf[..buf.len() - 8]) == tail
    }

    /// Anything of an interrupted commit to recover from?
    fn dirty(&self) -> bool {
        !self.entries.is_empty() || self.record_done.is_some()
    }

    fn clear(&mut self) {
        self.entries.clear();
        self.record_done = None;
    }
}

/// What [`Nvm::recover`] found (and did) at boot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Recovery {
    /// No interrupted commit: the store was already consistent.
    Clean,
    /// A valid commit record with its flushed images: the interrupted
    /// commit was completed (adopted) exactly as `commit_action` would
    /// have.
    RolledForward,
    /// A missing or torn commit record: the interrupted commit was
    /// discarded and the pre-transaction committed image stands.
    RolledBack,
}

/// Byte-granular non-volatile store with transactional action semantics.
#[derive(Debug)]
pub struct Nvm {
    slots: Vec<Slot>,
    index: BTreeMap<String, KeyId>,
    /// Is an action transaction open?
    txn_open: bool,
    /// Slots staged in the open transaction (commit/abort walk this).
    txn_dirty: Vec<KeyId>,
    /// Committed bytes (running counter; O(1) capacity checks).
    used: usize,
    /// Bytes the store would hold if the open transaction committed.
    staged_used: usize,
    /// Capacity limit in bytes (0 = unlimited). The paper's platforms
    /// range from 512 B (PIC) to 256 KB (MSP430 FRAM).
    pub capacity: usize,
    store_id: u64,
    /// Durable commit journal (flush log + commit record) of the
    /// in-flight commit; survives a power cut for [`Nvm::recover`].
    journal: Journal,
    /// Power-failure injector (disarmed by default: one branch per
    /// persist step). Not cloned — a clone is a different device.
    fault: FaultInjector,
    /// Reference-mode per-commit digest log (see
    /// [`Nvm::start_digest_log`]); not cloned.
    digest_log: Option<Vec<u64>>,
    /// Negative-control bug knob: commit record written before flushes.
    record_first: bool,
    /// Fixture knob: die right after the record becomes durable.
    cut_after_record: bool,
    // accounting
    pub bytes_written: u64,
    pub bytes_read: u64,
    pub commits: u64,
    pub aborts: u64,
    /// Armed access-trace recorder (debug builds; see [`Nvm::audit_start`]).
    /// Boxed so the idle field costs one pointer; not cloned — a clone is a
    /// different store and starts unobserved.
    #[cfg(debug_assertions)]
    audit: Option<Box<audit::AccessTrace>>,
}

impl Clone for Nvm {
    /// Clones copy the contents but get a **fresh** [`Nvm::store_id`]:
    /// cached [`KeyId`] handles from the original still point at the same
    /// names in the copy, but holders re-intern (idempotent) instead of
    /// risking aliasing with keys interned after the clone diverged.
    fn clone(&self) -> Self {
        Nvm {
            slots: self.slots.clone(),
            index: self.index.clone(),
            txn_open: self.txn_open,
            txn_dirty: self.txn_dirty.clone(),
            used: self.used,
            staged_used: self.staged_used,
            capacity: self.capacity,
            store_id: NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed),
            journal: self.journal.clone(),
            fault: FaultInjector::default(),
            digest_log: None,
            record_first: self.record_first,
            cut_after_record: false,
            bytes_written: self.bytes_written,
            bytes_read: self.bytes_read,
            commits: self.commits,
            aborts: self.aborts,
            #[cfg(debug_assertions)]
            audit: None,
        }
    }
}

impl Default for Nvm {
    fn default() -> Self {
        Nvm {
            slots: Vec::new(),
            index: BTreeMap::new(),
            txn_open: false,
            txn_dirty: Vec::new(),
            used: 0,
            staged_used: 0,
            capacity: 0,
            store_id: NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed),
            journal: Journal::default(),
            fault: FaultInjector::default(),
            digest_log: None,
            record_first: false,
            cut_after_record: false,
            bytes_written: 0,
            bytes_read: 0,
            commits: 0,
            aborts: 0,
            #[cfg(debug_assertions)]
            audit: None,
        }
    }
}

impl Nvm {
    /// Unlimited-capacity store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Store with a byte capacity (over-capacity writes fail).
    pub fn with_capacity(capacity: usize) -> Self {
        Nvm {
            capacity,
            ..Self::default()
        }
    }

    /// Identity of this store (distinct per store, including clones).
    /// Callers caching [`KeyId`] handles compare this to detect a foreign
    /// store and re-intern.
    pub fn store_id(&self) -> u64 {
        self.store_id
    }

    /// Resolve `key` to a handle, creating an (absent) slot on first use.
    /// The only key path that allocates; do it once at construction.
    pub fn intern(&mut self, key: &str) -> KeyId {
        if let Some(&id) = self.index.get(key) {
            return id;
        }
        let id = KeyId(self.slots.len() as u32);
        self.slots.push(Slot {
            name: key.to_string(),
            ..Slot::default()
        });
        self.index.insert(key.to_string(), id);
        id
    }

    /// Resolve without creating (reads of absent keys stay absent).
    pub fn resolve(&self, key: &str) -> Option<KeyId> {
        self.index.get(key).copied()
    }

    fn slot(&self, id: KeyId) -> Result<&Slot> {
        self.slots
            .get(id.0 as usize)
            .ok_or_else(|| Error::Nvm(format!("stale key handle {}", id.0)))
    }

    // ---- access auditing (intermittent-safety analyzer) ----------------

    /// Arm the access-trace recorder: every subsequent transaction bracket
    /// and byte-level read/write is appended to a fresh trace until
    /// [`Nvm::audit_take`] disarms it. Debug builds only — the release
    /// twin is a no-op so the hot path stays unobserved.
    #[cfg(debug_assertions)]
    pub fn audit_start(&mut self) {
        self.audit = Some(Box::new(audit::AccessTrace::new()));
    }

    /// Release twin of [`Nvm::audit_start`]: recording unavailable.
    #[cfg(not(debug_assertions))]
    pub fn audit_start(&mut self) {}

    /// Disarm the recorder and take the trace recorded since
    /// [`Nvm::audit_start`] (`None` if never armed, and always `None` in
    /// release builds).
    #[cfg(debug_assertions)]
    pub fn audit_take(&mut self) -> Option<audit::AccessTrace> {
        self.audit.take().map(|t| *t)
    }

    /// Release twin of [`Nvm::audit_take`]: recording unavailable.
    #[cfg(not(debug_assertions))]
    pub fn audit_take(&mut self) -> Option<audit::AccessTrace> {
        None
    }

    #[cfg(debug_assertions)]
    fn audit_mark(&mut self, event: audit::AccessEvent) {
        if let Some(trace) = self.audit.as_mut() {
            trace.events.push(event);
        }
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn audit_mark(&mut self, _event: audit::AccessEvent) {}

    /// Record a read of the first `len` bytes of `id`, splitting out the
    /// sub-ranges that observed committed pre-action state (the read range
    /// minus spans staged earlier in this transaction, clipped to the
    /// committed length) — only those can feed a write-after-read hazard.
    #[cfg(debug_assertions)]
    fn audit_read(&mut self, id: KeyId, len: usize) {
        if self.audit.is_none() {
            return;
        }
        let slot = &self.slots[id.0 as usize];
        let climit = if slot.present {
            len.min(slot.committed.len())
        } else {
            0
        };
        let event = audit::AccessEvent::Read {
            key: slot.name.clone(),
            range: (0, len),
            committed: audit::subtract((0, climit), &slot.dirty),
            in_txn: self.txn_open,
        };
        self.audit.as_mut().unwrap().events.push(event);
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn audit_read(&mut self, _id: KeyId, _len: usize) {}

    /// Record a write of `range` bytes of `id` (`full` = whole-value
    /// overwrite, which replays cleanly and is exempt from WAR analysis).
    #[cfg(debug_assertions)]
    fn audit_write(&mut self, id: KeyId, range: (usize, usize), full: bool) {
        if self.audit.is_none() {
            return;
        }
        let event = audit::AccessEvent::Write {
            key: self.slots[id.0 as usize].name.clone(),
            range,
            full,
            in_txn: self.txn_open,
        };
        self.audit.as_mut().unwrap().events.push(event);
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn audit_write(&mut self, _id: KeyId, _range: (usize, usize), _full: bool) {}

    /// Record a commit-path flush persist step (key name cloned only
    /// when a trace is armed).
    #[cfg(debug_assertions)]
    fn audit_flush(&mut self, id: KeyId, bytes: usize) {
        if self.audit.is_none() {
            return;
        }
        let event = audit::AccessEvent::Flush {
            key: self.slots[id.0 as usize].name.clone(),
            bytes,
        };
        self.audit.as_mut().unwrap().events.push(event);
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn audit_flush(&mut self, _id: KeyId, _bytes: usize) {}

    /// Dead-device guard: after an injected power cut every NVM operation
    /// fails without mutating, preserving the torn durable state.
    #[inline]
    fn fault_check(&self) -> Result<()> {
        if self.fault.tripped() {
            return Err(Error::PowerCut);
        }
        Ok(())
    }

    /// Open an action transaction. Nested transactions are an error (an
    /// intermittent MCU runs one action at a time).
    pub fn begin_action(&mut self) -> Result<()> {
        self.fault_check()?;
        if self.txn_open {
            return Err(Error::Nvm("action already in flight".into()));
        }
        self.txn_open = true;
        self.staged_used = self.used;
        self.audit_mark(audit::AccessEvent::Begin);
        Ok(())
    }

    /// Persist steps 1..k of a commit: flush each staged slot's image to
    /// the durable redo area, in key-id order, appending to the durable
    /// flush log. Errors with [`Error::PowerCut`] if the injector cuts or
    /// tears a step (a tear logs the durable prefix length).
    fn persist_flushes(&mut self) -> Result<()> {
        for i in 0..self.txn_dirty.len() {
            let id = self.txn_dirty[i];
            let len = self.slots[id.0 as usize].staged.len();
            let outcome =
                self.fault
                    .on_step(StepKind::Flush, &self.slots[id.0 as usize].name, len);
            match outcome {
                StepOutcome::Run => {
                    self.journal.entries.push(JournalEntry { id: id.0, len, done: len });
                    self.audit_flush(id, len);
                }
                StepOutcome::Cut => return Err(Error::PowerCut),
                StepOutcome::Tear(done) => {
                    self.journal.entries.push(JournalEntry { id: id.0, len, done });
                    return Err(Error::PowerCut);
                }
            }
        }
        Ok(())
    }

    /// The final persist step of a commit: encode and durably write the
    /// checksummed commit record. The record names every slot the commit
    /// flushes (id + length) plus the committed-byte counter, and its
    /// FNV-1a checksum covers only the record bytes themselves — a torn
    /// record is detectable, flushed data is trusted.
    fn persist_record(&mut self) -> Result<()> {
        self.journal.staged_used = self.staged_used;
        let mut buf = std::mem::take(&mut self.journal.record_buf);
        buf.clear();
        buf.extend_from_slice(&(self.journal.seq + 1).to_le_bytes());
        buf.extend_from_slice(&(self.staged_used as u64).to_le_bytes());
        buf.extend_from_slice(&(self.txn_dirty.len() as u32).to_le_bytes());
        for id in &self.txn_dirty {
            buf.extend_from_slice(&id.0.to_le_bytes());
            buf.extend_from_slice(&(self.slots[id.0 as usize].staged.len() as u64).to_le_bytes());
        }
        let sum = fault::fnv1a(&buf);
        buf.extend_from_slice(&sum.to_le_bytes());
        let len = buf.len();
        self.journal.record_buf = buf;
        match self.fault.on_step(StepKind::Record, "<commit-record>", len) {
            StepOutcome::Run => {
                self.journal.record_done = Some(len);
                self.audit_mark(audit::AccessEvent::Record { bytes: len });
                Ok(())
            }
            StepOutcome::Cut => Err(Error::PowerCut),
            StepOutcome::Tear(done) => {
                self.journal.record_done = Some(done);
                Err(Error::PowerCut)
            }
        }
    }

    /// Commit the in-flight action's writes. A commit that staged nothing
    /// is RAM-only; a non-empty commit runs the persist-step protocol
    /// (flushes in key-id order, checksummed record last) and only then
    /// adopts the staged images — so a power failure at any point leaves
    /// a state [`Nvm::recover`] heals to a bit-exact commit boundary.
    pub fn commit_action(&mut self) -> Result<()> {
        self.fault_check()?;
        if !self.txn_open {
            return Err(Error::Nvm("commit without begin".into()));
        }
        if self.txn_dirty.is_empty() {
            // nothing staged: no durable work, no record
            self.txn_open = false;
            self.commits += 1;
            self.audit_mark(audit::AccessEvent::Commit);
            return Ok(());
        }
        // deterministic flush order, so a reference run and a cut run
        // enumerate identical persist steps
        self.txn_dirty.sort_unstable_by_key(|id| id.0);
        self.journal.clear();
        if self.record_first {
            // negative-control bug: record before flushes (wrong order)
            self.persist_record()?;
            self.persist_flushes()?;
        } else {
            self.persist_flushes()?;
            self.persist_record()?;
        }
        if self.cut_after_record {
            // fixture knob: the record is durable but the device dies
            // before the RAM-side adoption — roll-forward territory
            self.fault.force_trip();
            return Err(Error::PowerCut);
        }
        // the commit is durable; adopt the staged images (recovery
        // performs this exact adoption if power fails before we do)
        while let Some(id) = self.txn_dirty.pop() {
            let slot = &mut self.slots[id.0 as usize];
            if slot.staged_present {
                // swap, not copy: the displaced committed buffer becomes
                // the next transaction's staging capacity
                std::mem::swap(&mut slot.committed, &mut slot.staged);
                slot.present = true;
                slot.staged_present = false;
            }
            slot.dirty.clear();
        }
        self.used = self.staged_used;
        self.journal.clear();
        self.journal.seq += 1;
        self.txn_open = false;
        self.commits += 1;
        self.audit_mark(audit::AccessEvent::Commit);
        if self.digest_log.is_some() {
            let d = self.committed_digest();
            self.digest_log.as_mut().unwrap().push(d);
        }
        Ok(())
    }

    /// Discard the in-flight action's writes (power failure mid-action).
    /// A no-op on a dead (fault-tripped) device: post-cut cleanup must
    /// not destroy the torn evidence recovery inspects.
    pub fn abort_action(&mut self) {
        if self.fault.tripped() || !self.txn_open {
            return;
        }
        while let Some(id) = self.txn_dirty.pop() {
            let slot = &mut self.slots[id.0 as usize];
            slot.staged_present = false;
            slot.dirty.clear();
        }
        self.staged_used = self.used;
        self.txn_open = false;
        self.aborts += 1;
        self.audit_mark(audit::AccessEvent::Abort);
    }

    /// Model the volatile loss of a host reboot after a power cut: the
    /// open transaction's RAM bookkeeping and any staged image the
    /// interrupted commit did **not** completely flush disappear; what
    /// reached durable media — committed values, fully-flushed redo
    /// images, the flush log and (possibly torn) commit record — stays.
    /// Also quiets the injector ([`FaultInjector::reboot`]). Call
    /// [`Nvm::recover`] next to heal the interrupted commit.
    pub fn power_failure_reset(&mut self) {
        while let Some(id) = self.txn_dirty.pop() {
            let complete = self
                .journal
                .entries
                .iter()
                .any(|e| e.id == id.0 && e.done == e.len);
            let slot = &mut self.slots[id.0 as usize];
            if !complete {
                slot.staged.clear();
                slot.staged_present = false;
            }
            slot.dirty.clear();
        }
        self.txn_open = false;
        self.staged_used = self.used;
        self.fault.reboot();
    }

    /// Crash recovery: inspect the commit journal a power failure left
    /// behind and heal the store to an exact commit boundary. A valid
    /// commit record rolls the interrupted commit **forward** (every
    /// recorded slot's flushed image is adopted, exactly as
    /// `commit_action` would have); a missing or torn record rolls it
    /// **back** (flushed images are discarded; the pre-transaction
    /// committed image stands untouched). Idempotent, and [`Recovery::
    /// Clean`] on a store with no interrupted commit — callers run it
    /// unconditionally at boot, before restoring learners or run state.
    pub fn recover(&mut self) -> Recovery {
        if !self.journal.dirty() {
            return Recovery::Clean;
        }
        if self.journal.record_valid() {
            // roll forward: replay the recorded entry set from the redo
            // area. The record is trusted (its checksum proved it whole);
            // if a recorded slot was never flushed — only possible under
            // a wrong-order commit bug — garbage is adopted, which is
            // precisely the corruption the crash sweep exists to catch.
            let buf = std::mem::take(&mut self.journal.record_buf);
            let n = u32::from_le_bytes(buf[16..20].try_into().unwrap()) as usize;
            for e in 0..n {
                let at = 20 + e * 12;
                let id = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap()) as usize;
                if let Some(slot) = self.slots.get_mut(id) {
                    std::mem::swap(&mut slot.committed, &mut slot.staged);
                    slot.present = true;
                    slot.staged_present = false;
                    slot.dirty.clear();
                }
            }
            self.journal.record_buf = buf;
            self.used = self.journal.staged_used;
            self.staged_used = self.used;
            self.journal.clear();
            self.journal.seq += 1;
            self.commits += 1;
            self.audit_mark(audit::AccessEvent::Heal { rolled_back: false });
            Recovery::RolledForward
        } else {
            // roll back: discard the flushed images; committed is the
            // pre-transaction image and was never touched by the commit
            for i in 0..self.journal.entries.len() {
                let id = self.journal.entries[i].id as usize;
                if let Some(slot) = self.slots.get_mut(id) {
                    slot.staged_present = false;
                    slot.dirty.clear();
                }
            }
            self.journal.clear();
            self.staged_used = self.used;
            self.aborts += 1;
            self.audit_mark(audit::AccessEvent::Heal { rolled_back: true });
            Recovery::RolledBack
        }
    }

    /// The store's power-failure injector (disarmed by default).
    pub fn fault(&self) -> &FaultInjector {
        &self.fault
    }

    /// Mutable injector access: arm fault points, start step traces.
    pub fn fault_mut(&mut self) -> &mut FaultInjector {
        &mut self.fault
    }

    /// FNV-1a fingerprint of the committed (durable, post-recovery)
    /// image: every interned key's name, presence, and committed bytes,
    /// in name order. Staged state, counters, and capacity are excluded —
    /// this is the durability fingerprint the crash sweep compares.
    pub fn committed_digest(&self) -> u64 {
        let mut h = fault::Fnv64::new();
        for (name, &id) in &self.index {
            let slot = &self.slots[id.0 as usize];
            h.update(name.as_bytes());
            h.update(&[0xff, slot.present as u8]);
            if slot.present {
                h.update(&(slot.committed.len() as u64).to_le_bytes());
                h.update(&slot.committed);
            }
        }
        h.finish()
    }

    /// Arm the reference-mode digest log: the current committed digest
    /// is recorded immediately, then again after every journaled
    /// (non-empty) commit — `log[k]` is the committed image after `k`
    /// durable commit records, the oracle a cut run's recovered digest
    /// must land on.
    pub fn start_digest_log(&mut self) {
        let d = self.committed_digest();
        self.digest_log = Some(vec![d]);
    }

    /// Take the digest log (`None` if never armed).
    pub fn take_digest_log(&mut self) -> Option<Vec<u64>> {
        self.digest_log.take()
    }

    /// Negative-control bug knob (crash-sweep self-test only): write the
    /// commit record *before* the slot flushes — the classic wrong-order
    /// bug the sweep must catch. Never set outside tests.
    #[doc(hidden)]
    pub fn debug_commit_record_first(&mut self, on: bool) {
        self.record_first = on;
    }

    /// Fixture knob: die right after the commit record becomes durable,
    /// before the RAM-side adoption — the one torn state only
    /// roll-forward recovery can reach. Never set outside tests.
    #[doc(hidden)]
    pub fn debug_cut_after_record(&mut self, on: bool) {
        self.cut_after_record = on;
    }

    /// Fixture knob: flip a bit of the in-flight commit record (medium
    /// decay / checksum corruption). Never call outside tests.
    #[doc(hidden)]
    pub fn debug_corrupt_record(&mut self) {
        if let Some(b) = self.journal.record_buf.last_mut() {
            *b ^= 0x01;
        }
    }

    /// Reset this store for reuse by a new logical device (the pooled
    /// slab arena, [`arena::NvmArena`]). Every committed and staged
    /// value disappears — reads behave exactly like a fresh store
    /// (resolved keys read as absent) — while the interned key table
    /// and every slot's buffer capacity survive, so a recycled slab
    /// re-runs a shard without re-growing what the previous shard
    /// already allocated. The store takes a fresh [`Nvm::store_id`]
    /// (handle caches keyed on it re-intern instead of aliasing) and
    /// zeroes its traffic counters; an open action is discarded along
    /// with everything else.
    pub fn reset_for_reuse(&mut self) {
        for slot in &mut self.slots {
            slot.committed.clear();
            slot.present = false;
            slot.staged.clear();
            slot.staged_present = false;
            slot.dirty.clear();
        }
        self.txn_open = false;
        self.txn_dirty.clear();
        self.used = 0;
        self.staged_used = 0;
        self.bytes_written = 0;
        self.bytes_read = 0;
        self.commits = 0;
        self.aborts = 0;
        self.journal.clear();
        self.journal.seq = 0;
        self.journal.record_buf.clear();
        self.fault = FaultInjector::default();
        self.digest_log = None;
        self.record_first = false;
        self.cut_after_record = false;
        self.store_id = NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed);
        #[cfg(debug_assertions)]
        {
            self.audit = None;
        }
    }

    /// Is an action transaction open?
    pub fn in_action(&self) -> bool {
        self.txn_open
    }

    /// Committed bytes (O(1) — a running counter, not a rescan).
    pub fn used_bytes(&self) -> usize {
        self.used
    }

    /// Length of the value visible at `id` (staged, else committed).
    pub fn value_len(&self, id: KeyId) -> Option<usize> {
        let slot = self.slots.get(id.0 as usize)?;
        if slot.staged_present {
            Some(slot.staged.len())
        } else if slot.present {
            Some(slot.committed.len())
        } else {
            None
        }
    }

    /// Dirty byte ranges staged on `id` by the open transaction.
    pub fn staged_dirty(&self, id: KeyId) -> &[(usize, usize)] {
        self.slots
            .get(id.0 as usize)
            .map(|s| s.dirty.as_slice())
            .unwrap_or(&[])
    }

    /// O(1) capacity check for a write that leaves `id` at `new_len`.
    fn check_capacity(&self, id: KeyId, new_len: usize) -> Result<()> {
        if self.capacity == 0 {
            return Ok(());
        }
        let slot = &self.slots[id.0 as usize];
        let base = if self.txn_open {
            self.staged_used
        } else {
            self.used
        };
        let total = base - slot.pending_len() + new_len;
        if total > self.capacity {
            return Err(Error::Nvm(format!(
                "capacity exceeded writing `{}` ({} B used of {} B)",
                slot.name, base, self.capacity
            )));
        }
        Ok(())
    }

    /// Bookkeep a write that left a slot at `new_len` (from `old_len`),
    /// `dirtied` bytes of which were actually written (charged as NVM
    /// traffic).
    fn account_write(&mut self, old_len: usize, new_len: usize, dirtied: usize) {
        self.bytes_written += dirtied as u64;
        if self.txn_open {
            self.staged_used = self.staged_used - old_len + new_len;
        } else {
            self.used = self.used - old_len + new_len;
        }
    }

    /// Mark `id` staged in the open transaction (idempotent).
    fn mark_staged(&mut self, id: KeyId) {
        let slot = &mut self.slots[id.0 as usize];
        if !slot.staged_present {
            slot.staged_present = true;
            self.txn_dirty.push(id);
        }
    }

    /// Full-value write through a handle. Inside an action the write is
    /// staged; outside (framework bookkeeping, e.g. at boot) it commits
    /// immediately. Allocation-free once the slot's buffers have grown.
    pub fn write_id(&mut self, id: KeyId, bytes: &[u8]) -> Result<()> {
        self.fault_check()?;
        self.slot(id)?;
        self.check_capacity(id, bytes.len())?;
        let old_len = self.slots[id.0 as usize].pending_len();
        if self.txn_open {
            {
                let slot = &mut self.slots[id.0 as usize];
                slot.staged.clear();
                slot.staged.extend_from_slice(bytes);
                // a full overwrite supersedes any earlier staged ranges
                slot.dirty.clear();
                slot.dirty.push((0, bytes.len()));
            }
            self.mark_staged(id);
        } else {
            let slot = &mut self.slots[id.0 as usize];
            slot.committed.clear();
            slot.committed.extend_from_slice(bytes);
            slot.present = true;
        }
        self.account_write(old_len, bytes.len(), bytes.len());
        self.audit_write(id, (0, bytes.len()), true);
        Ok(())
    }

    /// Range write through a handle: overwrite `bytes` starting at byte
    /// `offset`, extending the value (zero-filled) if needed. Only the
    /// written span is charged as NVM traffic — the delta-checkpoint
    /// primitive. Inside an action, the first touch of a slot seeds the
    /// staging buffer from the committed value (read-your-writes), and the
    /// dirty span is recorded per slot.
    pub fn write_at(&mut self, id: KeyId, offset: usize, bytes: &[u8]) -> Result<()> {
        self.fault_check()?;
        self.slot(id)?;
        let end = offset + bytes.len();
        let old_len = self.slots[id.0 as usize].pending_len();
        let new_len = old_len.max(end);
        self.check_capacity(id, new_len)?;
        if self.txn_open {
            {
                let slot = &mut self.slots[id.0 as usize];
                if !slot.staged_present {
                    slot.staged.clear();
                    if slot.present {
                        slot.staged.extend_from_slice(&slot.committed);
                    }
                }
                if slot.staged.len() < end {
                    slot.staged.resize(end, 0);
                }
                slot.staged[offset..end].copy_from_slice(bytes);
                slot.dirty.push((offset, end));
            }
            self.mark_staged(id);
        } else {
            let slot = &mut self.slots[id.0 as usize];
            if slot.committed.len() < end {
                slot.committed.resize(end, 0);
            }
            slot.committed[offset..end].copy_from_slice(bytes);
            slot.present = true;
        }
        self.account_write(old_len, new_len, bytes.len());
        self.audit_write(id, (offset, end), false);
        Ok(())
    }

    /// Borrowing read with read-your-writes semantics (no clone).
    /// Reads nothing (and charges nothing) on a dead device.
    pub fn read_id(&mut self, id: KeyId) -> Option<&[u8]> {
        if self.fault.tripped() {
            return None;
        }
        let slot = self.slots.get(id.0 as usize)?;
        let len = if slot.staged_present {
            slot.staged.len()
        } else if slot.present {
            slot.committed.len()
        } else {
            return None;
        };
        self.bytes_read += len as u64;
        self.audit_read(id, len);
        let slot = &self.slots[id.0 as usize];
        Some(if slot.staged_present {
            &slot.staged
        } else {
            &slot.committed
        })
    }

    /// Committed bytes at `id`, bypassing staging, read accounting, and
    /// the audit recorder — the analyzer's twin-comparison peek.
    pub fn committed_id(&self, id: KeyId) -> Option<&[u8]> {
        let slot = self.slots.get(id.0 as usize)?;
        slot.present.then_some(slot.committed.as_slice())
    }

    /// Iterate every interned key with its handle, in name order.
    pub fn keys(&self) -> impl Iterator<Item = (&str, KeyId)> + '_ {
        self.index.iter().map(|(k, &id)| (k.as_str(), id))
    }

    /// Does a committed or staged value exist at `id`?
    pub fn contains_id(&self, id: KeyId) -> bool {
        self.slots
            .get(id.0 as usize)
            .map(|s| s.staged_present || s.present)
            .unwrap_or(false)
    }

    // ---- typed handle helpers ------------------------------------------

    /// Write an f32 slice through a handle (full value).
    pub fn write_f32s_id(&mut self, id: KeyId, xs: &[f32]) -> Result<()> {
        self.fault_check()?;
        self.slot(id)?;
        let new_len = xs.len() * 4;
        self.check_capacity(id, new_len)?;
        let old_len = self.slots[id.0 as usize].pending_len();
        if self.txn_open {
            {
                let slot = &mut self.slots[id.0 as usize];
                slot.staged.clear();
                for x in xs {
                    slot.staged.extend_from_slice(&x.to_le_bytes());
                }
                // a full overwrite supersedes any earlier staged ranges
                slot.dirty.clear();
                slot.dirty.push((0, new_len));
            }
            self.mark_staged(id);
        } else {
            let slot = &mut self.slots[id.0 as usize];
            slot.committed.clear();
            for x in xs {
                slot.committed.extend_from_slice(&x.to_le_bytes());
            }
            slot.present = true;
        }
        self.account_write(old_len, new_len, new_len);
        self.audit_write(id, (0, new_len), true);
        Ok(())
    }

    /// Range write of f32s at *element* offset `at` (the dirty-slot
    /// delta-checkpoint primitive: one ring row, one cluster row).
    pub fn write_f32s_at(&mut self, id: KeyId, at: usize, xs: &[f32]) -> Result<()> {
        self.fault_check()?;
        self.slot(id)?;
        let offset = at * 4;
        let end = offset + xs.len() * 4;
        let old_len = self.slots[id.0 as usize].pending_len();
        let new_len = old_len.max(end);
        self.check_capacity(id, new_len)?;
        if self.txn_open {
            {
                let slot = &mut self.slots[id.0 as usize];
                if !slot.staged_present {
                    slot.staged.clear();
                    if slot.present {
                        slot.staged.extend_from_slice(&slot.committed);
                    }
                }
                if slot.staged.len() < end {
                    slot.staged.resize(end, 0);
                }
                for (i, x) in xs.iter().enumerate() {
                    slot.staged[offset + i * 4..offset + i * 4 + 4]
                        .copy_from_slice(&x.to_le_bytes());
                }
                slot.dirty.push((offset, end));
            }
            self.mark_staged(id);
        } else {
            let slot = &mut self.slots[id.0 as usize];
            if slot.committed.len() < end {
                slot.committed.resize(end, 0);
            }
            for (i, x) in xs.iter().enumerate() {
                slot.committed[offset + i * 4..offset + i * 4 + 4]
                    .copy_from_slice(&x.to_le_bytes());
            }
            slot.present = true;
        }
        self.account_write(old_len, new_len, xs.len() * 4);
        self.audit_write(id, (offset, end), false);
        Ok(())
    }

    /// Decode the value at `id` into `out` without allocating. Returns
    /// `false` (leaving `out` untouched, charging no read) unless a value
    /// of exactly `out.len()` f32s exists.
    pub fn read_f32s_into(&mut self, id: KeyId, out: &mut [f32]) -> bool {
        if self.fault.tripped() || self.value_len(id) != Some(out.len() * 4) {
            return false;
        }
        self.bytes_read += (out.len() * 4) as u64;
        self.audit_read(id, out.len() * 4);
        let slot = &self.slots[id.0 as usize];
        let bytes: &[u8] = if slot.staged_present {
            &slot.staged
        } else {
            &slot.committed
        };
        for (i, c) in bytes.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        true
    }

    /// Read an f32 slice through a handle (allocating convenience).
    pub fn read_f32s_id(&mut self, id: KeyId) -> Option<Vec<f32>> {
        let bytes = self.read_id(id)?;
        Some(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        )
    }

    /// Write a u64 counter through a handle.
    pub fn write_u64_id(&mut self, id: KeyId, v: u64) -> Result<()> {
        self.write_id(id, &v.to_le_bytes())
    }

    /// Read a u64 counter through a handle (0 if absent).
    pub fn read_u64_id(&mut self, id: KeyId) -> u64 {
        match self.read_id(id) {
            Some(b) if b.len() == 8 => u64::from_le_bytes(b.try_into().unwrap()),
            _ => 0,
        }
    }

    // ---- string-keyed compatibility API --------------------------------

    /// Raw write by string key (interns; prefer [`Nvm::write_id`] on hot
    /// paths).
    pub fn write(&mut self, key: &str, bytes: &[u8]) -> Result<()> {
        let id = self.intern(key);
        self.write_id(id, bytes)
    }

    /// Raw read by string key with read-your-writes semantics (clones;
    /// prefer [`Nvm::read_id`] / [`Nvm::read_f32s_into`] on hot paths).
    pub fn read(&mut self, key: &str) -> Option<Vec<u8>> {
        let id = self.resolve(key)?;
        self.read_id(id).map(|b| b.to_vec())
    }

    /// Does a committed or staged value exist?
    pub fn contains(&self, key: &str) -> bool {
        self.resolve(key).map(|id| self.contains_id(id)).unwrap_or(false)
    }

    /// Write an f32 slice.
    pub fn write_f32s(&mut self, key: &str, xs: &[f32]) -> Result<()> {
        let id = self.intern(key);
        self.write_f32s_id(id, xs)
    }

    /// Read an f32 slice.
    pub fn read_f32s(&mut self, key: &str) -> Option<Vec<f32>> {
        let id = self.resolve(key)?;
        self.read_f32s_id(id)
    }

    /// Write a u64 counter.
    pub fn write_u64(&mut self, key: &str, v: u64) -> Result<()> {
        let id = self.intern(key);
        self.write_u64_id(id, v)
    }

    /// Read a u64 counter (0 if absent).
    pub fn read_u64(&mut self, key: &str) -> u64 {
        match self.resolve(key) {
            Some(id) => self.read_u64_id(id),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn committed_writes_survive() {
        let mut nvm = Nvm::new();
        nvm.write_f32s("w", &[1.0, 2.0]).unwrap();
        assert_eq!(nvm.read_f32s("w").unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn abort_discards_staged_writes() {
        let mut nvm = Nvm::new();
        nvm.write_f32s("model", &[1.0]).unwrap();
        nvm.begin_action().unwrap();
        nvm.write_f32s("model", &[9.0]).unwrap();
        // read-your-writes inside the action
        assert_eq!(nvm.read_f32s("model").unwrap(), vec![9.0]);
        nvm.abort_action(); // power failure
        assert_eq!(nvm.read_f32s("model").unwrap(), vec![1.0]);
        assert_eq!(nvm.aborts, 1);
    }

    #[test]
    fn commit_publishes_staged_writes() {
        let mut nvm = Nvm::new();
        nvm.begin_action().unwrap();
        nvm.write_u64("count", 7).unwrap();
        nvm.commit_action().unwrap();
        assert_eq!(nvm.read_u64("count"), 7);
        assert_eq!(nvm.commits, 1);
    }

    #[test]
    fn nested_begin_rejected() {
        let mut nvm = Nvm::new();
        nvm.begin_action().unwrap();
        assert!(nvm.begin_action().is_err());
    }

    #[test]
    fn commit_without_begin_rejected() {
        let mut nvm = Nvm::new();
        assert!(nvm.commit_action().is_err());
    }

    #[test]
    fn capacity_enforced() {
        let mut nvm = Nvm::with_capacity(8);
        nvm.write_f32s("a", &[1.0, 2.0]).unwrap(); // 8 bytes
        assert!(nvm.write_f32s("b", &[3.0]).is_err());
        // overwriting the same key with the same size is fine
        nvm.write_f32s("a", &[4.0, 5.0]).unwrap();
        assert_eq!(nvm.used_bytes(), 8);
    }

    #[test]
    fn capacity_counts_staged_shrinkage() {
        // a staged shrink of one key frees budget for another in the same
        // transaction (the running staged counter is exact, not the old
        // committed-only rescan)
        let mut nvm = Nvm::with_capacity(8);
        nvm.write_f32s("a", &[1.0, 2.0]).unwrap();
        nvm.begin_action().unwrap();
        nvm.write_f32s("a", &[1.0]).unwrap();
        nvm.write_f32s("b", &[2.0]).unwrap();
        nvm.commit_action().unwrap();
        assert_eq!(nvm.used_bytes(), 8);
    }

    #[test]
    fn accounting_counts_bytes() {
        let mut nvm = Nvm::new();
        nvm.write_f32s("x", &[0.0; 4]).unwrap();
        nvm.read_f32s("x");
        assert_eq!(nvm.bytes_written, 16);
        assert_eq!(nvm.bytes_read, 16);
    }

    #[test]
    fn missing_counter_defaults_zero() {
        let mut nvm = Nvm::new();
        assert_eq!(nvm.read_u64("nope"), 0);
    }

    #[test]
    fn interned_handles_round_trip() {
        let mut nvm = Nvm::new();
        let id = nvm.intern("model/w");
        assert_eq!(nvm.intern("model/w"), id); // stable
        nvm.write_f32s_id(id, &[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(nvm.read_f32s_id(id).unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(nvm.resolve("model/w"), Some(id));
        assert_eq!(nvm.resolve("other"), None);
        // the string API sees the same slot
        assert_eq!(nvm.read_f32s("model/w").unwrap(), vec![1.0, 2.0, 3.0]);
        let mut out = [0.0f32; 3];
        assert!(nvm.read_f32s_into(id, &mut out));
        assert_eq!(out, [1.0, 2.0, 3.0]);
        // size mismatch leaves the output untouched
        let mut wrong = [9.0f32; 2];
        assert!(!nvm.read_f32s_into(id, &mut wrong));
        assert_eq!(wrong, [9.0, 9.0]);
    }

    #[test]
    fn range_writes_charge_only_the_dirty_span() {
        let mut nvm = Nvm::new();
        let id = nvm.intern("buf");
        nvm.write_f32s_id(id, &[0.0; 16]).unwrap(); // 64 B
        let before = nvm.bytes_written;
        nvm.write_f32s_at(id, 4, &[1.0, 2.0]).unwrap(); // 8 B dirty
        assert_eq!(nvm.bytes_written - before, 8);
        let got = nvm.read_f32s_id(id).unwrap();
        assert_eq!(got.len(), 16);
        assert_eq!(&got[4..6], &[1.0, 2.0]);
        assert!(got[..4].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn staged_range_write_rolls_back_and_records_dirty_ranges() {
        let mut nvm = Nvm::new();
        let id = nvm.intern("buf");
        nvm.write_f32s_id(id, &[0.0; 8]).unwrap();
        nvm.begin_action().unwrap();
        nvm.write_f32s_at(id, 2, &[5.0]).unwrap();
        nvm.write_f32s_at(id, 6, &[7.0]).unwrap();
        assert_eq!(nvm.staged_dirty(id), &[(8, 12), (24, 28)][..]);
        // read-your-writes sees the merged view
        let merged = nvm.read_f32s_id(id).unwrap();
        assert_eq!(merged[2], 5.0);
        assert_eq!(merged[6], 7.0);
        assert_eq!(merged[0], 0.0);
        nvm.abort_action();
        assert!(nvm.staged_dirty(id).is_empty());
        assert!(nvm.read_f32s_id(id).unwrap().iter().all(|&v| v == 0.0));
        // and a committed range write lands
        nvm.begin_action().unwrap();
        nvm.write_f32s_at(id, 3, &[9.0]).unwrap();
        nvm.commit_action().unwrap();
        assert_eq!(nvm.read_f32s_id(id).unwrap()[3], 9.0);
    }

    #[test]
    fn range_write_extends_with_zero_fill() {
        let mut nvm = Nvm::new();
        let id = nvm.intern("grow");
        nvm.write_f32s_at(id, 2, &[1.0]).unwrap();
        assert_eq!(nvm.read_f32s_id(id).unwrap(), vec![0.0, 0.0, 1.0]);
        assert_eq!(nvm.used_bytes(), 12);
    }

    #[test]
    fn used_bytes_tracks_commit_and_abort() {
        let mut nvm = Nvm::new();
        nvm.write("a", &[0; 10]).unwrap();
        assert_eq!(nvm.used_bytes(), 10);
        nvm.begin_action().unwrap();
        nvm.write("a", &[0; 4]).unwrap();
        nvm.write("b", &[0; 6]).unwrap();
        assert_eq!(nvm.used_bytes(), 10, "committed view until commit");
        nvm.commit_action().unwrap();
        assert_eq!(nvm.used_bytes(), 10); // 4 + 6
        nvm.begin_action().unwrap();
        nvm.write("c", &[0; 100]).unwrap();
        nvm.abort_action();
        assert_eq!(nvm.used_bytes(), 10);
        assert!(!nvm.contains("c"));
    }

    #[test]
    fn audit_records_brackets_reads_and_writes() {
        use audit::AccessEvent;
        let mut nvm = Nvm::new();
        let id = nvm.intern("buf");
        nvm.write_f32s_id(id, &[0.0; 4]).unwrap(); // pre-trace: not recorded
        nvm.audit_start();
        nvm.begin_action().unwrap();
        nvm.write_f32s_at(id, 1, &[5.0]).unwrap();
        nvm.read_f32s_id(id).unwrap();
        nvm.commit_action().unwrap();
        let trace = nvm.audit_take().unwrap();
        assert_eq!(trace.events.len(), 6, "{:?}", trace.events);
        assert_eq!(trace.events[0], AccessEvent::Begin);
        assert_eq!(
            trace.events[1],
            AccessEvent::Write {
                key: "buf".into(),
                range: (4, 8),
                full: false,
                in_txn: true
            }
        );
        // the read observes committed bytes everywhere except the staged span
        assert_eq!(
            trace.events[2],
            AccessEvent::Read {
                key: "buf".into(),
                range: (0, 16),
                committed: vec![(0, 4), (8, 16)],
                in_txn: true
            }
        );
        // the commit's persist steps: one slot flush, then the record
        assert_eq!(
            trace.events[3],
            AccessEvent::Flush {
                key: "buf".into(),
                bytes: 16
            }
        );
        assert!(matches!(trace.events[4], AccessEvent::Record { .. }));
        assert_eq!(trace.events[5], AccessEvent::Commit);
        // taking the trace disarms the recorder
        nvm.read_f32s_id(id).unwrap();
        assert!(nvm.audit_take().is_none());
    }

    #[test]
    fn audit_marks_full_overwrites_and_untransacted_writes() {
        use audit::AccessEvent;
        let mut nvm = Nvm::new();
        let id = nvm.intern("gen");
        nvm.audit_start();
        nvm.write_u64_id(id, 3).unwrap(); // outside any transaction
        let trace = nvm.audit_take().unwrap();
        assert_eq!(
            trace.events[0],
            AccessEvent::Write {
                key: "gen".into(),
                range: (0, 8),
                full: true,
                in_txn: false
            }
        );
    }

    #[test]
    fn committed_peek_bypasses_staging_and_accounting() {
        let mut nvm = Nvm::new();
        let id = nvm.intern("x");
        nvm.write_id(id, &[1, 2]).unwrap();
        nvm.begin_action().unwrap();
        nvm.write_id(id, &[9, 9]).unwrap();
        let before = nvm.bytes_read;
        assert_eq!(nvm.committed_id(id), Some(&[1u8, 2][..]));
        assert_eq!(nvm.bytes_read, before);
        nvm.abort_action();
        let keys: Vec<&str> = nvm.keys().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["x"]);
    }

    // ---- crash consistency: torn commits, detect-and-heal ---------------

    use crate::fault::FaultPoint;

    #[test]
    fn empty_commits_are_ram_only_with_no_persist_steps() {
        let mut nvm = Nvm::new();
        nvm.fault_mut().start_trace();
        nvm.begin_action().unwrap();
        nvm.commit_action().unwrap();
        assert!(nvm.fault_mut().take_trace().unwrap().is_empty());
        assert_eq!(nvm.fault().records_done(), 0);
        assert_eq!(nvm.commits, 1);
    }

    #[test]
    fn boundary_cut_before_any_flush_heals_to_the_pre_txn_image() {
        let mut nvm = Nvm::new();
        nvm.write("a", &[1, 2, 3]).unwrap();
        nvm.write("b", &[4, 5]).unwrap();
        let before = nvm.committed_digest();
        nvm.begin_action().unwrap();
        nvm.write("a", &[9, 9, 9]).unwrap();
        nvm.write("b", &[8, 8]).unwrap();
        nvm.fault_mut().arm(FaultPoint::Boundary(0));
        assert!(matches!(nvm.commit_action(), Err(Error::PowerCut)));
        // dead until reboot: no op mutates, reads see nothing
        assert!(matches!(nvm.begin_action(), Err(Error::PowerCut)));
        assert!(nvm.read("a").is_none());
        nvm.abort_action(); // post-cut cleanup must not destroy evidence
        nvm.power_failure_reset();
        assert_eq!(nvm.recover(), Recovery::RolledBack);
        assert_eq!(nvm.committed_digest(), before);
        assert_eq!(nvm.read("a").unwrap(), vec![1, 2, 3]);
        assert_eq!(nvm.read("b").unwrap(), vec![4, 5]);
        // fully usable after the heal
        nvm.begin_action().unwrap();
        nvm.write("a", &[7]).unwrap();
        nvm.commit_action().unwrap();
        assert_eq!(nvm.read("a").unwrap(), vec![7]);
    }

    #[test]
    fn missing_commit_record_rolls_back_flushed_slots() {
        // cut at the record step: both slots flushed durably, record absent
        let mut nvm = Nvm::new();
        nvm.write("a", &[1; 4]).unwrap();
        nvm.write("b", &[2; 4]).unwrap();
        let before = nvm.committed_digest();
        nvm.begin_action().unwrap();
        nvm.write("a", &[7; 4]).unwrap();
        nvm.write("b", &[8; 4]).unwrap();
        nvm.fault_mut().arm(FaultPoint::Boundary(2)); // steps: flush a, flush b, record
        assert!(matches!(nvm.commit_action(), Err(Error::PowerCut)));
        nvm.power_failure_reset();
        assert_eq!(nvm.recover(), Recovery::RolledBack);
        assert_eq!(nvm.committed_digest(), before);
        assert_eq!(nvm.read("a").unwrap(), vec![1; 4]);
    }

    #[test]
    fn torn_slot_flush_rolls_back() {
        let mut nvm = Nvm::new();
        nvm.write("buf", &[0; 8]).unwrap();
        let before = nvm.committed_digest();
        nvm.begin_action().unwrap();
        nvm.write("buf", &[9; 8]).unwrap();
        nvm.fault_mut().arm(FaultPoint::Tear { step: 0, offset: 3 });
        assert!(matches!(nvm.commit_action(), Err(Error::PowerCut)));
        nvm.power_failure_reset();
        assert_eq!(nvm.recover(), Recovery::RolledBack);
        assert_eq!(nvm.committed_digest(), before);
        assert_eq!(nvm.read("buf").unwrap(), vec![0; 8]);
    }

    #[test]
    fn torn_commit_record_rolls_back() {
        let mut nvm = Nvm::new();
        nvm.write("x", &[1]).unwrap();
        let before = nvm.committed_digest();
        nvm.begin_action().unwrap();
        nvm.write("x", &[2]).unwrap();
        nvm.fault_mut().arm(FaultPoint::Tear { step: 1, offset: 10 });
        assert!(matches!(nvm.commit_action(), Err(Error::PowerCut)));
        nvm.power_failure_reset();
        assert_eq!(nvm.recover(), Recovery::RolledBack);
        assert_eq!(nvm.committed_digest(), before);
        assert_eq!(nvm.read("x").unwrap(), vec![1]);
    }

    #[test]
    fn corrupted_record_checksum_rolls_back() {
        // record fully written, then the medium decays a record byte:
        // the checksum catches it and the commit is discarded whole
        let mut nvm = Nvm::new();
        nvm.write("x", &[1]).unwrap();
        let before = nvm.committed_digest();
        nvm.debug_cut_after_record(true);
        nvm.begin_action().unwrap();
        nvm.write("x", &[2]).unwrap();
        assert!(matches!(nvm.commit_action(), Err(Error::PowerCut)));
        nvm.debug_corrupt_record();
        nvm.power_failure_reset();
        assert_eq!(nvm.recover(), Recovery::RolledBack);
        assert_eq!(nvm.committed_digest(), before);
        assert_eq!(nvm.read("x").unwrap(), vec![1]);
    }

    #[test]
    fn cut_after_record_rolls_forward_to_the_committed_image() {
        let mut nvm = Nvm::new();
        nvm.write("x", &[1]).unwrap();
        let mut twin = nvm.clone();
        nvm.debug_cut_after_record(true);
        nvm.begin_action().unwrap();
        nvm.write("x", &[2]).unwrap();
        assert!(matches!(nvm.commit_action(), Err(Error::PowerCut)));
        nvm.debug_cut_after_record(false);
        nvm.power_failure_reset();
        assert_eq!(nvm.recover(), Recovery::RolledForward);
        // bit-identical to a twin whose commit was never interrupted
        twin.begin_action().unwrap();
        twin.write("x", &[2]).unwrap();
        twin.commit_action().unwrap();
        assert_eq!(nvm.committed_digest(), twin.committed_digest());
        assert_eq!(nvm.read("x").unwrap(), vec![2]);
        assert_eq!(nvm.used_bytes(), twin.used_bytes());
    }

    #[test]
    fn record_first_bug_corrupts_the_roll_forward() {
        // negative control: a wrong-order commit (record before flushes)
        // leaves a valid record over unflushed data — recovery trusts the
        // record and adopts garbage, which digests must expose
        let mut nvm = Nvm::new();
        nvm.write("a", &[1; 4]).unwrap();
        nvm.write("b", &[2; 4]).unwrap();
        let mut twin = nvm.clone();
        nvm.debug_commit_record_first(true);
        nvm.begin_action().unwrap();
        nvm.write("a", &[7; 4]).unwrap();
        nvm.write("b", &[8; 4]).unwrap();
        nvm.fault_mut().arm(FaultPoint::Boundary(1)); // record ran (step 0), cut first flush
        assert!(matches!(nvm.commit_action(), Err(Error::PowerCut)));
        nvm.power_failure_reset();
        assert_eq!(nvm.recover(), Recovery::RolledForward);
        twin.begin_action().unwrap();
        twin.write("a", &[7; 4]).unwrap();
        twin.write("b", &[8; 4]).unwrap();
        twin.commit_action().unwrap();
        assert_ne!(
            nvm.committed_digest(),
            twin.committed_digest(),
            "the seeded wrong-order bug must corrupt the store"
        );
    }

    #[test]
    fn recover_is_clean_on_healthy_stores_and_idempotent_after_a_heal() {
        let mut nvm = Nvm::new();
        assert_eq!(nvm.recover(), Recovery::Clean);
        nvm.begin_action().unwrap();
        nvm.write("x", &[5]).unwrap();
        nvm.commit_action().unwrap();
        assert_eq!(nvm.recover(), Recovery::Clean);
        nvm.begin_action().unwrap();
        nvm.write("x", &[6]).unwrap();
        let next = nvm.fault().steps_seen();
        nvm.fault_mut().arm(FaultPoint::Boundary(next));
        assert!(nvm.commit_action().is_err());
        nvm.power_failure_reset();
        assert_eq!(nvm.recover(), Recovery::RolledBack);
        assert_eq!(nvm.recover(), Recovery::Clean);
        assert_eq!(nvm.read("x").unwrap(), vec![5]);
    }

    #[test]
    fn digest_log_records_one_digest_per_journaled_commit() {
        let mut nvm = Nvm::new();
        nvm.write("x", &[1]).unwrap();
        nvm.start_digest_log();
        nvm.begin_action().unwrap();
        nvm.commit_action().unwrap(); // empty commit: no entry
        nvm.begin_action().unwrap();
        nvm.write("x", &[2]).unwrap();
        nvm.commit_action().unwrap();
        nvm.begin_action().unwrap();
        nvm.write("x", &[3]).unwrap();
        nvm.commit_action().unwrap();
        let log = nvm.take_digest_log().unwrap();
        assert_eq!(log.len(), 3, "initial + 2 journaled commits");
        assert_eq!(log[2], nvm.committed_digest());
        assert_ne!(log[0], log[1]);
        assert_eq!(nvm.fault().records_done(), 2);
        assert!(nvm.take_digest_log().is_none());
    }

    #[test]
    fn store_ids_distinguish_stores_and_clones() {
        let mut a = Nvm::new();
        let b = Nvm::new();
        assert_ne!(a.store_id(), b.store_id());
        // clones copy contents but get a fresh identity, so handle caches
        // re-intern instead of aliasing keys interned after the clone
        let id = a.intern("x");
        let mut c = a.clone();
        assert_ne!(c.store_id(), a.store_id());
        assert_eq!(c.intern("x"), id); // same layout, same slots
    }
}
