//! Non-volatile memory model with action atomicity.
//!
//! Paper §3.5 memory model: *action-shared* variables live in non-volatile
//! memory (EEPROM/FRAM) and survive power failures; *action-local* state
//! is volatile and lost. An action's writes become visible to other
//! actions only when the action completes ("once an action completes
//! writing a value ... the value can be read by any action"); if power
//! fails mid-action, the framework discards the intermediate results and
//! the action restarts from scratch (§3.5 action-based programming).
//!
//! This module implements exactly that: a committed store plus a staging
//! buffer with read-your-writes semantics, `commit` on action completion,
//! `abort` on power failure, and read/write accounting so the energy model
//! can charge NVM traffic.
//!
//! §Perf — the store is built for the steady-state learn hot path:
//!
//! * Keys are interned once into [`KeyId`] handles ([`Nvm::intern`]); the
//!   handle paths (`write_id`, `read_id`, `write_f32s_at`, ...) never
//!   touch a string or allocate a key.
//! * Values live in a slab indexed by handle; a running byte counter makes
//!   the capacity check O(1) instead of an O(#keys) rescan per write.
//! * Range writes ([`Nvm::write_at`] / [`Nvm::write_f32s_at`]) stage only
//!   the dirty span — the staging buffer records per-slot dirty ranges —
//!   so a delta checkpoint of one ring-buffer row costs that row's bytes,
//!   not the model's.
//! * Reads can borrow ([`Nvm::read_id`]) or decode into a caller buffer
//!   ([`Nvm::read_f32s_into`]) instead of cloning.
//!
//! Every buffer (staging, dirty lists) keeps its capacity across
//! transactions, so after warm-up the write/commit cycle performs no heap
//! allocation.

pub mod arena;
pub mod audit;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};

/// Interned key handle: resolve a string key once ([`Nvm::intern`]), then
/// address the slot directly. Handles are only meaningful for the store
/// that issued them; [`Nvm::store_id`] lets callers detect a foreign
/// store and re-intern. Clones get a fresh identity — their slot layout
/// is copied, so re-interning the same names yields the same slots, but
/// handles interned on either side *after* the clone would silently
/// alias otherwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KeyId(u32);

/// Distinct identity per store (including clones).
static NEXT_STORE_ID: AtomicU64 = AtomicU64::new(1);

/// One slab slot: a committed value plus its reusable staging buffer.
#[derive(Debug, Clone, Default)]
struct Slot {
    name: String,
    committed: Vec<u8>,
    /// Does a committed value exist? (`committed` keeps its capacity after
    /// the value conceptually disappears, so emptiness is not absence.)
    present: bool,
    /// Staging buffer for the open transaction (capacity reused).
    staged: Vec<u8>,
    /// Is this slot staged in the open transaction?
    staged_present: bool,
    /// Byte ranges of `staged` dirtied by the open transaction
    /// (start, end). A full overwrite records one whole-value range.
    dirty: Vec<(usize, usize)>,
}

impl Slot {
    /// Length the slot would have if the open transaction committed now.
    fn pending_len(&self) -> usize {
        if self.staged_present {
            self.staged.len()
        } else if self.present {
            self.committed.len()
        } else {
            0
        }
    }
}

/// Byte-granular non-volatile store with transactional action semantics.
#[derive(Debug)]
pub struct Nvm {
    slots: Vec<Slot>,
    index: BTreeMap<String, KeyId>,
    /// Is an action transaction open?
    txn_open: bool,
    /// Slots staged in the open transaction (commit/abort walk this).
    txn_dirty: Vec<KeyId>,
    /// Committed bytes (running counter; O(1) capacity checks).
    used: usize,
    /// Bytes the store would hold if the open transaction committed.
    staged_used: usize,
    /// Capacity limit in bytes (0 = unlimited). The paper's platforms
    /// range from 512 B (PIC) to 256 KB (MSP430 FRAM).
    pub capacity: usize,
    store_id: u64,
    // accounting
    pub bytes_written: u64,
    pub bytes_read: u64,
    pub commits: u64,
    pub aborts: u64,
    /// Armed access-trace recorder (debug builds; see [`Nvm::audit_start`]).
    /// Boxed so the idle field costs one pointer; not cloned — a clone is a
    /// different store and starts unobserved.
    #[cfg(debug_assertions)]
    audit: Option<Box<audit::AccessTrace>>,
}

impl Clone for Nvm {
    /// Clones copy the contents but get a **fresh** [`Nvm::store_id`]:
    /// cached [`KeyId`] handles from the original still point at the same
    /// names in the copy, but holders re-intern (idempotent) instead of
    /// risking aliasing with keys interned after the clone diverged.
    fn clone(&self) -> Self {
        Nvm {
            slots: self.slots.clone(),
            index: self.index.clone(),
            txn_open: self.txn_open,
            txn_dirty: self.txn_dirty.clone(),
            used: self.used,
            staged_used: self.staged_used,
            capacity: self.capacity,
            store_id: NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed),
            bytes_written: self.bytes_written,
            bytes_read: self.bytes_read,
            commits: self.commits,
            aborts: self.aborts,
            #[cfg(debug_assertions)]
            audit: None,
        }
    }
}

impl Default for Nvm {
    fn default() -> Self {
        Nvm {
            slots: Vec::new(),
            index: BTreeMap::new(),
            txn_open: false,
            txn_dirty: Vec::new(),
            used: 0,
            staged_used: 0,
            capacity: 0,
            store_id: NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed),
            bytes_written: 0,
            bytes_read: 0,
            commits: 0,
            aborts: 0,
            #[cfg(debug_assertions)]
            audit: None,
        }
    }
}

impl Nvm {
    /// Unlimited-capacity store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Store with a byte capacity (over-capacity writes fail).
    pub fn with_capacity(capacity: usize) -> Self {
        Nvm {
            capacity,
            ..Self::default()
        }
    }

    /// Identity of this store (distinct per store, including clones).
    /// Callers caching [`KeyId`] handles compare this to detect a foreign
    /// store and re-intern.
    pub fn store_id(&self) -> u64 {
        self.store_id
    }

    /// Resolve `key` to a handle, creating an (absent) slot on first use.
    /// The only key path that allocates; do it once at construction.
    pub fn intern(&mut self, key: &str) -> KeyId {
        if let Some(&id) = self.index.get(key) {
            return id;
        }
        let id = KeyId(self.slots.len() as u32);
        self.slots.push(Slot {
            name: key.to_string(),
            ..Slot::default()
        });
        self.index.insert(key.to_string(), id);
        id
    }

    /// Resolve without creating (reads of absent keys stay absent).
    pub fn resolve(&self, key: &str) -> Option<KeyId> {
        self.index.get(key).copied()
    }

    fn slot(&self, id: KeyId) -> Result<&Slot> {
        self.slots
            .get(id.0 as usize)
            .ok_or_else(|| Error::Nvm(format!("stale key handle {}", id.0)))
    }

    // ---- access auditing (intermittent-safety analyzer) ----------------

    /// Arm the access-trace recorder: every subsequent transaction bracket
    /// and byte-level read/write is appended to a fresh trace until
    /// [`Nvm::audit_take`] disarms it. Debug builds only — the release
    /// twin is a no-op so the hot path stays unobserved.
    #[cfg(debug_assertions)]
    pub fn audit_start(&mut self) {
        self.audit = Some(Box::new(audit::AccessTrace::new()));
    }

    /// Release twin of [`Nvm::audit_start`]: recording unavailable.
    #[cfg(not(debug_assertions))]
    pub fn audit_start(&mut self) {}

    /// Disarm the recorder and take the trace recorded since
    /// [`Nvm::audit_start`] (`None` if never armed, and always `None` in
    /// release builds).
    #[cfg(debug_assertions)]
    pub fn audit_take(&mut self) -> Option<audit::AccessTrace> {
        self.audit.take().map(|t| *t)
    }

    /// Release twin of [`Nvm::audit_take`]: recording unavailable.
    #[cfg(not(debug_assertions))]
    pub fn audit_take(&mut self) -> Option<audit::AccessTrace> {
        None
    }

    #[cfg(debug_assertions)]
    fn audit_mark(&mut self, event: audit::AccessEvent) {
        if let Some(trace) = self.audit.as_mut() {
            trace.events.push(event);
        }
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn audit_mark(&mut self, _event: audit::AccessEvent) {}

    /// Record a read of the first `len` bytes of `id`, splitting out the
    /// sub-ranges that observed committed pre-action state (the read range
    /// minus spans staged earlier in this transaction, clipped to the
    /// committed length) — only those can feed a write-after-read hazard.
    #[cfg(debug_assertions)]
    fn audit_read(&mut self, id: KeyId, len: usize) {
        if self.audit.is_none() {
            return;
        }
        let slot = &self.slots[id.0 as usize];
        let climit = if slot.present {
            len.min(slot.committed.len())
        } else {
            0
        };
        let event = audit::AccessEvent::Read {
            key: slot.name.clone(),
            range: (0, len),
            committed: audit::subtract((0, climit), &slot.dirty),
            in_txn: self.txn_open,
        };
        self.audit.as_mut().unwrap().events.push(event);
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn audit_read(&mut self, _id: KeyId, _len: usize) {}

    /// Record a write of `range` bytes of `id` (`full` = whole-value
    /// overwrite, which replays cleanly and is exempt from WAR analysis).
    #[cfg(debug_assertions)]
    fn audit_write(&mut self, id: KeyId, range: (usize, usize), full: bool) {
        if self.audit.is_none() {
            return;
        }
        let event = audit::AccessEvent::Write {
            key: self.slots[id.0 as usize].name.clone(),
            range,
            full,
            in_txn: self.txn_open,
        };
        self.audit.as_mut().unwrap().events.push(event);
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn audit_write(&mut self, _id: KeyId, _range: (usize, usize), _full: bool) {}

    /// Open an action transaction. Nested transactions are an error (an
    /// intermittent MCU runs one action at a time).
    pub fn begin_action(&mut self) -> Result<()> {
        if self.txn_open {
            return Err(Error::Nvm("action already in flight".into()));
        }
        self.txn_open = true;
        self.staged_used = self.used;
        self.audit_mark(audit::AccessEvent::Begin);
        Ok(())
    }

    /// Commit the in-flight action's writes.
    pub fn commit_action(&mut self) -> Result<()> {
        if !self.txn_open {
            return Err(Error::Nvm("commit without begin".into()));
        }
        while let Some(id) = self.txn_dirty.pop() {
            let slot = &mut self.slots[id.0 as usize];
            if slot.staged_present {
                // swap, not copy: the displaced committed buffer becomes
                // the next transaction's staging capacity
                std::mem::swap(&mut slot.committed, &mut slot.staged);
                slot.present = true;
                slot.staged_present = false;
            }
            slot.dirty.clear();
        }
        self.used = self.staged_used;
        self.txn_open = false;
        self.commits += 1;
        self.audit_mark(audit::AccessEvent::Commit);
        Ok(())
    }

    /// Discard the in-flight action's writes (power failure mid-action).
    pub fn abort_action(&mut self) {
        if !self.txn_open {
            return;
        }
        while let Some(id) = self.txn_dirty.pop() {
            let slot = &mut self.slots[id.0 as usize];
            slot.staged_present = false;
            slot.dirty.clear();
        }
        self.staged_used = self.used;
        self.txn_open = false;
        self.aborts += 1;
        self.audit_mark(audit::AccessEvent::Abort);
    }

    /// Reset this store for reuse by a new logical device (the pooled
    /// slab arena, [`arena::NvmArena`]). Every committed and staged
    /// value disappears — reads behave exactly like a fresh store
    /// (resolved keys read as absent) — while the interned key table
    /// and every slot's buffer capacity survive, so a recycled slab
    /// re-runs a shard without re-growing what the previous shard
    /// already allocated. The store takes a fresh [`Nvm::store_id`]
    /// (handle caches keyed on it re-intern instead of aliasing) and
    /// zeroes its traffic counters; an open action is discarded along
    /// with everything else.
    pub fn reset_for_reuse(&mut self) {
        for slot in &mut self.slots {
            slot.committed.clear();
            slot.present = false;
            slot.staged.clear();
            slot.staged_present = false;
            slot.dirty.clear();
        }
        self.txn_open = false;
        self.txn_dirty.clear();
        self.used = 0;
        self.staged_used = 0;
        self.bytes_written = 0;
        self.bytes_read = 0;
        self.commits = 0;
        self.aborts = 0;
        self.store_id = NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed);
        #[cfg(debug_assertions)]
        {
            self.audit = None;
        }
    }

    /// Is an action transaction open?
    pub fn in_action(&self) -> bool {
        self.txn_open
    }

    /// Committed bytes (O(1) — a running counter, not a rescan).
    pub fn used_bytes(&self) -> usize {
        self.used
    }

    /// Length of the value visible at `id` (staged, else committed).
    pub fn value_len(&self, id: KeyId) -> Option<usize> {
        let slot = self.slots.get(id.0 as usize)?;
        if slot.staged_present {
            Some(slot.staged.len())
        } else if slot.present {
            Some(slot.committed.len())
        } else {
            None
        }
    }

    /// Dirty byte ranges staged on `id` by the open transaction.
    pub fn staged_dirty(&self, id: KeyId) -> &[(usize, usize)] {
        self.slots
            .get(id.0 as usize)
            .map(|s| s.dirty.as_slice())
            .unwrap_or(&[])
    }

    /// O(1) capacity check for a write that leaves `id` at `new_len`.
    fn check_capacity(&self, id: KeyId, new_len: usize) -> Result<()> {
        if self.capacity == 0 {
            return Ok(());
        }
        let slot = &self.slots[id.0 as usize];
        let base = if self.txn_open {
            self.staged_used
        } else {
            self.used
        };
        let total = base - slot.pending_len() + new_len;
        if total > self.capacity {
            return Err(Error::Nvm(format!(
                "capacity exceeded writing `{}` ({} B used of {} B)",
                slot.name, base, self.capacity
            )));
        }
        Ok(())
    }

    /// Bookkeep a write that left a slot at `new_len` (from `old_len`),
    /// `dirtied` bytes of which were actually written (charged as NVM
    /// traffic).
    fn account_write(&mut self, old_len: usize, new_len: usize, dirtied: usize) {
        self.bytes_written += dirtied as u64;
        if self.txn_open {
            self.staged_used = self.staged_used - old_len + new_len;
        } else {
            self.used = self.used - old_len + new_len;
        }
    }

    /// Mark `id` staged in the open transaction (idempotent).
    fn mark_staged(&mut self, id: KeyId) {
        let slot = &mut self.slots[id.0 as usize];
        if !slot.staged_present {
            slot.staged_present = true;
            self.txn_dirty.push(id);
        }
    }

    /// Full-value write through a handle. Inside an action the write is
    /// staged; outside (framework bookkeeping, e.g. at boot) it commits
    /// immediately. Allocation-free once the slot's buffers have grown.
    pub fn write_id(&mut self, id: KeyId, bytes: &[u8]) -> Result<()> {
        self.slot(id)?;
        self.check_capacity(id, bytes.len())?;
        let old_len = self.slots[id.0 as usize].pending_len();
        if self.txn_open {
            {
                let slot = &mut self.slots[id.0 as usize];
                slot.staged.clear();
                slot.staged.extend_from_slice(bytes);
                // a full overwrite supersedes any earlier staged ranges
                slot.dirty.clear();
                slot.dirty.push((0, bytes.len()));
            }
            self.mark_staged(id);
        } else {
            let slot = &mut self.slots[id.0 as usize];
            slot.committed.clear();
            slot.committed.extend_from_slice(bytes);
            slot.present = true;
        }
        self.account_write(old_len, bytes.len(), bytes.len());
        self.audit_write(id, (0, bytes.len()), true);
        Ok(())
    }

    /// Range write through a handle: overwrite `bytes` starting at byte
    /// `offset`, extending the value (zero-filled) if needed. Only the
    /// written span is charged as NVM traffic — the delta-checkpoint
    /// primitive. Inside an action, the first touch of a slot seeds the
    /// staging buffer from the committed value (read-your-writes), and the
    /// dirty span is recorded per slot.
    pub fn write_at(&mut self, id: KeyId, offset: usize, bytes: &[u8]) -> Result<()> {
        self.slot(id)?;
        let end = offset + bytes.len();
        let old_len = self.slots[id.0 as usize].pending_len();
        let new_len = old_len.max(end);
        self.check_capacity(id, new_len)?;
        if self.txn_open {
            {
                let slot = &mut self.slots[id.0 as usize];
                if !slot.staged_present {
                    slot.staged.clear();
                    if slot.present {
                        slot.staged.extend_from_slice(&slot.committed);
                    }
                }
                if slot.staged.len() < end {
                    slot.staged.resize(end, 0);
                }
                slot.staged[offset..end].copy_from_slice(bytes);
                slot.dirty.push((offset, end));
            }
            self.mark_staged(id);
        } else {
            let slot = &mut self.slots[id.0 as usize];
            if slot.committed.len() < end {
                slot.committed.resize(end, 0);
            }
            slot.committed[offset..end].copy_from_slice(bytes);
            slot.present = true;
        }
        self.account_write(old_len, new_len, bytes.len());
        self.audit_write(id, (offset, end), false);
        Ok(())
    }

    /// Borrowing read with read-your-writes semantics (no clone).
    pub fn read_id(&mut self, id: KeyId) -> Option<&[u8]> {
        let slot = self.slots.get(id.0 as usize)?;
        let len = if slot.staged_present {
            slot.staged.len()
        } else if slot.present {
            slot.committed.len()
        } else {
            return None;
        };
        self.bytes_read += len as u64;
        self.audit_read(id, len);
        let slot = &self.slots[id.0 as usize];
        Some(if slot.staged_present {
            &slot.staged
        } else {
            &slot.committed
        })
    }

    /// Committed bytes at `id`, bypassing staging, read accounting, and
    /// the audit recorder — the analyzer's twin-comparison peek.
    pub fn committed_id(&self, id: KeyId) -> Option<&[u8]> {
        let slot = self.slots.get(id.0 as usize)?;
        slot.present.then_some(slot.committed.as_slice())
    }

    /// Iterate every interned key with its handle, in name order.
    pub fn keys(&self) -> impl Iterator<Item = (&str, KeyId)> + '_ {
        self.index.iter().map(|(k, &id)| (k.as_str(), id))
    }

    /// Does a committed or staged value exist at `id`?
    pub fn contains_id(&self, id: KeyId) -> bool {
        self.slots
            .get(id.0 as usize)
            .map(|s| s.staged_present || s.present)
            .unwrap_or(false)
    }

    // ---- typed handle helpers ------------------------------------------

    /// Write an f32 slice through a handle (full value).
    pub fn write_f32s_id(&mut self, id: KeyId, xs: &[f32]) -> Result<()> {
        self.slot(id)?;
        let new_len = xs.len() * 4;
        self.check_capacity(id, new_len)?;
        let old_len = self.slots[id.0 as usize].pending_len();
        if self.txn_open {
            {
                let slot = &mut self.slots[id.0 as usize];
                slot.staged.clear();
                for x in xs {
                    slot.staged.extend_from_slice(&x.to_le_bytes());
                }
                // a full overwrite supersedes any earlier staged ranges
                slot.dirty.clear();
                slot.dirty.push((0, new_len));
            }
            self.mark_staged(id);
        } else {
            let slot = &mut self.slots[id.0 as usize];
            slot.committed.clear();
            for x in xs {
                slot.committed.extend_from_slice(&x.to_le_bytes());
            }
            slot.present = true;
        }
        self.account_write(old_len, new_len, new_len);
        self.audit_write(id, (0, new_len), true);
        Ok(())
    }

    /// Range write of f32s at *element* offset `at` (the dirty-slot
    /// delta-checkpoint primitive: one ring row, one cluster row).
    pub fn write_f32s_at(&mut self, id: KeyId, at: usize, xs: &[f32]) -> Result<()> {
        self.slot(id)?;
        let offset = at * 4;
        let end = offset + xs.len() * 4;
        let old_len = self.slots[id.0 as usize].pending_len();
        let new_len = old_len.max(end);
        self.check_capacity(id, new_len)?;
        if self.txn_open {
            {
                let slot = &mut self.slots[id.0 as usize];
                if !slot.staged_present {
                    slot.staged.clear();
                    if slot.present {
                        slot.staged.extend_from_slice(&slot.committed);
                    }
                }
                if slot.staged.len() < end {
                    slot.staged.resize(end, 0);
                }
                for (i, x) in xs.iter().enumerate() {
                    slot.staged[offset + i * 4..offset + i * 4 + 4]
                        .copy_from_slice(&x.to_le_bytes());
                }
                slot.dirty.push((offset, end));
            }
            self.mark_staged(id);
        } else {
            let slot = &mut self.slots[id.0 as usize];
            if slot.committed.len() < end {
                slot.committed.resize(end, 0);
            }
            for (i, x) in xs.iter().enumerate() {
                slot.committed[offset + i * 4..offset + i * 4 + 4]
                    .copy_from_slice(&x.to_le_bytes());
            }
            slot.present = true;
        }
        self.account_write(old_len, new_len, xs.len() * 4);
        self.audit_write(id, (offset, end), false);
        Ok(())
    }

    /// Decode the value at `id` into `out` without allocating. Returns
    /// `false` (leaving `out` untouched, charging no read) unless a value
    /// of exactly `out.len()` f32s exists.
    pub fn read_f32s_into(&mut self, id: KeyId, out: &mut [f32]) -> bool {
        if self.value_len(id) != Some(out.len() * 4) {
            return false;
        }
        self.bytes_read += (out.len() * 4) as u64;
        self.audit_read(id, out.len() * 4);
        let slot = &self.slots[id.0 as usize];
        let bytes: &[u8] = if slot.staged_present {
            &slot.staged
        } else {
            &slot.committed
        };
        for (i, c) in bytes.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        true
    }

    /// Read an f32 slice through a handle (allocating convenience).
    pub fn read_f32s_id(&mut self, id: KeyId) -> Option<Vec<f32>> {
        let bytes = self.read_id(id)?;
        Some(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        )
    }

    /// Write a u64 counter through a handle.
    pub fn write_u64_id(&mut self, id: KeyId, v: u64) -> Result<()> {
        self.write_id(id, &v.to_le_bytes())
    }

    /// Read a u64 counter through a handle (0 if absent).
    pub fn read_u64_id(&mut self, id: KeyId) -> u64 {
        match self.read_id(id) {
            Some(b) if b.len() == 8 => u64::from_le_bytes(b.try_into().unwrap()),
            _ => 0,
        }
    }

    // ---- string-keyed compatibility API --------------------------------

    /// Raw write by string key (interns; prefer [`Nvm::write_id`] on hot
    /// paths).
    pub fn write(&mut self, key: &str, bytes: &[u8]) -> Result<()> {
        let id = self.intern(key);
        self.write_id(id, bytes)
    }

    /// Raw read by string key with read-your-writes semantics (clones;
    /// prefer [`Nvm::read_id`] / [`Nvm::read_f32s_into`] on hot paths).
    pub fn read(&mut self, key: &str) -> Option<Vec<u8>> {
        let id = self.resolve(key)?;
        self.read_id(id).map(|b| b.to_vec())
    }

    /// Does a committed or staged value exist?
    pub fn contains(&self, key: &str) -> bool {
        self.resolve(key).map(|id| self.contains_id(id)).unwrap_or(false)
    }

    /// Write an f32 slice.
    pub fn write_f32s(&mut self, key: &str, xs: &[f32]) -> Result<()> {
        let id = self.intern(key);
        self.write_f32s_id(id, xs)
    }

    /// Read an f32 slice.
    pub fn read_f32s(&mut self, key: &str) -> Option<Vec<f32>> {
        let id = self.resolve(key)?;
        self.read_f32s_id(id)
    }

    /// Write a u64 counter.
    pub fn write_u64(&mut self, key: &str, v: u64) -> Result<()> {
        let id = self.intern(key);
        self.write_u64_id(id, v)
    }

    /// Read a u64 counter (0 if absent).
    pub fn read_u64(&mut self, key: &str) -> u64 {
        match self.resolve(key) {
            Some(id) => self.read_u64_id(id),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn committed_writes_survive() {
        let mut nvm = Nvm::new();
        nvm.write_f32s("w", &[1.0, 2.0]).unwrap();
        assert_eq!(nvm.read_f32s("w").unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn abort_discards_staged_writes() {
        let mut nvm = Nvm::new();
        nvm.write_f32s("model", &[1.0]).unwrap();
        nvm.begin_action().unwrap();
        nvm.write_f32s("model", &[9.0]).unwrap();
        // read-your-writes inside the action
        assert_eq!(nvm.read_f32s("model").unwrap(), vec![9.0]);
        nvm.abort_action(); // power failure
        assert_eq!(nvm.read_f32s("model").unwrap(), vec![1.0]);
        assert_eq!(nvm.aborts, 1);
    }

    #[test]
    fn commit_publishes_staged_writes() {
        let mut nvm = Nvm::new();
        nvm.begin_action().unwrap();
        nvm.write_u64("count", 7).unwrap();
        nvm.commit_action().unwrap();
        assert_eq!(nvm.read_u64("count"), 7);
        assert_eq!(nvm.commits, 1);
    }

    #[test]
    fn nested_begin_rejected() {
        let mut nvm = Nvm::new();
        nvm.begin_action().unwrap();
        assert!(nvm.begin_action().is_err());
    }

    #[test]
    fn commit_without_begin_rejected() {
        let mut nvm = Nvm::new();
        assert!(nvm.commit_action().is_err());
    }

    #[test]
    fn capacity_enforced() {
        let mut nvm = Nvm::with_capacity(8);
        nvm.write_f32s("a", &[1.0, 2.0]).unwrap(); // 8 bytes
        assert!(nvm.write_f32s("b", &[3.0]).is_err());
        // overwriting the same key with the same size is fine
        nvm.write_f32s("a", &[4.0, 5.0]).unwrap();
        assert_eq!(nvm.used_bytes(), 8);
    }

    #[test]
    fn capacity_counts_staged_shrinkage() {
        // a staged shrink of one key frees budget for another in the same
        // transaction (the running staged counter is exact, not the old
        // committed-only rescan)
        let mut nvm = Nvm::with_capacity(8);
        nvm.write_f32s("a", &[1.0, 2.0]).unwrap();
        nvm.begin_action().unwrap();
        nvm.write_f32s("a", &[1.0]).unwrap();
        nvm.write_f32s("b", &[2.0]).unwrap();
        nvm.commit_action().unwrap();
        assert_eq!(nvm.used_bytes(), 8);
    }

    #[test]
    fn accounting_counts_bytes() {
        let mut nvm = Nvm::new();
        nvm.write_f32s("x", &[0.0; 4]).unwrap();
        nvm.read_f32s("x");
        assert_eq!(nvm.bytes_written, 16);
        assert_eq!(nvm.bytes_read, 16);
    }

    #[test]
    fn missing_counter_defaults_zero() {
        let mut nvm = Nvm::new();
        assert_eq!(nvm.read_u64("nope"), 0);
    }

    #[test]
    fn interned_handles_round_trip() {
        let mut nvm = Nvm::new();
        let id = nvm.intern("model/w");
        assert_eq!(nvm.intern("model/w"), id); // stable
        nvm.write_f32s_id(id, &[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(nvm.read_f32s_id(id).unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(nvm.resolve("model/w"), Some(id));
        assert_eq!(nvm.resolve("other"), None);
        // the string API sees the same slot
        assert_eq!(nvm.read_f32s("model/w").unwrap(), vec![1.0, 2.0, 3.0]);
        let mut out = [0.0f32; 3];
        assert!(nvm.read_f32s_into(id, &mut out));
        assert_eq!(out, [1.0, 2.0, 3.0]);
        // size mismatch leaves the output untouched
        let mut wrong = [9.0f32; 2];
        assert!(!nvm.read_f32s_into(id, &mut wrong));
        assert_eq!(wrong, [9.0, 9.0]);
    }

    #[test]
    fn range_writes_charge_only_the_dirty_span() {
        let mut nvm = Nvm::new();
        let id = nvm.intern("buf");
        nvm.write_f32s_id(id, &[0.0; 16]).unwrap(); // 64 B
        let before = nvm.bytes_written;
        nvm.write_f32s_at(id, 4, &[1.0, 2.0]).unwrap(); // 8 B dirty
        assert_eq!(nvm.bytes_written - before, 8);
        let got = nvm.read_f32s_id(id).unwrap();
        assert_eq!(got.len(), 16);
        assert_eq!(&got[4..6], &[1.0, 2.0]);
        assert!(got[..4].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn staged_range_write_rolls_back_and_records_dirty_ranges() {
        let mut nvm = Nvm::new();
        let id = nvm.intern("buf");
        nvm.write_f32s_id(id, &[0.0; 8]).unwrap();
        nvm.begin_action().unwrap();
        nvm.write_f32s_at(id, 2, &[5.0]).unwrap();
        nvm.write_f32s_at(id, 6, &[7.0]).unwrap();
        assert_eq!(nvm.staged_dirty(id), &[(8, 12), (24, 28)][..]);
        // read-your-writes sees the merged view
        let merged = nvm.read_f32s_id(id).unwrap();
        assert_eq!(merged[2], 5.0);
        assert_eq!(merged[6], 7.0);
        assert_eq!(merged[0], 0.0);
        nvm.abort_action();
        assert!(nvm.staged_dirty(id).is_empty());
        assert!(nvm.read_f32s_id(id).unwrap().iter().all(|&v| v == 0.0));
        // and a committed range write lands
        nvm.begin_action().unwrap();
        nvm.write_f32s_at(id, 3, &[9.0]).unwrap();
        nvm.commit_action().unwrap();
        assert_eq!(nvm.read_f32s_id(id).unwrap()[3], 9.0);
    }

    #[test]
    fn range_write_extends_with_zero_fill() {
        let mut nvm = Nvm::new();
        let id = nvm.intern("grow");
        nvm.write_f32s_at(id, 2, &[1.0]).unwrap();
        assert_eq!(nvm.read_f32s_id(id).unwrap(), vec![0.0, 0.0, 1.0]);
        assert_eq!(nvm.used_bytes(), 12);
    }

    #[test]
    fn used_bytes_tracks_commit_and_abort() {
        let mut nvm = Nvm::new();
        nvm.write("a", &[0; 10]).unwrap();
        assert_eq!(nvm.used_bytes(), 10);
        nvm.begin_action().unwrap();
        nvm.write("a", &[0; 4]).unwrap();
        nvm.write("b", &[0; 6]).unwrap();
        assert_eq!(nvm.used_bytes(), 10, "committed view until commit");
        nvm.commit_action().unwrap();
        assert_eq!(nvm.used_bytes(), 10); // 4 + 6
        nvm.begin_action().unwrap();
        nvm.write("c", &[0; 100]).unwrap();
        nvm.abort_action();
        assert_eq!(nvm.used_bytes(), 10);
        assert!(!nvm.contains("c"));
    }

    #[test]
    fn audit_records_brackets_reads_and_writes() {
        use audit::AccessEvent;
        let mut nvm = Nvm::new();
        let id = nvm.intern("buf");
        nvm.write_f32s_id(id, &[0.0; 4]).unwrap(); // pre-trace: not recorded
        nvm.audit_start();
        nvm.begin_action().unwrap();
        nvm.write_f32s_at(id, 1, &[5.0]).unwrap();
        nvm.read_f32s_id(id).unwrap();
        nvm.commit_action().unwrap();
        let trace = nvm.audit_take().unwrap();
        assert_eq!(trace.events.len(), 4, "{:?}", trace.events);
        assert_eq!(trace.events[0], AccessEvent::Begin);
        assert_eq!(
            trace.events[1],
            AccessEvent::Write {
                key: "buf".into(),
                range: (4, 8),
                full: false,
                in_txn: true
            }
        );
        // the read observes committed bytes everywhere except the staged span
        assert_eq!(
            trace.events[2],
            AccessEvent::Read {
                key: "buf".into(),
                range: (0, 16),
                committed: vec![(0, 4), (8, 16)],
                in_txn: true
            }
        );
        assert_eq!(trace.events[3], AccessEvent::Commit);
        // taking the trace disarms the recorder
        nvm.read_f32s_id(id).unwrap();
        assert!(nvm.audit_take().is_none());
    }

    #[test]
    fn audit_marks_full_overwrites_and_untransacted_writes() {
        use audit::AccessEvent;
        let mut nvm = Nvm::new();
        let id = nvm.intern("gen");
        nvm.audit_start();
        nvm.write_u64_id(id, 3).unwrap(); // outside any transaction
        let trace = nvm.audit_take().unwrap();
        assert_eq!(
            trace.events[0],
            AccessEvent::Write {
                key: "gen".into(),
                range: (0, 8),
                full: true,
                in_txn: false
            }
        );
    }

    #[test]
    fn committed_peek_bypasses_staging_and_accounting() {
        let mut nvm = Nvm::new();
        let id = nvm.intern("x");
        nvm.write_id(id, &[1, 2]).unwrap();
        nvm.begin_action().unwrap();
        nvm.write_id(id, &[9, 9]).unwrap();
        let before = nvm.bytes_read;
        assert_eq!(nvm.committed_id(id), Some(&[1u8, 2][..]));
        assert_eq!(nvm.bytes_read, before);
        nvm.abort_action();
        let keys: Vec<&str> = nvm.keys().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["x"]);
    }

    #[test]
    fn store_ids_distinguish_stores_and_clones() {
        let mut a = Nvm::new();
        let b = Nvm::new();
        assert_ne!(a.store_id(), b.store_id());
        // clones copy contents but get a fresh identity, so handle caches
        // re-intern instead of aliasing keys interned after the clone
        let id = a.intern("x");
        let mut c = a.clone();
        assert_ne!(c.store_id(), a.store_id());
        assert_eq!(c.intern("x"), id); // same layout, same slots
    }
}
