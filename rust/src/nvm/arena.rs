//! Pooled NVM slab arena for population-scale fleets.
//!
//! A streaming fleet runs millions of logical devices through a handful
//! of worker lanes. Giving every device its own heap slab (one [`Nvm`]
//! per shard) is what capped the old fleet at thousands of shards; the
//! arena instead recycles one slab per worker lane: when a shard
//! finishes, its store is [`Nvm::reset_for_reuse`]-scrubbed (committed
//! state erased, interned key table and grown buffer capacities kept,
//! fresh store identity) and handed to the lane's next shard. Total
//! slab allocations are O(workers), independent of the shard count,
//! and steady-state shards re-run inside buffers the first shard grew.
//!
//! A reset store is observationally identical to a fresh one — resolved
//! keys read as absent, counters start at zero, and the fresh
//! `store_id` makes learner handle caches re-intern — which is what
//! makes recycling bit-identity-safe for the fleet (`sim/soa.rs` pins
//! this against the per-shard-engine path).

use super::Nvm;

/// Free-list pool of recycled NVM slabs.
#[derive(Debug, Default)]
pub struct NvmArena {
    free: Vec<Nvm>,
    /// Slabs handed out fresh (pool was empty).
    pub builds: u64,
    /// Slabs handed out recycled.
    pub reuses: u64,
}

impl NvmArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// A slab ready for a new device: recycled if one is pooled, else
    /// freshly allocated.
    pub fn take(&mut self) -> Nvm {
        match self.free.pop() {
            Some(nvm) => {
                self.reuses += 1;
                nvm
            }
            None => {
                self.builds += 1;
                Nvm::new()
            }
        }
    }

    /// Return a slab to the pool, scrubbing it for the next device.
    pub fn put(&mut self, mut nvm: Nvm) {
        nvm.reset_for_reuse();
        self.free.push(nvm);
    }

    /// Recycled slabs currently pooled.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_builds_fresh_then_reuses_what_was_put_back() {
        let mut arena = NvmArena::new();
        let a = arena.take();
        assert_eq!((arena.builds, arena.reuses, arena.pooled()), (1, 0, 0));
        arena.put(a);
        assert_eq!(arena.pooled(), 1);
        let b = arena.take();
        assert_eq!((arena.builds, arena.reuses, arena.pooled()), (1, 1, 0));
        drop(b);
    }

    #[test]
    fn recycled_slab_reads_like_a_fresh_store() {
        let mut arena = NvmArena::new();
        let mut a = arena.take();
        a.write("model", &[1, 2, 3, 4]).unwrap();
        a.write_u64("gen", 7).unwrap();
        let old_id = a.store_id();
        arena.put(a);

        let mut b = arena.take();
        let fresh = Nvm::new();
        assert_ne!(b.store_id(), old_id, "recycled store takes a new identity");
        assert_eq!(b.read("model"), None);
        assert_eq!(b.read_u64("gen"), 0);
        assert_eq!(b.used_bytes(), fresh.used_bytes());
        assert_eq!(b.bytes_written, 0);
        assert_eq!(b.bytes_read, 0);
        assert_eq!(b.commits, 0);
        assert_eq!(b.aborts, 0);
        assert!(!b.in_action());
    }

    #[test]
    fn recycled_slab_discards_an_open_action() {
        let mut arena = NvmArena::new();
        let mut a = arena.take();
        a.begin_action().unwrap();
        a.write("staged", &[9; 16]).unwrap();
        arena.put(a);
        let mut b = arena.take();
        assert!(!b.in_action());
        assert_eq!(b.read("staged"), None);
        // The scrubbed store supports a full fresh transaction cycle.
        b.begin_action().unwrap();
        b.write("staged", &[1]).unwrap();
        b.commit_action().unwrap();
        assert_eq!(b.read("staged"), Some(vec![1]));
        assert_eq!(b.commits, 1);
    }
}
