//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them once on the PJRT CPU client, and
//! execute them from the L3 hot path. The XLA-backed pieces require the
//! `pjrt` cargo feature; manifest parsing is always available.
//!
//! Interchange format is **HLO text** (`HloModuleProto::from_text_file`):
//! jax ≥ 0.5 emits serialized protos with 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.
//! All payloads are lowered with `return_tuple=True`, so every execution
//! unwraps a tuple.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Artifact names the runtime knows how to serve.
pub const ARTIFACTS: [&str; 7] = [
    "extract",
    "knn_learn",
    "knn_infer",
    "knn_infer_batch",
    "kmeans_learn",
    "kmeans_infer",
    "diversity_repr",
];

/// An input/output shape parsed from `manifest.txt`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeSpec(pub Vec<usize>);

impl ShapeSpec {
    pub fn elements(&self) -> usize {
        self.0.iter().product()
    }
}

/// Manifest entry for one artifact.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub inputs: Vec<ShapeSpec>,
    pub outputs: Vec<ShapeSpec>,
}

/// Parse `manifest.txt` (written by aot.py). Strict: an unparseable shape
/// dimension is an [`Error::Runtime`], never silently dropped — a corrupt
/// manifest must not yield a wrong-but-plausible shape that only fails
/// (or worse, misreads buffers) at execution time.
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let parse_shapes = |s: &str| -> Result<Vec<ShapeSpec>> {
        s.split(';')
            .map(|one| {
                if one == "scalar" || one.is_empty() {
                    Ok(ShapeSpec(vec![]))
                } else {
                    one.split('x')
                        .map(|d| {
                            d.parse::<usize>().map_err(|_| {
                                Error::Runtime(format!(
                                    "bad shape dimension `{d}` in manifest shape `{s}`"
                                ))
                            })
                        })
                        .collect::<Result<Vec<usize>>>()
                        .map(ShapeSpec)
                }
            })
            .collect()
    };
    let mut entries = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split('\t');
        let name = parts
            .next()
            .ok_or_else(|| Error::Runtime(format!("bad manifest line: {line}")))?;
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        for p in parts {
            if let Some(s) = p.strip_prefix("in=") {
                inputs = parse_shapes(s)?;
            } else if let Some(s) = p.strip_prefix("out=") {
                outputs = parse_shapes(s)?;
            }
        }
        entries.push(ManifestEntry {
            name: name.to_string(),
            inputs,
            outputs,
        });
    }
    Ok(entries)
}

/// An input to [`Executable::run_args`]: either host data (uploaded on
/// this call) or an already-resident device buffer (the §Perf lever for
/// large, rarely-changing inputs like the k-NN example buffer).
#[cfg(feature = "pjrt")]
pub enum Arg<'a> {
    Host(&'a [f32]),
    Device(&'a xla::PjRtBuffer),
}

/// A compiled artifact ready for execution.
#[cfg(feature = "pjrt")]
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub entry: ManifestEntry,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with a mix of host slices and device-resident buffers.
    /// Host inputs are uploaded here; device inputs skip the copy.
    pub fn run_args(&self, inputs: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.entry.inputs.len() {
            return Err(Error::Artifact {
                name: self.entry.name.clone(),
                msg: format!(
                    "expected {} inputs, got {}",
                    self.entry.inputs.len(),
                    inputs.len()
                ),
            });
        }
        let client = self.exe.client().clone();
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        // two passes so `owned` is fully built before taking references
        let mut slots: Vec<Option<usize>> = Vec::with_capacity(inputs.len());
        for (i, (arg, spec)) in inputs.iter().zip(&self.entry.inputs).enumerate() {
            match arg {
                Arg::Host(buf) => {
                    if buf.len() != spec.elements() {
                        return Err(Error::Artifact {
                            name: self.entry.name.clone(),
                            msg: format!(
                                "input {i}: expected {} elements, got {}",
                                spec.elements(),
                                buf.len()
                            ),
                        });
                    }
                    let dims: Vec<usize> =
                        if spec.0.is_empty() { vec![] } else { spec.0.clone() };
                    owned.push(client.buffer_from_host_buffer::<f32>(buf, &dims, None)?);
                    slots.push(Some(owned.len() - 1));
                }
                Arg::Device(_) => slots.push(None),
            }
        }
        for (arg, slot) in inputs.iter().zip(&slots) {
            match (arg, slot) {
                (Arg::Device(b), _) => refs.push(b),
                (Arg::Host(_), Some(k)) => refs.push(&owned[*k]),
                _ => unreachable!(),
            }
        }
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&refs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(Into::into))
            .collect()
    }

    /// Execute with f32 inputs shaped per the manifest; returns one f32
    /// vector per output (scalars are length-1).
    pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.entry.inputs.len() {
            return Err(Error::Artifact {
                name: self.entry.name.clone(),
                msg: format!(
                    "expected {} inputs, got {}",
                    self.entry.inputs.len(),
                    inputs.len()
                ),
            });
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (buf, spec)) in inputs.iter().zip(&self.entry.inputs).enumerate() {
            if buf.len() != spec.elements() {
                return Err(Error::Artifact {
                    name: self.entry.name.clone(),
                    msg: format!(
                        "input {i}: expected {} elements for shape {:?}, got {}",
                        spec.elements(),
                        spec.0,
                        buf.len()
                    ),
                });
            }
            let lit = if spec.0.is_empty() {
                xla::Literal::scalar(buf[0])
            } else {
                let dims: Vec<i64> = spec.0.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(buf).reshape(&dims)?
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != self.entry.outputs.len() {
            return Err(Error::Artifact {
                name: self.entry.name.clone(),
                msg: format!(
                    "expected {} outputs, got {}",
                    self.entry.outputs.len(),
                    parts.len()
                ),
            });
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}

/// The PJRT runtime: one CPU client + a cache of compiled executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ManifestEntry>,
    cache: HashMap<String, Executable>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a runtime over an artifact directory (reads `manifest.txt`).
    pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = parse_manifest(&text)?
            .into_iter()
            .map(|e| (e.name.clone(), e))
            .collect();
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            dir,
            manifest,
            cache: HashMap::new(),
        })
    }

    /// Locate the artifact dir by walking up from CWD (repo-root layout).
    pub fn discover() -> Result<Self> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join(DEFAULT_ARTIFACT_DIR);
            if cand.join("manifest.txt").exists() {
                return Self::new(cand);
            }
            if !dir.pop() {
                return Err(Error::Runtime(
                    "artifacts/manifest.txt not found in any ancestor; run `make artifacts`"
                        .into(),
                ));
            }
        }
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .get(name)
                .ok_or_else(|| Error::Artifact {
                    name: name.to_string(),
                    msg: "not in manifest".into(),
                })?
                .clone();
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache
                .insert(name.to_string(), Executable { exe, entry });
        }
        Ok(&self.cache[name])
    }

    /// Compile every artifact up front (amortizes compile cost before the
    /// simulated hot path starts).
    pub fn preload(&mut self) -> Result<()> {
        for name in ARTIFACTS {
            if self.manifest.contains_key(name) {
                self.load(name)?;
            }
        }
        Ok(())
    }

    /// Upload a host buffer to the default device (for caching large,
    /// rarely-changing inputs across calls).
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    /// Names available in the manifest.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_shapes_and_scalars() {
        let text = "knn_infer\tin=64x32;64;32\tout=scalar\nextract\tin=64x4\tout=4x8\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].inputs.len(), 3);
        assert_eq!(m[0].inputs[0].0, vec![64, 32]);
        assert_eq!(m[0].inputs[0].elements(), 2048);
        assert_eq!(m[0].outputs[0].0, Vec::<usize>::new());
        assert_eq!(m[0].outputs[0].elements(), 1);
        assert_eq!(m[1].outputs[0].0, vec![4, 8]);
    }

    #[test]
    fn manifest_skips_blank_lines() {
        let m = parse_manifest("\n\na\tin=2\tout=2\n\n").unwrap();
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn corrupt_shape_dims_are_an_error_not_a_guess() {
        // a corrupt dim must not shrink 64xZZ to just [64]
        let err = parse_manifest("knn_infer\tin=64xZZ\tout=scalar\n").unwrap_err();
        assert!(
            matches!(&err, Error::Runtime(m) if m.contains("ZZ")),
            "{err:?}"
        );
        assert!(parse_manifest("a\tin=6 4\tout=2\n").is_err());
        assert!(parse_manifest("a\tin=\tout=2\n").is_ok(), "empty = scalar stays valid");
    }
}
