//! The crash-sweep driver: execute a scenario once to enumerate its
//! persist steps, then re-execute it once per cut point, killing the
//! device at that exact step and asserting the store recovers to a
//! bit-exact commit boundary.
//!
//! The oracle is the reference run's per-commit digest log
//! ([`crate::nvm::Nvm::start_digest_log`]): a run cut after `k` durable
//! commit records must recover to exactly `log[k]` — the committed image
//! the *uninterrupted* twin had after its `k`-th commit. On top of the
//! digest check, every cut run reboots into a fresh device (new engine,
//! recovered NVM) and must restore its run state
//! ([`crate::sim::engine::Engine::restore_run_state`]) and learner
//! ([`crate::learning::Learner::restore`]) without error — the
//! self-healing restore path the paper's §3.5 claim needs.
//!
//! [`sweep_scenario_sabotaged`] is the negative control: the same sweep
//! with the store's commit order deliberately broken (record before
//! flushes). A sweep that cannot flag that bug proves nothing, so the
//! self-test pins that it does.

use crate::error::Result;
use crate::fault::{FaultPlan, FaultPoint, SweepMode};
use crate::nvm::Recovery;
use crate::scenario::ScenarioSpec;
use crate::util::json::Json;

/// The outcome of one crash sweep, machine-readable via
/// [`CrashReport::to_json`]. The JSON document carries only fields that
/// are stable for a given (scenario, mode, seed, horizon) — cut counts
/// and violations — so it can be pinned as a golden file; the run-shape
/// statistics (`persist_steps`, `commits`, heal tallies) are for human
/// output.
#[derive(Debug, Clone)]
pub struct CrashReport {
    pub scenario: String,
    pub mode: SweepMode,
    /// Cut points executed (every one ran a full re-execution).
    pub cuts: usize,
    pub seed: u64,
    pub horizon_us: u64,
    /// Persist steps the reference run enumerated.
    pub persist_steps: usize,
    /// Journaled (non-empty) commits the reference run completed.
    pub commits: usize,
    /// Cut runs healed by rolling the interrupted commit back.
    pub rolled_back: usize,
    /// Cut runs healed by rolling the interrupted commit forward.
    pub rolled_forward: usize,
    /// Cut runs that left no interrupted commit to heal (the cut landed
    /// before the commit journaled anything durable).
    pub clean_cuts: usize,
    /// Consistency violations, one line each. Empty means the claim held
    /// at every cut point.
    pub violations: Vec<String>,
}

impl CrashReport {
    /// Did every cut point recover to a bit-exact commit boundary?
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn mode_label(&self) -> &'static str {
        match self.mode {
            SweepMode::Exhaustive => "exhaustive",
            SweepMode::Sample { .. } => "sample",
        }
    }

    /// Golden-stable JSON document (see the type docs for what is
    /// deliberately excluded).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("mode", Json::Str(self.mode_label().into())),
            ("cuts", Json::Num(self.cuts as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("horizon_us", Json::Num(self.horizon_us as f64)),
            (
                "violations",
                Json::Arr(self.violations.iter().cloned().map(Json::Str).collect()),
            ),
            (
                "verdict",
                Json::Str(if self.clean() { "clean" } else { "violations" }.into()),
            ),
        ])
    }

    /// Human-readable summary (one line) for progress output.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} cuts over {} persist steps ({} commits): \
             {} rolled back, {} rolled forward, {} clean, {} violations",
            self.scenario,
            self.cuts,
            self.persist_steps,
            self.commits,
            self.rolled_back,
            self.rolled_forward,
            self.clean_cuts,
            self.violations.len()
        )
    }
}

/// Run the crash sweep for `spec` under `mode`.
pub fn sweep_scenario(spec: &ScenarioSpec, mode: SweepMode) -> Result<CrashReport> {
    sweep_inner(spec, mode, false)
}

/// Negative control: the same sweep with the store's commit order broken
/// (record before flushes). A correct sweep MUST report violations here.
#[doc(hidden)]
pub fn sweep_scenario_sabotaged(spec: &ScenarioSpec, mode: SweepMode) -> Result<CrashReport> {
    sweep_inner(spec, mode, true)
}

fn describe(p: FaultPoint) -> String {
    match p {
        FaultPoint::Boundary(s) => format!("cut@step{s}"),
        FaultPoint::Tear { step, offset } => format!("tear@step{step}+{offset}B"),
    }
}

fn sweep_inner(spec: &ScenarioSpec, mode: SweepMode, record_first: bool) -> Result<CrashReport> {
    // reference run: enumerate the persist steps and log the committed
    // digest at every commit boundary
    let mut reference = spec.build_engine()?;
    if record_first {
        reference.exec.nvm.debug_commit_record_first(true);
    }
    reference.exec.nvm.fault_mut().start_trace();
    reference.exec.nvm.start_digest_log();
    let _ = reference.run_to_end()?;
    let trace = reference.exec.nvm.fault_mut().take_trace().unwrap_or_default();
    let digests = reference.exec.nvm.take_digest_log().unwrap_or_default();
    let plan = FaultPlan::from_trace(&trace, mode);

    let mut report = CrashReport {
        scenario: spec.name.clone(),
        mode,
        cuts: plan.points.len(),
        seed: spec.seed,
        horizon_us: spec.horizon_us,
        persist_steps: trace.len(),
        commits: digests.len().saturating_sub(1),
        rolled_back: 0,
        rolled_forward: 0,
        clean_cuts: 0,
        violations: Vec::new(),
    };

    for &point in &plan.points {
        // re-execute with the device set to die at exactly this step
        let mut e = spec.build_engine()?;
        if record_first {
            e.exec.nvm.debug_commit_record_first(true);
        }
        e.exec.nvm.fault_mut().arm(point);
        let run = e.run_to_end();
        if !e.exec.nvm.fault().tripped() {
            // the armed step never executed: the cut run diverged from
            // the reference run's persist-step enumeration
            report.violations.push(format!(
                "{}: armed cut never fired (run {})",
                describe(point),
                if run.is_ok() { "completed" } else { "failed early" }
            ));
            continue;
        }
        let records = e.exec.nvm.fault().records_done() as usize;
        // reboot: volatile state is lost, torn durable state survives
        e.exec.nvm.power_failure_reset();
        match e.exec.nvm.recover() {
            Recovery::Clean => report.clean_cuts += 1,
            Recovery::RolledBack => report.rolled_back += 1,
            Recovery::RolledForward => report.rolled_forward += 1,
        }
        let got = e.exec.nvm.committed_digest();
        match digests.get(records) {
            Some(&want) if want == got => {}
            Some(&want) => report.violations.push(format!(
                "{}: recovered digest {got:016x} != reference {want:016x} \
                 after {records} durable commits",
                describe(point)
            )),
            None => report.violations.push(format!(
                "{}: {records} durable commit records exceed the reference \
                 log ({} commits)",
                describe(point),
                digests.len().saturating_sub(1)
            )),
        }
        // the healed store must boot a fresh device: run state and
        // learner restore with no error
        let mut twin = spec.build_engine()?;
        twin.exec.nvm = std::mem::take(&mut e.exec.nvm);
        if let Err(err) = twin.restore_run_state() {
            report.violations.push(format!(
                "{}: run-state restore failed after heal: {err}",
                describe(point)
            ));
            continue;
        }
        if let Err(err) = twin.learner.restore(&mut twin.exec.nvm) {
            report.violations.push(format!(
                "{}: learner restore failed after heal: {err}",
                describe(point)
            ));
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario;

    /// A deliberately tiny vibration world: a 30-second horizon keeps the
    /// persist-step count (and so the exhaustive cut count) small enough
    /// to re-execute at every point.
    fn short_vibration() -> ScenarioSpec {
        scenario::preset("vibration", 7, 30_000_000).unwrap()
    }

    #[test]
    fn exhaustive_sweep_of_a_short_vibration_run_is_clean() {
        let spec = short_vibration();
        let r = sweep_scenario(&spec, SweepMode::Exhaustive).unwrap();
        assert!(r.persist_steps > 0, "no persist steps enumerated");
        assert!(r.commits > 0, "no journaled commits");
        assert!(r.cuts >= r.persist_steps, "boundaries alone cover steps");
        assert_eq!(r.violations, Vec::<String>::new());
        assert!(r.clean());
        // cuts before a commit's record must have rolled it back
        assert!(r.rolled_back > 0, "no cut landed inside a commit");
        // a valid record is adopted immediately in commit_action, so the
        // injector can never strand one un-adopted: every heal rolls back
        assert_eq!(r.rolled_forward, 0);
        assert_eq!(
            r.rolled_back + r.rolled_forward + r.clean_cuts,
            r.cuts,
            "every cut healed exactly once"
        );
        let doc = r.to_json().to_string();
        assert!(doc.contains("\"verdict\":\"clean\""), "{doc}");
        assert!(doc.contains("\"mode\":\"exhaustive\""), "{doc}");
    }

    #[test]
    fn negative_control_the_record_first_bug_is_caught() {
        // break the commit order (record before flushes) and the sweep
        // must find digest corruption — if it cannot catch a planted
        // wrong-order bug, a clean verdict means nothing
        let spec = short_vibration();
        let r = sweep_scenario_sabotaged(&spec, SweepMode::Exhaustive).unwrap();
        assert!(!r.clean(), "sabotaged store passed the sweep");
        assert!(
            r.violations.iter().any(|v| v.contains("digest")),
            "violations never mention the digest mismatch: {:?}",
            r.violations
        );
        let doc = r.to_json().to_string();
        assert!(doc.contains("\"verdict\":\"violations\""), "{doc}");
    }

    #[test]
    fn exhaustive_sweep_of_a_forecast_run_with_elisions_is_clean() {
        // Forecast mode skips probe-grid checkpoints it can prove redundant.
        // Elision is a pure function of simulation state, so the reference
        // run and every cut re-execution elide identically and the journaled
        // commit sequences stay aligned — an elided checkpoint must never
        // widen the replay window past a boundary this sweep verifies.
        let mut spec = short_vibration();
        spec.policy = Some(crate::scenario::PolicySpec { forecast: true });
        // the elision path must actually fire in this world, otherwise the
        // sweep below exercises nothing new
        let r0 = spec.build_engine().unwrap().run().unwrap();
        assert!(
            r0.checkpoints_elided > 0,
            "short vibration world never elided a checkpoint"
        );
        assert!(r0.checkpoints_taken >= 1, "final horizon save must persist");
        let r = sweep_scenario(&spec, SweepMode::Exhaustive).unwrap();
        assert!(r.persist_steps > 0, "no persist steps enumerated");
        assert!(r.commits > 0, "no journaled commits");
        assert_eq!(r.violations, Vec::<String>::new());
        assert!(r.clean());
        assert_eq!(
            r.rolled_back + r.rolled_forward + r.clean_cuts,
            r.cuts,
            "every cut healed exactly once"
        );
    }

    #[test]
    fn sampled_sweeps_are_seeded_and_stable() {
        let spec = short_vibration();
        let mode = SweepMode::Sample { n: 6, seed: 9 };
        let a = sweep_scenario(&spec, mode).unwrap();
        let b = sweep_scenario(&spec, mode).unwrap();
        assert_eq!(a.cuts, 6);
        assert!(a.clean(), "{:?}", a.violations);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!(a.to_json().to_string().contains("\"mode\":\"sample\""));
    }
}
