//! Deterministic power-failure injection (crash-consistency engine).
//!
//! The paper's §3.5 correctness claim is that an intermittent learner
//! survives a power failure at *any* instant. The dynamic half of
//! checking that claim (the static half is [`crate::analysis`]) is a
//! file-system-style crash sweep: run a scenario once to enumerate its
//! **persist steps** — the durable sub-operations of every NVM commit
//! ([`crate::nvm::Nvm`] flushes staged slots in a defined order, then
//! writes a checksummed commit record last) — then re-execute, cutting
//! power at each step boundary and at byte-granular tear points inside a
//! step, and assert the recovered store is bit-identical to an
//! uninterrupted twin at the corresponding commit.
//!
//! This module holds the mechanism: [`FaultInjector`] (armed with one
//! [`FaultPoint`], it kills the device at exactly that persist step),
//! [`FaultPlan`] (enumerates or samples the cut points of a recorded
//! step trace), the FNV-1a digests the sweep compares, and
//! [`decide`] — the one source of truth for the randomized
//! abort/reboot schedules the failure-injection property tests drive.
//! The sweep driver itself lives in [`sweep`].

pub mod sweep;

use crate::util::rng::Rng;

// ---- FNV-1a 64-bit (no external hash deps in the vendor set) -----------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streaming FNV-1a 64-bit hasher: the checksum on the NVM commit record
/// and the digest the crash sweep compares committed images with.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64(FNV_OFFSET)
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }

    #[inline]
    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot FNV-1a 64 over `bytes`.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

// ---- persist steps and fault points ------------------------------------

/// What kind of durable sub-operation a persist step is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    /// One staged slot's bytes flushed to the durable redo area.
    Flush,
    /// The checksummed commit record (written last in a correct commit).
    Record,
}

/// One persist step as observed by a reference (trace-armed) run.
#[derive(Debug, Clone, PartialEq)]
pub struct StepInfo {
    pub kind: StepKind,
    /// Key name for `Flush` steps; `"<commit-record>"` for `Record`.
    pub key: String,
    /// Durable payload size of the step in bytes.
    pub bytes: usize,
}

/// Where to kill the device. Steps are numbered globally across the run
/// in execution order, starting at 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// Power fails at the boundary **before** persist step `n` executes:
    /// steps `0..n` are durable, step `n` and everything after never
    /// happen.
    Boundary(u64),
    /// Power fails **inside** persist step `step`: only the first
    /// `offset` bytes of its payload reach durable media (a torn write).
    Tear { step: u64, offset: usize },
}

/// What the injector tells the store to do with the current persist step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// Step completes durably.
    Run,
    /// Power failed before the step: nothing of it is durable.
    Cut,
    /// Power failed mid-step: the first `n` payload bytes are durable,
    /// the rest never land.
    Tear(usize),
}

/// Seeded, reproducible power-failure injector. One lives inside every
/// [`crate::nvm::Nvm`]; disarmed it costs a branch per persist step.
/// Arm it with a [`FaultPoint`] and the store dies at exactly that step
/// — every NVM operation afterwards returns
/// [`crate::error::Error::PowerCut`] without mutating, so the torn
/// durable state survives intact for recovery to inspect.
#[derive(Debug, Clone, Default)]
pub struct FaultInjector {
    armed: Option<FaultPoint>,
    next_step: u64,
    records_done: u64,
    tripped: bool,
    trace: Option<Vec<StepInfo>>,
}

impl FaultInjector {
    /// Arm a single fault point (replaces any previous one).
    pub fn arm(&mut self, point: FaultPoint) {
        self.armed = Some(point);
    }

    /// Disarm without clearing counters.
    pub fn disarm(&mut self) {
        self.armed = None;
    }

    /// Has the armed fault fired? While true the owning store is dead.
    pub fn tripped(&self) -> bool {
        self.tripped
    }

    /// Commit records durably completed so far — the index into the
    /// reference run's per-commit digest log that recovery must land on.
    pub fn records_done(&self) -> u64 {
        self.records_done
    }

    /// Persist steps observed so far (the next step gets this index).
    pub fn steps_seen(&self) -> u64 {
        self.next_step
    }

    /// Start recording a [`StepInfo`] trace (reference-run mode).
    pub fn start_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Stop recording and take the trace (`None` if never started).
    pub fn take_trace(&mut self) -> Option<Vec<StepInfo>> {
        self.trace.take()
    }

    /// Host reboot after a trip: the device comes back up with the
    /// injector quiet (one cut per run) but its counters intact, so the
    /// sweep can still read [`FaultInjector::records_done`].
    pub fn reboot(&mut self) {
        self.tripped = false;
        self.armed = None;
    }

    /// Kill the device outside any persist step (fixture hook for torn
    /// states the step-indexed points cannot reach).
    pub fn force_trip(&mut self) {
        self.tripped = true;
    }

    /// Called by the store at each persist step, in execution order.
    /// Decides whether the step runs, is cut, or tears, and advances the
    /// step/record counters.
    pub fn on_step(&mut self, kind: StepKind, key: &str, bytes: usize) -> StepOutcome {
        if let Some(t) = self.trace.as_mut() {
            t.push(StepInfo {
                kind,
                key: key.to_string(),
                bytes,
            });
        }
        let idx = self.next_step;
        self.next_step += 1;
        let outcome = match self.armed {
            Some(FaultPoint::Boundary(n)) if n == idx => StepOutcome::Cut,
            Some(FaultPoint::Tear { step, offset }) if step == idx => {
                if bytes < 2 {
                    // nothing to tear: degrade to a boundary cut
                    StepOutcome::Cut
                } else {
                    StepOutcome::Tear(offset.clamp(1, bytes - 1))
                }
            }
            _ => StepOutcome::Run,
        };
        match outcome {
            StepOutcome::Run => {
                if kind == StepKind::Record {
                    self.records_done += 1;
                }
            }
            StepOutcome::Cut | StepOutcome::Tear(_) => self.tripped = true,
        }
        outcome
    }
}

// ---- cut-point planning ------------------------------------------------

/// How many cuts a sweep runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepMode {
    /// Every step boundary, plus representative tear offsets (first,
    /// middle, last byte) inside every step with a tearable payload.
    Exhaustive,
    /// Exactly `n` seeded draws over (step, boundary-or-tear, offset).
    Sample { n: usize, seed: u64 },
}

/// The cut points a crash sweep will execute, derived from a reference
/// run's persist-step trace.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub points: Vec<FaultPoint>,
}

impl FaultPlan {
    /// Build the cut list for `trace` under `mode`. Deterministic: the
    /// same trace and mode always yield the same points, in the same
    /// order.
    pub fn from_trace(trace: &[StepInfo], mode: SweepMode) -> FaultPlan {
        let mut points = Vec::new();
        match mode {
            SweepMode::Exhaustive => {
                for (s, info) in trace.iter().enumerate() {
                    let s = s as u64;
                    points.push(FaultPoint::Boundary(s));
                    if info.bytes >= 2 {
                        let mut offs = [1, info.bytes / 2, info.bytes - 1];
                        offs.sort_unstable();
                        let mut last = 0usize;
                        for &o in &offs {
                            if o != last {
                                points.push(FaultPoint::Tear { step: s, offset: o });
                                last = o;
                            }
                        }
                    }
                }
            }
            SweepMode::Sample { n, seed } => {
                let mut rng = Rng::new(seed);
                for _ in 0..n {
                    if trace.is_empty() {
                        break;
                    }
                    let step = rng.below_usize(trace.len());
                    let bytes = trace[step].bytes;
                    if bytes >= 2 && rng.chance(0.5) {
                        let offset = 1 + rng.below_usize(bytes - 1);
                        points.push(FaultPoint::Tear {
                            step: step as u64,
                            offset,
                        });
                    } else {
                        points.push(FaultPoint::Boundary(step as u64));
                    }
                }
            }
        }
        FaultPlan { points }
    }
}

// ---- randomized abort/reboot schedules ---------------------------------

/// One step of a randomized failure schedule (see [`decide`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    /// Power fails mid-action: the open NVM transaction aborts.
    pub abort: bool,
    /// The host reboots: state is restored from NVM into fresh objects.
    pub reboot: bool,
}

/// The one source of truth for the failure-injection property tests'
/// random schedules: draw an abort with probability `p_abort`, and a
/// reboot that always follows an abort or otherwise fires with
/// probability `p_reboot`. Draw order is pinned — `p_reboot` is only
/// drawn when the abort draw came up false (short-circuit) — so
/// schedules generated before this helper existed replay bit-for-bit.
pub fn decide(rng: &mut Rng, p_abort: f32, p_reboot: f32) -> Decision {
    let abort = rng.f32() < p_abort;
    let reboot = abort || rng.f32() < p_reboot;
    Decision { abort, reboot }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn steps(sizes: &[usize]) -> Vec<StepInfo> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &b)| StepInfo {
                kind: if i % 3 == 2 {
                    StepKind::Record
                } else {
                    StepKind::Flush
                },
                key: format!("k{i}"),
                bytes: b,
            })
            .collect()
    }

    #[test]
    fn disarmed_injector_runs_every_step() {
        let mut inj = FaultInjector::default();
        for i in 0..5 {
            let kind = if i == 4 { StepKind::Record } else { StepKind::Flush };
            assert_eq!(inj.on_step(kind, "k", 8), StepOutcome::Run);
        }
        assert!(!inj.tripped());
        assert_eq!(inj.steps_seen(), 5);
        assert_eq!(inj.records_done(), 1);
    }

    #[test]
    fn boundary_cut_fires_once_at_the_armed_step() {
        let mut inj = FaultInjector::default();
        inj.arm(FaultPoint::Boundary(2));
        assert_eq!(inj.on_step(StepKind::Flush, "a", 8), StepOutcome::Run);
        assert_eq!(inj.on_step(StepKind::Flush, "b", 8), StepOutcome::Run);
        assert_eq!(inj.on_step(StepKind::Record, "r", 24), StepOutcome::Cut);
        assert!(inj.tripped());
        // the cut step's record never completed
        assert_eq!(inj.records_done(), 0);
        inj.reboot();
        assert!(!inj.tripped());
        // quiet after reboot: no re-fire
        assert_eq!(inj.on_step(StepKind::Record, "r", 24), StepOutcome::Run);
        assert_eq!(inj.records_done(), 1);
    }

    #[test]
    fn tear_clamps_to_a_proper_prefix() {
        let mut inj = FaultInjector::default();
        inj.arm(FaultPoint::Tear { step: 0, offset: 999 });
        assert_eq!(inj.on_step(StepKind::Flush, "a", 16), StepOutcome::Tear(15));
        let mut inj = FaultInjector::default();
        inj.arm(FaultPoint::Tear { step: 0, offset: 0 });
        assert_eq!(inj.on_step(StepKind::Flush, "a", 16), StepOutcome::Tear(1));
        // a 1-byte payload cannot tear: degrade to a boundary cut
        let mut inj = FaultInjector::default();
        inj.arm(FaultPoint::Tear { step: 0, offset: 1 });
        assert_eq!(inj.on_step(StepKind::Flush, "a", 1), StepOutcome::Cut);
    }

    #[test]
    fn trace_records_every_step_in_order() {
        let mut inj = FaultInjector::default();
        inj.start_trace();
        inj.on_step(StepKind::Flush, "x", 4);
        inj.on_step(StepKind::Record, "<commit-record>", 36);
        let trace = inj.take_trace().unwrap();
        assert_eq!(
            trace,
            vec![
                StepInfo {
                    kind: StepKind::Flush,
                    key: "x".into(),
                    bytes: 4
                },
                StepInfo {
                    kind: StepKind::Record,
                    key: "<commit-record>".into(),
                    bytes: 36
                },
            ]
        );
        assert!(inj.take_trace().is_none());
    }

    #[test]
    fn exhaustive_plan_covers_every_boundary_and_tears_wide_steps() {
        let trace = steps(&[1, 8, 2]);
        let plan = FaultPlan::from_trace(&trace, SweepMode::Exhaustive);
        // step 0 (1 B): boundary only; step 1 (8 B): boundary + tears at
        // 1/4/7; step 2 (2 B): boundary + tear at 1 (dedup'd)
        assert_eq!(
            plan.points,
            vec![
                FaultPoint::Boundary(0),
                FaultPoint::Boundary(1),
                FaultPoint::Tear { step: 1, offset: 1 },
                FaultPoint::Tear { step: 1, offset: 4 },
                FaultPoint::Tear { step: 1, offset: 7 },
                FaultPoint::Boundary(2),
                FaultPoint::Tear { step: 2, offset: 1 },
            ]
        );
    }

    #[test]
    fn sampled_plan_is_seeded_and_exactly_n() {
        let trace = steps(&[8, 16, 24, 4]);
        let a = FaultPlan::from_trace(&trace, SweepMode::Sample { n: 10, seed: 7 });
        let b = FaultPlan::from_trace(&trace, SweepMode::Sample { n: 10, seed: 7 });
        let c = FaultPlan::from_trace(&trace, SweepMode::Sample { n: 10, seed: 8 });
        assert_eq!(a.points, b.points);
        assert_ne!(a.points, c.points);
        assert_eq!(a.points.len(), 10);
        for p in &a.points {
            match *p {
                FaultPoint::Boundary(s) => assert!((s as usize) < trace.len()),
                FaultPoint::Tear { step, offset } => {
                    let bytes = trace[step as usize].bytes;
                    assert!(offset >= 1 && offset < bytes, "{offset} of {bytes}");
                }
            }
        }
        // an empty trace yields an empty plan, not a hang
        let none = FaultPlan::from_trace(&[], SweepMode::Sample { n: 5, seed: 1 });
        assert!(none.points.is_empty());
    }

    #[test]
    fn decide_replays_the_hand_rolled_draw_order() {
        // the idiom `decide` replaced: a second draw only when the first
        // came up false
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..200 {
            let abort = a.f32() < 0.3;
            let reboot = abort || a.f32() < 0.1;
            let d = decide(&mut b, 0.3, 0.1);
            assert_eq!(d, Decision { abort, reboot });
        }
        // generators end in the same state: downstream draws line up too
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fnv_streaming_matches_one_shot() {
        let mut h = Fnv64::new();
        h.update(b"inter");
        h.update(b"mittent");
        assert_eq!(h.finish(), fnv1a(b"intermittent"));
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }
}
