//! Compute-backend abstraction for the numeric action payloads.
//!
//! Every learner dispatches its `extract` / `learn` / `infer` math through
//! [`ComputeBackend`]. Two implementations:
//!
//! * [`native::NativeBackend`] — pure-rust transcription of the same math
//!   (semantically identical to `python/compile/kernels/ref.py`), used for
//!   the large figure sweeps where millions of payload calls are made;
//! * [`pjrt::PjrtBackend`] — executes the AOT HLO artifacts produced by
//!   `python/compile/aot.py` on the PJRT CPU client, proving the
//!   L1 (Pallas) → L2 (JAX) → L3 (rust) stack composes end-to-end.
//!
//! Integration tests assert both backends agree within float tolerance on
//! random inputs, which transitively pins the native path to the Pallas
//! kernels (pytest pins kernels ↔ ref, `backend_parity` pins pjrt ↔
//! native).

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::error::Result;

/// Canonical artifact shapes — must match `python/compile/kernels/ref.py`.
pub mod shapes {
    /// Samples per sensing window.
    pub const WINDOW: usize = 64;
    /// Sensor channels in the artifact (apps use a prefix, rest zero).
    pub const CHANNELS: usize = 4;
    /// Features per channel emitted by `extract`.
    pub const N_FEATURES: usize = 8;
    /// Flattened example dimension.
    pub const FEAT_DIM: usize = CHANNELS * N_FEATURES;
    /// k-NN example-buffer capacity.
    pub const N_BUF: usize = 64;
    /// Paper's k for the anomaly score.
    pub const K_NEIGHBORS: usize = 3;
    /// Clusters of the NN-k-means learner (normal / abnormal).
    pub const N_CLUSTERS: usize = 2;
    /// Anomaly-threshold percentile.
    pub const PCTL: f64 = 0.9;
    /// Batched-inference width.
    pub const BATCH: usize = 16;
    /// k-last-lists list length.
    pub const KLAST: usize = 4;
}

/// One shard's k-NN `learn` slice of a wake-cohort call. The caller
/// (a population-scale fleet) lays shard state out struct-of-arrays —
/// flat per-lane buffers with disjoint `&mut` slices — and hands the
/// whole cohort to the backend in one [`ComputeBackend::knn_learn_cohort`]
/// call instead of one `knn_learn` call per shard.
pub struct KnnLearnJob<'a> {
    /// Cohort lane: the shard's stable slot across batched calls.
    /// Backends key per-lane incremental caches on it.
    pub lane: usize,
    /// (N_BUF, FEAT_DIM) example buffer.
    pub examples: &'a [f32],
    /// (N_BUF) validity mask.
    pub mask: &'a [f32],
    /// Out: per-example anomaly scores (len N_BUF, caller scratch).
    pub scores: &'a mut [f32],
    /// Out: the recomputed anomaly threshold.
    pub threshold: &'a mut f32,
}

/// One shard's k-means `learn` slice of a wake-cohort call.
pub struct KmeansLearnJob<'a> {
    /// Cohort lane (see [`KnnLearnJob::lane`]).
    pub lane: usize,
    /// (N_CLUSTERS, FEAT_DIM) centroids, updated in place.
    pub w: &'a mut [f32],
    /// The example to fold in.
    pub x: &'a [f32],
    pub eta: f32,
    /// Out: cluster activations.
    pub acts: &'a mut [f32; shapes::N_CLUSTERS],
    /// Out: the winning cluster.
    pub winner: &'a mut usize,
}

/// Numeric payloads of the learning actions. All buffers are row-major
/// f32 at the canonical shapes above.
///
/// Not `Send`: the PJRT client is thread-pinned; parallel sweeps build one
/// engine (and backend) per worker thread instead of sharing one.
///
/// The `*_cohort` entry points take every shard that woke at the same
/// event in one call. Their default implementations are the scalar loop
/// (bit-identical by construction); backends override them to batch —
/// the pjrt backend rides the BATCH-wide artifacts and per-lane device
/// caches, so a thousand-shard wake costs ~n/BATCH dispatches instead
/// of n.
pub trait ComputeBackend {
    /// `extract`: (WINDOW, CHANNELS) window -> (CHANNELS * N_FEATURES)
    /// flattened feature matrix.
    fn extract(&mut self, window: &[f32]) -> Result<Vec<f32>>;

    /// k-NN `learn`: (N_BUF, FEAT_DIM) examples + (N_BUF) validity mask.
    /// Writes the per-example anomaly scores into `scores` (len N_BUF,
    /// caller-owned scratch — the learn hot path allocates nothing) and
    /// returns the 90th-percentile threshold.
    fn knn_learn(&mut self, examples: &[f32], mask: &[f32], scores: &mut [f32]) -> Result<f32>;

    /// k-NN `infer`: anomaly score of one example against the buffer.
    fn knn_infer(&mut self, examples: &[f32], mask: &[f32], x: &[f32]) -> Result<f32>;

    /// Batched k-NN `infer` ((BATCH, FEAT_DIM) queries). Writes the
    /// BATCH scores into `scores` (caller-owned scratch — allocation-free,
    /// like `knn_learn`).
    fn knn_infer_batch(
        &mut self,
        examples: &[f32],
        mask: &[f32],
        xs: &[f32],
        scores: &mut [f32],
    ) -> Result<()>;

    /// Wake-cohort k-NN `infer`: score `queries` (flat, any count ×
    /// FEAT_DIM) against one example buffer, writing one score per query
    /// into `scores`. Used for a shard's whole evaluation probe set (and
    /// any same-model query cohort) in one backend call.
    fn knn_infer_cohort(
        &mut self,
        examples: &[f32],
        mask: &[f32],
        queries: &[f32],
        scores: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(queries.len(), scores.len() * shapes::FEAT_DIM);
        for (q, s) in queries
            .chunks_exact(shapes::FEAT_DIM)
            .zip(scores.iter_mut())
        {
            *s = self.knn_infer(examples, mask, q)?;
        }
        Ok(())
    }

    /// Wake-cohort k-NN `learn`: one call for every shard that woke at
    /// the same event. Each job's outputs must be bit-identical to a
    /// scalar `knn_learn` on the same inputs (the default is that loop).
    fn knn_learn_cohort(&mut self, jobs: &mut [KnnLearnJob<'_>]) -> Result<()> {
        for j in jobs.iter_mut() {
            *j.threshold = self.knn_learn(j.examples, j.mask, j.scores)?;
        }
        Ok(())
    }

    /// Wake-cohort k-means `learn` (see [`Self::knn_learn_cohort`]).
    fn kmeans_learn_cohort(&mut self, jobs: &mut [KmeansLearnJob<'_>]) -> Result<()> {
        for j in jobs.iter_mut() {
            *j.winner = self.kmeans_learn(j.w, j.x, j.eta, j.acts)?;
        }
        Ok(())
    }

    /// k-means `learn`: one competitive step, updating `w`
    /// ((N_CLUSTERS, FEAT_DIM)) in place. Writes the cluster activations
    /// into `acts` and returns the winner index. Allocation-free.
    fn kmeans_learn(
        &mut self,
        w: &mut [f32],
        x: &[f32],
        eta: f32,
        acts: &mut [f32; shapes::N_CLUSTERS],
    ) -> Result<usize>;

    /// k-means `infer`: cluster activations.
    fn kmeans_infer(&mut self, w: &[f32], x: &[f32]) -> Result<Vec<f32>>;

    /// k-last-lists scores: [div(B), div(B+x), rep(B,B'), rep(B+x,B')].
    fn diversity_repr(&mut self, b: &[f32], bp: &[f32], x: &[f32]) -> Result<[f32; 4]>;

    /// Backend name for logs/benches.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::shapes::*;

    #[test]
    fn shapes_are_consistent() {
        assert_eq!(FEAT_DIM, CHANNELS * N_FEATURES);
        assert!(K_NEIGHBORS < N_BUF);
        assert!(KLAST < N_BUF);
    }
}
