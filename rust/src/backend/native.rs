//! Pure-rust compute backend — a transcription of the oracle math in
//! `python/compile/kernels/ref.py` (same masking, same "enough
//! neighbours" rule, same ceil-percentile threshold), used for the large
//! simulation sweeps where PJRT dispatch overhead would dominate.
//!
//! Parity with [`super::pjrt::PjrtBackend`] is asserted by the
//! `backend_parity` integration test.

use super::shapes::*;
use super::{ComputeBackend, KnnLearnJob};
use crate::error::Result;
use crate::util::stats;

/// Cached pairwise-distance matrix for `knn_learn` (§Perf): each learn
/// replaces one ring-buffer slot, so instead of the O(N²F) full recompute
/// the backend diffs the example buffer against the previous call and
/// refreshes only the changed rows/columns (O(ΔN·N·F)), then rebuilds the
/// O(N²) score pass. Distances per pair are computed by the same
/// `stats::euclidean`, so results are bit-identical to the full recompute
/// (asserted by `knn_learn_cache_matches_full_recompute`).
#[derive(Debug, Default, Clone)]
struct KnnMatrixCache {
    examples: Vec<f32>,
    mask: Vec<f32>,
    /// (N_BUF, N_BUF) Euclidean distances (diagonal = 0, unmasked).
    d: Vec<f32>,
}

/// Pure-rust backend (no external state).
#[derive(Debug, Default, Clone)]
pub struct NativeBackend {
    /// Scratch distance row reused across `knn_infer` calls (perf: avoids
    /// one allocation per inference on the hot path).
    scratch: Vec<f32>,
    /// Scratch channel buffer reused across `extract` calls.
    ch_scratch: Vec<f32>,
    /// Scratch list of changed rows reused across `knn_learn` calls.
    changed_scratch: Vec<usize>,
    /// Scratch of valid scores for the percentile pass of `knn_learn`.
    valid_scratch: Vec<f32>,
    /// Incremental distance-matrix cache for `knn_learn`.
    knn_cache: Option<KnnMatrixCache>,
    /// Per-lane distance-matrix caches for `knn_learn_cohort`: one slot
    /// per shard lane of a population-scale fleet, so interleaved shards
    /// keep their incremental O(ΔN·N·F) updates instead of evicting each
    /// other out of the single scalar cache.
    lane_caches: Vec<Option<KnnMatrixCache>>,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cluster activations a_j = -||x - w_j||^2 into a fixed scratch (see
    /// kernels/ref.py for why the distance form replaces the paper's raw
    /// dot product).
    fn kmeans_acts(w: &[f32], x: &[f32], acts: &mut [f32; N_CLUSTERS]) {
        for k in 0..N_CLUSTERS {
            let wk = &w[k * FEAT_DIM..(k + 1) * FEAT_DIM];
            acts[k] = -stats::sq_euclidean(x, wk);
        }
    }

    /// Sum of the k smallest values in `d` (ignores +inf entries).
    fn k_smallest_sum(d: &[f32], k: usize) -> f32 {
        // selection by partial insertion: k is tiny (3)
        let mut best = [f32::INFINITY; 8];
        let k = k.min(8);
        for &v in d {
            if v < best[k - 1] {
                // insert into sorted prefix
                let mut i = k - 1;
                while i > 0 && best[i - 1] > v {
                    best[i] = best[i - 1];
                    i -= 1;
                }
                best[i] = v;
            }
        }
        best[..k].iter().filter(|v| v.is_finite()).sum()
    }

    /// `knn_learn` body, parameterised by which incremental cache slot
    /// backs it: `None` = the scalar-path cache, `Some(lane)` = a cohort
    /// lane's cache. Results are bit-identical for any cache state (a
    /// stale or foreign cache just recomputes more rows — asserted by
    /// `knn_learn_cache_matches_full_recompute`), so the slot choice is
    /// purely a performance decision.
    fn knn_learn_slot(
        &mut self,
        lane: Option<usize>,
        examples: &[f32],
        mask: &[f32],
        scores: &mut [f32],
    ) -> Result<f32> {
        debug_assert_eq!(examples.len(), N_BUF * FEAT_DIM);
        debug_assert_eq!(mask.len(), N_BUF);
        debug_assert_eq!(scores.len(), N_BUF);
        let cnt = mask.iter().filter(|&&m| m > 0.5).count();
        scores.fill(0.0);
        if cnt <= K_NEIGHBORS {
            // model undefined; drop any cache (cheap) and bail
            return Ok(0.0);
        }

        // ---- incremental distance-matrix maintenance (§Perf) ----------
        if let Some(l) = lane {
            if self.lane_caches.len() <= l {
                self.lane_caches.resize_with(l + 1, || None);
            }
        }
        let slot = match lane {
            Some(l) => &mut self.lane_caches[l],
            None => &mut self.knn_cache,
        };
        let cache_ok = slot
            .as_ref()
            .map(|c| c.examples.len() == examples.len())
            .unwrap_or(false);
        let mut cache = if cache_ok {
            slot.take().unwrap()
        } else {
            KnnMatrixCache {
                examples: vec![f32::NAN; N_BUF * FEAT_DIM],
                mask: vec![f32::NAN; N_BUF],
                d: vec![0.0; N_BUF * N_BUF],
            }
        };
        // rows whose features changed since the cached call (scratch list
        // reused across calls — the learn hot path allocates nothing)
        let mut changed = std::mem::take(&mut self.changed_scratch);
        changed.clear();
        for i in 0..N_BUF {
            if cache.examples[i * FEAT_DIM..(i + 1) * FEAT_DIM]
                != examples[i * FEAT_DIM..(i + 1) * FEAT_DIM]
            {
                changed.push(i);
            }
        }
        for &i in &changed {
            let xi = &examples[i * FEAT_DIM..(i + 1) * FEAT_DIM];
            for j in 0..N_BUF {
                let v = if j == i {
                    0.0
                } else {
                    stats::euclidean(xi, &examples[j * FEAT_DIM..(j + 1) * FEAT_DIM])
                };
                cache.d[i * N_BUF + j] = v;
                cache.d[j * N_BUF + i] = v;
            }
        }
        cache.examples.copy_from_slice(examples);
        cache.mask.copy_from_slice(mask);
        self.changed_scratch = changed;

        // ---- O(N^2) score pass over the cached matrix ------------------
        // K_NEIGHBORS = 3 is baked into the unrolled min-insertion below;
        // the const assert keeps the shortcut honest.
        const { assert!(K_NEIGHBORS == 3) };
        for i in 0..N_BUF {
            if mask[i] <= 0.5 {
                continue;
            }
            let base = i * N_BUF;
            let (mut b0, mut b1, mut b2) = (f32::INFINITY, f32::INFINITY, f32::INFINITY);
            for j in 0..N_BUF {
                if j == i || mask[j] <= 0.5 {
                    continue;
                }
                let v = cache.d[base + j];
                if v < b2 {
                    if v < b1 {
                        b2 = b1;
                        if v < b0 {
                            b1 = b0;
                            b0 = v;
                        } else {
                            b1 = v;
                        }
                    } else {
                        b2 = v;
                    }
                }
            }
            let mut sum = 0.0;
            for b in [b0, b1, b2] {
                if b.is_finite() {
                    sum += b;
                }
            }
            scores[i] = sum;
        }
        match lane {
            Some(l) => self.lane_caches[l] = Some(cache),
            None => self.knn_cache = Some(cache),
        }

        // percentile over the valid scores, sorted in a reused scratch
        // (no per-call clone on the learn hot path)
        let mut valid = std::mem::take(&mut self.valid_scratch);
        valid.clear();
        valid.extend((0..N_BUF).filter(|&i| mask[i] > 0.5).map(|i| scores[i]));
        valid.sort_unstable_by(|a, b| a.total_cmp(b));
        let thr = stats::percentile_sorted(&valid, PCTL);
        self.valid_scratch = valid;
        Ok(thr)
    }
}

impl ComputeBackend for NativeBackend {
    fn extract(&mut self, window: &[f32]) -> Result<Vec<f32>> {
        debug_assert_eq!(window.len(), WINDOW * CHANNELS);
        let mut out = vec![0.0f32; CHANNELS * N_FEATURES];
        // §Perf: fused single pass per channel (was 7 separate passes +
        // an allocation inside `median`); see EXPERIMENTS.md §Perf.
        let mut ch_buf = std::mem::take(&mut self.ch_scratch);
        ch_buf.resize(WINDOW, 0.0);
        for ch in 0..CHANNELS {
            // gather the channel and accumulate the one-pass moments
            let mut sum = 0.0f64;
            let mut sq = 0.0f64;
            let mut abs = 0.0f64;
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            let mut adiff = 0.0f64;
            let mut prev = window[ch];
            for r in 0..WINDOW {
                let v = window[r * CHANNELS + ch];
                ch_buf[r] = v;
                let vd = v as f64;
                sum += vd;
                sq += vd * vd;
                abs += vd.abs();
                lo = lo.min(v);
                hi = hi.max(v);
                adiff += (v - prev).abs() as f64;
                prev = v;
            }
            let n = WINDOW as f64;
            let mean = (sum / n) as f32;
            // zero crossings around the mean need a second (cheap) sweep
            let mut crossings = 0u32;
            let mut psign = ch_buf[0] >= mean;
            for r in 1..WINDOW {
                let s = ch_buf[r] >= mean;
                crossings += (s != psign) as u32;
                psign = s;
            }
            ch_buf.sort_unstable_by(|a, b| a.total_cmp(b));
            let med = 0.5 * (ch_buf[WINDOW / 2 - 1] + ch_buf[WINDOW / 2]);

            let f = &mut out[ch * N_FEATURES..(ch + 1) * N_FEATURES];
            f[0] = mean;
            f[1] = ((sq / n - (sum / n) * (sum / n)).max(0.0)).sqrt() as f32;
            f[2] = med;
            f[3] = (sq / n).sqrt() as f32;
            f[4] = hi - lo;
            f[5] = crossings as f32 / (WINDOW - 1) as f32;
            f[6] = (adiff / (WINDOW - 1) as f64) as f32;
            f[7] = (abs / n) as f32;
        }
        self.ch_scratch = ch_buf;
        Ok(out)
    }

    fn knn_learn(&mut self, examples: &[f32], mask: &[f32], scores: &mut [f32]) -> Result<f32> {
        self.knn_learn_slot(None, examples, mask, scores)
    }

    fn knn_infer(&mut self, examples: &[f32], mask: &[f32], x: &[f32]) -> Result<f32> {
        debug_assert_eq!(x.len(), FEAT_DIM);
        let cnt = mask.iter().filter(|&&m| m > 0.5).count();
        if cnt < K_NEIGHBORS {
            return Ok(0.0);
        }
        let mut row = std::mem::take(&mut self.scratch);
        row.clear();
        row.resize(N_BUF, f32::INFINITY);
        for j in 0..N_BUF {
            if mask[j] > 0.5 {
                row[j] = stats::euclidean(x, &examples[j * FEAT_DIM..(j + 1) * FEAT_DIM]);
            }
        }
        let s = Self::k_smallest_sum(&row, K_NEIGHBORS);
        self.scratch = row;
        Ok(s)
    }

    fn knn_infer_batch(
        &mut self,
        examples: &[f32],
        mask: &[f32],
        xs: &[f32],
        scores: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(xs.len(), BATCH * FEAT_DIM);
        debug_assert_eq!(scores.len(), BATCH);
        for (x, s) in xs.chunks_exact(FEAT_DIM).zip(scores.iter_mut()) {
            *s = self.knn_infer(examples, mask, x)?;
        }
        Ok(())
    }

    fn knn_learn_cohort(&mut self, jobs: &mut [KnnLearnJob<'_>]) -> Result<()> {
        for j in jobs.iter_mut() {
            *j.threshold = self.knn_learn_slot(Some(j.lane), j.examples, j.mask, j.scores)?;
        }
        Ok(())
    }

    fn kmeans_learn(
        &mut self,
        w: &mut [f32],
        x: &[f32],
        eta: f32,
        acts: &mut [f32; N_CLUSTERS],
    ) -> Result<usize> {
        debug_assert_eq!(w.len(), N_CLUSTERS * FEAT_DIM);
        debug_assert_eq!(x.len(), FEAT_DIM);
        Self::kmeans_acts(w, x, acts);
        let winner = acts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        // winner row updated in place: Δw = η(x − w), no reallocation
        let row = &mut w[winner * FEAT_DIM..(winner + 1) * FEAT_DIM];
        for i in 0..FEAT_DIM {
            row[i] += eta * (x[i] - row[i]);
        }
        Ok(winner)
    }

    fn kmeans_infer(&mut self, w: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let mut acts = [0.0f32; N_CLUSTERS];
        Self::kmeans_acts(w, x, &mut acts);
        Ok(acts.to_vec())
    }

    fn diversity_repr(&mut self, b: &[f32], bp: &[f32], x: &[f32]) -> Result<[f32; 4]> {
        debug_assert_eq!(b.len(), KLAST * FEAT_DIM);
        debug_assert_eq!(bp.len(), KLAST * FEAT_DIM);
        let row = |set: &[f32], i: usize| -> Vec<f32> {
            set[i * FEAT_DIM..(i + 1) * FEAT_DIM].to_vec()
        };
        let mut bx: Vec<Vec<f32>> = (0..KLAST).map(|i| row(b, i)).collect();
        bx.push(x.to_vec());
        let bset: Vec<Vec<f32>> = (0..KLAST).map(|i| row(b, i)).collect();
        let bpset: Vec<Vec<f32>> = (0..KLAST).map(|i| row(bp, i)).collect();

        let div = |s: &[Vec<f32>]| -> f32 {
            let k = s.len();
            let mut sum = 0.0f64;
            for a in s {
                for c in s {
                    sum += stats::euclidean(a, c) as f64;
                }
            }
            (sum / (k * k) as f64) as f32
        };
        let rep = |s: &[Vec<f32>], t: &[Vec<f32>]| -> f32 {
            let mut sum = 0.0f64;
            for a in s {
                for c in t {
                    sum += stats::euclidean(a, c) as f64;
                }
            }
            (sum / (s.len() * t.len()) as f64) as f32
        };
        Ok([div(&bset), div(&bx), rep(&bset, &bpset), rep(&bx, &bpset)])
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn filled_buffer(rng: &mut Rng, count: usize) -> (Vec<f32>, Vec<f32>) {
        let mut ex = vec![0.0f32; N_BUF * FEAT_DIM];
        let mut mask = vec![0.0f32; N_BUF];
        for i in 0..count {
            mask[i] = 1.0;
            for j in 0..FEAT_DIM {
                ex[i * FEAT_DIM + j] = rng.normal(0.0, 3.0) as f32;
            }
        }
        (ex, mask)
    }

    #[test]
    fn knn_learn_threshold_brackets_scores() {
        let mut be = NativeBackend::new();
        let mut rng = Rng::new(1);
        let (ex, mask) = filled_buffer(&mut rng, 40);
        let mut scores = vec![0.0f32; N_BUF];
        let thr = be.knn_learn(&ex, &mask, &mut scores).unwrap();
        let valid: Vec<f32> = scores[..40].to_vec();
        let above = valid.iter().filter(|&&s| s > thr).count();
        // 90th percentile: ~10% strictly above
        assert!(above <= 5, "above {above}");
        assert!(thr > 0.0);
        // padded rows untouched
        assert!(scores[40..].iter().all(|&s| s == 0.0));
    }

    #[test]
    fn knn_learn_cache_matches_full_recompute() {
        // the incremental matrix cache must give bit-identical results to
        // a fresh backend's full recompute, across ring-buffer updates
        let mut cached = NativeBackend::new();
        let mut rng = Rng::new(99);
        let (mut ex, mut mask) = filled_buffer(&mut rng, 20);
        let mut slot = 20usize;
        for step in 0..30 {
            // mutate one ring slot like the learner does
            for j in 0..FEAT_DIM {
                ex[slot * FEAT_DIM + j] = rng.normal(0.0, 3.0) as f32;
            }
            mask[slot] = 1.0;
            slot = (slot + 1) % N_BUF;
            let mut s_inc = vec![0.0f32; N_BUF];
            let t_inc = cached.knn_learn(&ex, &mask, &mut s_inc).unwrap();
            let mut fresh = NativeBackend::new();
            let mut s_full = vec![0.0f32; N_BUF];
            let t_full = fresh.knn_learn(&ex, &mask, &mut s_full).unwrap();
            assert_eq!(s_inc, s_full, "scores diverged at step {step}");
            assert_eq!(t_inc, t_full, "threshold diverged at step {step}");
        }
    }

    #[test]
    fn knn_learn_insufficient_examples() {
        let mut be = NativeBackend::new();
        let mut rng = Rng::new(2);
        let (ex, mask) = filled_buffer(&mut rng, K_NEIGHBORS);
        let mut scores = vec![9.0f32; N_BUF];
        let thr = be.knn_learn(&ex, &mask, &mut scores).unwrap();
        assert!(scores.iter().all(|&s| s == 0.0));
        assert_eq!(thr, 0.0);
    }

    #[test]
    fn knn_infer_far_point_scores_high() {
        let mut be = NativeBackend::new();
        let mut rng = Rng::new(3);
        let (ex, mask) = filled_buffer(&mut rng, 30);
        let near = ex[..FEAT_DIM].to_vec();
        let far = vec![100.0f32; FEAT_DIM];
        let s_near = be.knn_infer(&ex, &mask, &near).unwrap();
        let s_far = be.knn_infer(&ex, &mask, &far).unwrap();
        assert!(s_far > 10.0 * s_near.max(0.1));
    }

    #[test]
    fn knn_batch_matches_scalar() {
        let mut be = NativeBackend::new();
        let mut rng = Rng::new(4);
        let (ex, mask) = filled_buffer(&mut rng, 25);
        let xs: Vec<f32> = (0..BATCH * FEAT_DIM)
            .map(|_| rng.normal(0.0, 3.0) as f32)
            .collect();
        let mut batch = vec![0.0f32; BATCH];
        be.knn_infer_batch(&ex, &mask, &xs, &mut batch).unwrap();
        for bidx in 0..BATCH {
            let s = be
                .knn_infer(&ex, &mask, &xs[bidx * FEAT_DIM..(bidx + 1) * FEAT_DIM])
                .unwrap();
            assert!((batch[bidx] - s).abs() < 1e-6);
        }
    }

    #[test]
    fn knn_infer_cohort_matches_scalar_bit_for_bit() {
        let mut be = NativeBackend::new();
        let mut rng = Rng::new(11);
        let (ex, mask) = filled_buffer(&mut rng, 25);
        // a non-BATCH-aligned cohort size exercises the tail
        let n = 21;
        let qs: Vec<f32> = (0..n * FEAT_DIM)
            .map(|_| rng.normal(0.0, 3.0) as f32)
            .collect();
        let mut scores = vec![0.0f32; n];
        be.knn_infer_cohort(&ex, &mask, &qs, &mut scores).unwrap();
        for i in 0..n {
            let s = be
                .knn_infer(&ex, &mask, &qs[i * FEAT_DIM..(i + 1) * FEAT_DIM])
                .unwrap();
            assert_eq!(scores[i], s, "query {i}");
        }
    }

    #[test]
    fn knn_learn_cohort_matches_interleaved_scalar_calls_bit_for_bit() {
        // Two shard lanes stepped in lockstep through ring updates: the
        // cohort path (per-lane caches) must reproduce what per-shard
        // scalar knn_learn on dedicated backends computes, bit for bit.
        use super::super::KnnLearnJob;
        let mut cohort_be = NativeBackend::new();
        let mut solo = [NativeBackend::new(), NativeBackend::new()];
        let mut rng = Rng::new(12);
        let mut shards: Vec<(Vec<f32>, Vec<f32>)> =
            (0..2).map(|_| filled_buffer(&mut rng, 15)).collect();
        let mut slot = 15usize;
        for step in 0..10 {
            for (ex, mask) in shards.iter_mut() {
                for j in 0..FEAT_DIM {
                    ex[slot * FEAT_DIM + j] = rng.normal(0.0, 3.0) as f32;
                }
                mask[slot] = 1.0;
            }
            slot = (slot + 1) % N_BUF;
            let mut scores = vec![vec![0.0f32; N_BUF]; 2];
            let mut thresholds = vec![0.0f32; 2];
            {
                let mut jobs: Vec<KnnLearnJob<'_>> = Vec::new();
                for (lane, ((ex, mask), (sc, th))) in shards
                    .iter()
                    .zip(scores.iter_mut().zip(thresholds.iter_mut()))
                    .enumerate()
                {
                    jobs.push(KnnLearnJob {
                        lane,
                        examples: ex,
                        mask,
                        scores: sc,
                        threshold: th,
                    });
                }
                cohort_be.knn_learn_cohort(&mut jobs).unwrap();
            }
            for lane in 0..2 {
                let (ex, mask) = &shards[lane];
                let mut want = vec![0.0f32; N_BUF];
                let t = solo[lane].knn_learn(ex, mask, &mut want).unwrap();
                assert_eq!(scores[lane], want, "lane {lane} step {step}");
                assert_eq!(thresholds[lane], t, "lane {lane} step {step}");
            }
        }
    }

    #[test]
    fn kmeans_learn_moves_winner_only() {
        let mut be = NativeBackend::new();
        let mut w = vec![0.0f32; N_CLUSTERS * FEAT_DIM];
        w[0] = 1.0; // cluster 0 aligned with x
        let mut x = vec![0.0f32; FEAT_DIM];
        x[0] = 2.0;
        x[1] = 2.0;
        let mut acts = [0.0f32; N_CLUSTERS];
        let win = be.kmeans_learn(&mut w, &x, 0.5, &mut acts).unwrap();
        assert_eq!(win, 0);
        assert!(acts[0] > acts[1]);
        assert!((w[0] - 1.5).abs() < 1e-6);
        assert!((w[1] - 1.0).abs() < 1e-6);
        // cluster 1 untouched
        assert!(w[FEAT_DIM..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn k_smallest_sum_matches_sort() {
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let v: Vec<f32> = (0..20).map(|_| rng.f32() * 10.0).collect();
            let mut sorted = v.clone();
            sorted.sort_by(|a, b| a.total_cmp(b));
            let want: f32 = sorted[..3].iter().sum();
            let got = NativeBackend::k_smallest_sum(&v, 3);
            assert!((got - want).abs() < 1e-5);
        }
    }

    #[test]
    fn diversity_repr_identical_sets() {
        let mut be = NativeBackend::new();
        let b = vec![1.0f32; KLAST * FEAT_DIM];
        let bp = vec![1.0f32; KLAST * FEAT_DIM];
        let x = vec![1.0f32; FEAT_DIM];
        let out = be.diversity_repr(&b, &bp, &x).unwrap();
        assert_eq!(out, [0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn extract_feature_layout() {
        let mut be = NativeBackend::new();
        // channel 0 constant 2.0, others zero
        let mut win = vec![0.0f32; WINDOW * CHANNELS];
        for r in 0..WINDOW {
            win[r * CHANNELS] = 2.0;
        }
        let f = be.extract(&win).unwrap();
        assert_eq!(f.len(), FEAT_DIM);
        assert!((f[0] - 2.0).abs() < 1e-6); // mean ch0
        assert!((f[3] - 2.0).abs() < 1e-6); // rms ch0
        assert_eq!(f[N_FEATURES], 0.0); // mean ch1
    }
}
