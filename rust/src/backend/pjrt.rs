//! PJRT compute backend: every payload call executes the corresponding
//! AOT HLO artifact (L1 Pallas kernel lowered through the L2 JAX model) on
//! the PJRT CPU client. This is the backend that proves the three layers
//! compose; the end-to-end example (`examples/end_to_end.rs`) and the
//! parity integration tests run on it.
//!
//! §Perf: the k-NN example buffer (N_BUF×FEAT_DIM + mask ≈ 8.4 KB) only
//! changes on `learn`, but is an input to every `infer` dispatch. The
//! backend keeps it resident on the device and re-uploads only when the
//! host copy changes, cutting per-inference host→device traffic to just
//! the query vector. Measured effect in EXPERIMENTS.md §Perf.

use super::shapes::*;
use super::{ComputeBackend, KnnLearnJob};
use crate::error::Result;
use crate::runtime::{Arg, Runtime};

/// Cached device residency for the k-NN buffer.
struct KnnDeviceCache {
    host_ex: Vec<f32>,
    host_mask: Vec<f32>,
    dev_ex: xla::PjRtBuffer,
    dev_mask: xla::PjRtBuffer,
}

/// Backend that dispatches to compiled PJRT executables.
pub struct PjrtBackend {
    rt: Runtime,
    knn_cache: Option<KnnDeviceCache>,
    /// Per-lane device caches for wake-cohort calls: each shard lane of
    /// a population-scale fleet keeps its own device-resident k-NN
    /// buffer, so interleaved shards don't evict each other.
    lane_caches: Vec<Option<KnnDeviceCache>>,
    /// Number of artifact executions (for perf accounting in benches).
    pub dispatches: u64,
    /// Host→device uploads of the k-NN buffer avoided by the cache.
    pub cache_hits: u64,
}

impl PjrtBackend {
    /// Wrap a runtime; compiles all artifacts eagerly.
    pub fn new(mut rt: Runtime) -> Result<Self> {
        rt.preload()?;
        Ok(PjrtBackend {
            rt,
            knn_cache: None,
            lane_caches: Vec::new(),
            dispatches: 0,
            cache_hits: 0,
        })
    }

    /// Discover artifacts relative to CWD.
    pub fn discover() -> Result<Self> {
        Self::new(Runtime::discover()?)
    }

    fn run(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.dispatches += 1;
        self.rt.load(name)?.run(inputs)
    }

    /// Ensure `slot` holds a current device copy of the k-NN buffer
    /// (associated fn so `rt` and the cache slot borrow disjointly).
    fn ensure_slot(
        rt: &mut Runtime,
        slot: &mut Option<KnnDeviceCache>,
        cache_hits: &mut u64,
        examples: &[f32],
        mask: &[f32],
    ) -> Result<()> {
        let stale = match slot {
            Some(c) => c.host_ex != examples || c.host_mask != mask,
            None => true,
        };
        if stale {
            let dev_ex = rt.upload(examples, &[N_BUF, FEAT_DIM])?;
            let dev_mask = rt.upload(mask, &[N_BUF])?;
            *slot = Some(KnnDeviceCache {
                host_ex: examples.to_vec(),
                host_mask: mask.to_vec(),
                dev_ex,
                dev_mask,
            });
        } else {
            *cache_hits += 1;
        }
        Ok(())
    }

    /// Ensure the k-NN buffer is device-resident and current.
    fn ensure_knn_cache(&mut self, examples: &[f32], mask: &[f32]) -> Result<()> {
        Self::ensure_slot(
            &mut self.rt,
            &mut self.knn_cache,
            &mut self.cache_hits,
            examples,
            mask,
        )
    }

    /// Dispatch a k-NN artifact against the cache in `lane` (`None` =
    /// the scalar-path cache).
    fn run_knn_slot(
        &mut self,
        name: &str,
        extra: &[&[f32]],
        lane: Option<usize>,
    ) -> Result<Vec<Vec<f32>>> {
        self.dispatches += 1;
        let exe = self.rt.load(name)?;
        let cache = match lane {
            Some(l) => self.lane_caches[l].as_ref().expect("lane cache ensured"),
            None => self.knn_cache.as_ref().expect("cache ensured"),
        };
        let mut args: Vec<Arg<'_>> = vec![
            Arg::Device(&cache.dev_ex),
            Arg::Device(&cache.dev_mask),
        ];
        args.extend(extra.iter().map(|x| Arg::Host(x)));
        exe.run_args(&args)
    }

    fn run_knn(&mut self, name: &str, extra: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.run_knn_slot(name, extra, None)
    }
}

impl ComputeBackend for PjrtBackend {
    fn extract(&mut self, window: &[f32]) -> Result<Vec<f32>> {
        let mut out = self.run("extract", &[window])?;
        Ok(out.remove(0)) // (C, 8) row-major == flattened FEAT_DIM layout
    }

    fn knn_learn(&mut self, examples: &[f32], mask: &[f32], scores: &mut [f32]) -> Result<f32> {
        self.ensure_knn_cache(examples, mask)?;
        let out = self.run_knn("knn_learn", &[])?;
        scores.copy_from_slice(&out[0]);
        Ok(out[1][0])
    }

    fn knn_infer(&mut self, examples: &[f32], mask: &[f32], x: &[f32]) -> Result<f32> {
        self.ensure_knn_cache(examples, mask)?;
        let out = self.run_knn("knn_infer", &[x])?;
        Ok(out[0][0])
    }

    fn knn_infer_batch(
        &mut self,
        examples: &[f32],
        mask: &[f32],
        xs: &[f32],
        scores: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(xs.len(), BATCH * FEAT_DIM);
        debug_assert_eq!(scores.len(), BATCH);
        self.ensure_knn_cache(examples, mask)?;
        let out = self.run_knn("knn_infer_batch", &[xs])?;
        scores.copy_from_slice(&out[0]);
        Ok(())
    }

    fn knn_infer_cohort(
        &mut self,
        examples: &[f32],
        mask: &[f32],
        queries: &[f32],
        scores: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(queries.len(), scores.len() * FEAT_DIM);
        self.ensure_knn_cache(examples, mask)?;
        // Ride the BATCH-wide artifact: ceil(n/BATCH) dispatches, the
        // tail zero-padded and its padding lanes discarded.
        let mut padded = [0.0f32; BATCH * FEAT_DIM];
        for (qs, ss) in queries
            .chunks(BATCH * FEAT_DIM)
            .zip(scores.chunks_mut(BATCH))
        {
            if ss.len() == BATCH {
                let out = self.run_knn("knn_infer_batch", &[qs])?;
                ss.copy_from_slice(&out[0]);
            } else {
                padded[..qs.len()].copy_from_slice(qs);
                padded[qs.len()..].fill(0.0);
                let out = self.run_knn("knn_infer_batch", &[&padded[..]])?;
                ss.copy_from_slice(&out[0][..ss.len()]);
            }
        }
        Ok(())
    }

    fn knn_learn_cohort(&mut self, jobs: &mut [KnnLearnJob<'_>]) -> Result<()> {
        for j in jobs.iter_mut() {
            let l = j.lane;
            if self.lane_caches.len() <= l {
                self.lane_caches.resize_with(l + 1, || None);
            }
            Self::ensure_slot(
                &mut self.rt,
                &mut self.lane_caches[l],
                &mut self.cache_hits,
                j.examples,
                j.mask,
            )?;
            let out = self.run_knn_slot("knn_learn", &[], Some(l))?;
            j.scores.copy_from_slice(&out[0]);
            *j.threshold = out[1][0];
        }
        Ok(())
    }

    fn kmeans_learn(
        &mut self,
        w: &mut [f32],
        x: &[f32],
        eta: f32,
        acts: &mut [f32; N_CLUSTERS],
    ) -> Result<usize> {
        let eta_buf = [eta];
        let out = self.run("kmeans_learn", &[&w[..], x, &eta_buf])?;
        acts.copy_from_slice(&out[1]);
        // Recover the winner the kernel actually updated from the weight
        // delta — re-deriving argmax host-side could disagree with the
        // HLO argmax on activation ties and dirty-mark the wrong row.
        let new_w = &out[0];
        let moved = (0..N_CLUSTERS).find(|&c| {
            new_w[c * FEAT_DIM..(c + 1) * FEAT_DIM] != w[c * FEAT_DIM..(c + 1) * FEAT_DIM]
        });
        w.copy_from_slice(new_w);
        // no row moved (η = 0 or winner already at x): any maximal row is
        // equivalent for delta purposes — fall back to host argmax
        let winner = moved.unwrap_or_else(|| {
            acts.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0)
        });
        Ok(winner)
    }

    fn kmeans_infer(&mut self, w: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let mut out = self.run("kmeans_infer", &[w, x])?;
        Ok(out.remove(0))
    }

    fn diversity_repr(&mut self, b: &[f32], bp: &[f32], x: &[f32]) -> Result<[f32; 4]> {
        let out = self.run("diversity_repr", &[b, bp, x])?;
        Ok([out[0][0], out[0][1], out[0][2], out[0][3]])
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
