//! The global discrete-event fleet scheduler: rendezvous as heap events
//! instead of fleet-wide round barriers.
//!
//! The PR-5 round scheduler ([`super::fleet::Fleet`]) pauses *every*
//! shard at *every* fleet-wide sync boundary — the slowest shard of a
//! round gates the whole population, and per-shard sync cadences are
//! unrepresentable. This module replaces that barrier for synced fleets
//! with a single global binary min-heap of `(wake_us, slot)` events:
//!
//! - Each resident shard is a component whose next wake is its own next
//!   sync boundary (`period, 2·period, … < horizon` over its *own*
//!   `sync_period_us`). An idle shard costs one heap entry, not a
//!   blocked worker.
//! - Popping a wake time `t` yields the rendezvous *group* at `t`: all
//!   shards whose boundary lands there. Heterogeneous cadences meet
//!   pairwise at shared instants (30 s and 60 s shards at 60 s
//!   multiples); a shard alone at its boundary goes solo for free.
//! - Quarantine backoff is event re-scheduling: a quarantined shard's
//!   wake is pushed out without waking the shard at all, and the skipped
//!   boundaries are flushed into its `syncs_skipped` counter at its next
//!   real wake.
//!
//! Determinism does not depend on worker timing. The heap is keyed on
//! `(wake_us, slot)` so equal-time pops are slot-ordered; a group at
//! time `t` is dispatched only when no in-flight shard could still push
//! an event at or before `t` (the dispatch gate `t < min(t' + period')`
//! over in-flight shards), so group membership is a pure function of
//! the simulated trajectories; and the group plan is built from
//! participants sorted by slot, whatever order their reports arrived
//! in. Under one uniform period the scheduler degenerates to exactly
//! the round barrier's groups, deadlines and gossip rotation, which is
//! pinned bit-identical to [`super::fleet::Fleet`]'s rounds path.
//!
//! Partner selection: uniform-period fleets keep the PR-5 rotation
//! (`offset = 1 + round % (m - 1)`) — required for the bit-identity pin
//! — while heterogeneous fleets use energy-aware pairing: the
//! capacitor-starved half of the participants merges the energy-rich
//! half's snapshots, deterministic with a slot tie-break.

use crate::error::{Error, Result};
use crate::learning::ModelSnapshot;
use crate::sim::engine::Engine;
use crate::sim::fleet::{
    shard_error, FleetResult, QuarantineState, Shard, ShardFactory, SyncPlan, SyncStrategy,
};
use crate::sim::RunResult;
use crate::util::pool;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};
use std::sync::{mpsc, Arc};

pub use crate::sim::fleet::FleetSched;

/// Total wake events the heap schedules for `periods` over `horizon_us`:
/// each shard contributes one event per boundary of its own cadence
/// (`period, 2·period, … < horizon`; 0 = the shard never syncs). The
/// round barrier's equivalent is `shards × boundaries(min period)` —
/// the gap is what retiring the barrier saves.
pub fn planned_wakes(periods: &[u64], horizon_us: u64) -> u64 {
    periods
        .iter()
        .map(|&p| {
            if p == 0 || horizon_us == 0 {
                0
            } else {
                (horizon_us - 1) / p
            }
        })
        .sum()
}

/// The PR-5 gossip rotation for a uniform-period rendezvous: at the
/// 0-based boundary `k`, participant `i` (slot order) merges participant
/// `(i + offset) % m` where `offset = 1 + k % (m - 1)` — the offset
/// walks 1..m-1 across boundaries, so the gossip graph reaches every
/// pair without ever pairing a shard with itself. Must match
/// `Fleet::run_rounds` exactly: it is the event scheduler's half of the
/// uniform-period bit-identity pin.
fn rotation_partners(m: usize, k: u64) -> Vec<usize> {
    let offset = 1 + (k % (m as u64 - 1)) as usize;
    (0..m).map(|i| (i + offset) % m).collect()
}

/// Energy-aware gossip pairing for heterogeneous-cadence rendezvous:
/// sort the `m` participants by (stored energy, slot — the tie-break
/// that keeps the pairing deterministic), then the i-th poorest merges
/// the i-th richest's snapshot. With odd `m` the middle participant
/// would pair with itself; it merges its right neighbor in energy order
/// instead. Returns `partner[i]` = the participant index participant
/// `i` merges.
pub(crate) fn energy_partners(energy_uj: &[f64]) -> Vec<usize> {
    let m = energy_uj.len();
    debug_assert!(m >= 2);
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| {
        energy_uj[a]
            .partial_cmp(&energy_uj[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut partner = vec![0usize; m];
    for (i, &poor) in order.iter().enumerate() {
        let mut j = m - 1 - i;
        if j == i {
            j = (i + 1) % m;
        }
        partner[poor] = order[j];
    }
    partner
}

/// One rendezvous group's committed plan: the participants (sorted by
/// slot) and, for gossip, who merges whom.
struct EventPlan {
    participants: Vec<(usize, ModelSnapshot)>,
    strategy: SyncStrategy,
    rx_peers: u32,
    /// Gossip partner of `participants[i]` as an index into
    /// `participants` (empty under all-reduce or when `m < 2`).
    partner: Vec<usize>,
}

impl EventPlan {
    /// The snapshots shard `slot` merges at this rendezvous.
    fn peers_for(&self, slot: usize) -> Vec<&ModelSnapshot> {
        let m = self.participants.len();
        let Some(pos) = self.participants.iter().position(|&(i, _)| i == slot) else {
            return Vec::new();
        };
        if m < 2 {
            return Vec::new();
        }
        match self.strategy {
            SyncStrategy::AllReduce => self
                .participants
                .iter()
                .filter(|&&(i, _)| i != slot)
                .map(|(_, s)| s)
                .collect(),
            SyncStrategy::Gossip => vec![&self.participants[self.partner[pos]].1],
        }
    }
}

/// Coordinator → worker commands. Engines are not `Send` (their compute
/// backends are thread-pinned), so each worker owns the engines of its
/// statically assigned slots (`slot % workers`) and the coordinator
/// drives them through a per-worker FIFO mailbox.
enum Cmd {
    /// Run shard `slot` to its boundary at `t_us`, flush `skips`
    /// quarantine-skipped boundaries, then attempt the rendezvous
    /// (charge toward the radio price until `deadline_us`).
    Tick {
        slot: usize,
        t_us: u64,
        deadline_us: u64,
        skips: u64,
        rx_peers: u32,
    },
    /// The rendezvous plan for `slot`: commit + merge, or go solo.
    Plan { slot: usize, plan: Arc<EventPlan> },
    /// Run shard `slot` out to the horizon (flushing `skips`) and report
    /// its result.
    Drain { slot: usize, skips: u64 },
}

/// Worker → coordinator rendezvous reports.
enum Report {
    /// The shard charged to the price: its broadcast snapshot plus its
    /// post-charge stored energy (for energy-aware partner selection).
    Ready {
        slot: usize,
        snap: ModelSnapshot,
        energy_uj: f64,
    },
    /// The shard could not afford the exchange inside its window.
    Gated { slot: usize },
    /// The shard is past the horizon or failed: drop it from the heap.
    Done { slot: usize },
    /// A worker panicked: the coordinator must stop waiting on reports.
    Poison,
}

/// A rendezvous group being assembled at one wake time: how many ticked
/// shards still owe a report, and what came back so far.
#[derive(Default)]
struct Group {
    expect: usize,
    ready: Vec<(usize, ModelSnapshot, f64)>,
    gated: Vec<usize>,
    done: Vec<usize>,
}

impl Group {
    fn arrived(&self) -> usize {
        self.ready.len() + self.gated.len() + self.done.len()
    }
}

/// Coordinator-side per-shard state. The engine itself lives on the
/// owning worker; everything the scheduler decides from (cadence,
/// quarantine, batched skips) lives here so those decisions are
/// single-threaded and deterministic.
struct SlotState {
    period_us: u64,
    quarantine: QuarantineState,
    /// Boundaries sat out under quarantine since the shard's last wake —
    /// flushed into the engine's `syncs_skipped` at its next Tick/Drain
    /// (the whole point: a quarantined shard is not woken to count).
    pending_skips: u64,
    /// The wake time of the in-flight Tick, if any.
    in_flight: Option<u64>,
    /// Past the horizon or failed: no further events.
    done: bool,
}

/// Run a synced fleet on the event heap. Entered from [`super::fleet::
/// Fleet::run`] when the factory's [`FleetSched`] is `Event` (the
/// default); `plan` carries the fleet-wide strategy/horizon while each
/// shard's cadence comes from `ShardFactory::shard_sync_period_us`.
pub(crate) fn run_events<F: ShardFactory + ?Sized>(
    factory: &F,
    shards: &[Shard],
    threads: usize,
    plan: SyncPlan,
) -> Result<FleetResult> {
    let n = shards.len();
    let horizon = plan.horizon_us;
    let rx_peers = plan.rx_peers(n as u32);
    let workers = pool::resolve_workers(threads, n);
    let periods: Vec<u64> = shards
        .iter()
        .map(|sh| factory.shard_sync_period_us(sh.index))
        .collect();
    // all shards on one cadence → the rotation keeps the bit-identity
    // pin with the round barrier; any spread → energy-aware pairing
    let uniform = periods[0] > 0 && periods.iter().all(|&p| p == periods[0]);

    let mut slots: Vec<SlotState> = periods
        .iter()
        .map(|&period_us| SlotState {
            period_us,
            quarantine: QuarantineState::new(),
            pending_skips: 0,
            in_flight: None,
            done: false,
        })
        .collect();
    let (rep_tx, rep_rx) = mpsc::channel::<Report>();
    let (res_tx, res_rx) = mpsc::channel::<(usize, Result<RunResult>)>();
    let mut results: Vec<Option<Result<RunResult>>> = (0..n).map(|_| None).collect();

    std::thread::scope(|scope| {
        let mut cmd_txs = Vec::with_capacity(workers);
        for w in 0..workers {
            let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
            cmd_txs.push(cmd_tx);
            let rep_tx = rep_tx.clone();
            let poison_tx = rep_tx.clone();
            let res_tx = res_tx.clone();
            scope.spawn(move || {
                let body = std::panic::AssertUnwindSafe(|| {
                    // build this worker's engines up front; the static
                    // slot % workers assignment means the coordinator
                    // knows every shard's mailbox without a handshake
                    let mut pos = vec![usize::MAX; n];
                    let mut engines: Vec<Result<Engine>> = Vec::new();
                    for slot in (w..n).step_by(workers) {
                        pos[slot] = engines.len();
                        engines.push(factory.build_shard_engine(shards[slot].index));
                    }
                    for cmd in cmd_rx {
                        match cmd {
                            Cmd::Tick {
                                slot,
                                t_us,
                                deadline_us,
                                skips,
                                rx_peers,
                            } => {
                                let engine = &mut engines[pos[slot]];
                                let report = match engine {
                                    Ok(e) => {
                                        for _ in 0..skips {
                                            e.note_sync_skipped();
                                        }
                                        // the event heap knows this shard's
                                        // next rendezvous exactly: let a
                                        // forecast-aware shard reserve the
                                        // radio price ahead of it
                                        e.note_next_sync(t_us, rx_peers);
                                        match e.run_until(t_us) {
                                            // the horizon ends a shard's rendezvous
                                            Ok(()) if e.now_us() < e.cfg.horizon_us => {
                                                match e.prepare_sync(rx_peers, deadline_us) {
                                                    Some(snap) => Report::Ready {
                                                        slot,
                                                        snap,
                                                        energy_uj: e.stored_energy_uj(),
                                                    },
                                                    None => Report::Gated { slot },
                                                }
                                            }
                                            Ok(()) => Report::Done { slot },
                                            Err(err) => {
                                                *engine = Err(err);
                                                Report::Done { slot }
                                            }
                                        }
                                    }
                                    Err(_) => Report::Done { slot },
                                };
                                if rep_tx.send(report).is_err() {
                                    return;
                                }
                            }
                            Cmd::Plan { slot, plan } => {
                                let engine = &mut engines[pos[slot]];
                                if let Ok(e) = engine {
                                    if plan.participants.len() >= 2 {
                                        // pay the fleet-quoted price (the radio
                                        // budgets a full listen window regardless
                                        // of who transmits), then merge the peers
                                        e.commit_sync(plan.rx_peers);
                                        let peers = plan.peers_for(slot);
                                        if let Err(err) = e.apply_sync(&peers) {
                                            *engine = Err(err);
                                        }
                                    } else {
                                        // nobody else made this rendezvous:
                                        // skip the exchange for free
                                        e.solo_sync();
                                    }
                                }
                            }
                            Cmd::Drain { slot, skips } => {
                                let engine = std::mem::replace(
                                    &mut engines[pos[slot]],
                                    Err(Error::Config("shard already drained".into())),
                                );
                                let out = engine
                                    .and_then(|mut e| {
                                        for _ in 0..skips {
                                            e.note_sync_skipped();
                                        }
                                        let horizon = e.cfg.horizon_us;
                                        e.run_until(horizon)?;
                                        e.finish()
                                    })
                                    .map_err(|e| shard_error(shards[slot].index, e));
                                if res_tx.send((slot, out)).is_err() {
                                    return;
                                }
                            }
                        }
                    }
                });
                if std::panic::catch_unwind(body).is_err() {
                    // a worker bug must not hang the coordinator: poison
                    // it so it stops waiting (the panic message already
                    // went to stderr via the default hook); the lost
                    // worker's shards surface as worker-exited errors
                    let _ = poison_tx.send(Report::Poison);
                }
            });
        }
        drop(res_tx);

        // --- the event loop (coordinator) ---
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        for (slot, state) in slots.iter().enumerate() {
            if state.period_us > 0 && state.period_us < horizon {
                heap.push(Reverse((state.period_us, slot)));
            }
        }
        let mut groups: BTreeMap<u64, Group> = BTreeMap::new();
        let mut in_flight = 0usize;
        // the dispatch gate: a group at `t` may only be dispatched once
        // no in-flight shard could still push an event at or before `t`
        // (its next boundary is its wake time + its period), so group
        // membership never depends on worker timing
        let min_next_push = |slots: &[SlotState]| {
            slots
                .iter()
                .filter_map(|s| s.in_flight.map(|t| t + s.period_us))
                .min()
                .unwrap_or(u64::MAX)
        };
        'events: loop {
            // dispatch every event the gate allows, in (time, slot) order
            while let Some(&Reverse((t, slot))) = heap.peek() {
                if in_flight > 0 && t >= min_next_push(&slots) {
                    break;
                }
                heap.pop();
                let state = &mut slots[slot];
                if state.quarantine.sits_out(t) {
                    // quarantine as event re-scheduling: push the wake
                    // out one period without waking the shard at all
                    state.pending_skips += 1;
                    let next = t + state.period_us;
                    if next < horizon {
                        heap.push(Reverse((next, slot)));
                    }
                    continue;
                }
                let deadline_us = (t + state.period_us).min(horizon);
                let skips = std::mem::take(&mut state.pending_skips);
                state.in_flight = Some(t);
                in_flight += 1;
                groups.entry(t).or_default().expect += 1;
                if cmd_txs[slot % workers]
                    .send(Cmd::Tick {
                        slot,
                        t_us: t,
                        deadline_us,
                        skips,
                        rx_peers,
                    })
                    .is_err()
                {
                    break 'events;
                }
            }
            if in_flight == 0 {
                break; // heap drained: all rendezvous played out
            }
            let report = match rep_rx.recv() {
                Ok(r) => r,
                Err(_) => break,
            };
            let slot = match &report {
                Report::Ready { slot, .. }
                | Report::Gated { slot }
                | Report::Done { slot } => *slot,
                Report::Poison => break,
            };
            let t = slots[slot].in_flight.expect("report from an idle shard");
            let g = groups.get_mut(&t).expect("group of an in-flight shard");
            match report {
                Report::Ready {
                    slot,
                    snap,
                    energy_uj,
                } => g.ready.push((slot, snap, energy_uj)),
                Report::Gated { slot } => g.gated.push(slot),
                Report::Done { slot } => g.done.push(slot),
                Report::Poison => unreachable!(),
            }
            if g.arrived() < g.expect {
                continue;
            }
            // the group is complete: settle quarantine, pick partners,
            // broadcast the plan, reschedule every member
            let mut group = groups.remove(&t).expect("completed group");
            group.ready.sort_by_key(|&(slot, ..)| slot);
            for &slot in &group.gated {
                let period = slots[slot].period_us;
                slots[slot].quarantine.on_gated(t, period);
            }
            for &(slot, ..) in &group.ready {
                slots[slot].quarantine.on_made_rendezvous();
            }
            for &slot in &group.done {
                slots[slot].done = true;
            }
            let m = group.ready.len();
            let partner = if m >= 2 && plan.strategy == SyncStrategy::Gossip {
                if uniform {
                    // 0-based boundary index of this uniform rendezvous —
                    // exactly the round barrier's round counter
                    rotation_partners(m, t / periods[0] - 1)
                } else {
                    let energies: Vec<f64> = group.ready.iter().map(|&(.., e)| e).collect();
                    energy_partners(&energies)
                }
            } else {
                Vec::new()
            };
            let ready_slots: Vec<usize> = group.ready.iter().map(|&(slot, ..)| slot).collect();
            let event_plan = Arc::new(EventPlan {
                participants: group
                    .ready
                    .into_iter()
                    .map(|(slot, snap, _)| (slot, snap))
                    .collect(),
                strategy: plan.strategy,
                rx_peers,
                partner,
            });
            for &slot in &ready_slots {
                if cmd_txs[slot % workers]
                    .send(Cmd::Plan {
                        slot,
                        plan: event_plan.clone(),
                    })
                    .is_err()
                {
                    break 'events;
                }
            }
            for member in ready_slots
                .into_iter()
                .chain(group.gated)
                .chain(group.done)
            {
                let state = &mut slots[member];
                state.in_flight = None;
                in_flight -= 1;
                if !state.done {
                    let next = t + state.period_us;
                    if next < horizon {
                        heap.push(Reverse((next, member)));
                    }
                }
            }
        }

        // drain: run every shard out to the horizon and collect, with
        // any still-pending quarantine skips flushed on the way
        for (slot, state) in slots.iter_mut().enumerate() {
            let skips = std::mem::take(&mut state.pending_skips);
            let _ = cmd_txs[slot % workers].send(Cmd::Drain { slot, skips });
        }
        drop(cmd_txs);
        for (slot, r) in res_rx {
            results[slot] = Some(r);
        }
    });
    let shards: Result<Vec<RunResult>> = results
        .into_iter()
        .enumerate()
        .map(|(i, r)| {
            r.unwrap_or_else(|| {
                Err(Error::Config(format!(
                    "fleet shard {i}: worker exited without reporting a result"
                )))
            })
        })
        .collect();
    Ok(FleetResult::aggregate(shards?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fleet::testfleet::ConstFleet;
    use crate::sim::fleet::Fleet;

    /// ConstFleet plus a sync plan, a scheduler choice, and optional
    /// per-shard cadences — the event-scheduler test rig.
    struct EventFleet {
        inner: ConstFleet,
        plan: SyncPlan,
        sched: FleetSched,
        /// Per-shard periods (empty = the plan's uniform period).
        periods: Vec<u64>,
    }

    impl EventFleet {
        fn uniform(n: u32, period_us: u64, strategy: SyncStrategy, sched: FleetSched) -> Self {
            EventFleet {
                inner: ConstFleet { n },
                plan: SyncPlan {
                    period_us,
                    strategy,
                    horizon_us: 900_000_000, // ConstFleet's horizon
                },
                sched,
                periods: Vec::new(),
            }
        }
    }

    impl ShardFactory for EventFleet {
        fn shard_count(&self) -> u32 {
            self.inner.shard_count()
        }
        fn shard(&self, index: u32) -> Result<Shard> {
            self.inner.shard(index)
        }
        fn build_shard_engine(&self, index: u32) -> Result<Engine> {
            self.inner.build_shard_engine(index)
        }
        fn sync_plan(&self) -> Option<SyncPlan> {
            Some(self.plan)
        }
        fn shard_sync_period_us(&self, index: u32) -> u64 {
            self.periods
                .get(index as usize)
                .copied()
                .unwrap_or(self.plan.period_us)
        }
        fn fleet_sched(&self) -> FleetSched {
            self.sched
        }
    }

    fn fingerprint(f: &FleetResult) -> String {
        f.to_json().to_string()
    }

    #[test]
    fn uniform_period_event_schedule_is_bit_identical_to_rounds() {
        for strategy in [SyncStrategy::Gossip, SyncStrategy::AllReduce] {
            let rounds = EventFleet::uniform(4, 300_000_000, strategy, FleetSched::Rounds);
            let golden = Fleet::new(&rounds).unwrap().run(0).unwrap();
            assert!(
                golden.shards.iter().any(|r| r.syncs_done > 0),
                "{strategy:?}: barrier reference never exchanged"
            );
            let event = EventFleet::uniform(4, 300_000_000, strategy, FleetSched::Event);
            let fleet = Fleet::new(&event).unwrap();
            for threads in [1, 2, 0] {
                assert_eq!(
                    fingerprint(&fleet.run(threads).unwrap()),
                    fingerprint(&golden),
                    "{strategy:?}: event scheduler diverged from the round \
                     barrier at threads={threads}"
                );
            }
        }
    }

    #[test]
    fn heterogeneous_cadences_attend_their_own_boundaries_only() {
        // periods 150 s / 300 s / 450 s over a 900 s horizon: shard 0
        // attends 5 boundaries, shard 1 two, shard 2 one — every attended
        // boundary ends as exactly one of done/skipped/solo, and no
        // fleet-wide barrier means the counts differ per shard
        let mut factory =
            EventFleet::uniform(3, 300_000_000, SyncStrategy::Gossip, FleetSched::Event);
        factory.periods = vec![150_000_000, 300_000_000, 450_000_000];
        let fleet = Fleet::new(&factory).unwrap();
        let fr = fleet.run(1).unwrap();
        let attended: Vec<u64> = fr
            .shards
            .iter()
            .map(|r| r.syncs_done + r.syncs_skipped + r.syncs_solo)
            .collect();
        assert_eq!(attended, vec![5, 2, 1], "per-shard rendezvous counts");
        // the heap schedules exactly those wakes
        assert_eq!(planned_wakes(&factory.periods, 900_000_000), 8);
        // deterministic across thread counts
        for threads in [2, 0] {
            assert_eq!(
                fingerprint(&fr),
                fingerprint(&fleet.run(threads).unwrap()),
                "heterogeneous fleet diverged at threads={threads}"
            );
        }
    }

    #[test]
    fn shared_boundaries_of_mixed_cadences_still_exchange() {
        // 150 s and 300 s shards meet at 300 s multiples: the faster
        // shard's solo boundaries and the shared pairwise ones add up
        let mut factory =
            EventFleet::uniform(2, 300_000_000, SyncStrategy::Gossip, FleetSched::Event);
        factory.periods = vec![150_000_000, 300_000_000];
        let fr = Fleet::new(&factory).unwrap().run(0).unwrap();
        let fast = &fr.shards[0];
        let slow = &fr.shards[1];
        assert_eq!(fast.syncs_done + fast.syncs_skipped + fast.syncs_solo, 5);
        assert_eq!(slow.syncs_done + slow.syncs_skipped + slow.syncs_solo, 2);
        // exchanges can only happen at the two shared boundaries
        assert!(fast.syncs_done <= 2 && slow.syncs_done <= 2);
        assert!(
            fr.shards.iter().any(|r| r.syncs_done > 0),
            "constant-power shards never afforded a shared rendezvous"
        );
    }

    #[test]
    fn energy_pairing_is_deterministic_and_pairs_poor_with_rich() {
        // even count: strict poorest<->richest pairing
        let partner = energy_partners(&[50.0, 10.0, 40.0, 20.0]);
        // energy order: 1 (10) < 3 (20) < 2 (40) < 0 (50)
        assert_eq!(partner, vec![1, 0, 3, 2]);
        // ties break by participant index: 1 and 2 tie at 10, order 1 < 2
        let partner = energy_partners(&[30.0, 10.0, 10.0]);
        // order: 1, 2, 0; middle (2) pairs right in energy order (0)
        assert_eq!(partner[1], 0, "poorest merges richest");
        assert_eq!(partner[2], 0, "odd middle merges its right neighbor");
        assert_eq!(partner[0], 1, "richest merges poorest");
        // never self-paired
        for (i, &p) in partner.iter().enumerate() {
            assert_ne!(i, p);
        }
    }

    #[test]
    fn rotation_partners_match_the_round_barrier_formula() {
        // m = 4: offsets walk 1, 2, 3, 1, ... across boundaries
        assert_eq!(rotation_partners(4, 0), vec![1, 2, 3, 0]);
        assert_eq!(rotation_partners(4, 1), vec![2, 3, 0, 1]);
        assert_eq!(rotation_partners(4, 2), vec![3, 0, 1, 2]);
        assert_eq!(rotation_partners(4, 3), vec![1, 2, 3, 0]);
        // m = 2 always pairs the two participants
        assert_eq!(rotation_partners(2, 7), vec![1, 0]);
    }

    #[test]
    fn planned_wakes_counts_strict_interior_boundaries() {
        assert_eq!(planned_wakes(&[300], 900), 2); // 300, 600
        assert_eq!(planned_wakes(&[450], 900), 1); // 450 (900 excluded)
        assert_eq!(planned_wakes(&[900], 900), 0);
        assert_eq!(planned_wakes(&[0], 900), 0); // opted out
        assert_eq!(planned_wakes(&[300, 450, 0], 900), 3);
    }
}
