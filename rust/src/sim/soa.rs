//! Streaming population-scale fleets: fold-and-drop shard execution.
//!
//! [`super::fleet::Fleet`] retains one `RunResult` per shard (and, in
//! federated mode, one resident `Engine` per shard) — fine at
//! thousands of shards, impossible at the ROADMAP's 10⁵–10⁶. This
//! module runs the same shards through three structural changes:
//!
//! * **Struct-of-arrays fan-in.** What the fleet retains per shard is
//!   no longer an array-of-structs `Vec<RunResult>` but per-metric
//!   accumulators: each shard is reduced to [`ShardStats`] and folded
//!   into a [`FleetRollupAcc`] (exact mean/min/max/total, index-ordered
//!   so float op order matches the retained path bit for bit) plus
//!   [`FleetSketches`] (order-invariant quantile/histogram sketches).
//!   Memory is O(1) in the shard count.
//! * **Pooled NVM slab arena.** Each worker lane owns an
//!   [`NvmArena`]: the first shard on a lane grows a slab, every later
//!   shard reuses it after a [`crate::nvm::Nvm::reset_for_reuse`]
//!   scrub. Slab allocations are O(workers), not O(shards), and
//!   steady-state shards run inside already-grown buffers.
//! * **Pooled backends.** The lane's compute backend (with its warm
//!   distance-matrix / device caches and scratch) carries across
//!   shards instead of being rebuilt per shard. Safe for bit-identity:
//!   a stale k-NN cache recomputes exactly the changed rows
//!   (`knn_learn_cache_matches_full_recompute` pins this), and the
//!   pjrt device cache re-uploads on host mismatch.
//!
//! Work is distributed by [`pool::fold_indexed`]: the coordinator folds
//! each shard's stats in strict index order *while* workers run, then
//! drops them — no per-shard `Engine` or `RunResult` survives the fold.
//! The streaming path is for isolated fleets; a federated sync plan
//! needs resident engines at its rendezvous (whether the event heap's
//! pairwise boundaries or the round barrier, [`crate::sim::sched`] vs
//! [`super::fleet::Fleet::run_rounds`]) and is rejected up front.

use crate::backend::native::NativeBackend;
use crate::error::{Error, Result};
use crate::nvm::arena::NvmArena;
use crate::sim::fleet::{shard_error, FleetRollup, FleetRollupAcc, ShardFactory, ShardStats};
use crate::util::json::Json;
use crate::util::pool;
use crate::util::sketch::MetricSketch;
use std::sync::atomic::{AtomicU64, Ordering};

/// Order-invariant quantile/histogram sketches over the fleet's
/// per-shard metrics — the distributional complement to the exact
/// [`FleetRollup`]. Sync metrics are absent: the streaming path runs
/// isolated fleets only.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FleetSketches {
    pub final_accuracy: MetricSketch,
    pub mean_accuracy: MetricSketch,
    pub energy_uj: MetricSketch,
    pub learned: MetricSketch,
    pub inferred: MetricSketch,
    pub power_failures: MetricSketch,
    pub stale_plans: MetricSketch,
}

impl FleetSketches {
    pub fn new() -> FleetSketches {
        FleetSketches::default()
    }

    pub fn fold(&mut self, s: &ShardStats) {
        self.final_accuracy.record(s.final_accuracy);
        self.mean_accuracy.record(s.mean_accuracy);
        self.energy_uj.record(s.energy_uj);
        self.learned.record(s.learned);
        self.inferred.record(s.inferred);
        self.power_failures.record(s.power_failures);
        self.stale_plans.record(s.stale_plans);
    }

    /// Merge another sketch set in (associative and order-invariant —
    /// see [`MetricSketch::merge`]).
    pub fn merge(&mut self, other: &FleetSketches) {
        self.final_accuracy.merge(&other.final_accuracy);
        self.mean_accuracy.merge(&other.mean_accuracy);
        self.energy_uj.merge(&other.energy_uj);
        self.learned.merge(&other.learned);
        self.inferred.merge(&other.inferred);
        self.power_failures.merge(&other.power_failures);
        self.stale_plans.merge(&other.stale_plans);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("final_accuracy", self.final_accuracy.to_json()),
            ("mean_accuracy", self.mean_accuracy.to_json()),
            ("energy_uj", self.energy_uj.to_json()),
            ("learned", self.learned.to_json()),
            ("inferred", self.inferred.to_json()),
            ("power_failures", self.power_failures.to_json()),
            ("stale_plans", self.stale_plans.to_json()),
        ])
    }
}

/// What a streaming fleet run produces: the exact rollups (bit-identical
/// to [`super::fleet::FleetResult::rollup`] over the same shards), the
/// metric sketches, and pool telemetry. Deliberately no per-shard data —
/// that's the point.
#[derive(Debug)]
pub struct StreamResult {
    pub rollup: FleetRollup,
    pub sketches: FleetSketches,
    /// Shards that adopted a recycled NVM slab (fleet-wide; the first
    /// shard on each worker lane builds the lane's slab).
    pub slab_reuses: u64,
    /// Shards that inherited the lane's warm compute backend.
    pub backend_reuses: u64,
    /// Worker threads the run resolved to.
    pub workers: usize,
}

impl StreamResult {
    /// JSON document: like the retained fleet's but with `"sketches"`
    /// in place of `"per_shard"`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.rollup.shards as f64)),
            ("rollup", self.rollup.to_json()),
            ("sketches", self.sketches.to_json()),
        ])
    }
}

/// Per-worker lane state: the pooled slab arena and the carried backend.
/// Built on the worker thread (backends are deliberately not `Send`).
struct Lane {
    arena: NvmArena,
    backend: Option<Box<dyn crate::backend::ComputeBackend>>,
}

/// Run every shard of `factory` and fold the results in shard-index
/// order into rollups + sketches, retaining nothing per shard. The
/// rollup is bit-identical to `Fleet::run`'s over the same factory, for
/// any worker count (`threads`, 0 = available parallelism).
pub fn run_streaming<F: ShardFactory + ?Sized>(
    factory: &F,
    threads: usize,
) -> Result<StreamResult> {
    let n = factory.shard_count() as usize;
    if n == 0 {
        return Err(Error::Config("fleet: shard count must be >= 1".into()));
    }
    if let Some(plan) = factory.sync_plan() {
        if n > 1 && !plan.boundaries().is_empty() {
            return Err(Error::Config(
                "streaming fleet: federated sync needs resident engines \
                 at its rendezvous — use the per-shard path (stream=false)"
                    .into(),
            ));
        }
    }
    let workers = pool::resolve_workers(threads, n);
    let slab_reuses = AtomicU64::new(0);
    let backend_reuses = AtomicU64::new(0);
    let mut acc = FleetRollupAcc::new();
    let mut sketches = FleetSketches::new();
    let mut first_err: Option<Error> = None;
    pool::fold_indexed(
        n,
        threads,
        || Lane {
            arena: NvmArena::new(),
            backend: None,
        },
        |lane, i| run_shard(factory, lane, i as u32, &slab_reuses, &backend_reuses),
        |_, r| match r {
            Ok(stats) => {
                acc.fold(&stats);
                sketches.fold(&stats);
            }
            Err(e) => {
                // first failure by shard index, matching Fleet::run's
                // collect short-circuit
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        },
    );
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(StreamResult {
        rollup: acc.finish(),
        sketches,
        slab_reuses: slab_reuses.into_inner(),
        backend_reuses: backend_reuses.into_inner(),
        workers,
    })
}

/// Run one shard on a lane, swapping in the lane's pooled resources and
/// reclaiming them afterwards. The swap is bit-identity-safe: the
/// builder writes nothing to NVM before the run (a reset slab reads
/// exactly like the fresh store it replaces), and backend caches are
/// result-invariant by the pinned cache-vs-recompute tests.
fn run_shard<F: ShardFactory + ?Sized>(
    factory: &F,
    lane: &mut Lane,
    index: u32,
    slab_reuses: &AtomicU64,
    backend_reuses: &AtomicU64,
) -> Result<ShardStats> {
    let mut e = factory
        .build_shard_engine(index)
        .map_err(|e| shard_error(index, e))?;
    if lane.arena.pooled() > 0 {
        e.exec.nvm = lane.arena.take();
        slab_reuses.fetch_add(1, Ordering::Relaxed);
    }
    if let Some(be) = lane.backend.take() {
        e.backend = be;
        backend_reuses.fetch_add(1, Ordering::Relaxed);
    }
    let out = e.run_to_end();
    // reclaim the pooled resources whatever the outcome (reset scrubs
    // any half-finished state), then drop the engine
    lane.arena.put(std::mem::take(&mut e.exec.nvm));
    lane.backend = Some(std::mem::replace(
        &mut e.backend,
        Box::new(NativeBackend::new()),
    ));
    out.map(|r| ShardStats::of(&r))
        .map_err(|e| shard_error(index, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::Engine;
    use crate::sim::fleet::testfleet::ConstFleet;
    use crate::sim::fleet::{Fleet, Shard, SyncPlan, SyncStrategy};

    #[test]
    fn streaming_rollup_matches_retained_fleet_for_any_thread_count() {
        let fleet = ConstFleet { n: 6 };
        let retained = Fleet::new(&fleet).unwrap().run(1).unwrap();
        for threads in [1, 2, 0] {
            let streamed = run_streaming(&fleet, threads).unwrap();
            assert_eq!(
                streamed.rollup.to_json().to_string(),
                retained.rollup.to_json().to_string(),
                "threads={threads}"
            );
            assert_eq!(streamed.sketches.final_accuracy.count(), 6);
        }
    }

    #[test]
    fn streaming_document_is_deterministic_across_thread_counts() {
        let fleet = ConstFleet { n: 5 };
        let docs: Vec<String> = [1, 2, 0]
            .iter()
            .map(|&t| run_streaming(&fleet, t).unwrap().to_json().to_string())
            .collect();
        assert_eq!(docs[0], docs[1]);
        assert_eq!(docs[0], docs[2]);
        assert!(docs[0].contains("\"sketches\":{\"final_accuracy\":"));
        assert!(!docs[0].contains("per_shard"));
    }

    #[test]
    fn lanes_recycle_slabs_and_backends() {
        let fleet = ConstFleet { n: 8 };
        let r = run_streaming(&fleet, 1).unwrap();
        // one worker lane: first shard builds, the other 7 recycle
        assert_eq!(r.workers, 1);
        assert_eq!(r.slab_reuses, 7);
        assert_eq!(r.backend_reuses, 7);
    }

    #[test]
    fn zero_shards_is_a_config_error() {
        let fleet = ConstFleet { n: 0 };
        let err = run_streaming(&fleet, 1).unwrap_err();
        assert!(err.to_string().contains("shard count"), "{err}");
    }

    /// ConstFleet with a sync plan bolted on.
    struct Synced {
        inner: ConstFleet,
        plan: SyncPlan,
    }

    impl ShardFactory for Synced {
        fn shard_count(&self) -> u32 {
            self.inner.shard_count()
        }
        fn shard(&self, index: u32) -> Result<Shard> {
            self.inner.shard(index)
        }
        fn build_shard_engine(&self, index: u32) -> Result<Engine> {
            self.inner.build_shard_engine(index)
        }
        fn sync_plan(&self) -> Option<SyncPlan> {
            Some(self.plan)
        }
    }

    /// ConstFleet with one shard whose engine fails to build.
    struct Broken {
        inner: ConstFleet,
        broken: u32,
    }

    impl ShardFactory for Broken {
        fn shard_count(&self) -> u32 {
            self.inner.shard_count()
        }
        fn shard(&self, index: u32) -> Result<Shard> {
            self.inner.shard(index)
        }
        fn build_shard_engine(&self, index: u32) -> Result<Engine> {
            if index == self.broken {
                return Err(Error::Nvm("restore failed: torn learner snapshot".into()));
            }
            self.inner.build_shard_engine(index)
        }
    }

    #[test]
    fn failing_shard_is_named_in_the_error() {
        let fleet = Broken {
            inner: ConstFleet { n: 4 },
            broken: 2,
        };
        let err = run_streaming(&fleet, 1).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("fleet shard 2"), "{msg}");
        assert!(msg.contains("torn learner snapshot"), "{msg}");
    }

    #[test]
    fn active_sync_plan_is_rejected() {
        let mut fleet = Synced {
            inner: ConstFleet { n: 4 },
            plan: SyncPlan {
                period_us: 300_000_000,
                strategy: SyncStrategy::Gossip,
                horizon_us: 900_000_000,
            },
        };
        let err = run_streaming(&fleet, 1).unwrap_err();
        assert!(err.to_string().contains("streaming fleet"), "{err}");
        // a 1-shard "fleet" has no exchanges: streaming is fine
        fleet.inner.n = 1;
        assert!(run_streaming(&fleet, 1).is_ok());
    }
}
