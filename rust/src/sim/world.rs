//! The physical device world: harvester + capacitor + sensor + the
//! simulated clock, including the two charge kernels.
//!
//! The **event kernel** walks the harvester's piecewise segments (see
//! [`Harvester::segment_end_us`]): darkness and idle gaps are crossed in
//! one analytic jump, and the wake instant inside a segment is solved with
//! a Newton-style window refinement over the segment's closed-form mean
//! power. The **stepped kernel** is the pre-refactor fixed-step
//! integrator, kept as the reference oracle (`ChargeKernel::Stepped`, or
//! build with `--features stepped-kernel` to make it the default); the
//! equivalence suite pins the event kernel's `RunResult` to it.

use crate::energy::harvester::{Forecast, Harvester};
use crate::energy::Capacitor;
use crate::sensors::Sensor;
use crate::sim::ChargeKernel;

/// Below this window span the event kernel treats segment power as
/// constant and commits the analytic wake step (matches the stepped
/// kernel's default 60 s re-sampling granularity).
const RESOLVE_US: u64 = 60_000_000;

/// Longest single sleep-through hop. A window whose *mean* net power
/// never reaches the wake threshold can still contain an interior
/// crossing when net power changes sign inside it (possible only with
/// leakage rivalling harvest); bounding hops re-evaluates at least hourly,
/// capping any such divergence from the oracle at the cost of ~24 extra
/// iterations per simulated day.
const SLEEP_HOP_MAX_US: u64 = 3_600_000_000;

/// The assembled physical world and its clock.
pub struct World {
    pub harvester: Box<dyn Harvester>,
    pub cap: Capacitor,
    pub sensor: Box<dyn Sensor>,
    t_us: u64,
    /// Forecast-aware planning state (`None` unless the policy's
    /// `forecast` knob is on): exact piecewise lookahead for analytic
    /// harvesters, a causal EWMA for recorded traces.
    forecast: Option<Forecast>,
}

impl World {
    pub fn new(
        harvester: Box<dyn Harvester>,
        cap: Capacitor,
        sensor: Box<dyn Sensor>,
    ) -> Self {
        World {
            harvester,
            cap,
            sensor,
            t_us: 0,
            forecast: None,
        }
    }

    /// Current simulated time, µs.
    pub fn now_us(&self) -> u64 {
        self.t_us
    }

    /// Advance the clock (action execution time).
    pub fn advance_us(&mut self, dt_us: u64) {
        self.t_us = self.t_us.saturating_add(dt_us);
    }

    /// Turn on the forecast view (the policy layer's `forecast` knob).
    /// Picks the forecaster that fits the harvester; see
    /// [`Forecast::for_harvester`].
    pub fn enable_forecast(&mut self) {
        self.forecast = Some(Forecast::for_harvester(self.harvester.as_ref()));
    }

    pub fn forecast_enabled(&self) -> bool {
        self.forecast.is_some()
    }

    /// Net energy (µJ) the forecast predicts the capacitor can bank over
    /// the next `dt_us`: predicted mean harvest power through the
    /// conversion efficiency, minus leakage, floored at zero. `None` when
    /// the forecast knob is off.
    pub fn forecast_net_uj(&self, dt_us: u64) -> Option<f64> {
        let f = self.forecast.as_ref()?;
        if dt_us == 0 {
            return Some(0.0);
        }
        let to = self.t_us.saturating_add(dt_us);
        let p = f.mean_power_w(self.harvester.as_ref(), self.t_us, to);
        let net_w = p * self.cap.eff - self.cap.leak_w;
        Some((net_w * dt_us as f64).max(0.0)) // W · µs = µJ
    }

    /// Charge until the capacitor reaches the wake threshold or the clock
    /// reaches `until_us`, whichever is first. Returns `true` when awake.
    pub fn charge_until(
        &mut self,
        until_us: u64,
        kernel: ChargeKernel,
        charge_step_us: u64,
    ) -> bool {
        // feed the EWMA forecaster (trace worlds) at every charge call:
        // wake and sleep boundaries are the instants a real device could
        // sample its harvester, and they are deterministic per run
        if let Some(f) = self.forecast.as_mut() {
            f.observe(self.t_us, self.harvester.power_w(self.t_us));
        }
        match kernel {
            ChargeKernel::Event => self.charge_event(until_us),
            ChargeKernel::Stepped => self.charge_stepped(until_us, charge_step_us),
        }
    }

    /// Reference oracle: fixed-step integration, re-sampling instantaneous
    /// power each step (bounded below at 1 ms, above at `charge_step_us`,
    /// and clamped so the clock honors `until_us` exactly, like the event
    /// kernel).
    fn charge_stepped(&mut self, until_us: u64, charge_step_us: u64) -> bool {
        while self.t_us < until_us {
            if self.cap.awake_ready() {
                return true;
            }
            let p = self.harvester.power_w(self.t_us);
            let step = match self.cap.time_to_wake_s(p) {
                Some(s) => ((s * 1e6) as u64 + 1).min(charge_step_us),
                None => charge_step_us,
            }
            .max(1_000)
            .min(until_us - self.t_us);
            self.cap.charge(p, step);
            self.t_us += step;
        }
        self.cap.awake_ready()
    }

    /// Event-driven analytic kernel: jump segment to segment; inside a
    /// segment, refine a window around the predicted wake instant until it
    /// is small enough to treat the mean power as constant.
    fn charge_event(&mut self, until_us: u64) -> bool {
        while self.t_us < until_us {
            if self.cap.awake_ready() {
                return true;
            }
            let seg_end = self
                .harvester
                .segment_end_us(self.t_us)
                .max(self.t_us + 1)
                .min(until_us);
            let seg_span = seg_end - self.t_us;

            // Seed the probe window from the instantaneous power; when the
            // net is non-positive here (e.g. right at sunrise) fall back to
            // the whole segment — its mean decides whether a wake is due.
            let p0 = self.harvester.power_w(self.t_us);
            let guess = match self.cap.time_to_wake_s(p0) {
                Some(s) => ((s * 1e6) as u64).saturating_add(1),
                None => seg_span,
            };
            let mut end = self.t_us + guess.clamp(RESOLVE_US.min(seg_span), seg_span);

            loop {
                let span = end - self.t_us;
                let p = self.harvester.mean_power_w(self.t_us, end);
                let wake_dt = self
                    .cap
                    .time_to_wake_s(p)
                    .map(|s| ((s * 1e6) as u64).saturating_add(1));
                match wake_dt {
                    Some(dt) if dt < span => {
                        if span <= RESOLVE_US {
                            // window small enough: commit the analytic step
                            self.cap.charge(p, dt);
                            self.t_us += dt;
                            break;
                        }
                        // shrink toward the predicted instant; halving at
                        // minimum guarantees termination (span strictly
                        // decreases until it fits the resolve threshold)
                        let lo = RESOLVE_US.min(span - 1).max(1);
                        let hi = (span / 2).max(lo);
                        end = self.t_us + dt.clamp(lo, hi);
                    }
                    _ => {
                        // wake not inside this window: sleep through it,
                        // in bounded hops (see SLEEP_HOP_MAX_US)
                        let hop_end = self.t_us + span.min(SLEEP_HOP_MAX_US);
                        let p_hop = if hop_end == end {
                            p
                        } else {
                            self.harvester.mean_power_w(self.t_us, hop_end)
                        };
                        self.cap.charge(p_hop, hop_end - self.t_us);
                        self.t_us = hop_end;
                        break;
                    }
                }
            }
        }
        self.cap.awake_ready()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::harvester::{Constant, Solar, Trace};
    use crate::sensors::accel::{Accel, MotionProfile};

    fn world(h: Box<dyn Harvester>) -> World {
        let sensor = Accel::new(MotionProfile::alternating_hours(1.0, 3.0, 30), 1);
        World::new(h, Capacitor::vibration(), Box::new(sensor))
    }

    #[test]
    fn event_and_stepped_agree_on_constant_power() {
        let mut a = world(Box::new(Constant(0.005)));
        let mut b = world(Box::new(Constant(0.005)));
        let until = 3_600_000_000;
        let wa = a.charge_until(until, ChargeKernel::Event, 10_000_000);
        let wb = b.charge_until(until, ChargeKernel::Stepped, 10_000_000);
        assert!(wa && wb);
        // the analytic jump and the stepped integration land on the same
        // wake instant within the stepped kernel's own resolution
        let delta = a.now_us().abs_diff(b.now_us());
        assert!(delta <= 2_000, "event {} vs stepped {}", a.now_us(), b.now_us());
        assert!(a.cap.awake_ready() && b.cap.awake_ready());
    }

    #[test]
    fn event_kernel_jumps_darkness_in_one_call() {
        // zero power: the event kernel must land exactly on `until`
        let mut w = world(Box::new(Constant(0.0)));
        let awake = w.charge_until(7_200_000_000, ChargeKernel::Event, 60_000_000);
        assert!(!awake);
        assert_eq!(w.now_us(), 7_200_000_000);
    }

    #[test]
    fn event_kernel_respects_trace_boundaries() {
        // dark for 100 s, then strong power: wake must come after 100 s
        let mut w = world(Box::new(Trace {
            points: vec![(0, 0.0), (100_000_000, 0.050)],
        }));
        let awake = w.charge_until(3_600_000_000, ChargeKernel::Event, 60_000_000);
        assert!(awake);
        assert!(w.now_us() >= 100_000_000, "woke during darkness: {}", w.now_us());
        // and a stepped run from the same state agrees on the wake time
        let mut s = world(Box::new(Trace {
            points: vec![(0, 0.0), (100_000_000, 0.050)],
        }));
        s.charge_until(3_600_000_000, ChargeKernel::Stepped, 1_000_000);
        assert!(w.now_us().abs_diff(s.now_us()) <= 1_100_000);
    }

    #[test]
    fn event_kernel_wakes_through_solar_morning() {
        // start at midnight with a solar harvester: the kernel must cross
        // the whole night in one segment and wake shortly after sunrise
        let mut w = World::new(
            Box::new(Solar::default()),
            Capacitor::presence(),
            Box::new(Accel::new(MotionProfile::alternating_hours(1.0, 3.0, 30), 1)),
        );
        let awake = w.charge_until(24 * 3_600_000_000, ChargeKernel::Event, 60_000_000);
        assert!(awake);
        let sunrise_us = 6 * 3_600_000_000;
        assert!(w.now_us() > sunrise_us, "woke at {} before sunrise", w.now_us());
        assert!(
            w.now_us() < 12 * 3_600_000_000,
            "sunrise charge took implausibly long: {}",
            w.now_us()
        );
    }

    #[test]
    fn kernels_charge_identical_energy_through_leakage_only_night() {
        let mut a = world(Box::new(Constant(0.0)));
        let mut b = world(Box::new(Constant(0.0)));
        a.cap.set_voltage(2.5);
        b.cap.set_voltage(2.5);
        a.charge_until(3_600_000_000, ChargeKernel::Event, 60_000_000);
        b.charge_until(3_600_000_000, ChargeKernel::Stepped, 60_000_000);
        // leakage is linear in time: one jump equals many steps
        assert!((a.cap.voltage() - b.cap.voltage()).abs() < 1e-9);
    }
}
