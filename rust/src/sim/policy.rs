//! The decision layer: scheduler + example-selection heuristic + the
//! windowed completion bookkeeping that feeds [`PlanContext`].
//!
//! The planner's §4.2 goal logic compares learn/infer completions in the
//! current window of harvesting cycles against the goal rates. Before this
//! layer existed the engine hardcoded `window_learns: 0, window_infers: 0`
//! into every [`PlanContext`], so schedulers that rely on the context
//! (rather than private bookkeeping) never saw real rates. [`Policy`]
//! mirrors completions over the scheduler's declared window
//! ([`crate::sim::Scheduler::window_cycles`]) and stamps them into every
//! context it builds.

use crate::actions::Action;
use crate::energy::cost::{ActionCost, CostModel};
use crate::planner::{Pending, PlanContext, Planned};
use crate::selection::Selector;
use crate::sim::Scheduler;

/// Scheduler + selector + window bookkeeping.
pub struct Policy {
    pub scheduler: Box<dyn Scheduler>,
    pub selector: Box<dyn Selector>,
    window_learns: u32,
    window_infers: u32,
    cycles_in_window: u32,
}

impl Policy {
    pub fn new(scheduler: Box<dyn Scheduler>, selector: Box<dyn Selector>) -> Self {
        Policy {
            scheduler,
            selector,
            window_learns: 0,
            window_infers: 0,
            cycles_in_window: 0,
        }
    }

    /// Build the planning context for the next decision, carrying the real
    /// windowed completion counts and (in forecast mode) the engine's
    /// predicted energy budget for the current burst.
    pub fn context(
        &self,
        learned_total: u64,
        quality: f32,
        forecast_uj: Option<f64>,
    ) -> PlanContext {
        PlanContext {
            learned_total,
            quality,
            window_learns: self.window_learns,
            window_infers: self.window_infers,
            window_cycle: self.cycles_in_window,
            forecast_uj,
        }
    }

    /// Completions observed in the current window (learns, infers).
    pub fn window_counts(&self) -> (u32, u32) {
        (self.window_learns, self.window_infers)
    }

    /// Ask the scheduler for the next transition.
    pub fn decide(
        &mut self,
        pending: &Pending,
        ctx: &PlanContext,
        costs: &CostModel,
    ) -> Planned {
        self.scheduler.next(pending, ctx, costs)
    }

    /// Per-decision overhead of the scheduler.
    pub fn overhead(&self, costs: &CostModel) -> ActionCost {
        self.scheduler.overhead(costs)
    }

    /// Data-expiration interval, if the scheduler expires stale data.
    pub fn expiry_us(&self) -> Option<u64> {
        self.scheduler.expiry_us()
    }

    /// Does this policy run the select gate?
    pub fn uses_selection(&self) -> bool {
        self.scheduler.uses_selection()
    }

    /// A new harvesting cycle began: forward to the scheduler and roll the
    /// completion window (mirrors the planner's own §4.2 bookkeeping).
    /// Schedulers that declare no window ([`window_cycles`] `None`) get a
    /// one-cycle window — counts reset every wake — so the context never
    /// silently degrades into unbounded lifetime totals.
    ///
    /// [`window_cycles`]: crate::sim::Scheduler::window_cycles
    pub fn on_cycle(&mut self) {
        self.scheduler.on_cycle();
        match self.scheduler.window_cycles() {
            Some(window) => {
                self.cycles_in_window += 1;
                if self.cycles_in_window >= window {
                    self.cycles_in_window = 0;
                    self.window_learns = 0;
                    self.window_infers = 0;
                }
            }
            None => {
                self.window_learns = 0;
                self.window_infers = 0;
            }
        }
    }

    /// Outcome of a select gate.
    pub fn observe_select(&mut self, accepted: bool) {
        self.scheduler.observe_select(accepted);
    }

    /// A learn/infer completed: count it into the window and forward.
    pub fn observe_completion(&mut self, a: Action) {
        match a {
            Action::Learn => self.window_learns += 1,
            Action::Infer => self.window_infers += 1,
            _ => {}
        }
        self.scheduler.observe_completion(a);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::DynamicActionPlanner;
    use crate::selection::Heuristic;
    use crate::sim::PlannerScheduler;

    fn planner_policy() -> Policy {
        Policy::new(
            Box::new(PlannerScheduler(DynamicActionPlanner::default())),
            Heuristic::RoundRobin.build(1),
        )
    }

    #[test]
    fn context_carries_real_window_counts() {
        let mut p = planner_policy();
        assert_eq!(p.context(5, 0.5, None).window_learns, 0);
        p.observe_completion(Action::Learn);
        p.observe_completion(Action::Learn);
        p.observe_completion(Action::Infer);
        p.observe_completion(Action::Extract); // not a completion
        let ctx = p.context(5, 0.5, None);
        assert_eq!(ctx.window_learns, 2);
        assert_eq!(ctx.window_infers, 1);
        assert_eq!(ctx.learned_total, 5);
        assert_eq!(ctx.forecast_uj, None);
        // the engine's forecast budget passes through untouched
        assert_eq!(p.context(5, 0.5, Some(123.0)).forecast_uj, Some(123.0));
    }

    #[test]
    fn window_resets_after_goal_window_cycles() {
        let mut p = planner_policy();
        let window = p.scheduler.window_cycles().expect("planner has a window");
        p.observe_completion(Action::Learn);
        for _ in 0..window - 1 {
            p.on_cycle();
        }
        assert_eq!(p.window_counts(), (1, 0), "window rolled early");
        p.on_cycle();
        assert_eq!(p.window_counts(), (0, 0), "window did not roll");
    }

    #[test]
    fn baseline_schedulers_have_no_window() {
        let p = Policy::new(
            Box::new(crate::baselines::DutyCycleScheduler::new(0.5)),
            Heuristic::None.build(1),
        );
        assert_eq!(p.scheduler.window_cycles(), None);
        // no declared window -> one-cycle window: counts roll every wake
        // instead of growing into lifetime totals
        let mut p = p;
        p.observe_completion(Action::Learn);
        assert_eq!(p.window_counts(), (1, 0));
        p.on_cycle();
        assert_eq!(p.window_counts(), (0, 0));
    }
}
