//! Persistent run state: the engine's [`RunResult`] aggregates and
//! [`EnergyMeter`] accounting, checkpointed to NVM so an interrupted run
//! (host restart mid-sweep) restores its aggregates bit-identically.
//!
//! The store rides the same interned-[`KeyId`] + delta machinery as the
//! learner checkpoints: the append-only vectors (accuracy checkpoints,
//! inference log, energy series) are extended in place with
//! [`Nvm::write_at`] — O(new records) NVM traffic per save, not O(run) —
//! while the small parts (scalar counters, per-action tallies, scheduler
//! name) are rewritten wholesale. The committed watermarks live in the
//! head blob itself and the head is written **last**, so a save whose
//! transaction aborts (power failure) or that is torn by a crash between
//! writes leaves a previous consistent snapshot: the next save simply
//! re-appends from the committed lengths, and a restore never sees a
//! half-written record.

use crate::energy::meter::{ActionTally, EnergyMeter};
use crate::error::{Error, Result};
use crate::nvm::{KeyId, Nvm};
use crate::sim::{Checkpoint, RunResult};
use std::sync::atomic::{AtomicU64, Ordering};

/// Layout version tag (first u64 of the head blob). V2 added the fleet
/// sync counters, V3 the solo-sync counter, and V4 the forecast-mode
/// checkpoint counters (taken/elided/deferred/bytes); an old head
/// (earlier firmware) reads as "no run state", which is the correct
/// degradation for an in-memory store.
const MAGIC: u64 = 0x494C_5253_5634; // "ILRSV4"

/// Head blob: magic + run nonce + 15 scalar counters + 3 vector lengths +
/// total µJ.
const HEAD_LEN: usize = 21 * 8;
const CKPT_LEN: usize = 6 * 8;
const INFER_LEN: usize = 16;
const SERIES_LEN: usize = 16;

#[derive(Debug, Clone, Copy)]
struct StateKeys {
    head: KeyId,
    sched: KeyId,
    ckpts: KeyId,
    infers: KeyId,
    series: KeyId,
    tallies: KeyId,
}

/// Parsed head blob.
struct Head {
    nonce: u64,
    scalars: [u64; 15],
    ckpts: u64,
    infers: u64,
    series: u64,
    total_uj: f64,
}

/// Distinct identity per run (prevents a fresh run over adopted NVM from
/// appending onto a foreign run's snapshot).
static NEXT_RUN_NONCE: AtomicU64 = AtomicU64::new(1);

/// The run-state store: cached key handles plus a reusable encode buffer.
/// Keeps **no** volatile watermarks — committed lengths are read back
/// from the head blob on every save, which is what makes an aborted or
/// torn save self-healing. The head also carries this run's `nonce`: a
/// save only appends over a head *it* wrote (or one adopted via
/// [`RunState::restore`]); any foreign snapshot — a carried-over NVM from
/// a different run whose record counts happen to fit — is rewritten from
/// scratch instead of merged into a chimera.
#[derive(Debug)]
pub struct RunState {
    nonce: u64,
    keys: Option<(u64, StateKeys)>,
    scratch: Vec<u8>,
}

impl Default for RunState {
    fn default() -> Self {
        RunState::new()
    }
}

impl RunState {
    pub fn new() -> Self {
        RunState {
            nonce: NEXT_RUN_NONCE.fetch_add(1, Ordering::Relaxed),
            keys: None,
            scratch: Vec::new(),
        }
    }

    /// Key handles for `nvm`, interned once and re-resolved only when the
    /// store changes identity (the learners' caching pattern).
    fn keys(&mut self, nvm: &mut Nvm) -> StateKeys {
        match self.keys {
            Some((sid, k)) if sid == nvm.store_id() => k,
            _ => {
                let k = StateKeys {
                    head: nvm.intern("run/head"),
                    sched: nvm.intern("run/sched"),
                    ckpts: nvm.intern("run/ckpts"),
                    infers: nvm.intern("run/infers"),
                    series: nvm.intern("run/series"),
                    tallies: nvm.intern("run/tallies"),
                };
                self.keys = Some((nvm.store_id(), k));
                k
            }
        }
    }

    fn read_head(nvm: &mut Nvm, key: KeyId) -> Option<Head> {
        let bytes = nvm.read_id(key)?;
        if bytes.len() != HEAD_LEN {
            return None;
        }
        let u = |i: usize| u64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap());
        if u(0) != MAGIC {
            return None;
        }
        let mut scalars = [0u64; 15];
        for (j, s) in scalars.iter_mut().enumerate() {
            *s = u(2 + j);
        }
        Some(Head {
            nonce: u(1),
            scalars,
            ckpts: u(17),
            infers: u(18),
            series: u(19),
            total_uj: f64::from_bits(u(20)),
        })
    }

    /// Checkpoint `result` + `meter` into `nvm`. Appends only the records
    /// added since the last committed save; the first save (or a save over
    /// a foreign/stale blob) degrades to a full rewrite.
    pub fn save(&mut self, nvm: &mut Nvm, result: &RunResult, meter: &EnergyMeter) -> Result<()> {
        let k = self.keys(nvm);
        // committed watermarks from the head blob — but only a head this
        // run wrote (or adopted via restore): a foreign snapshot, or one
        // claiming more records than the run holds, is rewritten from 0
        let head = Self::read_head(nvm, k.head);
        let (c0, i0, s0) = match &head {
            Some(h)
                if h.nonce == self.nonce
                    && h.ckpts <= result.checkpoints.len() as u64
                    && h.infers <= result.infer_log.len() as u64
                    && h.series <= meter.series.len() as u64 =>
            {
                (h.ckpts as usize, h.infers as usize, h.series as usize)
            }
            _ => (0, 0, 0),
        };

        // append-only vectors: one range write per vector per save
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        for c in &result.checkpoints[c0..] {
            scratch.extend_from_slice(&c.t_us.to_le_bytes());
            scratch.extend_from_slice(&c.accuracy.to_le_bytes());
            scratch.extend_from_slice(&c.learned.to_le_bytes());
            scratch.extend_from_slice(&c.inferred.to_le_bytes());
            scratch.extend_from_slice(&c.energy_uj.to_le_bytes());
            scratch.extend_from_slice(&c.voltage.to_le_bytes());
        }
        if !scratch.is_empty() {
            nvm.write_at(k.ckpts, c0 * CKPT_LEN, &scratch)?;
        }
        scratch.clear();
        for &(t, pred, truth) in &result.infer_log[i0..] {
            scratch.extend_from_slice(&t.to_le_bytes());
            scratch.push(pred as u8);
            scratch.push(truth as u8);
            scratch.extend_from_slice(&[0u8; 6]);
        }
        if !scratch.is_empty() {
            nvm.write_at(k.infers, i0 * INFER_LEN, &scratch)?;
        }
        scratch.clear();
        for &(t, uj) in &meter.series[s0..] {
            scratch.extend_from_slice(&t.to_le_bytes());
            scratch.extend_from_slice(&uj.to_le_bytes());
        }
        if !scratch.is_empty() {
            nvm.write_at(k.series, s0 * SERIES_LEN, &scratch)?;
        }

        // small wholesale parts: scheduler name + per-action tallies
        nvm.write_id(k.sched, result.scheduler.as_bytes())?;
        scratch.clear();
        for (name, t) in meter.tallies() {
            scratch.extend_from_slice(&(name.len() as u32).to_le_bytes());
            scratch.extend_from_slice(name.as_bytes());
            scratch.extend_from_slice(&t.count.to_le_bytes());
            scratch.extend_from_slice(&t.energy_uj.to_le_bytes());
            scratch.extend_from_slice(&t.time_us.to_le_bytes());
            scratch.extend_from_slice(&t.aborted.to_le_bytes());
            scratch.extend_from_slice(&t.wasted_uj.to_le_bytes());
        }
        nvm.write_id(k.tallies, &scratch)?;

        // the head commits the snapshot (written last)
        scratch.clear();
        scratch.extend_from_slice(&MAGIC.to_le_bytes());
        scratch.extend_from_slice(&self.nonce.to_le_bytes());
        for v in [
            result.learned,
            result.inferred,
            result.discarded_select,
            result.expired,
            result.cycles,
            result.power_failures,
            result.stale_plans,
            result.sensed,
            result.syncs_done,
            result.syncs_skipped,
            result.syncs_solo,
            result.checkpoints_taken,
            result.checkpoints_elided,
            result.learns_deferred,
            result.ckpt_nvm_bytes,
        ] {
            scratch.extend_from_slice(&v.to_le_bytes());
        }
        scratch.extend_from_slice(&(result.checkpoints.len() as u64).to_le_bytes());
        scratch.extend_from_slice(&(result.infer_log.len() as u64).to_le_bytes());
        scratch.extend_from_slice(&(meter.series.len() as u64).to_le_bytes());
        scratch.extend_from_slice(&meter.total_uj().to_le_bytes());
        nvm.write_id(k.head, &scratch)?;
        self.scratch = scratch;
        Ok(())
    }

    /// Restore the last committed snapshot from `nvm`, or `None` if the
    /// store holds no run state. The returned [`RunResult`] carries the
    /// finalized aggregates (`energy_uj`, `energy_series`,
    /// `action_tallies`) derived from the restored meter, exactly as
    /// [`crate::sim::engine::Engine`] derives them at the end of a run.
    pub fn restore(&mut self, nvm: &mut Nvm) -> Result<Option<(RunResult, EnergyMeter)>> {
        let k = self.keys(nvm);
        let Some(head) = Self::read_head(nvm, k.head) else {
            return Ok(None);
        };
        // adopt the snapshot's identity: a run resumed from this state
        // appends over it instead of rewriting
        self.nonce = head.nonce;
        let torn = || Error::Nvm("run state torn: head ahead of its records".into());

        let sched = nvm
            .read_id(k.sched)
            .map(|b| String::from_utf8_lossy(b).into_owned())
            .unwrap_or_default();

        let need = head.ckpts as usize * CKPT_LEN;
        let bytes = nvm.read_id(k.ckpts).unwrap_or(&[]);
        if bytes.len() < need {
            return Err(torn());
        }
        let u = |b: &[u8], at: usize| u64::from_le_bytes(b[at..at + 8].try_into().unwrap());
        let f = |b: &[u8], at: usize| f64::from_bits(u(b, at));
        let mut checkpoints = Vec::with_capacity(head.ckpts as usize);
        for i in 0..head.ckpts as usize {
            let at = i * CKPT_LEN;
            checkpoints.push(Checkpoint {
                t_us: u(bytes, at),
                accuracy: f(bytes, at + 8),
                learned: u(bytes, at + 16),
                inferred: u(bytes, at + 24),
                energy_uj: f(bytes, at + 32),
                voltage: f(bytes, at + 40),
            });
        }

        let need = head.infers as usize * INFER_LEN;
        let bytes = nvm.read_id(k.infers).unwrap_or(&[]);
        if bytes.len() < need {
            return Err(torn());
        }
        let mut infer_log = Vec::with_capacity(head.infers as usize);
        for i in 0..head.infers as usize {
            let at = i * INFER_LEN;
            infer_log.push((u(bytes, at), bytes[at + 8] != 0, bytes[at + 9] != 0));
        }

        let need = head.series as usize * SERIES_LEN;
        let bytes = nvm.read_id(k.series).unwrap_or(&[]);
        if bytes.len() < need {
            return Err(torn());
        }
        let mut series = Vec::with_capacity(head.series as usize);
        for i in 0..head.series as usize {
            let at = i * SERIES_LEN;
            series.push((u(bytes, at), f(bytes, at + 8)));
        }

        let mut tallies = Vec::new();
        if let Some(bytes) = nvm.read_id(k.tallies) {
            let mut at = 0usize;
            while at + 4 <= bytes.len() {
                let nl = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize;
                at += 4;
                if at + nl + 40 > bytes.len() {
                    return Err(torn());
                }
                let name = String::from_utf8_lossy(&bytes[at..at + nl]).into_owned();
                at += nl;
                tallies.push((
                    name,
                    ActionTally {
                        count: u(bytes, at),
                        energy_uj: f(bytes, at + 8),
                        time_us: u(bytes, at + 16),
                        aborted: u(bytes, at + 24),
                        wasted_uj: f(bytes, at + 32),
                    },
                ));
                at += 40;
            }
        }

        let [
            learned,
            inferred,
            discarded_select,
            expired,
            cycles,
            power_failures,
            stale_plans,
            sensed,
            syncs_done,
            syncs_skipped,
            syncs_solo,
            checkpoints_taken,
            checkpoints_elided,
            learns_deferred,
            ckpt_nvm_bytes,
        ] = head.scalars;
        let meter = EnergyMeter::from_parts(tallies, series, head.total_uj);
        let result = RunResult {
            scheduler: sched,
            checkpoints,
            learned,
            inferred,
            discarded_select,
            expired,
            cycles,
            power_failures,
            stale_plans,
            syncs_done,
            syncs_skipped,
            syncs_solo,
            checkpoints_taken,
            checkpoints_elided,
            learns_deferred,
            ckpt_nvm_bytes,
            energy_uj: meter.total_uj(),
            energy_series: meter.series.clone(),
            action_tallies: meter
                .tallies()
                .map(|(k, t)| (k.to_string(), t.count, t.energy_uj, t.time_us))
                .collect(),
            infer_log,
            sensed,
        };
        Ok(Some((result, meter)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actions::Action;

    fn sample_run(n_ckpts: usize) -> (RunResult, EnergyMeter) {
        let mut meter = EnergyMeter::new();
        let mut r = RunResult {
            scheduler: "intermittent_learning".into(),
            ..Default::default()
        };
        for i in 0..n_ckpts as u64 {
            meter.record_action(Action::Learn, 9_309.0, 1_551_000);
            meter.record("planner", 57.0, 4_300);
            meter.sample(i * 1_000_000);
            r.learned += 1;
            r.sensed += 2;
            r.cycles += 3;
            r.infer_log.push((i * 500_000, i % 2 == 0, i % 3 == 0));
            r.checkpoints.push(Checkpoint {
                t_us: i * 1_000_000,
                accuracy: 0.5 + 0.01 * i as f64,
                learned: r.learned,
                inferred: r.inferred,
                energy_uj: meter.total_uj(),
                voltage: 3.0,
            });
        }
        r.energy_uj = meter.total_uj();
        r.energy_series = meter.series.clone();
        r.action_tallies = meter
            .tallies()
            .map(|(k, t)| (k.to_string(), t.count, t.energy_uj, t.time_us))
            .collect();
        (r, meter)
    }

    #[test]
    fn save_restore_is_bit_identical() {
        let (r, m) = sample_run(7);
        let mut nvm = Nvm::new();
        let mut st = RunState::new();
        st.save(&mut nvm, &r, &m).unwrap();
        // host restart: fresh handles, fresh store view
        let (back_r, back_m) = RunState::new().restore(&mut nvm).unwrap().unwrap();
        assert_eq!(back_r.to_json().to_string(), r.to_json().to_string());
        assert_eq!(back_m.total_uj(), m.total_uj());
        assert_eq!(back_m.series, m.series);
        assert_eq!(back_r.infer_log, r.infer_log);
        for (k, t) in m.tallies() {
            assert_eq!(back_m.tally(k), *t, "{k}");
        }
    }

    #[test]
    fn steady_state_saves_append_o_new_records() {
        let (r, m) = sample_run(20);
        let mut nvm = Nvm::new();
        let mut st = RunState::new();
        // a run that checkpoints incrementally: save after every added
        // checkpoint, like the engine does
        let (mut partial, mut pmeter) = sample_run(1);
        st.save(&mut nvm, &partial, &pmeter).unwrap();
        let full_bytes = nvm.bytes_written;
        (partial, pmeter) = sample_run(2);
        st.save(&mut nvm, &partial, &pmeter).unwrap();
        let delta = nvm.bytes_written - full_bytes;
        // the second save appends one checkpoint/infer/series record plus
        // the small wholesale parts — far less than rewriting the run
        let one_shot = {
            let mut nvm2 = Nvm::new();
            RunState::new().save(&mut nvm2, &r, &m).unwrap();
            nvm2.bytes_written
        };
        assert!(
            delta * 3 < one_shot,
            "incremental save wrote {delta} B vs {one_shot} B full"
        );
    }

    #[test]
    fn aborted_save_leaves_the_previous_snapshot_and_self_heals() {
        let mut nvm = Nvm::new();
        let mut st = RunState::new();
        let (r1, m1) = sample_run(3);
        st.save(&mut nvm, &r1, &m1).unwrap();
        // a power-failed save inside an action transaction rolls back
        let (r2, m2) = sample_run(5);
        nvm.begin_action().unwrap();
        st.save(&mut nvm, &r2, &m2).unwrap();
        nvm.abort_action();
        let (back, _) = RunState::new().restore(&mut nvm).unwrap().unwrap();
        assert_eq!(back.to_json().to_string(), r1.to_json().to_string());
        // the next save re-appends from the committed watermarks
        st.save(&mut nvm, &r2, &m2).unwrap();
        let (back, _) = RunState::new().restore(&mut nvm).unwrap().unwrap();
        assert_eq!(back.to_json().to_string(), r2.to_json().to_string());
    }

    #[test]
    fn empty_store_restores_none() {
        let mut nvm = Nvm::new();
        assert!(RunState::new().restore(&mut nvm).unwrap().is_none());
    }

    #[test]
    fn sync_counters_round_trip_through_run_state() {
        let (mut r, m) = sample_run(3);
        r.syncs_done = 5;
        r.syncs_skipped = 2;
        r.syncs_solo = 1;
        let mut nvm = Nvm::new();
        RunState::new().save(&mut nvm, &r, &m).unwrap();
        let (back, _) = RunState::new().restore(&mut nvm).unwrap().unwrap();
        assert_eq!(back.syncs_done, 5);
        assert_eq!(back.syncs_skipped, 2);
        assert_eq!(back.syncs_solo, 1);
        assert_eq!(back.to_json().to_string(), r.to_json().to_string());
    }

    #[test]
    fn forecast_counters_round_trip_through_run_state() {
        let (mut r, m) = sample_run(3);
        r.checkpoints_taken = 9;
        r.checkpoints_elided = 4;
        r.learns_deferred = 2;
        r.ckpt_nvm_bytes = 1_234;
        let mut nvm = Nvm::new();
        RunState::new().save(&mut nvm, &r, &m).unwrap();
        let (back, _) = RunState::new().restore(&mut nvm).unwrap().unwrap();
        assert_eq!(back.checkpoints_taken, 9);
        assert_eq!(back.checkpoints_elided, 4);
        assert_eq!(back.learns_deferred, 2);
        assert_eq!(back.ckpt_nvm_bytes, 1_234);
        assert_eq!(back.to_json().to_string(), r.to_json().to_string());
    }

    #[test]
    fn fresh_run_over_adopted_nvm_replaces_the_foreign_snapshot() {
        // regression: a new run saving into NVM that carries another run's
        // snapshot (e.g. adopted only to restore the learner) must rewrite
        // it, not append onto the foreign records just because its lengths
        // fit — that would persist a chimera of two runs
        let mut nvm = Nvm::new();
        let (r_old, m_old) = sample_run(3);
        RunState::new().save(&mut nvm, &r_old, &m_old).unwrap();
        // the new run's first save happens once it already has MORE
        // records than the foreign snapshot declares
        let (mut r_new, m_new) = sample_run(5);
        for c in &mut r_new.checkpoints {
            c.accuracy += 0.25; // distinguishable from the old run's
        }
        let mut st = RunState::new();
        st.save(&mut nvm, &r_new, &m_new).unwrap();
        let (back, _) = RunState::new().restore(&mut nvm).unwrap().unwrap();
        assert_eq!(back.to_json().to_string(), r_new.to_json().to_string());
        // and a resumed run (restore, then save more) appends, not rewrites
        let mut resumed = RunState::new();
        resumed.restore(&mut nvm).unwrap().unwrap();
        let before = nvm.bytes_written;
        let (mut r_more, m_more) = sample_run(6);
        for c in &mut r_more.checkpoints {
            c.accuracy += 0.25;
        }
        resumed.save(&mut nvm, &r_more, &m_more).unwrap();
        let delta = nvm.bytes_written - before;
        let full = {
            let mut nvm2 = Nvm::new();
            RunState::new().save(&mut nvm2, &r_more, &m_more).unwrap();
            nvm2.bytes_written
        };
        assert!(delta * 2 < full, "resume rewrote instead of appending: {delta} vs {full}");
    }
}
