//! The fleet layer: one scenario generalized from a single device to `N`
//! shards — the paper's deployed *population* of energy-harvesting nodes
//! (solar air-quality stations, RF presence sensors, kinetic tags), each
//! an independent intermittent device over a de-correlated energy world.
//!
//! A [`Fleet`] owns a vector of shard states: every shard gets its own
//! [`crate::sim::World`] (harvester phase-jittered or handed a distinct
//! trace slice via the per-shard seed/offset rule), its own
//! [`crate::sim::Executor`] (an independent NVM slab) and its own
//! [`crate::sim::Policy`] — concretely, one [`Engine`] per shard, built on
//! the worker thread that runs it (compute backends are deliberately not
//! `Send`). The plain single-device `Engine` run is exactly the 1-shard
//! special case: shard 0 derives the base seed and a zero phase offset,
//! so `shards = 1` reproduces `Engine::run` bit-for-bit.
//!
//! Shard recipes come from a [`ShardFactory`] (implemented by
//! [`crate::scenario::ScenarioSpec`], which owns the seed/phase derivation
//! rule); execution fans out on the shared claim-counter pool
//! ([`crate::util::pool`]) and fans back in — in shard order, so a
//! [`FleetResult`] is deterministic for any thread count.

use crate::error::{Error, Result};
use crate::learning::ModelSnapshot;
use crate::sim::engine::Engine;
use crate::sim::RunResult;
use crate::util::json::Json;
use crate::util::pool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// Consecutive energy-gated sync rendezvous before a shard enters
/// quarantined catch-up ([`QuarantineState`]).
const QUARANTINE_AFTER: u32 = 3;
/// Cap on the quarantine backoff, in multiples of the shard's own sync
/// period per quarantine spell.
const QUARANTINE_MAX_BACKOFF: u32 = 8;

/// Wrap a shard-local failure with the shard it came from, so one bad
/// shard surfaces as a clean, attributable error instead of an anonymous
/// one. The fleet still fails as a whole — rollups over a silently
/// partial fleet would be unrepresentative — but the operator knows
/// exactly which device to look at.
pub(crate) fn shard_error(index: u32, err: Error) -> Error {
    Error::Config(format!("fleet shard {index}: {err}"))
}

/// Graceful degradation for chronically energy-gated shards: after
/// [`QUARANTINE_AFTER`] consecutive rendezvous in which a shard could
/// not charge to the radio price inside its window, it stops attending
/// for a bounded *time* backoff (1, 2, 4, … sync periods, doubling per
/// re-entry and capped at [`QUARANTINE_MAX_BACKOFF`]) and spends the
/// spell catching up — charging and working on its normal wake rhythm
/// instead of idling against a gate it cannot afford, with each sat-out
/// boundary still counted under `syncs_skipped`. One successful
/// rendezvous fully rehabilitates the shard. The backoff is denominated
/// in µs (not rounds): under the round barrier every boundary is one
/// global period apart so a spell of `backoff` periods covers exactly
/// `backoff` rounds — the pre-event-scheduler behavior, bit for bit —
/// while the event scheduler turns the same state into pushed-out wake
/// times on heterogeneous per-shard cadences. Pure per-shard state —
/// rendezvous behavior is a function of the shard's own history, so
/// fleet results stay bit-identical for any worker-thread count.
#[derive(Debug, Clone, Copy)]
pub(crate) struct QuarantineState {
    gated_streak: u32,
    /// Sit out every rendezvous at instants `<= backoff_until_us`
    /// (0 = never quarantined yet; boundaries are strictly positive).
    backoff_until_us: u64,
    backoff: u32,
}

impl QuarantineState {
    pub(crate) fn new() -> QuarantineState {
        QuarantineState {
            gated_streak: 0,
            backoff_until_us: 0,
            backoff: 1,
        }
    }

    /// True when the shard should sit out a rendezvous at `now_us`
    /// without attempting it.
    pub(crate) fn sits_out(&self, now_us: u64) -> bool {
        now_us <= self.backoff_until_us
    }

    /// The shard charged to the price and made the rendezvous: fully
    /// rehabilitated.
    pub(crate) fn on_made_rendezvous(&mut self) {
        self.gated_streak = 0;
        self.backoff = 1;
    }

    /// The shard could not afford the exchange at the `now_us` boundary
    /// of its own `period_us` sync cadence.
    pub(crate) fn on_gated(&mut self, now_us: u64, period_us: u64) {
        self.gated_streak += 1;
        if self.gated_streak >= QUARANTINE_AFTER {
            self.gated_streak = 0;
            self.backoff_until_us =
                now_us.saturating_add(u64::from(self.backoff).saturating_mul(period_us));
            self.backoff = (self.backoff * 2).min(QUARANTINE_MAX_BACKOFF);
        }
    }
}

/// One shard's identity: its index plus the derived world parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub index: u32,
    /// Derived scenario seed (base seed + index × seed stride).
    pub seed: u64,
    /// Harvester phase offset (index × phase jitter).
    pub phase_us: u64,
}

/// How merged learner state moves across the fleet at a sync boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncStrategy {
    /// Pairwise exchange: each participant merges one rotating ring
    /// partner's snapshot per round (1 Tx + 1 Rx — the radio-cheap
    /// option; state diffuses over rounds).
    Gossip,
    /// Full exchange: each participant merges every other participant's
    /// snapshot (1 Tx + (fleet−1) Rx — converges in one round, priced
    /// accordingly).
    AllReduce,
}

impl SyncStrategy {
    pub fn name(self) -> &'static str {
        match self {
            SyncStrategy::Gossip => "gossip",
            SyncStrategy::AllReduce => "all_reduce",
        }
    }

    pub fn parse(s: &str) -> Option<SyncStrategy> {
        match s {
            "gossip" => Some(SyncStrategy::Gossip),
            "all_reduce" => Some(SyncStrategy::AllReduce),
            _ => None,
        }
    }
}

/// Runtime form of the spec's `"sync"` block: when to pause the shards
/// and how to exchange state. Radio prices live in the shards' own
/// [`crate::energy::cost::CostModel`]s (spec-level overrides are applied
/// at engine build time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SyncPlan {
    /// Sync boundary period, µs (> 0).
    pub period_us: u64,
    pub strategy: SyncStrategy,
    /// The scenario horizon — boundaries lie strictly inside
    /// `(0, horizon)`; the final segment runs boundary → horizon.
    pub horizon_us: u64,
}

impl SyncPlan {
    /// The sync boundaries, in order: `period, 2·period, … < horizon`.
    pub fn boundaries(&self) -> Vec<u64> {
        if self.period_us == 0 {
            return Vec::new();
        }
        (1..)
            .map(|k| k * self.period_us)
            .take_while(|&b| b < self.horizon_us)
            .collect()
    }

    /// Snapshots a participant receives per round under `strategy` in a
    /// fleet of `shards` devices. The price is quoted against the fleet
    /// size, not the (unknowable in advance) participant count: the radio
    /// budgets a full listen window regardless of who transmits.
    pub fn rx_peers(&self, shards: u32) -> u32 {
        match self.strategy {
            SyncStrategy::Gossip => 1,
            SyncStrategy::AllReduce => shards.saturating_sub(1),
        }
    }
}

/// A recipe for building the shards of one fleet. The factory owns the
/// derivation rule (seeds, phase offsets, per-shard overrides); the
/// [`Fleet`] owns scheduling and fan-in.
pub trait ShardFactory: Sync {
    /// Number of shards (>= 1).
    fn shard_count(&self) -> u32;

    /// Identity of shard `index`.
    fn shard(&self, index: u32) -> Result<Shard>;

    /// Build shard `index`'s engine (called on the worker thread that
    /// runs it).
    fn build_shard_engine(&self, index: u32) -> Result<Engine>;

    /// Run shard `index` to its horizon.
    fn run_shard(&self, index: u32) -> Result<RunResult> {
        self.build_shard_engine(index)?.run()
    }

    /// The fleet's sync plan, if cross-device aggregation is enabled.
    /// `None` (the default) runs every shard in isolation — the PR-4
    /// behavior, bit for bit.
    fn sync_plan(&self) -> Option<SyncPlan> {
        None
    }

    /// Shard `index`'s own sync cadence, µs (0 = the shard never attends
    /// a rendezvous). Defaults to the fleet-wide plan period; factories
    /// with per-shard `sync_period_us` overrides return heterogeneous
    /// cadences here, which only the event scheduler
    /// ([`crate::sim::sched`]) can honor.
    fn shard_sync_period_us(&self, index: u32) -> u64 {
        let _ = index;
        self.sync_plan().map_or(0, |p| p.period_us)
    }

    /// Which coordinator drives a synced fleet (ignored for isolated
    /// fleets). The default is the event scheduler, which is pinned
    /// bit-identical to the round barrier under a uniform period.
    fn fleet_sched(&self) -> FleetSched {
        FleetSched::Event
    }
}

/// Which coordinator drives a synced fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FleetSched {
    /// The global discrete-event scheduler ([`crate::sim::sched`]):
    /// rendezvous are per-shard heap events, heterogeneous sync periods
    /// are honored, idle shards cost one heap entry. The default.
    #[default]
    Event,
    /// The PR-5 round barrier ([`Fleet::run_rounds`]): every shard
    /// pauses at every fleet-wide boundary. Uniform period only; kept
    /// as the reference oracle for the event scheduler's bit-identity
    /// pin.
    Rounds,
}

impl FleetSched {
    pub fn name(self) -> &'static str {
        match self {
            FleetSched::Event => "event",
            FleetSched::Rounds => "rounds",
        }
    }

    pub fn parse(s: &str) -> Option<FleetSched> {
        match s {
            "event" => Some(FleetSched::Event),
            "rounds" => Some(FleetSched::Rounds),
            _ => None,
        }
    }
}

/// Mean/min/max/total of one metric across a fleet's shards.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rollup {
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub total: f64,
}

impl Rollup {
    /// Roll up a metric over shard values (zeros for an empty fleet).
    pub fn of(xs: impl IntoIterator<Item = f64>) -> Rollup {
        let mut acc = RollupAcc::new();
        for x in xs {
            acc.fold(x);
        }
        acc.finish()
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("mean", Json::Num(self.mean)),
            ("min", Json::Num(self.min)),
            ("max", Json::Num(self.max)),
            ("total", Json::Num(self.total)),
        ])
    }
}

/// Streaming accumulator behind [`Rollup::of`]: `fold` one value at a
/// time, `finish` into the rollup. Folding in shard-index order
/// reproduces `Rollup::of` over the same values bit for bit (same
/// min/max/total op sequence — float addition is order-dependent, so
/// the streaming fleet's coordinator folds in strict index order).
#[derive(Debug, Clone, Copy)]
struct RollupAcc {
    n: usize,
    min: f64,
    max: f64,
    total: f64,
}

impl RollupAcc {
    fn new() -> RollupAcc {
        RollupAcc {
            n: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            total: 0.0,
        }
    }

    fn fold(&mut self, x: f64) {
        self.n += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.total += x;
    }

    fn finish(&self) -> Rollup {
        if self.n == 0 {
            return Rollup {
                mean: 0.0,
                min: 0.0,
                max: 0.0,
                total: 0.0,
            };
        }
        Rollup {
            mean: self.total / self.n as f64,
            min: self.min,
            max: self.max,
            total: self.total,
        }
    }
}

/// The fan-in aggregate over a fleet's shards.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetRollup {
    pub shards: usize,
    /// Final probe accuracy per shard.
    pub final_accuracy: Rollup,
    /// Mean probe accuracy per shard (3 warmup checkpoints skipped).
    pub mean_accuracy: Rollup,
    /// Total energy spent per shard, µJ.
    pub energy_uj: Rollup,
    pub learned: Rollup,
    pub inferred: Rollup,
    pub power_failures: Rollup,
    pub stale_plans: Rollup,
    /// Completed / energy-skipped / solo sync rounds per shard (all zero
    /// for an isolated fleet; omitted from the JSON then, so sync-less
    /// documents keep the PR-4 shape byte for byte).
    pub syncs_done: Rollup,
    pub syncs_skipped: Rollup,
    pub syncs_solo: Rollup,
    /// Forecast-mode checkpoint counters per shard (all zero unless the
    /// `forecast` policy knob is on; omitted from the JSON then, so
    /// default documents keep the pre-forecast shape byte for byte).
    pub checkpoints_taken: Rollup,
    pub checkpoints_elided: Rollup,
    pub learns_deferred: Rollup,
}

impl FleetRollup {
    pub fn of(shards: &[RunResult]) -> FleetRollup {
        let mut acc = FleetRollupAcc::new();
        for r in shards {
            acc.fold(&ShardStats::of(r));
        }
        acc.finish()
    }

    pub fn to_json(&self) -> Json {
        let mut kvs = vec![
            ("shards", Json::Num(self.shards as f64)),
            ("final_accuracy", self.final_accuracy.to_json()),
            ("mean_accuracy", self.mean_accuracy.to_json()),
            ("energy_uj", self.energy_uj.to_json()),
            ("learned", self.learned.to_json()),
            ("inferred", self.inferred.to_json()),
            ("power_failures", self.power_failures.to_json()),
            ("stale_plans", self.stale_plans.to_json()),
        ];
        if self.syncs_done.total + self.syncs_skipped.total + self.syncs_solo.total > 0.0 {
            kvs.push(("syncs_done", self.syncs_done.to_json()));
            kvs.push(("syncs_skipped", self.syncs_skipped.to_json()));
            kvs.push(("syncs_solo", self.syncs_solo.to_json()));
        }
        if self.checkpoints_taken.total + self.checkpoints_elided.total > 0.0 {
            kvs.push(("checkpoints_taken", self.checkpoints_taken.to_json()));
            kvs.push(("checkpoints_elided", self.checkpoints_elided.to_json()));
            kvs.push(("learns_deferred", self.learns_deferred.to_json()));
        }
        Json::obj(kvs)
    }
}

/// The scalar metrics one shard contributes to the fan-in — everything
/// a streaming fleet retains of a `RunResult` before dropping it
/// (struct-of-arrays across shards: the fleet keeps per-metric
/// accumulators, not per-shard documents). Field order mirrors
/// [`FleetRollup`]; values are exactly what [`FleetRollup::of`] reads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardStats {
    pub final_accuracy: f64,
    pub mean_accuracy: f64,
    pub energy_uj: f64,
    pub learned: f64,
    pub inferred: f64,
    pub power_failures: f64,
    pub stale_plans: f64,
    pub syncs_done: f64,
    pub syncs_skipped: f64,
    pub syncs_solo: f64,
    pub checkpoints_taken: f64,
    pub checkpoints_elided: f64,
    pub learns_deferred: f64,
}

impl ShardStats {
    pub fn of(r: &RunResult) -> ShardStats {
        ShardStats {
            final_accuracy: r.final_accuracy(),
            mean_accuracy: r.mean_accuracy(3),
            energy_uj: r.energy_uj,
            learned: r.learned as f64,
            inferred: r.inferred as f64,
            power_failures: r.power_failures as f64,
            stale_plans: r.stale_plans as f64,
            syncs_done: r.syncs_done as f64,
            syncs_skipped: r.syncs_skipped as f64,
            syncs_solo: r.syncs_solo as f64,
            checkpoints_taken: r.checkpoints_taken as f64,
            checkpoints_elided: r.checkpoints_elided as f64,
            learns_deferred: r.learns_deferred as f64,
        }
    }
}

/// Streaming accumulator behind [`FleetRollup::of`]: one [`RollupAcc`]
/// per metric, fed shard stats in index order. The retained path
/// (`FleetRollup::of` over a `Vec<RunResult>`) and the streaming path
/// (`sim::soa`, which folds and drops) both go through this type, so
/// their rollups cannot drift — each metric's accumulator sees the
/// identical value sequence either way.
#[derive(Debug, Clone)]
pub struct FleetRollupAcc {
    shards: usize,
    accs: [RollupAcc; 13],
}

impl FleetRollupAcc {
    pub fn new() -> FleetRollupAcc {
        FleetRollupAcc {
            shards: 0,
            accs: [RollupAcc::new(); 13],
        }
    }

    /// Fold one shard's stats in (must be called in shard-index order
    /// for bit-identity with the retained path).
    pub fn fold(&mut self, s: &ShardStats) {
        self.shards += 1;
        self.accs[0].fold(s.final_accuracy);
        self.accs[1].fold(s.mean_accuracy);
        self.accs[2].fold(s.energy_uj);
        self.accs[3].fold(s.learned);
        self.accs[4].fold(s.inferred);
        self.accs[5].fold(s.power_failures);
        self.accs[6].fold(s.stale_plans);
        self.accs[7].fold(s.syncs_done);
        self.accs[8].fold(s.syncs_skipped);
        self.accs[9].fold(s.syncs_solo);
        self.accs[10].fold(s.checkpoints_taken);
        self.accs[11].fold(s.checkpoints_elided);
        self.accs[12].fold(s.learns_deferred);
    }

    pub fn finish(&self) -> FleetRollup {
        FleetRollup {
            shards: self.shards,
            final_accuracy: self.accs[0].finish(),
            mean_accuracy: self.accs[1].finish(),
            energy_uj: self.accs[2].finish(),
            learned: self.accs[3].finish(),
            inferred: self.accs[4].finish(),
            power_failures: self.accs[5].finish(),
            stale_plans: self.accs[6].finish(),
            syncs_done: self.accs[7].finish(),
            syncs_skipped: self.accs[8].finish(),
            syncs_solo: self.accs[9].finish(),
            checkpoints_taken: self.accs[10].finish(),
            checkpoints_elided: self.accs[11].finish(),
            learns_deferred: self.accs[12].finish(),
        }
    }
}

impl Default for FleetRollupAcc {
    fn default() -> Self {
        Self::new()
    }
}

/// Everything a fleet run produces: the per-shard results (in shard
/// order) plus the fan-in rollups.
#[derive(Debug, Clone)]
pub struct FleetResult {
    pub shards: Vec<RunResult>,
    pub rollup: FleetRollup,
}

impl FleetResult {
    /// Fan shard results (in shard order) into the aggregate.
    pub fn aggregate(shards: Vec<RunResult>) -> FleetResult {
        let rollup = FleetRollup::of(&shards);
        FleetResult { shards, rollup }
    }

    /// Shard 0's result — for a 1-shard fleet, exactly the single-device
    /// [`RunResult`].
    pub fn primary(&self) -> &RunResult {
        &self.shards[0]
    }

    /// Full JSON rendering: rollups plus every shard's run document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards.len() as f64)),
            ("rollup", self.rollup.to_json()),
            (
                "per_shard",
                Json::Arr(self.shards.iter().map(RunResult::to_json).collect()),
            ),
        ])
    }
}

/// The fleet coordinator: shard identities up front, engines built and
/// run on the worker pool, results fanned in deterministically.
pub struct Fleet<'a, F: ShardFactory + ?Sized> {
    factory: &'a F,
    shards: Vec<Shard>,
}

impl<'a, F: ShardFactory + ?Sized> Fleet<'a, F> {
    /// Derive every shard's identity from the factory.
    pub fn new(factory: &'a F) -> Result<Self> {
        let n = factory.shard_count();
        if n == 0 {
            return Err(Error::Config("fleet: shard count must be >= 1".into()));
        }
        let shards = (0..n).map(|i| factory.shard(i)).collect::<Result<_>>()?;
        Ok(Fleet { factory, shards })
    }

    /// The shard identities, in shard order.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Run every shard (`threads` = 0 uses the available parallelism) and
    /// fan the results in. Deterministic in shard order for any thread
    /// count; the first failing shard fails the fleet.
    ///
    /// Without a sync plan (or with a degenerate one — a single shard, or
    /// no shard with a boundary inside the horizon) every shard runs in
    /// isolation on the claim-counter pool, exactly the PR-4 path. With
    /// one, the fleet is driven by the factory's [`FleetSched`]: the
    /// event scheduler ([`crate::sim::sched`], the default) turns each
    /// shard's own boundaries into heap events, or the round barrier
    /// ([`Fleet::run_rounds`]) pauses all shards at every fleet-wide
    /// boundary. Both exchange learner snapshots under the radio energy
    /// gate, merge, and continue; under a uniform period they are pinned
    /// bit-identical.
    pub fn run(&self, threads: usize) -> Result<FleetResult> {
        let plan = self.factory.sync_plan().filter(|p| {
            self.shards.len() > 1
                && self.shards.iter().any(|sh| {
                    let period = self.factory.shard_sync_period_us(sh.index);
                    period > 0 && period < p.horizon_us
                })
        });
        match plan {
            Some(plan) => match self.factory.fleet_sched() {
                FleetSched::Event => {
                    super::sched::run_events(self.factory, &self.shards, threads, plan)
                }
                FleetSched::Rounds => self.run_rounds(threads, plan),
            },
            None => {
                let results = pool::run_indexed(self.shards.len(), threads, |i| {
                    let index = self.shards[i].index;
                    self.factory
                        .run_shard(index)
                        .map_err(|e| shard_error(index, e))
                });
                let shards: Result<Vec<RunResult>> = results.into_iter().collect();
                Ok(FleetResult::aggregate(shards?))
            }
        }
    }

    /// The round scheduler. Engines are not `Send` (their compute
    /// backends are thread-pinned), so shards are claimed once through an
    /// atomic counter and stay pinned to the worker that built them; the
    /// claim order cannot affect results because every shard's execution
    /// and every round's merge set are deterministic functions of shard
    /// state and shard index alone — which is what makes the
    /// [`FleetResult`] bit-identical for any thread count.
    ///
    /// Per round: every worker runs its shards to the boundary
    /// ([`Engine::run_until`]) and reports one of {snapshot, out} per
    /// shard — out covering energy-skipped exchanges, quarantined
    /// shards ([`QuarantineState`]), shards past the horizon, failed
    /// shards and non-snapshotting learners. The coordinator (the
    /// calling thread) sorts the participants by shard index and
    /// broadcasts the round plan; each participant then pays the radio
    /// price ([`Engine::commit_sync`]) and merges its peer set
    /// ([`Engine::apply_sync`]) — unless the plan shows it was alone, in
    /// which case it skips the exchange for free ([`Engine::solo_sync`]).
    fn run_rounds(&self, threads: usize, plan: SyncPlan) -> Result<FleetResult> {
        enum Report {
            Snapshot(ModelSnapshot),
            Out,
            /// A worker panicked: the coordinator must stop waiting on the
            /// round barrier (sent outside the panic path, so the hang a
            /// lost worker would otherwise cause becomes a clean error).
            Poison,
        }
        /// One round's participants, sorted by shard index.
        struct RoundPlan {
            round: usize,
            participants: Vec<(usize, ModelSnapshot)>,
        }
        impl RoundPlan {
            /// The snapshots shard `i` merges this round (empty if it sat
            /// the round out or is the only participant).
            fn peers_for(&self, shard: usize, strategy: SyncStrategy) -> Vec<&ModelSnapshot> {
                let m = self.participants.len();
                let Some(pos) = self.participants.iter().position(|&(i, _)| i == shard) else {
                    return Vec::new();
                };
                if m < 2 {
                    return Vec::new();
                }
                match strategy {
                    SyncStrategy::AllReduce => self
                        .participants
                        .iter()
                        .filter(|&&(i, _)| i != shard)
                        .map(|(_, s)| s)
                        .collect(),
                    SyncStrategy::Gossip => {
                        // rotating ring partner: the offset walks 1..m-1
                        // across rounds, so the gossip graph reaches every
                        // pair without ever pairing a shard with itself
                        let offset = 1 + self.round % (m - 1);
                        vec![&self.participants[(pos + offset) % m].1]
                    }
                }
            }
        }

        let n = self.shards.len();
        let workers = pool::resolve_workers(threads, n);
        let rx_peers = plan.rx_peers(n as u32);
        let boundaries = plan.boundaries();
        let claim = AtomicUsize::new(0);
        let (rep_tx, rep_rx) = mpsc::channel::<(usize, Report)>();
        let (res_tx, res_rx) = mpsc::channel::<(usize, Result<RunResult>)>();
        let mut results: Vec<Option<Result<RunResult>>> = (0..n).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut plan_txs = Vec::with_capacity(workers);
            for _ in 0..workers {
                let (plan_tx, plan_rx) = mpsc::channel::<Arc<RoundPlan>>();
                plan_txs.push(plan_tx);
                let rep_tx = rep_tx.clone();
                let poison_tx = rep_tx.clone();
                let res_tx = res_tx.clone();
                let (claim, boundaries, factory, shards) =
                    (&claim, &boundaries, self.factory, &self.shards);
                scope.spawn(move || {
                    let body = std::panic::AssertUnwindSafe(|| {
                    /// One worker-owned shard: its slot, engine, and the
                    /// round bookkeeping that must stay pinned to it.
                    struct Owned {
                        slot: usize,
                        engine: Result<Engine>,
                        quarantine: QuarantineState,
                        /// Sent a snapshot at the current boundary; pays
                        /// (or goes solo) once the round plan arrives.
                        in_round: bool,
                    }
                    // claim shards and build their engines on this thread
                    let mut mine: Vec<Owned> = Vec::new();
                    loop {
                        let i = claim.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        mine.push(Owned {
                            slot: i,
                            engine: factory.build_shard_engine(shards[i].index),
                            quarantine: QuarantineState::new(),
                            in_round: false,
                        });
                    }
                    if mine.is_empty() {
                        return;
                    }
                    'rounds: for (round, &boundary) in boundaries.iter().enumerate() {
                        // the rendezvous window for charging toward the
                        // radio price runs to the next boundary
                        let deadline = boundaries
                            .get(round + 1)
                            .copied()
                            .unwrap_or(plan.horizon_us);
                        for sh in &mut mine {
                            let report = match &mut sh.engine {
                                Ok(e) => {
                                    // forecast-aware shards hold the radio
                                    // price in reserve ahead of the boundary
                                    // (no-op unless the knob is on)
                                    e.note_next_sync(boundary, rx_peers);
                                    match e.run_until(boundary) {
                                        // the horizon ends a shard's rounds
                                        Ok(()) if e.now_us() < e.cfg.horizon_us => {
                                            if sh.quarantine.sits_out(boundary) {
                                                // quarantined catch-up: keep
                                                // the normal charge/wake
                                                // rhythm instead of idling at
                                                // a gate it cannot afford
                                                e.note_sync_skipped();
                                                Report::Out
                                            } else {
                                                match e.prepare_sync(rx_peers, deadline) {
                                                    Some(s) => {
                                                        sh.quarantine.on_made_rendezvous();
                                                        sh.in_round = true;
                                                        Report::Snapshot(s)
                                                    }
                                                    None => {
                                                        sh.quarantine
                                                            .on_gated(boundary, plan.period_us);
                                                        Report::Out
                                                    }
                                                }
                                            }
                                        }
                                        Ok(()) => Report::Out,
                                        Err(err) => {
                                            sh.engine = Err(err);
                                            Report::Out
                                        }
                                    }
                                }
                                Err(_) => Report::Out,
                            };
                            if rep_tx.send((sh.slot, report)).is_err() {
                                return;
                            }
                        }
                        let Ok(round_plan) = plan_rx.recv() else {
                            // coordination collapsed (a sibling worker
                            // panicked and the coordinator poisoned the
                            // rounds): stop syncing and run this worker's
                            // shards out, so healthy results still report
                            break 'rounds;
                        };
                        for sh in &mut mine {
                            if !std::mem::take(&mut sh.in_round) {
                                continue;
                            }
                            if let Ok(e) = &mut sh.engine {
                                if round_plan.participants.len() >= 2 {
                                    // pay the fleet-quoted price (the
                                    // radio budgets a full listen window
                                    // regardless of who transmits), then
                                    // merge the peer set
                                    e.commit_sync(rx_peers);
                                    let peers =
                                        round_plan.peers_for(sh.slot, plan.strategy);
                                    if let Err(err) = e.apply_sync(&peers) {
                                        sh.engine = Err(err);
                                    }
                                } else {
                                    // nobody else made the rendezvous:
                                    // skip the exchange for free
                                    e.solo_sync();
                                }
                            }
                        }
                    }
                    for sh in mine {
                        let out = sh
                            .engine
                            .and_then(|mut e| {
                                let horizon = e.cfg.horizon_us;
                                e.run_until(horizon)?;
                                e.finish()
                            })
                            .map_err(|e| shard_error(shards[sh.slot].index, e));
                        if res_tx.send((sh.slot, out)).is_err() {
                            return;
                        }
                    }
                    });
                    if std::panic::catch_unwind(body).is_err() {
                        // a worker bug must not hang the round barrier:
                        // poison the coordinator so it stops waiting (the
                        // panic message already went to stderr via the
                        // default hook); the lost worker's shards surface
                        // as worker-exited errors at collection
                        let _ = poison_tx.send((usize::MAX, Report::Poison));
                    }
                });
            }
            drop(rep_tx);
            drop(res_tx);
            // coordinate the rounds: n reports in, one sorted plan out
            'rounds: for round in 0..boundaries.len() {
                let mut participants = Vec::new();
                for _ in 0..n {
                    match rep_rx.recv() {
                        Ok((i, Report::Snapshot(s))) => participants.push((i, s)),
                        Ok((_, Report::Out)) => {}
                        // a worker panicked (poison) or every worker
                        // exited: stop coordinating — dropping the plan
                        // channels unblocks the survivors, which then
                        // report whatever they can on the results channel
                        Ok((_, Report::Poison)) | Err(_) => break 'rounds,
                    }
                }
                participants.sort_by_key(|&(i, _)| i);
                let round_plan = Arc::new(RoundPlan {
                    round,
                    participants,
                });
                for plan_tx in &plan_txs {
                    let _ = plan_tx.send(round_plan.clone());
                }
            }
            drop(plan_txs);
            for (i, r) in res_rx {
                results[i] = Some(r);
            }
        });
        let shards: Result<Vec<RunResult>> = results
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                r.unwrap_or_else(|| {
                    Err(Error::Config(format!(
                        "fleet shard {i}: worker exited without reporting a result"
                    )))
                })
            })
            .collect();
        Ok(FleetResult::aggregate(shards?))
    }
}

/// Shared test fixture: the minimal constant-power fleet factory, used
/// by this module's tests and the streaming fleet's ([`super::soa`]).
#[cfg(test)]
pub(crate) mod testfleet {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::energy::cost::CostModel;
    use crate::energy::harvester::{Constant, Harvester, PhaseShift};
    use crate::energy::Capacitor;
    use crate::learning::KnnAnomalyLearner;
    use crate::sensors::accel::{Accel, MotionProfile};
    use crate::sim::SimConfig;

    /// Minimal factory: constant-power worlds, seeds striding by 10.
    pub(crate) struct ConstFleet {
        pub n: u32,
    }

    impl ShardFactory for ConstFleet {
        fn shard_count(&self) -> u32 {
            self.n
        }
        fn shard(&self, index: u32) -> Result<Shard> {
            Ok(Shard {
                index,
                seed: 1 + u64::from(index) * 10,
                phase_us: u64::from(index) * 1_000_000,
            })
        }
        fn build_shard_engine(&self, index: u32) -> Result<Engine> {
            let sh = self.shard(index)?;
            let profile = MotionProfile::alternating_hours(1.0, 3.0, 2);
            let h: Box<dyn Harvester> = if sh.phase_us > 0 {
                Box::new(PhaseShift::new(Box::new(Constant(0.010)), sh.phase_us))
            } else {
                Box::new(Constant(0.010))
            };
            Engine::builder()
                .sim(SimConfig {
                    seed: sh.seed,
                    horizon_us: 900_000_000,
                    eval_period_us: 300_000_000,
                    probe_count: 10,
                    charge_step_us: 10_000_000,
                    probe_lookback_us: 3_600_000_000,
                    ..Default::default()
                })
                .harvester(h)
                .capacitor(Capacitor::vibration())
                .sensor(Box::new(Accel::new(profile, sh.seed)))
                .learner(Box::new(KnnAnomalyLearner::new()))
                .backend(Box::new(NativeBackend::new()))
                .costs(CostModel::kmeans())
                .build()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testfleet::ConstFleet;
    use super::*;

    fn fingerprint(f: &FleetResult) -> String {
        f.to_json().to_string()
    }

    #[test]
    fn rollup_math_is_exact() {
        let r = Rollup::of([1.0, 2.0, 3.0]);
        assert_eq!(
            r,
            Rollup {
                mean: 2.0,
                min: 1.0,
                max: 3.0,
                total: 6.0
            }
        );
        let z = Rollup::of(std::iter::empty::<f64>());
        assert_eq!(z.mean, 0.0);
        assert_eq!(z.total, 0.0);
    }

    #[test]
    fn fleet_results_are_deterministic_across_thread_counts() {
        let factory = ConstFleet { n: 4 };
        let fleet = Fleet::new(&factory).unwrap();
        assert_eq!(fleet.shards().len(), 4);
        assert_eq!(fleet.shards()[2].seed, 21);
        let serial = fleet.run(1).unwrap();
        let two = fleet.run(2).unwrap();
        let all = fleet.run(0).unwrap();
        assert_eq!(fingerprint(&serial), fingerprint(&two));
        assert_eq!(fingerprint(&serial), fingerprint(&all));
        assert!(serial.shards.iter().any(|r| r.sensed > 0), "dead fleet");
    }

    #[test]
    fn rollups_fan_in_every_shard() {
        let factory = ConstFleet { n: 3 };
        let fr = Fleet::new(&factory).unwrap().run(0).unwrap();
        assert_eq!(fr.rollup.shards, 3);
        let total: u64 = fr.shards.iter().map(|r| r.learned).sum();
        assert_eq!(fr.rollup.learned.total, total as f64);
        assert!(fr.rollup.energy_uj.min <= fr.rollup.energy_uj.mean);
        assert!(fr.rollup.energy_uj.mean <= fr.rollup.energy_uj.max);
        // distinct seeds actually diversified the shards
        let fp: Vec<String> = fr.shards.iter().map(|r| r.to_json().to_string()).collect();
        assert!(fp.iter().any(|f| f != &fp[0]), "shards identical");
        // JSON rendering carries rollup + per-shard docs
        let doc = fr.to_json().to_string();
        assert!(doc.contains("\"rollup\"") && doc.contains("\"per_shard\""));
    }

    #[test]
    fn one_shard_fleet_is_the_plain_engine_run() {
        let factory = ConstFleet { n: 1 };
        let fr = Fleet::new(&factory).unwrap().run(0).unwrap();
        let solo = factory.build_shard_engine(0).unwrap().run().unwrap();
        assert_eq!(
            fr.primary().to_json().to_string(),
            solo.to_json().to_string()
        );
    }

    #[test]
    fn zero_shards_is_a_config_error() {
        let factory = ConstFleet { n: 0 };
        assert!(Fleet::new(&factory).is_err());
    }

    /// ConstFleet plus a sync plan: the round-scheduler test rig.
    struct SyncedFleet {
        inner: ConstFleet,
        plan: SyncPlan,
    }

    impl ShardFactory for SyncedFleet {
        fn shard_count(&self) -> u32 {
            self.inner.shard_count()
        }
        fn shard(&self, index: u32) -> Result<Shard> {
            self.inner.shard(index)
        }
        fn build_shard_engine(&self, index: u32) -> Result<Engine> {
            self.inner.build_shard_engine(index)
        }
        fn sync_plan(&self) -> Option<SyncPlan> {
            Some(self.plan)
        }
    }

    fn synced(n: u32, period_us: u64, strategy: SyncStrategy) -> SyncedFleet {
        SyncedFleet {
            inner: ConstFleet { n },
            plan: SyncPlan {
                period_us,
                strategy,
                horizon_us: 900_000_000, // ConstFleet's horizon
            },
        }
    }

    #[test]
    fn sync_plan_boundaries_lie_strictly_inside_the_horizon() {
        let p = SyncPlan {
            period_us: 300,
            strategy: SyncStrategy::Gossip,
            horizon_us: 900,
        };
        assert_eq!(p.boundaries(), vec![300, 600]);
        let exact = SyncPlan { period_us: 450, ..p };
        assert_eq!(exact.boundaries(), vec![450]);
        let none = SyncPlan { period_us: 900, ..p };
        assert!(none.boundaries().is_empty());
        let zero = SyncPlan { period_us: 0, ..p };
        assert!(zero.boundaries().is_empty());
        assert_eq!(p.rx_peers(16), 1);
        let ar = SyncPlan {
            strategy: SyncStrategy::AllReduce,
            ..p
        };
        assert_eq!(ar.rx_peers(16), 15);
    }

    #[test]
    fn synced_fleet_is_bit_identical_across_thread_counts() {
        for strategy in [SyncStrategy::Gossip, SyncStrategy::AllReduce] {
            let factory = synced(4, 300_000_000, strategy);
            let fleet = Fleet::new(&factory).unwrap();
            let one = fleet.run(1).unwrap();
            let two = fleet.run(2).unwrap();
            let all = fleet.run(0).unwrap();
            assert_eq!(fingerprint(&one), fingerprint(&two), "{strategy:?}");
            assert_eq!(fingerprint(&one), fingerprint(&all), "{strategy:?}");
            // the rounds actually happened and were paid for
            let done: u64 = one.shards.iter().map(|r| r.syncs_done).sum();
            assert!(done > 0, "{strategy:?}: no sync exchange completed");
            assert_eq!(one.rollup.syncs_done.total, done as f64);
            let radio: u64 = one
                .shards
                .iter()
                .flat_map(|r| &r.action_tallies)
                .filter(|(n, ..)| n == "tx")
                .map(|&(_, c, ..)| c)
                .sum();
            assert_eq!(radio, done, "one tx per completed exchange");
            // sync counters reach the JSON document
            assert!(fingerprint(&one).contains("\"syncs_done\""));
        }
    }

    #[test]
    fn degenerate_sync_plans_reproduce_the_isolated_fleet() {
        // no boundary inside the horizon, or a single shard: the round
        // scheduler must not engage at all (bit-identical to PR-4 runs)
        let isolated = Fleet::new(&ConstFleet { n: 3 }).unwrap().run(0).unwrap();
        let late = synced(3, 900_000_000, SyncStrategy::Gossip); // period == horizon
        let fr = Fleet::new(&late).unwrap().run(0).unwrap();
        assert_eq!(fingerprint(&fr), fingerprint(&isolated));
        let solo_sync = synced(1, 300_000_000, SyncStrategy::AllReduce);
        let solo = Fleet::new(&solo_sync).unwrap().run(0).unwrap();
        let solo_plain = Fleet::new(&ConstFleet { n: 1 }).unwrap().run(0).unwrap();
        assert_eq!(fingerprint(&solo), fingerprint(&solo_plain));
        assert!(!fingerprint(&solo).contains("syncs_done"));
    }

    #[test]
    fn sync_changes_the_runs_but_only_after_the_first_boundary() {
        // a synced shard's trajectory is identical to its isolated twin
        // up to the first sync boundary (run_until pauses, nothing else),
        // then diverges once merged state and radio time arrive
        let isolated = Fleet::new(&ConstFleet { n: 3 }).unwrap().run(0).unwrap();
        let fr = Fleet::new(&synced(3, 300_000_000, SyncStrategy::AllReduce))
            .unwrap()
            .run(0)
            .unwrap();
        assert!(fr.shards.iter().any(|r| r.syncs_done > 0));
        for (a, b) in fr.shards.iter().zip(&isolated.shards) {
            // checkpoints strictly before the first boundary agree
            for (ca, cb) in a.checkpoints.iter().zip(&b.checkpoints) {
                if ca.t_us >= 300_000_000 {
                    break;
                }
                assert_eq!(ca.t_us, cb.t_us);
                assert_eq!(ca.learned, cb.learned);
                assert_eq!(ca.energy_uj, cb.energy_uj);
            }
        }
    }

    #[test]
    fn quarantine_backoff_doubles_and_caps() {
        // always-gated shard on a fixed boundary cadence: 3 gated
        // boundaries buy 1 sat-out period, then 2, 4, 8, 8, ...
        // (doubling, capped) — the time-based backoff walks the exact
        // round schedule the pre-event-scheduler (round-counted) state
        // machine produced
        const P: u64 = 1_000_000;
        let mut q = QuarantineState::new();
        let mut pattern = String::new();
        for k in 1..=40u64 {
            let boundary = k * P;
            if q.sits_out(boundary) {
                pattern.push('q');
            } else {
                q.on_gated(boundary, P);
                pattern.push('g');
            }
        }
        assert!(
            pattern.starts_with("gggqgggqqgggqqqqgggqqqqqqqq"),
            "unexpected schedule: {pattern}"
        );
        // one successful rendezvous fully rehabilitates
        let mut q = QuarantineState::new();
        for k in 1..=3u64 {
            assert!(!q.sits_out(k * P));
            q.on_gated(k * P, P);
        }
        assert!(q.sits_out(4 * P), "third gate should trigger quarantine");
        assert!(!q.sits_out(5 * P), "first sit-out spent");
        q.on_made_rendezvous();
        q.on_gated(6 * P, P);
        q.on_gated(7 * P, P);
        assert!(!q.sits_out(8 * P), "streak reset by the rendezvous");
        q.on_gated(8 * P, P);
        assert!(q.sits_out(9 * P), "backoff restarts at one period");
        assert!(!q.sits_out(10 * P));
    }

    /// ConstFleet's recipe, but with one harvester power per shard — the
    /// rig for fleets where some shards can afford the radio and some
    /// never can.
    struct MixedPowerFleet {
        powers: Vec<f64>,
        plan: Option<SyncPlan>,
    }

    impl ShardFactory for MixedPowerFleet {
        fn shard_count(&self) -> u32 {
            self.powers.len() as u32
        }
        fn shard(&self, index: u32) -> Result<Shard> {
            Ok(Shard {
                index,
                seed: 1 + u64::from(index) * 10,
                phase_us: 0,
            })
        }
        fn build_shard_engine(&self, index: u32) -> Result<Engine> {
            use crate::backend::native::NativeBackend;
            use crate::energy::cost::CostModel;
            use crate::energy::harvester::Constant;
            use crate::energy::Capacitor;
            use crate::learning::KnnAnomalyLearner;
            use crate::sensors::accel::{Accel, MotionProfile};
            use crate::sim::SimConfig;
            let sh = self.shard(index)?;
            let profile = MotionProfile::alternating_hours(1.0, 3.0, 2);
            Engine::builder()
                .sim(SimConfig {
                    seed: sh.seed,
                    horizon_us: 900_000_000,
                    eval_period_us: 300_000_000,
                    probe_count: 10,
                    charge_step_us: 10_000_000,
                    probe_lookback_us: 3_600_000_000,
                    ..Default::default()
                })
                .harvester(Box::new(Constant(self.powers[index as usize])))
                .capacitor(Capacitor::vibration())
                .sensor(Box::new(Accel::new(profile, sh.seed)))
                .learner(Box::new(KnnAnomalyLearner::new()))
                .backend(Box::new(NativeBackend::new()))
                .costs(CostModel::kmeans())
                .build()
        }
        fn sync_plan(&self) -> Option<SyncPlan> {
            self.plan
        }
    }

    #[test]
    fn lone_rendezvous_participant_skips_the_exchange_and_counts_solo() {
        // shard 0 harvests plenty; shard 1 harvests nothing, so it is
        // energy-gated at every rendezvous and shard 0 always stands alone
        let factory = MixedPowerFleet {
            powers: vec![0.010, 0.0],
            plan: Some(SyncPlan {
                period_us: 300_000_000,
                strategy: SyncStrategy::Gossip,
                horizon_us: 900_000_000,
            }),
        };
        let fleet = Fleet::new(&factory).unwrap();
        let fr = fleet.run(1).unwrap();
        let live = &fr.shards[0];
        let dark = &fr.shards[1];
        assert!(live.syncs_solo > 0, "live shard never stood alone");
        assert_eq!(live.syncs_done, 0, "nobody to exchange with");
        // the lone participant pays nothing: no radio action ever fires
        assert!(
            !live.action_tallies.iter().any(|(n, ..)| n == "tx"),
            "solo rendezvous still paid the broadcast"
        );
        assert!(dark.syncs_skipped > 0, "dark shard should be gated");
        assert_eq!(dark.syncs_done + dark.syncs_solo, 0);
        assert_eq!(fr.rollup.syncs_solo.total, live.syncs_solo as f64);
        assert!(fingerprint(&fr).contains("\"syncs_solo\""));
        // per-shard quarantine state keeps thread counts bit-identical
        assert_eq!(fingerprint(&fr), fingerprint(&fleet.run(2).unwrap()));
        assert_eq!(fingerprint(&fr), fingerprint(&fleet.run(0).unwrap()));
    }

    /// ConstFleet with one shard whose engine fails to build — standing in
    /// for a shard whose NVM image no longer restores.
    struct BrokenShardFleet {
        inner: ConstFleet,
        broken: u32,
        plan: Option<SyncPlan>,
    }

    impl ShardFactory for BrokenShardFleet {
        fn shard_count(&self) -> u32 {
            self.inner.shard_count()
        }
        fn shard(&self, index: u32) -> Result<Shard> {
            self.inner.shard(index)
        }
        fn build_shard_engine(&self, index: u32) -> Result<Engine> {
            if index == self.broken {
                return Err(Error::Nvm("restore failed: torn learner snapshot".into()));
            }
            self.inner.build_shard_engine(index)
        }
        fn sync_plan(&self) -> Option<SyncPlan> {
            self.plan
        }
    }

    #[test]
    fn failing_shard_surfaces_a_clean_per_shard_error() {
        // both the isolated pool and the round scheduler must name the
        // shard that failed, not just bubble a bare NVM error
        let plans = [
            None,
            Some(SyncPlan {
                period_us: 300_000_000,
                strategy: SyncStrategy::Gossip,
                horizon_us: 900_000_000,
            }),
        ];
        for plan in plans {
            let factory = BrokenShardFleet {
                inner: ConstFleet { n: 3 },
                broken: 1,
                plan,
            };
            let err = Fleet::new(&factory).unwrap().run(0).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("fleet shard 1"), "{msg}");
            assert!(msg.contains("torn learner snapshot"), "{msg}");
        }
    }
}
