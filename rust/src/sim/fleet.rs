//! The fleet layer: one scenario generalized from a single device to `N`
//! shards — the paper's deployed *population* of energy-harvesting nodes
//! (solar air-quality stations, RF presence sensors, kinetic tags), each
//! an independent intermittent device over a de-correlated energy world.
//!
//! A [`Fleet`] owns a vector of shard states: every shard gets its own
//! [`crate::sim::World`] (harvester phase-jittered or handed a distinct
//! trace slice via the per-shard seed/offset rule), its own
//! [`crate::sim::Executor`] (an independent NVM slab) and its own
//! [`crate::sim::Policy`] — concretely, one [`Engine`] per shard, built on
//! the worker thread that runs it (compute backends are deliberately not
//! `Send`). The plain single-device `Engine` run is exactly the 1-shard
//! special case: shard 0 derives the base seed and a zero phase offset,
//! so `shards = 1` reproduces `Engine::run` bit-for-bit.
//!
//! Shard recipes come from a [`ShardFactory`] (implemented by
//! [`crate::scenario::ScenarioSpec`], which owns the seed/phase derivation
//! rule); execution fans out on the shared claim-counter pool
//! ([`crate::util::pool`]) and fans back in — in shard order, so a
//! [`FleetResult`] is deterministic for any thread count.

use crate::error::{Error, Result};
use crate::sim::engine::Engine;
use crate::sim::RunResult;
use crate::util::json::Json;
use crate::util::pool;

/// One shard's identity: its index plus the derived world parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub index: u32,
    /// Derived scenario seed (base seed + index × seed stride).
    pub seed: u64,
    /// Harvester phase offset (index × phase jitter).
    pub phase_us: u64,
}

/// A recipe for building the shards of one fleet. The factory owns the
/// derivation rule (seeds, phase offsets, per-shard overrides); the
/// [`Fleet`] owns scheduling and fan-in.
pub trait ShardFactory: Sync {
    /// Number of shards (>= 1).
    fn shard_count(&self) -> u32;

    /// Identity of shard `index`.
    fn shard(&self, index: u32) -> Result<Shard>;

    /// Build shard `index`'s engine (called on the worker thread that
    /// runs it).
    fn build_shard_engine(&self, index: u32) -> Result<Engine>;

    /// Run shard `index` to its horizon.
    fn run_shard(&self, index: u32) -> Result<RunResult> {
        self.build_shard_engine(index)?.run()
    }
}

/// Mean/min/max/total of one metric across a fleet's shards.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rollup {
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub total: f64,
}

impl Rollup {
    /// Roll up a metric over shard values (zeros for an empty fleet).
    pub fn of(xs: impl IntoIterator<Item = f64>) -> Rollup {
        let mut n = 0usize;
        let (mut min, mut max, mut total) = (f64::INFINITY, f64::NEG_INFINITY, 0.0);
        for x in xs {
            n += 1;
            min = min.min(x);
            max = max.max(x);
            total += x;
        }
        if n == 0 {
            return Rollup {
                mean: 0.0,
                min: 0.0,
                max: 0.0,
                total: 0.0,
            };
        }
        Rollup {
            mean: total / n as f64,
            min,
            max,
            total,
        }
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("mean", Json::Num(self.mean)),
            ("min", Json::Num(self.min)),
            ("max", Json::Num(self.max)),
            ("total", Json::Num(self.total)),
        ])
    }
}

/// The fan-in aggregate over a fleet's shards.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetRollup {
    pub shards: usize,
    /// Final probe accuracy per shard.
    pub final_accuracy: Rollup,
    /// Mean probe accuracy per shard (3 warmup checkpoints skipped).
    pub mean_accuracy: Rollup,
    /// Total energy spent per shard, µJ.
    pub energy_uj: Rollup,
    pub learned: Rollup,
    pub inferred: Rollup,
    pub power_failures: Rollup,
    pub stale_plans: Rollup,
}

impl FleetRollup {
    pub fn of(shards: &[RunResult]) -> FleetRollup {
        let roll = |f: &dyn Fn(&RunResult) -> f64| Rollup::of(shards.iter().map(f));
        FleetRollup {
            shards: shards.len(),
            final_accuracy: roll(&|r| r.final_accuracy()),
            mean_accuracy: roll(&|r| r.mean_accuracy(3)),
            energy_uj: roll(&|r| r.energy_uj),
            learned: roll(&|r| r.learned as f64),
            inferred: roll(&|r| r.inferred as f64),
            power_failures: roll(&|r| r.power_failures as f64),
            stale_plans: roll(&|r| r.stale_plans as f64),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards as f64)),
            ("final_accuracy", self.final_accuracy.to_json()),
            ("mean_accuracy", self.mean_accuracy.to_json()),
            ("energy_uj", self.energy_uj.to_json()),
            ("learned", self.learned.to_json()),
            ("inferred", self.inferred.to_json()),
            ("power_failures", self.power_failures.to_json()),
            ("stale_plans", self.stale_plans.to_json()),
        ])
    }
}

/// Everything a fleet run produces: the per-shard results (in shard
/// order) plus the fan-in rollups.
#[derive(Debug, Clone)]
pub struct FleetResult {
    pub shards: Vec<RunResult>,
    pub rollup: FleetRollup,
}

impl FleetResult {
    /// Fan shard results (in shard order) into the aggregate.
    pub fn aggregate(shards: Vec<RunResult>) -> FleetResult {
        let rollup = FleetRollup::of(&shards);
        FleetResult { shards, rollup }
    }

    /// Shard 0's result — for a 1-shard fleet, exactly the single-device
    /// [`RunResult`].
    pub fn primary(&self) -> &RunResult {
        &self.shards[0]
    }

    /// Full JSON rendering: rollups plus every shard's run document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards.len() as f64)),
            ("rollup", self.rollup.to_json()),
            (
                "per_shard",
                Json::Arr(self.shards.iter().map(RunResult::to_json).collect()),
            ),
        ])
    }
}

/// The fleet coordinator: shard identities up front, engines built and
/// run on the worker pool, results fanned in deterministically.
pub struct Fleet<'a, F: ShardFactory + ?Sized> {
    factory: &'a F,
    shards: Vec<Shard>,
}

impl<'a, F: ShardFactory + ?Sized> Fleet<'a, F> {
    /// Derive every shard's identity from the factory.
    pub fn new(factory: &'a F) -> Result<Self> {
        let n = factory.shard_count();
        if n == 0 {
            return Err(Error::Config("fleet: shard count must be >= 1".into()));
        }
        let shards = (0..n).map(|i| factory.shard(i)).collect::<Result<_>>()?;
        Ok(Fleet { factory, shards })
    }

    /// The shard identities, in shard order.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Run every shard (`threads` = 0 uses the available parallelism) and
    /// fan the results in. Deterministic in shard order for any thread
    /// count; the first failing shard fails the fleet.
    pub fn run(&self, threads: usize) -> Result<FleetResult> {
        let results = pool::run_indexed(self.shards.len(), threads, |i| {
            self.factory.run_shard(self.shards[i].index)
        });
        let shards: Result<Vec<RunResult>> = results.into_iter().collect();
        Ok(FleetResult::aggregate(shards?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::energy::cost::CostModel;
    use crate::energy::harvester::{Constant, Harvester, PhaseShift};
    use crate::energy::Capacitor;
    use crate::learning::KnnAnomalyLearner;
    use crate::sensors::accel::{Accel, MotionProfile};
    use crate::sim::SimConfig;

    /// Minimal factory: constant-power worlds, seeds striding by 10.
    struct ConstFleet {
        n: u32,
    }

    impl ShardFactory for ConstFleet {
        fn shard_count(&self) -> u32 {
            self.n
        }
        fn shard(&self, index: u32) -> Result<Shard> {
            Ok(Shard {
                index,
                seed: 1 + u64::from(index) * 10,
                phase_us: u64::from(index) * 1_000_000,
            })
        }
        fn build_shard_engine(&self, index: u32) -> Result<Engine> {
            let sh = self.shard(index)?;
            let profile = MotionProfile::alternating_hours(1.0, 3.0, 2);
            let h: Box<dyn Harvester> = if sh.phase_us > 0 {
                Box::new(PhaseShift::new(Box::new(Constant(0.010)), sh.phase_us))
            } else {
                Box::new(Constant(0.010))
            };
            Engine::builder()
                .sim(SimConfig {
                    seed: sh.seed,
                    horizon_us: 900_000_000,
                    eval_period_us: 300_000_000,
                    probe_count: 10,
                    charge_step_us: 10_000_000,
                    probe_lookback_us: 3_600_000_000,
                    ..Default::default()
                })
                .harvester(h)
                .capacitor(Capacitor::vibration())
                .sensor(Box::new(Accel::new(profile, sh.seed)))
                .learner(Box::new(KnnAnomalyLearner::new()))
                .backend(Box::new(NativeBackend::new()))
                .costs(CostModel::kmeans())
                .build()
        }
    }

    fn fingerprint(f: &FleetResult) -> String {
        f.to_json().to_string()
    }

    #[test]
    fn rollup_math_is_exact() {
        let r = Rollup::of([1.0, 2.0, 3.0]);
        assert_eq!(
            r,
            Rollup {
                mean: 2.0,
                min: 1.0,
                max: 3.0,
                total: 6.0
            }
        );
        let z = Rollup::of(std::iter::empty::<f64>());
        assert_eq!(z.mean, 0.0);
        assert_eq!(z.total, 0.0);
    }

    #[test]
    fn fleet_results_are_deterministic_across_thread_counts() {
        let factory = ConstFleet { n: 4 };
        let fleet = Fleet::new(&factory).unwrap();
        assert_eq!(fleet.shards().len(), 4);
        assert_eq!(fleet.shards()[2].seed, 21);
        let serial = fleet.run(1).unwrap();
        let two = fleet.run(2).unwrap();
        let all = fleet.run(0).unwrap();
        assert_eq!(fingerprint(&serial), fingerprint(&two));
        assert_eq!(fingerprint(&serial), fingerprint(&all));
        assert!(serial.shards.iter().any(|r| r.sensed > 0), "dead fleet");
    }

    #[test]
    fn rollups_fan_in_every_shard() {
        let factory = ConstFleet { n: 3 };
        let fr = Fleet::new(&factory).unwrap().run(0).unwrap();
        assert_eq!(fr.rollup.shards, 3);
        let total: u64 = fr.shards.iter().map(|r| r.learned).sum();
        assert_eq!(fr.rollup.learned.total, total as f64);
        assert!(fr.rollup.energy_uj.min <= fr.rollup.energy_uj.mean);
        assert!(fr.rollup.energy_uj.mean <= fr.rollup.energy_uj.max);
        // distinct seeds actually diversified the shards
        let fp: Vec<String> = fr.shards.iter().map(|r| r.to_json().to_string()).collect();
        assert!(fp.iter().any(|f| f != &fp[0]), "shards identical");
        // JSON rendering carries rollup + per-shard docs
        let doc = fr.to_json().to_string();
        assert!(doc.contains("\"rollup\"") && doc.contains("\"per_shard\""));
    }

    #[test]
    fn one_shard_fleet_is_the_plain_engine_run() {
        let factory = ConstFleet { n: 1 };
        let fr = Fleet::new(&factory).unwrap().run(0).unwrap();
        let solo = factory.build_shard_engine(0).unwrap().run().unwrap();
        assert_eq!(
            fr.primary().to_json().to_string(),
            solo.to_json().to_string()
        );
    }

    #[test]
    fn zero_shards_is_a_config_error() {
        let factory = ConstFleet { n: 0 };
        assert!(Fleet::new(&factory).is_err());
    }
}
