//! The intermittent execution engine (the L3 coordinator core), split into
//! three layers (see `ARCHITECTURE.md`):
//!
//! * [`world::World`] — the physical device: harvester + capacitor +
//!   sensor + the simulated clock, including the charge kernels (the
//!   event-driven analytic kernel and the stepped reference oracle).
//! * [`executor::Executor`] — the sub-action transaction machinery: runs
//!   one action against the NVM staging buffer, deducting energy per
//!   sub-action and rolling back on power failure (§3.4/§3.5).
//! * [`policy::Policy`] — the decision layer: scheduler (dynamic action
//!   planner or a duty-cycled baseline) + example-selection heuristic +
//!   the windowed completion bookkeeping the planner's goal logic reads.
//!
//! [`engine::Engine`] is the thin coordinator that owns one of each plus
//! the learner/backend/meter, and advances simulated time through
//! charge → wake → execute-actions → power-fail/sleep cycles, recording
//! everything the evaluation section needs.
//!
//! [`fleet::Fleet`] generalizes one scenario from a single device to `N`
//! shards — one World/Executor/Policy stack per shard with fan-in
//! aggregation ([`fleet::FleetResult`]); the plain `Engine` run is its
//! 1-shard special case, and synced fleets advance on [`sched`]'s global
//! event heap — per-shard rendezvous instead of fleet-wide round
//! barriers. [`state::RunState`] persists a run's aggregates
//! through NVM so interrupted runs restore bit-identically.

pub mod engine;
pub mod executor;
pub mod fleet;
pub mod policy;
pub mod probe;
pub mod sched;
pub mod soa;
pub mod state;
pub mod world;

pub use executor::{Exec, Executor};
pub use fleet::{
    Fleet, FleetResult, FleetRollup, FleetSched, Rollup, Shard, ShardFactory, SyncPlan,
    SyncStrategy,
};
pub use policy::Policy;
pub use sched::planned_wakes;
pub use soa::{run_streaming, FleetSketches, StreamResult};
pub use state::RunState;
pub use world::World;

use crate::actions::Action;
use crate::energy::cost::{ActionCost, CostModel};
use crate::learning::Example;
use crate::planner::{DynamicActionPlanner, PlanContext, Planned, Pending};
use crate::sensors::Window;
use crate::util::json::Json;

/// An action scheduler: given the in-flight examples and the goal context,
/// pick the next transition. Implemented by the dynamic action planner and
/// by the Alpaca/Mayfly-style fixed duty-cycle baselines.
pub trait Scheduler: Send {
    /// Choose the next transition.
    fn next(&mut self, pending: &Pending, ctx: &PlanContext, costs: &CostModel) -> Planned;

    /// Feedback: outcome of a `select` gate.
    fn observe_select(&mut self, _accepted: bool) {}

    /// Feedback: a learn/infer completed.
    fn observe_completion(&mut self, _a: Action) {}

    /// Called once per harvesting cycle (wake-up).
    fn on_cycle(&mut self) {}

    /// Per-decision overhead (the planner's 57 µJ / 4.3 ms; ~0 for the
    /// baselines' hardcoded schedules).
    fn overhead(&self, costs: &CostModel) -> ActionCost;

    /// Data-expiration interval (Mayfly); `None` = never expires.
    fn expiry_us(&self) -> Option<u64> {
        None
    }

    /// Does this scheduler use the select gate? (Baselines learn every
    /// example: the engine bypasses `select`/`learnable` for them.)
    fn uses_selection(&self) -> bool {
        true
    }

    /// Completion-rate window length in harvesting cycles, if the
    /// scheduler plans against one (the planner's goal window; `None` for
    /// the fixed-schedule baselines). [`Policy`] mirrors its completion
    /// counts over this window so [`PlanContext`] carries real rates.
    fn window_cycles(&self) -> Option<u32> {
        None
    }

    fn name(&self) -> &'static str;
}

/// The dynamic action planner as a scheduler.
pub struct PlannerScheduler(pub DynamicActionPlanner);

impl Scheduler for PlannerScheduler {
    fn next(&mut self, pending: &Pending, ctx: &PlanContext, costs: &CostModel) -> Planned {
        self.0.next_action(pending, ctx, costs)
    }

    fn observe_select(&mut self, accepted: bool) {
        self.0.observe_select(accepted);
    }

    // observe_completion / on_cycle: default no-ops — the windowed
    // completion bookkeeping lives in [`Policy`] and reaches the planner
    // through [`PlanContext`]; the planner keeps no mirror of it.

    fn overhead(&self, costs: &CostModel) -> ActionCost {
        costs.planner
    }

    fn window_cycles(&self) -> Option<u32> {
        Some(self.0.goal.window)
    }

    fn name(&self) -> &'static str {
        "intermittent_learning"
    }
}

/// An in-flight example and its execution status (§4.1's (x, a) tuple).
#[derive(Debug, Clone)]
pub struct PendingEx {
    /// Last action completed on this example.
    pub last: Action,
    /// Raw window (present after `sense`).
    pub window: Option<Window>,
    /// Extracted features (present after `extract`).
    pub example: Option<Example>,
    /// Completed sub-actions of the currently executing action (survives
    /// power failures — the point of action splitting, §3.4).
    pub sub_done: u32,
    /// Time the example was sensed (Mayfly expiration).
    pub sensed_at_us: u64,
}

impl PendingEx {
    pub fn new(last: Action, t_us: u64) -> Self {
        PendingEx {
            last,
            window: None,
            example: None,
            sub_done: 0,
            sensed_at_us: t_us,
        }
    }
}

/// Which charging integrator advances the world while asleep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChargeKernel {
    /// Event-driven analytic kernel: jumps across harvester segments
    /// (whole nights, idle motion gaps) using closed-form mean power and
    /// solves the wake instant inside a segment (the default).
    Event,
    /// Fixed-step reference oracle: integrates in `charge_step_us` steps,
    /// re-sampling instantaneous power each step (the pre-event-kernel
    /// integrator, kept for equivalence testing and as a fallback).
    Stepped,
}

impl ChargeKernel {
    pub fn name(self) -> &'static str {
        match self {
            ChargeKernel::Event => "event",
            ChargeKernel::Stepped => "stepped",
        }
    }

    pub fn parse(s: &str) -> Option<ChargeKernel> {
        match s {
            "event" => Some(ChargeKernel::Event),
            "stepped" => Some(ChargeKernel::Stepped),
            _ => None,
        }
    }
}

impl Default for ChargeKernel {
    /// Event-driven, unless the crate is built with the `stepped-kernel`
    /// cfg feature (the reference-oracle escape hatch).
    fn default() -> Self {
        if cfg!(feature = "stepped-kernel") {
            ChargeKernel::Stepped
        } else {
            ChargeKernel::Event
        }
    }
}

/// Simulation parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub seed: u64,
    /// Simulated horizon, µs.
    pub horizon_us: u64,
    /// Accuracy-probe checkpoint period, µs.
    pub eval_period_us: u64,
    /// Probe-set size (balanced across classes where possible).
    pub probe_count: usize,
    /// Max charging step while asleep, µs (power re-sampling interval of
    /// the stepped kernel).
    pub charge_step_us: u64,
    /// Probe lookback: checkpoint accuracy is measured on probes drawn
    /// from `[t - lookback, t]` — the *current* environment, as in the
    /// paper's hourly test-case protocol.
    pub probe_lookback_us: u64,
    /// Charging integrator (event-driven by default).
    pub charge_kernel: ChargeKernel,
    /// Forecast-aware planning (the `"policy": {"forecast": true}` spec
    /// knob): surface the harvester's energy forecast in `PlanContext`,
    /// elide checkpoints the forecast proves unnecessary, and hold a
    /// radio reserve ahead of a known sync rendezvous. Off by default;
    /// when off the engine is bit-identical to the pre-forecast policy.
    pub forecast: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            seed: 42,
            horizon_us: 4 * 3_600_000_000,
            eval_period_us: 600_000_000,
            probe_count: 30,
            charge_step_us: 60_000_000,
            probe_lookback_us: 2 * 3_600_000_000,
            charge_kernel: ChargeKernel::default(),
            forecast: false,
        }
    }
}

/// Drop pending examples whose *unprocessed* sensed data outlived
/// `expiry_us` (Mayfly-style expiration: stale *sensor data* is discarded
/// — examples already past `sense` carry processed state and are kept).
/// Returns how many were dropped.
pub fn expire_stale(pending: &mut Vec<PendingEx>, expiry_us: u64, now_us: u64) -> u64 {
    let before = pending.len();
    pending.retain(|p| {
        p.last != Action::Sense || p.sensed_at_us.saturating_add(expiry_us) > now_us
    });
    (before - pending.len()) as u64
}

/// One accuracy checkpoint.
#[derive(Debug, Clone, Copy)]
pub struct Checkpoint {
    pub t_us: u64,
    /// Probe accuracy in [0, 1] (Unknown verdicts count as wrong).
    pub accuracy: f64,
    /// Examples learned by this time.
    pub learned: u64,
    /// Inferences performed by this time.
    pub inferred: u64,
    /// Cumulative energy, µJ.
    pub energy_uj: f64,
    /// Capacitor voltage at the checkpoint.
    pub voltage: f64,
}

/// Everything a run produces.
#[derive(Debug, Clone, Default)]
pub struct RunResult {
    pub scheduler: String,
    pub checkpoints: Vec<Checkpoint>,
    pub learned: u64,
    pub inferred: u64,
    /// Examples discarded by the select gate.
    pub discarded_select: u64,
    /// Examples dropped by Mayfly-style expiration.
    pub expired: u64,
    /// Wake cycles experienced.
    pub cycles: u64,
    /// Mid-action power failures (rolled back).
    pub power_failures: u64,
    /// Scheduler decisions that referenced a no-longer-existing pending
    /// slot (stale plans; the engine breaks the burst after repeats so a
    /// buggy scheduler cannot spin without consuming energy or time).
    pub stale_plans: u64,
    /// Fleet sync exchanges this shard paid for and performed: radio
    /// Tx + listen window charged, snapshot broadcast and peers merged.
    /// Only rendezvous with ≥ 2 participants count — the round
    /// coordinator knows who showed up before anyone keys the radio, so
    /// a lone participant commits nothing (see [`RunResult::syncs_solo`]).
    /// 0 for sync-less runs.
    pub syncs_done: u64,
    /// Fleet sync rounds this shard skipped because its capacitor could
    /// not cover the radio price — the paper's learn-or-discard energy
    /// gating lifted to the fleet tier.
    pub syncs_skipped: u64,
    /// Fleet sync rounds where this shard was the only participant with
    /// energy to spare: the exchange is skipped (broadcasting to nobody
    /// and listening to silence buys nothing) and no radio energy is
    /// spent. Fixes the PR-5 lone-participant tax.
    pub syncs_solo: u64,
    /// Checkpoint persists actually written in forecast mode (the
    /// elision decision points that persisted). Forecast-off runs never
    /// reach a decision point, so both this and
    /// [`RunResult::checkpoints_elided`] stay 0 and the JSON keeps its
    /// pre-forecast shape.
    pub checkpoints_taken: u64,
    /// Checkpoint persists the forecast proved unnecessary and skipped:
    /// either stored + predicted harvest covers the next persist window
    /// with margin, or nothing at risk was added since the last persist.
    pub checkpoints_elided: u64,
    /// Learn-path work (a `SenseNew` or a `Learn` advance) the sync
    /// energy reserve deferred ahead of a known rendezvous boundary —
    /// learns the shard would have burned and then skipped the sync for.
    pub learns_deferred: u64,
    /// NVM bytes written by checkpoint persists (learner delta saves and
    /// run-state saves). Tracked in every mode; reported in JSON only
    /// alongside the forecast counters (it is the elision savings
    /// denominator).
    pub ckpt_nvm_bytes: u64,
    /// Total energy spent, µJ.
    pub energy_uj: f64,
    /// Energy time series (t_us, cumulative µJ).
    pub energy_series: Vec<(u64, f64)>,
    /// Per-action tallies snapshot (name, count, energy_uj, time_us).
    pub action_tallies: Vec<(String, u64, f64, u64)>,
    /// Per-inference log (t_us, predicted_abnormal, truth_abnormal) —
    /// on-line inferences (not probes).
    pub infer_log: Vec<(u64, bool, bool)>,
    /// Examples that entered the system (sense completions).
    pub sensed: u64,
}

impl RunResult {
    /// Final probe accuracy (last checkpoint), or 0 if none.
    pub fn final_accuracy(&self) -> f64 {
        self.checkpoints.last().map(|c| c.accuracy).unwrap_or(0.0)
    }

    /// Mean probe accuracy over all checkpoints after `skip` warmup ones.
    pub fn mean_accuracy(&self, skip: usize) -> f64 {
        let cps = &self.checkpoints[skip.min(self.checkpoints.len())..];
        if cps.is_empty() {
            return 0.0;
        }
        cps.iter().map(|c| c.accuracy).sum::<f64>() / cps.len() as f64
    }

    /// On-line inference accuracy (from `infer_log`).
    pub fn online_accuracy(&self) -> f64 {
        if self.infer_log.is_empty() {
            return 0.0;
        }
        let ok = self
            .infer_log
            .iter()
            .filter(|&&(_, p, t)| p == t)
            .count();
        ok as f64 / self.infer_log.len() as f64
    }

    /// JSON rendering of the run (sweep-cell output format). Covers the
    /// counters, accuracy summaries, checkpoints and per-action tallies
    /// (the per-inference log is summarized, not dumped). The sync
    /// counters appear only when the run actually hit sync boundaries, so
    /// sync-less documents keep the pre-sync (PR-4) shape byte for byte.
    pub fn to_json(&self) -> Json {
        let mut kvs = vec![
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("cycles", Json::Num(self.cycles as f64)),
            ("sensed", Json::Num(self.sensed as f64)),
            ("learned", Json::Num(self.learned as f64)),
            ("inferred", Json::Num(self.inferred as f64)),
            ("discarded_select", Json::Num(self.discarded_select as f64)),
            ("expired", Json::Num(self.expired as f64)),
            ("power_failures", Json::Num(self.power_failures as f64)),
            ("stale_plans", Json::Num(self.stale_plans as f64)),
        ];
        if self.syncs_done + self.syncs_skipped + self.syncs_solo > 0 {
            kvs.push(("syncs_done", Json::Num(self.syncs_done as f64)));
            kvs.push(("syncs_skipped", Json::Num(self.syncs_skipped as f64)));
            kvs.push(("syncs_solo", Json::Num(self.syncs_solo as f64)));
        }
        // forecast-mode counters: only forecast runs reach an elision
        // decision point, so default documents keep the pre-forecast shape
        if self.checkpoints_taken + self.checkpoints_elided > 0 {
            kvs.push(("checkpoints_taken", Json::Num(self.checkpoints_taken as f64)));
            kvs.push(("checkpoints_elided", Json::Num(self.checkpoints_elided as f64)));
            kvs.push(("learns_deferred", Json::Num(self.learns_deferred as f64)));
            kvs.push(("ckpt_nvm_bytes", Json::Num(self.ckpt_nvm_bytes as f64)));
        }
        kvs.extend([
            ("energy_uj", Json::Num(self.energy_uj)),
            ("mean_accuracy", Json::Num(self.mean_accuracy(3))),
            ("final_accuracy", Json::Num(self.final_accuracy())),
            ("online_accuracy", Json::Num(self.online_accuracy())),
            (
                "checkpoints",
                Json::Arr(
                    self.checkpoints
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("t_us", Json::Num(c.t_us as f64)),
                                ("accuracy", Json::Num(c.accuracy)),
                                ("learned", Json::Num(c.learned as f64)),
                                ("inferred", Json::Num(c.inferred as f64)),
                                ("energy_uj", Json::Num(c.energy_uj)),
                                ("voltage", Json::Num(c.voltage)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "action_tallies",
                Json::Arr(
                    self.action_tallies
                        .iter()
                        .map(|(name, count, e_uj, t_us)| {
                            Json::obj(vec![
                                ("action", Json::Str(name.clone())),
                                ("count", Json::Num(*count as f64)),
                                ("energy_uj", Json::Num(*e_uj)),
                                ("time_us", Json::Num(*t_us as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        Json::obj(kvs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pend(last: Action, sensed_at_us: u64) -> PendingEx {
        PendingEx::new(last, sensed_at_us)
    }

    #[test]
    fn expire_stale_drops_only_unprocessed_stale_data() {
        let now = 10_000_000;
        let exp = 5_000_000;
        let mut pending = vec![
            pend(Action::Sense, 1_000_000),   // sensed-stale: dropped
            pend(Action::Sense, 9_000_000),   // sensed-fresh: kept
            pend(Action::Extract, 1_000_000), // post-extract, stale age: kept
            pend(Action::Select, 0),          // deep in the pipeline: kept
        ];
        let dropped = expire_stale(&mut pending, exp, now);
        assert_eq!(dropped, 1);
        assert_eq!(pending.len(), 3);
        assert!(pending.iter().all(|p| p.last != Action::Sense || p.sensed_at_us == 9_000_000));
        // boundary: age == expiry is stale (strict `>` survival)
        let mut edge = vec![pend(Action::Sense, now - exp)];
        assert_eq!(expire_stale(&mut edge, exp, now), 1);
        // huge expiry never drops (saturating add)
        let mut never = vec![pend(Action::Sense, 0)];
        assert_eq!(expire_stale(&mut never, u64::MAX, now), 0);
    }

    #[test]
    fn charge_kernel_names_round_trip() {
        for k in [ChargeKernel::Event, ChargeKernel::Stepped] {
            assert_eq!(ChargeKernel::parse(k.name()), Some(k));
        }
        assert_eq!(ChargeKernel::parse("nope"), None);
    }
}
