//! The sub-action transaction machinery (§3.4 action splitting, §3.5
//! atomicity), lifted out of the engine so alternative executors (e.g.
//! per-shard or speculative ones) can be swapped in behind the same
//! seam.
//!
//! One [`Executor`] owns the NVM store and runs a single action to
//! completion sub-action by sub-action: each sub-action opens an NVM
//! transaction, deducts its energy share from the capacitor, advances the
//! clock, and commits. A mid-sub-action power failure aborts the open
//! transaction (the §3.5 rollback) but keeps the completed sub-action
//! count — that persistence is the whole point of action splitting.

use crate::actions::Action;
use crate::energy::cost::ActionCost;
use crate::energy::EnergyMeter;
use crate::error::{Error, Result};
use crate::nvm::Nvm;
use crate::sim::world::World;
use crate::sim::PendingEx;

/// Outcome of attempting one action.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Exec {
    /// All sub-actions committed; the payload may be applied.
    Done,
    /// Power failed mid-sub-action: open transaction rolled back,
    /// completed sub-actions preserved on the example.
    PowerFailed,
}

/// Transactional action executor over an NVM store.
#[derive(Debug, Default)]
pub struct Executor {
    pub nvm: Nvm,
}

impl Executor {
    pub fn new() -> Self {
        Executor { nvm: Nvm::new() }
    }

    /// Execute `action` on `ex` at the given cost, sub-action by
    /// sub-action, against `world`'s capacitor and clock. Payload effects
    /// belong to the caller and must only be applied on [`Exec::Done`].
    pub fn run_action(
        &mut self,
        world: &mut World,
        meter: &mut EnergyMeter,
        action: Action,
        cost: ActionCost,
        ex: &mut PendingEx,
    ) -> Result<Exec> {
        let sub_e = cost.sub_energy_uj();
        let sub_t = cost.sub_time_us();
        if sub_e > world.cap.full_budget_uj() {
            return Err(Error::EnergyBudget {
                action: action.name().into(),
                needed_uj: sub_e,
                budget_uj: world.cap.full_budget_uj(),
            });
        }
        while ex.sub_done < cost.splits {
            self.nvm.begin_action()?;
            if !world.cap.deduct_uj(sub_e) {
                // power failure mid-sub-action: roll back
                self.nvm.abort_action();
                meter.record_abort(action, world.cap.usable_uj().max(0.0));
                return Ok(Exec::PowerFailed);
            }
            world.advance_us(sub_t);
            ex.sub_done += 1;
            self.nvm.commit_action()?;
            meter.record_action(action, sub_e, sub_t);
        }
        Ok(Exec::Done)
    }

    /// Persist a model checkpoint through one atomic NVM transaction and
    /// return the bytes it wrote. This is the persistence seam the engine
    /// brackets learner delta saves and sync merges through — and the
    /// point forecast-aware checkpoint elision bypasses: an elided
    /// checkpoint simply never opens the transaction, so every persist
    /// that *does* happen stays a whole atomic commit and crash recovery
    /// still lands on an exact commit boundary (the `fault::sweep`
    /// invariant).
    pub fn persist_model(
        &mut self,
        save: impl FnOnce(&mut Nvm) -> Result<()>,
    ) -> Result<u64> {
        let before = self.nvm.bytes_written;
        self.nvm.begin_action()?;
        if let Err(err) = save(&mut self.nvm) {
            self.nvm.abort_action();
            return Err(err);
        }
        self.nvm.commit_action()?;
        Ok(self.nvm.bytes_written - before)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::harvester::Constant;
    use crate::energy::Capacitor;
    use crate::sensors::accel::{Accel, MotionProfile};

    fn world_at(v: f64) -> World {
        let sensor = Accel::new(MotionProfile::alternating_hours(1.0, 3.0, 2), 1);
        let mut w = World::new(
            Box::new(Constant(0.0)),
            Capacitor::vibration(),
            Box::new(sensor),
        );
        w.cap.set_voltage(v);
        w
    }

    #[test]
    fn completed_action_commits_every_sub_action() {
        let mut exec = Executor::new();
        let mut meter = EnergyMeter::new();
        let mut world = world_at(3.3);
        let mut ex = PendingEx::new(Action::Sense, 0);
        let cost = ActionCost::new(900.0, 9_000, 3);
        let r = exec
            .run_action(&mut world, &mut meter, Action::Extract, cost, &mut ex)
            .unwrap();
        assert_eq!(r, Exec::Done);
        assert_eq!(ex.sub_done, 3);
        assert_eq!(exec.nvm.commits, 3);
        assert_eq!(exec.nvm.aborts, 0);
        assert_eq!(world.now_us(), 9_000);
        assert!((meter.total_uj() - 900.0).abs() < 1e-9);
    }

    #[test]
    fn power_failure_rolls_back_but_keeps_sub_action_progress() {
        let mut exec = Executor::new();
        let mut meter = EnergyMeter::new();
        // barely above brown-out: only one 300 µJ sub-action fits
        let mut world = world_at(2.03);
        let mut ex = PendingEx::new(Action::Sense, 0);
        let cost = ActionCost::new(900.0, 9_000, 3);
        let r = exec
            .run_action(&mut world, &mut meter, Action::Extract, cost, &mut ex)
            .unwrap();
        assert_eq!(r, Exec::PowerFailed);
        assert!(ex.sub_done >= 1, "no sub-action survived: {}", ex.sub_done);
        assert!(ex.sub_done < 3);
        assert_eq!(exec.nvm.aborts, 1);
        assert_eq!(exec.nvm.commits, u64::from(ex.sub_done));
        assert!(!world.cap.alive());
        // resuming on a recharged capacitor finishes the remaining splits
        world.cap.set_voltage(3.3);
        let r = exec
            .run_action(&mut world, &mut meter, Action::Extract, cost, &mut ex)
            .unwrap();
        assert_eq!(r, Exec::Done);
        assert_eq!(ex.sub_done, 3);
    }

    #[test]
    fn persist_model_brackets_one_atomic_commit() {
        let mut exec = Executor::new();
        let bytes = exec
            .persist_model(|nvm| {
                nvm.write("model/a", &[1, 2, 3])?;
                nvm.write_u64("model/n", 7)
            })
            .unwrap();
        assert_eq!(exec.nvm.commits, 1);
        assert_eq!(exec.nvm.aborts, 0);
        assert!(bytes >= 3 + 8, "bytes written not accounted: {bytes}");
        assert_eq!(exec.nvm.read("model/a").unwrap(), vec![1, 2, 3]);
        // a failing save aborts the open transaction and stages nothing
        let err = exec.persist_model(|nvm| {
            nvm.write("model/a", &[9])?;
            Err(Error::Config("save failed".into()))
        });
        assert!(err.is_err());
        assert_eq!(exec.nvm.aborts, 1);
        assert_eq!(exec.nvm.read("model/a").unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn oversized_sub_action_is_a_budget_error() {
        let mut exec = Executor::new();
        let mut meter = EnergyMeter::new();
        let mut world = world_at(3.3);
        let mut ex = PendingEx::new(Action::Sense, 0);
        let budget = world.cap.full_budget_uj();
        let cost = ActionCost::new(budget * 2.0, 1_000, 1);
        let err = exec
            .run_action(&mut world, &mut meter, Action::Learn, cost, &mut ex)
            .unwrap_err();
        assert!(matches!(err, Error::EnergyBudget { .. }), "{err:?}");
    }
}
