//! Probe-set construction for accuracy checkpoints.
//!
//! The paper evaluates its learners with held-out test cases ("accuracy is
//! tested every hour using 30 test cases of human presence and absence",
//! §6.2) labelled by ground truth. Probes are *external* to the device:
//! they cost no harvested energy. We precompute a balanced, deterministic
//! probe set over the sim horizon by scanning the sensor's ground truth.

use crate::backend::shapes::{CHANNELS, WINDOW};
use crate::backend::ComputeBackend;
use crate::error::Result;
use crate::learning::{Example, Learner, Verdict};
use crate::sensors::Sensor;

/// A precomputed probe: extracted features + truth.
#[derive(Debug, Clone)]
pub struct Probe {
    pub example: Example,
}

/// First scan-grid instant (multiple of `step`) at or after `from_us`.
fn grid_start(from_us: u64, step: u64) -> u64 {
    let rem = from_us % step;
    if rem == 0 {
        from_us
    } else {
        (from_us - rem).saturating_add(step)
    }
}

/// Build a balanced probe set of up to `count` probes by scanning
/// `[0, horizon)` at `scan_step_us` and extracting windows through the
/// same backend the learner uses.
pub fn build_probes(
    sensor: &dyn Sensor,
    be: &mut dyn ComputeBackend,
    horizon_us: u64,
    count: usize,
    scan_step_us: u64,
) -> Result<Vec<Probe>> {
    build_probes_range(sensor, be, 0, horizon_us, count, scan_step_us)
}

/// Build probes from the time range `[from_us, to_us)` — the paper tests
/// "every hour using 30 test cases" drawn from the *current* environment,
/// so checkpoint accuracy must be measured against temporally local
/// conditions (after an area move, old-area probes are the wrong test).
pub fn build_probes_range(
    sensor: &dyn Sensor,
    be: &mut dyn ComputeBackend,
    from_us: u64,
    to_us: u64,
    count: usize,
    scan_step_us: u64,
) -> Result<Vec<Probe>> {
    let mut normal_times = Vec::new();
    let mut abnormal_times = Vec::new();
    // The scan grid is anchored to *absolute* time (multiples of the scan
    // step), not to the window start: two lookback windows that differ by
    // less than one step then scan identical instants, which is what lets
    // the ProbeCache treat them as the same probe set. Degenerate windows
    // narrower than one step keep their single window-start sample.
    let step = scan_step_us.max(1);
    let mut t = grid_start(from_us, step);
    if t >= to_us {
        t = from_us;
    }
    while t < to_us {
        // classify by mid-window truth to avoid boundary ambiguity
        let mid = t + (WINDOW as u64 / 2) * sensor.sample_period_us();
        if sensor.truth_at(mid) {
            abnormal_times.push(t);
        } else {
            normal_times.push(t);
        }
        t += step;
    }
    let half = count / 2;
    let pick = |times: &[u64], n: usize| -> Vec<u64> {
        if times.is_empty() || n == 0 {
            return vec![];
        }
        (0..n)
            .map(|i| times[i * times.len() / n.max(1)])
            .collect()
    };
    // If one class is missing, fill with the other (accuracy then measures
    // the false-positive rate only — same as the paper's normal-only hours).
    let mut chosen = pick(&abnormal_times, half.min(abnormal_times.len()));
    let rest = count - chosen.len();
    chosen.extend(pick(&normal_times, rest.min(normal_times.len())));

    let mut probes = Vec::with_capacity(chosen.len());
    for t0 in chosen {
        let win = sensor.window(t0, WINDOW).fit(WINDOW, CHANNELS);
        let feats = be.extract(&win.data)?;
        probes.push(Probe {
            example: Example::new(feats, t0, win.truth_abnormal),
        });
    }
    Ok(probes)
}

/// Cache of the last-built probe set, keyed by the lookback window's
/// position on the absolute scan grid.
///
/// Checkpoints re-scan the sensor's ground truth and re-extract up to
/// `count` windows every time. The probe grid is anchored to absolute
/// time (see [`build_probes_range`]), so a window that advanced by less
/// than one scan step shares all interior grid instants with the previous
/// one and the cached set is reused — not just the exact-window repeats
/// (the back-to-back final checkpoint at the horizon) the pre-anchored
/// cache caught. The reuse is deliberately approximate at the *edges*:
/// the served set may keep the grid instant just before the advanced
/// window's start and lack one newly entered instant — at most one
/// boundary probe out of `count`, bounded by one scan step in time.
/// Degenerate windows narrower than one scan step fall back to their
/// window-start sample, so those are cached by exact window instead of
/// grid bucket (two distinct sub-step windows never alias).
#[derive(Debug, Default)]
pub struct ProbeCache {
    key: Option<(u64, u64, usize, u64, bool)>,
    probes: Vec<Probe>,
    /// Served from cache (window unchanged on the scan grid).
    pub hits: u64,
    /// Rebuilt from the sensor.
    pub builds: u64,
}

impl ProbeCache {
    pub fn new() -> Self {
        ProbeCache::default()
    }

    /// Build (or reuse) the probe set for `[from_us, to_us)`.
    pub fn probes_for(
        &mut self,
        sensor: &dyn Sensor,
        be: &mut dyn ComputeBackend,
        from_us: u64,
        to_us: u64,
        count: usize,
        scan_step_us: u64,
    ) -> Result<&[Probe]> {
        let step = scan_step_us.max(1);
        // grid-holding windows key by scan-step bucket; degenerate ones
        // (no grid instant inside) key by the exact window, with a
        // discriminant so the two key spaces cannot collide
        let key = if grid_start(from_us, step) < to_us {
            (from_us / step, to_us / step, count, step, true)
        } else {
            (from_us, to_us, count, step, false)
        };
        if self.key != Some(key) {
            self.probes = build_probes_range(sensor, be, from_us, to_us, count, scan_step_us)?;
            self.key = Some(key);
            self.builds += 1;
        } else {
            self.hits += 1;
        }
        Ok(&self.probes)
    }
}

/// Probe accuracy of a learner: fraction of probes classified correctly
/// (Unknown counts as wrong — an undecided learner is not yet useful).
///
/// The probe set is a wake-event cohort: it is scored through
/// [`Learner::infer_batch`], one backend cohort call per checkpoint
/// instead of one dispatch per probe, with verdicts identical to the
/// per-probe loop by the `infer_batch` contract.
pub fn probe_accuracy(
    probes: &[Probe],
    learner: &mut dyn Learner,
    be: &mut dyn ComputeBackend,
) -> Result<f64> {
    if probes.is_empty() {
        return Ok(0.0);
    }
    let exs: Vec<&crate::learning::Example> = probes.iter().map(|p| &p.example).collect();
    let verdicts = learner.infer_batch(&exs, be)?;
    let mut ok = 0usize;
    for (p, v) in probes.iter().zip(verdicts) {
        let correct = match v {
            Verdict::Abnormal => p.example.truth_abnormal,
            Verdict::Normal => !p.example.truth_abnormal,
            Verdict::Unknown => false,
        };
        ok += correct as usize;
    }
    Ok(ok as f64 / probes.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::sensors::accel::{Accel, MotionProfile};

    #[test]
    fn probes_are_balanced_when_both_classes_exist() {
        let sensor = Accel::new(MotionProfile::alternating_hours(1.0, 3.0, 4), 1);
        let mut be = NativeBackend::new();
        // gestures are 5 s long every ~36 s: scan fine enough to hit them
        let probes = build_probes(&sensor, &mut be, 4 * 3_600_000_000, 30, 15_000_000)
            .unwrap();
        assert_eq!(probes.len(), 30);
        let abn = probes.iter().filter(|p| p.example.truth_abnormal).count();
        assert!((13..=17).contains(&abn), "abn {abn}");
    }

    #[test]
    fn probes_deterministic() {
        let sensor = Accel::new(MotionProfile::alternating_hours(1.0, 3.0, 2), 2);
        let mut be = NativeBackend::new();
        let a = build_probes(&sensor, &mut be, 7_200_000_000, 10, 60_000_000).unwrap();
        let b = build_probes(&sensor, &mut be, 7_200_000_000, 10, 60_000_000).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.example.features, y.example.features);
        }
    }

    #[test]
    fn probe_cache_reuses_identical_windows_and_rebuilds_moved_ones() {
        let sensor = Accel::new(MotionProfile::alternating_hours(1.0, 3.0, 4), 2);
        let mut be = NativeBackend::new();
        let mut cache = ProbeCache::new();
        let fresh =
            build_probes_range(&sensor, &mut be, 0, 7_200_000_000, 10, 60_000_000).unwrap();
        let a: Vec<u64> = cache
            .probes_for(&sensor, &mut be, 0, 7_200_000_000, 10, 60_000_000)
            .unwrap()
            .iter()
            .map(|p| p.example.t_us)
            .collect();
        // cache serves exactly what a direct build produces
        assert_eq!(a, fresh.iter().map(|p| p.example.t_us).collect::<Vec<_>>());
        // same window again: served from cache (same contents)
        let b: Vec<u64> = cache
            .probes_for(&sensor, &mut be, 0, 7_200_000_000, 10, 60_000_000)
            .unwrap()
            .iter()
            .map(|p| p.example.t_us)
            .collect();
        assert_eq!(a, b);
        // advanced window: rebuilt, matching a direct build of that window
        let moved =
            build_probes_range(&sensor, &mut be, 3_600_000_000, 10_800_000_000, 10, 60_000_000)
                .unwrap();
        let c: Vec<u64> = cache
            .probes_for(&sensor, &mut be, 3_600_000_000, 10_800_000_000, 10, 60_000_000)
            .unwrap()
            .iter()
            .map(|p| p.example.t_us)
            .collect();
        assert_eq!(c, moved.iter().map(|p| p.example.t_us).collect::<Vec<_>>());
        assert_ne!(a, c);
    }

    #[test]
    fn sub_step_window_advances_hit_the_cache() {
        // regression: the probe grid is anchored to absolute time, so a
        // window that advanced by less than one scan step reuses the
        // cached set instead of rebuilding (the pre-anchor cache only
        // caught exact-window repeats)
        let sensor = Accel::new(MotionProfile::alternating_hours(1.0, 3.0, 4), 2);
        let mut be = NativeBackend::new();
        let mut cache = ProbeCache::new();
        let step = 60_000_000u64;
        let mut times = |c: &mut ProbeCache, f: u64, t: u64| -> Vec<u64> {
            c.probes_for(&sensor, &mut be, f, t, 10, step)
                .unwrap()
                .iter()
                .map(|p| p.example.t_us)
                .collect()
        };
        let a = times(&mut cache, 0, 7_200_000_000);
        assert_eq!((cache.builds, cache.hits), (1, 0));
        // advanced by half a step: same grid bucket, served from cache
        let b = times(&mut cache, 30_000_000, 7_230_000_000);
        assert_eq!((cache.builds, cache.hits), (1, 1), "sub-step advance missed");
        assert_eq!(a, b);
        // advanced by a whole step: new grid bucket, rebuilt
        let c = times(&mut cache, 60_000_000, 7_260_000_000);
        assert_eq!((cache.builds, cache.hits), (2, 1));
        assert_ne!(a, c);
        // probe times sit on the absolute grid regardless of window start
        let d = times(&mut cache, 90_000_000, 7_280_000_000);
        assert!(d.iter().all(|t| t % step == 0), "{d:?}");
        // degenerate windows (narrower than a step, no grid instant
        // inside) key by exact window: two distinct ones never alias even
        // though they share grid buckets
        let e = times(&mut cache, 70_000_000, 80_000_000);
        let f = times(&mut cache, 90_000_000, 100_000_000);
        assert_eq!(e, vec![70_000_000]);
        assert_eq!(f, vec![90_000_000]);
        assert_ne!(e, f);
    }

    #[test]
    fn untrained_learner_scores_zero() {
        let sensor = Accel::new(MotionProfile::alternating_hours(1.0, 3.0, 2), 3);
        let mut be = NativeBackend::new();
        let probes = build_probes(&sensor, &mut be, 7_200_000_000, 10, 60_000_000).unwrap();
        let mut learner = crate::learning::KnnAnomalyLearner::new();
        let acc = probe_accuracy(&probes, &mut learner, &mut be).unwrap();
        assert_eq!(acc, 0.0); // all Unknown
    }
}
