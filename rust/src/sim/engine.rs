//! The intermittent execution engine — a thin coordinator over the three
//! layers ([`World`] / [`Executor`] / [`Policy`], see `ARCHITECTURE.md`):
//!
//! ```text
//! loop {
//!   world: charge until V >= v_on            (event kernel; time jumps)
//!   while V > v_off {
//!     policy picks next transition           (planner overhead charged)
//!     executor runs it sub-action by sub-action (atomic; NVM commit each)
//!     on energy exhaustion: abort + rollback (power failure)
//!   }
//! }
//! ```
//!
//! Action semantics map the paper's Table 1 onto the learner/selector
//! payloads; the boolean gates `select` and `learnable` discard examples
//! (the example "leaves the system", §4.1).

use crate::actions::Action;
use crate::backend::native::NativeBackend;
use crate::backend::shapes::{CHANNELS, WINDOW};
use crate::backend::ComputeBackend;
use crate::energy::cost::CostModel;
use crate::energy::harvester::Harvester;
use crate::energy::{Capacitor, EnergyMeter};
use crate::error::{Error, Result};
use crate::learning::{Example, Learner, Verdict};
use crate::planner::DynamicActionPlanner;
use crate::planner::Planned;
use crate::selection::{Heuristic, Selector};
use crate::sensors::Sensor;
use crate::sim::executor::{Exec, Executor};
use crate::sim::policy::Policy;
use crate::sim::probe::{probe_accuracy, ProbeCache};
use crate::sim::state::RunState;
use crate::sim::world::World;
use crate::sim::{
    expire_stale, Checkpoint, PendingEx, PlannerScheduler, RunResult, Scheduler, SimConfig,
};

/// Consecutive stale scheduler plans tolerated before the engine breaks
/// the wake burst (a stale plan consumes neither energy nor time, so
/// letting it repeat would spin the burst loop for free).
const MAX_STALE_PLANS: u32 = 3;

/// Checkpoint-elision safety margin: a persist may be skipped only when
/// stored energy plus the forecast's net harvest over the persist window
/// covers this many full learn paths — the device will comfortably reach
/// the next persist point, so the skipped save costs at most re-running
/// work whose inputs replay deterministically.
const ELIDE_MARGIN: f64 = 2.0;

/// Longest burst window the forecast budget looks ahead over: the
/// harvester's current segment, capped here so bursts stay harvest-sized
/// even inside an hours-long analytic segment.
const BURST_WINDOW_MAX_US: u64 = 60_000_000;

/// The assembled device: one [`World`], one [`Executor`], one [`Policy`],
/// plus the learner/backend/costs/meter the action payloads run against.
pub struct Engine {
    pub cfg: SimConfig,
    /// Physical layer: harvester + capacitor + sensor + clock.
    pub world: World,
    /// Transaction layer: NVM + sub-action machinery.
    pub exec: Executor,
    /// Decision layer: scheduler + selector + window bookkeeping.
    pub policy: Policy,
    pub learner: Box<dyn Learner>,
    pub backend: Box<dyn ComputeBackend>,
    pub costs: CostModel,
    pub meter: EnergyMeter,

    pending: Vec<PendingEx>,
    /// Scaled `tx` price (µJ, µs) of the snapshot returned by the last
    /// [`Engine::prepare_sync`], consumed by [`Engine::commit_sync`] so
    /// the commit pays for the bytes the rendezvous actually bid (a delta
    /// snapshot pays a fraction of the calibrated full-snapshot `Tx`).
    pending_sync: Option<(f64, u64)>,
    /// The next known rendezvous boundary `(boundary_us, rx_peers)` the
    /// fleet tier announced via [`Engine::note_next_sync`] — the radio
    /// price forecast-aware planning holds in reserve ahead of a sync.
    next_sync: Option<(u64, u32)>,
    /// Work counters (learned, inferred, sensed, syncs_done) at the last
    /// persisted run-state save — the nothing-at-risk elision test.
    last_persist_mark: (u64, u64, u64, u64),
    /// Scratch mirror of `pending`'s last actions handed to the scheduler
    /// (reused every decision — no per-decision allocation).
    plan_scratch: Vec<Action>,
    result: RunResult,
    next_eval_us: u64,
    quality: f32,
    probe_cache: ProbeCache,
    run_state: RunState,
}

/// Step-by-step construction of an [`Engine`].
///
/// The world parts that define a scenario — harvester, capacitor, sensor,
/// learner and cost model — are *required*: [`EngineBuilder::build`] fails
/// fast with a [`Error::Config`] naming every missing part. The remaining
/// parts carry typed defaults: [`SimConfig::default`], the round-robin
/// selection heuristic, the dynamic action planner, and the native
/// backend. Declarative construction lives one level up in
/// [`crate::scenario::ScenarioSpec`], which drives this builder.
#[derive(Default)]
pub struct EngineBuilder {
    cfg: Option<SimConfig>,
    harvester: Option<Box<dyn Harvester>>,
    cap: Option<Capacitor>,
    sensor: Option<Box<dyn Sensor>>,
    learner: Option<Box<dyn Learner>>,
    selector: Option<Box<dyn Selector>>,
    scheduler: Option<Box<dyn Scheduler>>,
    backend: Option<Box<dyn ComputeBackend>>,
    costs: Option<CostModel>,
}

impl EngineBuilder {
    pub fn new() -> Self {
        EngineBuilder::default()
    }

    /// Simulation parameters (default: [`SimConfig::default`]).
    pub fn sim(mut self, cfg: SimConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// Energy source (required).
    pub fn harvester(mut self, h: Box<dyn Harvester>) -> Self {
        self.harvester = Some(h);
        self
    }

    /// Energy store (required).
    pub fn capacitor(mut self, c: Capacitor) -> Self {
        self.cap = Some(c);
        self
    }

    /// Sensor world (required).
    pub fn sensor(mut self, s: Box<dyn Sensor>) -> Self {
        self.sensor = Some(s);
        self
    }

    /// On-device learner (required).
    pub fn learner(mut self, l: Box<dyn Learner>) -> Self {
        self.learner = Some(l);
        self
    }

    /// Example-selection policy (default: round-robin, seeded from the
    /// sim config's seed).
    pub fn selector(mut self, s: Box<dyn Selector>) -> Self {
        self.selector = Some(s);
        self
    }

    /// Action scheduler (default: the dynamic action planner with the
    /// default goal).
    pub fn scheduler(mut self, s: Box<dyn Scheduler>) -> Self {
        self.scheduler = Some(s);
        self
    }

    /// Compute backend (default: native).
    pub fn backend(mut self, b: Box<dyn ComputeBackend>) -> Self {
        self.backend = Some(b);
        self
    }

    /// Per-action cost model (required).
    pub fn costs(mut self, m: CostModel) -> Self {
        self.costs = Some(m);
        self
    }

    /// Assemble the engine; fails fast naming every missing required part.
    pub fn build(self) -> Result<Engine> {
        let mut missing = Vec::new();
        if self.harvester.is_none() {
            missing.push("harvester");
        }
        if self.cap.is_none() {
            missing.push("capacitor");
        }
        if self.sensor.is_none() {
            missing.push("sensor");
        }
        if self.learner.is_none() {
            missing.push("learner");
        }
        if self.costs.is_none() {
            missing.push("costs");
        }
        if !missing.is_empty() {
            return Err(Error::Config(format!(
                "EngineBuilder: missing required part(s): {}",
                missing.join(", ")
            )));
        }
        let cfg = self.cfg.unwrap_or_default();
        let selector = self
            .selector
            .unwrap_or_else(|| Heuristic::RoundRobin.build(cfg.seed ^ 0x5E1));
        let scheduler = self
            .scheduler
            .unwrap_or_else(|| Box::new(PlannerScheduler(DynamicActionPlanner::default())));
        let backend = self
            .backend
            .unwrap_or_else(|| Box::new(NativeBackend::new()));
        let mut world = World::new(
            self.harvester.expect("checked"),
            self.cap.expect("checked"),
            self.sensor.expect("checked"),
        );
        if cfg.forecast {
            world.enable_forecast();
        }
        Ok(Engine {
            cfg,
            world,
            exec: Executor::new(),
            policy: Policy::new(scheduler, selector),
            learner: self.learner.expect("checked"),
            backend,
            costs: self.costs.expect("checked"),
            meter: EnergyMeter::new(),
            pending: Vec::new(),
            pending_sync: None,
            next_sync: None,
            last_persist_mark: (0, 0, 0, 0),
            plan_scratch: Vec::new(),
            result: RunResult::default(),
            next_eval_us: 0,
            quality: 0.0,
            probe_cache: ProbeCache::new(),
            run_state: RunState::new(),
        })
    }
}

impl Engine {
    /// Start assembling an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Current simulated time (µs).
    pub fn now_us(&self) -> u64 {
        self.world.now_us()
    }

    /// Usable energy currently stored in the capacitor (µJ) — the local
    /// state the event scheduler's energy-aware partner selection reads
    /// at a rendezvous (starved shards are paired with rich ones).
    pub fn stored_energy_uj(&self) -> f64 {
        self.world.cap.usable_uj()
    }

    /// Announce the next fleet rendezvous boundary so forecast-aware
    /// planning can hold the radio price in reserve ahead of it — instead
    /// of burning a learn that [`Engine::prepare_sync`] then skips for
    /// lack of energy. The fleet tiers (round scheduler and event heap)
    /// call this before driving the shard to the boundary; it is a no-op
    /// unless the forecast knob is on, and `prepare_sync` clears the
    /// reserve once the rendezvous it funded arrives.
    pub fn note_next_sync(&mut self, boundary_us: u64, rx_peers: u32) {
        if self.world.forecast_enabled() && boundary_us > self.world.now_us() {
            self.next_sync = Some((boundary_us, rx_peers));
        }
    }

    /// Forecast-mode planning budgets for the current decision:
    /// `(reserved, free)` in µJ, `None` when the forecast knob is off.
    /// `free` is stored usable energy plus the net harvest the forecast
    /// predicts over the burst window (the harvester's current segment,
    /// capped at [`BURST_WINDOW_MAX_US`] — harvest-sized bursts);
    /// `reserved` additionally holds back whatever part of the next
    /// rendezvous' radio price the window up to the boundary will not
    /// re-harvest.
    fn forecast_budgets(&self) -> Option<(f64, f64)> {
        if !self.world.forecast_enabled() {
            return None; // off: the decision path costs nothing extra
        }
        let now = self.world.now_us();
        let seg = self.world.harvester.segment_end_us(now).max(now + 1);
        let window = (seg - now).min(BURST_WINDOW_MAX_US);
        let free = self.world.cap.usable_uj() + self.world.forecast_net_uj(window)?;
        let reserved = match self.next_sync {
            Some((boundary_us, rx_peers)) if boundary_us > now => {
                let (price_uj, _) = self.costs.sync_price(rx_peers);
                let refill = self.world.forecast_net_uj(boundary_us - now).unwrap_or(0.0);
                (free - (price_uj - refill).max(0.0)).max(0.0)
            }
            _ => free,
        };
        Some((reserved, free))
    }

    /// Can the upcoming model/state persist be safely skipped? Only in
    /// forecast mode, never at or past the horizon (the final checkpoint
    /// always persists), and only when either
    ///
    /// * the margin holds — stored energy plus the forecast's net harvest
    ///   over the persist window covers [`ELIDE_MARGIN`] full learn
    ///   paths, so the device will comfortably reach the next persist
    ///   point — or
    /// * (eval-grid saves only) nothing durable is at risk: no learn,
    ///   infer, sense or sync completed since the last persisted save, so
    ///   a crash at worst replays probe records whose inputs re-derive
    ///   deterministically.
    ///
    /// Soundness: elision is a pure function of simulation state, so a
    /// crash-sweep cut run elides the exact same checkpoints as its
    /// uninterrupted reference — every persist that *does* happen is
    /// still one atomic commit, the per-commit digest logs stay aligned,
    /// and recovery lands on the same commit boundary `fault::sweep`
    /// verifies. An elided save never widens the replay window beyond
    /// what the sweep checks; it only re-runs work whose inputs replay.
    fn checkpoint_elidable(&self, grid_save: bool) -> bool {
        if !self.cfg.forecast {
            return false;
        }
        let now = self.world.now_us();
        if now >= self.cfg.horizon_us {
            return false;
        }
        if grid_save && !self.work_since_last_persist() {
            return true;
        }
        let dt = self
            .next_eval_us
            .saturating_sub(now)
            .clamp(1, self.cfg.eval_period_us.max(1));
        let banked = self.world.cap.usable_uj() + self.world.forecast_net_uj(dt).unwrap_or(0.0);
        banked >= ELIDE_MARGIN * self.costs.learn_path_uj()
    }

    /// Did any durable-work counter move since the last persisted save?
    fn work_since_last_persist(&self) -> bool {
        self.persist_mark() != self.last_persist_mark
    }

    fn persist_mark(&self) -> (u64, u64, u64, u64) {
        (
            self.result.learned,
            self.result.inferred,
            self.result.sensed,
            self.result.syncs_done,
        )
    }

    /// The run's aggregates so far (live during a run; repopulated by
    /// [`Engine::restore_run_state`] after a simulated host restart).
    pub fn aggregates(&self) -> &RunResult {
        &self.result
    }

    /// Run to the horizon and return the results.
    pub fn run(mut self) -> Result<RunResult> {
        self.run_to_end()
    }

    /// Run to the horizon by reference — the seam for callers that need
    /// the engine's parts afterwards (e.g. carrying `exec.nvm`, which now
    /// holds the persisted run state, across a simulated host restart).
    /// Single-shot: the result is moved out, so a second call would start
    /// from empty aggregates.
    pub fn run_to_end(&mut self) -> Result<RunResult> {
        self.run_until(self.cfg.horizon_us)?;
        self.finish()
    }

    /// Segmented execution: advance the simulation until the clock reaches
    /// `t_us` (clamped to the horizon), then pause. Pausing happens only
    /// *between* the same charge-chunk and wake-burst steps an
    /// unsegmented run performs — the charge targets and burst logic never
    /// read the boundary — so running to the horizon in one segment or in
    /// many produces bit-identical results; the clock may land past the
    /// boundary (a burst or charge chunk finishes first), never short of
    /// it unless the horizon intervenes. This is the seam the fleet's
    /// round scheduler drives: run every shard to the sync boundary,
    /// exchange models, continue.
    pub fn run_until(&mut self, t_us: u64) -> Result<()> {
        self.result.scheduler = self.policy.scheduler.name().to_string();
        let bound = t_us.min(self.cfg.horizon_us);
        while self.world.now_us() < bound {
            if !self.charge_phase(bound) {
                break; // boundary (or horizon) reached while asleep
            }
            self.result.cycles += 1;
            self.policy.on_cycle();
            self.awake_burst()?;
            self.maybe_checkpoint()?;
        }
        Ok(())
    }

    /// Final checkpoint + aggregate finalization after the last segment.
    /// Call once, after [`Engine::run_until`] reached the horizon.
    pub fn finish(&mut self) -> Result<RunResult> {
        // final checkpoint at the horizon
        self.checkpoint()?;
        self.result.energy_uj = self.meter.total_uj();
        self.result.energy_series = self.meter.series.clone();
        self.result.action_tallies = self
            .meter
            .tallies()
            .map(|(k, t)| (k.to_string(), t.count, t.energy_uj, t.time_us))
            .collect();
        Ok(std::mem::take(&mut self.result))
    }

    /// Attempt the rendezvous of one fleet sync round: charge the
    /// capacitor toward the worst-case `tx` + `rx_peers`·`rx` radio price
    /// and, if the shard can get there, return the learner's model
    /// snapshot as its bid to participate. Wake bursts routinely end at
    /// brown-out, so the shard first *charges toward the price* (the
    /// rendezvous window runs to `deadline_us`, normally the next sync
    /// boundary); a shard whose harvester cannot get it there in a whole
    /// round skips (`syncs_skipped`) — sync is an energy-gated action,
    /// not a free barrier. Learners that do not support snapshots opt the
    /// shard out silently (no charge, no counters).
    ///
    /// Nothing is spent here: once the round coordinator knows who showed
    /// up, each participant pays via [`Engine::commit_sync`] — or, if it
    /// turned out to be alone, skips the pointless exchange for free via
    /// [`Engine::solo_sync`] (the PR-5 lone-participant tax).
    pub fn prepare_sync(
        &mut self,
        rx_peers: u32,
        deadline_us: u64,
    ) -> Option<crate::learning::ModelSnapshot> {
        self.pending_sync = None;
        // the rendezvous the forecast reserve was funding is here: release
        // the hold (the fleet tier re-announces the next boundary)
        self.next_sync = None;
        // the snapshot is taken before the energy gate on purpose: it is
        // also the participation probe, and a non-snapshotting learner
        // must opt out without the gate moving the clock. The copy a
        // skipped round wastes (one ring, ~9 KB worst case) is noise next
        // to the round of simulation around it.
        let snap = self.learner.snapshot_outgoing()?;
        let tx_share = self
            .costs
            .sync_price_bytes(0, snap.bytes(), snap.full_bytes());
        let (price_uj, price_us) =
            self.costs
                .sync_price_bytes(rx_peers, snap.bytes(), snap.full_bytes());
        // wake for the exchange: charge (inside the rendezvous window)
        // until the radio price fits — keeping the eval-cadence
        // checkpoints alive exactly like charge_phase does during
        // darkness, so a synced shard's probe series stays comparable to
        // its isolated twin's
        while self.world.cap.usable_uj() < price_uj {
            let now = self.world.now_us();
            if now >= deadline_us {
                break;
            }
            if now >= self.next_eval_us {
                let _ = self.checkpoint();
            }
            let target = deadline_us
                .min(self.next_eval_us.max(now + 1_000))
                .min(now + self.cfg.charge_step_us.max(1_000));
            if self
                .world
                .charge_until(target, self.cfg.charge_kernel, self.cfg.charge_step_us)
            {
                // awake (V >= v_on) but the price still does not fit: the
                // kernels stop at the wake threshold, so top up directly
                if self.world.cap.usable_uj() >= price_uj {
                    break;
                }
                let p = self.world.harvester.power_w(self.world.now_us());
                let dt = target
                    .saturating_sub(self.world.now_us())
                    .clamp(1_000, self.cfg.charge_step_us.max(1_000));
                self.world.cap.charge(p, dt);
                self.world.advance_us(dt);
            }
        }
        if self.world.cap.usable_uj() < price_uj {
            self.result.syncs_skipped += 1;
            return None;
        }
        let _ = price_us; // airtime is spent at commit, not at rendezvous
        self.pending_sync = Some(tx_share);
        Some(snap)
    }

    /// Pay for one prepared sync exchange: deduct the radio price for the
    /// `rx_peers` peers that actually showed up, advance the clock by the
    /// airtime and meter one `Tx` plus `rx_peers` `Rx` actions. Call only
    /// after [`Engine::prepare_sync`] returned a snapshot this round — the
    /// rendezvous already charged the capacitor up to the worst-case price
    /// and no simulation ran in between, so the deduction cannot fail
    /// (actual peers ≤ the fleet-wide count the rendezvous charged for).
    pub fn commit_sync(&mut self, rx_peers: u32) {
        // the tx leg is what the rendezvous actually bid (a delta snapshot
        // pays its byte-scaled share); the rx legs are full listen windows
        // for the peers that showed up
        let (tx_uj, tx_us) = self.pending_sync.take().unwrap_or_else(|| {
            let tx = self.costs.cost(Action::Tx);
            (tx.energy_uj, tx.time_us)
        });
        let rx = self.costs.cost(Action::Rx);
        let price_uj = tx_uj + rx.energy_uj * f64::from(rx_peers);
        let price_us = tx_us + rx.time_us * u64::from(rx_peers);
        let ok = self.world.cap.deduct_uj(price_uj);
        debug_assert!(ok, "prepare_sync charged toward the sync price");
        let _ = ok;
        self.world.advance_us(price_us);
        self.meter.record_action(Action::Tx, tx_uj, tx_us);
        for _ in 0..rx_peers {
            self.meter.record_action(Action::Rx, rx.energy_uj, rx.time_us);
        }
        // the outgoing snapshot reached its peers: the learner may take
        // its next wire delta relative to it
        self.learner.note_broadcast();
        self.result.syncs_done += 1;
    }

    /// A prepared sync round where nobody else made the rendezvous:
    /// broadcasting to nobody and listening to silence buys nothing, so
    /// the exchange is skipped with zero energy and zero airtime and the
    /// round is counted under [`RunResult::syncs_solo`].
    pub fn solo_sync(&mut self) {
        // the prepared snapshot reached nobody: drop its pending tx price
        // and leave the learner's broadcast tracking untouched
        self.pending_sync = None;
        self.result.syncs_solo += 1;
    }

    /// Count a sync round this shard sat out without even attempting the
    /// rendezvous — the fleet tier's quarantined catch-up rounds.
    pub fn note_sync_skipped(&mut self) {
        self.result.syncs_skipped += 1;
    }

    /// Fold the peer snapshots of one sync round into the local learner
    /// and persist the merged model (the delta path degrades to a full
    /// save after a merge), charging the checkpoint traffic at the
    /// model's NVM byte rate exactly like the learn path does.
    pub fn apply_sync(&mut self, peers: &[&crate::learning::ModelSnapshot]) -> Result<()> {
        if peers.is_empty() {
            return Ok(());
        }
        let expiry = self.policy.expiry_us();
        let now = self.world.now_us();
        let merged = self
            .learner
            .merge(peers, self.backend.as_mut(), now, expiry)?;
        if !merged {
            return Ok(());
        }
        // atomic checkpoint: a power failure mid-save must not tear the
        // merged model (the intermittent-safety analyzer's IL-ATOM rule).
        // Never elided: a merged model aggregates peer work this shard
        // cannot re-derive locally, so it is always at risk.
        let learner = self.learner.as_mut();
        let bytes = self.exec.persist_model(|nvm| learner.save_delta(nvm))?;
        if self.cfg.forecast {
            self.result.checkpoints_taken += 1;
        }
        self.result.ckpt_nvm_bytes += bytes;
        let ckpt_uj = self.costs.nvm_uj_per_byte * bytes as f64;
        if ckpt_uj > 0.0 {
            let avail = self.world.cap.usable_uj().max(0.0);
            if self.world.cap.deduct_uj(ckpt_uj) {
                self.meter.record("nvm_ckpt", ckpt_uj, 0);
            } else {
                self.result.power_failures += 1;
                self.meter.record("nvm_ckpt", avail.min(ckpt_uj), 0);
            }
        }
        Ok(())
    }

    /// Restore persisted run aggregates (counters, checkpoints, meter)
    /// from this engine's NVM — the resume path after a host restart where
    /// `exec.nvm` was carried over. Returns `false` when the store holds
    /// no run state. The learner restores separately through its own NVM
    /// checkpoint ([`crate::learning::Learner::restore`]).
    ///
    /// Self-heals first: if the carried-over store died inside a commit,
    /// [`crate::nvm::Nvm::recover`] rolls the interrupted transaction
    /// forward (complete commit record) or back (torn) before anything
    /// reads it, so a restore never observes a half-committed snapshot.
    pub fn restore_run_state(&mut self) -> Result<bool> {
        self.exec.nvm.recover();
        match self.run_state.restore(&mut self.exec.nvm)? {
            Some((result, meter)) => {
                self.result = result;
                self.meter = meter;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Sleep/charge until the wake threshold; false if `bound` (the
    /// current segment boundary — the horizon for unsegmented runs)
    /// passed. Checkpoints continue on cadence during darkness (the
    /// charge target is clipped at the next eval instant, so the kernel
    /// can jump freely in between). The charge targets derive from the
    /// horizon and eval cadence only — never from `bound` — which is what
    /// keeps segmented runs bit-identical to unsegmented ones.
    fn charge_phase(&mut self, bound: u64) -> bool {
        loop {
            if self.world.cap.awake_ready() {
                return self.world.now_us() < bound;
            }
            if self.world.now_us() >= bound {
                return false;
            }
            if self.world.now_us() >= self.next_eval_us {
                // checkpoints continue during darkness (best effort, as
                // before the layer split)
                let _ = self.checkpoint();
            }
            // floor the charge target 1 ms ahead (the old loop's minimum
            // step): a degenerate eval_period_us of 0 then costs one
            // checkpoint per millisecond instead of per microsecond
            let until = self
                .cfg
                .horizon_us
                .min(self.next_eval_us.max(self.world.now_us() + 1_000));
            if self
                .world
                .charge_until(until, self.cfg.charge_kernel, self.cfg.charge_step_us)
            {
                // awake — unless the clock landed on the boundary doing it
                return self.world.now_us() < bound;
            }
        }
    }

    /// Execute actions until energy is exhausted or nothing remains.
    fn awake_burst(&mut self) -> Result<()> {
        // stay below a bounded number of actions per wake to keep single
        // cycles from monopolizing the horizon (real platforms drain far
        // earlier; this is a safety valve)
        let mut stale = 0u32;
        for _ in 0..256 {
            if !self.world.cap.alive() || self.world.now_us() >= self.cfg.horizon_us {
                break;
            }
            // Mayfly-style expiration sweep: expire *unprocessed* sensed
            // data only (Mayfly discards stale sensor data, not models)
            if let Some(exp) = self.policy.expiry_us() {
                self.result.expired += expire_stale(&mut self.pending, exp, self.world.now_us());
            }

            // scheduler decision (+ overhead)
            let budgets = self.forecast_budgets();
            let ctx = self
                .policy
                .context(self.result.learned, self.quality, budgets.map(|(r, _)| r));
            self.plan_scratch.clear();
            self.plan_scratch.extend(self.pending.iter().map(|p| p.last));
            let oh = self.policy.overhead(&self.costs);
            if oh.energy_uj > 0.0 {
                if !self.world.cap.deduct_uj(oh.energy_uj) {
                    self.result.power_failures += 1;
                    break;
                }
                self.world.advance_us(oh.time_us);
                self.meter.record("planner", oh.energy_uj, oh.time_us);
            }
            let planned = self.policy.decide(&self.plan_scratch, &ctx, &self.costs);
            // attribute the sync reserve: when the unreserved budget would
            // have started or advanced a learn path that the reserved one
            // did not, the engine deferred that work to keep the upcoming
            // rendezvous funded (a learn it would otherwise burn just
            // before `prepare_sync` skips the exchange)
            if let Some((reserved, free)) = budgets {
                if free > reserved {
                    let free_ctx =
                        self.policy
                            .context(self.result.learned, self.quality, Some(free));
                    let unreserved =
                        self.policy.decide(&self.plan_scratch, &free_ctx, &self.costs);
                    let learn_path = matches!(
                        unreserved,
                        Planned::SenseNew
                            | Planned::Advance {
                                action: Action::Learn,
                                ..
                            }
                    );
                    if learn_path && unreserved != planned {
                        self.result.learns_deferred += 1;
                    }
                }
            }

            match planned {
                Planned::Idle => {
                    // nothing useful; burn the cycle by napping 1 s
                    self.world.advance_us(1_000_000);
                    break;
                }
                Planned::SenseNew => {
                    stale = 0;
                    let mut ex = PendingEx::new(Action::Sense, self.world.now_us());
                    match self.run_action(Action::Sense, &mut ex)? {
                        Exec::Done => {
                            ex.last = Action::Sense;
                            ex.sub_done = 0;
                            self.post_action(Action::Sense, &mut ex)?;
                            self.pending.push(ex);
                            self.result.sensed += 1;
                        }
                        Exec::PowerFailed => break,
                    }
                }
                Planned::Advance { slot, action } => {
                    if slot >= self.pending.len() {
                        // stale plan: the scheduler referenced a slot that
                        // no longer exists. It consumed no energy or time,
                        // so a repeating one would spin the burst for
                        // free — count it and break after repeats.
                        self.result.stale_plans += 1;
                        stale += 1;
                        if stale >= MAX_STALE_PLANS {
                            // nap like Idle: without this, a zero-overhead
                            // scheduler stuck on a stale plan would leave
                            // both clock and capacitor untouched and the
                            // outer run loop would never terminate
                            self.world.advance_us(1_000_000);
                            break;
                        }
                        continue;
                    }
                    stale = 0;
                    let mut ex = self.pending[slot].clone();
                    match self.run_action(action, &mut ex)? {
                        Exec::Done => {
                            ex.last = action;
                            ex.sub_done = 0;
                            let leaves = self.post_action(action, &mut ex)?;
                            if leaves || action.next().is_empty() {
                                self.pending.remove(slot);
                            } else {
                                self.pending[slot] = ex;
                            }
                        }
                        Exec::PowerFailed => {
                            // keep sub-action progress (splitting's purpose)
                            self.pending[slot] = ex;
                            break;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Price `action` (folding the selection heuristic's cost onto
    /// `select`) and run it through the executor.
    fn run_action(&mut self, action: Action, ex: &mut PendingEx) -> Result<Exec> {
        let mut cost = self.costs.cost(action);
        if action == Action::Select {
            let sc = self.policy.selector.cost(&self.costs);
            cost.energy_uj += sc.energy_uj;
            cost.time_us += sc.time_us;
        }
        let outcome = self
            .exec
            .run_action(&mut self.world, &mut self.meter, action, cost, ex)?;
        if outcome == Exec::PowerFailed {
            self.result.power_failures += 1;
        }
        Ok(outcome)
    }

    /// Apply the payload of a completed action. Returns `true` if the
    /// example leaves the system (discarded or terminal).
    fn post_action(&mut self, action: Action, ex: &mut PendingEx) -> Result<bool> {
        match action {
            Action::Sense => {
                let win = self
                    .world
                    .sensor
                    .window(self.world.now_us(), WINDOW)
                    .fit(WINDOW, CHANNELS);
                ex.window = Some(win);
                Ok(false)
            }
            Action::Extract => {
                let win = ex
                    .window
                    .as_ref()
                    .ok_or_else(|| Error::Nvm("extract without window".into()))?;
                let feats = self.backend.extract(&win.data)?;
                ex.example = Some(Example::new(feats, win.t_us, win.truth_abnormal));
                ex.window = None; // raw window released
                Ok(false)
            }
            Action::Decide => Ok(false),
            Action::Select => {
                let e = ex
                    .example
                    .as_ref()
                    .ok_or_else(|| Error::Nvm("select without example".into()))?;
                let keep = if self.policy.uses_selection() {
                    self.policy.selector.select(e, self.backend.as_mut())?
                } else {
                    true
                };
                self.policy.observe_select(keep);
                if !keep {
                    self.result.discarded_select += 1;
                }
                Ok(!keep)
            }
            Action::Learnable => Ok(!self.learner.learnable()),
            Action::Learn => {
                let e = ex
                    .example
                    .as_ref()
                    .ok_or_else(|| Error::Nvm("learn without example".into()))?;
                self.learner.learn(e, self.backend.as_mut())?;
                // O(dirty) delta checkpoint: only the slots this learn
                // touched hit NVM (the first call degrades to a full save),
                // bracketed so a power failure mid-save cannot tear the
                // committed model (the analyzer's IL-ATOM rule). Forecast
                // mode may elide the save when the energy margin proves the
                // device reaches the next persist point — the dirty slots
                // stay dirty, so the next save that does run covers them.
                if self.checkpoint_elidable(false) {
                    self.result.checkpoints_elided += 1;
                } else {
                    if self.cfg.forecast {
                        self.result.checkpoints_taken += 1;
                    }
                    let learner = self.learner.as_mut();
                    let bytes = self.exec.persist_model(|nvm| learner.save_delta(nvm))?;
                    self.result.ckpt_nvm_bytes += bytes;
                    // Optionally charge the actual checkpoint traffic (the
                    // calibrated learn cost already includes a full-model
                    // save, so the default rate is 0 — see `CostModel`).
                    let ckpt_uj = self.costs.nvm_uj_per_byte * bytes as f64;
                    if ckpt_uj > 0.0 {
                        let avail = self.world.cap.usable_uj().max(0.0);
                        if self.world.cap.deduct_uj(ckpt_uj) {
                            self.meter.record("nvm_ckpt", ckpt_uj, 0);
                        } else {
                            // brown-out paying for the checkpoint: the learn
                            // and its committed save stand (the FRAM write
                            // landed before the debt was discovered); meter
                            // what actually drained, not the full price
                            self.result.power_failures += 1;
                            self.meter.record("nvm_ckpt", avail.min(ckpt_uj), 0);
                        }
                    }
                }
                self.result.learned += 1;
                self.policy.observe_completion(Action::Learn);
                Ok(false)
            }
            Action::Evaluate => {
                self.quality = self.learner.evaluate(self.backend.as_mut())?;
                Ok(true) // terminal
            }
            Action::Infer => {
                let e = ex
                    .example
                    .as_ref()
                    .ok_or_else(|| Error::Nvm("infer without example".into()))?;
                let v = self.learner.infer(e, self.backend.as_mut())?;
                self.result.inferred += 1;
                self.result.infer_log.push((
                    self.world.now_us(),
                    v == Verdict::Abnormal,
                    e.truth_abnormal,
                ));
                self.policy.observe_completion(Action::Infer);
                Ok(true) // terminal
            }
            // fleet-tier radio actions never enter the per-example
            // pipeline (they have no inbound edges in the state diagram);
            // reaching here means a scheduler invented an illegal plan
            Action::Tx | Action::Rx => Err(Error::Config(format!(
                "radio action `{action}` scheduled on an example (fleet sync \
                 runs at round boundaries, not in the action pipeline)"
            ))),
        }
    }

    fn maybe_checkpoint(&mut self) -> Result<()> {
        if self.world.now_us() >= self.next_eval_us {
            self.checkpoint()?;
        }
        Ok(())
    }

    fn checkpoint(&mut self) -> Result<()> {
        let now = self.world.now_us();
        self.next_eval_us = now + self.cfg.eval_period_us;
        // Probe the *current* environment: test cases from the lookback
        // window ending now (paper: hourly tests against live conditions).
        let from = now.saturating_sub(self.cfg.probe_lookback_us);
        let to = now.max(from + self.cfg.eval_period_us.min(600_000_000)).max(1);
        let span = to - from;
        let scan = (span / 600).max(500_000);
        let probes = self.probe_cache.probes_for(
            self.world.sensor.as_ref(),
            self.backend.as_mut(),
            from,
            to,
            self.cfg.probe_count,
            scan,
        )?;
        let acc = probe_accuracy(probes, self.learner.as_mut(), self.backend.as_mut())?;
        self.meter.sample(now);
        self.result.checkpoints.push(Checkpoint {
            t_us: now,
            accuracy: acc,
            learned: self.result.learned,
            inferred: self.result.inferred,
            energy_uj: self.meter.total_uj(),
            voltage: self.world.cap.voltage(),
        });
        // persist the aggregates (O(new records) — append-only deltas) so
        // an interrupted run restores them from NVM after a host restart —
        // atomically, so a half-written stats save never becomes visible.
        // Forecast mode elides the save when the energy margin holds or
        // when no durable work happened since the last persisted save
        // (night grids: only probe records changed); the final checkpoint
        // at the horizon always persists.
        if self.checkpoint_elidable(true) {
            self.result.checkpoints_elided += 1;
            return Ok(());
        }
        if self.cfg.forecast {
            self.result.checkpoints_taken += 1;
        }
        let run_state = &mut self.run_state;
        let result = &self.result;
        let meter = &self.meter;
        let bytes = self
            .exec
            .persist_model(|nvm| run_state.save(nvm, result, meter))?;
        self.result.ckpt_nvm_bytes += bytes;
        self.last_persist_mark = self.persist_mark();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::energy::cost::ActionCost;
    use crate::energy::harvester::Constant;
    use crate::learning::KnnAnomalyLearner;
    use crate::planner::{DynamicActionPlanner, PlanContext, Pending};
    use crate::selection::{Heuristic, Selector};
    use crate::sensors::accel::{Accel, MotionProfile};
    use crate::sim::{ChargeKernel, PlannerScheduler};
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    fn small_engine(power_w: f64, horizon_s: u64) -> Engine {
        small_engine_with(power_w, horizon_s, None)
    }

    fn small_engine_with(
        power_w: f64,
        horizon_s: u64,
        scheduler: Option<Box<dyn Scheduler>>,
    ) -> Engine {
        let profile = MotionProfile::alternating_hours(1.0, 3.0, 8);
        let sensor = Accel::new(profile, 11);
        let selector: Box<dyn Selector> = Heuristic::RoundRobin.build(1);
        let scheduler = scheduler
            .unwrap_or_else(|| Box::new(PlannerScheduler(DynamicActionPlanner::default())));
        Engine::builder()
            .sim(SimConfig {
                seed: 1,
                horizon_us: horizon_s * 1_000_000,
                eval_period_us: 300_000_000,
                probe_count: 20,
                charge_step_us: 10_000_000,
                probe_lookback_us: 3_600_000_000,
                ..Default::default()
            })
            .harvester(Box::new(Constant(power_w)))
            .capacitor(Capacitor::vibration())
            .sensor(Box::new(sensor))
            .learner(Box::new(KnnAnomalyLearner::new()))
            .selector(selector)
            .scheduler(scheduler)
            .backend(Box::new(NativeBackend::new()))
            .costs(CostModel::kmeans())
            .build()
            .expect("all parts provided")
    }

    #[test]
    fn builder_fails_fast_naming_missing_parts() {
        let err = Engine::builder().build().unwrap_err();
        let msg = err.to_string();
        for part in ["harvester", "capacitor", "sensor", "learner", "costs"] {
            assert!(msg.contains(part), "missing `{part}` in: {msg}");
        }
        // partially specified: only the still-missing parts are named
        let err = Engine::builder()
            .harvester(Box::new(Constant(0.01)))
            .capacitor(Capacitor::vibration())
            .build()
            .unwrap_err();
        let msg = err.to_string();
        assert!(!msg.contains("harvester") && !msg.contains("capacitor"), "{msg}");
        assert!(msg.contains("sensor") && msg.contains("learner"), "{msg}");
    }

    #[test]
    fn builder_defaults_fill_optional_parts() {
        let profile = MotionProfile::alternating_hours(1.0, 3.0, 2);
        let e = Engine::builder()
            .harvester(Box::new(Constant(0.01)))
            .capacitor(Capacitor::vibration())
            .sensor(Box::new(Accel::new(profile, 7)))
            .learner(Box::new(KnnAnomalyLearner::new()))
            .costs(CostModel::kmeans())
            .build()
            .unwrap();
        assert_eq!(e.policy.selector.name(), "round_robin");
        assert_eq!(e.policy.scheduler.name(), "intermittent_learning");
        assert_eq!(e.backend.name(), "native");
        assert_eq!(e.cfg.seed, SimConfig::default().seed);
    }

    #[test]
    fn engine_makes_progress_with_power() {
        let r = small_engine(0.010, 1800).run().unwrap();
        assert!(r.cycles > 0);
        assert!(r.sensed > 0, "{r:?}");
        assert!(r.learned > 0);
        assert!(r.energy_uj > 0.0);
        assert!(!r.checkpoints.is_empty());
    }

    #[test]
    fn engine_starves_without_power() {
        let r = small_engine(0.0, 1800).run().unwrap();
        assert_eq!(r.learned, 0);
        assert_eq!(r.sensed, 0);
    }

    #[test]
    fn weak_power_causes_power_failures_but_still_progresses() {
        // 1.2 mW: one vibration-cap charge holds ~3.6 mJ usable — less than
        // a full learn path, so mid-action failures must occur.
        let r = small_engine(0.0012, 3600).run().unwrap();
        assert!(r.power_failures > 0, "{r:?}");
        assert!(r.sensed > 0);
    }

    #[test]
    fn nvm_byte_rate_charges_checkpoint_traffic() {
        // default rate 0: no nvm_ckpt tally; non-zero rate: the metered
        // checkpoint energy equals rate x delta-save bytes (tiny, because
        // steady-state saves are O(dirty))
        let free = small_engine(0.010, 1800).run().unwrap();
        assert!(!free.action_tallies.iter().any(|(n, ..)| n == "nvm_ckpt"));
        let mut e = small_engine(0.010, 1800);
        e.costs.nvm_uj_per_byte = 0.001; // ~1 nJ/B FRAM write
        let charged = e.run().unwrap();
        let tally = charged
            .action_tallies
            .iter()
            .find(|(n, ..)| n == "nvm_ckpt")
            .expect("nvm_ckpt metered");
        assert_eq!(tally.1, charged.learned, "one checkpoint per learn");
        assert!(tally.2 > 0.0);
        // delta checkpoints keep the charge marginal: well under one
        // planner decision's worth of energy per learn on average
        let per_learn = tally.2 / tally.1 as f64;
        assert!(per_learn < 57.0, "{per_learn} uJ/learn");
    }

    #[test]
    fn segmented_run_is_bit_identical_to_single_shot() {
        // the round scheduler's seam: run_until in many unequal segments
        // (boundaries mid-charge, mid-hour, repeated, past the horizon)
        // must reproduce the one-shot run bit for bit
        for power in [0.010, 0.0012] {
            let once = small_engine(power, 1800).run().unwrap();
            let mut e = small_engine(power, 1800);
            for b_s in [60u64, 300, 301, 301, 900, 1333, 1800, 9999] {
                e.run_until(b_s * 1_000_000).unwrap();
                assert!(
                    e.now_us() >= (b_s * 1_000_000).min(e.cfg.horizon_us),
                    "paused short of the boundary"
                );
            }
            let seg = e.finish().unwrap();
            assert_eq!(
                seg.to_json().to_string(),
                once.to_json().to_string(),
                "segmented run diverged at {power} W"
            );
            assert_eq!(seg.energy_series, once.energy_series);
            assert_eq!(seg.infer_log, once.infer_log);
        }
    }

    #[test]
    fn sync_exchange_is_energy_gated_and_metered() {
        let mut e = small_engine(0.010, 1800);
        e.run_until(300_000_000).unwrap();
        // a full capacitor affords the exchange immediately (deadline =
        // now: no rendezvous charging allowed): tx + rx charged
        e.world.cap.set_voltage(3.3);
        let before = e.world.cap.usable_uj();
        let t0 = e.now_us();
        let snap = e.prepare_sync(1, t0);
        assert!(snap.is_some(), "full capacitor could not afford a sync");
        // the rendezvous itself spends nothing — the commit pays
        assert_eq!(e.world.cap.usable_uj(), before);
        assert_eq!(e.now_us(), t0);
        e.commit_sync(1);
        let (price_uj, price_us) = e.costs.sync_price(1);
        assert!((before - e.world.cap.usable_uj() - price_uj).abs() < 1e-6);
        assert_eq!(e.now_us() - t0, price_us, "airtime not charged");
        assert_eq!(e.meter.tally("tx").count, 1);
        assert_eq!(e.meter.tally("rx").count, 1);
        // a drained capacitor with no rendezvous window skips: no charge,
        // no time
        e.world.cap.set_voltage(e.world.cap.v_off);
        let t1 = e.now_us();
        assert!(e.prepare_sync(1, t1).is_none());
        assert_eq!(e.now_us(), t1);
        assert_eq!(e.meter.tally("tx").count, 1, "skipped round paid tx");
        // counters reach the run result
        e.run_until(e.cfg.horizon_us).unwrap();
        let r = e.finish().unwrap();
        assert_eq!(r.syncs_done, 1);
        assert_eq!(r.syncs_skipped, 1);
        let doc = r.to_json().to_string();
        assert!(doc.contains("\"syncs_done\":1"), "{doc}");
        // an all-reduce exchange in a 4-fleet meters 3 rx
        let mut e = small_engine(0.010, 600);
        e.world.cap.set_voltage(3.3);
        assert!(e.prepare_sync(3, 0).is_some());
        e.commit_sync(3);
        assert_eq!(e.meter.tally("rx").count, 3);
    }

    #[test]
    fn lone_participant_skips_the_exchange_for_free() {
        let mut e = small_engine(0.010, 1800);
        e.world.cap.set_voltage(3.3);
        let before = e.world.cap.usable_uj();
        let t0 = e.now_us();
        assert!(e.prepare_sync(1, t0).is_some());
        e.solo_sync();
        assert_eq!(e.world.cap.usable_uj(), before, "solo round spent energy");
        assert_eq!(e.now_us(), t0, "solo round spent airtime");
        assert_eq!(e.meter.tally("tx").count, 0);
        assert_eq!(e.meter.tally("rx").count, 0);
        e.run_until(e.cfg.horizon_us).unwrap();
        let r = e.finish().unwrap();
        assert_eq!(r.syncs_solo, 1);
        assert_eq!(r.syncs_done, 0);
        let doc = r.to_json().to_string();
        assert!(doc.contains("\"syncs_solo\":1"), "{doc}");
    }

    #[test]
    fn sync_rendezvous_charges_toward_the_price_within_the_window() {
        // drained at the boundary, 10 mW of harvest and a whole round to
        // find the energy: the shard charges up and pays
        let mut e = small_engine(0.010, 1800);
        e.world.cap.set_voltage(e.world.cap.v_off);
        let t0 = e.now_us();
        assert!(e.prepare_sync(1, t0 + 600_000_000).is_some());
        assert!(e.now_us() > t0, "no charging time passed");
        e.commit_sync(1);
        assert_eq!(e.result.syncs_done, 1);
        // a dead harvester never gets there: the window runs out at the
        // deadline and the round is skipped
        let mut dark = small_engine(0.0, 1800);
        dark.world.cap.set_voltage(dark.world.cap.v_off);
        let t0 = dark.now_us();
        assert!(dark.prepare_sync(1, t0 + 600_000_000).is_none());
        assert!(dark.now_us() >= t0 + 600_000_000, "skip before the deadline");
        assert_eq!(dark.result.syncs_skipped, 1);
    }

    #[test]
    fn delta_snapshots_shrink_the_sync_commit_price() {
        let mut e = small_engine(0.010, 1800);
        e.run_until(300_000_000).unwrap();
        assert!(e.learner.learned_count() > 0);
        // first contact: full snapshot at the exact calibrated price
        e.world.cap.set_voltage(3.3);
        let t0 = e.now_us();
        assert!(e.prepare_sync(1, t0).is_some());
        e.commit_sync(1);
        let (full_uj, full_us) = e.costs.sync_price(1);
        // steady state: the next exchange radios a delta and pays its
        // byte-scaled share of the tx leg (the rx leg stays full)
        e.world.cap.set_voltage(3.3);
        let before = e.world.cap.usable_uj();
        let t1 = e.now_us();
        let snap = e.prepare_sync(1, t1).expect("prepared");
        assert!(
            snap.bytes() < snap.full_bytes(),
            "no delta: {} B",
            snap.bytes()
        );
        e.commit_sync(1);
        let paid = before - e.world.cap.usable_uj();
        let rx_uj = e.costs.cost(Action::Rx).energy_uj;
        assert!(paid < full_uj, "delta paid the full price: {paid} uJ");
        assert!(paid >= rx_uj, "rx leg must stay at full price");
        assert!(e.now_us() - t1 < full_us, "delta paid full airtime");
        assert_eq!(e.meter.tally("tx").count, 2);
    }

    #[test]
    fn apply_sync_persists_the_merged_model() {
        let mut donor = small_engine(0.010, 1800);
        donor.run_until(900_000_000).unwrap();
        let donor_learned = donor.learner.learned_count();
        assert!(donor_learned > 0, "donor learned nothing");
        let snap = donor.learner.snapshot().unwrap();
        let mut e = small_engine(0.010, 600);
        e.apply_sync(&[&snap]).unwrap();
        assert_eq!(e.learner.learned_count(), donor_learned);
        // the merged model hit NVM: a cold learner restores it
        let mut back = KnnAnomalyLearner::new();
        back.restore(&mut e.exec.nvm).unwrap();
        assert_eq!(back.learned_count(), donor_learned);
        // empty peer set is a no-op
        let w = e.exec.nvm.bytes_written;
        e.apply_sync(&[]).unwrap();
        assert_eq!(e.exec.nvm.bytes_written, w);
    }

    #[test]
    fn energy_series_is_monotone() {
        let r = small_engine(0.010, 1800).run().unwrap();
        for w in r.energy_series.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn checkpoints_cover_horizon() {
        let r = small_engine(0.010, 3600).run().unwrap();
        assert!(r.checkpoints.len() >= 3);
        let last = r.checkpoints.last().unwrap();
        assert!(last.t_us >= 3_600_000_000 * 9 / 10);
    }

    #[test]
    fn learning_improves_probe_accuracy() {
        let r = small_engine(0.012, 7200).run().unwrap();
        let first = r.checkpoints.first().unwrap().accuracy;
        let best = r
            .checkpoints
            .iter()
            .map(|c| c.accuracy)
            .fold(0.0f64, f64::max);
        assert!(best > first, "first {first} best {best}");
        assert!(best > 0.5, "best {best}");
    }

    #[test]
    fn event_and_stepped_kernels_agree_on_constant_power() {
        // a constant-power world is exactly piecewise constant: the two
        // kernels must produce near-identical runs (wake instants can
        // differ by ~1 µs of float rounding, so counters get a hair of
        // slack rather than exact equality)
        let mut a = small_engine(0.010, 1800);
        a.cfg.charge_kernel = ChargeKernel::Event;
        let mut b = small_engine(0.010, 1800);
        b.cfg.charge_kernel = ChargeKernel::Stepped;
        let ra = a.run().unwrap();
        let rb = b.run().unwrap();
        let near = |x: u64, y: u64, slack: u64| x.abs_diff(y) <= slack.max(x.max(y) / 50);
        assert!(near(ra.cycles, rb.cycles, 2), "{ra:?}\n{rb:?}");
        assert!(near(ra.sensed, rb.sensed, 3), "{ra:?}\n{rb:?}");
        assert!(near(ra.learned, rb.learned, 3), "{ra:?}\n{rb:?}");
        assert!(near(ra.inferred, rb.inferred, 3), "{ra:?}\n{rb:?}");
    }

    /// Scheduler wrapper recording the largest windowed learn count the
    /// engine ever put into a [`PlanContext`] (regression: these used to
    /// be hardcoded to zero).
    struct CtxProbe {
        inner: PlannerScheduler,
        max_window_learns: Arc<AtomicU32>,
    }

    impl Scheduler for CtxProbe {
        fn next(
            &mut self,
            pending: &Pending,
            ctx: &PlanContext,
            costs: &CostModel,
        ) -> Planned {
            self.max_window_learns
                .fetch_max(ctx.window_learns, Ordering::Relaxed);
            self.inner.next(pending, ctx, costs)
        }
        fn observe_select(&mut self, accepted: bool) {
            self.inner.observe_select(accepted);
        }
        fn observe_completion(&mut self, a: Action) {
            self.inner.observe_completion(a);
        }
        fn on_cycle(&mut self) {
            self.inner.on_cycle();
        }
        fn overhead(&self, costs: &CostModel) -> ActionCost {
            self.inner.overhead(costs)
        }
        fn window_cycles(&self) -> Option<u32> {
            self.inner.window_cycles()
        }
        fn name(&self) -> &'static str {
            "ctx_probe"
        }
    }

    #[test]
    fn plan_context_carries_windowed_completions() {
        let seen = Arc::new(AtomicU32::new(0));
        let probe = CtxProbe {
            inner: PlannerScheduler(DynamicActionPlanner::default()),
            max_window_learns: seen.clone(),
        };
        let r = small_engine_with(0.010, 1800, Some(Box::new(probe)))
            .run()
            .unwrap();
        assert!(r.learned > 0, "run learned nothing, probe proves nothing");
        assert!(
            seen.load(Ordering::Relaxed) > 0,
            "planner never saw a non-zero window_learns"
        );
    }

    /// A scheduler that always advances a non-existent slot: the engine
    /// must count the stale plans and break instead of spinning.
    struct StalePlanner;

    impl Scheduler for StalePlanner {
        fn next(&mut self, _p: &Pending, _c: &PlanContext, _m: &CostModel) -> Planned {
            Planned::Advance {
                slot: 999,
                action: Action::Extract,
            }
        }
        fn overhead(&self, _m: &CostModel) -> ActionCost {
            ActionCost::new(0.0, 0, 1) // free decisions: the spin case
        }
        fn name(&self) -> &'static str {
            "stale"
        }
    }

    #[test]
    fn stale_plans_are_counted_and_cannot_spin_the_burst() {
        let r = small_engine_with(0.010, 120, Some(Box::new(StalePlanner)))
            .run()
            .unwrap();
        // counted...
        assert!(r.stale_plans > 0, "{r:?}");
        // ...and bounded: every wake breaks after MAX_STALE_PLANS repeats
        // instead of running the 256-action safety valve dry
        assert!(
            r.stale_plans <= u64::from(MAX_STALE_PLANS) * (r.cycles + 1),
            "stale plans spun the burst: {} over {} cycles",
            r.stale_plans,
            r.cycles
        );
        assert_eq!(r.sensed, 0);
    }

    #[test]
    fn forecast_budgets_hold_back_the_sync_price() {
        let mut e = small_engine(0.0, 600);
        assert!(e.forecast_budgets().is_none(), "knob off must stay None");
        e.cfg.forecast = true;
        e.world.enable_forecast();
        e.world.cap.set_voltage(3.3);
        let (r0, f0) = e.forecast_budgets().unwrap();
        assert_eq!(r0, f0, "no rendezvous announced, nothing reserved");
        // a rendezvous one minute out with a dead harvester (no refill):
        // the whole radio price comes out of the reserved budget
        e.note_next_sync(60_000_000, 1);
        let (r1, f1) = e.forecast_budgets().unwrap();
        let (price_uj, _) = e.costs.sync_price(1);
        assert_eq!(f1, f0);
        assert!(
            (f1 - r1 - price_uj).abs() < 1e-6,
            "reserve {} vs price {price_uj}",
            f1 - r1
        );
        // the rendezvous arriving releases the hold
        assert!(e.prepare_sync(1, e.now_us()).is_some());
        let (r2, f2) = e.forecast_budgets().unwrap();
        assert_eq!(r2, f2, "prepare_sync left the reserve armed");
    }

    #[test]
    fn forecast_mode_elides_checkpoints_and_keeps_the_final_save() {
        let mut e = small_engine(0.010, 1800);
        e.cfg.forecast = true;
        e.world.enable_forecast();
        let r = e.run_to_end().unwrap();
        // 10 mW against a ~15 mJ learn path: the margin holds at most
        // persist points, so saves are elided — but never the horizon's
        assert!(r.checkpoints_elided > 0, "{r:?}");
        assert!(r.checkpoints_taken >= 1, "final checkpoint must persist");
        let doc = r.to_json().to_string();
        assert!(doc.contains("\"checkpoints_elided\""), "{doc}");
        assert!(doc.contains("\"ckpt_nvm_bytes\""), "{doc}");
        // the learner model is still durable: a cold learner restores it
        let mut back = KnnAnomalyLearner::new();
        back.restore(&mut e.exec.nvm).unwrap();
        assert!(back.learned_count() > 0);
        // the default policy reaches no elision decision and its document
        // keeps the pre-forecast shape; byte accounting runs regardless
        let base = small_engine(0.010, 1800).run().unwrap();
        assert_eq!(base.checkpoints_taken + base.checkpoints_elided, 0);
        assert!(!base.to_json().to_string().contains("checkpoints_taken"));
        assert!(base.ckpt_nvm_bytes > 0);
    }

    #[test]
    fn mayfly_expiry_drops_only_stale_sensed_examples() {
        use crate::baselines::MayflyScheduler;
        // short expiry in a weak-power world: sensed examples go stale
        // while the capacitor recharges
        let sched = MayflyScheduler::new(0.5, 1_000_000);
        let r = small_engine_with(0.0012, 3600, Some(Box::new(sched)))
            .run()
            .unwrap();
        assert!(r.sensed > 0);
        assert!(r.expired > 0, "nothing expired: {r:?}");
        // bookkeeping stays coherent (expired examples left the system)
        assert!(r.learned + r.inferred + r.discarded_select + r.expired + 2 >= r.sensed);
    }
}
