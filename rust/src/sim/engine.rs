//! The discrete-event intermittent execution engine.
//!
//! One `Engine` owns a full device world and advances it through
//! charge/wake/execute cycles:
//!
//! ```text
//! loop {
//!   charge capacitor until V >= v_on          (sleep; time jumps)
//!   while V > v_off {
//!     scheduler picks next transition          (planner overhead charged)
//!     execute it sub-action by sub-action      (atomic; NVM commit each)
//!     on energy exhaustion: abort + rollback   (power failure)
//!   }
//! }
//! ```
//!
//! Action semantics map the paper's Table 1 onto the learner/selector
//! payloads; the boolean gates `select` and `learnable` discard examples
//! (the example "leaves the system", §4.1).

use crate::actions::Action;
use crate::backend::native::NativeBackend;
use crate::backend::shapes::{CHANNELS, WINDOW};
use crate::backend::ComputeBackend;
use crate::energy::cost::CostModel;
use crate::energy::harvester::Harvester;
use crate::energy::{Capacitor, EnergyMeter};
use crate::error::{Error, Result};
use crate::learning::{Example, Learner, Verdict};
use crate::nvm::Nvm;
use crate::planner::{DynamicActionPlanner, PlanContext, Planned};
use crate::selection::{Heuristic, Selector};
use crate::sensors::Sensor;
use crate::sim::probe::{build_probes_range, probe_accuracy};
use crate::sim::{Checkpoint, PendingEx, PlannerScheduler, RunResult, Scheduler, SimConfig};

/// Outcome of attempting one action.
enum Exec {
    Done,
    PowerFailed,
}

/// The assembled device world.
pub struct Engine {
    pub cfg: SimConfig,
    pub harvester: Box<dyn Harvester>,
    pub cap: Capacitor,
    pub nvm: Nvm,
    pub sensor: Box<dyn Sensor>,
    pub learner: Box<dyn Learner>,
    pub selector: Box<dyn Selector>,
    pub scheduler: Box<dyn Scheduler>,
    pub backend: Box<dyn ComputeBackend>,
    pub costs: CostModel,
    pub meter: EnergyMeter,

    t_us: u64,
    pending: Vec<PendingEx>,
    result: RunResult,
    next_eval_us: u64,
    quality: f32,
}

/// Step-by-step construction of an [`Engine`].
///
/// The world parts that define a scenario — harvester, capacitor, sensor,
/// learner and cost model — are *required*: [`EngineBuilder::build`] fails
/// fast with a [`Error::Config`] naming every missing part. The remaining
/// parts carry typed defaults: [`SimConfig::default`], the round-robin
/// selection heuristic, the dynamic action planner, and the native
/// backend. Declarative construction lives one level up in
/// [`crate::scenario::ScenarioSpec`], which drives this builder.
#[derive(Default)]
pub struct EngineBuilder {
    cfg: Option<SimConfig>,
    harvester: Option<Box<dyn Harvester>>,
    cap: Option<Capacitor>,
    sensor: Option<Box<dyn Sensor>>,
    learner: Option<Box<dyn Learner>>,
    selector: Option<Box<dyn Selector>>,
    scheduler: Option<Box<dyn Scheduler>>,
    backend: Option<Box<dyn ComputeBackend>>,
    costs: Option<CostModel>,
}

impl EngineBuilder {
    pub fn new() -> Self {
        EngineBuilder::default()
    }

    /// Simulation parameters (default: [`SimConfig::default`]).
    pub fn sim(mut self, cfg: SimConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// Energy source (required).
    pub fn harvester(mut self, h: Box<dyn Harvester>) -> Self {
        self.harvester = Some(h);
        self
    }

    /// Energy store (required).
    pub fn capacitor(mut self, c: Capacitor) -> Self {
        self.cap = Some(c);
        self
    }

    /// Sensor world (required).
    pub fn sensor(mut self, s: Box<dyn Sensor>) -> Self {
        self.sensor = Some(s);
        self
    }

    /// On-device learner (required).
    pub fn learner(mut self, l: Box<dyn Learner>) -> Self {
        self.learner = Some(l);
        self
    }

    /// Example-selection policy (default: round-robin, seeded from the
    /// sim config's seed).
    pub fn selector(mut self, s: Box<dyn Selector>) -> Self {
        self.selector = Some(s);
        self
    }

    /// Action scheduler (default: the dynamic action planner with the
    /// default goal).
    pub fn scheduler(mut self, s: Box<dyn Scheduler>) -> Self {
        self.scheduler = Some(s);
        self
    }

    /// Compute backend (default: native).
    pub fn backend(mut self, b: Box<dyn ComputeBackend>) -> Self {
        self.backend = Some(b);
        self
    }

    /// Per-action cost model (required).
    pub fn costs(mut self, m: CostModel) -> Self {
        self.costs = Some(m);
        self
    }

    /// Assemble the engine; fails fast naming every missing required part.
    pub fn build(self) -> Result<Engine> {
        let mut missing = Vec::new();
        if self.harvester.is_none() {
            missing.push("harvester");
        }
        if self.cap.is_none() {
            missing.push("capacitor");
        }
        if self.sensor.is_none() {
            missing.push("sensor");
        }
        if self.learner.is_none() {
            missing.push("learner");
        }
        if self.costs.is_none() {
            missing.push("costs");
        }
        if !missing.is_empty() {
            return Err(Error::Config(format!(
                "EngineBuilder: missing required part(s): {}",
                missing.join(", ")
            )));
        }
        let cfg = self.cfg.unwrap_or_default();
        let selector = self
            .selector
            .unwrap_or_else(|| Heuristic::RoundRobin.build(cfg.seed ^ 0x5E1));
        let scheduler = self
            .scheduler
            .unwrap_or_else(|| Box::new(PlannerScheduler(DynamicActionPlanner::default())));
        let backend = self
            .backend
            .unwrap_or_else(|| Box::new(NativeBackend::new()));
        Ok(Engine {
            cfg,
            harvester: self.harvester.expect("checked"),
            cap: self.cap.expect("checked"),
            nvm: Nvm::new(),
            sensor: self.sensor.expect("checked"),
            learner: self.learner.expect("checked"),
            selector,
            scheduler,
            backend,
            costs: self.costs.expect("checked"),
            meter: EnergyMeter::new(),
            t_us: 0,
            pending: Vec::new(),
            result: RunResult::default(),
            next_eval_us: 0,
            quality: 0.0,
        })
    }
}

impl Engine {
    /// Start assembling an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Current simulated time (µs).
    pub fn now_us(&self) -> u64 {
        self.t_us
    }

    /// Run to the horizon and return the results.
    pub fn run(mut self) -> Result<RunResult> {
        self.result.scheduler = self.scheduler.name().to_string();
        while self.t_us < self.cfg.horizon_us {
            if !self.charge_until_wake() {
                break; // horizon reached while asleep
            }
            self.result.cycles += 1;
            self.scheduler.on_cycle();
            self.awake_burst()?;
            self.maybe_checkpoint()?;
        }
        // final checkpoint at the horizon
        self.checkpoint()?;
        self.result.energy_uj = self.meter.total_uj();
        self.result.energy_series = self.meter.series.clone();
        self.result.action_tallies = self
            .meter
            .tallies()
            .map(|(k, t)| (k.to_string(), t.count, t.energy_uj, t.time_us))
            .collect();
        Ok(self.result)
    }

    /// Sleep/charge until the wake threshold; false if the horizon passed.
    fn charge_until_wake(&mut self) -> bool {
        while self.t_us < self.cfg.horizon_us {
            if self.cap.awake_ready() {
                return true;
            }
            let p = self.harvester.power_w(self.t_us);
            let step = match self.cap.time_to_wake_s(p) {
                Some(s) => ((s * 1e6) as u64 + 1).min(self.cfg.charge_step_us),
                None => self.cfg.charge_step_us,
            }
            .max(1_000);
            self.cap.charge(p, step);
            self.t_us += step;
            // checkpoints continue during darkness
            if self.t_us >= self.next_eval_us {
                let _ = self.checkpoint();
            }
        }
        false
    }

    /// Execute actions until energy is exhausted or nothing remains.
    fn awake_burst(&mut self) -> Result<()> {
        // stay below a bounded number of actions per wake to keep single
        // cycles from monopolizing the horizon (real platforms drain far
        // earlier; this is a safety valve)
        for _ in 0..256 {
            if !self.cap.alive() || self.t_us >= self.cfg.horizon_us {
                break;
            }
            // Mayfly-style expiration sweep
            if let Some(exp) = self.scheduler.expiry_us() {
                let t = self.t_us;
                let before = self.pending.len();
                self.pending
                    .retain(|p| p.last == Action::Sense && p.sensed_at_us + exp > t || p.last != Action::Sense);
                // expire *unprocessed* sensed data only (Mayfly discards stale
                // sensor data, not models)
                self.result.expired += (before - self.pending.len()) as u64;
            }

            // scheduler decision (+ overhead)
            let ctx = self.plan_context();
            let pending_actions: Vec<Action> = self.pending.iter().map(|p| p.last).collect();
            let oh = self.scheduler.overhead(&self.costs);
            if oh.energy_uj > 0.0 {
                if !self.cap.deduct_uj(oh.energy_uj) {
                    self.result.power_failures += 1;
                    break;
                }
                self.t_us += oh.time_us;
                self.meter.record("planner", oh.energy_uj, oh.time_us);
            }
            let planned = self
                .scheduler
                .next(&pending_actions, &ctx, &self.costs);

            match planned {
                Planned::Idle => {
                    // nothing useful; burn the cycle by napping 1 s
                    self.t_us += 1_000_000;
                    break;
                }
                Planned::SenseNew => {
                    let mut ex = PendingEx::new(Action::Sense, self.t_us);
                    match self.execute(Action::Sense, &mut ex)? {
                        Exec::Done => {
                            ex.last = Action::Sense;
                            ex.sub_done = 0;
                            self.post_action(Action::Sense, &mut ex)?;
                            self.pending.push(ex);
                            self.result.sensed += 1;
                        }
                        Exec::PowerFailed => break,
                    }
                }
                Planned::Advance { slot, action } => {
                    if slot >= self.pending.len() {
                        // stale plan (shouldn't happen); skip
                        continue;
                    }
                    let mut ex = self.pending[slot].clone();
                    match self.execute(action, &mut ex)? {
                        Exec::Done => {
                            ex.last = action;
                            ex.sub_done = 0;
                            let leaves = self.post_action(action, &mut ex)?;
                            if leaves || action.next().is_empty() {
                                self.pending.remove(slot);
                            } else {
                                self.pending[slot] = ex;
                            }
                        }
                        Exec::PowerFailed => {
                            // keep sub-action progress (splitting's purpose)
                            self.pending[slot] = ex;
                            break;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn plan_context(&self) -> PlanContext {
        PlanContext {
            learned_total: self.result.learned,
            quality: self.quality,
            window_learns: 0,
            window_infers: 0,
        }
    }

    /// Execute `action` on `ex`, sub-action by sub-action. Payload effects
    /// materialize only when the last sub-action commits.
    fn execute(&mut self, action: Action, ex: &mut PendingEx) -> Result<Exec> {
        let mut cost = self.costs.cost(action);
        // selection heuristic cost rides on the select action
        if action == Action::Select {
            let sc = self.selector.cost(&self.costs);
            cost.energy_uj += sc.energy_uj;
            cost.time_us += sc.time_us;
        }
        let sub_e = cost.sub_energy_uj();
        let sub_t = cost.sub_time_us();
        if sub_e > self.cap.full_budget_uj() {
            return Err(Error::EnergyBudget {
                action: action.name().into(),
                needed_uj: sub_e,
                budget_uj: self.cap.full_budget_uj(),
            });
        }
        while ex.sub_done < cost.splits {
            self.nvm.begin_action()?;
            if !self.cap.deduct_uj(sub_e) {
                // power failure mid-sub-action: roll back
                self.nvm.abort_action();
                self.meter.record_abort(action, self.cap.usable_uj().max(0.0));
                self.result.power_failures += 1;
                return Ok(Exec::PowerFailed);
            }
            self.t_us += sub_t;
            ex.sub_done += 1;
            self.nvm.commit_action()?;
            self.meter.record_action(action, sub_e, sub_t);
        }
        Ok(Exec::Done)
    }

    /// Apply the payload of a completed action. Returns `true` if the
    /// example leaves the system (discarded or terminal).
    fn post_action(&mut self, action: Action, ex: &mut PendingEx) -> Result<bool> {
        match action {
            Action::Sense => {
                let win = self
                    .sensor
                    .window(self.t_us, WINDOW)
                    .fit(WINDOW, CHANNELS);
                ex.window = Some(win);
                Ok(false)
            }
            Action::Extract => {
                let win = ex
                    .window
                    .as_ref()
                    .ok_or_else(|| Error::Nvm("extract without window".into()))?;
                let feats = self.backend.extract(&win.data)?;
                ex.example = Some(Example::new(feats, win.t_us, win.truth_abnormal));
                ex.window = None; // raw window released
                Ok(false)
            }
            Action::Decide => Ok(false),
            Action::Select => {
                let e = ex
                    .example
                    .as_ref()
                    .ok_or_else(|| Error::Nvm("select without example".into()))?;
                let keep = if self.scheduler.uses_selection() {
                    self.selector.select(e, self.backend.as_mut())?
                } else {
                    true
                };
                self.scheduler.observe_select(keep);
                if !keep {
                    self.result.discarded_select += 1;
                }
                Ok(!keep)
            }
            Action::Learnable => Ok(!self.learner.learnable()),
            Action::Learn => {
                let e = ex
                    .example
                    .as_ref()
                    .ok_or_else(|| Error::Nvm("learn without example".into()))?;
                self.learner.learn(e, self.backend.as_mut())?;
                self.learner.save(&mut self.nvm)?;
                self.result.learned += 1;
                self.scheduler.observe_completion(Action::Learn);
                Ok(false)
            }
            Action::Evaluate => {
                self.quality = self.learner.evaluate(self.backend.as_mut())?;
                Ok(true) // terminal
            }
            Action::Infer => {
                let e = ex
                    .example
                    .as_ref()
                    .ok_or_else(|| Error::Nvm("infer without example".into()))?;
                let v = self.learner.infer(e, self.backend.as_mut())?;
                self.result.inferred += 1;
                self.result.infer_log.push((
                    self.t_us,
                    v == Verdict::Abnormal,
                    e.truth_abnormal,
                ));
                self.scheduler.observe_completion(Action::Infer);
                Ok(true) // terminal
            }
        }
    }

    fn maybe_checkpoint(&mut self) -> Result<()> {
        if self.t_us >= self.next_eval_us {
            self.checkpoint()?;
        }
        Ok(())
    }

    fn checkpoint(&mut self) -> Result<()> {
        self.next_eval_us = self.t_us + self.cfg.eval_period_us;
        // Probe the *current* environment: test cases from the lookback
        // window ending now (paper: hourly tests against live conditions).
        let from = self.t_us.saturating_sub(self.cfg.probe_lookback_us);
        let to = self.t_us.max(from + self.cfg.eval_period_us.min(600_000_000)).max(1);
        let span = to - from;
        let scan = (span / 600).max(500_000);
        let probes = build_probes_range(
            self.sensor.as_ref(),
            self.backend.as_mut(),
            from,
            to,
            self.cfg.probe_count,
            scan,
        )?;
        let acc = probe_accuracy(&probes, self.learner.as_mut(), self.backend.as_mut())?;
        self.meter.sample(self.t_us);
        self.result.checkpoints.push(Checkpoint {
            t_us: self.t_us,
            accuracy: acc,
            learned: self.result.learned,
            inferred: self.result.inferred,
            energy_uj: self.meter.total_uj(),
            voltage: self.cap.voltage(),
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::energy::harvester::Constant;
    use crate::learning::KnnAnomalyLearner;
    use crate::planner::DynamicActionPlanner;
    use crate::selection::{Heuristic, Selector};
    use crate::sensors::accel::{Accel, MotionProfile};
    use crate::sim::PlannerScheduler;

    fn small_engine(power_w: f64, horizon_s: u64) -> Engine {
        let profile = MotionProfile::alternating_hours(1.0, 3.0, 8);
        let sensor = Accel::new(profile, 11);
        let selector: Box<dyn Selector> = Heuristic::RoundRobin.build(1);
        Engine::builder()
            .sim(SimConfig {
                seed: 1,
                horizon_us: horizon_s * 1_000_000,
                eval_period_us: 300_000_000,
                probe_count: 20,
                charge_step_us: 10_000_000,
                probe_lookback_us: 3_600_000_000,
            })
            .harvester(Box::new(Constant(power_w)))
            .capacitor(Capacitor::vibration())
            .sensor(Box::new(sensor))
            .learner(Box::new(KnnAnomalyLearner::new()))
            .selector(selector)
            .scheduler(Box::new(PlannerScheduler(DynamicActionPlanner::default())))
            .backend(Box::new(NativeBackend::new()))
            .costs(CostModel::kmeans())
            .build()
            .expect("all parts provided")
    }

    #[test]
    fn builder_fails_fast_naming_missing_parts() {
        let err = Engine::builder().build().unwrap_err();
        let msg = err.to_string();
        for part in ["harvester", "capacitor", "sensor", "learner", "costs"] {
            assert!(msg.contains(part), "missing `{part}` in: {msg}");
        }
        // partially specified: only the still-missing parts are named
        let err = Engine::builder()
            .harvester(Box::new(Constant(0.01)))
            .capacitor(Capacitor::vibration())
            .build()
            .unwrap_err();
        let msg = err.to_string();
        assert!(!msg.contains("harvester") && !msg.contains("capacitor"), "{msg}");
        assert!(msg.contains("sensor") && msg.contains("learner"), "{msg}");
    }

    #[test]
    fn builder_defaults_fill_optional_parts() {
        let profile = MotionProfile::alternating_hours(1.0, 3.0, 2);
        let e = Engine::builder()
            .harvester(Box::new(Constant(0.01)))
            .capacitor(Capacitor::vibration())
            .sensor(Box::new(Accel::new(profile, 7)))
            .learner(Box::new(KnnAnomalyLearner::new()))
            .costs(CostModel::kmeans())
            .build()
            .unwrap();
        assert_eq!(e.selector.name(), "round_robin");
        assert_eq!(e.scheduler.name(), "intermittent_learning");
        assert_eq!(e.backend.name(), "native");
        assert_eq!(e.cfg.seed, SimConfig::default().seed);
    }

    #[test]
    fn engine_makes_progress_with_power() {
        let r = small_engine(0.010, 1800).run().unwrap();
        assert!(r.cycles > 0);
        assert!(r.sensed > 0, "{r:?}");
        assert!(r.learned > 0);
        assert!(r.energy_uj > 0.0);
        assert!(!r.checkpoints.is_empty());
    }

    #[test]
    fn engine_starves_without_power() {
        let r = small_engine(0.0, 1800).run().unwrap();
        assert_eq!(r.learned, 0);
        assert_eq!(r.sensed, 0);
    }

    #[test]
    fn weak_power_causes_power_failures_but_still_progresses() {
        // 1.2 mW: one vibration-cap charge holds ~3.6 mJ usable — less than
        // a full learn path, so mid-action failures must occur.
        let r = small_engine(0.0012, 3600).run().unwrap();
        assert!(r.power_failures > 0, "{r:?}");
        assert!(r.sensed > 0);
    }

    #[test]
    fn energy_series_is_monotone() {
        let r = small_engine(0.010, 1800).run().unwrap();
        for w in r.energy_series.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn checkpoints_cover_horizon() {
        let r = small_engine(0.010, 3600).run().unwrap();
        assert!(r.checkpoints.len() >= 3);
        let last = r.checkpoints.last().unwrap();
        assert!(last.t_us >= 3_600_000_000 * 9 / 10);
    }

    #[test]
    fn learning_improves_probe_accuracy() {
        let r = small_engine(0.012, 7200).run().unwrap();
        let first = r.checkpoints.first().unwrap().accuracy;
        let best = r
            .checkpoints
            .iter()
            .map(|c| c.accuracy)
            .fold(0.0f64, f64::max);
        assert!(best > first, "first {first} best {best}");
        assert!(best > 0.5, "best {best}");
    }
}
