//! The eight action primitives (paper Table 1) and the action state
//! diagram (Fig. 3) that constrains their per-example execution order.
//!
//! An *action* is the unit of atomic intermittent execution: it either
//! runs to completion on one capacitor charge (possibly as several
//! sub-actions, §3.4) or its intermediate results are discarded and it
//! restarts after the next power-up (§3.5 programming model).

use std::fmt;

/// The action primitives of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Action {
    /// Sense and convert data to an example.
    Sense,
    /// Extract features from an example.
    Extract,
    /// Decide to learn or infer.
    Decide,
    /// Determine whether a training example increases learning performance.
    Select,
    /// Check prerequisites of a learn action.
    Learnable,
    /// Execute a learning algorithm intermittently.
    Learn,
    /// Evaluate the learning performance.
    Evaluate,
    /// Make an inference using the current model.
    Infer,
    /// Transmit the local model snapshot to fleet peers (federated sync).
    /// Not part of the per-example state diagram: the fleet round
    /// scheduler charges a Tx/Rx pair at each sync boundary.
    Tx,
    /// Receive peer model snapshot(s) at a fleet sync boundary.
    Rx,
}

impl Action {
    /// All actions: the eight Table-1 primitives in state-diagram order,
    /// then the fleet-sync radio pair (not reachable from `sense`).
    pub const ALL: [Action; 10] = [
        Action::Sense,
        Action::Extract,
        Action::Decide,
        Action::Select,
        Action::Learnable,
        Action::Learn,
        Action::Evaluate,
        Action::Infer,
        Action::Tx,
        Action::Rx,
    ];

    /// Successor actions per the action state diagram (Fig. 3).
    ///
    /// `sense → extract → decide → {select → learnable → learn → evaluate}
    /// | {infer}`; `evaluate` and `infer` are terminal (the example then
    /// leaves the system). `select` and `learnable` may also terminate an
    /// example early (discard), which is modelled by the planner as the
    /// example leaving the system rather than by an edge here.
    pub fn next(self) -> &'static [Action] {
        match self {
            Action::Sense => &[Action::Extract],
            Action::Extract => &[Action::Decide],
            Action::Decide => &[Action::Select, Action::Infer],
            Action::Select => &[Action::Learnable],
            Action::Learnable => &[Action::Learn],
            Action::Learn => &[Action::Evaluate],
            Action::Evaluate => &[],
            Action::Infer => &[],
            // radio actions live outside the per-example diagram
            Action::Tx => &[],
            Action::Rx => &[],
        }
    }

    /// Can `to` legally follow `self` for the same example?
    pub fn can_precede(self, to: Action) -> bool {
        self.next().contains(&to)
    }

    /// Actions whose result is a boolean gate that may discard the example
    /// (used by the planner's "bypass boolean actions at random" search
    /// refinement, §4.3).
    pub fn is_boolean_gate(self) -> bool {
        matches!(self, Action::Select | Action::Learnable | Action::Decide)
    }

    /// Length of the longest path in the state diagram starting from
    /// `sense` (= 7 actions: sense, extract, decide, select, learnable,
    /// learn, evaluate). The paper recommends the planning horizon L be on
    /// this order (§4.3).
    pub fn longest_path_len() -> usize {
        7
    }

    /// Static name (for cost tables, logs, figures).
    pub fn name(self) -> &'static str {
        match self {
            Action::Sense => "sense",
            Action::Extract => "extract",
            Action::Decide => "decide",
            Action::Select => "select",
            Action::Learnable => "learnable",
            Action::Learn => "learn",
            Action::Evaluate => "evaluate",
            Action::Infer => "infer",
            Action::Tx => "tx",
            Action::Rx => "rx",
        }
    }

    /// Parse from the CLI / config name.
    pub fn parse(s: &str) -> Option<Action> {
        Action::ALL.iter().copied().find(|a| a.name() == s)
    }
}

impl fmt::Display for Action {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Phase groups of Fig. 3 (acquiring / learning / evaluating), plus the
/// fleet-sync phase the radio pair belongs to (ours, not the paper's).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Acquiring,
    Learning,
    Evaluating,
    Syncing,
}

impl Action {
    /// Which Fig. 3 group an action belongs to.
    pub fn phase(self) -> Phase {
        match self {
            Action::Sense | Action::Extract => Phase::Acquiring,
            Action::Decide | Action::Select | Action::Learnable | Action::Learn => {
                Phase::Learning
            }
            Action::Evaluate | Action::Infer => Phase::Evaluating,
            Action::Tx | Action::Rx => Phase::Syncing,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagram_has_no_cycles() {
        // DFS from sense must terminate; collect max depth.
        fn depth(a: Action, seen: &mut Vec<Action>) -> usize {
            assert!(!seen.contains(&a), "cycle at {a}");
            seen.push(a);
            let d = a
                .next()
                .iter()
                .map(|&n| depth(n, seen))
                .max()
                .unwrap_or(0);
            seen.pop();
            d + 1
        }
        assert_eq!(depth(Action::Sense, &mut vec![]), Action::longest_path_len());
    }

    #[test]
    fn decide_branches_to_learn_or_infer_paths() {
        assert!(Action::Decide.can_precede(Action::Select));
        assert!(Action::Decide.can_precede(Action::Infer));
        assert!(!Action::Decide.can_precede(Action::Learn));
    }

    #[test]
    fn terminals_have_no_successors() {
        assert!(Action::Evaluate.next().is_empty());
        assert!(Action::Infer.next().is_empty());
    }

    #[test]
    fn parse_round_trips() {
        for a in Action::ALL {
            assert_eq!(Action::parse(a.name()), Some(a));
        }
        assert_eq!(Action::parse("bogus"), None);
    }

    #[test]
    fn phases_cover_fig3_grouping() {
        assert_eq!(Action::Sense.phase(), Phase::Acquiring);
        assert_eq!(Action::Learn.phase(), Phase::Learning);
        assert_eq!(Action::Infer.phase(), Phase::Evaluating);
        assert_eq!(Action::Tx.phase(), Phase::Syncing);
        assert_eq!(Action::Rx.phase(), Phase::Syncing);
    }

    #[test]
    fn radio_actions_stay_outside_the_example_diagram() {
        // Tx/Rx are fleet-tier actions: no example transitions into or out
        // of them, so the planner's per-example search never sees them
        assert!(Action::Tx.next().is_empty());
        assert!(Action::Rx.next().is_empty());
        for a in Action::ALL {
            assert!(!a.can_precede(Action::Tx), "{a} precedes tx");
            assert!(!a.can_precede(Action::Rx), "{a} precedes rx");
        }
        assert_eq!(Action::parse("tx"), Some(Action::Tx));
        assert_eq!(Action::parse("rx"), Some(Action::Rx));
    }
}
