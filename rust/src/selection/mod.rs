//! Example-selection heuristics (paper §5): decide at run-time whether a
//! freshly extracted example is worth spending a `learn` action on.
//!
//! Three heuristics from §5.2 plus the no-selection baseline:
//!
//! * **Round-robin** (balance, Eq. 4): keep k running centroids; select
//!   x_{n+1} iff its nearest centroid is the one whose turn it is
//!   (`1 + n mod k == argmin_j d(x, μ_j)`).
//! * **k-last lists** (diversity + representation, Eq. 5): keep the last k
//!   selected (B) and last k rejected (B′) examples; select x iff
//!   `div(B∪{x}) > div(B)` and `rep(B∪{x}, B′) < rep(B, B′)`.
//! * **Randomized choice** (uncertainty proxy): select with probability p.
//! * **None**: learn everything (the baseline the paper compares against).

use crate::backend::shapes::*;
use crate::backend::ComputeBackend;
use crate::energy::cost::{ActionCost, CostModel};
use crate::error::Result;
use crate::learning::Example;
use crate::util::{stats, Rng};

/// A run-time example-selection policy.
pub trait Selector: Send {
    /// Decide whether to learn `ex` (and update internal state).
    fn select(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<bool>;

    /// Per-invocation overhead from the cost model (Fig. 17).
    fn cost(&self, m: &CostModel) -> ActionCost;

    fn name(&self) -> &'static str;
}

/// Which heuristic to instantiate (config-level enum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Heuristic {
    RoundRobin,
    KLastLists,
    Randomized,
    None,
}

impl Heuristic {
    pub fn build(self, seed: u64) -> Box<dyn Selector> {
        match self {
            Heuristic::RoundRobin => Box::new(RoundRobin::new(K_NEIGHBORS)),
            Heuristic::KLastLists => Box::new(KLastLists::new()),
            Heuristic::Randomized => Box::new(Randomized::new(0.5, seed)),
            Heuristic::None => Box::new(NoSelection),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Heuristic::RoundRobin => "round_robin",
            Heuristic::KLastLists => "k_last_lists",
            Heuristic::Randomized => "randomized",
            Heuristic::None => "none",
        }
    }

    pub const ALL: [Heuristic; 4] = [
        Heuristic::RoundRobin,
        Heuristic::KLastLists,
        Heuristic::Randomized,
        Heuristic::None,
    ];

    /// Inverse of [`Heuristic::name`].
    pub fn parse(s: &str) -> Option<Heuristic> {
        Heuristic::ALL.into_iter().find(|h| h.name() == s)
    }
}

// ---------------------------------------------------------------- round-robin

/// Round-robin balance heuristic (Eq. 4).
#[derive(Debug, Clone)]
pub struct RoundRobin {
    k: usize,
    /// Running centroids of selected examples, one per cluster.
    centroids: Vec<Vec<f32>>,
    /// Per-centroid selected counts (for the running mean).
    counts: Vec<u64>,
    /// Total selected so far (the paper's n).
    n: u64,
    /// Total candidates observed (drives the turn rotation).
    seen: u64,
    /// EMA of the nearest-centroid distance over *all* observed examples
    /// (bootstrap scale estimate).
    dbar: f32,
}

impl RoundRobin {
    pub fn new(k: usize) -> Self {
        RoundRobin {
            k: k.max(1),
            centroids: Vec::new(),
            counts: Vec::new(),
            n: 0,
            seen: 0,
            dbar: 0.0,
        }
    }

    fn nearest(&self, x: &[f32]) -> usize {
        let mut best = 0;
        let mut bd = f32::INFINITY;
        for (i, c) in self.centroids.iter().enumerate() {
            let d = stats::sq_euclidean(x, c);
            if d < bd {
                bd = d;
                best = i;
            }
        }
        best
    }

    fn accept(&mut self, x: &[f32], slot: usize) {
        if slot == self.centroids.len() {
            self.centroids.push(x.to_vec());
            self.counts.push(1);
        } else {
            let cnt = self.counts[slot] + 1;
            let c = &mut self.centroids[slot];
            for i in 0..c.len() {
                c[i] += (x[i] - c[i]) / cnt as f32;
            }
            self.counts[slot] = cnt;
        }
        self.n += 1;
    }
}

impl Selector for RoundRobin {
    fn select(&mut self, ex: &Example, _be: &mut dyn ComputeBackend) -> Result<bool> {
        // Bootstrap: the first example seeds centroid 0; further centroids
        // are seeded only by examples clearly *distinct* from the existing
        // ones (nearest distance well above the running scale estimate).
        // Seeding all k centroids from near-identical early examples makes
        // `nearest` a coin flip and the turn test almost never passes.
        self.seen += 1;
        if self.centroids.is_empty() {
            self.accept(&ex.features, 0);
            return Ok(true);
        }
        let dmin = self
            .centroids
            .iter()
            .map(|c| stats::euclidean(&ex.features, c))
            .fold(f32::INFINITY, f32::min);
        let prev_dbar = self.dbar;
        self.dbar = if self.seen <= 2 {
            dmin
        } else {
            0.95 * self.dbar + 0.05 * dmin
        };
        if self.centroids.len() < self.k && dmin > 2.0 * prev_dbar.max(1e-6) {
            let slot = self.centroids.len();
            self.accept(&ex.features, slot);
            return Ok(true);
        }
        // Eq. 4 (0-indexed): select iff the nearest centroid is the one
        // whose turn it is. Deviation from the paper's letter (documented
        // in DESIGN.md): the turn rotates per *candidate* (`seen`), not per
        // *selection* (`n`). With the paper's rule, class-batched arrivals
        // (e.g. the vibration protocol's gentle-only hours) freeze the
        // turn on a cluster that never arrives and selection starves; the
        // per-candidate rotation preserves the balance intent.
        let turn = (self.seen % self.centroids.len() as u64) as usize;
        let nearest = self.nearest(&ex.features);
        if nearest == turn {
            self.accept(&ex.features, nearest);
            Ok(true)
        } else {
            Ok(false)
        }
    }

    fn cost(&self, m: &CostModel) -> ActionCost {
        m.sel_round_robin
    }

    fn name(&self) -> &'static str {
        "round_robin"
    }
}

// --------------------------------------------------------------- k-last lists

/// k-last-lists diversity/representation heuristic (Eq. 5).
#[derive(Debug, Clone)]
pub struct KLastLists {
    /// Last KLAST selected examples (ring, row-major KLAST×FEAT_DIM).
    b: Vec<f32>,
    b_len: usize,
    b_next: usize,
    /// Last KLAST rejected examples.
    bp: Vec<f32>,
    bp_len: usize,
    bp_next: usize,
}

impl Default for KLastLists {
    fn default() -> Self {
        Self::new()
    }
}

impl KLastLists {
    pub fn new() -> Self {
        KLastLists {
            b: vec![0.0; KLAST * FEAT_DIM],
            b_len: 0,
            b_next: 0,
            bp: vec![0.0; KLAST * FEAT_DIM],
            bp_len: 0,
            bp_next: 0,
        }
    }

    fn push(buf: &mut [f32], len: &mut usize, next: &mut usize, x: &[f32]) {
        buf[*next * FEAT_DIM..(*next + 1) * FEAT_DIM].copy_from_slice(x);
        *next = (*next + 1) % KLAST;
        *len = (*len + 1).min(KLAST);
    }
}

impl Selector for KLastLists {
    fn select(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<bool> {
        // Bootstrap: fill B first, then B' gets rejections naturally; until
        // both lists are full the gate cannot be evaluated — select.
        if self.b_len < KLAST {
            Self::push(&mut self.b, &mut self.b_len, &mut self.b_next, &ex.features);
            return Ok(true);
        }
        if self.bp_len < KLAST {
            // cannot evaluate representation yet: alternate to fill B'
            Self::push(&mut self.bp, &mut self.bp_len, &mut self.bp_next, &ex.features);
            return Ok(false);
        }
        let [div_b, div_bx, rep_b, rep_bx] =
            be.diversity_repr(&self.b, &self.bp, &ex.features)?;
        let take = div_bx > div_b && rep_bx < rep_b;
        if take {
            Self::push(&mut self.b, &mut self.b_len, &mut self.b_next, &ex.features);
        } else {
            Self::push(&mut self.bp, &mut self.bp_len, &mut self.bp_next, &ex.features);
        }
        Ok(take)
    }

    fn cost(&self, m: &CostModel) -> ActionCost {
        m.sel_k_last
    }

    fn name(&self) -> &'static str {
        "k_last_lists"
    }
}

// ---------------------------------------------------------------- randomized

/// Randomized-choice heuristic: select with probability `p`.
#[derive(Debug, Clone)]
pub struct Randomized {
    pub p: f64,
    rng: Rng,
}

impl Randomized {
    pub fn new(p: f64, seed: u64) -> Self {
        Randomized {
            p,
            rng: Rng::with_stream(seed, 0x5E1EC7),
        }
    }
}

impl Selector for Randomized {
    fn select(&mut self, _ex: &Example, _be: &mut dyn ComputeBackend) -> Result<bool> {
        Ok(self.rng.chance(self.p))
    }

    fn cost(&self, m: &CostModel) -> ActionCost {
        m.sel_randomized
    }

    fn name(&self) -> &'static str {
        "randomized"
    }
}

// ------------------------------------------------------------------- none

/// Learn-everything baseline (what Alpaca/Mayfly do).
#[derive(Debug, Clone, Copy)]
pub struct NoSelection;

impl Selector for NoSelection {
    fn select(&mut self, _ex: &Example, _be: &mut dyn ComputeBackend) -> Result<bool> {
        Ok(true)
    }

    fn cost(&self, _m: &CostModel) -> ActionCost {
        ActionCost::new(0.0, 0, 1)
    }

    fn name(&self) -> &'static str {
        "none"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;

    fn ex(features: Vec<f32>) -> Example {
        Example::new(features, 0, false)
    }

    fn axis_ex(axis: usize, v: f32) -> Example {
        let mut f = vec![0.0; FEAT_DIM];
        f[axis] = v;
        ex(f)
    }

    #[test]
    fn round_robin_bootstraps_distinct_centroids() {
        let mut be = NativeBackend::new();
        let mut rr = RoundRobin::new(3);
        // progressively farther examples each clear the 2x-dbar gate
        assert!(rr.select(&axis_ex(0, 5.0), &mut be).unwrap());
        assert!(rr.select(&axis_ex(1, 5.0), &mut be).unwrap());
        assert!(rr.select(&axis_ex(2, 40.0), &mut be).unwrap());
        assert_eq!(rr.centroids.len(), 3);
        // a near-duplicate of centroid 0 does NOT seed (k is full) and is
        // subject to the turn test instead
        let before = rr.centroids.len();
        let _ = rr.select(&axis_ex(0, 5.1), &mut be).unwrap();
        assert_eq!(rr.centroids.len(), before);
    }

    #[test]
    fn round_robin_turn_rotates_per_candidate() {
        let mut be = NativeBackend::new();
        let mut rr = RoundRobin::new(2);
        // seed two distinct centroids (seen = 1, 2)
        assert!(rr.select(&axis_ex(0, 5.0), &mut be).unwrap());
        assert!(rr.select(&axis_ex(1, 5.0), &mut be).unwrap());
        // seen=3 -> turn 1: a cluster-1 example is accepted
        assert!(rr.select(&axis_ex(1, 5.2), &mut be).unwrap());
        // seen=4 -> turn 0: a cluster-1 example is rejected
        assert!(!rr.select(&axis_ex(1, 5.2), &mut be).unwrap());
        // seen=5 -> turn 1 again: accepted
        assert!(rr.select(&axis_ex(1, 5.2), &mut be).unwrap());
        // ... so a batched stream still gets through at ~1/k rate rather
        // than freezing (see module docs for the deviation rationale)
    }

    #[test]
    fn round_robin_balances_selected_counts() {
        let mut be = NativeBackend::new();
        let mut rr = RoundRobin::new(2);
        let mut rng = Rng::new(9);
        let mut picked = [0u32; 2];
        for i in 0..400 {
            let cluster = (rng.next_u32() % 2) as usize;
            let mut f = vec![0.0; FEAT_DIM];
            f[cluster * 4] = 5.0 + rng.normal(0.0, 0.3) as f32;
            if rr.select(&ex(f), &mut be).unwrap() && i >= 2 {
                picked[cluster] += 1;
            }
        }
        let ratio = picked[0] as f64 / picked[1].max(1) as f64;
        assert!((0.6..1.6).contains(&ratio), "picked {picked:?}");
    }

    #[test]
    fn k_last_rejects_redundant_accepts_diverse() {
        let mut be = NativeBackend::new();
        let mut kl = KLastLists::new();
        // fill B with 4 identical-ish examples, B' with 4 others
        for _ in 0..KLAST {
            assert!(kl.select(&axis_ex(0, 1.0), &mut be).unwrap());
        }
        for _ in 0..KLAST {
            assert!(!kl.select(&axis_ex(1, 1.0), &mut be).unwrap());
        }
        // a duplicate of B adds no diversity -> rejected
        assert!(!kl.select(&axis_ex(0, 1.0), &mut be).unwrap());
        // a new direction far from B but *near* B' raises div and lowers rep
        assert!(kl.select(&axis_ex(1, 0.9), &mut be).unwrap());
    }

    #[test]
    fn randomized_matches_probability() {
        let mut be = NativeBackend::new();
        let mut r = Randomized::new(0.3, 42);
        let e = axis_ex(0, 1.0);
        let taken = (0..10_000)
            .filter(|_| r.select(&e, &mut be).unwrap())
            .count();
        assert!((2_700..3_300).contains(&taken), "taken {taken}");
    }

    #[test]
    fn none_selects_everything() {
        let mut be = NativeBackend::new();
        let mut s = NoSelection;
        assert!(s.select(&axis_ex(0, 1.0), &mut be).unwrap());
    }

    #[test]
    fn costs_match_fig17_ordering() {
        let m = CostModel::kmeans();
        let kl = KLastLists::new();
        let rr = RoundRobin::new(3);
        let rz = Randomized::new(0.5, 1);
        assert!(kl.cost(&m).energy_uj > rr.cost(&m).energy_uj);
        assert!(rr.cost(&m).energy_uj > rz.cost(&m).energy_uj);
        assert_eq!(NoSelection.cost(&m).energy_uj, 0.0);
    }

    #[test]
    fn heuristic_enum_builds_all() {
        for h in Heuristic::ALL {
            let s = h.build(1);
            assert_eq!(s.name(), h.name());
        }
    }
}
