//! On-device learners (the paper's "library of learning algorithms",
//! §3.1): the k-NN anomaly learner used by the air-quality and
//! human-presence apps (§6.1, §6.2) and the neural-network k-means
//! (competitive learning) cluster-then-label learner used by the
//! vibration app (§6.3).
//!
//! Learners hold their model state in plain vectors, dispatch all numeric
//! work through a [`crate::backend::ComputeBackend`], and can checkpoint
//! themselves to [`crate::nvm::Nvm`] so the model survives power failures.

pub mod kmeans_nn;
pub mod knn;

pub use kmeans_nn::ClusterLabelLearner;
pub use knn::KnnAnomalyLearner;

use crate::backend::shapes::{FEAT_DIM, N_BUF, N_CLUSTERS};
use crate::backend::ComputeBackend;
use crate::error::Result;
use crate::nvm::Nvm;

/// A serializable snapshot of one learner's model state — the payload a
/// fleet shard radios at a federated sync boundary. Plain owned data:
/// `Send + Clone`, so snapshots cross worker threads while the learners
/// (and their non-`Send` backends) stay pinned.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSnapshot {
    /// k-NN ring state: the buffered examples with their validity mask and
    /// per-slot acquisition times (recency for the ring merge + Mayfly
    /// expiry), plus the ring cursor and counters.
    Knn {
        /// (N_BUF, FEAT_DIM) ring buffer, row-major.
        buf: Vec<f32>,
        /// (N_BUF) validity mask.
        mask: Vec<f32>,
        /// (N_BUF) per-slot acquisition time, µs.
        times: Vec<u64>,
        /// Next ring slot to overwrite.
        next: usize,
        /// Monotonic learned-example counter.
        learned: u64,
        /// Current anomaly threshold AS_TH.
        threshold: f32,
    },
    /// k-NN *delta* snapshot: only the ring rows written since the
    /// sender's last committed broadcast, newest first — the wire analog
    /// of the NVM delta checkpoint. Receivers treat each row as one merge
    /// candidate (recency from `times`, subject to Mayfly expiry); senders
    /// fall back to the full [`ModelSnapshot::Knn`] on first contact or
    /// whenever the delta would not be smaller.
    KnnDelta {
        /// (k, FEAT_DIM) changed rows, newest first, row-major.
        rows: Vec<f32>,
        /// (k) per-row acquisition time, µs.
        times: Vec<u64>,
        /// Monotonic learned-example counter.
        learned: u64,
        /// Current anomaly threshold AS_TH.
        threshold: f32,
    },
    /// NN-k-means state: centroid weights plus the per-cluster update
    /// counts accumulated since the last merge (FedAvg-style count
    /// weighting), label votes and activation EMAs.
    Kmeans {
        /// (N_CLUSTERS, FEAT_DIM) weights, row-major.
        w: Vec<f32>,
        /// Per-cluster competitive updates since the last merge.
        counts: [u32; N_CLUSTERS],
        /// Per-cluster (normal, abnormal) label votes.
        votes: [[u32; 2]; N_CLUSTERS],
        /// Per-cluster winning-activation EMA.
        act_ema: [f32; N_CLUSTERS],
        /// Monotonic learned-example counter.
        learned: u64,
    },
}

impl ModelSnapshot {
    /// Wire size of the snapshot in bytes (what a radio would carry) —
    /// f32/u32 payloads at 4 B, u64 at 8 B, enum tag excluded.
    pub fn bytes(&self) -> usize {
        match self {
            ModelSnapshot::Knn {
                buf, mask, times, ..
            } => buf.len() * 4 + mask.len() * 4 + times.len() * 8 + 8 + 8 + 4,
            ModelSnapshot::KnnDelta { rows, times, .. } => {
                rows.len() * 4 + times.len() * 8 + 8 + 4
            }
            ModelSnapshot::Kmeans { w, .. } => {
                w.len() * 4 + N_CLUSTERS * 4 + N_CLUSTERS * 2 * 4 + N_CLUSTERS * 4 + 8
            }
        }
    }

    /// Wire size of the *full* snapshot this payload stands in for — what
    /// the radio would carry without delta compression. The sync price
    /// scales the calibrated `Tx` cost by `bytes() / full_bytes()`, so a
    /// full snapshot keeps the exact calibrated price.
    pub fn full_bytes(&self) -> usize {
        match self {
            ModelSnapshot::KnnDelta { .. } => {
                N_BUF * FEAT_DIM * 4 + N_BUF * 4 + N_BUF * 8 + 8 + 8 + 4
            }
            _ => self.bytes(),
        }
    }
}

/// One example: a feature vector plus bookkeeping. The ground-truth label
/// is carried for *evaluation only* — the unsupervised learners never read
/// it, the semi-supervised learner reads it only for the few bootstrap
/// labels the paper's cluster-then-label scheme assumes.
#[derive(Debug, Clone)]
pub struct Example {
    /// FEAT_DIM feature vector (output of `extract`).
    pub features: Vec<f32>,
    /// Acquisition time, µs.
    pub t_us: u64,
    /// Ground truth (evaluation only).
    pub truth_abnormal: bool,
}

impl Example {
    pub fn new(features: Vec<f32>, t_us: u64, truth_abnormal: bool) -> Self {
        Example {
            features,
            t_us,
            truth_abnormal,
        }
    }
}

/// Verdict of an inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Normal,
    Abnormal,
    /// The model cannot decide yet (e.g. not enough learned examples).
    Unknown,
}

impl Verdict {
    pub fn abnormal(self) -> bool {
        self == Verdict::Abnormal
    }
}

/// An online learner whose `learn`/`infer` payloads run on a backend.
pub trait Learner: Send {
    /// Incorporate one example (the `learn` action's payload).
    fn learn(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<()>;

    /// Classify one example (the `infer` action's payload).
    fn infer(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<Verdict>;

    /// Classify a cohort of examples against the *current* model in one
    /// call — the evaluation-probe path, where a whole probe set is
    /// scored at a checkpoint wake. Must return exactly what calling
    /// [`Learner::infer`] per example (in order) would; the default is
    /// that loop. Learners whose backends batch (k-NN via
    /// [`ComputeBackend::knn_infer_cohort`]) override it to amortize
    /// dispatch: one backend call per wake event instead of per example.
    fn infer_batch(
        &mut self,
        exs: &[&Example],
        be: &mut dyn ComputeBackend,
    ) -> Result<Vec<Verdict>> {
        exs.iter().map(|ex| self.infer(ex, be)).collect()
    }

    /// Prerequisites of `learn` (the `learnable` action): e.g. clustering
    /// needs a minimum number of examples.
    fn learnable(&self) -> bool;

    /// Re-assess model quality (the `evaluate` action's payload); returns
    /// a scalar quality indicator in [0, 1] the planner may consult.
    fn evaluate(&mut self, be: &mut dyn ComputeBackend) -> Result<f32>;

    /// Number of examples learned so far.
    fn learned_count(&self) -> u64;

    /// Full checkpoint of the model state to NVM (boot, restore points).
    /// `&mut self` so implementations can cache interned
    /// [`crate::nvm::KeyId`] handles and clear their dirty tracking.
    fn save(&mut self, nvm: &mut Nvm) -> Result<()>;

    /// Cheap steady-state checkpoint after one `learn`: write only what
    /// changed since the last save (O(dirty) NVM traffic instead of
    /// O(model)). Implementations must fall back to a full [`Learner::save`]
    /// whenever NVM does not hold their own last save — first boot, a
    /// foreign store, or an aborted (power-failed) save detected via a
    /// generation counter — so the committed NVM state is always a
    /// consistent snapshot. Default: a full save.
    fn save_delta(&mut self, nvm: &mut Nvm) -> Result<()> {
        self.save(nvm)
    }

    /// Restore model state from NVM (no-op if nothing saved).
    fn restore(&mut self, nvm: &mut Nvm) -> Result<()>;

    /// Snapshot the model for a fleet sync exchange, or `None` if this
    /// learner does not participate in federated merging (baselines).
    /// Taking a snapshot must not mutate observable model state.
    fn snapshot(&self) -> Option<ModelSnapshot> {
        None
    }

    /// Snapshot to *transmit* at a sync rendezvous. Learners that track
    /// what they last broadcast may return a delta
    /// ([`ModelSnapshot::KnnDelta`]) covering only the state written since
    /// — with a full-snapshot fallback on first contact or whenever the
    /// delta would not be smaller. Must describe the same model state as
    /// [`Learner::snapshot`]. Default: the full snapshot.
    fn snapshot_outgoing(&self) -> Option<ModelSnapshot> {
        self.snapshot()
    }

    /// The rendezvous committed: the payload from the last
    /// [`Learner::snapshot_outgoing`] was actually transmitted, so the
    /// next outgoing delta may be taken relative to it. Called only by
    /// [`crate::sim::engine::Engine::commit_sync`] — never for solo or
    /// skipped rounds, whose snapshots reached nobody.
    fn note_broadcast(&mut self) {}

    /// Fold peer snapshots into the local model at a sync boundary.
    /// `now_us` is the boundary instant and `expiry_us` the deployment's
    /// Mayfly data-expiration interval (peer examples older than it are
    /// discarded rather than adopted). Mismatched snapshot kinds are
    /// skipped, not errors — a heterogeneous fleet simply has nothing to
    /// merge across learner families. Implementations MUST leave their
    /// next [`Learner::save_delta`] equivalent to a full [`Learner::save`]
    /// (a merge rewrites state outside the dirty tracking). Returns `true`
    /// if any peer state was folded in. Default: merging unsupported.
    fn merge(
        &mut self,
        peers: &[&ModelSnapshot],
        be: &mut dyn ComputeBackend,
        now_us: u64,
        expiry_us: Option<u64>,
    ) -> Result<bool> {
        let _ = (peers, be, now_us, expiry_us);
        Ok(false)
    }

    fn name(&self) -> &'static str;
}
