//! On-device learners (the paper's "library of learning algorithms",
//! §3.1): the k-NN anomaly learner used by the air-quality and
//! human-presence apps (§6.1, §6.2) and the neural-network k-means
//! (competitive learning) cluster-then-label learner used by the
//! vibration app (§6.3).
//!
//! Learners hold their model state in plain vectors, dispatch all numeric
//! work through a [`crate::backend::ComputeBackend`], and can checkpoint
//! themselves to [`crate::nvm::Nvm`] so the model survives power failures.

pub mod kmeans_nn;
pub mod knn;

pub use kmeans_nn::ClusterLabelLearner;
pub use knn::KnnAnomalyLearner;

use crate::backend::ComputeBackend;
use crate::error::Result;
use crate::nvm::Nvm;

/// One example: a feature vector plus bookkeeping. The ground-truth label
/// is carried for *evaluation only* — the unsupervised learners never read
/// it, the semi-supervised learner reads it only for the few bootstrap
/// labels the paper's cluster-then-label scheme assumes.
#[derive(Debug, Clone)]
pub struct Example {
    /// FEAT_DIM feature vector (output of `extract`).
    pub features: Vec<f32>,
    /// Acquisition time, µs.
    pub t_us: u64,
    /// Ground truth (evaluation only).
    pub truth_abnormal: bool,
}

impl Example {
    pub fn new(features: Vec<f32>, t_us: u64, truth_abnormal: bool) -> Self {
        Example {
            features,
            t_us,
            truth_abnormal,
        }
    }
}

/// Verdict of an inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Normal,
    Abnormal,
    /// The model cannot decide yet (e.g. not enough learned examples).
    Unknown,
}

impl Verdict {
    pub fn abnormal(self) -> bool {
        self == Verdict::Abnormal
    }
}

/// An online learner whose `learn`/`infer` payloads run on a backend.
pub trait Learner: Send {
    /// Incorporate one example (the `learn` action's payload).
    fn learn(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<()>;

    /// Classify one example (the `infer` action's payload).
    fn infer(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<Verdict>;

    /// Prerequisites of `learn` (the `learnable` action): e.g. clustering
    /// needs a minimum number of examples.
    fn learnable(&self) -> bool;

    /// Re-assess model quality (the `evaluate` action's payload); returns
    /// a scalar quality indicator in [0, 1] the planner may consult.
    fn evaluate(&mut self, be: &mut dyn ComputeBackend) -> Result<f32>;

    /// Number of examples learned so far.
    fn learned_count(&self) -> u64;

    /// Full checkpoint of the model state to NVM (boot, restore points).
    /// `&mut self` so implementations can cache interned
    /// [`crate::nvm::KeyId`] handles and clear their dirty tracking.
    fn save(&mut self, nvm: &mut Nvm) -> Result<()>;

    /// Cheap steady-state checkpoint after one `learn`: write only what
    /// changed since the last save (O(dirty) NVM traffic instead of
    /// O(model)). Implementations must fall back to a full [`Learner::save`]
    /// whenever NVM does not hold their own last save — first boot, a
    /// foreign store, or an aborted (power-failed) save detected via a
    /// generation counter — so the committed NVM state is always a
    /// consistent snapshot. Default: a full save.
    fn save_delta(&mut self, nvm: &mut Nvm) -> Result<()> {
        self.save(nvm)
    }

    /// Restore model state from NVM (no-op if nothing saved).
    fn restore(&mut self, nvm: &mut Nvm) -> Result<()>;

    fn name(&self) -> &'static str;
}
