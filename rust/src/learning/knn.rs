//! k-NN anomaly learner (paper §6.1).
//!
//! Maintains a ring buffer of the most recent learned examples. The
//! `learn` payload recomputes every buffered example's anomaly score
//! AS_i = Σ_{j∈kNN(i)} d(e_i, e_j) and sets the detection threshold AS_TH
//! to the 90th percentile of the scores; `infer` computes the score of a
//! new example and classifies it abnormal iff AS_new > AS_TH. The
//! threshold evolves as new examples are learned — the paper's
//! "anomaly threshold AS_TH evolves over time".
//!
//! §Perf: checkpointing is two-speed. [`Learner::save`] writes the whole
//! model (boot / restore points); [`Learner::save_delta`] writes only the
//! ring slots overwritten since the last save plus the scalars — O(dirty)
//! NVM traffic per learn instead of O(model) — guarded by a generation
//! counter so an aborted (power-failed) save degrades to a full save, not
//! a corrupt delta.

use crate::backend::shapes::*;
use crate::backend::ComputeBackend;
use crate::error::Result;
use crate::learning::{Example, Learner, Verdict};
use crate::nvm::{KeyId, Nvm};

/// Interned NVM handles for the learner's keys (resolved once per store).
#[derive(Debug, Clone, Copy)]
struct KnnKeys {
    buf: KeyId,
    mask: KeyId,
    scalars: KeyId,
    learned: KeyId,
    gen: KeyId,
}

/// k-NN anomaly learner state (all state is NVM-checkpointable).
#[derive(Debug, Clone)]
pub struct KnnAnomalyLearner {
    /// Ring buffer, (N_BUF, FEAT_DIM) row-major.
    buf: Vec<f32>,
    /// Validity mask (1.0 = row holds a learned example).
    mask: Vec<f32>,
    /// Next ring slot to overwrite.
    next: usize,
    /// Learned-example counter (monotonic).
    learned: u64,
    /// Current anomaly threshold AS_TH.
    threshold: f32,
    /// Last `evaluate` quality indicator.
    quality: f32,
    /// Scratch for the backend's per-example scores (reused every learn).
    scores: Vec<f32>,
    /// Cached key handles for the store identified by the `u64`.
    keys: Option<(u64, KnnKeys)>,
    /// Ring slots overwritten since the last save (delta-checkpoint set).
    dirty_slots: Vec<usize>,
    /// Generation of this learner's last save (mirrors the NVM `knn/gen`
    /// counter; a mismatch means NVM lost a save — full save required).
    save_gen: u64,
}

impl Default for KnnAnomalyLearner {
    fn default() -> Self {
        Self::new()
    }
}

impl KnnAnomalyLearner {
    pub fn new() -> Self {
        KnnAnomalyLearner {
            buf: vec![0.0; N_BUF * FEAT_DIM],
            mask: vec![0.0; N_BUF],
            next: 0,
            learned: 0,
            threshold: 0.0,
            quality: 0.0,
            scores: vec![0.0; N_BUF],
            keys: None,
            dirty_slots: Vec::with_capacity(N_BUF),
            save_gen: 0,
        }
    }

    /// Current detection threshold.
    pub fn threshold(&self) -> f32 {
        self.threshold
    }

    /// Number of valid examples currently buffered.
    pub fn buffered(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.5).count()
    }

    /// Raw buffer access (benches / parity tests).
    pub fn buffer(&self) -> (&[f32], &[f32]) {
        (&self.buf, &self.mask)
    }

    /// Anomaly score of an example under the current model.
    pub fn score(&self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<f32> {
        be.knn_infer(&self.buf, &self.mask, &ex.features)
    }

    /// Key handles for `nvm`, interned once and re-resolved only when the
    /// learner meets a different store.
    fn keys(&mut self, nvm: &mut Nvm) -> KnnKeys {
        match self.keys {
            Some((sid, k)) if sid == nvm.store_id() => k,
            _ => {
                let k = KnnKeys {
                    buf: nvm.intern("knn/buf"),
                    mask: nvm.intern("knn/mask"),
                    scalars: nvm.intern("knn/scalars"),
                    learned: nvm.intern("knn/learned"),
                    gen: nvm.intern("knn/gen"),
                };
                self.keys = Some((nvm.store_id(), k));
                k
            }
        }
    }

    /// Write the non-buffer state — scalars, learned counter, generation
    /// guard — and clear the dirty set (shared by full and delta saves so
    /// the two checkpoint paths cannot drift).
    fn save_tail(&mut self, nvm: &mut Nvm, k: KnnKeys) -> Result<()> {
        nvm.write_f32s_id(k.scalars, &[self.next as f32, self.threshold, self.quality])?;
        nvm.write_u64_id(k.learned, self.learned)?;
        self.save_gen = self.save_gen.wrapping_add(1);
        nvm.write_u64_id(k.gen, self.save_gen)?;
        self.dirty_slots.clear();
        Ok(())
    }
}

impl Learner for KnnAnomalyLearner {
    fn learn(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<()> {
        debug_assert_eq!(ex.features.len(), FEAT_DIM);
        let slot = self.next;
        self.buf[slot * FEAT_DIM..(slot + 1) * FEAT_DIM].copy_from_slice(&ex.features);
        self.mask[slot] = 1.0;
        self.next = (self.next + 1) % N_BUF;
        self.learned += 1;
        if !self.dirty_slots.contains(&slot) {
            self.dirty_slots.push(slot);
        }
        self.threshold = be.knn_learn(&self.buf, &self.mask, &mut self.scores)?;
        Ok(())
    }

    fn infer(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<Verdict> {
        if self.buffered() <= K_NEIGHBORS || self.threshold <= 0.0 {
            return Ok(Verdict::Unknown);
        }
        let s = be.knn_infer(&self.buf, &self.mask, &ex.features)?;
        Ok(if s > self.threshold {
            Verdict::Abnormal
        } else {
            Verdict::Normal
        })
    }

    fn learnable(&self) -> bool {
        // k-NN can always absorb an example (ring overwrite); the paper's
        // precondition is about having a sensed example available, which
        // the engine enforces. A model-level precondition: buffer space or
        // ring age — always true here.
        true
    }

    fn evaluate(&mut self, be: &mut dyn ComputeBackend) -> Result<f32> {
        // Quality: fraction of buffered examples whose score is below the
        // threshold (how well the normal envelope fits). 0 when untrained.
        if self.buffered() <= K_NEIGHBORS {
            self.quality = 0.0;
            return Ok(0.0);
        }
        self.threshold = be.knn_learn(&self.buf, &self.mask, &mut self.scores)?;
        let thr = self.threshold;
        let n = self.buffered();
        let ok = (0..N_BUF)
            .filter(|&i| self.mask[i] > 0.5 && self.scores[i] <= thr)
            .count();
        self.quality = ok as f32 / n as f32;
        Ok(self.quality)
    }

    fn learned_count(&self) -> u64 {
        self.learned
    }

    fn save(&mut self, nvm: &mut Nvm) -> Result<()> {
        let k = self.keys(nvm);
        nvm.write_f32s_id(k.buf, &self.buf)?;
        nvm.write_f32s_id(k.mask, &self.mask)?;
        self.save_tail(nvm, k)
    }

    fn save_delta(&mut self, nvm: &mut Nvm) -> Result<()> {
        let k = self.keys(nvm);
        // Delta saves assume NVM holds this learner's previous save; if it
        // does not (first boot, foreign store, or an aborted save left the
        // generation behind), fall back to the full checkpoint.
        let fresh = self.save_gen != 0
            && nvm.read_u64_id(k.gen) == self.save_gen
            && nvm.value_len(k.buf) == Some(N_BUF * FEAT_DIM * 4);
        if !fresh {
            return self.save(nvm);
        }
        for &s in &self.dirty_slots {
            let row = &self.buf[s * FEAT_DIM..(s + 1) * FEAT_DIM];
            nvm.write_f32s_at(k.buf, s * FEAT_DIM, row)?;
            nvm.write_f32s_at(k.mask, s, &self.mask[s..s + 1])?;
        }
        self.save_tail(nvm, k)
    }

    fn restore(&mut self, nvm: &mut Nvm) -> Result<()> {
        let k = self.keys(nvm);
        nvm.read_f32s_into(k.buf, &mut self.buf);
        nvm.read_f32s_into(k.mask, &mut self.mask);
        let mut s = [0.0f32; 3];
        if nvm.read_f32s_into(k.scalars, &mut s) {
            self.next = (s[0] as usize) % N_BUF;
            self.threshold = s[1];
            self.quality = s[2];
        }
        self.learned = nvm.read_u64_id(k.learned);
        self.save_gen = nvm.read_u64_id(k.gen);
        self.dirty_slots.clear();
        Ok(())
    }

    fn name(&self) -> &'static str {
        "knn_anomaly"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::util::Rng;

    fn normal_ex(rng: &mut Rng, t: u64) -> Example {
        Example::new(
            (0..FEAT_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect(),
            t,
            false,
        )
    }

    #[test]
    fn detects_far_outlier_after_learning() {
        let mut be = NativeBackend::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(1);
        for t in 0..30 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        assert!(l.threshold() > 0.0);
        let outlier = Example::new(vec![40.0; FEAT_DIM], 99, true);
        assert_eq!(l.infer(&outlier, &mut be).unwrap(), Verdict::Abnormal);
        let typical = normal_ex(&mut rng, 100);
        // most typical points are below the 90th percentile threshold
        let mut normals = 0;
        for _ in 0..20 {
            if l.infer(&normal_ex(&mut rng, 0), &mut be).unwrap() == Verdict::Normal {
                normals += 1;
            }
        }
        assert!(normals >= 14, "normals {normals}");
        let _ = typical;
    }

    #[test]
    fn unknown_before_enough_examples() {
        let mut be = NativeBackend::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(2);
        let ex = normal_ex(&mut rng, 0);
        assert_eq!(l.infer(&ex, &mut be).unwrap(), Verdict::Unknown);
        for t in 0..3 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        assert_eq!(l.infer(&ex, &mut be).unwrap(), Verdict::Unknown);
    }

    #[test]
    fn ring_buffer_wraps() {
        let mut be = NativeBackend::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(3);
        for t in 0..(N_BUF as u64 + 10) {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        assert_eq!(l.buffered(), N_BUF);
        assert_eq!(l.learned_count(), N_BUF as u64 + 10);
    }

    #[test]
    fn save_restore_round_trip() {
        let mut be = NativeBackend::new();
        let mut nvm = Nvm::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(4);
        for t in 0..10 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        l.save(&mut nvm).unwrap();
        let mut l2 = KnnAnomalyLearner::new();
        l2.restore(&mut nvm).unwrap();
        assert_eq!(l2.learned_count(), 10);
        assert_eq!(l2.threshold(), l.threshold());
        let ex = normal_ex(&mut rng, 99);
        assert_eq!(
            l.infer(&ex, &mut be).unwrap(),
            l2.infer(&ex, &mut be).unwrap()
        );
    }

    #[test]
    fn delta_save_restores_bit_identically() {
        let mut be = NativeBackend::new();
        let mut nvm = Nvm::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(6);
        for t in 0..(N_BUF as u64 + 20) {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
            l.save_delta(&mut nvm).unwrap();
        }
        let mut l2 = KnnAnomalyLearner::new();
        l2.restore(&mut nvm).unwrap();
        assert_eq!(l2.buffer().0, l.buffer().0);
        assert_eq!(l2.buffer().1, l.buffer().1);
        assert_eq!(l2.threshold(), l.threshold());
        assert_eq!(l2.learned_count(), l.learned_count());
    }

    #[test]
    fn delta_save_writes_o_dirty_not_o_model() {
        let mut be = NativeBackend::new();
        let mut nvm = Nvm::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(7);
        l.learn(&normal_ex(&mut rng, 0), &mut be).unwrap();
        l.save_delta(&mut nvm).unwrap(); // first save is a full save
        let full = nvm.bytes_written;
        l.learn(&normal_ex(&mut rng, 1), &mut be).unwrap();
        l.save_delta(&mut nvm).unwrap(); // steady state: one dirty row
        let delta = nvm.bytes_written - full;
        assert!(
            delta as usize * 5 <= full as usize,
            "delta {delta} B vs full {full} B"
        );
        // one f32 row + one mask slot + scalars + learned + gen
        assert_eq!(delta as usize, FEAT_DIM * 4 + 4 + 12 + 8 + 8);
    }

    #[test]
    fn evaluate_reports_fit_quality() {
        let mut be = NativeBackend::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(5);
        assert_eq!(l.evaluate(&mut be).unwrap(), 0.0);
        for t in 0..20 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        let q = l.evaluate(&mut be).unwrap();
        assert!((0.8..=1.0).contains(&q), "q {q}");
    }
}
