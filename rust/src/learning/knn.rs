//! k-NN anomaly learner (paper §6.1).
//!
//! Maintains a ring buffer of the most recent learned examples. The
//! `learn` payload recomputes every buffered example's anomaly score
//! AS_i = Σ_{j∈kNN(i)} d(e_i, e_j) and sets the detection threshold AS_TH
//! to the 90th percentile of the scores; `infer` computes the score of a
//! new example and classifies it abnormal iff AS_new > AS_TH. The
//! threshold evolves as new examples are learned — the paper's
//! "anomaly threshold AS_TH evolves over time".
//!
//! §Perf: checkpointing is two-speed. [`Learner::save`] writes the whole
//! model (boot / restore points); [`Learner::save_delta`] writes only the
//! ring slots overwritten since the last save plus the scalars — O(dirty)
//! NVM traffic per learn instead of O(model) — guarded by a generation
//! counter so an aborted (power-failed) save degrades to a full save, not
//! a corrupt delta.

use crate::backend::shapes::*;
use crate::backend::ComputeBackend;
use crate::error::Result;
use crate::learning::{Example, Learner, ModelSnapshot, Verdict};
use crate::nvm::{KeyId, Nvm};

/// Interned NVM handles for the learner's keys (resolved once per store).
#[derive(Debug, Clone, Copy)]
struct KnnKeys {
    buf: KeyId,
    mask: KeyId,
    times: KeyId,
    scalars: KeyId,
    learned: KeyId,
    gen: KeyId,
}

/// k-NN anomaly learner state (all state is NVM-checkpointable).
#[derive(Debug, Clone)]
pub struct KnnAnomalyLearner {
    /// Ring buffer, (N_BUF, FEAT_DIM) row-major.
    buf: Vec<f32>,
    /// Validity mask (1.0 = row holds a learned example).
    mask: Vec<f32>,
    /// Per-slot acquisition time, µs (recency for the fleet ring merge +
    /// Mayfly expiry of adopted peer examples).
    times: Vec<u64>,
    /// Next ring slot to overwrite.
    next: usize,
    /// Learned-example counter (monotonic).
    learned: u64,
    /// Current anomaly threshold AS_TH.
    threshold: f32,
    /// Last `evaluate` quality indicator.
    quality: f32,
    /// Scratch for the backend's per-example scores (reused every learn).
    scores: Vec<f32>,
    /// Cached key handles for the store identified by the `u64`.
    keys: Option<(u64, KnnKeys)>,
    /// Ring slots overwritten since the last save (delta-checkpoint set).
    dirty_slots: Vec<usize>,
    /// Generation of this learner's last save (mirrors the NVM `knn/gen`
    /// counter; a mismatch means NVM lost a save — full save required).
    save_gen: u64,
    /// Model generation: bumped on every `learn` and every `merge`. The
    /// wire-delta analog of `save_gen` — it orders ring writes so an
    /// outgoing snapshot can carry only the rows written since the last
    /// committed broadcast.
    model_gen: u64,
    /// Per-slot model generation of the row currently in the slot. Rows a
    /// merge adopts from peers are stamped with the merge's generation
    /// (they are news to *this* shard's next partner); rows the merge
    /// keeps from the local ring carry their generation through the slot
    /// move.
    slot_gens: Vec<u64>,
    /// `model_gen` at the last *committed* broadcast
    /// ([`Learner::note_broadcast`]); `None` until first contact, which
    /// forces the full-snapshot fallback.
    last_broadcast_gen: Option<u64>,
}

impl Default for KnnAnomalyLearner {
    fn default() -> Self {
        Self::new()
    }
}

impl KnnAnomalyLearner {
    pub fn new() -> Self {
        KnnAnomalyLearner {
            buf: vec![0.0; N_BUF * FEAT_DIM],
            mask: vec![0.0; N_BUF],
            times: vec![0; N_BUF],
            next: 0,
            learned: 0,
            threshold: 0.0,
            quality: 0.0,
            scores: vec![0.0; N_BUF],
            keys: None,
            dirty_slots: Vec::with_capacity(N_BUF),
            save_gen: 0,
            model_gen: 0,
            slot_gens: vec![0; N_BUF],
            last_broadcast_gen: None,
        }
    }

    /// Current detection threshold.
    pub fn threshold(&self) -> f32 {
        self.threshold
    }

    /// Number of valid examples currently buffered.
    pub fn buffered(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.5).count()
    }

    /// Raw buffer access (benches / parity tests).
    pub fn buffer(&self) -> (&[f32], &[f32]) {
        (&self.buf, &self.mask)
    }

    /// Anomaly score of an example under the current model.
    pub fn score(&self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<f32> {
        be.knn_infer(&self.buf, &self.mask, &ex.features)
    }

    /// Key handles for `nvm`, interned once and re-resolved only when the
    /// learner meets a different store.
    fn keys(&mut self, nvm: &mut Nvm) -> KnnKeys {
        match self.keys {
            Some((sid, k)) if sid == nvm.store_id() => k,
            _ => {
                let k = KnnKeys {
                    buf: nvm.intern("knn/buf"),
                    mask: nvm.intern("knn/mask"),
                    times: nvm.intern("knn/times"),
                    scalars: nvm.intern("knn/scalars"),
                    learned: nvm.intern("knn/learned"),
                    gen: nvm.intern("knn/gen"),
                };
                self.keys = Some((nvm.store_id(), k));
                k
            }
        }
    }

    /// Write the non-buffer state — scalars, learned counter, generation
    /// guard — and clear the dirty set (shared by full and delta saves so
    /// the two checkpoint paths cannot drift).
    fn save_tail(&mut self, nvm: &mut Nvm, k: KnnKeys) -> Result<()> {
        nvm.write_f32s_id(k.scalars, &[self.next as f32, self.threshold, self.quality])?;
        nvm.write_u64_id(k.learned, self.learned)?;
        self.save_gen = self.save_gen.wrapping_add(1);
        nvm.write_u64_id(k.gen, self.save_gen)?;
        self.dirty_slots.clear();
        Ok(())
    }
}

impl Learner for KnnAnomalyLearner {
    fn learn(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<()> {
        debug_assert_eq!(ex.features.len(), FEAT_DIM);
        let slot = self.next;
        self.buf[slot * FEAT_DIM..(slot + 1) * FEAT_DIM].copy_from_slice(&ex.features);
        self.mask[slot] = 1.0;
        self.times[slot] = ex.t_us;
        self.next = (self.next + 1) % N_BUF;
        self.learned += 1;
        self.model_gen += 1;
        self.slot_gens[slot] = self.model_gen;
        if !self.dirty_slots.contains(&slot) {
            self.dirty_slots.push(slot);
        }
        self.threshold = be.knn_learn(&self.buf, &self.mask, &mut self.scores)?;
        Ok(())
    }

    fn infer(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<Verdict> {
        if self.buffered() <= K_NEIGHBORS || self.threshold <= 0.0 {
            return Ok(Verdict::Unknown);
        }
        let s = be.knn_infer(&self.buf, &self.mask, &ex.features)?;
        Ok(if s > self.threshold {
            Verdict::Abnormal
        } else {
            Verdict::Normal
        })
    }

    fn infer_batch(
        &mut self,
        exs: &[&Example],
        be: &mut dyn ComputeBackend,
    ) -> Result<Vec<Verdict>> {
        // `infer` never mutates the model, so its gate is loop-invariant:
        // check it once, then score the whole cohort in one backend call.
        // Bit-identical to the per-example loop — the native cohort is
        // that loop, the pjrt cohort rides the BATCH artifact.
        if self.buffered() <= K_NEIGHBORS || self.threshold <= 0.0 {
            return Ok(vec![Verdict::Unknown; exs.len()]);
        }
        let mut queries = Vec::with_capacity(exs.len() * FEAT_DIM);
        for ex in exs {
            queries.extend_from_slice(&ex.features);
        }
        let mut scores = vec![0.0f32; exs.len()];
        be.knn_infer_cohort(&self.buf, &self.mask, &queries, &mut scores)?;
        Ok(scores
            .iter()
            .map(|&s| {
                if s > self.threshold {
                    Verdict::Abnormal
                } else {
                    Verdict::Normal
                }
            })
            .collect())
    }

    fn learnable(&self) -> bool {
        // k-NN can always absorb an example (ring overwrite); the paper's
        // precondition is about having a sensed example available, which
        // the engine enforces. A model-level precondition: buffer space or
        // ring age — always true here.
        true
    }

    fn evaluate(&mut self, be: &mut dyn ComputeBackend) -> Result<f32> {
        // Quality: fraction of buffered examples whose score is below the
        // threshold (how well the normal envelope fits). 0 when untrained.
        if self.buffered() <= K_NEIGHBORS {
            self.quality = 0.0;
            return Ok(0.0);
        }
        self.threshold = be.knn_learn(&self.buf, &self.mask, &mut self.scores)?;
        let thr = self.threshold;
        let n = self.buffered();
        let ok = (0..N_BUF)
            .filter(|&i| self.mask[i] > 0.5 && self.scores[i] <= thr)
            .count();
        self.quality = ok as f32 / n as f32;
        Ok(self.quality)
    }

    fn learned_count(&self) -> u64 {
        self.learned
    }

    fn save(&mut self, nvm: &mut Nvm) -> Result<()> {
        let k = self.keys(nvm);
        nvm.write_f32s_id(k.buf, &self.buf)?;
        nvm.write_f32s_id(k.mask, &self.mask)?;
        let mut tb = Vec::with_capacity(N_BUF * 8);
        for &t in &self.times {
            tb.extend_from_slice(&t.to_le_bytes());
        }
        nvm.write_id(k.times, &tb)?;
        self.save_tail(nvm, k)
    }

    fn save_delta(&mut self, nvm: &mut Nvm) -> Result<()> {
        let k = self.keys(nvm);
        // Delta saves assume NVM holds this learner's previous save; if it
        // does not (first boot, foreign store, or an aborted save left the
        // generation behind), fall back to the full checkpoint.
        let fresh = self.save_gen != 0
            && nvm.read_u64_id(k.gen) == self.save_gen
            && nvm.value_len(k.buf) == Some(N_BUF * FEAT_DIM * 4)
            && nvm.value_len(k.times) == Some(N_BUF * 8);
        if !fresh {
            return self.save(nvm);
        }
        for &s in &self.dirty_slots {
            let row = &self.buf[s * FEAT_DIM..(s + 1) * FEAT_DIM];
            nvm.write_f32s_at(k.buf, s * FEAT_DIM, row)?;
            nvm.write_f32s_at(k.mask, s, &self.mask[s..s + 1])?;
            nvm.write_at(k.times, s * 8, &self.times[s].to_le_bytes())?;
        }
        self.save_tail(nvm, k)
    }

    fn restore(&mut self, nvm: &mut Nvm) -> Result<()> {
        let k = self.keys(nvm);
        nvm.read_f32s_into(k.buf, &mut self.buf);
        nvm.read_f32s_into(k.mask, &mut self.mask);
        if let Some(tb) = nvm.read_id(k.times) {
            if tb.len() == N_BUF * 8 {
                for (i, c) in tb.chunks_exact(8).enumerate() {
                    self.times[i] = u64::from_le_bytes(c.try_into().expect("8-byte chunk"));
                }
            }
        }
        let mut s = [0.0f32; 3];
        if nvm.read_f32s_into(k.scalars, &mut s) {
            self.next = (s[0] as usize) % N_BUF;
            self.threshold = s[1];
            self.quality = s[2];
        }
        self.learned = nvm.read_u64_id(k.learned);
        self.save_gen = nvm.read_u64_id(k.gen);
        self.dirty_slots.clear();
        // broadcast tracking is not persisted: after a restore the next
        // outgoing snapshot falls back to full, exactly like first contact
        self.last_broadcast_gen = None;
        Ok(())
    }

    fn snapshot(&self) -> Option<ModelSnapshot> {
        Some(ModelSnapshot::Knn {
            buf: self.buf.clone(),
            mask: self.mask.clone(),
            times: self.times.clone(),
            next: self.next,
            learned: self.learned,
            threshold: self.threshold,
        })
    }

    /// Recency-weighted ring merge: pool the local ring with every peer
    /// ring, drop peer examples that Mayfly expiry would have discarded
    /// (`t + expiry <= now`, mirroring [`crate::sim::expire_stale`]) and
    /// exact duplicates (gossip re-circulates examples), keep the N_BUF
    /// most recent, and rebuild the ring oldest→newest so subsequent
    /// learns overwrite the oldest adopted state first. The threshold is
    /// recomputed over the merged buffer (it "evolves over time", §6.1 —
    /// now also over the fleet).
    fn merge(
        &mut self,
        peers: &[&ModelSnapshot],
        be: &mut dyn ComputeBackend,
        now_us: u64,
        expiry_us: Option<u64>,
    ) -> Result<bool> {
        // candidate = (t, source rank, age rank within source, borrowed
        // feature row, model generation); self is source 0, peers follow
        // in caller order — fully deterministic. Rows adopted from peers
        // are stamped with this merge's generation (`adopt_gen`) so the
        // next outgoing wire delta forwards them; local rows keep their
        // generation through any slot move.
        struct Cand<'a> {
            t: u64,
            src: usize,
            age: usize,
            row: &'a [f32],
            gen: u64,
        }
        /// Push one ring's valid entries, walking backwards from the
        /// cursor so age 0 is the most recently written slot. `expiry`
        /// (`Some` only for adopted peer data — Mayfly discards stale
        /// *sensor data*, not local models) drops entries with
        /// `t + expiry <= now`. `gens` carries per-slot generations for
        /// the local ring; peer rings stamp every row `adopt_gen`.
        #[allow(clippy::too_many_arguments)]
        fn push_ring<'a>(
            cands: &mut Vec<Cand<'a>>,
            src: usize,
            buf: &'a [f32],
            mask: &'a [f32],
            times: &'a [u64],
            next: usize,
            now_us: u64,
            expiry: Option<u64>,
            gens: Option<&'a [u64]>,
            adopt_gen: u64,
        ) {
            for age in 0..N_BUF {
                let slot = (next + N_BUF - 1 - age) % N_BUF;
                if mask[slot] <= 0.5 {
                    continue;
                }
                let t = times[slot];
                if let Some(e) = expiry {
                    if t.saturating_add(e) <= now_us {
                        continue;
                    }
                }
                cands.push(Cand {
                    t,
                    src,
                    age,
                    row: &buf[slot * FEAT_DIM..(slot + 1) * FEAT_DIM],
                    gen: gens.map_or(adopt_gen, |g| g[slot]),
                });
            }
        }
        let adopt_gen = self.model_gen + 1;
        let mut cands: Vec<Cand> = Vec::new();
        push_ring(
            &mut cands,
            0,
            &self.buf,
            &self.mask,
            &self.times,
            self.next,
            now_us,
            None,
            Some(&self.slot_gens),
            adopt_gen,
        );
        let mut merged_learned = self.learned;
        let mut any_peer = false;
        for (i, p) in peers.iter().enumerate() {
            match p {
                ModelSnapshot::Knn {
                    buf,
                    mask,
                    times,
                    next,
                    learned,
                    ..
                } => {
                    any_peer = true;
                    merged_learned = merged_learned.max(*learned);
                    push_ring(
                        &mut cands, i + 1, buf, mask, times, *next, now_us, expiry_us, None,
                        adopt_gen,
                    );
                }
                // wire delta: rows arrive newest first, so the position
                // within the payload is the in-source age rank
                ModelSnapshot::KnnDelta {
                    rows,
                    times,
                    learned,
                    ..
                } => {
                    any_peer = true;
                    merged_learned = merged_learned.max(*learned);
                    for (age, (row, &t)) in
                        rows.chunks_exact(FEAT_DIM).zip(times.iter()).enumerate()
                    {
                        if let Some(e) = expiry_us {
                            if t.saturating_add(e) <= now_us {
                                continue;
                            }
                        }
                        cands.push(Cand {
                            t,
                            src: i + 1,
                            age,
                            row,
                            gen: adopt_gen,
                        });
                    }
                }
                ModelSnapshot::Kmeans { .. } => {}
            }
        }
        if !any_peer {
            return Ok(false);
        }
        // recency-weighted: newest first; ties broken by source order then
        // in-source age so the result is identical on every shard
        cands.sort_by(|a, b| {
            b.t.cmp(&a.t)
                .then(a.src.cmp(&b.src))
                .then(a.age.cmp(&b.age))
        });
        // capacity + dedup: gossip re-circulates adopted examples, so an
        // entry equal (time and feature bits) to an already-kept one is
        // the same example coming back around
        let mut kept: Vec<&Cand> = Vec::with_capacity(N_BUF);
        for c in &cands {
            if kept.len() >= N_BUF {
                break;
            }
            if kept.iter().any(|k| k.t == c.t && k.row == c.row) {
                continue;
            }
            kept.push(c);
        }
        // rebuild oldest→newest so the ring cursor overwrites oldest first
        let mut buf = vec![0.0f32; N_BUF * FEAT_DIM];
        let mut mask = vec![0.0f32; N_BUF];
        let mut times = vec![0u64; N_BUF];
        let mut gens = vec![0u64; N_BUF];
        for (slot, c) in kept.iter().rev().enumerate() {
            buf[slot * FEAT_DIM..(slot + 1) * FEAT_DIM].copy_from_slice(c.row);
            mask[slot] = 1.0;
            times[slot] = c.t;
            gens[slot] = c.gen;
        }
        let kept_len = kept.len();
        drop(kept);
        drop(cands);
        self.next = kept_len % N_BUF;
        self.buf = buf;
        self.mask = mask;
        self.times = times;
        self.slot_gens = gens;
        self.model_gen = adopt_gen;
        self.learned = merged_learned;
        self.threshold = be.knn_learn(&self.buf, &self.mask, &mut self.scores)?;
        // the whole model changed: dirty tracking is void, the next
        // save_delta must degrade to a full save
        self.dirty_slots.clear();
        self.save_gen = 0;
        Ok(true)
    }

    /// Wire delta: the ring rows written (learned or adopted) since the
    /// last committed broadcast, walked newest first so the receiver's
    /// in-payload position is the recency rank. Falls back to the full
    /// snapshot on first contact, after a restore, or whenever the delta
    /// would not beat the full payload.
    fn snapshot_outgoing(&self) -> Option<ModelSnapshot> {
        let base = match self.last_broadcast_gen {
            Some(g) => g,
            None => return self.snapshot(),
        };
        let mut rows = Vec::new();
        let mut times = Vec::new();
        for age in 0..N_BUF {
            let slot = (self.next + N_BUF - 1 - age) % N_BUF;
            if self.mask[slot] <= 0.5 || self.slot_gens[slot] <= base {
                continue;
            }
            rows.extend_from_slice(&self.buf[slot * FEAT_DIM..(slot + 1) * FEAT_DIM]);
            times.push(self.times[slot]);
        }
        let delta = ModelSnapshot::KnnDelta {
            rows,
            times,
            learned: self.learned,
            threshold: self.threshold,
        };
        if delta.bytes() >= delta.full_bytes() {
            return self.snapshot();
        }
        Some(delta)
    }

    fn note_broadcast(&mut self) {
        self.last_broadcast_gen = Some(self.model_gen);
    }

    fn name(&self) -> &'static str {
        "knn_anomaly"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::util::Rng;

    fn normal_ex(rng: &mut Rng, t: u64) -> Example {
        Example::new(
            (0..FEAT_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect(),
            t,
            false,
        )
    }

    #[test]
    fn infer_batch_matches_per_example_infer_bit_for_bit() {
        let mut be = NativeBackend::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(21);
        let probes: Vec<Example> = (0..13).map(|t| normal_ex(&mut rng, 1000 + t)).collect();
        let refs: Vec<&Example> = probes.iter().collect();
        // Ungated model (nothing learned): whole cohort is Unknown.
        assert_eq!(
            l.infer_batch(&refs, &mut be).unwrap(),
            vec![Verdict::Unknown; 13]
        );
        for t in 0..30 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        let batch = l.infer_batch(&refs, &mut be).unwrap();
        for (i, p) in probes.iter().enumerate() {
            assert_eq!(batch[i], l.infer(p, &mut be).unwrap(), "probe {i}");
        }
    }

    #[test]
    fn detects_far_outlier_after_learning() {
        let mut be = NativeBackend::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(1);
        for t in 0..30 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        assert!(l.threshold() > 0.0);
        let outlier = Example::new(vec![40.0; FEAT_DIM], 99, true);
        assert_eq!(l.infer(&outlier, &mut be).unwrap(), Verdict::Abnormal);
        let typical = normal_ex(&mut rng, 100);
        // most typical points are below the 90th percentile threshold
        let mut normals = 0;
        for _ in 0..20 {
            if l.infer(&normal_ex(&mut rng, 0), &mut be).unwrap() == Verdict::Normal {
                normals += 1;
            }
        }
        assert!(normals >= 14, "normals {normals}");
        let _ = typical;
    }

    #[test]
    fn unknown_before_enough_examples() {
        let mut be = NativeBackend::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(2);
        let ex = normal_ex(&mut rng, 0);
        assert_eq!(l.infer(&ex, &mut be).unwrap(), Verdict::Unknown);
        for t in 0..3 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        assert_eq!(l.infer(&ex, &mut be).unwrap(), Verdict::Unknown);
    }

    #[test]
    fn ring_buffer_wraps() {
        let mut be = NativeBackend::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(3);
        for t in 0..(N_BUF as u64 + 10) {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        assert_eq!(l.buffered(), N_BUF);
        assert_eq!(l.learned_count(), N_BUF as u64 + 10);
    }

    #[test]
    fn save_restore_round_trip() {
        let mut be = NativeBackend::new();
        let mut nvm = Nvm::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(4);
        for t in 0..10 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        l.save(&mut nvm).unwrap();
        let mut l2 = KnnAnomalyLearner::new();
        l2.restore(&mut nvm).unwrap();
        assert_eq!(l2.learned_count(), 10);
        assert_eq!(l2.threshold(), l.threshold());
        let ex = normal_ex(&mut rng, 99);
        assert_eq!(
            l.infer(&ex, &mut be).unwrap(),
            l2.infer(&ex, &mut be).unwrap()
        );
    }

    #[test]
    fn delta_save_restores_bit_identically() {
        let mut be = NativeBackend::new();
        let mut nvm = Nvm::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(6);
        for t in 0..(N_BUF as u64 + 20) {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
            l.save_delta(&mut nvm).unwrap();
        }
        let mut l2 = KnnAnomalyLearner::new();
        l2.restore(&mut nvm).unwrap();
        assert_eq!(l2.buffer().0, l.buffer().0);
        assert_eq!(l2.buffer().1, l.buffer().1);
        assert_eq!(l2.threshold(), l.threshold());
        assert_eq!(l2.learned_count(), l.learned_count());
    }

    #[test]
    fn delta_save_writes_o_dirty_not_o_model() {
        let mut be = NativeBackend::new();
        let mut nvm = Nvm::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(7);
        l.learn(&normal_ex(&mut rng, 0), &mut be).unwrap();
        l.save_delta(&mut nvm).unwrap(); // first save is a full save
        let full = nvm.bytes_written;
        l.learn(&normal_ex(&mut rng, 1), &mut be).unwrap();
        l.save_delta(&mut nvm).unwrap(); // steady state: one dirty row
        let delta = nvm.bytes_written - full;
        assert!(
            delta as usize * 5 <= full as usize,
            "delta {delta} B vs full {full} B"
        );
        // one f32 row + one mask slot + one time slot + scalars + learned + gen
        assert_eq!(delta as usize, FEAT_DIM * 4 + 4 + 8 + 12 + 8 + 8);
    }

    #[test]
    fn merge_adopts_peer_ring_by_recency() {
        let mut be = NativeBackend::new();
        let mut rng = Rng::new(8);
        // a trained donor with timestamps 100..130
        let mut donor = KnnAnomalyLearner::new();
        for t in 0..30 {
            donor.learn(&normal_ex(&mut rng, 100 + t), &mut be).unwrap();
        }
        let snap = donor.snapshot().expect("knn snapshots");
        // a cold shard adopts the whole donor ring
        let mut cold = KnnAnomalyLearner::new();
        assert!(cold.merge(&[&snap], &mut be, 1_000, None).unwrap());
        assert_eq!(cold.buffered(), 30);
        assert_eq!(cold.learned_count(), 30);
        assert!(cold.threshold() > 0.0);
        // merged verdicts match the donor's (same buffered set)
        let probe = normal_ex(&mut rng, 999);
        assert_eq!(
            cold.infer(&probe, &mut be).unwrap(),
            donor.infer(&probe, &mut be).unwrap()
        );
        // re-merging the same snapshot is a no-growth fixpoint (dedup)
        let again = cold.snapshot().unwrap();
        assert!(cold.merge(&[&snap, &again], &mut be, 1_000, None).unwrap());
        assert_eq!(cold.buffered(), 30, "duplicates inflated the ring");
        // an empty peer list is a no-op
        assert!(!cold.merge(&[], &mut be, 1_000, None).unwrap());
    }

    #[test]
    fn merge_respects_capacity_and_prefers_recent_examples() {
        let mut be = NativeBackend::new();
        let mut rng = Rng::new(9);
        let mut old = KnnAnomalyLearner::new();
        let mut new = KnnAnomalyLearner::new();
        for i in 0..N_BUF as u64 {
            old.learn(&normal_ex(&mut rng, 1_000 + i), &mut be).unwrap();
            new.learn(&normal_ex(&mut rng, 9_000 + i), &mut be).unwrap();
        }
        let newer = new.snapshot().unwrap();
        assert!(old.merge(&[&newer], &mut be, 20_000, None).unwrap());
        // two full rings compete for N_BUF slots: only the newest survive,
        // which is exactly the peer's ring here
        assert_eq!(old.buffered(), N_BUF);
        assert_eq!(old.buffer().0, new.buffer().0);
    }

    #[test]
    fn merge_expires_stale_peer_examples_mayfly_style() {
        let mut be = NativeBackend::new();
        let mut rng = Rng::new(10);
        let mut donor = KnnAnomalyLearner::new();
        for t in 0..20 {
            donor.learn(&normal_ex(&mut rng, t), &mut be).unwrap(); // t = 0..20 µs
        }
        let snap = donor.snapshot().unwrap();
        let mut cold = KnnAnomalyLearner::new();
        // expiry 50 µs at now = 1000 µs: every donor example is stale
        assert!(cold.merge(&[&snap], &mut be, 1_000, Some(50)).unwrap());
        assert_eq!(cold.buffered(), 0, "stale peer examples were adopted");
        // same merge with a lenient expiry adopts them all (boundary is
        // strict, matching sim::expire_stale)
        assert!(cold.merge(&[&snap], &mut be, 1_000, Some(2_000)).unwrap());
        assert_eq!(cold.buffered(), 20);
    }

    #[test]
    fn merge_forces_the_next_delta_save_to_be_full() {
        let mut be = NativeBackend::new();
        let mut nvm = Nvm::new();
        let mut rng = Rng::new(12);
        let mut l = KnnAnomalyLearner::new();
        for t in 0..10 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
            l.save_delta(&mut nvm).unwrap();
        }
        let mut donor = KnnAnomalyLearner::new();
        for t in 0..5 {
            donor.learn(&normal_ex(&mut rng, 100 + t), &mut be).unwrap();
        }
        let dsnap = donor.snapshot().unwrap();
        l.merge(&[&dsnap], &mut be, 1_000, None).unwrap();
        // the next delta save must rewrite the whole model, not the (now
        // void) dirty set
        let before = nvm.bytes_written;
        l.save_delta(&mut nvm).unwrap();
        let wrote = (nvm.bytes_written - before) as usize;
        assert_eq!(wrote, N_BUF * FEAT_DIM * 4 + N_BUF * 4 + N_BUF * 8 + 12 + 8 + 8);
        // and a restore after it reproduces the merged model bit for bit
        let mut back = KnnAnomalyLearner::new();
        back.restore(&mut nvm).unwrap();
        assert_eq!(back.buffer().0, l.buffer().0);
        assert_eq!(back.buffer().1, l.buffer().1);
        assert_eq!(back.threshold(), l.threshold());
        assert_eq!(back.learned_count(), l.learned_count());
    }

    #[test]
    fn first_broadcast_is_full_then_deltas_carry_only_new_rows() {
        let mut be = NativeBackend::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(14);
        for t in 0..5 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        // first contact: full snapshot
        let first = l.snapshot_outgoing().unwrap();
        assert!(matches!(&first, ModelSnapshot::Knn { .. }));
        assert_eq!(first.bytes(), first.full_bytes());
        l.note_broadcast();
        // two learns later: a two-row delta, newest first
        l.learn(&normal_ex(&mut rng, 100), &mut be).unwrap();
        l.learn(&normal_ex(&mut rng, 101), &mut be).unwrap();
        let delta = l.snapshot_outgoing().unwrap();
        match &delta {
            ModelSnapshot::KnnDelta { times, learned, .. } => {
                assert_eq!(times, &[101, 100]);
                assert_eq!(*learned, 7);
            }
            other => panic!("expected a delta, got {other:?}"),
        }
        assert_eq!(delta.bytes(), 2 * FEAT_DIM * 4 + 2 * 8 + 8 + 4);
        assert_eq!(delta.full_bytes(), first.bytes());
        // nothing new since the last committed broadcast: an empty delta
        l.note_broadcast();
        let empty = l.snapshot_outgoing().unwrap();
        assert_eq!(empty.bytes(), 8 + 4);
        // a restore voids broadcast tracking: back to the full fallback
        let mut nvm = Nvm::new();
        l.save(&mut nvm).unwrap();
        l.restore(&mut nvm).unwrap();
        assert!(matches!(
            l.snapshot_outgoing().unwrap(),
            ModelSnapshot::Knn { .. }
        ));
    }

    #[test]
    fn delta_merge_matches_full_merge() {
        let mut be = NativeBackend::new();
        let mut rng = Rng::new(15);
        let mut donor = KnnAnomalyLearner::new();
        for t in 0..10 {
            donor.learn(&normal_ex(&mut rng, 100 + t), &mut be).unwrap();
        }
        // follower A tracks the donor: full snapshot, then a delta
        let mut a = KnnAnomalyLearner::new();
        assert!(a
            .merge(&[&donor.snapshot_outgoing().unwrap()], &mut be, 1_000, None)
            .unwrap());
        donor.note_broadcast();
        for t in 0..4 {
            donor.learn(&normal_ex(&mut rng, 200 + t), &mut be).unwrap();
        }
        let delta = donor.snapshot_outgoing().unwrap();
        assert!(matches!(&delta, ModelSnapshot::KnnDelta { .. }));
        assert!(a.merge(&[&delta], &mut be, 1_000, None).unwrap());
        // follower B gets the same state in one full merge
        let mut b = KnnAnomalyLearner::new();
        assert!(b
            .merge(&[&donor.snapshot().unwrap()], &mut be, 1_000, None)
            .unwrap());
        assert_eq!(a.buffer().0, b.buffer().0);
        assert_eq!(a.buffer().1, b.buffer().1);
        assert_eq!(a.threshold(), b.threshold());
        assert_eq!(a.learned_count(), b.learned_count());
    }

    #[test]
    fn adopted_peer_rows_ride_the_next_outgoing_delta() {
        let mut be = NativeBackend::new();
        let mut rng = Rng::new(16);
        let mut a = KnnAnomalyLearner::new();
        for t in 0..6 {
            a.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        a.note_broadcast(); // peers have seen everything so far
        let mut donor = KnnAnomalyLearner::new();
        for t in 0..3 {
            donor.learn(&normal_ex(&mut rng, 500 + t), &mut be).unwrap();
        }
        a.merge(&[&donor.snapshot().unwrap()], &mut be, 1_000, None)
            .unwrap();
        // gossip forwards what the merge adopted, not just local learns
        match a.snapshot_outgoing().unwrap() {
            ModelSnapshot::KnnDelta { times, .. } => {
                assert_eq!(times, vec![502, 501, 500]);
            }
            other => panic!("expected a delta, got {other:?}"),
        }
    }

    #[test]
    fn evaluate_reports_fit_quality() {
        let mut be = NativeBackend::new();
        let mut l = KnnAnomalyLearner::new();
        let mut rng = Rng::new(5);
        assert_eq!(l.evaluate(&mut be).unwrap(), 0.0);
        for t in 0..20 {
            l.learn(&normal_ex(&mut rng, t), &mut be).unwrap();
        }
        let q = l.evaluate(&mut be).unwrap();
        assert!((0.8..=1.0).contains(&q), "q {q}");
    }
}
