//! Neural-network k-means (competitive learning) with cluster-then-label
//! semi-supervision — the vibration learner of paper §6.3.
//!
//! A two-layer network: the input layer is the feature vector, the two
//! output neurons are the clusters (normal / abnormal vibration). Only the
//! winner neuron (largest activation a_j = Σ w_ij x_i) is updated per
//! example: Δw = η(x − w). Classification feeds the features forward and
//! takes the winner.
//!
//! Cluster→label assignment follows the cluster-then-label scheme: a small
//! number of *labelled* examples (the semi-supervised budget) vote on the
//! label of the cluster they fall into; unlabelled examples only move the
//! cluster means.
//!
//! §Perf: `learn` updates the weights in place through the backend's
//! in-place `kmeans_learn` (no per-step weight reallocation), and
//! [`Learner::save_delta`] checkpoints only the updated cluster row plus
//! the misc scalars (see `learning::knn` for the generation-guard
//! contract).

use crate::backend::shapes::*;
use crate::backend::ComputeBackend;
use crate::error::Result;
use crate::learning::{Example, Learner, ModelSnapshot, Verdict};
use crate::nvm::{KeyId, Nvm};

/// Interned NVM handles for the learner's keys (resolved once per store).
#[derive(Debug, Clone, Copy)]
struct KmeansKeys {
    w: KeyId,
    misc: KeyId,
    learned: KeyId,
    gen: KeyId,
}

/// Misc scalar block: eta, quality, budgets + per-cluster votes / EMA /
/// since-merge update counts / since-merge vote deltas.
const MISC_LEN: usize = 4 + 6 * N_CLUSTERS;

/// Competitive-learning k-means with cluster labelling.
#[derive(Debug, Clone)]
pub struct ClusterLabelLearner {
    /// (N_CLUSTERS, FEAT_DIM) weights.
    w: Vec<f32>,
    /// Learning rate η.
    pub eta: f32,
    /// Per-cluster (normal votes, abnormal votes) from labelled examples.
    votes: [[u32; 2]; N_CLUSTERS],
    /// Votes gained since the last fleet merge (the delta a sync
    /// broadcasts — re-sending cumulative votes would double-count them
    /// every round under all-reduce).
    fresh_votes: [[u32; 2]; N_CLUSTERS],
    /// Competitive updates per cluster since the last fleet merge — the
    /// FedAvg-style count weights of the centroid average. Reset after
    /// every merge so a round contributes each example exactly once.
    counts: [u32; N_CLUSTERS],
    /// Labelled examples still allowed to vote (semi-supervised budget).
    label_budget: u32,
    /// The budget the learner started with (per-cluster cap base).
    initial_budget: u32,
    learned: u64,
    /// Per-cluster running mean of the winning activation (drift monitor
    /// used by `evaluate`).
    act_ema: [f32; N_CLUSTERS],
    quality: f32,
    /// Cached key handles for the store identified by the `u64`.
    keys: Option<(u64, KmeansKeys)>,
    /// Cluster rows updated since the last save (delta-checkpoint set).
    dirty_rows: Vec<usize>,
    /// Generation of this learner's last save (see `learning::knn`).
    save_gen: u64,
}

impl ClusterLabelLearner {
    /// `label_budget` = number of ground-truth labels the deployment can
    /// afford to reveal (paper's controlled experiment effectively labels
    /// the calibration gestures).
    pub fn new(seed: u64, label_budget: u32) -> Self {
        // deterministic small random init, distinct per cluster
        let mut rng = crate::util::Rng::with_stream(seed, 0x5EED);
        let w = (0..N_CLUSTERS * FEAT_DIM)
            .map(|_| rng.normal(0.0, 0.05) as f32)
            .collect();
        ClusterLabelLearner {
            w,
            eta: 0.15,
            votes: [[0; 2]; N_CLUSTERS],
            fresh_votes: [[0; 2]; N_CLUSTERS],
            counts: [0; N_CLUSTERS],
            label_budget,
            initial_budget: label_budget,
            learned: 0,
            act_ema: [0.0; N_CLUSTERS],
            quality: 0.0,
            keys: None,
            dirty_rows: Vec::with_capacity(N_CLUSTERS),
            save_gen: 0,
        }
    }

    /// Winner cluster for a feature vector.
    pub fn winner(&self, x: &[f32], be: &mut dyn ComputeBackend) -> Result<usize> {
        let acts = be.kmeans_infer(&self.w, x)?;
        Ok(argmax(&acts))
    }

    /// Label of a cluster by majority vote; `None` if unvoted.
    pub fn cluster_label(&self, cluster: usize) -> Option<bool> {
        let [n, a] = self.votes[cluster];
        if n == a {
            None
        } else {
            Some(a > n)
        }
    }

    /// Current weights (tests/benches).
    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    /// Remaining labelled-example budget.
    pub fn labels_remaining(&self) -> u32 {
        self.label_budget
    }

    /// Spend one label on `cluster` if budget remains AND the cluster has
    /// not used its per-cluster share. Without the per-cluster cap, a
    /// deployment whose early phase is all one class (e.g. the vibration
    /// protocol's gentle-only first hour) burns the whole budget labelling
    /// one cluster and the other stays forever unlabelled.
    fn spend_label(&mut self, cluster: usize, abnormal: bool) {
        let initial = self.initial_budget.max(self.label_budget);
        let cap = (initial / N_CLUSTERS as u32).max(1);
        let used: u32 = self.votes[cluster].iter().sum();
        if self.label_budget > 0 && used < cap {
            self.votes[cluster][abnormal as usize] += 1;
            self.fresh_votes[cluster][abnormal as usize] += 1;
            self.label_budget -= 1;
        }
    }

    /// Record a cluster row as dirty for the next delta save.
    fn mark_dirty(&mut self, row: usize) {
        if !self.dirty_rows.contains(&row) {
            self.dirty_rows.push(row);
        }
    }

    /// Pack the misc scalar block (everything but the weight matrix).
    fn misc_block(&self) -> [f32; MISC_LEN] {
        let mut misc = [0.0f32; MISC_LEN];
        misc[0] = self.eta;
        misc[1] = self.quality;
        misc[2] = self.label_budget as f32;
        misc[3] = self.initial_budget as f32;
        for c in 0..N_CLUSTERS {
            misc[4 + 6 * c] = self.votes[c][0] as f32;
            misc[5 + 6 * c] = self.votes[c][1] as f32;
            misc[6 + 6 * c] = self.act_ema[c];
            misc[7 + 6 * c] = self.counts[c] as f32;
            misc[8 + 6 * c] = self.fresh_votes[c][0] as f32;
            misc[9 + 6 * c] = self.fresh_votes[c][1] as f32;
        }
        misc
    }

    /// Key handles for `nvm`, interned once and re-resolved only when the
    /// learner meets a different store.
    fn keys(&mut self, nvm: &mut Nvm) -> KmeansKeys {
        match self.keys {
            Some((sid, k)) if sid == nvm.store_id() => k,
            _ => {
                let k = KmeansKeys {
                    w: nvm.intern("kmeans/w"),
                    misc: nvm.intern("kmeans/misc"),
                    learned: nvm.intern("kmeans/learned"),
                    gen: nvm.intern("kmeans/gen"),
                };
                self.keys = Some((nvm.store_id(), k));
                k
            }
        }
    }

    /// Write the non-weight state (shared by full and delta saves).
    fn save_tail(&mut self, nvm: &mut Nvm, k: KmeansKeys) -> Result<()> {
        nvm.write_f32s_id(k.misc, &self.misc_block())?;
        nvm.write_u64_id(k.learned, self.learned)?;
        self.save_gen = self.save_gen.wrapping_add(1);
        nvm.write_u64_id(k.gen, self.save_gen)?;
        self.dirty_rows.clear();
        Ok(())
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

impl Learner for ClusterLabelLearner {
    fn learn(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<()> {
        debug_assert_eq!(ex.features.len(), FEAT_DIM);
        // Init-from-data: the first K examples seed the K cluster weights
        // directly (standard k-means init). Without this, a near-zero
        // random init lets one neuron capture both populations (the
        // classic competitive-learning dead-unit problem).
        if self.learned < N_CLUSTERS as u64 {
            let c = self.learned as usize;
            self.w[c * FEAT_DIM..(c + 1) * FEAT_DIM].copy_from_slice(&ex.features);
            self.mark_dirty(c);
            self.counts[c] = self.counts[c].saturating_add(1);
            self.spend_label(c, ex.truth_abnormal);
            self.learned += 1;
            return Ok(());
        }
        let mut acts = [0.0f32; N_CLUSTERS];
        let win = be.kmeans_learn(&mut self.w, &ex.features, self.eta, &mut acts)?;
        self.act_ema[win] = 0.9 * self.act_ema[win] + 0.1 * acts[win];
        self.mark_dirty(win);
        self.counts[win] = self.counts[win].saturating_add(1);
        self.spend_label(win, ex.truth_abnormal);
        self.learned += 1;
        Ok(())
    }

    fn infer(&mut self, ex: &Example, be: &mut dyn ComputeBackend) -> Result<Verdict> {
        if self.learned < 2 {
            return Ok(Verdict::Unknown);
        }
        let win = self.winner(&ex.features, be)?;
        Ok(match self.cluster_label(win) {
            Some(true) => Verdict::Abnormal,
            Some(false) => Verdict::Normal,
            None => Verdict::Unknown,
        })
    }

    fn learnable(&self) -> bool {
        true
    }

    fn evaluate(&mut self, _be: &mut dyn ComputeBackend) -> Result<f32> {
        // Quality: do both clusters have a confident (non-tied) label and
        // have both been exercised? 0.5 per labelled cluster.
        let q = (0..N_CLUSTERS)
            .map(|c| if self.cluster_label(c).is_some() { 0.5 } else { 0.0 })
            .sum();
        self.quality = q;
        Ok(q)
    }

    fn learned_count(&self) -> u64 {
        self.learned
    }

    fn save(&mut self, nvm: &mut Nvm) -> Result<()> {
        let k = self.keys(nvm);
        nvm.write_f32s_id(k.w, &self.w)?;
        self.save_tail(nvm, k)
    }

    fn save_delta(&mut self, nvm: &mut Nvm) -> Result<()> {
        let k = self.keys(nvm);
        let fresh = self.save_gen != 0
            && nvm.read_u64_id(k.gen) == self.save_gen
            && nvm.value_len(k.w) == Some(N_CLUSTERS * FEAT_DIM * 4);
        if !fresh {
            return self.save(nvm);
        }
        for &c in &self.dirty_rows {
            let row = &self.w[c * FEAT_DIM..(c + 1) * FEAT_DIM];
            nvm.write_f32s_at(k.w, c * FEAT_DIM, row)?;
        }
        self.save_tail(nvm, k)
    }

    fn restore(&mut self, nvm: &mut Nvm) -> Result<()> {
        let k = self.keys(nvm);
        nvm.read_f32s_into(k.w, &mut self.w);
        let mut m = [0.0f32; MISC_LEN];
        if nvm.read_f32s_into(k.misc, &mut m) {
            self.eta = m[0];
            self.quality = m[1];
            self.label_budget = m[2] as u32;
            self.initial_budget = m[3] as u32;
            for c in 0..N_CLUSTERS {
                self.votes[c][0] = m[4 + 6 * c] as u32;
                self.votes[c][1] = m[5 + 6 * c] as u32;
                self.act_ema[c] = m[6 + 6 * c];
                self.counts[c] = m[7 + 6 * c] as u32;
                self.fresh_votes[c][0] = m[8 + 6 * c] as u32;
                self.fresh_votes[c][1] = m[9 + 6 * c] as u32;
            }
        }
        self.learned = nvm.read_u64_id(k.learned);
        self.save_gen = nvm.read_u64_id(k.gen);
        self.dirty_rows.clear();
        Ok(())
    }

    fn snapshot(&self) -> Option<ModelSnapshot> {
        Some(ModelSnapshot::Kmeans {
            w: self.w.clone(),
            counts: self.counts,
            // broadcast only the since-merge vote deltas: cumulative votes
            // would double-count under repeated all-reduce rounds
            votes: self.fresh_votes,
            act_ema: self.act_ema,
            learned: self.learned,
        })
    }

    /// Count-weighted centroid averaging with label-vote fusion: each
    /// cluster's merged weights are the mean of every participant's row
    /// weighted by its competitive updates *since the last merge* (FedAvg
    /// over the round's contributions — a shard that learned nothing this
    /// round pulls no weight), peer vote deltas are added into the local
    /// tallies, and activation EMAs average under the same weights. Local
    /// since-merge counters reset: the round's contribution is consumed.
    fn merge(
        &mut self,
        peers: &[&ModelSnapshot],
        _be: &mut dyn ComputeBackend,
        _now_us: u64,
        _expiry_us: Option<u64>,
    ) -> Result<bool> {
        let mut any_peer = false;
        let mut merged_learned = self.learned;
        let mut w_new = self.w.clone();
        let mut ema_new = self.act_ema;
        for c in 0..N_CLUSTERS {
            let mut total = f64::from(self.counts[c]);
            let mut acc: Vec<f64> = self.w[c * FEAT_DIM..(c + 1) * FEAT_DIM]
                .iter()
                .map(|&v| f64::from(v) * f64::from(self.counts[c]))
                .collect();
            let mut ema_acc = f64::from(self.act_ema[c]) * f64::from(self.counts[c]);
            for p in peers {
                if let ModelSnapshot::Kmeans {
                    w,
                    counts,
                    act_ema,
                    ..
                } = p
                {
                    let n = f64::from(counts[c]);
                    total += n;
                    for (a, &v) in acc.iter_mut().zip(&w[c * FEAT_DIM..(c + 1) * FEAT_DIM]) {
                        *a += f64::from(v) * n;
                    }
                    ema_acc += f64::from(act_ema[c]) * n;
                }
            }
            if total > 0.0 {
                for (dst, a) in w_new[c * FEAT_DIM..(c + 1) * FEAT_DIM]
                    .iter_mut()
                    .zip(&acc)
                {
                    *dst = (a / total) as f32;
                }
                ema_new[c] = (ema_acc / total) as f32;
            }
            // total == 0: nobody updated this cluster since the last
            // merge — keep the local row
        }
        for p in peers {
            if let ModelSnapshot::Kmeans { votes, learned, .. } = p {
                any_peer = true;
                merged_learned = merged_learned.max(*learned);
                for c in 0..N_CLUSTERS {
                    for j in 0..2 {
                        self.votes[c][j] = self.votes[c][j].saturating_add(votes[c][j]);
                    }
                }
            }
        }
        if !any_peer {
            return Ok(false);
        }
        self.w = w_new;
        self.act_ema = ema_new;
        self.learned = merged_learned;
        self.counts = [0; N_CLUSTERS];
        self.fresh_votes = [[0; 2]; N_CLUSTERS];
        // the whole weight matrix changed: force the next delta save full
        self.dirty_rows.clear();
        self.save_gen = 0;
        Ok(true)
    }

    fn name(&self) -> &'static str {
        "kmeans_cluster_label"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::util::Rng;

    /// Two well-separated example populations on distinct axes.
    fn population(rng: &mut Rng, abnormal: bool) -> Example {
        let mut f = vec![0.0f32; FEAT_DIM];
        let base = if abnormal { 8 } else { 0 };
        for i in 0..8 {
            f[base + i] = 2.0 + rng.normal(0.0, 0.2) as f32;
        }
        Example::new(f, 0, abnormal)
    }

    #[test]
    fn separates_two_populations() {
        let mut be = NativeBackend::new();
        let mut l = ClusterLabelLearner::new(7, 40);
        let mut rng = Rng::new(7);
        for i in 0..120 {
            let ex = population(&mut rng, i % 2 == 0);
            l.learn(&ex, &mut be).unwrap();
        }
        // evaluate: both clusters labelled
        assert_eq!(l.evaluate(&mut be).unwrap(), 1.0);
        let mut correct = 0;
        for i in 0..40 {
            let ex = population(&mut rng, i % 2 == 0);
            let v = l.infer(&ex, &mut be).unwrap();
            if v.abnormal() == ex.truth_abnormal {
                correct += 1;
            }
        }
        assert!(correct >= 36, "correct {correct}/40");
    }

    #[test]
    fn unknown_until_learned() {
        let mut be = NativeBackend::new();
        let mut l = ClusterLabelLearner::new(1, 10);
        let mut rng = Rng::new(1);
        let ex = population(&mut rng, false);
        assert_eq!(l.infer(&ex, &mut be).unwrap(), Verdict::Unknown);
    }

    #[test]
    fn label_budget_is_finite() {
        let mut be = NativeBackend::new();
        let mut l = ClusterLabelLearner::new(2, 5);
        let mut rng = Rng::new(2);
        for i in 0..20 {
            l.learn(&population(&mut rng, i % 2 == 0), &mut be).unwrap();
        }
        // budget 5, per-cluster cap = 5/2 = 2: at most 4 spendable
        let total_votes: u32 = l.votes.iter().flatten().sum();
        assert_eq!(total_votes, 4);
        assert_eq!(l.labels_remaining(), 1);
        for c in 0..N_CLUSTERS {
            let used: u32 = l.votes[c].iter().sum();
            assert!(used <= 2, "cluster {c} used {used}");
        }
    }

    #[test]
    fn save_restore_round_trip() {
        let mut be = NativeBackend::new();
        let mut nvm = Nvm::new();
        let mut l = ClusterLabelLearner::new(3, 20);
        let mut rng = Rng::new(3);
        for i in 0..30 {
            l.learn(&population(&mut rng, i % 2 == 0), &mut be).unwrap();
        }
        l.save(&mut nvm).unwrap();
        let mut l2 = ClusterLabelLearner::new(999, 0); // different init
        l2.restore(&mut nvm).unwrap();
        assert_eq!(l2.learned_count(), 30);
        assert_eq!(l2.weights(), l.weights());
        let ex = population(&mut rng, true);
        assert_eq!(
            l.infer(&ex, &mut be).unwrap(),
            l2.infer(&ex, &mut be).unwrap()
        );
    }

    #[test]
    fn delta_save_restores_bit_identically_and_writes_less() {
        let mut be = NativeBackend::new();
        let mut nvm = Nvm::new();
        let mut l = ClusterLabelLearner::new(11, 20);
        let mut rng = Rng::new(11);
        let mut after_full = 0;
        for i in 0..40 {
            l.learn(&population(&mut rng, i % 2 == 0), &mut be).unwrap();
            l.save_delta(&mut nvm).unwrap();
            if i == 0 {
                after_full = nvm.bytes_written;
            }
        }
        // steady-state deltas: winner row + misc + learned + gen
        let per_delta = (nvm.bytes_written - after_full) / 39;
        assert_eq!(
            per_delta as usize,
            FEAT_DIM * 4 + MISC_LEN * 4 + 8 + 8,
            "unexpected delta footprint"
        );
        let mut l2 = ClusterLabelLearner::new(999, 0);
        l2.restore(&mut nvm).unwrap();
        assert_eq!(l2.weights(), l.weights());
        assert_eq!(l2.learned_count(), l.learned_count());
        assert_eq!(l2.votes, l.votes);
    }

    #[test]
    fn merge_is_count_weighted_centroid_averaging() {
        let mut be = NativeBackend::new();
        // two learners over opposite populations with known update counts
        let mut a = ClusterLabelLearner::new(21, 10);
        let mut b = ClusterLabelLearner::new(21, 10);
        let mut rng = Rng::new(21);
        for i in 0..12 {
            a.learn(&population(&mut rng, i % 2 == 0), &mut be).unwrap();
        }
        for i in 0..36 {
            b.learn(&population(&mut rng, i % 2 == 0), &mut be).unwrap();
        }
        let (wa, wb) = (a.weights().to_vec(), b.weights().to_vec());
        let (ca, cb) = (a.counts, b.counts);
        let snap_b = b.snapshot().unwrap();
        assert!(a.merge(&[&snap_b], &mut be, 0, None).unwrap());
        for c in 0..N_CLUSTERS {
            let (na, nb) = (ca[c] as f64, cb[c] as f64);
            assert!(na > 0.0 && nb > 0.0, "populations must hit both clusters");
            for j in 0..FEAT_DIM {
                let want = (wa[c * FEAT_DIM + j] as f64 * na
                    + wb[c * FEAT_DIM + j] as f64 * nb)
                    / (na + nb);
                let got = a.weights()[c * FEAT_DIM + j] as f64;
                assert!((got - want).abs() < 1e-6, "c{c} j{j}: {got} vs {want}");
            }
        }
        // the heavier learner (3x the updates) pulled the mean toward it
        let d = |x: &[f32], y: &[f32]| -> f32 {
            x.iter().zip(y).map(|(p, q)| (p - q).abs()).sum()
        };
        assert!(d(a.weights(), &wb) < d(a.weights(), &wa));
        // since-merge counters consumed
        assert_eq!(a.counts, [0; N_CLUSTERS]);
        assert_eq!(a.learned_count(), 36);
    }

    #[test]
    fn merge_fuses_label_votes_and_enables_cold_inference() {
        let mut be = NativeBackend::new();
        let mut donor = ClusterLabelLearner::new(31, 40);
        let mut rng = Rng::new(31);
        for i in 0..60 {
            donor.learn(&population(&mut rng, i % 2 == 0), &mut be).unwrap();
        }
        assert_eq!(donor.evaluate(&mut be).unwrap(), 1.0);
        // a cold shard (zero labels of its own) adopts weights AND votes
        let mut cold = ClusterLabelLearner::new(999, 0);
        let dsnap = donor.snapshot().unwrap();
        assert!(cold.merge(&[&dsnap], &mut be, 0, None).unwrap());
        assert_eq!(cold.evaluate(&mut be).unwrap(), 1.0, "votes did not fuse");
        let mut correct = 0;
        for i in 0..20 {
            let ex = population(&mut rng, i % 2 == 0);
            if cold.infer(&ex, &mut be).unwrap().abnormal() == ex.truth_abnormal {
                correct += 1;
            }
        }
        assert!(correct >= 17, "cold shard classifies {correct}/20 after merge");
        // vote deltas are consumed on the donor side only when IT merges;
        // here the cold side snapshot now carries no fresh votes
        match cold.snapshot().unwrap() {
            ModelSnapshot::Kmeans { votes, counts, .. } => {
                assert_eq!(votes, [[0; 2]; N_CLUSTERS], "adopted votes re-broadcast");
                assert_eq!(counts, [0; N_CLUSTERS]);
            }
            other => panic!("unexpected snapshot {other:?}"),
        }
        // merging a contribution-free snapshot moves nothing
        let w = cold.weights().to_vec();
        let idle = cold.snapshot().unwrap();
        assert!(cold.merge(&[&idle], &mut be, 0, None).unwrap());
        assert_eq!(cold.weights(), &w[..], "zero-count merge moved the weights");
    }

    #[test]
    fn merge_forces_the_next_delta_save_to_be_full() {
        let mut be = NativeBackend::new();
        let mut nvm = Nvm::new();
        let mut rng = Rng::new(41);
        let mut l = ClusterLabelLearner::new(41, 10);
        for i in 0..10 {
            l.learn(&population(&mut rng, i % 2 == 0), &mut be).unwrap();
            l.save_delta(&mut nvm).unwrap();
        }
        let mut donor = ClusterLabelLearner::new(42, 10);
        for i in 0..10 {
            donor.learn(&population(&mut rng, i % 2 == 0), &mut be).unwrap();
        }
        let dsnap = donor.snapshot().unwrap();
        l.merge(&[&dsnap], &mut be, 0, None).unwrap();
        let before = nvm.bytes_written;
        l.save_delta(&mut nvm).unwrap();
        let wrote = (nvm.bytes_written - before) as usize;
        assert_eq!(wrote, N_CLUSTERS * FEAT_DIM * 4 + MISC_LEN * 4 + 8 + 8);
        let mut back = ClusterLabelLearner::new(999, 0);
        back.restore(&mut nvm).unwrap();
        assert_eq!(back.weights(), l.weights());
        assert_eq!(back.votes, l.votes);
        assert_eq!(back.learned_count(), l.learned_count());
    }

    #[test]
    fn eta_controls_step_size() {
        let mut be = NativeBackend::new();
        let mut slow = ClusterLabelLearner::new(4, 0);
        let mut fast = ClusterLabelLearner::new(4, 0);
        slow.eta = 0.01;
        fast.eta = 0.5;
        let mut rng = Rng::new(4);
        // first two examples seed the clusters (init-from-data);
        // the third exercises the competitive update whose step is eta.
        let seeds = [population(&mut rng, false), population(&mut rng, true)];
        for l in [&mut slow, &mut fast] {
            l.learn(&seeds[0], &mut be).unwrap();
            l.learn(&seeds[1], &mut be).unwrap();
        }
        let snapshot = slow.weights().to_vec();
        assert_eq!(snapshot, fast.weights());
        let ex = population(&mut rng, false);
        slow.learn(&ex, &mut be).unwrap();
        fast.learn(&ex, &mut be).unwrap();
        let delta = |l: &ClusterLabelLearner| -> f32 {
            l.weights()
                .iter()
                .zip(&snapshot)
                .map(|(a, b)| (a - b).abs())
                .sum()
        };
        assert!(delta(&fast) > 5.0 * delta(&slow));
    }
}
