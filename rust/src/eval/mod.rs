//! Evaluation harness: runs the experiment matrix and regenerates every
//! table and figure of the paper's §7 (see DESIGN.md §4 for the index).
//!
//! Each `fig*`/`table*` function in [`figures`] returns a [`FigData`] —
//! a set of named series plus formatted rows — which the CLI prints and
//! optionally writes as JSON under `out/`.

pub mod figures;

use crate::util::json::Json;

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series {
            name: name.into(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn mean_y(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|p| p.1).sum::<f64>() / self.points.len() as f64
    }

    pub fn last_y(&self) -> f64 {
        self.points.last().map(|p| p.1).unwrap_or(0.0)
    }
}

/// A regenerated figure/table: series for plotting + rows for the console.
#[derive(Debug, Clone, Default)]
pub struct FigData {
    pub id: String,
    pub title: String,
    /// Axis labels (x, y).
    pub axes: (String, String),
    pub series: Vec<Series>,
    /// Pre-formatted summary rows (what the paper's table shows).
    pub rows: Vec<String>,
}

impl FigData {
    pub fn new(id: &str, title: &str, x: &str, y: &str) -> Self {
        FigData {
            id: id.to_string(),
            title: title.to_string(),
            axes: (x.to_string(), y.to_string()),
            series: Vec::new(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, s: impl Into<String>) {
        self.rows.push(s.into());
    }

    /// Console rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} — {} ==\n", self.id, self.title));
        out.push_str(&format!("   x: {}   y: {}\n", self.axes.0, self.axes.1));
        for row in &self.rows {
            out.push_str(row);
            out.push('\n');
        }
        for s in &self.series {
            out.push_str(&format!(
                "  series {:<28} n={:<4} mean={:.3} last={:.3}\n",
                s.name,
                s.points.len(),
                s.mean_y(),
                s.last_y()
            ));
        }
        out
    }

    /// JSON rendering (for plotting scripts).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("title", Json::Str(self.title.clone())),
            ("x", Json::Str(self.axes.0.clone())),
            ("y", Json::Str(self.axes.1.clone())),
            (
                "series",
                Json::Arr(
                    self.series
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::Str(s.name.clone())),
                                (
                                    "points",
                                    Json::Arr(
                                        s.points
                                            .iter()
                                            .map(|&(x, y)| Json::nums([x, y]))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "rows",
                Json::Arr(self.rows.iter().map(|r| Json::Str(r.clone())).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let mut s = Series::new("a");
        s.push(0.0, 0.5);
        s.push(1.0, 1.0);
        assert_eq!(s.mean_y(), 0.75);
        assert_eq!(s.last_y(), 1.0);
    }

    #[test]
    fn figdata_renders_and_serializes() {
        let mut f = FigData::new("fig9a", "test", "t", "acc");
        let mut s = Series::new("il");
        s.push(0.0, 0.8);
        f.series.push(s);
        f.row("il: 0.80");
        let txt = f.render();
        assert!(txt.contains("fig9a") && txt.contains("il: 0.80"));
        let j = f.to_json().to_string();
        assert!(j.contains("\"id\":\"fig9a\"") && j.contains("[0,0.8]"));
    }
}
