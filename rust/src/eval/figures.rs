//! Regeneration of every table and figure in the paper's evaluation (§7).
//!
//! Absolute numbers come from a simulated testbed (DESIGN.md §1), so the
//! claims to check are the *shapes*: who wins, by roughly what factor, and
//! where the crossovers fall. EXPERIMENTS.md records paper-vs-measured for
//! each entry.

use crate::actions::Action;
use crate::apps::AppKind;
use crate::backend::native::NativeBackend;
use crate::backend::shapes::{CHANNELS, WINDOW};
use crate::backend::ComputeBackend;
use crate::baselines::offline::{
    detector_accuracy, ArDetector, IsolationForest, OfflineDetector, OneClassSvm,
};
use crate::baselines::RunningMeanThreshold;
use crate::energy::CostModel;
use crate::error::Result;
use crate::eval::{FigData, Series};
use crate::planner::{DynamicActionPlanner, PlanContext};
use crate::scenario::sweep::run_parallel;
use crate::scenario::{ScenarioSpec, SchedulerKind};
use crate::selection::Heuristic;
use crate::sensors::Sensor;
use crate::sim::probe::build_probes;
use crate::sim::RunResult;
use crate::util::bench;

const H: u64 = 3_600_000_000;

/// Run a batch of scenarios in parallel (one engine per worker thread) —
/// a thin alias over [`crate::scenario::sweep::run_parallel`] with
/// auto-sized workers.
pub fn par_run(specs: Vec<ScenarioSpec>) -> Result<Vec<RunResult>> {
    run_parallel(&specs, 0)
}

fn accuracy_series(name: &str, r: &RunResult) -> Series {
    let mut s = Series::new(name);
    for c in &r.checkpoints {
        s.push(c.t_us as f64 / H as f64, c.accuracy);
    }
    s
}

/// All figure ids the harness can regenerate (`fleet16` is ours, not the
/// paper's: the population-scale extension of Fig. 6(c)).
pub const FIGURE_IDS: [&str; 17] = [
    "fig6c", "fig7c", "fig8c", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15",
    "fig16", "fig17", "fleet16", "sync16", "table3", "table4", "table5",
];

/// Dispatch by figure id.
pub fn generate(id: &str, seed: u64) -> Result<FigData> {
    match id {
        "fig6c" => fig6c(seed),
        "fig7c" => fig7c(seed),
        "fig8c" => fig8c(seed),
        "fig9" => fig9_10(seed, false),
        "fig10" => fig9_10(seed, true),
        "fig11" => fig11(seed),
        "fig12" => fig12(seed),
        "fig13" => fig13_14(seed, false),
        "fig14" => fig13_14(seed, true),
        "fig15" => fig15(seed),
        "fig16" => fig16(),
        "fig17" => fig17(seed),
        "fleet16" => fleet16(seed),
        "sync16" => sync16(seed),
        "table3" => table34(seed, false),
        "table4" => table34(seed, true),
        "table5" => table5(seed),
        other => Err(crate::error::Error::Config(format!(
            "unknown figure `{other}`; known: {FIGURE_IDS:?}"
        ))),
    }
}

/// Fig. 6(c): air-quality detection accuracy over deployment time
/// (paper: 20 weeks at 81–83%; we compress to days — DESIGN.md §1).
pub fn fig6c(seed: u64) -> Result<FigData> {
    let mut fig = FigData::new(
        "fig6c",
        "Air-quality anomaly detection accuracy over time",
        "days",
        "accuracy",
    );
    let spec = AppKind::AirQuality.spec(seed, 5 * 24 * H);
    let r = spec.build_engine()?.run()?;
    let mut s = Series::new("air_quality(knn, solar)");
    for c in &r.checkpoints {
        s.push(c.t_us as f64 / (24.0 * H as f64), c.accuracy);
    }
    fig.row(format!(
        "air_quality: mean accuracy {:.2} (paper: 0.81-0.83), learned {}, inferred {}",
        r.mean_accuracy(4),
        r.learned,
        r.inferred
    ));
    fig.series.push(s);
    Ok(fig)
}

/// `fleet16` (ours): a 16-shard solar air-quality fleet — the §6.1 node
/// deployed as a phase-jittered population. Per-shard accuracy spread plus
/// the fan-in rollups; shards parallelize on the worker pool.
pub fn fleet16(seed: u64) -> Result<FigData> {
    use crate::scenario::FleetSpec;
    let mut fig = FigData::new(
        "fleet16",
        "16-shard solar fleet: per-shard accuracy and fan-in rollups",
        "shard",
        "accuracy",
    );
    let mut spec = AppKind::AirQuality.spec(seed, 12 * H);
    spec.fleet = Some(FleetSpec {
        shards: 16,
        // half an hour of solar phase per shard: the fleet spans 8 h of
        // the diurnal curve
        phase_jitter_us: 1_800_000_000,
        seed_stride: 1,
        overrides: vec![],
        sync: None,
        sched: None,
        stream: None,
    });
    let fr = spec.run_fleet(0)?;
    let mut final_acc = Series::new("final_accuracy_by_shard");
    let mut learned = Series::new("learned_by_shard");
    for (i, r) in fr.shards.iter().enumerate() {
        final_acc.push(i as f64, r.final_accuracy());
        learned.push(i as f64, r.learned as f64);
    }
    let roll = &fr.rollup;
    fig.row(format!(
        "final accuracy: mean {:.2} [{:.2}, {:.2}] across {} shards",
        roll.final_accuracy.mean, roll.final_accuracy.min, roll.final_accuracy.max, roll.shards
    ));
    fig.row(format!(
        "learned {} total (mean {:.1}/shard), energy {:.1} mJ total, {} stale plans",
        roll.learned.total as u64,
        roll.learned.mean,
        roll.energy_uj.total / 1000.0,
        roll.stale_plans.total as u64
    ));
    fig.series.push(final_acc);
    fig.series.push(learned);
    Ok(fig)
}

/// `sync16` (ours): the `fleet16` population with and without round-based
/// federated sync — per-shard mean accuracy under periodic gossip vs
/// total isolation, plus the radio bill and the energy-gated skip count.
pub fn sync16(seed: u64) -> Result<FigData> {
    use crate::scenario::{FleetSpec, SyncSpec};
    use crate::sim::SyncStrategy;
    let mut fig = FigData::new(
        "sync16",
        "16-shard solar fleet: federated sync vs isolated accuracy",
        "shard",
        "mean accuracy",
    );
    let base = |sync: Option<SyncSpec>| {
        let mut spec = AppKind::AirQuality.spec(seed, 12 * H);
        spec.fleet = Some(FleetSpec {
            shards: 16,
            phase_jitter_us: 1_800_000_000,
            seed_stride: 1,
            overrides: vec![],
            sync,
            sched: None,
            stream: None,
        });
        spec
    };
    let isolated = base(None).run_fleet(0)?;
    let synced_spec = base(Some(SyncSpec {
        // hourly model gossip across the population
        period_us: 3_600_000_000,
        strategy: SyncStrategy::Gossip,
        radio: None,
    }));
    let synced = synced_spec.run_fleet(0)?;
    let mut iso_s = Series::new("isolated_mean_accuracy_by_shard");
    let mut syn_s = Series::new("synced_mean_accuracy_by_shard");
    for (i, (a, b)) in isolated.shards.iter().zip(&synced.shards).enumerate() {
        iso_s.push(i as f64, a.mean_accuracy(3));
        syn_s.push(i as f64, b.mean_accuracy(3));
    }
    fig.row(format!(
        "mean accuracy rollup: isolated {:.3} -> synced {:.3} ({} shards)",
        isolated.rollup.mean_accuracy.mean, synced.rollup.mean_accuracy.mean, synced.rollup.shards
    ));
    fig.row(format!(
        "syncs: {} done / {} skipped (energy-gated); radio+merge energy delta {:.1} mJ total",
        synced.rollup.syncs_done.total as u64,
        synced.rollup.syncs_skipped.total as u64,
        (synced.rollup.energy_uj.total - isolated.rollup.energy_uj.total) / 1000.0
    ));
    fig.series.push(iso_s);
    fig.series.push(syn_s);
    Ok(fig)
}

/// Fig. 7(c): presence accuracy across three areas vs the RSSI
/// running-mean-threshold baseline.
pub fn fig7c(seed: u64) -> Result<FigData> {
    let mut fig = FigData::new(
        "fig7c",
        "Human presence accuracy across area moves (vs threshold baseline)",
        "hours",
        "accuracy",
    );
    let horizon = 30 * H;
    let il = AppKind::Presence.spec(seed, horizon);
    // Baseline: same world, same duty-cycled execution, threshold learner.
    let mut base_spec = AppKind::Presence.spec(seed, horizon);
    base_spec.scheduler = SchedulerKind::Alpaca { learn_pct: 0.5 };
    let mut results = par_run(vec![il])?;
    let il_r = results.remove(0);

    // threshold baseline needs a custom learner: swap it on the built
    // engine (the builder wires the default; engine parts stay public)
    let base_r = {
        let mut e = base_spec.build_engine()?;
        e.learner = Box::new(RunningMeanThreshold::new(0, 2.5));
        e.run()?
    };

    fig.series.push(accuracy_series("intermittent_learning", &il_r));
    fig.series.push(accuracy_series("rssi_threshold_baseline", &base_r));
    fig.row(format!(
        "IL mean {:.2} vs threshold baseline mean {:.2} (paper: baseline stays <0.50)",
        il_r.mean_accuracy(3),
        base_r.mean_accuracy(3)
    ));
    Ok(fig)
}

/// Fig. 8(c): vibration (gentle vs abrupt) classification accuracy, 4 h.
pub fn fig8c(seed: u64) -> Result<FigData> {
    let mut fig = FigData::new(
        "fig8c",
        "Vibration learning accuracy (gentle vs abrupt shaking)",
        "hours",
        "accuracy",
    );
    let r = AppKind::Vibration.spec(seed, 4 * H).build_engine()?.run()?;
    fig.series.push(accuracy_series("vibration(kmeans, piezo)", &r));
    fig.row(format!(
        "vibration: mean accuracy {:.2} (paper: 0.76), final {:.2}, learned {}",
        r.mean_accuracy(2),
        r.final_accuracy(),
        r.learned
    ));
    Ok(fig)
}

fn duty_schedulers(mayfly: bool) -> Vec<SchedulerKind> {
    let pcts = [0.1, 0.5, 0.9];
    let mut v = vec![SchedulerKind::Planner];
    for p in pcts {
        v.push(if mayfly {
            SchedulerKind::Mayfly {
                learn_pct: p,
                // Mayfly data-expiration: examples stale after 2 minutes
                expiry_us: 120_000_000,
            }
        } else {
            SchedulerKind::Alpaca { learn_pct: p }
        });
    }
    v
}

fn app_horizon(kind: AppKind) -> u64 {
    match kind {
        AppKind::AirQuality => 48 * H,
        AppKind::Presence => 24 * H,
        AppKind::Vibration => 8 * H,
    }
}

/// Figs. 9/10: accuracy of the intermittent learner vs Alpaca/Mayfly at
/// [10/50/90]% learn duty cycles, for all three apps.
pub fn fig9_10(seed: u64, mayfly: bool) -> Result<FigData> {
    let (id, base) = if mayfly {
        ("fig10", "Mayfly")
    } else {
        ("fig9", "Alpaca")
    };
    let mut fig = FigData::new(
        id,
        &format!("Accuracy vs {base} duty-cycled baselines"),
        "hours",
        "accuracy",
    );
    for kind in AppKind::ALL {
        let mut specs = Vec::new();
        for sched in duty_schedulers(mayfly) {
            let mut s = kind.spec(seed, app_horizon(kind));
            s.scheduler = sched;
            specs.push(s);
        }
        let scheds = duty_schedulers(mayfly);
        let results = par_run(specs)?;
        for (sched, r) in scheds.iter().zip(&results) {
            let name = format!("{}/{}", kind.name(), sched.label());
            fig.series.push(accuracy_series(&name, r));
        }
        let il = &results[0];
        let best_base = results[1..]
            .iter()
            .map(|r| r.mean_accuracy(3))
            .fold(0.0f64, f64::max);
        let base90 = &results[3];
        fig.row(format!(
            "{}: IL {:.2} (learned {}) vs best {base} {:.2}; IL learn actions = {:.0}% of {base}[90l] ({} vs {})",
            kind.name(),
            il.mean_accuracy(3),
            il.learned,
            best_base,
            100.0 * il.learned as f64 / base90.learned.max(1) as f64,
            il.learned,
            base90.learned,
        ));
    }
    Ok(fig)
}

/// Fig. 11: cumulative energy vs Alpaca duty cycles over time.
pub fn fig11(seed: u64) -> Result<FigData> {
    let mut fig = FigData::new(
        "fig11",
        "Cumulative energy consumption vs Alpaca",
        "hours",
        "energy_mj",
    );
    for kind in AppKind::ALL {
        let mut specs = Vec::new();
        for sched in duty_schedulers(false) {
            let mut s = kind.spec(seed, app_horizon(kind));
            s.scheduler = sched;
            specs.push(s);
        }
        let scheds = duty_schedulers(false);
        let results = par_run(specs)?;
        for (sched, r) in scheds.iter().zip(&results) {
            let mut s = Series::new(format!("{}/{}", kind.name(), sched.label()));
            for &(t, e) in &r.energy_series {
                s.push(t as f64 / H as f64, e / 1000.0);
            }
            fig.series.push(s);
        }
        let il = &results[0];
        let a90 = &results[3];
        fig.row(format!(
            "{}: IL total {:.0} mJ vs Alpaca[90l] {:.0} mJ ({:+.0}%); accuracies {:.2} vs {:.2}",
            kind.name(),
            il.energy_uj / 1000.0,
            a90.energy_uj / 1000.0,
            100.0 * (il.energy_uj - a90.energy_uj) / a90.energy_uj.max(1.0),
            il.mean_accuracy(3),
            a90.mean_accuracy(3),
        ));
    }
    Ok(fig)
}

/// Collect a training set + probes for the offline detectors by scanning
/// the sensor world the same way the device would sense it.
fn offline_dataset(
    sensor: &dyn Sensor,
    be: &mut dyn ComputeBackend,
    horizon_us: u64,
    n_train: usize,
) -> Result<(Vec<Vec<f32>>, Vec<(Vec<f32>, bool)>)> {
    let step = horizon_us / n_train as u64;
    let mut train = Vec::with_capacity(n_train);
    for i in 0..n_train {
        let w = sensor.window(i as u64 * step, WINDOW).fit(WINDOW, CHANNELS);
        train.push(be.extract(&w.data)?);
    }
    let probes = build_probes(sensor, be, horizon_us, 60, horizon_us / 700)?
        .into_iter()
        .map(|p| (p.example.features, p.example.truth_abnormal))
        .collect();
    Ok((train, probes))
}

/// Fig. 12 / Table 5: intermittent learner vs offline detectors.
pub fn fig12(seed: u64) -> Result<FigData> {
    let mut fig = FigData::new(
        "fig12",
        "Accuracy vs offline anomaly detectors (OC-SVM, iForest, AR(IMA))",
        "app",
        "accuracy",
    );
    let mut il_specs = Vec::new();
    for kind in AppKind::ALL {
        il_specs.push(kind.spec(seed, app_horizon(kind)));
    }
    let il_results = par_run(il_specs)?;

    for (kind, il) in AppKind::ALL.iter().zip(&il_results) {
        let spec = kind.spec(seed, app_horizon(*kind));
        let sensor = spec.build_sensor();
        let mut be = NativeBackend::new();
        let (train, probes) =
            offline_dataset(sensor.as_ref(), &mut be, spec.horizon_us, 240)?;

        let mut svm = OneClassSvm::new(0.1);
        svm.fit(&train);
        let mut forest = IsolationForest::new(0.1, seed);
        forest.fit(&train);
        let mut ar = ArDetector::new(2, 3.0);
        ar.fit(&train);

        let accs: Vec<(String, f64)> = vec![
            ("intermittent_learning".into(), il.mean_accuracy(4)),
            ("one_class_svm".into(), detector_accuracy(&svm, &probes)),
            ("isolation_forest".into(), detector_accuracy(&forest, &probes)),
            ("arima".into(), detector_accuracy(&ar, &probes)),
        ];
        let learned_pct = 100.0 * il.learned as f64 / il.sensed.max(1) as f64;
        fig.row(format!(
            "{}: IL {:.2} (learned {:.1}% of sensed examples) | svm {:.2} | iforest {:.2} | arima {:.2}",
            kind.name(),
            accs[0].1,
            learned_pct,
            accs[1].1,
            accs[2].1,
            accs[3].1
        ));
        for (name, acc) in accs {
            let mut s = Series::new(format!("{}/{}", kind.name(), name));
            s.push(0.0, acc);
            fig.series.push(s);
        }
    }
    Ok(fig)
}

/// Figs. 13/14: effect of the example-selection heuristics — accuracy vs
/// number of learned examples (13) or vs energy (14).
pub fn fig13_14(seed: u64, vs_energy: bool) -> Result<FigData> {
    let (id, x) = if vs_energy {
        ("fig14", "energy_mj")
    } else {
        ("fig13", "learned_examples")
    };
    let mut fig = FigData::new(
        id,
        "Effect of example-selection heuristics",
        x,
        "accuracy",
    );
    for kind in AppKind::ALL {
        let mut specs = Vec::new();
        for h in Heuristic::ALL {
            let mut s = kind.spec(seed, app_horizon(kind));
            s.heuristic = h;
            specs.push(s);
        }
        let results = par_run(specs)?;
        for (h, r) in Heuristic::ALL.iter().zip(&results) {
            let mut s = Series::new(format!("{}/{}", kind.name(), h.name()));
            for c in &r.checkpoints {
                let xv = if vs_energy {
                    c.energy_uj / 1000.0
                } else {
                    c.learned as f64
                };
                s.push(xv, c.accuracy);
            }
            fig.series.push(s);
        }
        let accs: Vec<String> = Heuristic::ALL
            .iter()
            .zip(&results)
            .map(|(h, r)| {
                format!(
                    "{} {:.2}@{}ex",
                    h.name(),
                    r.mean_accuracy(4),
                    r.learned
                )
            })
            .collect();
        fig.row(format!("{}: {}", kind.name(), accs.join(" | ")));
    }
    Ok(fig)
}

/// Fig. 15: energy-harvesting pattern vs accuracy for the three sources.
pub fn fig15(seed: u64) -> Result<FigData> {
    let mut fig = FigData::new(
        "fig15",
        "Energy harvesting pattern vs detection accuracy",
        "hours",
        "accuracy / voltage",
    );
    // (a) solar, 3 days
    let mut solar = AppKind::AirQuality.spec(seed, 72 * H);
    solar.scheduler = SchedulerKind::Planner;
    // (b) RF at 3/5/7 m for 3 h each
    let mut rf = AppKind::Presence.spec(seed, 9 * H);
    rf.set_rf_distances(vec![(0, 3.0), (3 * H, 5.0), (6 * H, 7.0)])?;
    // (c) piezo gentle/abrupt alternating 4 h (the app default)
    let piezo = AppKind::Vibration.spec(seed, 4 * H);

    let results = par_run(vec![solar, rf, piezo])?;
    let names = ["solar_3days", "rf_3_5_7m", "piezo_gentle_abrupt"];
    for (name, r) in names.iter().zip(&results) {
        fig.series.push(accuracy_series(&format!("{name}/accuracy"), r));
        let mut v = Series::new(format!("{name}/voltage"));
        for c in &r.checkpoints {
            v.push(c.t_us as f64 / H as f64, c.voltage);
        }
        fig.series.push(v);
    }
    let rf_r = &results[1];
    // the paper reports accuracy *at* hours 3/6/9 — the end of each
    // distance segment (the learner has adapted as much as it will)
    let thirds: Vec<f64> = (0..3)
        .map(|i| {
            let lo = i as u64 * 3 * H;
            let hi = lo + 3 * H;
            rf_r.checkpoints
                .iter()
                .filter(|c| c.t_us > lo && c.t_us <= hi)
                .last()
                .map(|c| c.accuracy)
                .unwrap_or(0.0)
        })
        .collect();
    fig.row(format!(
        "rf accuracy at segment end (h3/h6/h9): 3m {:.2}, 5m {:.2}, 7m {:.2} (paper: 0.86/0.74/0.46 decreasing)",
        thirds[0], thirds[1], thirds[2]
    ));
    fig.row(format!(
        "solar: mean {:.2}; piezo: final {:.2} (paper: solar diurnal recovery; piezo converges 0.80)",
        results[0].mean_accuracy(6),
        results[2].final_accuracy()
    ));
    Ok(fig)
}

/// Fig. 16: energy and time of each action (k-NN and NN-k-means tables).
pub fn fig16() -> Result<FigData> {
    let mut fig = FigData::new(
        "fig16",
        "Energy and execution time per action",
        "action",
        "energy_uj / time_ms",
    );
    for m in [CostModel::knn(), CostModel::kmeans()] {
        fig.row(format!("-- {} --", m.name));
        // only the paper's eight Table-1 primitives: the trailing radio
        // pair (tx/rx) is ours and belongs to sync16, not a reproduction
        // of the paper's figure
        for &a in &Action::ALL[..8] {
            let c = m.cost(a);
            fig.row(format!(
                "{:<10} {:>12.1} uJ {:>12.2} ms  (splits {})",
                a.name(),
                c.energy_uj,
                c.time_us as f64 / 1000.0,
                c.splits
            ));
            let mut s = Series::new(format!("{}/{}", m.name, a.name()));
            s.push(0.0, c.energy_uj);
            s.push(1.0, c.time_us as f64 / 1000.0);
            fig.series.push(s);
        }
    }
    fig.row("paper anchors: knn.learn 9309 uJ/1551 ms; kmeans.learn 5417 uJ/953.6 ms; kmeans.infer 63.2 uJ/9.47 ms");
    Ok(fig)
}

/// Fig. 17: overhead of the dynamic action planner and the selection
/// heuristics — cost-model values plus *measured* decision latency.
pub fn fig17(seed: u64) -> Result<FigData> {
    let mut fig = FigData::new(
        "fig17",
        "Planner and example-selection overhead",
        "component",
        "energy_uj / time",
    );
    let m = CostModel::kmeans();
    fig.row(format!(
        "planner        {:>8.1} uJ {:>8.2} ms (paper: 57 uJ / 4.3 ms)",
        m.planner.energy_uj,
        m.planner.time_us as f64 / 1000.0
    ));
    fig.row(format!(
        "round_robin    {:>8.1} uJ   |  k_last {:>8.1} uJ  |  randomized {:>8.1} uJ (paper: 270 uJ vs 1.8 uJ)",
        m.sel_round_robin.energy_uj, m.sel_k_last.energy_uj, m.sel_randomized.energy_uj
    ));

    // measured host-side decision latency of the planner search
    let mut planner = DynamicActionPlanner::default();
    let ctx = PlanContext {
        learned_total: 10,
        quality: 0.5,
        window_learns: 1,
        window_infers: 1,
        window_cycle: 2,
        forecast_uj: None,
    };
    let pending = vec![Action::Decide, Action::Sense];
    let meas = bench::bench("planner.next_action", 60, || {
        bench::black_box(planner.next_action(&pending, &ctx, &m));
    });
    fig.row(format!("measured planner decision: {}", meas.row()));

    // overhead fraction from a real run (paper: <= 3.5% energy)
    let mut engine = AppKind::Vibration.spec(seed, 2 * H).build_engine()?;
    engine.meter = crate::energy::EnergyMeter::new();
    let r = engine.run()?;
    let planner_uj: f64 = r
        .action_tallies
        .iter()
        .filter(|(n, ..)| n == "planner")
        .map(|(_, _, e, _)| *e)
        .sum();
    fig.row(format!(
        "planner energy share in a 2h vibration run: {:.1}% (paper: <=3.5%... 4.3%)",
        100.0 * planner_uj / r.energy_uj.max(1.0)
    ));
    let mut s = Series::new("planner_overhead_pct");
    s.push(0.0, 100.0 * planner_uj / r.energy_uj.max(1.0));
    fig.series.push(s);
    Ok(fig)
}

/// Tables 3/4: average accuracy summary vs Alpaca/Mayfly.
pub fn table34(seed: u64, mayfly: bool) -> Result<FigData> {
    let base = if mayfly { "Mayfly" } else { "Alpaca" };
    let mut fig = fig9_10(seed, mayfly)?;
    fig.id = if mayfly { "table4" } else { "table3" }.into();
    fig.title = format!("Average accuracy: intermittent learning vs {base}");
    // rows already carry the summary; add the overall average
    let il_mean: f64 = fig
        .series
        .iter()
        .filter(|s| s.name.contains("intermittent_learning"))
        .map(|s| s.mean_y())
        .sum::<f64>()
        / 3.0;
    fig.row(format!(
        "overall IL average accuracy {:.2} (paper: 0.80 vs {base} 0.54-0.79)",
        il_mean
    ));
    Ok(fig)
}

/// Table 5: summary vs offline detectors.
pub fn table5(seed: u64) -> Result<FigData> {
    let mut fig = fig12(seed)?;
    fig.id = "table5".into();
    fig.title = "Average accuracy vs offline detectors (paper: IL 0.80 vs 0.78/0.86/0.83, learning 44% of examples)".into();
    Ok(fig)
}

/// Make a learner checkpoint/restore stress run for failure injection
/// tests (exposed for integration tests).
pub fn quick_run(kind: AppKind, seed: u64, hours: u64) -> Result<RunResult> {
    kind.spec(seed, hours * H).build_engine()?.run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_ids_dispatch() {
        // only the cheap ones here; the expensive figures run in benches
        let f = generate("fig16", 1).unwrap();
        assert!(f.rows.iter().any(|r| r.contains("9309")));
        assert!(generate("nope", 1).is_err());
    }

    #[test]
    fn fig8c_reaches_reasonable_accuracy() {
        let f = fig8c(3).unwrap();
        assert!(!f.series.is_empty());
        let last = f.series[0].last_y();
        assert!(last >= 0.6, "vibration final accuracy {last}");
    }

    #[test]
    fn par_run_preserves_order_and_determinism() {
        let mk = || {
            let mut s = AppKind::Vibration.spec(9, 2 * H);
            s.heuristic = Heuristic::Randomized;
            s
        };
        let a = par_run(vec![mk(), mk()]).unwrap();
        assert_eq!(a[0].learned, a[1].learned);
        assert_eq!(a[0].energy_uj, a[1].energy_uj);
        let b = par_run(vec![mk()]).unwrap();
        assert_eq!(a[0].learned, b[0].learned);
    }
}
