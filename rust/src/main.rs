//! `ilearn` — CLI for the intermittent-learning reproduction.
//!
//! Subcommands:
//!   run     — run one scenario (paper preset or JSON spec) end-to-end
//!   fleet   — run one scenario sharded across N devices, with fan-in rollups
//!   sweep   — expand a JSON grid spec and run every cell on worker threads
//!   figure  — regenerate a paper figure/table (fig6c..fig17, table3..5)
//!   inspect — energy pre-inspection of an app's action set (§3.5 tool)
//!   analyze — intermittent-safety analysis of every checkpoint path
//!   list    — list scenario presets, figures, heuristics, schedulers
//!
//! Examples:
//!   ilearn run vibration --hours 4 --scheduler alpaca:50
//!   ilearn run --spec my_scenario.json
//!   ilearn fleet air_quality --shards 16 --jitter-us 60000000
//!   ilearn fleet --spec my_scenario.json --shards 8 --threads 4
//!   ilearn sweep examples/paper_matrix.json --out out/sweep --threads 8
//!   ilearn figure fig9 --out out/

use anyhow::{bail, Context, Result};
use ilearn::apps::AppKind;
use ilearn::energy::inspect;
use ilearn::eval::figures;
use ilearn::scenario::{
    BackendKind, FleetSpec, PolicySpec, ScenarioSpec, SchedulerKind, SweepRunner, SweepSpec,
    SyncSpec, PRESETS,
};
use ilearn::selection::Heuristic;
use ilearn::sim::RunResult;

const H: u64 = 3_600_000_000;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("fleet") => cmd_fleet(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("figure") => cmd_figure(&args[1..]),
        Some("inspect") => cmd_inspect(&args[1..]),
        Some("analyze") => cmd_analyze(&args[1..]),
        Some("crash") => cmd_crash(&args[1..]),
        Some("list") => cmd_list(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown command `{other}` (try `ilearn help`)"),
    }
}

fn print_help() {
    println!(
        "ilearn — Intermittent Learning (IMWUT'19) reproduction\n\
         \n\
         USAGE: ilearn <command> [options]\n\
         \n\
         COMMANDS:\n\
           run <scenario>   run a scenario preset (air_quality|presence|vibration)\n\
               --hours N        simulated hours            [default per app]\n\
               --seed N         experiment seed            [default 42]\n\
               --backend B      native|pjrt                [default native]\n\
               --scheduler S    planner|alpaca:<pct>|mayfly:<pct>:<expiry_s>\n\
               --heuristic X    round_robin|k_last_lists|randomized|none\n\
               --forecast       forecast-aware planning: checkpoint elision,\n\
                                harvest-sized bursts, sync energy reserves\n\
           run --spec FILE  run a declarative scenario spec (JSON)\n\
               --seed/--backend/--scheduler/--heuristic override the spec\n\
               (--hours is preset-only: spec worlds are horizon-derived)\n\
           fleet <scenario> | fleet --spec FILE\n\
                            run one scenario sharded across N devices and\n\
                            fan the per-shard results into rollups\n\
               --shards N       shard count                [default: spec fleet, else 1]\n\
               --jitter-us J    per-shard harvester phase offset (shard i: i x J)\n\
               --stride S       per-shard seed stride      [default 1]\n\
               --sync-period-us P   federated sync boundary period (0 = isolated)\n\
               --sync-strategy S    gossip|all_reduce      [default gossip]\n\
               --sched S        event|rounds coordinator for synced fleets\n\
                                [default event; rounds = reference barrier]\n\
               --stream         streaming fan-in: fold rollups + quantile\n\
                                sketches shard by shard and drop per-shard\n\
                                results (bounded memory at any shard count;\n\
                                auto above 4095 isolated shards)\n\
               --threads N      worker threads             [default: all cores]\n\
               (run's --seed/--backend/--scheduler/--heuristic/--forecast apply too)\n\
           sweep <FILE>     expand a JSON grid spec (scenarios x schedulers x\n\
                            heuristics x backends x seeds) and run every cell\n\
                            on worker threads, one JSON result per cell\n\
               --out DIR        output directory           [default out/sweep]\n\
               --threads N      worker threads             [default: all cores]\n\
           figure <id>      regenerate a figure/table (see `ilearn list`; `all`)\n\
               --seed N --out DIR   write <id>.json under DIR\n\
           inspect <app>    energy pre-inspection (per-action worst case)\n\
               --budget-uj E    per-wake energy budget     [default: capacitor]\n\
           analyze <scenario>... | analyze --all\n\
                            lint every checkpoint path (learner x backend +\n\
                            run-state) for WAR hazards (IL-WAR), unbracketed\n\
                            writes (IL-ATOM), delta-checkpoint divergence\n\
                            (IL-DELTA) and restore parity (IL-PARITY);\n\
                            exits non-zero on any finding. Needs a dev\n\
                            (debug_assertions) build: `cargo run -- analyze`\n\
               --out DIR        write <scenario>.json reports under DIR\n\
           crash <scenario>... | crash --all | crash --spec FILE\n\
                            crash-consistency sweep: enumerate every NVM\n\
                            persist step of a reference run, then re-execute\n\
                            once per cut point (power cut at a step boundary\n\
                            or a torn write inside one) and assert the store\n\
                            self-heals to a bit-exact commit boundary and the\n\
                            run state + learner restore cleanly; exits\n\
                            non-zero on any consistency violation\n\
               --exhaustive     every boundary + tear point (small runs)\n\
               --sample N       N seeded cut points        [default 16]\n\
               --seed N         scenario seed              [default 42]\n\
               --hours N        simulated hours            [default 1]\n\
               --out DIR        write <scenario>.json reports under DIR\n\
           list             scenario presets, figures, schedulers, heuristics"
    );
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn hours_to_us(hours: u64) -> Result<u64> {
    hours
        .checked_mul(H)
        .with_context(|| format!("--hours {hours} overflows the simulated horizon"))
}

/// Resolve the `run` arguments to a scenario spec. Flags apply on top of
/// both sources: a preset or a `--spec` file.
fn run_spec(args: &[String]) -> Result<ScenarioSpec> {
    let mut spec = if let Some(path) = flag(args, "--spec") {
        if let Some(name) = args.first().filter(|a| !a.starts_with("--")) {
            bail!(
                "`ilearn run {name} --spec {path}` is ambiguous — pass either a preset \
                 name or --spec, not both"
            );
        }
        if flag(args, "--hours").is_some() {
            // presets regenerate horizon-derived parts (motion protocol,
            // checkpoint cadence) for the requested hours; a spec file
            // pins them, so stretching only horizon_us would run a world
            // that goes dead past the spec's original horizon
            bail!(
                "--hours cannot rescale a spec file (its motion/sensor worlds are \
                 horizon-derived); edit `horizon_us` and the dependent fields in `{path}`"
            );
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("cannot read spec file `{path}`"))?;
        ScenarioSpec::parse(&text).with_context(|| format!("bad scenario spec `{path}`"))?
    } else {
        let app = args
            .first()
            .filter(|a| !a.starts_with("--"))
            .context("usage: ilearn run <scenario> [options] | ilearn run --spec <file>")?;
        let kind = AppKind::parse(app).with_context(|| {
            format!("unknown scenario `{app}` (presets: {})", PRESETS.join(", "))
        })?;
        let hours: u64 = match flag(args, "--hours") {
            Some(h) => h.parse()?,
            None => match kind {
                AppKind::AirQuality => 48,
                AppKind::Presence => 24,
                AppKind::Vibration => 8,
            },
        };
        kind.spec(42, hours_to_us(hours)?)
    };
    if let Some(s) = flag(args, "--seed") {
        spec.seed = s.parse()?;
    }
    if let Some(b) = flag(args, "--backend") {
        spec.backend = BackendKind::parse(&b)
            .with_context(|| format!("unknown backend `{b}` (native|pjrt)"))?;
    }
    if let Some(s) = flag(args, "--scheduler") {
        spec.scheduler = SchedulerKind::parse(&s)?;
    }
    if let Some(h) = flag(args, "--heuristic") {
        spec.heuristic =
            Heuristic::parse(&h).with_context(|| format!("unknown heuristic `{h}`"))?;
    }
    if args.iter().any(|a| a == "--forecast") {
        spec.policy = Some(PolicySpec { forecast: true });
    }
    Ok(spec)
}

fn print_run_summary(spec: &ScenarioSpec, r: &RunResult, wall_s: f64) {
    println!("== run summary: {} / {} ==", spec.name, r.scheduler);
    println!("  wake cycles        {}", r.cycles);
    println!("  examples sensed    {}", r.sensed);
    println!("  examples learned   {}", r.learned);
    println!("  inferences         {}", r.inferred);
    println!("  discarded (select) {}", r.discarded_select);
    println!("  expired (mayfly)   {}", r.expired);
    println!("  power failures     {}", r.power_failures);
    if r.checkpoints_taken + r.checkpoints_elided > 0 {
        println!("  checkpoints taken  {}", r.checkpoints_taken);
        println!("  checkpoints elided {}", r.checkpoints_elided);
        println!("  learns deferred    {}", r.learns_deferred);
        println!("  ckpt NVM bytes     {}", r.ckpt_nvm_bytes);
    }
    println!("  energy             {:.1} mJ", r.energy_uj / 1000.0);
    println!("  mean probe acc.    {:.3}", r.mean_accuracy(3));
    println!("  final probe acc.   {:.3}", r.final_accuracy());
    println!("  online infer acc.  {:.3}", r.online_accuracy());
    println!("  wallclock          {wall_s:.2}s");
    println!("  accuracy trajectory:");
    for c in &r.checkpoints {
        println!(
            "    t={:>6.1}h acc={:.2} learned={:<5} E={:>9.1} mJ V={:.2}",
            c.t_us as f64 / H as f64,
            c.accuracy,
            c.learned,
            c.energy_uj / 1000.0,
            c.voltage
        );
    }
}

fn cmd_run(args: &[String]) -> Result<()> {
    let spec = run_spec(args)?;
    eprintln!(
        "running scenario `{}` for {:.1} h (seed {}, backend {}, scheduler {}) ...",
        spec.name,
        spec.horizon_us as f64 / H as f64,
        spec.seed,
        spec.backend.name(),
        spec.scheduler.label()
    );
    let t0 = std::time::Instant::now();
    let r = spec.build_engine()?.run()?;
    print_run_summary(&spec, &r, t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_fleet(args: &[String]) -> Result<()> {
    let mut spec = run_spec(args)?;
    // CLI flags layer onto the spec's own fleet block (created on demand)
    if let Some(n) = flag(args, "--shards") {
        spec.fleet.get_or_insert_with(FleetSpec::default).shards = n.parse()?;
    }
    if let Some(j) = flag(args, "--jitter-us") {
        spec.fleet.get_or_insert_with(FleetSpec::default).phase_jitter_us = j.parse()?;
    }
    if let Some(s) = flag(args, "--stride") {
        spec.fleet.get_or_insert_with(FleetSpec::default).seed_stride = s.parse()?;
    }
    if args.iter().any(|a| a == "--stream") {
        spec.fleet.get_or_insert_with(FleetSpec::default).stream = Some(true);
    }
    if let Some(p) = flag(args, "--sync-period-us") {
        let period_us: u64 = p.parse()?;
        let fleet = spec.fleet.get_or_insert_with(FleetSpec::default);
        if period_us == 0 {
            fleet.sync = None; // explicit isolation override
        } else {
            fleet
                .sync
                .get_or_insert(SyncSpec {
                    period_us,
                    strategy: ilearn::sim::SyncStrategy::Gossip,
                    radio: None,
                })
                .period_us = period_us;
        }
    }
    if let Some(s) = flag(args, "--sync-strategy") {
        let strategy = ilearn::sim::SyncStrategy::parse(&s)
            .with_context(|| format!("unknown sync strategy `{s}` (gossip|all_reduce)"))?;
        let fleet = spec.fleet.get_or_insert_with(FleetSpec::default);
        match &mut fleet.sync {
            Some(sync) => sync.strategy = strategy,
            None => bail!("--sync-strategy needs --sync-period-us (or a spec sync block)"),
        }
    }
    if let Some(s) = flag(args, "--sched") {
        let sched = ilearn::sim::FleetSched::parse(&s)
            .with_context(|| format!("unknown fleet sched `{s}` (event|rounds)"))?;
        spec.fleet.get_or_insert_with(FleetSpec::default).sched = Some(sched);
    }
    let threads: usize = flag(args, "--threads").map_or(Ok(0), |s| s.parse())?;
    let fleet = spec.fleet.clone().unwrap_or_default();
    let sync_desc = match &fleet.sync {
        Some(s) => format!("sync {} every {:.1} s", s.strategy.name(), s.period_us as f64 / 1e6),
        None => "isolated".into(),
    };
    eprintln!(
        "running fleet `{}`: {} shard(s) for {:.1} h each (seed {} stride {}, jitter {} us, \
         {}, scheduler {}) ...",
        spec.name,
        fleet.shards,
        spec.horizon_us as f64 / H as f64,
        spec.seed,
        fleet.seed_stride,
        fleet.phase_jitter_us,
        sync_desc,
        spec.scheduler.label()
    );
    let t0 = std::time::Instant::now();
    if fleet.streaming() {
        // population-scale path: fold-and-drop fan-in, O(1) memory in
        // the shard count, no per-shard table
        eprintln!("  (streaming fan-in: rollups + sketches, no per-shard results)");
        let sr = spec.run_fleet_streaming(threads)?;
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "== fleet summary: {} x {} shard(s), streamed on {} worker(s) ==",
            spec.name, sr.rollup.shards, sr.workers
        );
        let roll = &sr.rollup;
        println!("  rollups (mean / min / max / total):");
        for (name, r) in [
            ("final_accuracy", roll.final_accuracy),
            ("mean_accuracy", roll.mean_accuracy),
            ("energy_uj", roll.energy_uj),
            ("learned", roll.learned),
            ("inferred", roll.inferred),
            ("power_failures", roll.power_failures),
            ("stale_plans", roll.stale_plans),
        ] {
            println!(
                "    {name:<15} {:>12.3} {:>12.3} {:>12.3} {:>14.3}",
                r.mean, r.min, r.max, r.total
            );
        }
        let sk = &sr.sketches;
        println!("  sketches (p50 / p90 / p99):");
        for (name, s) in [
            ("final_accuracy", &sk.final_accuracy),
            ("mean_accuracy", &sk.mean_accuracy),
            ("energy_uj", &sk.energy_uj),
            ("learned", &sk.learned),
            ("inferred", &sk.inferred),
            ("power_failures", &sk.power_failures),
            ("stale_plans", &sk.stale_plans),
        ] {
            println!(
                "    {name:<15} {:>12.3} {:>12.3} {:>12.3}",
                s.quantile(0.5),
                s.quantile(0.9),
                s.quantile(0.99)
            );
        }
        println!(
            "  pooled: {} NVM slab reuse(s), {} backend reuse(s)",
            sr.slab_reuses, sr.backend_reuses
        );
        println!(
            "  wallclock          {:.2}s ({:.0} shards/s)",
            secs,
            sr.rollup.shards as f64 / secs.max(1e-9)
        );
        if let Some(out) = flag(args, "--out") {
            std::fs::create_dir_all(&out)?;
            let path = format!("{out}/{}-fleet.json", spec.label());
            std::fs::write(&path, sr.to_json().to_string())?;
            eprintln!("wrote {path}");
        }
        return Ok(());
    }
    let fr = spec.run_fleet(threads)?;
    println!("== fleet summary: {} x {} shard(s) ==", spec.name, fr.shards.len());
    let synced = fr.rollup.syncs_done.total
        + fr.rollup.syncs_skipped.total
        + fr.rollup.syncs_solo.total
        > 0.0;
    println!(
        "{:>6} {:>6} {:>8} {:>8} {:>10} {:>9} {:>9}{}",
        "shard",
        "seed",
        "learned",
        "infer",
        "energy_mJ",
        "mean_acc",
        "final_acc",
        if synced { "     syncs" } else { "" }
    );
    for (i, r) in fr.shards.iter().enumerate() {
        let sh = spec.shard(i as u32)?;
        let syncs = if synced {
            format!("  {}/{}", r.syncs_done, r.syncs_done + r.syncs_skipped)
        } else {
            String::new()
        };
        println!(
            "{i:>6} {:>6} {:>8} {:>8} {:>10.1} {:>9.3} {:>9.3}{syncs}",
            sh.seed,
            r.learned,
            r.inferred,
            r.energy_uj / 1000.0,
            r.mean_accuracy(3),
            r.final_accuracy()
        );
    }
    let roll = &fr.rollup;
    println!("  rollups (mean / min / max / total):");
    let mut rows = vec![
        ("final_accuracy", roll.final_accuracy),
        ("mean_accuracy", roll.mean_accuracy),
        ("energy_uj", roll.energy_uj),
        ("learned", roll.learned),
        ("inferred", roll.inferred),
        ("power_failures", roll.power_failures),
        ("stale_plans", roll.stale_plans),
    ];
    if synced {
        rows.push(("syncs_done", roll.syncs_done));
        rows.push(("syncs_skipped", roll.syncs_skipped));
        rows.push(("syncs_solo", roll.syncs_solo));
    }
    for (name, r) in rows {
        println!(
            "    {name:<15} {:>12.3} {:>12.3} {:>12.3} {:>14.3}",
            r.mean, r.min, r.max, r.total
        );
    }
    println!("  wallclock          {:.2}s", t0.elapsed().as_secs_f64());
    if let Some(out) = flag(args, "--out") {
        std::fs::create_dir_all(&out)?;
        let path = format!("{out}/{}-fleet.json", spec.label());
        std::fs::write(&path, fr.to_json().to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let path = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .context("usage: ilearn sweep <grid.json> [--out DIR] [--threads N]")?;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("cannot read sweep spec `{path}`"))?;
    let sweep = SweepSpec::parse(&text).with_context(|| format!("bad sweep spec `{path}`"))?;
    let threads: usize = flag(args, "--threads").map_or(Ok(0), |s| s.parse())?;
    let out_dir = flag(args, "--out").unwrap_or_else(|| "out/sweep".into());

    let cells = sweep.expand()?;
    let jobs: usize = cells.iter().map(|c| c.spec.shard_count() as usize).sum();
    eprintln!(
        "sweep `{}`: {} cell(s) / {jobs} shard job(s) on {} worker thread(s), \
         writing {out_dir}/<cell>.json ...",
        sweep.name,
        cells.len(),
        ilearn::scenario::sweep::resolve_workers(threads, jobs)
    );
    let t0 = std::time::Instant::now();
    let outcomes = SweepRunner::new(threads).run_cells(cells);

    std::fs::create_dir_all(&out_dir)?;
    println!(
        "{:<58} {:>7} {:>7} {:>9} {:>9} {:>9}",
        "cell", "learned", "infer", "mean_acc", "final_acc", "energy_mJ"
    );
    let mut failed = 0usize;
    for o in &outcomes {
        let path = format!("{out_dir}/{}.json", o.id);
        std::fs::write(&path, o.to_json().to_string())?;
        match &o.result {
            // fleet cells print their rollup means; plain cells their run
            Ok(f) if f.shards.len() > 1 => println!(
                "{:<58} {:>7} {:>7} {:>9.3} {:>9.3} {:>9.1}  (x{} shards)",
                o.id,
                f.rollup.learned.total as u64,
                f.rollup.inferred.total as u64,
                f.rollup.mean_accuracy.mean,
                f.rollup.final_accuracy.mean,
                f.rollup.energy_uj.total / 1000.0,
                f.shards.len()
            ),
            Ok(f) => {
                let r = f.primary();
                println!(
                    "{:<58} {:>7} {:>7} {:>9.3} {:>9.3} {:>9.1}",
                    o.id,
                    r.learned,
                    r.inferred,
                    r.mean_accuracy(3),
                    r.final_accuracy(),
                    r.energy_uj / 1000.0
                )
            }
            Err(e) => {
                failed += 1;
                println!("{:<58} FAILED: {e}", o.id);
            }
        }
    }
    eprintln!(
        "({} cell(s) in {:.1}s; results under {out_dir}/)",
        outcomes.len(),
        t0.elapsed().as_secs_f64()
    );
    if failed > 0 {
        bail!(
            "{failed} of {} sweep cell(s) failed (see FAILED rows above; per-cell errors are in the JSON files)",
            outcomes.len()
        );
    }
    Ok(())
}

fn cmd_figure(args: &[String]) -> Result<()> {
    let id = args
        .first()
        .context("usage: ilearn figure <id> [--seed N] [--out DIR]")?;
    let seed: u64 = flag(args, "--seed").map_or(Ok(42), |s| s.parse())?;
    let t0 = std::time::Instant::now();
    let ids: Vec<String> = if id == "all" {
        figures::FIGURE_IDS.iter().map(|s| s.to_string()).collect()
    } else {
        vec![id.clone()]
    };
    for id in &ids {
        let fig = figures::generate(id, seed)?;
        println!("{}", fig.render());
        if let Some(dir) = flag(args, "--out") {
            std::fs::create_dir_all(&dir)?;
            let path = format!("{dir}/{id}.json");
            std::fs::write(&path, fig.to_json().to_string())?;
            eprintln!("wrote {path}");
        }
    }
    eprintln!(
        "({} figure(s) in {:.1}s)",
        ids.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let app = args
        .first()
        .context("usage: ilearn inspect <app> [--budget-uj E]")?;
    let kind = AppKind::parse(app).with_context(|| format!("unknown app `{app}`"))?;
    let spec = kind.spec(0, H);
    let cap = spec.build_capacitor();
    let budget: f64 = flag(args, "--budget-uj")
        .map_or(Ok(cap.full_budget_uj() * 0.8), |s| s.parse())?;
    let model = kind.cost_model();
    println!(
        "energy pre-inspection: app {} (cost model {}), budget {:.1} uJ/wake",
        kind.name(),
        model.name,
        budget
    );
    let report = inspect::inspect(&model, budget, 0.10);
    for (a, worst) in &report.measured {
        let verdict = if report.violations.iter().any(|v| v.action == *a) {
            "VIOLATION"
        } else {
            "ok"
        };
        println!(
            "  {:<10} worst-case {:>10.1} uJ   {}",
            a.name(),
            worst,
            verdict
        );
    }
    if report.passed() {
        println!("all actions fit the budget.");
    } else {
        println!("{} action(s) need splitting:", report.violations.len());
        for v in &report.violations {
            println!(
                "  {} -> split into {} sub-actions",
                v.action.name(),
                v.required_splits
            );
        }
        let (fixed, after) = inspect::auto_split(&model, budget, 0.10);
        assert!(after.passed());
        println!("auto-split result:");
        for a in ilearn::actions::Action::ALL {
            let c = fixed.cost(a);
            if c.splits > 1 {
                println!(
                    "  {:<10} {} sub-actions of {:.1} uJ",
                    a.name(),
                    c.splits,
                    c.sub_energy_uj()
                );
            }
        }
    }
    Ok(())
}

fn cmd_analyze(args: &[String]) -> Result<()> {
    let mut names: Vec<String> = Vec::new();
    let mut all = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--all" => all = true,
            "--out" => i += 1, // value consumed by flag()
            a if a.starts_with("--") => bail!("unknown analyze flag `{a}`"),
            a => names.push(a.to_string()),
        }
        i += 1;
    }
    if all {
        names = PRESETS.iter().map(|s| s.to_string()).collect();
    } else if names.is_empty() {
        bail!("usage: ilearn analyze <scenario>... | ilearn analyze --all [--out DIR]");
    }
    let out_dir = flag(args, "--out");
    if let Some(dir) = &out_dir {
        std::fs::create_dir_all(dir)?;
    }
    let t0 = std::time::Instant::now();
    let mut total = 0usize;
    for name in &names {
        let report = ilearn::analysis::analyze_preset(name)
            .with_context(|| format!("analyzing scenario `{name}`"))?;
        total += report.findings_total();
        println!("== analyze: {} ==", report.scenario);
        for entry in &report.entries {
            let verdict = if entry.findings.is_empty() {
                "clean".to_string()
            } else {
                format!("{} finding(s)", entry.findings.len())
            };
            println!("  {:<14} {:<8} {verdict}", entry.learner, entry.backend);
            for f in &entry.findings {
                let range = match f.range {
                    Some((s, e)) => format!(" [{s}..{e})"),
                    None => String::new(),
                };
                println!("    {:<10} {}{range}: {}", f.rule, f.key, f.detail);
            }
        }
        if let Some(dir) = &out_dir {
            let path = format!("{dir}/{name}.json");
            let mut text = report.to_json().to_string();
            text.push('\n');
            std::fs::write(&path, text)?;
            eprintln!("wrote {path}");
        }
    }
    eprintln!(
        "({} scenario(s) analyzed in {:.1}s)",
        names.len(),
        t0.elapsed().as_secs_f64()
    );
    if total > 0 {
        bail!("intermittent-safety analysis found {total} issue(s)");
    }
    println!("all checkpoint paths clean.");
    Ok(())
}

fn cmd_crash(args: &[String]) -> Result<()> {
    use ilearn::fault::sweep::sweep_scenario;
    use ilearn::fault::SweepMode;
    let mut names: Vec<String> = Vec::new();
    let mut all = false;
    let mut exhaustive = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--all" => all = true,
            "--exhaustive" => exhaustive = true,
            "--sample" | "--out" | "--spec" | "--seed" | "--hours" => i += 1,
            a if a.starts_with("--") => bail!("unknown crash flag `{a}`"),
            a => names.push(a.to_string()),
        }
        i += 1;
    }
    let mut specs: Vec<ScenarioSpec> = Vec::new();
    if let Some(path) = flag(args, "--spec") {
        if all || !names.is_empty() {
            bail!("`ilearn crash --spec` takes no preset names (pass one or the other)");
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("cannot read spec file `{path}`"))?;
        specs.push(ScenarioSpec::parse(&text).with_context(|| format!("bad scenario spec `{path}`"))?);
    } else {
        if all {
            names = PRESETS.iter().map(|s| s.to_string()).collect();
        } else if names.is_empty() {
            bail!(
                "usage: ilearn crash <scenario>... | ilearn crash --all | ilearn crash --spec FILE \
                 [--exhaustive | --sample N] [--out DIR]"
            );
        }
        let seed: u64 = flag(args, "--seed").map_or(Ok(42), |s| s.parse())?;
        let hours: u64 = flag(args, "--hours").map_or(Ok(1), |s| s.parse())?;
        for name in &names {
            specs.push(ilearn::scenario::preset(name, seed, hours_to_us(hours)?)?);
        }
    }
    let mode = if exhaustive {
        SweepMode::Exhaustive
    } else {
        let n: usize = flag(args, "--sample").map_or(Ok(16), |s| s.parse())?;
        // the plan seed is pinned: the cut list must be reproducible for
        // the committed golden reports
        SweepMode::Sample { n, seed: 7 }
    };
    let out_dir = flag(args, "--out");
    if let Some(dir) = &out_dir {
        std::fs::create_dir_all(dir)?;
    }
    let t0 = std::time::Instant::now();
    let mut violations = 0usize;
    for spec in &specs {
        let report = sweep_scenario(spec, mode)
            .with_context(|| format!("crash sweep of scenario `{}`", spec.name))?;
        println!("== crash: {} ==", report.summary());
        for v in &report.violations {
            println!("  VIOLATION {v}");
        }
        violations += report.violations.len();
        if let Some(dir) = &out_dir {
            let path = format!("{dir}/{}.json", spec.name);
            let mut text = report.to_json().to_string();
            text.push('\n');
            std::fs::write(&path, text)?;
            eprintln!("wrote {path}");
        }
    }
    eprintln!(
        "({} scenario(s) swept in {:.1}s)",
        specs.len(),
        t0.elapsed().as_secs_f64()
    );
    if violations > 0 {
        bail!("crash sweep found {violations} consistency violation(s)");
    }
    println!("every cut point recovered to a bit-exact commit boundary.");
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("scenarios:  {}  (presets; or any JSON spec via `run --spec`)", PRESETS.join("  "));
    println!("figures:    {}", figures::FIGURE_IDS.join("  "));
    println!("schedulers: planner  alpaca:<pct>  mayfly:<pct>:<expiry_s>");
    println!(
        "heuristics: {}",
        Heuristic::ALL
            .iter()
            .map(|h| h.name())
            .collect::<Vec<_>>()
            .join("  ")
    );
    println!("backends:   native  pjrt (requires `--features pjrt` + `make artifacts`)");
    println!();
    println!("sweep grid spec example:");
    println!(
        "{}",
        r#"  {"name": "matrix", "hours": 4,
   "scenarios": ["vibration", "presence"],
   "seeds": [1, 2],
   "schedulers": ["planner", "alpaca:50"],
   "heuristics": ["round_robin"],
   "fleet": {"shards": 16, "phase_jitter_us": 60000000}}"#
    );
    println!();
    println!("scenario fleet block (also a spec-level field):");
    println!(
        "{}",
        r#"  "fleet": {"shards": 16, "phase_jitter_us": 60000000, "seed_stride": 1,
            "overrides": [{"shard": 3, "harvester": {"kind": "constant", "power_w": 0.01}}],
            "sync": {"period_us": 3600000000, "strategy": "gossip",
                     "radio": {"tx_uj": 2200, "tx_us": 85000, "rx_uj": 1700, "rx_us": 85000}}}"#
    );
    println!();
    println!(
        "trace harvesters: {{\"kind\": \"trace\", \"path\": \"examples/traces/solar_day.csv\"}}"
    );
    println!("trace corpus:    examples/traces/*.csv (see examples/traces/README.md)");
    Ok(())
}
