//! `ilearn` — CLI for the intermittent-learning reproduction.
//!
//! Subcommands:
//!   run     — run one application end-to-end and print the run summary
//!   figure  — regenerate a paper figure/table (fig6c..fig17, table3..5)
//!   inspect — energy pre-inspection of an app's action set (§3.5 tool)
//!   list    — list apps, figures, heuristics, schedulers
//!
//! Examples:
//!   ilearn run vibration --hours 4 --backend pjrt
//!   ilearn figure fig9 --out out/
//!   ilearn inspect air_quality --budget-uj 2000

use anyhow::{bail, Context, Result};
use ilearn::apps::{AppConfig, AppKind, BackendKind, SchedulerKind};
use ilearn::energy::inspect;
use ilearn::eval::figures;
use ilearn::selection::Heuristic;

const H: u64 = 3_600_000_000;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("figure") => cmd_figure(&args[1..]),
        Some("inspect") => cmd_inspect(&args[1..]),
        Some("list") => cmd_list(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown command `{other}` (try `ilearn help`)"),
    }
}

fn print_help() {
    println!(
        "ilearn — Intermittent Learning (IMWUT'19) reproduction\n\
         \n\
         USAGE: ilearn <command> [options]\n\
         \n\
         COMMANDS:\n\
           run <app>        run an application (air_quality|presence|vibration)\n\
               --hours N        simulated hours            [default per app]\n\
               --seed N         experiment seed            [default 42]\n\
               --backend B      native|pjrt                [default native]\n\
               --scheduler S    planner|alpaca:<pct>|mayfly:<pct>:<expiry_s>\n\
               --heuristic X    round_robin|k_last_lists|randomized|none\n\
           figure <id>      regenerate a figure/table (see `ilearn list`; `all`)\n\
               --seed N --out DIR   write <id>.json under DIR\n\
           inspect <app>    energy pre-inspection (per-action worst case)\n\
               --budget-uj E    per-wake energy budget     [default: capacitor]\n\
           list             apps, figures, schedulers, heuristics"
    );
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn parse_scheduler(s: &str) -> Result<SchedulerKind> {
    if s == "planner" {
        return Ok(SchedulerKind::Planner);
    }
    let parts: Vec<&str> = s.split(':').collect();
    match parts.as_slice() {
        ["alpaca", pct] => Ok(SchedulerKind::Alpaca {
            learn_pct: pct.parse::<f64>()? / 100.0,
        }),
        ["mayfly", pct, expiry_s] => Ok(SchedulerKind::Mayfly {
            learn_pct: pct.parse::<f64>()? / 100.0,
            expiry_us: expiry_s.parse::<u64>()? * 1_000_000,
        }),
        _ => bail!("bad scheduler `{s}` (planner | alpaca:<pct> | mayfly:<pct>:<expiry_s>)"),
    }
}

fn cmd_run(args: &[String]) -> Result<()> {
    let app = args
        .first()
        .context("usage: ilearn run <app> [options]")?;
    let kind = AppKind::parse(app).with_context(|| format!("unknown app `{app}`"))?;
    let seed: u64 = flag(args, "--seed").map_or(Ok(42), |s| s.parse())?;
    let hours: u64 = match flag(args, "--hours") {
        Some(h) => h.parse()?,
        None => match kind {
            AppKind::AirQuality => 48,
            AppKind::Presence => 24,
            AppKind::Vibration => 8,
        },
    };
    let mut cfg = AppConfig::new(kind, seed, hours * H);
    if let Some(b) = flag(args, "--backend") {
        cfg.backend = match b.as_str() {
            "native" => BackendKind::Native,
            "pjrt" => BackendKind::Pjrt,
            other => bail!("unknown backend `{other}`"),
        };
    }
    if let Some(s) = flag(args, "--scheduler") {
        cfg.scheduler = parse_scheduler(&s)?;
    }
    if let Some(h) = flag(args, "--heuristic") {
        cfg.heuristic = Heuristic::ALL
            .into_iter()
            .find(|x| x.name() == h)
            .with_context(|| format!("unknown heuristic `{h}`"))?;
    }

    eprintln!(
        "running {} for {hours} h (seed {seed}, backend {:?}, scheduler {}) ...",
        kind.name(),
        cfg.backend,
        cfg.scheduler.label()
    );
    let t0 = std::time::Instant::now();
    let r = cfg.build_engine()?.run()?;
    let wall = t0.elapsed();
    println!("== run summary: {} / {} ==", kind.name(), r.scheduler);
    println!("  wake cycles        {}", r.cycles);
    println!("  examples sensed    {}", r.sensed);
    println!("  examples learned   {}", r.learned);
    println!("  inferences         {}", r.inferred);
    println!("  discarded (select) {}", r.discarded_select);
    println!("  expired (mayfly)   {}", r.expired);
    println!("  power failures     {}", r.power_failures);
    println!("  energy             {:.1} mJ", r.energy_uj / 1000.0);
    println!("  mean probe acc.    {:.3}", r.mean_accuracy(3));
    println!("  final probe acc.   {:.3}", r.final_accuracy());
    println!("  online infer acc.  {:.3}", r.online_accuracy());
    println!("  wallclock          {:.2}s", wall.as_secs_f64());
    println!("  accuracy trajectory:");
    for c in &r.checkpoints {
        println!(
            "    t={:>6.1}h acc={:.2} learned={:<5} E={:>9.1} mJ V={:.2}",
            c.t_us as f64 / H as f64,
            c.accuracy,
            c.learned,
            c.energy_uj / 1000.0,
            c.voltage
        );
    }
    Ok(())
}

fn cmd_figure(args: &[String]) -> Result<()> {
    let id = args
        .first()
        .context("usage: ilearn figure <id> [--seed N] [--out DIR]")?;
    let seed: u64 = flag(args, "--seed").map_or(Ok(42), |s| s.parse())?;
    let t0 = std::time::Instant::now();
    let ids: Vec<String> = if id == "all" {
        figures::FIGURE_IDS.iter().map(|s| s.to_string()).collect()
    } else {
        vec![id.clone()]
    };
    for id in &ids {
        let fig = figures::generate(id, seed)?;
        println!("{}", fig.render());
        if let Some(dir) = flag(args, "--out") {
            std::fs::create_dir_all(&dir)?;
            let path = format!("{dir}/{id}.json");
            std::fs::write(&path, fig.to_json().to_string())?;
            eprintln!("wrote {path}");
        }
    }
    eprintln!(
        "({} figure(s) in {:.1}s)",
        ids.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let app = args
        .first()
        .context("usage: ilearn inspect <app> [--budget-uj E]")?;
    let kind = AppKind::parse(app).with_context(|| format!("unknown app `{app}`"))?;
    let cfg = AppConfig::new(kind, 0, H);
    let cap = cfg.build_capacitor();
    let budget: f64 = flag(args, "--budget-uj")
        .map_or(Ok(cap.full_budget_uj() * 0.8), |s| s.parse())?;
    let model = kind.cost_model();
    println!(
        "energy pre-inspection: app {} (cost model {}), budget {:.1} uJ/wake",
        kind.name(),
        model.name,
        budget
    );
    let report = inspect::inspect(&model, budget, 0.10);
    for (a, worst) in &report.measured {
        let verdict = if report.violations.iter().any(|v| v.action == *a) {
            "VIOLATION"
        } else {
            "ok"
        };
        println!(
            "  {:<10} worst-case {:>10.1} uJ   {}",
            a.name(),
            worst,
            verdict
        );
    }
    if report.passed() {
        println!("all actions fit the budget.");
    } else {
        println!("{} action(s) need splitting:", report.violations.len());
        for v in &report.violations {
            println!(
                "  {} -> split into {} sub-actions",
                v.action.name(),
                v.required_splits
            );
        }
        let (fixed, after) = inspect::auto_split(&model, budget, 0.10);
        assert!(after.passed());
        println!("auto-split result:");
        for a in ilearn::actions::Action::ALL {
            let c = fixed.cost(a);
            if c.splits > 1 {
                println!(
                    "  {:<10} {} sub-actions of {:.1} uJ",
                    a.name(),
                    c.splits,
                    c.sub_energy_uj()
                );
            }
        }
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("apps:       air_quality  presence  vibration");
    println!("figures:    {}", figures::FIGURE_IDS.join("  "));
    println!("schedulers: planner  alpaca:<pct>  mayfly:<pct>:<expiry_s>");
    println!(
        "heuristics: {}",
        Heuristic::ALL
            .iter()
            .map(|h| h.name())
            .collect::<Vec<_>>()
            .join("  ")
    );
    println!("backends:   native  pjrt (requires `make artifacts`)");
    Ok(())
}
