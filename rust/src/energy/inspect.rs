//! Energy pre-inspection — the development-time tool of §3.5.
//!
//! The paper's tool (built on TI EnergyTrace) runs the compiled actions on
//! a battery-powered target over *all test inputs*, takes the worst-case
//! energy per action, flags every action whose worst case exceeds the
//! target budget, and prompts the programmer to split it. This module
//! reproduces that contract against the simulated cost model: it measures
//! worst-case sub-action energy, reports violations, and can compute the
//! split factor that would make an action fit.

use crate::actions::Action;
use crate::energy::cost::{ActionCost, CostModel};

/// One pre-inspection finding.
#[derive(Debug, Clone, PartialEq)]
pub struct Violation {
    pub action: Action,
    /// Worst-case energy of one (sub-)action, µJ.
    pub worst_uj: f64,
    /// The budget it must fit into, µJ.
    pub budget_uj: f64,
    /// Minimum number of sub-actions that makes every piece fit.
    pub required_splits: u32,
}

/// Report for a whole cost model.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub violations: Vec<Violation>,
    /// (action, worst-case sub-action energy) for every action measured.
    pub measured: Vec<(Action, f64)>,
}

impl Report {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Inspect every action of `model` against a per-wake energy budget
/// (typically [`crate::energy::Capacitor::full_budget_uj`] minus a safety
/// margin).
///
/// `jitter` emulates the measurement spread EnergyTrace observes across
/// test inputs: the worst case is taken as `cost * (1 + jitter)`.
pub fn inspect(model: &CostModel, budget_uj: f64, jitter: f64) -> Report {
    let mut report = Report::default();
    for a in Action::ALL {
        let c = model.cost(a);
        let worst = c.sub_energy_uj() * (1.0 + jitter);
        report.measured.push((a, worst));
        if worst > budget_uj {
            report.violations.push(Violation {
                action: a,
                worst_uj: worst,
                budget_uj,
                required_splits: required_splits(c, budget_uj, jitter),
            });
        }
    }
    report
}

/// Smallest split count that makes each sub-action fit the budget.
pub fn required_splits(c: ActionCost, budget_uj: f64, jitter: f64) -> u32 {
    let worst_total = c.energy_uj * (1.0 + jitter);
    (worst_total / budget_uj).ceil().max(1.0) as u32
}

/// Apply the pre-inspection loop of Fig. 4: keep splitting every violating
/// action until the whole model passes, returning the adjusted model.
/// Mirrors the interactive "split until all actions pass" workflow.
pub fn auto_split(model: &CostModel, budget_uj: f64, jitter: f64) -> (CostModel, Report) {
    let mut m = model.clone();
    let before = inspect(&m, budget_uj, jitter);
    for v in &before.violations {
        let mut c = m.cost(v.action);
        c.splits = v.required_splits;
        m.set_cost(v.action, c);
    }
    let after = inspect(&m, budget_uj, jitter);
    debug_assert!(after.passed(), "auto_split must converge in one pass");
    (m, after)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_fits_its_platform_budget() {
        // 0.2 F supercap has a huge budget; nothing should violate.
        let budget = crate::energy::Capacitor::air_quality().full_budget_uj() * 0.5;
        let r = inspect(&CostModel::knn(), budget, 0.10);
        assert!(r.passed(), "{:?}", r.violations);
    }

    #[test]
    fn tight_budget_flags_learn_and_sense() {
        // 2 mJ budget: kNN learn (3.103 mJ/sub) and sense (1.9 mJ/sub) at
        // 10% jitter -> learn violates, sense is borderline-pass.
        let r = inspect(&CostModel::knn(), 2_000.0, 0.10);
        assert!(!r.passed());
        assert!(r.violations.iter().any(|v| v.action == Action::Learn));
    }

    #[test]
    fn required_splits_is_minimal() {
        let c = ActionCost::new(9_309.0, 1_551_000, 3);
        let s = required_splits(c, 2_000.0, 0.10);
        // 9309*1.1 = 10239.9 / 2000 = 5.12 -> 6
        assert_eq!(s, 6);
        // with 6 splits each piece is 9309/6*1.1 = 1706 <= 2000
        assert!(c.energy_uj / s as f64 * 1.1 <= 2_000.0);
        // 5 would not fit
        assert!(c.energy_uj / 5.0 * 1.1 > 2_000.0);
    }

    #[test]
    fn auto_split_converges() {
        let (m, report) = auto_split(&CostModel::knn(), 1_500.0, 0.10);
        assert!(report.passed());
        assert!(m.cost(Action::Learn).splits >= 7);
        // energy is conserved by splitting
        assert_eq!(m.cost(Action::Learn).energy_uj, 9_309.0);
    }

    #[test]
    fn zero_jitter_uses_raw_costs() {
        let r = inspect(&CostModel::kmeans(), 10_000.0, 0.0);
        assert!(r.passed());
    }
}
