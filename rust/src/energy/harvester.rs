//! Energy-harvester models: solar (diurnal), RF (path-loss over a distance
//! schedule), piezoelectric (motion-driven), plus constant and replayed
//! trace sources for tests.
//!
//! All models are *deterministic functions of simulated time*: stochastic
//! texture (clouds, fading) comes from hashing the time bucket with the
//! seed, so querying the same instant twice gives the same power and two
//! runs with the same seed produce identical harvest traces.

use crate::sensors::accel::MotionProfile;

/// Seconds per simulated day.
pub const DAY_S: f64 = 86_400.0;

/// A power source that can be sampled at any simulated time.
pub trait Harvester: Send {
    /// Instantaneous harvested power in watts at time `t_us`.
    fn power_w(&self, t_us: u64) -> f64;

    /// Human-readable name for logs/figures.
    fn name(&self) -> &'static str;
}

/// Deterministic per-bucket noise in [0, 1): splitmix64 of (seed, bucket).
fn bucket_noise(seed: u64, bucket: u64) -> f64 {
    let mut z = seed ^ bucket.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Solar harvester: half-sine irradiance between sunrise and sunset with
/// per-minute cloud attenuation and occasional deep dips (the daytime
/// interruptions visible in the paper's Fig. 15(a)).
#[derive(Debug, Clone)]
pub struct Solar {
    /// Peak panel output at noon, watts (small panel: ~45 mW).
    pub peak_w: f64,
    /// Sunrise/sunset as seconds-of-day.
    pub sunrise_s: f64,
    pub sunset_s: f64,
    /// Probability that a given minute is deeply clouded.
    pub cloud_prob: f64,
    pub seed: u64,
}

impl Default for Solar {
    fn default() -> Self {
        Solar {
            peak_w: 0.045,
            sunrise_s: 6.0 * 3600.0,
            sunset_s: 19.0 * 3600.0,
            cloud_prob: 0.08,
            seed: 1,
        }
    }
}

impl Harvester for Solar {
    fn power_w(&self, t_us: u64) -> f64 {
        let t_s = t_us as f64 / 1e6;
        let tod = t_s % DAY_S;
        if tod < self.sunrise_s || tod > self.sunset_s {
            return 0.0;
        }
        let phase = (tod - self.sunrise_s) / (self.sunset_s - self.sunrise_s);
        let irradiance = (std::f64::consts::PI * phase).sin().max(0.0);
        // Per-minute cloud texture: mild jitter plus occasional deep dips.
        let minute = (t_s / 60.0) as u64;
        let n1 = bucket_noise(self.seed, minute);
        let n2 = bucket_noise(self.seed ^ 0xABCD, minute);
        let jitter = 0.85 + 0.15 * n1;
        let cloud = if n2 < self.cloud_prob { 0.06 } else { 1.0 };
        self.peak_w * irradiance * jitter * cloud
    }

    fn name(&self) -> &'static str {
        "solar"
    }
}

/// RF harvester: free-space path loss over a piecewise-constant distance
/// schedule, with per-second fading. Calibrated to the paper's Powercast
/// setup (§7.4: avg 3.1 V / 2.2 V / 0.9 V at 3 / 5 / 7 m).
#[derive(Debug, Clone)]
pub struct Rf {
    /// Received power at the reference distance, watts (P2110-class:
    /// ~10 mW at 3 m from a 3 W transmitter).
    pub p_ref_w: f64,
    /// Reference distance in meters.
    pub d_ref_m: f64,
    /// (start time us, distance m) schedule; must be sorted by time.
    pub schedule: Vec<(u64, f64)>,
    pub seed: u64,
}

impl Default for Rf {
    fn default() -> Self {
        Rf {
            p_ref_w: 0.010,
            d_ref_m: 3.0,
            schedule: vec![(0, 3.0)],
            seed: 2,
        }
    }
}

impl Rf {
    /// Distance at time `t_us` from the schedule.
    pub fn distance_m(&self, t_us: u64) -> f64 {
        let mut d = self.schedule.first().map(|&(_, d)| d).unwrap_or(3.0);
        for &(start, dist) in &self.schedule {
            if t_us >= start {
                d = dist;
            } else {
                break;
            }
        }
        d
    }
}

impl Harvester for Rf {
    fn power_w(&self, t_us: u64) -> f64 {
        let d = self.distance_m(t_us).max(0.1);
        let base = self.p_ref_w * (self.d_ref_m / d).powi(2);
        // Per-second multipath fading in [0.6, 1.1].
        let sec = t_us / 1_000_000;
        let fade = 0.6 + 0.5 * bucket_noise(self.seed, sec);
        base * fade
    }

    fn name(&self) -> &'static str {
        "rf"
    }
}

/// Piezoelectric harvester driven by the *same* motion profile the
/// accelerometer sensor observes — this is the paper's energy↔data
/// correlation (§2.3): shaking generates both the training data and the
/// energy to learn it. Output calibrated to the PPA-2014 range
/// (1.8–36.5 mW, §6.3).
#[derive(Debug, Clone)]
pub struct Piezo {
    pub profile: MotionProfile,
    /// Power at unit motion amplitude, watts.
    pub w_per_amp2: f64,
    pub seed: u64,
}

impl Piezo {
    pub fn new(profile: MotionProfile) -> Self {
        Piezo {
            profile,
            w_per_amp2: 0.009,
            seed: 3,
        }
    }
}

impl Harvester for Piezo {
    fn power_w(&self, t_us: u64) -> f64 {
        let amp = self.profile.amplitude(t_us);
        if amp <= 0.0 {
            return 0.0;
        }
        let sec = t_us / 1_000_000;
        let jitter = 0.8 + 0.4 * bucket_noise(self.seed, sec);
        // P ~ amp^2 (velocity-squared scaling), clamped to the PPA-2014
        // datasheet range: 1.8 mW floor while moving, 36.5 mW ceiling.
        (self.w_per_amp2 * amp * amp * jitter).clamp(0.0018, 0.0365)
    }

    fn name(&self) -> &'static str {
        "piezo"
    }
}

/// Multi-harvester combination (paper §3.1: systems like CapBand combine
/// RF and solar "to guarantee continuous energy supply ... the energy
/// harvester subsystem takes care of selecting and switching to the
/// preferred harvester transparently"). The subsystem draws from the
/// best source at each instant.
pub struct Combined {
    pub sources: Vec<Box<dyn Harvester>>,
}

impl Combined {
    pub fn new(sources: Vec<Box<dyn Harvester>>) -> Self {
        Combined { sources }
    }

    /// Index of the currently preferred (highest-power) source.
    pub fn preferred(&self, t_us: u64) -> usize {
        let mut best = 0;
        let mut bp = f64::NEG_INFINITY;
        for (i, s) in self.sources.iter().enumerate() {
            let p = s.power_w(t_us);
            if p > bp {
                bp = p;
                best = i;
            }
        }
        best
    }
}

impl Harvester for Combined {
    fn power_w(&self, t_us: u64) -> f64 {
        self.sources
            .iter()
            .map(|s| s.power_w(t_us))
            .fold(0.0, f64::max)
    }

    fn name(&self) -> &'static str {
        "combined"
    }
}

/// Constant power source (unit tests, pre-inspection rig).
#[derive(Debug, Clone)]
pub struct Constant(pub f64);

impl Harvester for Constant {
    fn power_w(&self, _t_us: u64) -> f64 {
        self.0
    }
    fn name(&self) -> &'static str {
        "constant"
    }
}

/// Replay a recorded power trace (piecewise constant, sorted by time).
#[derive(Debug, Clone)]
pub struct Trace {
    pub points: Vec<(u64, f64)>,
}

impl Harvester for Trace {
    fn power_w(&self, t_us: u64) -> f64 {
        let mut p = 0.0;
        for &(start, pw) in &self.points {
            if t_us >= start {
                p = pw;
            } else {
                break;
            }
        }
        p
    }
    fn name(&self) -> &'static str {
        "trace"
    }
}

/// Enum wrapper so app configs can own a harvester without trait objects.
#[derive(Debug, Clone)]
pub enum HarvesterKind {
    Solar(Solar),
    Rf(Rf),
    Piezo(Piezo),
    Constant(Constant),
    Trace(Trace),
}

impl Harvester for HarvesterKind {
    fn power_w(&self, t_us: u64) -> f64 {
        match self {
            HarvesterKind::Solar(h) => h.power_w(t_us),
            HarvesterKind::Rf(h) => h.power_w(t_us),
            HarvesterKind::Piezo(h) => h.power_w(t_us),
            HarvesterKind::Constant(h) => h.power_w(t_us),
            HarvesterKind::Trace(h) => h.power_w(t_us),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            HarvesterKind::Solar(h) => h.name(),
            HarvesterKind::Rf(h) => h.name(),
            HarvesterKind::Piezo(h) => h.name(),
            HarvesterKind::Constant(h) => h.name(),
            HarvesterKind::Trace(h) => h.name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn us(h: f64) -> u64 {
        (h * 3600.0 * 1e6) as u64
    }

    #[test]
    fn solar_dark_at_night_bright_at_noon() {
        let s = Solar::default();
        assert_eq!(s.power_w(us(0.0)), 0.0);
        assert_eq!(s.power_w(us(23.0)), 0.0);
        let noon = s.power_w(us(12.5));
        assert!(noon > 0.0_f64);
        assert!(noon <= s.peak_w);
        // noon beats early morning on average over several days
        let avg = |hr: f64| -> f64 {
            (0..5).map(|d| s.power_w(us(hr + 24.0 * d as f64))).sum::<f64>() / 5.0
        };
        assert!(avg(12.5) > avg(6.5));
    }

    #[test]
    fn solar_deterministic() {
        let s = Solar::default();
        assert_eq!(s.power_w(us(10.0)), s.power_w(us(10.0)));
    }

    #[test]
    fn rf_follows_inverse_square() {
        let mut rf = Rf::default();
        rf.schedule = vec![(0, 3.0), (us(1.0), 6.0)];
        // average over fading
        let avg = |t0: u64| -> f64 {
            (0..100).map(|i| rf.power_w(t0 + i * 1_000_000)).sum::<f64>() / 100.0
        };
        let p3 = avg(0);
        let p6 = avg(us(2.0));
        let ratio = p3 / p6;
        assert!((ratio - 4.0).abs() < 0.8, "ratio {ratio}");
    }

    #[test]
    fn rf_distance_schedule_lookup() {
        let mut rf = Rf::default();
        rf.schedule = vec![(0, 3.0), (100, 5.0), (200, 7.0)];
        assert_eq!(rf.distance_m(0), 3.0);
        assert_eq!(rf.distance_m(150), 5.0);
        assert_eq!(rf.distance_m(999), 7.0);
    }

    #[test]
    fn piezo_idle_is_zero_shaking_is_positive() {
        let profile = MotionProfile::alternating_hours(1.2, 3.5, 4);
        let p = Piezo::new(profile.clone());
        // during a gentle gesture: power in the PPA-2014 range
        let g0 = profile.gesture_start(10) + 1_000;
        assert!(p.power_w(g0) >= 0.0018);
        assert!(p.power_w(g0) <= 0.0365);
        // between gestures: zero (no motion, no energy — §2.3 correlation)
        assert_eq!(p.power_w(profile.episodes[10].end_us + 100_000), 0.0);
        // abrupt gestures harvest more than gentle ones on average
        let avg = |base: usize| -> f64 {
            (0..50)
                .map(|i| p.power_w(profile.gesture_start(base + i) + 1_000))
                .sum::<f64>()
                / 50.0
        };
        assert!(avg(100) > avg(0)); // hour 1 (abrupt) vs hour 0 (gentle)
    }

    #[test]
    fn combined_switches_to_best_source() {
        // indoor RF by night, solar by day (the CapBand pattern)
        let solar = Solar::default();
        let mut rf = Rf::default();
        rf.schedule = vec![(0, 6.0)]; // weak-ish RF, always on
        let c = Combined::new(vec![Box::new(solar.clone()), Box::new(rf.clone())]);
        // night: solar = 0, RF > 0 -> prefers RF and delivers its power
        let night = us(2.0);
        assert_eq!(c.preferred(night), 1);
        assert!(c.power_w(night) > 0.0);
        assert_eq!(c.power_w(night), rf.power_w(night));
        // noon: solar beats the 6 m RF link
        let noon = us(12.5);
        assert_eq!(c.preferred(noon), 0);
        assert!(c.power_w(noon) >= solar.power_w(noon));
    }

    #[test]
    fn trace_replay() {
        let t = Trace {
            points: vec![(0, 0.0), (50, 0.5), (100, 0.25)],
        };
        assert_eq!(t.power_w(10), 0.0);
        assert_eq!(t.power_w(60), 0.5);
        assert_eq!(t.power_w(1000), 0.25);
    }
}
