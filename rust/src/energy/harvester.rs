//! Energy-harvester models: solar (diurnal), RF (path-loss over a distance
//! schedule), piezoelectric (motion-driven), plus constant and replayed
//! trace sources for tests.
//!
//! All models are *deterministic functions of simulated time*: stochastic
//! texture (clouds, fading) comes from hashing the time bucket with the
//! seed, so querying the same instant twice gives the same power and two
//! runs with the same seed produce identical harvest traces.

use crate::sensors::accel::MotionProfile;

/// Seconds per simulated day.
pub const DAY_S: f64 = 86_400.0;

/// Microseconds per simulated day.
pub const DAY_US: u64 = 86_400_000_000;

const MINUTE_US: u64 = 60_000_000;

/// A power source that can be sampled at any simulated time.
///
/// Besides the instantaneous sample, every harvester exposes a *piecewise
/// view* — [`Harvester::segment_end_us`] plus [`Harvester::mean_power_w`]
/// — that the event-driven charge kernel uses to jump analytically across
/// stretches of smooth output (a whole night of darkness, the idle gap
/// between motion gestures) instead of integrating in fixed steps. The
/// defaults are conservative (short segments, start-of-span sampling), so
/// custom harvesters stay correct without implementing the fast path.
pub trait Harvester: Send {
    /// Instantaneous harvested power in watts at time `t_us`.
    fn power_w(&self, t_us: u64) -> f64;

    /// End (µs, exclusive) of the model segment containing `t_us`: the
    /// largest `e > t_us` such that [`Harvester::mean_power_w`] is an
    /// accurate average over any sub-span of `[t_us, e)`. Implementations
    /// should make segments as long as their texture allows (darkness
    /// until sunrise, idle until the next gesture). The default is a
    /// conservative 1 s — as fine as the finest `charge_step_us` any
    /// in-tree scenario uses, so a custom harvester that implements only
    /// `power_w` cannot alias against sub-step power bursts the stepped
    /// kernel would have sampled (it just charges slower than one that
    /// implements the view).
    fn segment_end_us(&self, t_us: u64) -> u64 {
        t_us.saturating_add(1_000_000)
    }

    /// Mean power (watts) over `[from_us, to_us)`. Only called with spans
    /// inside one segment (see [`Harvester::segment_end_us`]); the default
    /// holds the instantaneous power at `from_us` across the span.
    fn mean_power_w(&self, from_us: u64, to_us: u64) -> f64 {
        let _ = to_us;
        self.power_w(from_us)
    }

    /// Human-readable name for logs/figures.
    fn name(&self) -> &'static str;

    /// Whether the piecewise view evaluates a *model* that extends into
    /// the simulated future (solar geometry, RF fade, gesture profiles),
    /// as opposed to replaying a recording whose future a deployed device
    /// could not know. Analytic harvesters double as an exact
    /// short-horizon forecast ([`Forecast::Exact`]); recordings get the
    /// causal EWMA estimator ([`Forecast::Ewma`]) instead.
    fn analytic(&self) -> bool {
        true
    }
}

/// Deterministic per-bucket noise in [0, 1): splitmix64 of (seed, bucket).
fn bucket_noise(seed: u64, bucket: u64) -> f64 {
    let mut z = seed ^ bucket.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Lazily grown prefix sums of the per-minute jitter×cloud attenuation.
/// Interior-mutable because the [`Harvester`] sampling API takes `&self`;
/// engines own their harvester per thread, so a `RefCell` suffices.
#[derive(Debug, Clone, Default)]
struct MinuteTexCache(std::cell::RefCell<Vec<f64>>);

/// Cache ceiling: ~4 simulated years of minutes (~16 MB). Longer horizons
/// fall back to sparse sampling of the texture.
const TEX_CACHE_MAX: usize = 2_000_000;

/// Solar harvester: half-sine irradiance between sunrise and sunset with
/// per-minute cloud attenuation and occasional deep dips (the daytime
/// interruptions visible in the paper's Fig. 15(a)).
#[derive(Debug, Clone)]
pub struct Solar {
    /// Peak panel output at noon, watts (small panel: ~45 mW).
    pub peak_w: f64,
    /// Sunrise/sunset as seconds-of-day.
    pub sunrise_s: f64,
    pub sunset_s: f64,
    /// Probability that a given minute is deeply clouded.
    pub cloud_prob: f64,
    pub seed: u64,
    tex: MinuteTexCache,
}

impl Default for Solar {
    fn default() -> Self {
        Solar {
            peak_w: 0.045,
            sunrise_s: 6.0 * 3600.0,
            sunset_s: 19.0 * 3600.0,
            cloud_prob: 0.08,
            seed: 1,
            tex: MinuteTexCache::default(),
        }
    }
}

impl Solar {
    /// Solar panel with explicit parameters (texture cache starts empty).
    pub fn new(
        peak_w: f64,
        sunrise_s: f64,
        sunset_s: f64,
        cloud_prob: f64,
        seed: u64,
    ) -> Self {
        Solar {
            peak_w,
            sunrise_s,
            sunset_s,
            cloud_prob,
            seed,
            tex: MinuteTexCache::default(),
        }
    }

    /// Sunrise/sunset as µs-of-day, clamped to one day.
    fn sun_us(&self) -> (u64, u64) {
        let clamp = |s: f64| ((s * 1e6) as u64).min(DAY_US);
        (clamp(self.sunrise_s), clamp(self.sunset_s))
    }

    /// jitter×cloud attenuation of one minute bucket.
    fn tex_at(&self, minute: u64) -> f64 {
        let n1 = bucket_noise(self.seed, minute);
        let n2 = bucket_noise(self.seed ^ 0xABCD, minute);
        let jitter = 0.85 + 0.15 * n1;
        let cloud = if n2 < self.cloud_prob { 0.06 } else { 1.0 };
        jitter * cloud
    }

    /// Time-weighted mean attenuation over `[lo_us, hi_us)`: partial
    /// boundary minutes are weighted by their covered fraction (a short
    /// wake-commit window can straddle a deep-cloud minute edge, where an
    /// unweighted bucket mean would bias the wake instant), full middle
    /// minutes come from the prefix-sum cache.
    fn tex_mean_weighted(&self, lo_us: u64, hi_us: u64) -> f64 {
        let m0 = lo_us / MINUTE_US;
        let m1 = (hi_us - 1) / MINUTE_US;
        if m0 == m1 {
            return self.tex_at(m0);
        }
        let first_w = ((m0 + 1) * MINUTE_US - lo_us) as f64;
        let last_w = (hi_us - m1 * MINUTE_US) as f64;
        let mut acc = self.tex_at(m0) * first_w + self.tex_at(m1) * last_w;
        if m1 > m0 + 1 {
            let middle = (m1 - m0 - 1) as f64 * MINUTE_US as f64;
            acc += self.tex_mean(m0 + 1, m1 - 1) * middle;
        }
        acc / (hi_us - lo_us) as f64
    }

    /// Mean jitter×cloud attenuation over minute buckets `[m0, m1]`,
    /// served from the prefix-sum cache (O(1) once a day is touched).
    fn tex_mean(&self, m0: u64, m1: u64) -> f64 {
        let n = m1 - m0 + 1;
        if m1 as usize >= TEX_CACHE_MAX {
            // horizon beyond the cache ceiling: sample the texture sparsely
            let take = n.min(64);
            let sum: f64 = (0..take)
                .map(|i| self.tex_at(m0 + i * n / take))
                .sum();
            return sum / take as f64;
        }
        let mut pre = self.tex.0.borrow_mut();
        if pre.is_empty() {
            pre.push(0.0);
        }
        while pre.len() <= m1 as usize + 1 {
            let m = pre.len() as u64 - 1;
            let last = *pre.last().expect("seeded above");
            let next = last + self.tex_at(m);
            pre.push(next);
        }
        (pre[m1 as usize + 1] - pre[m0 as usize]) / n as f64
    }
}

impl Harvester for Solar {
    fn power_w(&self, t_us: u64) -> f64 {
        let t_s = t_us as f64 / 1e6;
        let tod = t_s % DAY_S;
        if tod < self.sunrise_s || tod > self.sunset_s {
            return 0.0;
        }
        let phase = (tod - self.sunrise_s) / (self.sunset_s - self.sunrise_s);
        let irradiance = (std::f64::consts::PI * phase).sin().max(0.0);
        // Per-minute cloud texture: mild jitter plus occasional deep dips.
        let minute = (t_s / 60.0) as u64;
        self.peak_w * irradiance * self.tex_at(minute)
    }

    /// Darkness runs until the next sunrise in one segment; daylight is
    /// segmented at sunset (the mean integrates the in-between texture).
    fn segment_end_us(&self, t_us: u64) -> u64 {
        let (sunrise_us, sunset_us) = self.sun_us();
        let tod = t_us % DAY_US;
        let day0 = t_us - tod;
        if tod < sunrise_us {
            return day0 + sunrise_us;
        }
        if tod >= sunset_us {
            return day0.saturating_add(DAY_US).saturating_add(sunrise_us);
        }
        day0 + sunset_us
    }

    /// Exact closed-form mean: the half-sine irradiance integral times the
    /// cached mean of the per-minute jitter×cloud texture (the two factors
    /// are independent), scaled by the sunlit fraction of the span.
    fn mean_power_w(&self, from_us: u64, to_us: u64) -> f64 {
        if to_us <= from_us {
            return self.power_w(from_us);
        }
        let (sunrise_us, sunset_us) = self.sun_us();
        if sunset_us <= sunrise_us {
            return 0.0;
        }
        let day0 = from_us - from_us % DAY_US;
        let lo = from_us.max(day0 + sunrise_us);
        let hi = to_us.min(day0 + sunset_us);
        if hi <= lo {
            return 0.0; // the span (within this day) is entirely dark
        }
        let span_sun = (sunset_us - sunrise_us) as f64;
        let ua = (lo - day0 - sunrise_us) as f64 / span_sun;
        let ub = (hi - day0 - sunrise_us) as f64 / span_sun;
        let pi = std::f64::consts::PI;
        let mean_irr = if ub - ua < 1e-9 {
            (pi * 0.5 * (ua + ub)).sin().max(0.0)
        } else {
            (((pi * ua).cos() - (pi * ub).cos()) / (pi * (ub - ua))).max(0.0)
        };
        let tex = self.tex_mean_weighted(lo, hi);
        let sunlit = (hi - lo) as f64 / (to_us - from_us) as f64;
        self.peak_w * mean_irr * tex * sunlit
    }

    fn name(&self) -> &'static str {
        "solar"
    }
}

/// RF harvester: free-space path loss over a piecewise-constant distance
/// schedule, with per-second fading. Calibrated to the paper's Powercast
/// setup (§7.4: avg 3.1 V / 2.2 V / 0.9 V at 3 / 5 / 7 m).
#[derive(Debug, Clone)]
pub struct Rf {
    /// Received power at the reference distance, watts (P2110-class:
    /// ~10 mW at 3 m from a 3 W transmitter).
    pub p_ref_w: f64,
    /// Reference distance in meters.
    pub d_ref_m: f64,
    /// (start time us, distance m) schedule; must be sorted by time.
    pub schedule: Vec<(u64, f64)>,
    pub seed: u64,
}

impl Default for Rf {
    fn default() -> Self {
        Rf {
            p_ref_w: 0.010,
            d_ref_m: 3.0,
            schedule: vec![(0, 3.0)],
            seed: 2,
        }
    }
}

impl Rf {
    /// Per-second multipath fading factor in [0.6, 1.1].
    fn fade(&self, sec: u64) -> f64 {
        0.6 + 0.5 * bucket_noise(self.seed, sec)
    }

    /// Path-loss base power (before fading) at time `t_us`.
    fn base_w(&self, t_us: u64) -> f64 {
        let d = self.distance_m(t_us).max(0.1);
        self.p_ref_w * (self.d_ref_m / d).powi(2)
    }

    /// Distance at time `t_us` from the schedule.
    pub fn distance_m(&self, t_us: u64) -> f64 {
        let mut d = self.schedule.first().map(|&(_, d)| d).unwrap_or(3.0);
        for &(start, dist) in &self.schedule {
            if t_us >= start {
                d = dist;
            } else {
                break;
            }
        }
        d
    }
}

impl Harvester for Rf {
    fn power_w(&self, t_us: u64) -> f64 {
        self.base_w(t_us) * self.fade(t_us / 1_000_000)
    }

    /// Segments are bounded at minute granularity (and clipped at the
    /// next distance-schedule change); within one, [`Rf::mean_power_w`]
    /// integrates the per-second fading exactly.
    fn segment_end_us(&self, t_us: u64) -> u64 {
        let next_sched = self
            .schedule
            .iter()
            .map(|&(start, _)| start)
            .find(|&start| start > t_us)
            .unwrap_or(u64::MAX);
        let next_minute = (t_us / MINUTE_US + 1).saturating_mul(MINUTE_US);
        next_sched.min(next_minute)
    }

    /// Exact time-weighted mean over the span's per-second fade buckets
    /// (the distance is constant within a segment; fading is piecewise
    /// constant per second, and partial boundary seconds are weighted by
    /// coverage). Pathologically long spans are sampled at 64 points.
    fn mean_power_w(&self, from_us: u64, to_us: u64) -> f64 {
        if to_us <= from_us {
            return self.power_w(from_us);
        }
        let base = self.base_w(from_us);
        let s0 = from_us / 1_000_000;
        let s1 = (to_us - 1) / 1_000_000;
        if s0 == s1 {
            return base * self.fade(s0);
        }
        let n = s1 - s0 + 1;
        if n > 64 {
            let take = 64;
            let sum: f64 = (0..take).map(|i| self.fade(s0 + i * n / take)).sum();
            return base * sum / take as f64;
        }
        let first_w = ((s0 + 1) * 1_000_000 - from_us) as f64;
        let last_w = (to_us - s1 * 1_000_000) as f64;
        let mut acc = self.fade(s0) * first_w + self.fade(s1) * last_w;
        for s in s0 + 1..s1 {
            acc += self.fade(s) * 1_000_000.0;
        }
        base * acc / (to_us - from_us) as f64
    }

    fn name(&self) -> &'static str {
        "rf"
    }
}

/// Piezoelectric harvester driven by the *same* motion profile the
/// accelerometer sensor observes — this is the paper's energy↔data
/// correlation (§2.3): shaking generates both the training data and the
/// energy to learn it. Output calibrated to the PPA-2014 range
/// (1.8–36.5 mW, §6.3).
#[derive(Debug, Clone)]
pub struct Piezo {
    pub profile: MotionProfile,
    /// Power at unit motion amplitude, watts.
    pub w_per_amp2: f64,
    pub seed: u64,
}

impl Piezo {
    pub fn new(profile: MotionProfile) -> Self {
        Piezo {
            profile,
            w_per_amp2: 0.009,
            seed: 3,
        }
    }
}

impl Harvester for Piezo {
    fn power_w(&self, t_us: u64) -> f64 {
        let amp = self.profile.amplitude(t_us);
        if amp <= 0.0 {
            return 0.0;
        }
        let sec = t_us / 1_000_000;
        let jitter = 0.8 + 0.4 * bucket_noise(self.seed, sec);
        // P ~ amp^2 (velocity-squared scaling), clamped to the PPA-2014
        // datasheet range: 1.8 mW floor while moving, 36.5 mW ceiling.
        (self.w_per_amp2 * amp * amp * jitter).clamp(0.0018, 0.0365)
    }

    /// Idle gaps between gestures are one zero-power segment (no motion,
    /// no energy — §2.3); inside a gesture the per-second jitter bounds
    /// segments at second granularity.
    fn segment_end_us(&self, t_us: u64) -> u64 {
        let motion_end = self.profile.segment_end_us(t_us);
        if self.profile.amplitude(t_us) > 0.0 {
            let next_second = (t_us / 1_000_000 + 1).saturating_mul(1_000_000);
            motion_end.min(next_second)
        } else {
            motion_end
        }
    }

    fn name(&self) -> &'static str {
        "piezo"
    }
}

/// Multi-harvester combination (paper §3.1: systems like CapBand combine
/// RF and solar "to guarantee continuous energy supply ... the energy
/// harvester subsystem takes care of selecting and switching to the
/// preferred harvester transparently"). The subsystem draws from the
/// best source at each instant.
pub struct Combined {
    pub sources: Vec<Box<dyn Harvester>>,
}

impl Combined {
    pub fn new(sources: Vec<Box<dyn Harvester>>) -> Self {
        Combined { sources }
    }

    /// Index of the currently preferred (highest-power) source.
    pub fn preferred(&self, t_us: u64) -> usize {
        let mut best = 0;
        let mut bp = f64::NEG_INFINITY;
        for (i, s) in self.sources.iter().enumerate() {
            let p = s.power_w(t_us);
            if p > bp {
                bp = p;
                best = i;
            }
        }
        best
    }
}

impl Harvester for Combined {
    fn power_w(&self, t_us: u64) -> f64 {
        self.sources
            .iter()
            .map(|s| s.power_w(t_us))
            .fold(0.0, f64::max)
    }

    /// Intersection of the sources' segments, additionally bounded at
    /// minute granularity: max-of-means only tracks mean-of-max while
    /// every source is roughly constant, and a source crossing (solar
    /// overtaking RF at dawn) can happen deep inside one source's own
    /// segment. A fully dark instant needs no such bound — crossings
    /// require a live source — so whole dark spans are jumped at the
    /// sources' own segment granularity.
    fn segment_end_us(&self, t_us: u64) -> u64 {
        let intersect = self
            .sources
            .iter()
            .map(|s| s.segment_end_us(t_us))
            .min()
            .unwrap_or(u64::MAX);
        if self.power_w(t_us) == 0.0 {
            return intersect;
        }
        let next_minute = (t_us / MINUTE_US + 1).saturating_mul(MINUTE_US);
        intersect.min(next_minute)
    }

    fn mean_power_w(&self, from_us: u64, to_us: u64) -> f64 {
        self.sources
            .iter()
            .map(|s| s.mean_power_w(from_us, to_us))
            .fold(0.0, f64::max)
    }

    fn name(&self) -> &'static str {
        "combined"
    }

    fn analytic(&self) -> bool {
        self.sources.iter().all(|s| s.analytic())
    }
}

/// Phase-offset wrapper: evaluates the wrapped harvester `offset_us`
/// ahead of the shard's local clock. Fleet shards use this to de-correlate
/// a shared energy model — 16 solar nodes see the same diurnal curve but
/// each a little deeper into the day — and to hand each shard a distinct
/// slice of one recorded [`Trace`]. An offset of zero is exactly the
/// wrapped harvester.
pub struct PhaseShift {
    pub inner: Box<dyn Harvester>,
    pub offset_us: u64,
}

impl PhaseShift {
    pub fn new(inner: Box<dyn Harvester>, offset_us: u64) -> Self {
        PhaseShift { inner, offset_us }
    }
}

impl Harvester for PhaseShift {
    fn power_w(&self, t_us: u64) -> f64 {
        self.inner.power_w(t_us.saturating_add(self.offset_us))
    }

    /// The inner segment end, translated back into local time.
    fn segment_end_us(&self, t_us: u64) -> u64 {
        let shifted = t_us.saturating_add(self.offset_us);
        let end = self
            .inner
            .segment_end_us(shifted)
            .max(shifted.saturating_add(1));
        // u64::MAX means "one segment forever" — keep it untranslated so
        // the event kernel still sees an unbounded span
        if end == u64::MAX {
            u64::MAX
        } else {
            end - self.offset_us
        }
    }

    fn mean_power_w(&self, from_us: u64, to_us: u64) -> f64 {
        self.inner.mean_power_w(
            from_us.saturating_add(self.offset_us),
            to_us.saturating_add(self.offset_us),
        )
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn analytic(&self) -> bool {
        self.inner.analytic()
    }
}

/// Constant power source (unit tests, pre-inspection rig).
#[derive(Debug, Clone)]
pub struct Constant(pub f64);

impl Harvester for Constant {
    fn power_w(&self, _t_us: u64) -> f64 {
        self.0
    }
    fn segment_end_us(&self, _t_us: u64) -> u64 {
        u64::MAX // one segment forever
    }
    fn name(&self) -> &'static str {
        "constant"
    }
}

/// Replay a recorded power trace (piecewise constant, sorted by time).
#[derive(Debug, Clone)]
pub struct Trace {
    pub points: Vec<(u64, f64)>,
}

impl Trace {
    /// Load a trace from a CSV file of `t_us,power_w` rows (the preset
    /// corpus under `examples/traces/`). Blank lines and `#` comments are
    /// skipped; times must be strictly increasing and powers non-negative.
    pub fn from_csv(path: &str) -> crate::error::Result<Trace> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            crate::error::Error::Config(format!("cannot read trace `{path}`: {e}"))
        })?;
        let points = Self::parse_csv(&text)
            .map_err(|e| crate::error::Error::Config(format!("trace `{path}`: {e}")))?;
        Ok(Trace { points })
    }

    /// Parse CSV text into trace points (see [`Trace::from_csv`]).
    pub fn parse_csv(text: &str) -> std::result::Result<Vec<(u64, f64)>, String> {
        let mut points = Vec::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut cols = line.split(',').map(str::trim);
            let (t, p) = match (cols.next(), cols.next(), cols.next()) {
                (Some(t), Some(p), None) => (t, p),
                _ => return Err(format!("line {}: expected `t_us,power_w`", ln + 1)),
            };
            let t: u64 = t
                .parse()
                .map_err(|_| format!("line {}: bad time `{t}`", ln + 1))?;
            let p: f64 = p
                .parse()
                .map_err(|_| format!("line {}: bad power `{p}`", ln + 1))?;
            if p < 0.0 || !p.is_finite() {
                return Err(format!("line {}: power {p} must be finite and >= 0", ln + 1));
            }
            if let Some(&(prev, _)) = points.last() {
                if t <= prev {
                    return Err(format!(
                        "line {}: time {t} not after previous point {prev}",
                        ln + 1
                    ));
                }
            }
            points.push((t, p));
        }
        if points.is_empty() {
            return Err("no data rows (a permanently 0 W world)".into());
        }
        Ok(points)
    }
}

impl Harvester for Trace {
    fn power_w(&self, t_us: u64) -> f64 {
        let mut p = 0.0;
        for &(start, pw) in &self.points {
            if t_us >= start {
                p = pw;
            } else {
                break;
            }
        }
        p
    }
    /// Traces are exactly piecewise constant: the segment runs to the next
    /// trace point.
    fn segment_end_us(&self, t_us: u64) -> u64 {
        let idx = self.points.partition_point(|&(start, _)| start <= t_us);
        self.points.get(idx).map(|&(start, _)| start).unwrap_or(u64::MAX)
    }
    fn name(&self) -> &'static str {
        "trace"
    }
    /// A recording's future is unknowable to the device replaying it:
    /// forecast it causally (EWMA) instead of reading ahead.
    fn analytic(&self) -> bool {
        false
    }
}

/// Enum wrapper so app configs can own a harvester without trait objects.
#[derive(Debug, Clone)]
pub enum HarvesterKind {
    Solar(Solar),
    Rf(Rf),
    Piezo(Piezo),
    Constant(Constant),
    Trace(Trace),
}

impl Harvester for HarvesterKind {
    fn power_w(&self, t_us: u64) -> f64 {
        match self {
            HarvesterKind::Solar(h) => h.power_w(t_us),
            HarvesterKind::Rf(h) => h.power_w(t_us),
            HarvesterKind::Piezo(h) => h.power_w(t_us),
            HarvesterKind::Constant(h) => h.power_w(t_us),
            HarvesterKind::Trace(h) => h.power_w(t_us),
        }
    }

    fn segment_end_us(&self, t_us: u64) -> u64 {
        match self {
            HarvesterKind::Solar(h) => h.segment_end_us(t_us),
            HarvesterKind::Rf(h) => h.segment_end_us(t_us),
            HarvesterKind::Piezo(h) => h.segment_end_us(t_us),
            HarvesterKind::Constant(h) => h.segment_end_us(t_us),
            HarvesterKind::Trace(h) => h.segment_end_us(t_us),
        }
    }

    fn mean_power_w(&self, from_us: u64, to_us: u64) -> f64 {
        match self {
            HarvesterKind::Solar(h) => h.mean_power_w(from_us, to_us),
            HarvesterKind::Rf(h) => h.mean_power_w(from_us, to_us),
            HarvesterKind::Piezo(h) => h.mean_power_w(from_us, to_us),
            HarvesterKind::Constant(h) => h.mean_power_w(from_us, to_us),
            HarvesterKind::Trace(h) => h.mean_power_w(from_us, to_us),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            HarvesterKind::Solar(h) => h.name(),
            HarvesterKind::Rf(h) => h.name(),
            HarvesterKind::Piezo(h) => h.name(),
            HarvesterKind::Constant(h) => h.name(),
            HarvesterKind::Trace(h) => h.name(),
        }
    }

    fn analytic(&self) -> bool {
        match self {
            HarvesterKind::Solar(h) => h.analytic(),
            HarvesterKind::Rf(h) => h.analytic(),
            HarvesterKind::Piezo(h) => h.analytic(),
            HarvesterKind::Constant(h) => h.analytic(),
            HarvesterKind::Trace(h) => h.analytic(),
        }
    }
}

// ------------------------------------------------------------- forecast

/// Exact mean power over `[from_us, to_us)` read off a harvester's
/// piecewise view: walk the segments covering the span and weight each
/// segment's closed-form mean by the part of the span it covers. This is
/// the "an analytic harvester is already a forecast" primitive of the
/// forecast-aware policy mode — the same view the event charge kernel
/// integrates, evaluated ahead of `now` instead of behind it.
///
/// The walk is capped (pathologically fine textures, e.g. second-granular
/// piezo gestures over a long span); past the cap the last reached
/// instant's power is held across the remainder, which keeps the result
/// deterministic and the cost bounded.
pub fn piecewise_mean_w(h: &dyn Harvester, from_us: u64, to_us: u64) -> f64 {
    const MAX_SEGMENTS: usize = 96;
    if to_us <= from_us {
        return h.power_w(from_us);
    }
    let mut t = from_us;
    let mut acc = 0.0;
    for _ in 0..MAX_SEGMENTS {
        let end = h.segment_end_us(t).max(t.saturating_add(1)).min(to_us);
        acc += h.mean_power_w(t, end) * (end - t) as f64;
        t = end;
        if t >= to_us {
            return acc / (to_us - from_us) as f64;
        }
    }
    acc += h.power_w(t) * (to_us - t) as f64;
    acc / (to_us - from_us) as f64
}

/// Causal exponentially-weighted moving average of observed harvest
/// power, for harvesters whose future is a recording the device cannot
/// read ahead ([`Trace`]). Samples arrive at irregular intervals (wake
/// and sleep boundaries), so the blend weight is time-based; the decay
/// uses the rational form `w = dt / (dt + tau)` rather than
/// `1 - exp(-dt/tau)` — same fixed point, same monotone saturation, but
/// exactly reproducible across platforms and trivially replayable, which
/// the determinism pins require. State is deliberately volatile: a
/// device rebooting from NVM re-primes from the power it then observes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ewma {
    /// Decay time constant, µs.
    pub tau_us: u64,
    est_w: f64,
    last_us: u64,
    primed: bool,
}

impl Ewma {
    pub fn new(tau_us: u64) -> Ewma {
        Ewma { tau_us: tau_us.max(1), est_w: 0.0, last_us: 0, primed: false }
    }

    /// Blend in an observed instantaneous power at `t_us`. The first
    /// sample primes the estimate; out-of-order or same-instant samples
    /// are ignored (dt = 0 carries no information under a time-based
    /// decay).
    pub fn observe(&mut self, t_us: u64, p_w: f64) {
        if !self.primed {
            self.est_w = p_w;
            self.last_us = t_us;
            self.primed = true;
            return;
        }
        let dt = t_us.saturating_sub(self.last_us);
        if dt == 0 {
            return;
        }
        let w = dt as f64 / (dt + self.tau_us) as f64;
        self.est_w += (p_w - self.est_w) * w;
        self.last_us = t_us;
    }

    /// Current estimate of the mean harvest power, W (0 until primed).
    pub fn mean_power_w(&self) -> f64 {
        self.est_w
    }
}

/// A short-horizon energy forecast over a harvester.
///
/// Analytic harvesters ([`Harvester::analytic`]) evaluate a closed-form
/// model, so their piecewise view *is* the forecast — `Exact` just walks
/// it forward via [`piecewise_mean_w`]. Recorded traces get `Ewma`: the
/// causal estimator a deployed device could actually run.
#[derive(Debug, Clone)]
pub enum Forecast {
    /// Read the harvester's own piecewise model forward.
    Exact,
    /// Predict the future mean as the EWMA of power observed so far.
    Ewma(Ewma),
}

impl Forecast {
    /// Default EWMA decay: 2 simulated minutes. Chosen against the
    /// recorded preset corpus (`python/tools/forecast_mirror.py` scans
    /// the candidates): short enough to track the minute-granular
    /// walk/idle gestures of `kinetic_walk` (a 10 min decay lags them
    /// into uselessness), long enough to smooth single-sample glitches
    /// in the office-RF duty cycle.
    pub const EWMA_TAU_US: u64 = 120_000_000;

    /// The right forecaster for `h`: exact piecewise lookahead for
    /// analytic models, EWMA for recordings.
    pub fn for_harvester(h: &dyn Harvester) -> Forecast {
        if h.analytic() {
            Forecast::Exact
        } else {
            Forecast::Ewma(Ewma::new(Self::EWMA_TAU_US))
        }
    }

    /// Feed an observed instantaneous power sample (no-op for `Exact`,
    /// which needs no history).
    pub fn observe(&mut self, t_us: u64, p_w: f64) {
        if let Forecast::Ewma(e) = self {
            e.observe(t_us, p_w);
        }
    }

    /// Predicted mean harvest power (W) over `[from_us, to_us)`.
    pub fn mean_power_w(&self, h: &dyn Harvester, from_us: u64, to_us: u64) -> f64 {
        match self {
            Forecast::Exact => piecewise_mean_w(h, from_us, to_us),
            Forecast::Ewma(e) => e.mean_power_w(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn us(h: f64) -> u64 {
        (h * 3600.0 * 1e6) as u64
    }

    #[test]
    fn solar_dark_at_night_bright_at_noon() {
        let s = Solar::default();
        assert_eq!(s.power_w(us(0.0)), 0.0);
        assert_eq!(s.power_w(us(23.0)), 0.0);
        let noon = s.power_w(us(12.5));
        assert!(noon > 0.0_f64);
        assert!(noon <= s.peak_w);
        // noon beats early morning on average over several days
        let avg = |hr: f64| -> f64 {
            (0..5).map(|d| s.power_w(us(hr + 24.0 * d as f64))).sum::<f64>() / 5.0
        };
        assert!(avg(12.5) > avg(6.5));
    }

    #[test]
    fn solar_deterministic() {
        let s = Solar::default();
        assert_eq!(s.power_w(us(10.0)), s.power_w(us(10.0)));
    }

    #[test]
    fn rf_follows_inverse_square() {
        let mut rf = Rf::default();
        rf.schedule = vec![(0, 3.0), (us(1.0), 6.0)];
        // average over fading
        let avg = |t0: u64| -> f64 {
            (0..100).map(|i| rf.power_w(t0 + i * 1_000_000)).sum::<f64>() / 100.0
        };
        let p3 = avg(0);
        let p6 = avg(us(2.0));
        let ratio = p3 / p6;
        assert!((ratio - 4.0).abs() < 0.8, "ratio {ratio}");
    }

    #[test]
    fn rf_distance_schedule_lookup() {
        let mut rf = Rf::default();
        rf.schedule = vec![(0, 3.0), (100, 5.0), (200, 7.0)];
        assert_eq!(rf.distance_m(0), 3.0);
        assert_eq!(rf.distance_m(150), 5.0);
        assert_eq!(rf.distance_m(999), 7.0);
    }

    #[test]
    fn piezo_idle_is_zero_shaking_is_positive() {
        let profile = MotionProfile::alternating_hours(1.2, 3.5, 4);
        let p = Piezo::new(profile.clone());
        // during a gentle gesture: power in the PPA-2014 range
        let g0 = profile.gesture_start(10) + 1_000;
        assert!(p.power_w(g0) >= 0.0018);
        assert!(p.power_w(g0) <= 0.0365);
        // between gestures: zero (no motion, no energy — §2.3 correlation)
        assert_eq!(p.power_w(profile.episodes[10].end_us + 100_000), 0.0);
        // abrupt gestures harvest more than gentle ones on average
        let avg = |base: usize| -> f64 {
            (0..50)
                .map(|i| p.power_w(profile.gesture_start(base + i) + 1_000))
                .sum::<f64>()
                / 50.0
        };
        assert!(avg(100) > avg(0)); // hour 1 (abrupt) vs hour 0 (gentle)
    }

    #[test]
    fn combined_switches_to_best_source() {
        // indoor RF by night, solar by day (the CapBand pattern)
        let solar = Solar::default();
        let mut rf = Rf::default();
        rf.schedule = vec![(0, 6.0)]; // weak-ish RF, always on
        let c = Combined::new(vec![Box::new(solar.clone()), Box::new(rf.clone())]);
        // night: solar = 0, RF > 0 -> prefers RF and delivers its power
        let night = us(2.0);
        assert_eq!(c.preferred(night), 1);
        assert!(c.power_w(night) > 0.0);
        assert_eq!(c.power_w(night), rf.power_w(night));
        // noon: solar beats the 6 m RF link
        let noon = us(12.5);
        assert_eq!(c.preferred(noon), 0);
        assert!(c.power_w(noon) >= solar.power_w(noon));
    }

    #[test]
    fn solar_segments_jump_darkness_and_stop_at_sunset() {
        let s = Solar::default();
        // midnight: one segment to sunrise
        assert_eq!(s.segment_end_us(0), us(6.0));
        // after sunset: one segment to the NEXT day's sunrise
        assert_eq!(s.segment_end_us(us(20.0)), us(24.0 + 6.0));
        // daylight: segment runs to sunset (mean integrates the texture)
        assert_eq!(s.segment_end_us(us(12.0)), us(19.0));
        // darkness means zero mean power
        assert_eq!(s.mean_power_w(us(0.5), us(5.5)), 0.0);
    }

    #[test]
    fn solar_mean_matches_fine_stepped_average() {
        let s = Solar::default();
        // compare the closed-form mean against brute-force 1 s sampling
        // over several daylight spans (incl. sunrise/sunset partial cover)
        for (a, b) in [(7.0, 9.0), (11.9, 12.4), (5.5, 7.0), (18.0, 20.0)] {
            let (a_us, b_us) = (us(a), us(b));
            let n = ((b_us - a_us) / 1_000_000) as usize;
            let brute: f64 = (0..n)
                .map(|i| s.power_w(a_us + i as u64 * 1_000_000))
                .sum::<f64>()
                / n as f64;
            let mean = s.mean_power_w(a_us, b_us);
            let tol = (0.03 * brute).max(1e-4);
            assert!(
                (mean - brute).abs() < tol,
                "span {a}-{b}h: closed-form {mean} vs stepped {brute}"
            );
        }
    }

    #[test]
    fn solar_mean_time_weights_partial_boundary_minutes() {
        let s = Solar::default();
        // asymmetric 20 s window straddling a minute edge at noon (sin is
        // flat there, so the brute average isolates the texture weighting:
        // 15 s of one cloud minute, 5 s of the next)
        let a = 720 * 60_000_000 + 45_000_000u64;
        let b = 721 * 60_000_000 + 5_000_000u64;
        let n = ((b - a) / 1_000_000) as usize;
        let brute: f64 =
            (0..n).map(|i| s.power_w(a + i as u64 * 1_000_000)).sum::<f64>() / n as f64;
        let mean = s.mean_power_w(a, b);
        assert!(
            (mean - brute).abs() < 0.003 * brute.max(1e-9),
            "weighted {mean} vs brute {brute}"
        );
    }

    #[test]
    fn rf_segments_hold_fading_per_minute_and_split_at_schedule_changes() {
        let mut rf = Rf::default();
        // mid-minute schedule change at t = 90 s
        rf.schedule = vec![(0, 3.0), (90_000_000, 6.0)];
        // minute-aligned fading hold
        assert_eq!(rf.segment_end_us(0), 60_000_000);
        assert_eq!(rf.segment_end_us(61_000_000), 90_000_000); // clipped at the change
        assert_eq!(rf.segment_end_us(90_000_000), 120_000_000); // next minute
        assert_eq!(rf.segment_end_us(130_000_000), 180_000_000);
        // mean over a segment integrates the per-second fading exactly
        let brute: f64 = (60..90).map(|s| rf.power_w(s * 1_000_000)).sum::<f64>() / 30.0;
        let mean = rf.mean_power_w(60_000_000, 90_000_000);
        assert!((mean - brute).abs() < 1e-9 * brute.max(1e-9), "{mean} vs {brute}");
        // partial boundary seconds are weighted by coverage: brute at
        // 100 ms over an unaligned span aligns exactly with the weighting
        let brute: f64 =
            (0..20).map(|i| rf.power_w(60_500_000 + i * 100_000)).sum::<f64>() / 20.0;
        let mean = rf.mean_power_w(60_500_000, 62_500_000);
        assert!((mean - brute).abs() < 1e-9 * brute.max(1e-9), "{mean} vs {brute}");
        // segments always advance
        for t in [0u64, 59_999_999, 89_999_999, 90_000_000, 7_777_777_777] {
            assert!(rf.segment_end_us(t) > t, "t={t}");
        }
    }

    #[test]
    fn piezo_segments_jump_idle_gaps() {
        let profile = MotionProfile::alternating_hours(1.2, 3.5, 2);
        let p = Piezo::new(profile.clone());
        // idle between gestures: one segment to the next gesture
        let gap_t = profile.episodes[0].end_us + 1_000;
        assert_eq!(p.segment_end_us(gap_t), profile.episodes[1].start_us);
        // shaking: bounded at second granularity (per-second jitter)
        let g = profile.gesture_start(3) + 1_500;
        let end = p.segment_end_us(g);
        assert!(end <= (g / 1_000_000 + 1) * 1_000_000, "{g} -> {end}");
        assert!(end > g);
    }

    #[test]
    fn constant_and_trace_segments_are_exact() {
        assert_eq!(Constant(0.01).segment_end_us(123), u64::MAX);
        assert_eq!(Constant(0.01).mean_power_w(0, 1_000_000), 0.01);
        let t = Trace {
            points: vec![(0, 0.0), (50, 0.5), (100, 0.25)],
        };
        assert_eq!(t.segment_end_us(0), 50);
        assert_eq!(t.segment_end_us(50), 100);
        assert_eq!(t.segment_end_us(777), u64::MAX);
        assert_eq!(t.mean_power_w(60, 90), 0.5);
    }

    #[test]
    fn default_piecewise_view_is_conservative() {
        // a harvester that only implements the required methods still
        // exposes a usable (short-segment) piecewise view
        struct Custom;
        impl Harvester for Custom {
            fn power_w(&self, _t: u64) -> f64 {
                0.002
            }
            fn name(&self) -> &'static str {
                "custom"
            }
        }
        let c = Custom;
        assert_eq!(c.segment_end_us(1_000), 1_000 + 1_000_000);
        assert_eq!(c.mean_power_w(0, 5_000_000), 0.002);
    }

    #[test]
    fn phase_shift_translates_the_whole_piecewise_view() {
        let trace = || Trace {
            points: vec![(0, 0.0), (100, 0.5), (250, 0.25)],
        };
        let p = PhaseShift::new(Box::new(trace()), 100);
        // local t=0 sees the trace at t=100
        assert_eq!(p.power_w(0), 0.5);
        assert_eq!(p.power_w(150), 0.25);
        // segment ends come back in local time
        assert_eq!(p.segment_end_us(0), 150);
        assert_eq!(p.segment_end_us(200), u64::MAX);
        assert_eq!(p.mean_power_w(0, 150), 0.5);
        // zero offset is exactly the inner harvester
        let id = PhaseShift::new(Box::new(trace()), 0);
        for t in [0u64, 99, 100, 249, 250, 1_000] {
            assert_eq!(id.power_w(t), trace().power_w(t));
            assert_eq!(id.segment_end_us(t), trace().segment_end_us(t));
        }
        // solar: a 6 h offset turns midnight into dawn
        let s = Solar::default();
        let shifted = PhaseShift::new(Box::new(s.clone()), us(6.5));
        assert_eq!(shifted.power_w(us(6.0)), s.power_w(us(12.5)));
        assert_eq!(shifted.name(), "solar");
    }

    #[test]
    fn trace_csv_parses_and_rejects_bad_rows() {
        let pts = Trace::parse_csv(
            "# irradiance trace\n\n0, 0.0\n100, 0.5\n  250 , 0.25 \n",
        )
        .unwrap();
        assert_eq!(pts, vec![(0, 0.0), (100, 0.5), (250, 0.25)]);
        // non-increasing times, negative power, malformed rows, empty file
        assert!(Trace::parse_csv("0,0.1\n0,0.2").unwrap_err().contains("line 2"));
        assert!(Trace::parse_csv("0,-0.1").unwrap_err().contains(">= 0"));
        assert!(Trace::parse_csv("0;0.1").unwrap_err().contains("t_us,power_w"));
        assert!(Trace::parse_csv("0,0.1,9").unwrap_err().contains("t_us,power_w"));
        assert!(Trace::parse_csv("# only comments\n").is_err());
        assert!(Trace::from_csv("/nonexistent/trace.csv").is_err());
    }

    #[test]
    fn trace_csv_rejects_every_malformed_row_with_its_line_number() {
        // each rejection class, one by one, with the offending line named
        // (comments/blank lines still count toward the line numbers)
        let case = |text: &str| Trace::parse_csv(text).unwrap_err();
        // NaN power: Rust's f64 parser happily accepts "NaN" — the
        // validator must not
        let e = case("0,0.1\n# mid comment\n100,NaN");
        assert!(e.contains("line 3") && e.contains("finite"), "{e}");
        // infinities are equally non-physical
        let e = case("0,inf");
        assert!(e.contains("line 1") && e.contains("finite"), "{e}");
        let e = case("0,0.1\n100,-inf");
        assert!(e.contains("line 2"), "{e}");
        // negative power mid-file
        let e = case("0,0.1\n100,0.2\n200,-0.3");
        assert!(e.contains("line 3") && e.contains(">= 0"), "{e}");
        // time going backwards (not just repeating)
        let e = case("0,0.1\n500,0.2\n400,0.3");
        assert!(e.contains("line 3") && e.contains("not after"), "{e}");
        // unparseable time: fractional, negative, empty
        for bad_t in ["1.5,0.1", "-10,0.1", ",0.1"] {
            let e = case(bad_t);
            assert!(e.contains("line 1") && e.contains("bad time"), "{bad_t}: {e}");
        }
        // unparseable power
        let e = case("0,watts");
        assert!(e.contains("line 1") && e.contains("bad power"), "{e}");
        // and the path-level wrapper names the file for spec errors
        let e = Trace::from_csv("/nonexistent/dir/t.csv").unwrap_err().to_string();
        assert!(e.contains("/nonexistent/dir/t.csv"), "{e}");
    }

    #[test]
    fn trace_replay() {
        let t = Trace {
            points: vec![(0, 0.0), (50, 0.5), (100, 0.25)],
        };
        assert_eq!(t.power_w(10), 0.0);
        assert_eq!(t.power_w(60), 0.5);
        assert_eq!(t.power_w(1000), 0.25);
    }

    #[test]
    fn forecast_picks_exact_for_models_and_ewma_for_recordings() {
        for h in [
            Box::new(Solar::default()) as Box<dyn Harvester>,
            Box::new(Rf::default()),
            Box::new(Piezo::new(MotionProfile::alternating_hours(1.2, 3.5, 4))),
            Box::new(Constant(0.01)),
        ] {
            assert!(h.analytic(), "{}", h.name());
            assert!(matches!(Forecast::for_harvester(h.as_ref()), Forecast::Exact));
        }
        let trace = Trace { points: vec![(0, 0.01)] };
        assert!(!trace.analytic());
        assert!(matches!(
            Forecast::for_harvester(&trace),
            Forecast::Ewma(_)
        ));
        // wrappers follow their contents
        let shifted = PhaseShift::new(Box::new(trace.clone()), 1_000_000);
        assert!(!shifted.analytic());
        let shifted = PhaseShift::new(Box::new(Constant(0.01)), 1_000_000);
        assert!(shifted.analytic());
        let mix = Combined::new(vec![Box::new(Constant(0.01)), Box::new(trace)]);
        assert!(!mix.analytic());
    }

    #[test]
    fn piecewise_mean_is_exact_across_trace_segments() {
        let t = Trace {
            points: vec![(0, 0.0), (50, 0.5), (100, 0.25)],
        };
        // [25, 125): 25 µs of 0.0 + 50 µs of 0.5 + 25 µs of 0.25
        let want = (25.0 * 0.0 + 50.0 * 0.5 + 25.0 * 0.25) / 100.0;
        assert_eq!(piecewise_mean_w(&t, 25, 125), want);
        // degenerate span holds the instantaneous power
        assert_eq!(piecewise_mean_w(&t, 60, 60), 0.5);
        // exact forecast == the view itself, even through Forecast
        assert_eq!(Forecast::Exact.mean_power_w(&t, 25, 125), want);
    }

    /// The EWMA unit tests mirror `python/tools/forecast_mirror.py` (same
    /// cadence, lookahead and per-trace ceilings); keep the two in sync.
    fn ewma_replay(trace: &Trace) -> (Vec<u64>, f64) {
        const STEP_US: u64 = 30_000_000;
        const LOOKAHEAD_US: u64 = 600_000_000;
        let span = trace.points.last().unwrap().0;
        let mut ewma = Ewma::new(Forecast::EWMA_TAU_US);
        let (mut abs_err, mut base) = (0.0, 0.0);
        let mut bits = Vec::new();
        let mut t = trace.points[0].0;
        while t + LOOKAHEAD_US <= span {
            ewma.observe(t, trace.power_w(t));
            bits.push(ewma.mean_power_w().to_bits());
            let future = piecewise_mean_w(trace, t, t + LOOKAHEAD_US);
            abs_err += (ewma.mean_power_w() - future).abs();
            base += future;
            t += STEP_US;
        }
        assert!(base > 0.0);
        (bits, abs_err / base)
    }

    #[test]
    fn ewma_tracks_the_recorded_preset_traces() {
        // ceilings = forecast_mirror.py's, with slack above the measured
        // 0.6562 / 0.1415 / 0.0720; ≥ 1.0 would mean the estimator is no
        // better than predicting zero
        for (name, bound) in [
            ("kinetic_walk", 0.75),
            ("rf_office", 0.20),
            ("solar_day", 0.12),
        ] {
            let trace =
                Trace::from_csv(&format!("../examples/traces/{name}.csv")).unwrap();
            let (_, rel) = ewma_replay(&trace);
            assert!(rel < bound, "{name}: EWMA relative error {rel} >= {bound}");
        }
    }

    #[test]
    fn ewma_replay_is_deterministic_across_restarts() {
        for name in ["kinetic_walk", "rf_office", "solar_day"] {
            let trace =
                Trace::from_csv(&format!("../examples/traces/{name}.csv")).unwrap();
            // a fresh estimator fed the same observations lands on
            // bit-identical state at every step — restarting the host (or
            // resuming a run) and replaying reproduces the forecast exactly
            let (a, _) = ewma_replay(&trace);
            let (b, _) = ewma_replay(&trace);
            assert_eq!(a, b, "{name}");
        }
    }

    #[test]
    fn ewma_priming_and_degenerate_samples() {
        let mut e = Ewma::new(Forecast::EWMA_TAU_US);
        assert_eq!(e.mean_power_w(), 0.0);
        e.observe(1_000_000, 0.04);
        assert_eq!(e.mean_power_w(), 0.04); // first sample primes exactly
        let primed = e;
        e.observe(1_000_000, 9.0); // same instant: no information
        assert_eq!(e, primed);
        e.observe(500_000, 9.0); // out of order: ignored
        assert_eq!(e, primed);
        // one decay constant later the estimate has moved halfway
        e.observe(1_000_000 + Forecast::EWMA_TAU_US, 0.0);
        assert!((e.mean_power_w() - 0.02).abs() < 1e-12);
        // Exact forecasts ignore observations entirely
        let mut f = Forecast::Exact;
        f.observe(0, 123.0);
        assert!(matches!(f, Forecast::Exact));
    }
}
